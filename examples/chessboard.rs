//! Figure 1: the 'chessboard' (XOR) vs 'tablecloth' (SUM) toy problems —
//! the paper's illustration of the non-linearity assumption.
//!
//! The linear pairwise kernel can only express `f(d,t) = f_d(d) + f_t(t)`
//! (a global drug ordering), so it fails on the XOR chessboard; the
//! Kronecker product kernel models drug×target feature interactions and
//! solves it.
//!
//! ```bash
//! cargo run --release --example chessboard
//! ```

use gvt_rls::data::chessboard::{ChessboardConfig, Pattern};
use gvt_rls::eval::auc;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};

fn evaluate(pattern: Pattern, kernel: PairwiseKernel) -> gvt_rls::error::Result<f64> {
    let data = ChessboardConfig::new(pattern).generate(3);
    let split = data.split_setting(1, 0.3, 11);
    let cfg = RidgeConfig { max_iters: 100, ..Default::default() };
    let model = PairwiseRidge::fit_early_stopping(&split.train, 1, kernel, &cfg, 11)?;
    let preds = model.predict(&split.test.pairs)?;
    Ok(auc(&preds, &split.test.binary_labels()).unwrap_or(f64::NAN))
}

fn main() -> gvt_rls::error::Result<()> {
    println!("Figure 1 — pairwise vs additive signal (test AUC, setting 1)\n");
    println!(
        "{:<14} {:>10} {:>10} {:>10}",
        "pattern", "linear", "poly2d", "kronecker"
    );
    for pattern in [Pattern::Chessboard, Pattern::Tablecloth] {
        let lin = evaluate(pattern, PairwiseKernel::Linear)?;
        let poly = evaluate(pattern, PairwiseKernel::Poly2D)?;
        let kron = evaluate(pattern, PairwiseKernel::Kronecker)?;
        println!("{:<14} {:>10.3} {:>10.3} {:>10.3}", format!("{pattern:?}"), lin, poly, kron);
    }
    println!(
        "\nExpected shape: linear ≈ 0.5 on Chessboard (XOR is outside its \
         hypothesis space — Minsky & Papert 1969) but ≈ 1.0 on Tablecloth; \
         the interaction kernels solve both."
    );
    Ok(())
}
