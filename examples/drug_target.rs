//! Drug–target interaction prediction workflow (the Metz task, §5.2 /
//! Figure 5): compare base kernels and pairwise kernels across the four
//! prediction settings, and plot the early-stopping curve (Figure 3).
//!
//! ```bash
//! cargo run --release --example drug_target
//! ```

use gvt_rls::data::metz::MetzConfig;
use gvt_rls::eval::auc;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::kernels::BaseKernel;
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};

fn main() -> gvt_rls::error::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let seed = 42;
    let base_cfg = if quick {
        MetzConfig::small()
    } else {
        MetzConfig { drugs: 80, targets: 250, density: 0.42, ..MetzConfig::small() }
    };
    let ridge = RidgeConfig { max_iters: if quick { 40 } else { 150 }, ..Default::default() };

    // --------------------------------------------------------------
    // Figure 5 shape: base kernel × pairwise kernel × setting.
    // --------------------------------------------------------------
    println!("# Drug–target interaction prediction (Metz-like)\n");
    for base in [BaseKernel::Linear, BaseKernel::Gaussian] {
        let data = base_cfg.clone().with_kernel(base).generate(seed);
        println!(
            "## base kernel: {} ({} pairs, {} drugs × {} targets)\n",
            base.name(),
            data.len(),
            data.pairs.m(),
            data.pairs.q()
        );
        println!(
            "| {:<11} | {:>7} | {:>7} | {:>7} | {:>7} |",
            "kernel", "S1", "S2", "S3", "S4"
        );
        for kernel in [
            PairwiseKernel::Linear,
            PairwiseKernel::Poly2D,
            PairwiseKernel::Kronecker,
            PairwiseKernel::Cartesian,
        ] {
            let mut cells = Vec::new();
            for setting in 1..=4u8 {
                let split = data.split_setting(setting, 0.25, seed);
                let model = PairwiseRidge::fit_early_stopping(
                    &split.train,
                    setting,
                    kernel,
                    &ridge,
                    seed,
                )?;
                let preds = model.predict(&split.test.pairs)?;
                cells.push(auc(&preds, &split.test.binary_labels()).unwrap_or(f64::NAN));
            }
            println!(
                "| {:<11} | {:>7.4} | {:>7.4} | {:>7.4} | {:>7.4} |",
                kernel.name(),
                cells[0],
                cells[1],
                cells[2],
                cells[3]
            );
        }
        println!();
    }

    // --------------------------------------------------------------
    // Figure 3 shape: validation AUC per iteration, small λ.
    // --------------------------------------------------------------
    println!("## Early stopping curve (Kronecker kernel, λ = 1e-5)\n");
    let data = base_cfg.generate(seed);
    let split = data.split_setting(1, 0.25, seed);
    let inner = split.train.split_setting(1, 0.25, seed ^ 1);
    let (best, history) = PairwiseRidge::find_optimal_iters(
        &inner.train,
        &inner.test,
        PairwiseKernel::Kronecker,
        &RidgeConfig {
            max_iters: if quick { 30 } else { 80 },
            patience: usize::MAX,
            ..Default::default()
        },
    )?;
    for p in history.iter().step_by(5) {
        let bar_len = ((p.validation_auc - 0.5).max(0.0) * 80.0) as usize;
        println!(
            "iter {:>4}  AUC {:.4}  {}",
            p.iteration,
            p.validation_auc,
            "█".repeat(bar_len)
        );
    }
    println!("\nbest validation AUC at iteration {best} — early stopping as regularization.");
    Ok(())
}
