//! Heterodimeric protein complex prediction (§5.1 / Figure 4): a
//! homogeneous pairwise task where the symmetric kernels apply, swept
//! over the three feature families.
//!
//! The paper's headline observation: the best pairwise kernel depends
//! strongly on the feature family (MLPK dominates on domain features;
//! Poly2D/Symmetric elsewhere).
//!
//! ```bash
//! cargo run --release --example heterodimer
//! ```

use gvt_rls::data::heterodimer::{HeterodimerConfig, ProteinFeature};
use gvt_rls::eval::auc;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};

fn main() -> gvt_rls::error::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let seed = 42;
    let cfg = if quick {
        HeterodimerConfig::small()
    } else {
        HeterodimerConfig {
            proteins: 400,
            pairs: 1600,
            positive_rate: 0.05,
            clusters: 50,
            feature_scale: 0.3,
        }
    };
    let ridge = RidgeConfig { max_iters: if quick { 40 } else { 120 }, ..Default::default() };
    let kernels = [
        PairwiseKernel::Linear,
        PairwiseKernel::Poly2D,
        PairwiseKernel::Kronecker,
        PairwiseKernel::Cartesian,
        PairwiseKernel::Symmetric,
        PairwiseKernel::Mlpk,
    ];

    println!("# Heterodimer prediction ({} proteins, {} pairs)\n", cfg.proteins, cfg.pairs);
    for feature in ProteinFeature::ALL {
        let data = cfg.generate(feature, seed);
        println!(
            "## features: {} (positives {:.1}%)\n",
            feature.name(),
            100.0 * data.positive_rate()
        );
        println!(
            "| {:<14} | {:>7} | {:>7} | {:>7} | {:>7} |",
            "kernel", "S1", "S2", "S3", "S4"
        );
        for kernel in kernels {
            let mut cells = Vec::new();
            for setting in 1..=4u8 {
                let split = data.split_setting(setting, 0.25, seed);
                let model = PairwiseRidge::fit_early_stopping(
                    &split.train,
                    setting,
                    kernel,
                    &ridge,
                    seed,
                )?;
                let preds = model.predict(&split.test.pairs)?;
                cells.push(auc(&preds, &split.test.binary_labels()).unwrap_or(f64::NAN));
            }
            println!(
                "| {:<14} | {:>7.4} | {:>7.4} | {:>7.4} | {:>7.4} |",
                kernel.name(),
                cells[0],
                cells[1],
                cells[2],
                cells[3]
            );
        }
        println!();
    }
    println!("Note how kernel ranking shifts with the feature family — Figure 4's finding.");
    Ok(())
}
