//! END-TO-END DRIVER — the paper's kernel-filling scalability experiment
//! (§6.4, Figure 7) run as a real workload through the full stack:
//! dataset generation → Settings 1–4 splits → early-stopped MINRES
//! training with GVT mat-vecs → predictions → AUC, with the explicit
//! O(n²) baseline raced head-to-head until it hits the memory cutoff,
//! and (when `make artifacts` has been run) the AOT-compiled XLA/Pallas
//! mat-vec cross-checked against the rust-native one on the live problem.
//!
//! ```bash
//! cargo run --release --example kernel_filling            # full run
//! cargo run --release --example kernel_filling -- --quick # smoke
//! ```
//!
//! Background on the GVT factorizations and the dense-formulation trade
//! this example races is in rust/DESIGN.md (§GVT-Factorizations,
//! §Hardware-Adaptation).

use gvt_rls::coordinator::memory::{format_bytes, peak_bytes, reset_peak, TrackingAlloc};
use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::eval::auc;
use gvt_rls::gvt::explicit::ExplicitLinOp;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};
use gvt_rls::obs::clock;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

/// Baseline memory cutoff: the paper stopped the naive method at 16 GiB;
/// we scale the story down to keep the example runnable everywhere.
const BASELINE_MEM_CUTOFF: usize = 2 << 30; // 2 GiB

fn main() -> gvt_rls::error::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let seed = 42;
    let cfg = KernelFillingConfig::small();
    let (k, sizes): (usize, Vec<usize>) = if quick {
        (48, vec![500, 1000, 2000])
    } else {
        (192, vec![1_000, 4_000, 16_000, 32_000])
    };
    let ridge = RidgeConfig {
        max_iters: if quick { 40 } else { 120 },
        patience: 8,
        ..Default::default()
    };

    println!("# Kernel filling end-to-end (k = {k} drugs, GVT vs explicit baseline)\n");

    // ------------------------------------------------------------------
    // Part 1 — Figure 7 scalability race: N sweep, Kronecker kernel.
    // ------------------------------------------------------------------
    println!("## Part 1 — scalability (setting 1, Kronecker kernel)\n");
    println!(
        "| {:>7} | {:>9} | {:>11} | {:>11} | {:>11} | {:>11} | {:>7} |",
        "N", "AUC", "gvt time", "gvt mem", "base time", "base mem", "speedup"
    );
    for &n in &sizes {
        let data = cfg.generate(k, n, seed);
        let split = data.split_setting(1, 0.25, seed);

        // GVT method.
        reset_peak();
        let t0 = clock::now();
        let model = PairwiseRidge::fit_early_stopping(
            &split.train,
            1,
            PairwiseKernel::Kronecker,
            &ridge,
            seed,
        )?;
        let gvt_secs = t0.elapsed().as_secs_f64();
        let gvt_mem = peak_bytes();
        let preds = model.predict(&split.test.pairs)?;
        let a = auc(&preds, &split.test.binary_labels()).unwrap_or(f64::NAN);

        // Explicit baseline (identical solver; only the mat-vec differs),
        // skipped once its K matrix would cross the cutoff.
        let ntr = split.train.len();
        let baseline_bytes = ntr * ntr * 8;
        let (base_time, base_mem, speedup) = if baseline_bytes > BASELINE_MEM_CUTOFF {
            ("OOM".to_string(), format_bytes(baseline_bytes), "∞".to_string())
        } else {
            reset_peak();
            let t1 = clock::now();
            let op = ExplicitLinOp::new(
                PairwiseKernel::Kronecker,
                &split.train.d,
                &split.train.t,
                &split.train.pairs,
                &split.train.pairs,
            );
            let (_alpha, _iters) =
                PairwiseRidge::fit_with_op(&op, &split.train.y, &ridge, model.iterations)
                    .unwrap();
            let base_secs = t1.elapsed().as_secs_f64();
            (
                format!("{base_secs:>9.2}s"),
                format_bytes(peak_bytes()),
                format!("{:.1}×", base_secs / gvt_secs.max(1e-9)),
            )
        };
        println!(
            "| {:>7} | {:>9.4} | {:>10.2}s | {:>11} | {:>11} | {:>11} | {:>7} |",
            n,
            a,
            gvt_secs,
            format_bytes(gvt_mem),
            base_time,
            base_mem,
            speedup
        );
    }

    // ------------------------------------------------------------------
    // Part 2 — all kernels × all settings at one size (Fig 7 AUC panel).
    // ------------------------------------------------------------------
    let n = *sizes.last().unwrap();
    let data = cfg.generate(k, n, seed);
    println!("\n## Part 2 — AUC by kernel and setting (N = {n})\n");
    println!(
        "| {:<14} | {:>7} | {:>7} | {:>7} | {:>7} | {:>6} |",
        "kernel", "S1", "S2", "S3", "S4", "iters"
    );
    for kernel in [
        PairwiseKernel::Linear,
        PairwiseKernel::Poly2D,
        PairwiseKernel::Kronecker,
        PairwiseKernel::Cartesian,
        PairwiseKernel::Symmetric,
        PairwiseKernel::Mlpk,
    ] {
        let mut cells = Vec::new();
        let mut iters = 0;
        for setting in 1..=4u8 {
            let split = data.split_setting(setting, 0.25, seed);
            let model =
                PairwiseRidge::fit_early_stopping(&split.train, setting, kernel, &ridge, seed)?;
            iters = iters.max(model.iterations);
            let preds = model.predict(&split.test.pairs)?;
            cells.push(auc(&preds, &split.test.binary_labels()).unwrap_or(f64::NAN));
        }
        println!(
            "| {:<14} | {:>7.4} | {:>7.4} | {:>7.4} | {:>7.4} | {:>6} |",
            kernel.name(),
            cells[0],
            cells[1],
            cells[2],
            cells[3],
            iters
        );
    }

    // ------------------------------------------------------------------
    // Part 3 — the three-layer stack: run the same mat-vec through the
    // AOT-compiled JAX/Pallas artifact and cross-check.
    // ------------------------------------------------------------------
    println!("\n## Part 3 — XLA artifact cross-check\n");
    match gvt_rls::runtime::Registry::discover() {
        None => println!("(artifacts not built — run `make artifacts` to enable this part)"),
        Some(reg) => {
            let small = cfg.generate(64.min(k), 2000.min(n), seed);
            match reg.pick(small.pairs.m(), small.pairs.q()) {
                None => println!("(no artifact bucket covers m=q={})", small.pairs.m()),
                Some(meta) => {
                    let exec = gvt_rls::runtime::KronExec::load(&reg, meta)?;
                    let a: Vec<f64> =
                        (0..small.len()).map(|i| ((i % 11) as f64) - 5.0).collect();
                    let t0 = clock::now();
                    let p_xla =
                        exec.matvec(&small.d, &small.t, &small.pairs, &small.pairs, &a)?;
                    let xla_secs = t0.elapsed().as_secs_f64();
                    let t1 = clock::now();
                    let p_rust = gvt_rls::gvt::vec_trick::gvt_matvec(
                        &small.d,
                        &small.t,
                        &small.pairs,
                        &small.pairs,
                        &a,
                        gvt_rls::gvt::vec_trick::GvtPolicy::Auto,
                    );
                    let rust_secs = t1.elapsed().as_secs_f64();
                    let err = gvt_rls::linalg::vecops::max_abs_diff(&p_xla, &p_rust);
                    let scale = p_rust.iter().fold(1.0f64, |m, x| m.max(x.abs()));
                    println!(
                        "artifact {}: XLA {:.4}s vs rust {:.4}s, max|Δ|/scale = {:.2e}",
                        exec.meta().name,
                        xla_secs,
                        rust_secs,
                        err / scale
                    );
                    assert!(err / scale < 1e-3, "XLA and rust paths disagree");
                }
            }
        }
    }

    println!("\nDone. See rust/DESIGN.md for the factorization and cost-model notes.");
    Ok(())
}
