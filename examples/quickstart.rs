//! Quickstart: train a pairwise kernel ridge model with the generalized
//! vec trick and evaluate it in all four prediction settings.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gvt_rls::data::metz::MetzConfig;
use gvt_rls::eval::auc;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};

fn main() -> gvt_rls::error::Result<()> {
    // 1. A drug–target interaction dataset: kernels over 40 drugs and 60
    //    targets plus ~1200 labeled pairs (Metz-like synthetic data).
    let data = MetzConfig::small().generate(7);
    println!(
        "dataset '{}': {} labeled pairs over {} drugs × {} targets ({:.0}% dense)",
        data.name,
        data.len(),
        data.pairs.m(),
        data.pairs.q(),
        100.0 * data.density()
    );

    // 2. Train with the paper's protocol (inner split → early stopping →
    //    refit) and evaluate each of the four settings of Table 1:
    //    known pairs / novel targets / novel drugs / both novel.
    let cfg = RidgeConfig::default();
    println!("\n{:<10} {:>22} {:>12} {:>10}", "setting", "task", "iterations", "AUC");
    for (setting, label) in [
        (1u8, "known drugs+targets"),
        (2, "novel targets"),
        (3, "novel drugs"),
        (4, "novel drugs+targets"),
    ] {
        let split = data.split_setting(setting, 0.25, 42);
        let model = PairwiseRidge::fit_early_stopping(
            &split.train,
            setting,
            PairwiseKernel::Kronecker,
            &cfg,
            42,
        )?;
        let preds = model.predict(&split.test.pairs)?;
        let a = auc(&preds, &split.test.binary_labels()).unwrap_or(f64::NAN);
        println!("{:<10} {:>22} {:>12} {:>10.4}", setting, label, model.iterations, a);
    }

    println!(
        "\nEvery training iteration and every prediction above ran in \
         O(nm + nq) via the generalized vec trick — the n×n pairwise \
         kernel matrix was never materialized."
    );
    Ok(())
}
