//! Online inference end-to-end: train, persist a self-contained v2
//! artifact, reload it into a `Predictor`, and serve concurrent clients
//! through the micro-batching dispatcher.
//!
//! ```bash
//! cargo run --release --example serve
//! ```
//!
//! The same predictor also backs the CLI:
//!
//! ```bash
//! gvt-rls train --quick --save-model /tmp/model.txt
//! gvt-rls serve --model /tmp/model.txt --listen 127.0.0.1:0 &
//! # then speak line-delimited JSON, e.g.:
//! #   {"id": 1, "pairs": [[0, 3], [5, 1]]}
//! #   {"cmd": "stats"}
//! #   {"cmd": "shutdown"}
//! ```

use gvt_rls::data::metz::MetzConfig;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::serve::{BatchConfig, Batcher, ObjectRef, Predictor, QueryPair, ServeOptions};
use gvt_rls::solvers::persist::{save_model_v2, EmbedV2};
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};
use std::sync::Arc;
use std::time::Duration;

fn main() -> gvt_rls::error::Result<()> {
    // 1. Train a model on the Metz-like drug–target task.
    let data = MetzConfig::small().generate(7);
    let cfg = RidgeConfig { max_iters: 60, ..Default::default() };
    let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg)?;
    println!(
        "trained: {} on '{}' ({} pairs, {}x{} domains, {} iterations)",
        model.kernel().name(),
        data.name,
        data.len(),
        data.pairs.m(),
        data.pairs.q(),
        model.iterations
    );

    // 2. Persist a v2 artifact that embeds the kernel matrices — a
    //    server starts from this single file.
    let path = std::env::temp_dir().join(format!("gvt_serve_example_{}.txt", std::process::id()));
    save_model_v2(&model, &path, &EmbedV2 { matrices: true, ..Default::default() })?;
    println!("saved self-contained artifact: {}", path.display());

    // 3. Reload for serving. The predictor compiles the prediction-side
    //    GVT plan against the training sample once, pins the
    //    factorization (bit-stable micro-batching), and keeps its
    //    workspace warm across batches.
    let predictor = Arc::new(Predictor::from_file(&path, ServeOptions::default())?);
    println!(
        "serving with pinned policy '{}', plan [{}]",
        predictor.policy().name(),
        predictor.plan_summary()
    );

    // 4. Micro-batched serving: 6 concurrent clients, each firing 1-pair
    //    requests; the dispatcher coalesces whatever lands within the
    //    200 µs window into one multi-row GVT pass.
    let batcher = Batcher::start(
        predictor.clone(),
        BatchConfig {
            max_batch: 128,
            max_wait: Duration::from_micros(200),
            ..Default::default()
        },
    );
    let mut clients = Vec::new();
    for c in 0..6u32 {
        let handle = batcher.handle();
        let (m, q) = (data.pairs.m() as u32, data.pairs.q() as u32);
        clients.push(std::thread::spawn(move || {
            let mut sum = 0.0;
            for k in 0..50u32 {
                let pair = QueryPair::known((c * 7 + k) % m, (c * 11 + k) % q);
                let scores = handle.score(vec![pair]).expect("scoring failed");
                sum += scores[0];
            }
            sum
        }));
    }
    for (c, th) in clients.into_iter().enumerate() {
        println!("client {c}: score sum {:+.4}", th.join().expect("client thread"));
    }
    batcher.shutdown();

    // 5. Queries are answered identically however they are phrased: by
    //    domain index, or (with an artifact that bundles feature spaces)
    //    by raw feature vector for objects the model never saw.
    let by_index = predictor.score(&[QueryPair::known(3, 5)])?;
    let same_again = predictor.score(&[QueryPair {
        drug: ObjectRef::Known(3),
        target: ObjectRef::Known(5),
    }])?;
    assert_eq!(by_index, same_again);
    println!("score(drug 3, target 5) = {:+.6}", by_index[0]);

    let stats = predictor.stats();
    println!(
        "dispatcher stats: {} requests → {} batches (largest batch: {} pairs)",
        stats.requests, stats.batches, stats.batch_pairs_max
    );
    std::fs::remove_file(&path).ok();
    Ok(())
}
