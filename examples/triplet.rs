//! Third-order (drug, target, cell-line) interaction prediction — the
//! paper's §7 future-work scenario, running on this library's third-order
//! generalized vec trick (`gvt::tensor`).
//!
//! Generates a synthetic triplet assay with a 3-way latent signal, trains
//! kernel ridge regression with MINRES where every `K·v` is a
//! `gvt3_matvec` (never the n×n matrix), and evaluates known-triplet and
//! novel-cell-line splits.
//!
//! ```bash
//! cargo run --release --example triplet
//! ```

use gvt_rls::eval::auc;
use gvt_rls::gvt::tensor::{gvt3_matvec, naive3_matvec, TensorKronOp, TripletIndex};
use gvt_rls::kernels::{kernel_matrix, BaseKernel, KernelParams};
use gvt_rls::linalg::Mat;
use gvt_rls::rng::{dist, Rng, Xoshiro256};
use gvt_rls::solvers::linear_op::ShiftedOp;
use gvt_rls::solvers::minres::{minres, MinresOptions};
use std::ops::ControlFlow;
use std::sync::Arc;
use gvt_rls::obs::clock;

fn latent_kernel(rng: &mut Xoshiro256, n: usize, r: usize) -> (Mat, Mat) {
    let u = Mat::from_vec(n, r, dist::normal_vec(rng, n * r));
    let features = Mat::from_fn(n, r + 2, |i, j| {
        if j < r {
            u[(i, j)] + 0.3 * dist::standard_normal(rng)
        } else {
            dist::standard_normal(rng)
        }
    });
    let k = kernel_matrix(
        BaseKernel::Gaussian,
        &KernelParams { gamma: 0.5 / r as f64, ..Default::default() },
        &features,
    );
    (u, k)
}

fn main() -> gvt_rls::error::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let seed = 42;
    let mut rng = Xoshiro256::seed_from(seed);
    let (m, q, c, r) = if quick { (20, 15, 8, 3) } else { (40, 30, 12, 4) };
    let n = if quick { 2_000 } else { 10_000 };

    // Latent 3-way chemistry and observed (noisy) kernels per mode.
    let (ud, d) = latent_kernel(&mut rng, m, r);
    let (vt, t) = latent_kernel(&mut rng, q, r);
    let (wc, cmat) = latent_kernel(&mut rng, c, r);

    // Sample n triplets; label = sign of the 3-way inner product + noise.
    let mut drugs = Vec::with_capacity(n);
    let mut targets = Vec::with_capacity(n);
    let mut cells = Vec::with_capacity(n);
    let mut scores = Vec::with_capacity(n);
    for _ in 0..n {
        let (i, j, k) = (rng.index(m), rng.index(q), rng.index(c));
        let mut s = 0.0;
        for f in 0..r {
            s += ud[(i, f)] * vt[(j, f)] * wc[(k, f)];
        }
        drugs.push(i as u32);
        targets.push(j as u32);
        cells.push(k as u32);
        scores.push(s + 0.2 * dist::standard_normal(&mut rng));
    }
    let threshold = {
        let mut sorted = scores.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted[(n as f64 * 0.85) as usize] // 15% positives
    };
    let y: Vec<f64> = scores.iter().map(|&s| if s >= threshold { 1.0 } else { 0.0 }).collect();
    let all = TripletIndex::new(drugs, targets, cells, m, q, c);
    println!(
        "triplet assay: {n} labeled (drug, target, cell) triplets over {m}×{q}×{c}\n"
    );

    // Split: setting 1 (random triplets) and novel cell lines.
    let perm = dist::permutation(&mut rng, n);
    let (test_rows, train_rows) = perm.split_at(n / 4);
    let train = all.subset(train_rows);
    let test = all.subset(test_rows);
    let y_train: Vec<f64> = train_rows.iter().map(|&i| y[i]).collect();
    let y_test: Vec<bool> = test_rows.iter().map(|&i| y[i] >= 0.5).collect();

    // Train: (K + λI) a = y with third-order GVT mat-vecs.
    let d = Arc::new(d);
    let t = Arc::new(t);
    let cmat = Arc::new(cmat);
    let op = TensorKronOp::new(d.clone(), t.clone(), cmat.clone(), train.clone(), train.clone());
    let shifted = ShiftedOp::new(&op, 1e-3);
    let t0 = clock::now();
    let out = minres(
        &shifted,
        &y_train,
        &MinresOptions { max_iters: if quick { 40 } else { 100 }, rel_tol: 1e-8 },
        |_, _, _| ControlFlow::Continue(()),
    )
    .unwrap();
    let train_secs = t0.elapsed().as_secs_f64();

    // Predict: one third-order GVT product.
    let preds = gvt3_matvec(&d, &t, &cmat, &test, &train, &out.x);
    let a = auc(&preds, &y_test).unwrap_or(f64::NAN);
    println!(
        "trained in {train_secs:.2}s ({} MINRES iterations) | test AUC (known objects): {a:.4}",
        out.iterations
    );

    // Timing: gvt3 vs naive O(n²) on one mat-vec.
    let probe: Vec<f64> = (0..train.len()).map(|i| ((i % 7) as f64) - 3.0).collect();
    let t1 = clock::now();
    let fast = gvt3_matvec(&d, &t, &cmat, &train, &train, &probe);
    let fast_s = t1.elapsed().as_secs_f64();
    let naive_n = train.len().min(if quick { 1_000 } else { 3_000 });
    let sub = train.subset(&(0..naive_n).collect::<Vec<_>>());
    let t2 = clock::now();
    let slow = naive3_matvec(&d, &t, &cmat, &sub, &sub, &probe[..naive_n]);
    let slow_s = t2.elapsed().as_secs_f64();
    // Scale the naive time quadratically to the full size for the report.
    let slow_full = slow_s * (train.len() as f64 / naive_n as f64).powi(2);
    let err = {
        let fast_sub = gvt3_matvec(&d, &t, &cmat, &sub, &sub, &probe[..naive_n]);
        gvt_rls::linalg::vecops::max_abs_diff(&fast_sub, &slow)
    };
    println!(
        "mat-vec at n={}: gvt3 {:.4}s vs naive {:.4}s (extrapolated {:.2}s at full n) — {:.0}× ; max|Δ| {err:.2e}",
        train.len(),
        fast_s,
        slow_s,
        slow_full,
        slow_full / fast_s.max(1e-9),
    );
    let _ = fast;
    println!(
        "\nThis is the paper's §7 open problem made concrete: the same \
         factorization peels one Kronecker mode at a time, O(n·(m+q+c)) \
         per product instead of O(n²)."
    );
    Ok(())
}
