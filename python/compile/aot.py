"""AOT lowering: JAX/Pallas (L2+L1) → HLO text artifacts for the rust
runtime (L3).

HLO *text* — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the
published ``xla`` rust crate) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``cd python && python -m compile.aot --out ../artifacts``

Emits one shape-specialized program per size bucket plus manifest.json:

    kron_matvec_m{M}_q{Q}_n{N}.hlo.txt
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# Size buckets: (m, q, n). Small for tests; larger for the examples /
# benches. The runtime zero-pads kernels into a bucket and chunks the
# output sample by n.
BUCKETS = [
    (64, 64, 4096),
    (128, 128, 8192),
    (256, 256, 16384),
]


def to_hlo_text(fn, args) -> str:
    """Lower a jittable function to XLA HLO text via StableHLO."""
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str, buckets=None) -> dict:
    buckets = buckets or BUCKETS
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "artifacts": []}
    for m, q, n in buckets:
        name = f"kron_matvec_m{m}_q{q}_n{n}"
        fname = f"{name}.hlo.txt"
        print(f"lowering {name} …", flush=True)
        text = to_hlo_text(model.kron_matvec, model.example_args(m, q, n))
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {"name": name, "m": m, "q": q, "n": n, "file": fname, "dtype": "f32"}
        )
        print(f"  wrote {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts → {out_dir}/manifest.json")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--quick", action="store_true", help="only the smallest bucket (CI smoke)"
    )
    args = ap.parse_args()
    build(args.out, BUCKETS[:1] if args.quick else None)


if __name__ == "__main__":
    main()
