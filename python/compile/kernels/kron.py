"""L1 — Pallas kernel for the dense Kronecker mat-vec's MXU hot spot.

The generalized vec trick never materializes the pairwise kernel matrix;
its dense (complete-data) formulation reduces every pairwise-kernel
mat-vec to

    S = T @ W        # this file: tiled matmul on the MXU
    p[i] = <D[row_d[i], :], S[row_t[i], :]>   # VPU gather-dot (model.py)

HARDWARE ADAPTATION (rust/DESIGN.md §Hardware-Adaptation): the paper's CPU
algorithm is two sparse gather/scatter passes; on TPU we restructure the
same factorization into a dense matmul so the MXU systolic array does the
O(q·q·m) work. BlockSpec tiles below are MXU-shaped (multiples of 8×128
lanes when the problem allows); `interpret=True` is mandatory here —
real-TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot
execute, and this sandbox validates numerics on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bq × K) @ (K × bm) tile; K is carried whole in VMEM.

    With K = domain size ≤ 2048 this is ≤ 2048·128·4 B ≈ 1 MiB per input
    panel — comfortably inside a TPU core's ~16 MiB VMEM, so no K-loop /
    scratch accumulator is needed at the shapes this library compiles.
    """
    acc = jnp.float32 if o_ref.dtype == jnp.float32 else o_ref.dtype
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=acc
    ).astype(o_ref.dtype)


def _pick_block(dim: int, preferred: int = 128) -> int:
    """Largest divisor of `dim` that is ≤ preferred (MXU tiles want 128;
    fall back gracefully for small/odd dims)."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def matmul(x: jax.Array, y: jax.Array, *, block_rows: int = 0, block_cols: int = 0):
    """Tiled Pallas matmul `x @ y` (f32 accumulate), interpret-mode.

    x: (Q_r, K), y: (K, M) -> (Q_r, M).
    """
    qr, k = x.shape
    k2, m = y.shape
    assert k == k2, f"matmul inner dims {k} vs {k2}"
    br = block_rows or _pick_block(qr)
    bc = block_cols or _pick_block(m)
    grid = (qr // br, m // bc)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qr, m), x.dtype),
        interpret=True,  # CPU sandbox: Mosaic lowering is compile-only
    )(x, y)


def kron_matvec_core(d, t, w, row_d, row_t):
    """The artifact program body (called by model.kron_matvec).

    d: (M, M) f32 — drug kernel (zero-padded by the runtime)
    t: (Q, Q) f32 — target kernel
    w: (Q, M) f32 — scattered coefficients W[t_j, d_j] += a_j
    row_d, row_t: (N,) i32 — output gather indices
    returns p: (N,) f32 with p[i] = Σ_dd D[row_d[i], dd] · S[row_t[i], dd]
    """
    s = matmul(t, w)  # (Q, M) — the MXU part (L1)
    d_rows = jnp.take(d, row_d, axis=0)  # (N, M)
    s_rows = jnp.take(s, row_t, axis=0)  # (N, M)
    return jnp.sum(d_rows * s_rows, axis=1)
