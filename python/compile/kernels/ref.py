"""Pure-jnp / numpy oracles for the Pallas kernel and the model layer.

Three levels of reference, each independent of the code it checks:

* ``matmul_ref`` — jnp matmul for the Pallas tile kernel.
* ``kron_matvec_ref`` — jnp composition for the artifact program.
* ``gvt_entry_loop`` — the *literal* Theorem-1 definition
  ``p_i = Σ_j A[d̄_i, d_j] · B[t̄_i, t_j] · a_j`` as a python loop: the
  ground truth for everything, mirroring the rust ``naive_matvec``.
* ``pairwise_kernel_matrix`` — Table 3 closed forms, entry by entry,
  mirroring the rust ``explicit.rs`` oracle.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def kron_matvec_ref(d, t, w, row_d, row_t):
    s = jnp.dot(t, w, preferred_element_type=jnp.float32)
    return jnp.sum(jnp.take(d, row_d, axis=0) * jnp.take(s, row_t, axis=0), axis=1)


def gvt_entry_loop(d, t, rows, cols, a):
    """Literal Theorem-1 loop. rows/cols: (n, 2) integer arrays of
    (drug, target) indices."""
    d = np.asarray(d, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    a = np.asarray(a, dtype=np.float64)
    out = np.zeros(len(rows))
    for i, (rd, rt) in enumerate(rows):
        acc = 0.0
        for j, (cd, ct) in enumerate(cols):
            acc += d[rd, cd] * t[rt, ct] * a[j]
        out[i] = acc
    return out


def pairwise_kernel_entry(kernel: str, d, t, row, col) -> float:
    """Table 3 closed forms (homogeneous kernels read only ``d``)."""
    rd, rt = row
    cd, ct = col
    if kernel == "linear":
        return d[rd, cd] + t[rt, ct]
    if kernel == "poly2d":
        return (d[rd, cd] + t[rt, ct]) ** 2
    if kernel == "kronecker":
        return d[rd, cd] * t[rt, ct]
    if kernel == "cartesian":
        return d[rd, cd] * (rt == ct) + (rd == cd) * t[rt, ct]
    if kernel == "symmetric":
        return d[rd, cd] * d[rt, ct] + d[rd, ct] * d[rt, cd]
    if kernel == "antisymmetric":
        return d[rd, cd] * d[rt, ct] - d[rd, ct] * d[rt, cd]
    if kernel == "ranking":
        return d[rd, cd] - d[rd, ct] - d[rt, cd] + d[rt, ct]
    if kernel == "mlpk":
        r = d[rd, cd] - d[rd, ct] - d[rt, cd] + d[rt, ct]
        return r * r
    raise ValueError(f"unknown kernel {kernel}")


def pairwise_kernel_matrix(kernel: str, d, t, rows, cols):
    """Dense ``n̄ × n`` pairwise kernel matrix from the closed forms."""
    d = np.asarray(d, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    k = np.zeros((len(rows), len(cols)))
    for i, row in enumerate(rows):
        for j, col in enumerate(cols):
            k[i, j] = pairwise_kernel_entry(kernel, d, t, tuple(row), tuple(col))
    return k
