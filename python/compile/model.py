"""L2 — the JAX model layer: pairwise-kernel mat-vecs built on the L1
Pallas primitive.

``kron_matvec`` is the AOT artifact program (one Kronecker summand; the
rust coordinator composes Corollary-1 term sums from it with index
plumbing, exactly as its own native implementation does).
``pairwise_matvec`` composes the full per-kernel sums *in JAX* — it
exists to pin the operator algebra at this layer too, validated against
the Table 3 closed forms in python/tests.

Python never runs at serve time: everything here is lowered once by
``aot.py`` to HLO text and executed from rust via PJRT.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from compile.kernels import kron


def scatter_coefficients(cols_d, cols_t, a, q: int, m: int):
    """W[t_j, d_j] += a_j — the VPU scatter feeding the MXU matmul."""
    w = jnp.zeros((q, m), dtype=jnp.float32)
    return w.at[cols_t, cols_d].add(a.astype(jnp.float32))


def kron_matvec(d, t, w, row_d, row_t):
    """The artifact program: see kernels/kron.kron_matvec_core."""
    return kron.kron_matvec_core(d, t, w, row_d, row_t)


def gvt_matvec(d, t, rows_d, rows_t, cols_d, cols_t, a):
    """Full dense GVT product `p = R(rows) (D ⊗ T) R(cols)ᵀ a`."""
    q = t.shape[0]
    m = d.shape[1]
    w = scatter_coefficients(cols_d, cols_t, a, q, m)
    return kron_matvec(d, t, w, rows_d, rows_t)


# --------------------------------------------------------------------------
# Corollary 1 term tables (mirrors rust/src/gvt/pairwise.rs): each term is
# (coeff, left, right, row_map, col_map) with left/right in
# {D, T, DSq, TSq, Ones, Identity} and maps in {id, swap, dupd, dupt}.
# --------------------------------------------------------------------------

PAIRWISE_TERMS = {
    "linear": [(1.0, "D", "Ones", "id", "id"), (1.0, "Ones", "T", "id", "id")],
    "poly2d": [
        (1.0, "DSq", "Ones", "id", "id"),
        (2.0, "D", "T", "id", "id"),
        (1.0, "Ones", "TSq", "id", "id"),
    ],
    "kronecker": [(1.0, "D", "T", "id", "id")],
    "cartesian": [(1.0, "D", "I", "id", "id"), (1.0, "I", "T", "id", "id")],
    "symmetric": [(1.0, "D", "D", "id", "id"), (1.0, "D", "D", "swap", "id")],
    "antisymmetric": [(1.0, "D", "D", "id", "id"), (-1.0, "D", "D", "swap", "id")],
    "ranking": [
        (1.0, "D", "Ones", "id", "id"),
        (-1.0, "D", "Ones", "swap", "id"),
        (-1.0, "D", "Ones", "id", "swap"),
        (1.0, "D", "Ones", "swap", "swap"),
    ],
    "mlpk": [
        (1.0, "DSq", "Ones", "id", "id"),
        (1.0, "DSq", "Ones", "id", "swap"),
        (1.0, "DSq", "Ones", "swap", "id"),
        (1.0, "DSq", "Ones", "swap", "swap"),
        (-2.0, "D", "D", "dupd", "id"),
        (-2.0, "D", "D", "id", "dupd"),
        (2.0, "D", "D", "id", "id"),
        (2.0, "D", "D", "id", "swap"),
        (-2.0, "D", "D", "id", "dupt"),
        (-2.0, "D", "D", "dupt", "id"),
    ],
}


def _apply_map(idx_d, idx_t, which: str):
    if which == "id":
        return idx_d, idx_t
    if which == "swap":
        return idx_t, idx_d
    if which == "dupd":
        return idx_d, idx_d
    if which == "dupt":
        return idx_t, idx_t
    raise ValueError(which)


def _factor(mat_name: str, d, t, n_rows: int, n_cols: int):
    if mat_name == "D":
        return d
    if mat_name == "T":
        return t
    if mat_name == "DSq":
        return d * d
    if mat_name == "TSq":
        return t * t
    if mat_name == "Ones":
        return jnp.ones((n_rows, n_cols), dtype=jnp.float32)
    if mat_name == "I":
        assert n_rows == n_cols
        return jnp.eye(n_rows, dtype=jnp.float32)
    raise ValueError(mat_name)


def pairwise_matvec(kernel: str, d, t, rows_d, rows_t, cols_d, cols_t, a):
    """`p = R(rows) K R(cols)ᵀ a` for any Table 3 kernel, as a sum of GVT
    products (Corollary 1). The special factors `1` and `I` are passed as
    dense matrices here (the L2 graph lets XLA fold them); the rust L3
    path uses dedicated fast paths instead.
    """
    terms = PAIRWISE_TERMS[kernel]
    m = d.shape[0]
    q = t.shape[0]
    p = jnp.zeros(rows_d.shape[0], dtype=jnp.float32)
    for coeff, left, right, rmap, cmap in terms:
        rd, rt = _apply_map(rows_d, rows_t, rmap)
        cd, ct = _apply_map(cols_d, cols_t, cmap)
        # Domain sizes of the transformed slots.
        ldim_r = m if rmap in ("id", "dupd") else q
        ldim_c = m if cmap in ("id", "dupd") else q
        rdim_r = q if rmap in ("id", "dupt") else m
        rdim_c = q if cmap in ("id", "dupt") else m
        a_mat = _factor(left, d, t, ldim_r, ldim_c)
        b_mat = _factor(right, d, t, rdim_r, rdim_c)
        w = jnp.zeros((rdim_c, ldim_c), dtype=jnp.float32)
        w = w.at[ct, cd].add(a.astype(jnp.float32))
        p = p + coeff * kron.kron_matvec_core(a_mat, b_mat, w, rd, rt)
    return p


def example_args(m: int, q: int, n: int):
    """ShapeDtypeStructs for AOT lowering of ``kron_matvec``."""
    import jax

    return (
        jax.ShapeDtypeStruct((m, m), jnp.float32),  # d
        jax.ShapeDtypeStruct((q, q), jnp.float32),  # t
        jax.ShapeDtypeStruct((q, m), jnp.float32),  # w
        jax.ShapeDtypeStruct((n,), jnp.int32),  # row_d
        jax.ShapeDtypeStruct((n,), jnp.int32),  # row_t
    )


def random_problem(rng: np.random.Generator, m: int, q: int, n: int, nbar: int):
    """Random dense-GVT test problem (shared by the python tests)."""
    d = rng.standard_normal((m, m)).astype(np.float32)
    d = (d + d.T) / 2
    t = rng.standard_normal((q, q)).astype(np.float32)
    t = (t + t.T) / 2
    cols = np.stack(
        [rng.integers(0, m, size=n), rng.integers(0, q, size=n)], axis=1
    ).astype(np.int32)
    rows = np.stack(
        [rng.integers(0, m, size=nbar), rng.integers(0, q, size=nbar)], axis=1
    ).astype(np.int32)
    a = rng.standard_normal(n).astype(np.float32)
    return d, t, rows, cols, a
