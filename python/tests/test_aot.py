"""AOT pipeline smoke: lowering produces parseable HLO text + a manifest
the rust registry can read."""

import json
import os

from compile import aot, model


def test_to_hlo_text_produces_hlo(tmp_path):
    text = aot.to_hlo_text(model.kron_matvec, model.example_args(8, 8, 16))
    # HLO text format starts with the module header and must contain an
    # ENTRY computation; ids are text-reassigned (the xla 0.5.1 gotcha).
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # Tuple return (the rust side unwraps to_tuple1).
    assert "f32[16]" in text


def test_build_writes_manifest_and_files(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out, buckets=[(8, 8, 32)])
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded == manifest
    assert loaded["version"] == 1
    (a,) = loaded["artifacts"]
    assert a["m"] == 8 and a["q"] == 8 and a["n"] == 32
    path = os.path.join(out, a["file"])
    assert os.path.isfile(path)
    assert os.path.getsize(path) > 100
