"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-128-divisible and degenerate
ones) and dtypes; assert_allclose against ref.py is the CORE correctness
signal for the kernel layer.
"""

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)  # allow true f64 in the dtype sweep
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import kron, ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


dims = st.integers(min_value=1, max_value=96)


@given(qr=dims, k=dims, m=dims, seed=st.integers(0, 2**31 - 1))
def test_pallas_matmul_matches_ref_f32(qr, k, m, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((qr, k)).astype(np.float32)
    y = rng.standard_normal((k, m)).astype(np.float32)
    got = np.asarray(kron.matmul(x, y))
    want = np.asarray(ref.matmul_ref(x, y))
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_pallas_matmul_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((32, 17)).astype(dtype)
    y = rng.standard_normal((17, 48)).astype(dtype)
    got = np.asarray(kron.matmul(x, y))
    assert got.dtype == dtype
    assert_allclose(got, x @ y, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "block", [1, 2, 8, 32],
)
def test_pallas_matmul_explicit_blocks(block):
    rng = np.random.default_rng(1)
    x = rng.standard_normal((64, 64)).astype(np.float32)
    y = rng.standard_normal((64, 64)).astype(np.float32)
    got = np.asarray(kron.matmul(x, y, block_rows=block, block_cols=block))
    assert_allclose(got, x @ y, rtol=1e-5, atol=1e-5)


@given(
    m=st.integers(2, 24),
    q=st.integers(2, 24),
    n=st.integers(1, 60),
    nbar=st.integers(1, 60),
    seed=st.integers(0, 2**31 - 1),
)
def test_kron_matvec_core_matches_theorem1_loop(m, q, n, nbar, seed):
    rng = np.random.default_rng(seed)
    from compile import model

    d, t, rows, cols, a = model.random_problem(rng, m, q, n, nbar)
    w = np.zeros((q, m), dtype=np.float32)
    np.add.at(w, (cols[:, 1], cols[:, 0]), a)
    got = np.asarray(kron.kron_matvec_core(d, t, w, rows[:, 0], rows[:, 1]))
    want = ref.gvt_entry_loop(d, t, rows, cols, a)
    assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_block_picker_divides():
    for dim in [1, 7, 64, 96, 100, 128, 1000]:
        b = kron._pick_block(dim)
        assert dim % b == 0
        assert 1 <= b <= 128
