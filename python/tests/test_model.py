"""L2 correctness: the pairwise mat-vec compositions (Corollary 1) vs the
Table 3 closed-form kernel matrices — the same oracle relationship the
rust tests enforce, pinned at the JAX layer too."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

settings.register_profile("model", max_examples=12, deadline=None)
settings.load_profile("model")

HETEROGENEOUS = ["linear", "poly2d", "kronecker", "cartesian"]
HOMOGENEOUS = ["symmetric", "antisymmetric", "ranking", "mlpk"]


def _case(rng, m, q, n, nbar):
    d, t, rows, cols, a = model.random_problem(rng, m, q, n, nbar)
    return d, t, rows, cols, a


@given(seed=st.integers(0, 2**31 - 1))
def test_heterogeneous_kernels_match_closed_form(seed):
    rng = np.random.default_rng(seed)
    m, q, n, nbar = 7, 5, 30, 20
    d, t, rows, cols, a = _case(rng, m, q, n, nbar)
    for kernel in HETEROGENEOUS:
        got = np.asarray(
            model.pairwise_matvec(
                kernel, d, t, rows[:, 0], rows[:, 1], cols[:, 0], cols[:, 1], a
            )
        )
        k_mat = ref.pairwise_kernel_matrix(kernel, d, t, rows, cols)
        want = k_mat @ np.asarray(a, dtype=np.float64)
        assert_allclose(got, want, rtol=2e-3, atol=2e-3, err_msg=kernel)


@given(seed=st.integers(0, 2**31 - 1))
def test_homogeneous_kernels_match_closed_form(seed):
    rng = np.random.default_rng(seed)
    m = 6  # homogeneous: both slots index the same domain
    d, _, rows, cols, a = _case(rng, m, m, 25, 15)
    for kernel in HOMOGENEOUS:
        got = np.asarray(
            model.pairwise_matvec(
                kernel, d, d, rows[:, 0], rows[:, 1], cols[:, 0], cols[:, 1], a
            )
        )
        k_mat = ref.pairwise_kernel_matrix(kernel, d, d, rows, cols)
        want = k_mat @ np.asarray(a, dtype=np.float64)
        assert_allclose(got, want, rtol=2e-3, atol=2e-3, err_msg=kernel)


def test_gvt_matvec_shapes():
    rng = np.random.default_rng(3)
    d, t, rows, cols, a = _case(rng, 9, 4, 40, 13)
    p = model.gvt_matvec(d, t, rows[:, 0], rows[:, 1], cols[:, 0], cols[:, 1], a)
    assert p.shape == (13,)


def test_scatter_accumulates_duplicates():
    # Two coefficients on the same (t, d) cell must add.
    w = model.scatter_coefficients(
        np.array([2, 2], dtype=np.int32),
        np.array([1, 1], dtype=np.int32),
        np.array([0.5, 0.25], dtype=np.float32),
        q=3,
        m=4,
    )
    w = np.asarray(w)
    assert w[1, 2] == 0.75
    assert w.sum() == 0.75


def test_mlpk_term_table_has_ten_terms():
    # §6.4: "the MLPK slowest because it has 10 such terms".
    assert len(model.PAIRWISE_TERMS["mlpk"]) == 10
    assert len(model.PAIRWISE_TERMS["kronecker"]) == 1
