//! Eigen shortcut vs CG: full λ-grid training wall time on complete
//! grids.
//!
//! On a complete m×q grid with the Kronecker kernel the eigen solver
//! pays one `O(m³ + q³)` decomposition and then `O(mq(m+q))` per λ —
//! while CG pays `O(iters · (nm + nq))` per λ with `n = mq`. This bench
//! times both lanes over the same λ grid (plus the eigen LOOCV pass,
//! which replaces a whole cross-validation) so the crossover is a
//! measured number, not folklore (rust/DESIGN.md §Eigen-Shortcut).
//!
//! Set `GVT_RLS_BENCH_JSON=<path>` to emit the suite as JSON —
//! scripts/bench.sh points it at BENCH_eigen.json in the repo root
//! (full sizes: m = q ∈ {64, 128}).

use gvt_rls::bench::{reduced_size, smoke, BenchConfig, BenchSuite};
use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use gvt_rls::solvers::cg::{cg, CgOptions};
use gvt_rls::solvers::complete::EigenRidge;
use gvt_rls::solvers::linear_op::ShiftedOp;
use std::hint::black_box;
use std::ops::ControlFlow;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut suite = BenchSuite::new();
    let (grids, lambdas): (&[usize], Vec<f64>) = if smoke() {
        (&[16], vec![1e-2, 1.0])
    } else if reduced_size() {
        (&[48], vec![1e-3, 1e-2, 1e-1, 1.0, 10.0])
    } else {
        (
            &[64, 128],
            vec![1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0, 1000.0],
        )
    };
    let rel_tol = if smoke() { 1e-6 } else { 1e-8 };

    println!(
        "# bench_eigen — closed-form eigen λ-grid vs CG λ-grid on complete \
         m×q grids ({} λ values, cg rel_tol {rel_tol:.0e})\n",
        lambdas.len()
    );

    let mut rows: Vec<(usize, f64, f64, f64, usize)> = Vec::new();
    for &k in grids {
        // n = k² covers the k×k grid: the complete-data case.
        let data = KernelFillingConfig::small().generate(k, k * k, 42);
        assert_eq!(data.len(), k * k, "kernel-filling grid must be complete");

        // --- eigen: one decomposition, every λ closed-form ----------
        let r = suite.run(&format!("eigen λ-grid     m=q={k}"), &cfg, || {
            let er = EigenRidge::new(&data, PairwiseKernel::Kronecker).unwrap();
            black_box(er.alpha_grid(&lambdas).unwrap());
        });
        let eig_secs = r.mean.as_secs_f64();

        // --- eigen LOOCV: exact model selection on top --------------
        let er = EigenRidge::new(&data, PairwiseKernel::Kronecker).unwrap();
        let r = suite.run(&format!("eigen LOOCV grid m=q={k}"), &cfg, || {
            black_box(er.loocv(&lambdas).unwrap());
        });
        let loo_secs = r.mean.as_secs_f64();

        // --- cg: one shared GVT operator, one Krylov solve per λ ----
        let op = PairwiseLinOp::new(
            PairwiseKernel::Kronecker,
            data.d.clone(),
            data.t.clone(),
            data.pairs.clone(),
            data.pairs.clone(),
            GvtPolicy::Auto,
        )
        .unwrap();
        let mut cg_iters_total = 0usize;
        let r = suite.run(&format!("cg λ-grid        m=q={k}"), &cfg, || {
            cg_iters_total = 0;
            for &lambda in &lambdas {
                let shifted = ShiftedOp::new(&op, lambda);
                let out = cg(
                    &shifted,
                    black_box(&data.y),
                    None,
                    &CgOptions { max_iters: 10_000, rel_tol },
                    |_, _, _| ControlFlow::Continue(()),
                )
                .unwrap();
                cg_iters_total += out.iterations;
                black_box(out.x);
            }
        });
        let cg_secs = r.mean.as_secs_f64();

        println!(
            "    m=q={k}: eigen {:.1}ms (+loocv {:.1}ms) | cg {cg_iters_total} iters \
             {:.1}ms | speedup {:.2}x",
            eig_secs * 1e3,
            loo_secs * 1e3,
            cg_secs * 1e3,
            cg_secs / eig_secs.max(1e-12)
        );
        rows.push((k, eig_secs, loo_secs, cg_secs, cg_iters_total));
    }

    println!("\n{}", suite.table());

    if let Ok(path) = std::env::var("GVT_RLS_BENCH_JSON") {
        let meta: Vec<(&str, String)> = vec![
            ("bench", "bench_eigen".to_string()),
            ("rel_tol", format!("{rel_tol:e}")),
            (
                "lambda_grid",
                lambdas.iter().map(|l| format!("{l:e}")).collect::<Vec<_>>().join(","),
            ),
            (
                "grids",
                grids.iter().map(|g| g.to_string()).collect::<Vec<_>>().join(","),
            ),
            (
                "lambda_grid_secs",
                rows.iter()
                    .map(|(k, e, l, c, it)| {
                        format!("m{k}:eigen={e:.4}s,loocv={l:.4}s,cg={c:.4}s,cg_iters={it}")
                    })
                    .collect::<Vec<_>>()
                    .join(";"),
            ),
        ];
        suite.write_json(&path, &meta).expect("writing bench JSON");
        println!("wrote {path}");
    }
}
