//! Theorem 1 / Figure 7 (time panel): GVT vs explicit kernel mat-vec
//! scaling in n, plus the GVT factorization ablation (sparse-left /
//! sparse-right / dense-GEMM / auto).
//!
//! Expected shape: explicit cost grows ~n² (and its build dominates);
//! GVT grows ~n·(m+q). Crossover is below the smallest size here.

use gvt_rls::bench::{BenchConfig, BenchSuite};
use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::gvt::explicit::ExplicitLinOp;
use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use gvt_rls::solvers::linear_op::LinOp;
use std::hint::black_box;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut suite = BenchSuite::new();
    let smoke = gvt_rls::bench::smoke();
    let quick = std::env::var("GVT_RLS_BENCH_QUICK").is_ok() || smoke;
    let k = if smoke { 32 } else if quick { 64 } else { 192 };
    let sizes: &[usize] = if smoke {
        &[200]
    } else if quick {
        &[500, 2000]
    } else {
        &[1_000, 4_000, 16_000]
    };

    println!("# bench_gvt_vs_explicit — Theorem 1 scaling (k = {k} drugs)\n");
    for &n in sizes {
        let data = KernelFillingConfig::small().generate(k, n, 42);
        let a: Vec<f64> = (0..n).map(|i| ((i % 13) as f64) - 6.0).collect();

        let op = PairwiseLinOp::new(
            PairwiseKernel::Kronecker,
            data.d.clone(),
            data.t.clone(),
            data.pairs.clone(),
            data.pairs.clone(),
            GvtPolicy::Auto,
        )
        .unwrap();
        suite.run(&format!("gvt matvec n={n}"), &cfg, || {
            black_box(op.matvec(black_box(&a)));
        });

        // Explicit baseline: build once (time it separately), then matvec.
        if n <= 16_000 {
            suite.run(&format!("explicit BUILD n={n}"), &cfg, || {
                black_box(ExplicitLinOp::new(
                    PairwiseKernel::Kronecker,
                    &data.d,
                    &data.t,
                    &data.pairs,
                    &data.pairs,
                ));
            });
            let exp = ExplicitLinOp::new(
                PairwiseKernel::Kronecker,
                &data.d,
                &data.t,
                &data.pairs,
                &data.pairs,
            );
            suite.run(&format!("explicit matvec n={n}"), &cfg, || {
                black_box(exp.apply(black_box(&a)));
            });
        }
    }

    // Factorization ablation at a fixed size.
    let n = if smoke { 200 } else if quick { 2000 } else { 16_000 };
    let data = KernelFillingConfig::small().generate(k, n, 43);
    let a: Vec<f64> = (0..n).map(|i| ((i % 5) as f64) - 2.0).collect();
    println!("\n## factorization ablation (n = {n}, density {:.0}%)\n", 100.0 * data.density());
    for policy in [
        GvtPolicy::SparseLeft,
        GvtPolicy::SparseRight,
        GvtPolicy::Dense,
        GvtPolicy::Auto,
    ] {
        let op = PairwiseLinOp::new(
            PairwiseKernel::Kronecker,
            data.d.clone(),
            data.t.clone(),
            data.pairs.clone(),
            data.pairs.clone(),
            policy,
        )
        .unwrap();
        suite.run(&format!("gvt {policy:?} n={n}"), &cfg, || {
            black_box(op.matvec(black_box(&a)));
        });
    }

    println!("\n{}", suite.table());
}
