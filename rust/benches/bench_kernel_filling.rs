//! Figure 7 — the full kernel-filling scalability experiment: CPU time,
//! memory and AUC for GVT vs the explicit baseline across training-set
//! sizes N, for all six kernels the paper plots.
//!
//! The baseline is cut off at a memory budget exactly as the paper cut
//! it at 16 GiB ("the naive method experiments were stopped when N
//! required > 16 GiB memory").

use gvt_rls::coordinator::memory::{format_bytes, peak_bytes, reset_peak, TrackingAlloc};
use gvt_rls::coordinator::report::{series_table, Series};
use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::eval::auc;
use gvt_rls::gvt::explicit::ExplicitLinOp;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};
use std::time::Instant;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

const BASELINE_CUTOFF: usize = 1 << 31; // 2 GiB (paper: 16 GiB)

fn main() {
    let smoke = gvt_rls::bench::smoke();
    let quick = std::env::var("GVT_RLS_BENCH_QUICK").is_ok() || smoke;
    let k = if smoke { 32 } else if quick { 64 } else { 192 };
    let sizes: Vec<usize> = if smoke {
        vec![300]
    } else if quick {
        vec![500, 1_000, 2_000]
    } else {
        vec![1_000, 2_000, 4_000, 8_000, 16_000, 32_000]
    };
    let max_iters = if smoke { 8 } else if quick { 25 } else { 60 };
    let ridge = RidgeConfig { max_iters, patience: 6, ..Default::default() };
    let cfgk = KernelFillingConfig::small();

    println!("# bench_kernel_filling — Figure 7 (k = {k} drugs)\n");

    // ---------------- time/memory race, Kronecker kernel ----------------
    let mut gvt_time = Series { label: "gvt secs".into(), points: vec![] };
    let mut base_time = Series { label: "naive secs".into(), points: vec![] };
    let mut gvt_mem = Series { label: "gvt MiB".into(), points: vec![] };
    let mut base_mem = Series { label: "naive MiB".into(), points: vec![] };
    for &n in &sizes {
        let data = cfgk.generate(k, n, 42);
        let split = data.split_setting(1, 0.25, 42);
        let ntr = split.train.len();

        reset_peak();
        let t0 = Instant::now();
        let model = PairwiseRidge::fit_early_stopping(
            &split.train,
            1,
            PairwiseKernel::Kronecker,
            &ridge,
            42,
        )
        .unwrap();
        let secs = t0.elapsed().as_secs_f64();
        gvt_time.points.push((n as f64, secs));
        gvt_mem.points.push((n as f64, peak_bytes() as f64 / (1 << 20) as f64));
        eprintln!("n={n}: gvt {secs:.2}s ({} iters), mem {}", model.iterations, format_bytes(peak_bytes()));

        if ntr * ntr * 8 <= BASELINE_CUTOFF {
            reset_peak();
            let t1 = Instant::now();
            let op = ExplicitLinOp::new(
                PairwiseKernel::Kronecker,
                &split.train.d,
                &split.train.t,
                &split.train.pairs,
                &split.train.pairs,
            );
            let _ =
                PairwiseRidge::fit_with_op(&op, &split.train.y, &ridge, model.iterations);
            let bsecs = t1.elapsed().as_secs_f64();
            base_time.points.push((n as f64, bsecs));
            base_mem.points.push((n as f64, peak_bytes() as f64 / (1 << 20) as f64));
            eprintln!("n={n}: naive {bsecs:.2}s, mem {}", format_bytes(peak_bytes()));
        } else {
            eprintln!(
                "n={n}: naive SKIPPED (K would need {}, cutoff {})",
                format_bytes(ntr * ntr * 8),
                format_bytes(BASELINE_CUTOFF)
            );
        }
    }
    println!("## CPU time (s)\n");
    println!("{}", series_table("N", &[gvt_time, base_time]));
    println!("## peak memory (MiB)\n");
    println!("{}", series_table("N", &[gvt_mem, base_mem]));

    // ---------------- AUC panel: all kernels at max N -------------------
    let n = *sizes.last().unwrap();
    let data = cfgk.generate(k, n, 42);
    println!("## AUC at N = {n} by kernel and setting\n");
    let kernels = [
        PairwiseKernel::Linear,
        PairwiseKernel::Poly2D,
        PairwiseKernel::Kronecker,
        PairwiseKernel::Cartesian,
        PairwiseKernel::Symmetric,
        PairwiseKernel::Mlpk,
    ];
    println!("| {:<14} | {:>7} | {:>7} | {:>7} | {:>7} |", "kernel", "S1", "S2", "S3", "S4");
    for kernel in kernels {
        let mut row = format!("| {:<14} |", kernel.name());
        for setting in 1..=4u8 {
            let split = data.split_setting(setting, 0.25, 42);
            let model =
                PairwiseRidge::fit_early_stopping(&split.train, setting, kernel, &ridge, 42)
                    .unwrap();
            let preds = model.predict(&split.test.pairs).unwrap();
            let a = auc(&preds, &split.test.binary_labels()).unwrap_or(f64::NAN);
            row.push_str(&format!(" {a:>7.4} |"));
        }
        println!("{row}");
    }
    println!("\n(paper shape: nonlinear kernels ≥ linear at large N; S1 > S2/S3 > S4)");
}
