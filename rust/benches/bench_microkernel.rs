//! Micro-kernel ablation: register-blocked tile kernels
//! (`linalg::microkernel`) vs the scalar chunk bodies
//! (`GVT_RLS_MICROKERNEL=0`), A/B'd in-process via
//! [`gvt_rls::linalg::microkernel::set_enabled`]. Three sweeps:
//!
//! 1. **GEMV** — square `y = A·x` (the fused plan's pooled terms and
//!    every solver iteration's dense factor product).
//! 2. **GEMM** — square `C = A·B` (Dense-policy GVT, eigen-basis
//!    rotations, Nyström assembly).
//! 3. **Stage-1 + stage-2** — the multi-RHS pairwise mat-mat (Kronecker,
//!    B = 8 coefficient columns) at n ∈ {4k, 16k, 64k} pairs: the
//!    scatter/row-dot chunk bodies the tiles rewire.
//!
//! Both settings are bit-identical (tests/microkernel_equiv.rs); this
//! bench records what the tiling buys. Every row reports GFLOP/s next to
//! the time so the distance to machine peak stays visible. Set
//! `GVT_RLS_BENCH_JSON=<path>` to emit JSON — scripts/bench.sh points it
//! at BENCH_microkernel.json.

use gvt_rls::bench::{reduced_size, BenchConfig, BenchSuite};
use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use gvt_rls::linalg::{microkernel, par, Mat};
use gvt_rls::rng::{dist, Xoshiro256};
use gvt_rls::runtime::pool;
use std::hint::black_box;

const MODES: [(&str, bool); 2] = [("tiled ", true), ("scalar", false)];

fn main() {
    let cfg = BenchConfig::from_env();
    let mut suite = BenchSuite::new();
    let mut rng = Xoshiro256::seed_from(7);
    pool::warm();
    // name, size, GFLOP/s per mode [tiled, scalar].
    let mut gflops: Vec<(&'static str, usize, [f64; 2])> = Vec::new();

    println!("# bench_microkernel — register-blocked tiles vs scalar chunk bodies\n");

    // 1. GEMV: y = A·x, square.
    let gemv_sizes: &[usize] = if reduced_size() { &[256] } else { &[1_024, 2_048, 4_096] };
    for &m in gemv_sizes {
        let a = Mat::from_vec(m, m, dist::normal_vec(&mut rng, m * m));
        let x = dist::normal_vec(&mut rng, m);
        let mut y = vec![0.0; m];
        let flops = 2.0 * (m as f64) * (m as f64);
        let mut per_mode = [0.0f64; 2];
        for (mi, &(label, on)) in MODES.iter().enumerate() {
            microkernel::set_enabled(Some(on));
            let r = suite.run(&format!("gemv  m={m:<5} {label}"), &cfg, || {
                a.matvec_into(black_box(&x), black_box(&mut y));
            });
            per_mode[mi] = flops / r.mean.as_secs_f64().max(1e-12) / 1e9;
        }
        println!(
            "gemv  m={m}: tiled {:.2} GFLOP/s, scalar {:.2} GFLOP/s ({:.2}x)",
            per_mode[0],
            per_mode[1],
            per_mode[0] / per_mode[1].max(1e-12)
        );
        gflops.push(("gemv", m, per_mode));
    }

    // 2. GEMM: C = A·B, square.
    let gemm_sizes: &[usize] = if reduced_size() { &[96] } else { &[256, 512, 768] };
    for &m in gemm_sizes {
        let a = Mat::from_vec(m, m, dist::normal_vec(&mut rng, m * m));
        let b = Mat::from_vec(m, m, dist::normal_vec(&mut rng, m * m));
        let mut c = Mat::zeros(m, m);
        let flops = 2.0 * (m as f64).powi(3);
        let mut per_mode = [0.0f64; 2];
        for (mi, &(label, on)) in MODES.iter().enumerate() {
            microkernel::set_enabled(Some(on));
            let r = suite.run(&format!("gemm  m={m:<5} {label}"), &cfg, || {
                a.matmul_into(black_box(&b), black_box(&mut c));
            });
            per_mode[mi] = flops / r.mean.as_secs_f64().max(1e-12) / 1e9;
        }
        println!(
            "gemm  m={m}: tiled {:.2} GFLOP/s, scalar {:.2} GFLOP/s ({:.2}x)",
            per_mode[0],
            per_mode[1],
            per_mode[0] / per_mode[1].max(1e-12)
        );
        gflops.push(("gemm", m, per_mode));
    }

    // 3. Stage-1 + stage-2: multi-RHS pairwise mat-mat over n pairs.
    let (k, sizes): (usize, &[usize]) =
        if reduced_size() { (48, &[800]) } else { (192, &[4_000, 16_000, 64_000]) };
    let bcols = 8usize;
    for &n in sizes {
        let data = KernelFillingConfig::small().generate(k, n, 42);
        let op = PairwiseLinOp::new(
            PairwiseKernel::Kronecker,
            data.d.clone(),
            data.t.clone(),
            data.pairs.clone(),
            data.pairs.clone(),
            GvtPolicy::Auto,
        )
        .unwrap();
        let abm = Mat::from_vec(n, bcols, dist::normal_vec(&mut rng, n * bcols));
        let mut out = Mat::zeros(n, bcols);
        // Stage 1 scatters n·q MACs, stage 2 row-dots n·m, per RHS column.
        let flops = 2.0 * (bcols as f64) * (n as f64) * (2 * k) as f64;
        let mut per_mode = [0.0f64; 2];
        for (mi, &(label, on)) in MODES.iter().enumerate() {
            microkernel::set_enabled(Some(on));
            let r = suite.run(&format!("stage12 n={n:<6} B={bcols} {label}"), &cfg, || {
                op.matmat_into(black_box(&abm), black_box(&mut out));
            });
            per_mode[mi] = flops / r.mean.as_secs_f64().max(1e-12) / 1e9;
        }
        println!(
            "stage12 n={n}: tiled {:.2} GFLOP/s, scalar {:.2} GFLOP/s ({:.2}x)",
            per_mode[0],
            per_mode[1],
            per_mode[0] / per_mode[1].max(1e-12)
        );
        gflops.push(("stage12", n, per_mode));
    }
    microkernel::set_enabled(None);

    println!("\n{}", suite.table());
    println!("name          size      tiled-GFLOP/s  scalar-GFLOP/s  speedup");
    for (name, sz, g) in &gflops {
        println!(
            "{name:<12} {sz:>8} {:>14.2} {:>15.2} {:>8.2}x",
            g[0],
            g[1],
            g[0] / g[1].max(1e-12)
        );
    }

    if let Ok(path) = std::env::var("GVT_RLS_BENCH_JSON") {
        let meta: Vec<(&str, String)> = vec![
            ("bench", "bench_microkernel".to_string()),
            ("threads", par::num_threads().to_string()),
            ("tile", format!("MR={} NR={} KC={}", microkernel::MR, microkernel::NR, microkernel::KC)),
            (
                "gflops",
                gflops
                    .iter()
                    .map(|(nm, sz, g)| format!("{nm}@{sz}=tiled:{:.3},scalar:{:.3}", g[0], g[1]))
                    .collect::<Vec<_>>()
                    .join(";"),
            ),
        ];
        suite.write_json(&path, &meta).expect("writing bench JSON");
        println!("wrote {path}");
    }
}
