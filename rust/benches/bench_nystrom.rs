//! Figures 8–9 — Nyström (Falkon-style) vs the exact GVT solution:
//! AUC / time / memory as a function of the number of basis vectors,
//! against RLScore-equivalent full training.
//!
//! Paper shape: Nyström AUC approaches the full solution from below as N
//! grows; the GVT full solution costs less memory (O(m²) vs O(n·N)) and
//! comparable-or-less time, with slightly better AUC — especially S1.

use gvt_rls::coordinator::memory::{format_bytes, peak_bytes, reset_peak, TrackingAlloc};
use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::eval::auc;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::solvers::nystrom::{NystromConfig, NystromModel};
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};
use std::time::Instant;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() {
    let smoke = gvt_rls::bench::smoke();
    let quick = std::env::var("GVT_RLS_BENCH_QUICK").is_ok() || smoke;
    let (k, n, centers): (usize, usize, Vec<usize>) = if smoke {
        (32, 400, vec![8, 32])
    } else if quick {
        (48, 1_500, vec![16, 64, 256])
    } else {
        (160, 12_000, vec![32, 128, 512, 2048])
    };
    let seed = 42;
    let data = KernelFillingConfig::small().generate(k, n, seed);

    println!("# bench_nystrom — Figures 8–9 (n = {n} pairs, k = {k} drugs)\n");
    println!(
        "| {:<22} | {:>8} | {:>9} | {:>12} | {:>6} |",
        "method", "AUC(S1)", "time", "peak mem", "iters"
    );

    for setting in [1u8, 4u8] {
        let split = data.split_setting(setting, 0.25, seed);
        let inner = split.train.split_setting(setting, 0.25, seed ^ 1);
        println!("|--- setting {setting} {}|", "-".repeat(58));

        // Nyström sweep.
        for &nc in &centers {
            reset_peak();
            let t0 = Instant::now();
            let cfg = NystromConfig { num_centers: nc, seed, ..Default::default() };
            let model = NystromModel::fit_with_validation(
                &inner.train,
                &inner.test,
                PairwiseKernel::Kronecker,
                &cfg,
            )
            .unwrap();
            let secs = t0.elapsed().as_secs_f64();
            let mem = peak_bytes();
            let preds = model.predict(&split.test.pairs);
            let a = auc(&preds, &split.test.binary_labels()).unwrap_or(f64::NAN);
            println!(
                "| {:<22} | {:>8.4} | {:>8.2}s | {:>12} | {:>6} |",
                format!("falkon N={nc}"),
                a,
                secs,
                format_bytes(mem),
                model.iterations
            );
        }

        // Full GVT solution (RLScore-equivalent).
        reset_peak();
        let t0 = Instant::now();
        let ridge = RidgeConfig {
            max_iters: if smoke { 8 } else if quick { 30 } else { 100 },
            patience: 10,
            ..Default::default()
        };
        let model = PairwiseRidge::fit_early_stopping(
            &split.train,
            setting,
            PairwiseKernel::Kronecker,
            &ridge,
            seed,
        )
        .unwrap();
        let secs = t0.elapsed().as_secs_f64();
        let mem = peak_bytes();
        let preds = model.predict(&split.test.pairs).unwrap();
        let a = auc(&preds, &split.test.binary_labels()).unwrap_or(f64::NAN);
        println!(
            "| {:<22} | {:>8.4} | {:>8.2}s | {:>12} | {:>6} |",
            "gvt full (RLScore)",
            a,
            secs,
            format_bytes(mem),
            model.iterations
        );
    }
    println!(
        "\n(paper shape: Nyström AUC ↑ with N, approaching the full GVT \
         solution, which uses less memory and achieves ≥ AUC)"
    );
}
