//! Corollary 1 / §6.4: per-kernel mat-vec cost. "The Kronecker kernel is
//! fastest of these because it has only one term and the MLPK slowest
//! because it has 10 such terms" — this bench regenerates that ordering.

use gvt_rls::bench::{BenchConfig, BenchSuite};
use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use std::hint::black_box;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut suite = BenchSuite::new();
    let quick = std::env::var("GVT_RLS_BENCH_QUICK").is_ok();
    let (k, n) = if quick { (64, 2_000) } else { (192, 16_000) };
    let data = KernelFillingConfig::small().generate(k, n, 42);
    let a: Vec<f64> = (0..n).map(|i| ((i % 9) as f64) - 4.0).collect();

    println!("# bench_pairwise_kernels — per-kernel GVT mat-vec (n = {n}, m = q = {k})\n");
    let mut order: Vec<(String, f64, usize)> = Vec::new();
    for kernel in PairwiseKernel::ALL {
        let op = PairwiseLinOp::new(
            kernel,
            data.d.clone(),
            data.t.clone(),
            data.pairs.clone(),
            data.pairs.clone(),
            GvtPolicy::Auto,
        )
        .unwrap();
        let r = suite.run(
            &format!("{:<14} ({} terms)", kernel.name(), op.term_count()),
            &cfg,
            || {
                black_box(op.matvec(black_box(&a)));
            },
        );
        order.push((kernel.name().to_string(), r.mean.as_secs_f64(), op.term_count()));
    }

    println!("\n{}", suite.table());

    // Paper-shape check: Kronecker fastest, MLPK slowest.
    let kron = order.iter().find(|(n, _, _)| n == "kronecker").unwrap().1;
    let mlpk = order.iter().find(|(n, _, _)| n == "mlpk").unwrap().1;
    println!(
        "kronecker {:.4}ms vs mlpk {:.4}ms → ratio {:.1}× (paper: ~10 terms vs 1)",
        kron * 1e3,
        mlpk * 1e3,
        mlpk / kron
    );
}
