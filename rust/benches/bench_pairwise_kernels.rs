//! Corollary 1 / §6.4: per-kernel mat-vec cost. "The Kronecker kernel is
//! fastest of these because it has only one term and the MLPK slowest
//! because it has 10 such terms" — this bench regenerates that ordering,
//! and since the fused-plan PR also measures how much of the per-term
//! cost the [`gvt_rls::gvt::plan::GvtPlan`] fusion claws back (the
//! `unfused` rows are the `GVT_RLS_NO_FUSE=1` path run in-process).
//!
//! Set `GVT_RLS_BENCH_JSON=<path>` to emit the suite as JSON —
//! scripts/bench.sh points it at BENCH_gvt.json in the repo root to seed
//! the perf trajectory.

use gvt_rls::bench::{reduced_size, BenchConfig, BenchSuite};
use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use std::hint::black_box;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut suite = BenchSuite::new();
    let (k, sizes): (usize, &[usize]) =
        if reduced_size() { (48, &[800]) } else { (192, &[4_000, 16_000]) };

    let mut speedups: Vec<(String, usize, f64)> = Vec::new();
    for &n in sizes {
        let data = KernelFillingConfig::small().generate(k, n, 42);
        let a: Vec<f64> = (0..n).map(|i| ((i % 9) as f64) - 4.0).collect();
        println!("# bench_pairwise_kernels — per-kernel GVT mat-vec (n = {n}, m = q = {k})\n");
        let mut order: Vec<(String, f64, usize)> = Vec::new();
        for kernel in PairwiseKernel::ALL {
            let op = PairwiseLinOp::new(
                kernel,
                data.d.clone(),
                data.t.clone(),
                data.pairs.clone(),
                data.pairs.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let r = suite.run(
                &format!("{:<14} n={n:<6} fused   ({} terms)", kernel.name(), op.term_count()),
                &cfg,
                || {
                    black_box(op.matvec(black_box(&a)));
                },
            );
            let fused_mean = r.mean.as_secs_f64();
            order.push((kernel.name().to_string(), fused_mean, op.term_count()));
            // Fusion ablation on the multi-term kernels (the acceptance
            // targets): same operator, pre-plan per-term path.
            if matches!(kernel, PairwiseKernel::Ranking | PairwiseKernel::Mlpk) {
                let mut out = vec![0.0; n];
                let r2 = suite.run(
                    &format!("{:<14} n={n:<6} unfused ({} terms)", kernel.name(), op.term_count()),
                    &cfg,
                    || {
                        op.matvec_into_unfused(black_box(&a), black_box(&mut out));
                    },
                );
                let s = r2.mean.as_secs_f64() / fused_mean.max(1e-12);
                println!(
                    "    {} n={n}: plan [{}] fused speedup {s:.2}x",
                    kernel.name(),
                    op.plan_summary()
                );
                speedups.push((kernel.name().to_string(), n, s));
            }
        }

        // Paper-shape check: Kronecker fastest, MLPK slowest.
        let kron = order.iter().find(|(nm, _, _)| nm == "kronecker").unwrap().1;
        let mlpk = order.iter().find(|(nm, _, _)| nm == "mlpk").unwrap().1;
        println!(
            "\nkronecker {:.4}ms vs mlpk {:.4}ms → ratio {:.1}× (paper: ~10 terms vs 1)\n",
            kron * 1e3,
            mlpk * 1e3,
            mlpk / kron
        );
    }

    println!("{}", suite.table());
    for (name, n, s) in &speedups {
        println!("fused speedup {name} n={n}: {s:.2}x");
    }

    if let Ok(path) = std::env::var("GVT_RLS_BENCH_JSON") {
        let meta: Vec<(&str, String)> = vec![
            ("bench", "bench_pairwise_kernels".to_string()),
            ("domain", k.to_string()),
            (
                "sizes",
                sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(","),
            ),
            (
                "speedups",
                speedups
                    .iter()
                    .map(|(nm, n, s)| format!("{nm}@{n}={s:.3}x"))
                    .collect::<Vec<_>>()
                    .join(";"),
            ),
        ];
        suite.write_json(&path, &meta).expect("writing bench JSON");
        println!("wrote {path}");
    }
}
