//! §Perf ablation driver: in-process A/B of hot-path variants with
//! min-of-N statistics (robust to the shared-box noise that defeats
//! mean/median comparisons across processes).

use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use std::hint::black_box;
use std::time::Instant;

fn min_time<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let smoke = gvt_rls::bench::smoke();
    let quick = std::env::var("GVT_RLS_BENCH_QUICK").is_ok() || smoke;
    let (k, n, reps) =
        if smoke { (32, 300, 2) } else if quick { (64, 2000, 10) } else { (192, 16_000, 60) };
    let data = KernelFillingConfig::small().generate(k, n, 42);
    let a: Vec<f64> = (0..n).map(|i| ((i % 13) as f64) - 6.0).collect();
    println!("# perf ablation (k={k}, n={n}, min of {reps})\n");
    for policy in [GvtPolicy::SparseLeft, GvtPolicy::SparseRight, GvtPolicy::Dense, GvtPolicy::Auto] {
        let op = PairwiseLinOp::new(
            PairwiseKernel::Kronecker,
            data.d.clone(), data.t.clone(), data.pairs.clone(), data.pairs.clone(), policy,
        ).unwrap();
        let t = min_time(reps, || { black_box(op.matvec(black_box(&a))); });
        println!("kron {policy:?}: {:.3} ms", t * 1e3);
    }
    for kernel in [PairwiseKernel::Poly2D, PairwiseKernel::Mlpk] {
        let op = PairwiseLinOp::new(
            kernel, data.d.clone(), data.t.clone(), data.pairs.clone(), data.pairs.clone(), GvtPolicy::Auto,
        ).unwrap();
        let t = min_time(reps / 2, || { black_box(op.matvec(black_box(&a))); });
        println!("{}: {:.3} ms", kernel.name(), t * 1e3);
    }

    // Plan-fusion ablation (§Plan-Fusion): fused plan vs the isolated
    // per-term path, in-process (equivalent to GVT_RLS_NO_FUSE=1).
    println!("\n## plan fusion (fused vs per-term)\n");
    for kernel in [
        PairwiseKernel::Ranking,
        PairwiseKernel::Mlpk,
        PairwiseKernel::Symmetric,
        PairwiseKernel::Poly2D,
    ] {
        let op = PairwiseLinOp::new(
            kernel, data.d.clone(), data.t.clone(), data.pairs.clone(), data.pairs.clone(), GvtPolicy::Auto,
        ).unwrap();
        let mut out = vec![0.0; n];
        let t_fused = min_time(reps.max(2) / 2, || { op.matvec_into(black_box(&a), black_box(&mut out)); });
        let t_unfused = min_time(reps.max(2) / 2, || { op.matvec_into_unfused(black_box(&a), black_box(&mut out)); });
        println!(
            "{:<12} [{}]: fused {:.3} ms, unfused {:.3} ms, speedup {:.2}x",
            kernel.name(), op.plan_summary(), t_fused * 1e3, t_unfused * 1e3, t_unfused / t_fused.max(1e-12)
        );
    }

    // Multi-RHS: matmat over an 8-vector block vs 8 matvecs.
    {
        let b = 8;
        let cols: Vec<Vec<f64>> =
            (0..b).map(|s| (0..n).map(|i| (((i + s) % 11) as f64) - 5.0).collect()).collect();
        let refs: Vec<&[f64]> = cols.iter().map(|v| v.as_slice()).collect();
        let ab = gvt_rls::linalg::Mat::from_columns(&refs);
        let op = PairwiseLinOp::new(
            PairwiseKernel::Kronecker,
            data.d.clone(), data.t.clone(), data.pairs.clone(), data.pairs.clone(), GvtPolicy::Auto,
        ).unwrap();
        let t_block = min_time(reps.max(2) / 2, || { black_box(op.matmat(black_box(&ab))); });
        let t_loop = min_time(reps.max(2) / 2, || {
            for c in &cols {
                black_box(op.matvec(black_box(c)));
            }
        });
        println!(
            "\nmatmat B={b}: block {:.3} ms vs column-loop {:.3} ms ({:.2}x)",
            t_block * 1e3, t_loop * 1e3, t_loop / t_block.max(1e-12)
        );
    }

    // Cartesian: the paper's GVT formulation vs the Kashima (2009b)
    // Kronecker-sum shortcut it improves on (§4.8).
    {
        let op = PairwiseLinOp::new(
            PairwiseKernel::Cartesian,
            data.d.clone(), data.t.clone(), data.pairs.clone(), data.pairs.clone(), GvtPolicy::Auto,
        ).unwrap();
        let t_gvt = min_time(reps, || { black_box(op.matvec(black_box(&a))); });
        let t_kashima = min_time(reps, || {
            black_box(gvt_rls::gvt::kashima::cartesian_matvec_kashima(
                &data.d, &data.t, &data.pairs, &data.pairs, black_box(&a),
            ));
        });
        println!("cartesian GVT: {:.3} ms | Kashima O(m²q+q²m): {:.3} ms", t_gvt * 1e3, t_kashima * 1e3);
    }

    // Third-order GVT (the §7 extension).
    {
        use gvt_rls::gvt::tensor::{gvt3_matvec, TripletIndex};
        use gvt_rls::rng::{dist, Rng, Xoshiro256};
        use gvt_rls::testing::gen;
        let mut rng = Xoshiro256::seed_from(9);
        let (m, q, c, n3) = (48, 48, 12, n);
        let d = gen::psd_kernel(&mut rng, m);
        let t = gen::psd_kernel(&mut rng, q);
        let cm = gen::psd_kernel(&mut rng, c);
        let trip = TripletIndex::new(
            (0..n3).map(|_| rng.index(m) as u32).collect(),
            (0..n3).map(|_| rng.index(q) as u32).collect(),
            (0..n3).map(|_| rng.index(c) as u32).collect(),
            m, q, c,
        );
        let a3 = dist::normal_vec(&mut rng, n3);
        let t3 = min_time(reps / 2, || {
            black_box(gvt3_matvec(&d, &t, &cm, &trip, &trip, black_box(&a3)));
        });
        println!("gvt3 (m=q=48, c=12, n={n3}): {:.3} ms", t3 * 1e3);
    }
}
