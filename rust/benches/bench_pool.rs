//! Runtime-pool ablation: persistent parked workers vs the pre-pool
//! scoped-spawn path (`GVT_RLS_POOL=0`), A/B'd in-process via
//! [`gvt_rls::runtime::pool::set_pool_enabled`]. Three views:
//!
//! 1. **Region dispatch** — a fixed-size trivial fill, isolating the
//!    per-parallel-region overhead (condvar wake vs thread spawn/join)
//!    that every GVT stage pays.
//! 2. **GVT mat-vec latency** — Kronecker (1 term) and MLPK (10 terms,
//!    concurrent multi-unit stage 1) at n ∈ {4k, 16k, 64k}.
//! 3. **Per-iteration solver overhead** — a fixed-iteration MINRES run
//!    divided by its iteration count: the number a training run
//!    multiplies by thousands.
//!
//! Both paths produce bit-identical results (tests/pool_determinism.rs);
//! this bench records what the determinism costs or saves. Set
//! `GVT_RLS_BENCH_JSON=<path>` to emit JSON — scripts/bench.sh points it
//! at BENCH_pool.json.

use gvt_rls::bench::{reduced_size, BenchConfig, BenchSuite};
use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use gvt_rls::linalg::par;
use gvt_rls::runtime::pool;
use gvt_rls::solvers::linear_op::{LinOp, ShiftedOp};
use gvt_rls::solvers::minres::{minres, MinresOptions};
use std::hint::black_box;
use std::ops::ControlFlow;

const MODES: [(&str, bool); 2] = [("pooled", true), ("scoped", false)];

fn main() {
    let cfg = BenchConfig::from_env();
    let mut suite = BenchSuite::new();
    let (k, sizes): (usize, &[usize]) =
        if reduced_size() { (48, &[800]) } else { (192, &[4_000, 16_000, 64_000]) };
    pool::warm();

    // 1. Region-dispatch overhead on a trivial fixed-size fill.
    println!("# bench_pool — persistent pool vs scoped spawn\n");
    let mut buf = vec![0.0f64; 64 * 1024];
    for (label, on) in MODES {
        pool::set_pool_enabled(Some(on));
        suite.run(&format!("region-dispatch 64k-fill        {label}"), &cfg, || {
            par::parallel_fill(&mut buf, 1024, |start, _end, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (start + i) as f64;
                }
            });
            black_box(&buf);
        });
    }

    // 2 + 3. GVT mat-vec latency and per-iteration solver overhead.
    let mut speedups: Vec<(String, usize, f64)> = Vec::new();
    for &n in sizes {
        let data = KernelFillingConfig::small().generate(k, n, 42);
        let a: Vec<f64> = (0..n).map(|i| ((i % 9) as f64) - 4.0).collect();
        let y: Vec<f64> = (0..n).map(|i| ((i % 7) as f64) - 3.0).collect();
        println!("\n## n = {n}, m = q = {k}\n");
        for kernel in [PairwiseKernel::Kronecker, PairwiseKernel::Mlpk] {
            let op = PairwiseLinOp::new(
                kernel,
                data.d.clone(),
                data.t.clone(),
                data.pairs.clone(),
                data.pairs.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let mut out = vec![0.0; n];
            let mut means = [0.0f64; 2];
            for (mi, &(label, on)) in MODES.iter().enumerate() {
                pool::set_pool_enabled(Some(on));
                let r = suite.run(
                    &format!("{:<10} n={n:<6} matvec      {label}", kernel.name()),
                    &cfg,
                    || {
                        op.apply_into(black_box(&a), black_box(&mut out));
                    },
                );
                means[mi] = r.mean.as_secs_f64();
            }
            let s = means[1] / means[0].max(1e-12);
            println!("    {} n={n}: pooled speedup {s:.2}x over scoped", kernel.name());
            speedups.push((format!("{}-matvec", kernel.name()), n, s));
        }

        // Per-iteration solver overhead (MINRES, fixed 8 iterations).
        let op = PairwiseLinOp::new(
            PairwiseKernel::Kronecker,
            data.d.clone(),
            data.t.clone(),
            data.pairs.clone(),
            data.pairs.clone(),
            GvtPolicy::Auto,
        )
        .unwrap();
        let shifted = ShiftedOp::new(&op, 1e-3);
        let iters = 8usize;
        let mut means = [0.0f64; 2];
        for (mi, &(label, on)) in MODES.iter().enumerate() {
            pool::set_pool_enabled(Some(on));
            let r = suite.run(
                &format!("minres-{iters}it  n={n:<6} solver      {label}"),
                &cfg,
                || {
                    let out = minres(
                        &shifted,
                        black_box(&y),
                        &MinresOptions { max_iters: iters, rel_tol: 0.0 },
                        |_, _, _| ControlFlow::Continue(()),
                    )
                    .unwrap();
                    black_box(out.x);
                },
            );
            means[mi] = r.mean.as_secs_f64();
            println!(
                "    per-iteration ({label}): {:.1} µs",
                r.mean.as_secs_f64() * 1e6 / iters as f64
            );
        }
        let s = means[1] / means[0].max(1e-12);
        println!("    minres n={n}: pooled speedup {s:.2}x over scoped");
        speedups.push(("minres-iter".to_string(), n, s));
    }
    pool::set_pool_enabled(None);

    println!("\n{}", suite.table());
    for (name, n, s) in &speedups {
        println!("pooled speedup {name} n={n}: {s:.2}x");
    }

    if let Ok(path) = std::env::var("GVT_RLS_BENCH_JSON") {
        let meta: Vec<(&str, String)> = vec![
            ("bench", "bench_pool".to_string()),
            ("domain", k.to_string()),
            ("threads", par::num_threads().to_string()),
            (
                "sizes",
                sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(","),
            ),
            (
                "speedups",
                speedups
                    .iter()
                    .map(|(nm, n, s)| format!("{nm}@{n}={s:.3}x"))
                    .collect::<Vec<_>>()
                    .join(";"),
            ),
        ];
        suite.write_json(&path, &meta).expect("writing bench JSON");
        println!("wrote {path}");
    }
}
