//! The three-layer bridge: rust-native GVT vs the AOT-compiled JAX/Pallas
//! artifact (PJRT CPU) on identical Kronecker mat-vecs. Not a paper
//! figure — this is the ablation for rust/DESIGN.md §Hardware-Adaptation: the
//! dense artifact formulation costs O(q²m) FLOPs vs the sparse O(n(m+q)),
//! so on CPU the sparse rust path should win at low density and the gap
//! should close as density → 1.

use gvt_rls::bench::{BenchConfig, BenchSuite};
use gvt_rls::gvt::vec_trick::{gvt_matvec, GvtPolicy};
use gvt_rls::rng::{dist, Xoshiro256};
use gvt_rls::runtime::{KronExec, Registry};
use gvt_rls::testing::gen;
use std::hint::black_box;

fn main() {
    let Some(reg) = Registry::discover() else {
        println!("bench_runtime SKIPPED: artifacts not built (run `make artifacts`)");
        return;
    };
    let cfg = BenchConfig::from_env();
    let mut suite = BenchSuite::new();
    let quick = std::env::var("GVT_RLS_BENCH_QUICK").is_ok() || gvt_rls::bench::smoke();

    let m = if quick { 64 } else { 128 };
    let meta = reg.pick(m, m).expect("no artifact bucket").clone();
    let exec = KronExec::load(&reg, &meta).expect("compile artifact");
    println!("# bench_runtime — rust GVT vs XLA artifact {} \n", meta.name);

    let mut rng = Xoshiro256::seed_from(42);
    let d = gen::psd_kernel(&mut rng, m);
    let t = gen::psd_kernel(&mut rng, m);

    for density in [0.05, 0.25, 1.0] {
        let n = ((m * m) as f64 * density) as usize;
        let cols = gen::pair_sample(&mut rng, n, m, m);
        let rows = gen::pair_sample(&mut rng, n, m, m);
        let a = dist::normal_vec(&mut rng, n);

        suite.run(&format!("rust gvt  m={m} density={density}"), &cfg, || {
            black_box(gvt_matvec(
                black_box(&d),
                &t,
                &rows,
                &cols,
                black_box(&a),
                GvtPolicy::Auto,
            ));
        });
        suite.run(&format!("xla kron  m={m} density={density}"), &cfg, || {
            black_box(exec.matvec(black_box(&d), &t, &rows, &cols, black_box(&a)).unwrap());
        });
    }

    println!("\n{}", suite.table());
    println!(
        "(the XLA path includes per-call host↔device literal transfers; \
         on a real TPU the dense formulation amortizes those over MXU \
         throughput — see rust/DESIGN.md §Hardware-Adaptation)"
    );
}
