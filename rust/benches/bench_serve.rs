//! Serving-path benchmark: micro-batched vs per-request scoring, plus
//! request-latency percentiles through a live batcher.
//!
//! For each batch size `b ∈ {1, 8, 64, 256}` two rows are measured:
//!
//! * `serve batched     b=N` — one `Predictor::score` call carrying `b`
//!   pairs (one operator build + one GVT pass for the batch);
//! * `serve per-request b=N` — `b` separate 1-pair `score` calls (the
//!   no-batching ablation: every request pays the full stage-1 streaming
//!   of the training sample's index arrays).
//!
//! The acceptance signal is the batched row beating `b ×` the per-pair
//! cost of the per-request row from `b ≥ 8` — the `speedup@b` meta
//! entries in BENCH_serve.json record exactly that ratio. A final
//! section drives a live [`Batcher`] with concurrent 1-pair clients and
//! reports p50/p99 request latency per batching window.
//!
//! Set `GVT_RLS_BENCH_JSON=<path>` to emit the suite as JSON —
//! scripts/bench.sh points it at BENCH_serve.json in the repo root.

use gvt_rls::bench::{reduced_size, BenchConfig, BenchSuite};
use gvt_rls::data::metz::MetzConfig;
use gvt_rls::gvt::pairwise::PairwiseKernel;
use gvt_rls::rng::Xoshiro256;
use gvt_rls::serve::{BatchConfig as ServeBatch, Batcher, Predictor, QueryPair, ServeOptions};
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};
use gvt_rls::testing::gen;
use std::hint::black_box;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn percentile(sorted: &[Duration], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn main() {
    let cfg = BenchConfig::from_env();
    let mut suite = BenchSuite::new();

    // Problem: a Metz-like drug–target task. The serving cost model is
    // dominated by the training-sample size n (stage 1 streams it once
    // per pass), so n is the knob.
    let data = if reduced_size() {
        MetzConfig::small().generate(42)
    } else {
        MetzConfig::paper().generate(42)
    };
    let (m, q) = (data.pairs.m(), data.pairs.q());
    println!(
        "# bench_serve — online inference over '{}' ({} training pairs, {}x{} domains)\n",
        data.name,
        data.len(),
        m,
        q
    );
    let ridge_cfg = RidgeConfig {
        max_iters: if reduced_size() { 15 } else { 60 },
        ..Default::default()
    };
    let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &ridge_cfg)
        .expect("training the serving model");
    let predictor =
        Arc::new(Predictor::new(model, None, None, ServeOptions::default()).unwrap());
    println!(
        "policy {} | plan [{}]\n",
        predictor.policy().name(),
        predictor.plan_summary()
    );

    // A pool of in-domain queries to draw batches from.
    let mut rng = Xoshiro256::seed_from(7);
    let pool_size = 4096.max(256);
    let pool_idx = gen::pair_sample(&mut rng, pool_size, m, q);
    let pool: Vec<QueryPair> = (0..pool_size)
        .map(|i| QueryPair::known(pool_idx.drug(i) as u32, pool_idx.target(i) as u32))
        .collect();

    let batch_sizes: &[usize] = if reduced_size() { &[1, 8, 64] } else { &[1, 8, 64, 256] };
    let mut speedups: Vec<(usize, f64)> = Vec::new();
    for &b in batch_sizes {
        let mut off = 0usize;
        let batched_mean = suite
            .run(&format!("serve batched     b={b:<3}"), &cfg, || {
                let chunk = &pool[off..off + b];
                off = (off + b) % (pool.len() - b);
                black_box(predictor.score(black_box(chunk)).unwrap());
            })
            .mean
            .as_secs_f64();
        let mut off2 = 0usize;
        let per_req_mean = suite
            .run(&format!("serve per-request b={b:<3}"), &cfg, || {
                for k in 0..b {
                    let at = (off2 + k) % pool.len();
                    let one = &pool[at..at + 1];
                    black_box(predictor.score(black_box(one)).unwrap());
                }
                off2 = (off2 + b) % (pool.len() - b);
            })
            .mean
            .as_secs_f64();
        let speedup = per_req_mean / batched_mean.max(1e-12);
        let thru = b as f64 / batched_mean.max(1e-12);
        println!(
            "    b={b}: batched {:.3} ms ({:.0} pairs/s) vs per-request {:.3} ms → {speedup:.2}x",
            batched_mean * 1e3,
            thru,
            per_req_mean * 1e3
        );
        speedups.push((b, speedup));
    }

    // Latency distribution through the live dispatcher: concurrent
    // 1-pair clients, one batching window.
    let clients = 4usize;
    let per_client = if reduced_size() { 8usize } else { 64 };
    let mut latency_meta: Vec<(usize, Duration, Duration)> = Vec::new();
    for &window_us in &[0u64, 200] {
        let batcher = Batcher::start(
            predictor.clone(),
            ServeBatch {
                max_batch: 64,
                max_wait: Duration::from_micros(window_us),
                ..ServeBatch::default()
            },
        );
        let mut threads = Vec::new();
        for c in 0..clients {
            let handle = batcher.handle();
            let queries: Vec<QueryPair> = (0..per_client)
                .map(|k| pool[(c * per_client + k) % pool.len()].clone())
                .collect();
            threads.push(std::thread::spawn(move || {
                let mut lat = Vec::with_capacity(queries.len());
                for query in queries {
                    let t0 = Instant::now();
                    let _ = handle.score(vec![query]).unwrap();
                    lat.push(t0.elapsed());
                }
                lat
            }));
        }
        let mut lat: Vec<Duration> = Vec::new();
        for th in threads {
            lat.extend(th.join().unwrap());
        }
        batcher.shutdown();
        lat.sort();
        let (p50, p99) = (percentile(&lat, 0.50), percentile(&lat, 0.99));
        println!(
            "latency window={window_us}us clients={clients}: p50 {:.1} µs, p99 {:.1} µs ({} reqs)",
            p50.as_secs_f64() * 1e6,
            p99.as_secs_f64() * 1e6,
            lat.len()
        );
        latency_meta.push((window_us as usize, p50, p99));
    }

    let stats = predictor.stats();
    println!(
        "\ndispatcher: {} requests in {} batches (max {} pairs/batch)\n",
        stats.requests, stats.batches, stats.batch_pairs_max
    );
    println!("{}", suite.table());

    if let Ok(path) = std::env::var("GVT_RLS_BENCH_JSON") {
        let mut meta: Vec<(&str, String)> = vec![
            ("bench", "bench_serve".to_string()),
            ("train_pairs", data.len().to_string()),
            ("domains", format!("{m}x{q}")),
            ("kernel", "kronecker".to_string()),
            ("policy", predictor.policy().name().to_string()),
            (
                "speedups",
                speedups
                    .iter()
                    .map(|(b, s)| format!("batched@{b}={s:.3}x"))
                    .collect::<Vec<_>>()
                    .join(";"),
            ),
        ];
        let latency = latency_meta
            .iter()
            .map(|(w, p50, p99)| {
                format!(
                    "window{w}us:p50={:.1}us,p99={:.1}us",
                    p50.as_secs_f64() * 1e6,
                    p99.as_secs_f64() * 1e6
                )
            })
            .collect::<Vec<_>>()
            .join(";");
        meta.push(("latency", latency));
        suite.write_json(&path, &meta).expect("writing bench JSON");
        println!("wrote {path}");
    }
}
