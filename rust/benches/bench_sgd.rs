//! Stochastic vec trick vs exact CG: time-to-ε on the training objective.
//!
//! For each kernel and problem size, solve `(K + λI)α = y` two ways —
//! exact CG (one full GVT product per iteration) and mini-batched SGD
//! (one batch-shaped product per step, [`gvt_rls::solvers::SgdTrainer`])
//! — both run until the relative residual / gradient norm drops below
//! the same ε, and report wall-clock time plus iteration/step counts.
//! The interesting regime is `n ≫ m, q`, where the exact iteration's
//! `O(n·m)` stage-2 sweep dominates and the batch step's `O(b·m)` wins.
//!
//! Set `GVT_RLS_BENCH_JSON=<path>` to emit the suite as JSON —
//! scripts/bench.sh points it at BENCH_sgd.json in the repo root to seed
//! the perf trajectory (full sizes: n ∈ {16k, 64k}, all 8 kernels).

use gvt_rls::bench::{reduced_size, smoke, BenchConfig, BenchSuite};
use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use gvt_rls::solvers::cg::{cg, CgOptions};
use gvt_rls::solvers::linear_op::ShiftedOp;
use gvt_rls::solvers::{SgdConfig, SgdTrainer};
use std::hint::black_box;
use std::ops::ControlFlow;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut suite = BenchSuite::new();
    // ε: the stochastic solver's practical accuracy regime — both
    // solvers stop at the same relative residual so times compare.
    let epsilon = 1e-3;
    let lambda = 1e-2;
    let (k, sizes, kernels): (usize, &[usize], &[PairwiseKernel]) = if smoke() {
        (32, &[400], &[PairwiseKernel::Kronecker, PairwiseKernel::Ranking])
    } else if reduced_size() {
        (48, &[1_500], &PairwiseKernel::ALL)
    } else {
        (256, &[16_000, 64_000], &PairwiseKernel::ALL)
    };
    let (batch, max_epochs) = if smoke() { (64, 40) } else { (1_024, 400) };

    println!(
        "# bench_sgd — exact CG vs stochastic vec trick, time-to-ε \
         (ε = {epsilon:.0e}, λ = {lambda}, batch = {batch})\n"
    );

    let mut rows: Vec<(String, usize, f64, f64)> = Vec::new();
    for &n in sizes {
        let data = KernelFillingConfig::small().generate(k, n, 42);
        for &kernel in kernels {
            // --- exact CG to ε -------------------------------------
            let op = PairwiseLinOp::new(
                kernel,
                data.d.clone(),
                data.t.clone(),
                data.pairs.clone(),
                data.pairs.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let mut cg_iters = 0;
            let r_cg = suite.run(
                &format!("{:<14} n={n:<6} cg  →ε", kernel.name()),
                &cfg,
                || {
                    let shifted = ShiftedOp::new(&op, lambda);
                    let out = cg(
                        &shifted,
                        black_box(&data.y),
                        None,
                        &CgOptions { max_iters: 10_000, rel_tol: epsilon },
                        |_, _, _| ControlFlow::Continue(()),
                    )
                    .unwrap();
                    cg_iters = out.iterations;
                    black_box(out.x);
                },
            );
            let cg_secs = r_cg.mean.as_secs_f64();

            // --- stochastic vec trick to ε -------------------------
            // Trainer built once outside the timed region: the compiled
            // template + power-iteration step bound are one-off setup a
            // λ grid amortizes; the timed quantity is the training loop.
            let scfg = SgdConfig {
                batch_size: batch,
                epochs: max_epochs,
                tol: epsilon,
                check_every: 1,
                ..Default::default()
            };
            let trainer = SgdTrainer::new(&data, kernel, scfg).unwrap();
            let mut sgd_epochs = 0;
            let mut sgd_converged = false;
            let r_sgd = suite.run(
                &format!("{:<14} n={n:<6} sgd →ε", kernel.name()),
                &cfg,
                || {
                    let run = trainer.fit(lambda, 7).unwrap();
                    sgd_epochs = run.epochs;
                    sgd_converged = run.converged;
                    black_box(run.alpha);
                },
            );
            let sgd_secs = r_sgd.mean.as_secs_f64();
            println!(
                "    {} n={n}: cg {cg_iters} iters {:.1}ms | sgd {sgd_epochs} epochs \
                 {:.1}ms (converged={sgd_converged}) | ratio {:.2}x",
                kernel.name(),
                cg_secs * 1e3,
                sgd_secs * 1e3,
                cg_secs / sgd_secs.max(1e-12)
            );
            rows.push((kernel.name().to_string(), n, cg_secs, sgd_secs));
        }
    }

    println!("\n{}", suite.table());

    if let Ok(path) = std::env::var("GVT_RLS_BENCH_JSON") {
        let meta: Vec<(&str, String)> = vec![
            ("bench", "bench_sgd".to_string()),
            ("epsilon", format!("{epsilon:e}")),
            ("lambda", lambda.to_string()),
            ("batch", batch.to_string()),
            ("domain", k.to_string()),
            (
                "sizes",
                sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(","),
            ),
            (
                "time_to_eps",
                rows.iter()
                    .map(|(nm, n, c, s)| format!("{nm}@{n}:cg={c:.4}s,sgd={s:.4}s"))
                    .collect::<Vec<_>>()
                    .join(";"),
            ),
        ];
        suite.write_json(&path, &meta).expect("writing bench JSON");
        println!("wrote {path}");
    }
}
