//! Solver-layer benches: MINRES iteration cost through GVT vs explicit
//! operators (the per-iteration costs behind Figure 7's time panel), and
//! Figure 3's iteration-count-to-optimum by setting.

use gvt_rls::bench::{BenchConfig, BenchSuite};
use gvt_rls::data::kernel_filling::KernelFillingConfig;
use gvt_rls::gvt::explicit::ExplicitLinOp;
use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use gvt_rls::gvt::vec_trick::GvtPolicy;
use gvt_rls::solvers::linear_op::ShiftedOp;
use gvt_rls::solvers::minres::{minres, MinresOptions};
use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};
use std::hint::black_box;
use std::ops::ControlFlow;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut suite = BenchSuite::new();
    let smoke = gvt_rls::bench::smoke();
    let quick = std::env::var("GVT_RLS_BENCH_QUICK").is_ok() || smoke;
    let (k, n, iters) =
        if smoke { (32, 400, 4) } else if quick { (48, 1_500, 10) } else { (128, 8_000, 25) };
    let data = KernelFillingConfig::small().generate(k, n, 42);

    println!("# bench_solvers — MINRES training cost (n = {n}, {iters} iterations)\n");

    let gvt_op = PairwiseLinOp::new(
        PairwiseKernel::Kronecker,
        data.d.clone(),
        data.t.clone(),
        data.pairs.clone(),
        data.pairs.clone(),
        GvtPolicy::Auto,
    )
    .unwrap();
    suite.run(&format!("minres {iters} iters, GVT operator"), &cfg, || {
        let shifted = ShiftedOp::new(&gvt_op, 1e-5);
        black_box(
            minres(
                &shifted,
                black_box(&data.y),
                &MinresOptions { max_iters: iters, rel_tol: 0.0 },
                |_, _, _| ControlFlow::Continue(()),
            )
            .unwrap(),
        );
    });

    if n <= 8_000 {
        let exp_op = ExplicitLinOp::new(
            PairwiseKernel::Kronecker,
            &data.d,
            &data.t,
            &data.pairs,
            &data.pairs,
        );
        suite.run(&format!("minres {iters} iters, explicit operator"), &cfg, || {
            let shifted = ShiftedOp::new(&exp_op, 1e-5);
            black_box(
                minres(
                    &shifted,
                    black_box(&data.y),
                    &MinresOptions { max_iters: iters, rel_tol: 0.0 },
                    |_, _, _| ControlFlow::Continue(()),
                )
                .unwrap(),
            );
        });
    }

    println!("\n{}", suite.table());

    // Figure 3/7 iterations panel: optimal iteration count per setting.
    println!("## iterations to optimal validation AUC by setting (Kronecker)\n");
    let rcfg = RidgeConfig {
        max_iters: if smoke { 8 } else if quick { 30 } else { 100 },
        patience: 10,
        ..Default::default()
    };
    for setting in 1..=4u8 {
        let split = data.split_setting(setting, 0.25, 7);
        let inner = split.train.split_setting(setting, 0.25, 8);
        if inner.train.is_empty() || inner.test.is_empty() {
            continue;
        }
        let (best, _) = PairwiseRidge::find_optimal_iters(
            &inner.train,
            &inner.test,
            PairwiseKernel::Kronecker,
            &rcfg,
        )
        .unwrap();
        println!("setting {setting}: optimal at {best} iterations");
    }
    println!("\n(paper shape: setting 1 needs most iterations, setting 4 fewest)");
}
