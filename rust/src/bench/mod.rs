//! Benchmark harness (criterion is unavailable offline).
//!
//! Plain `harness = false` bench binaries drive this: adaptive iteration
//! count against a wall-clock budget, warmup, median/mean/σ, and markdown
//! output. Deliberately simple — the benches compare *methods against each
//! other* (GVT vs explicit, kernel vs kernel), so relative numbers are
//! what matters.

use std::time::{Duration, Instant};

/// Statistics of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    /// Milliseconds mean (series plotting).
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Total measurement budget per benchmark.
    pub budget: Duration,
    /// Warmup runs (not measured).
    pub warmup: usize,
    /// Max measured iterations.
    pub max_iters: usize,
    /// Min measured iterations.
    pub min_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            budget: Duration::from_secs(2),
            warmup: 2,
            max_iters: 50,
            min_iters: 3,
        }
    }
}

impl BenchConfig {
    /// Environment-driven config: `GVT_BENCH_SMOKE=1` → 1 warmup + 1
    /// measured iteration (CI smoke execution, see scripts/verify.sh);
    /// `GVT_RLS_BENCH_QUICK=1` → short budget for local iteration.
    pub fn from_env() -> Self {
        if smoke() {
            Self {
                budget: Duration::ZERO,
                warmup: 1,
                max_iters: 1,
                min_iters: 1,
            }
        } else if std::env::var("GVT_RLS_BENCH_QUICK").is_ok() {
            Self {
                budget: Duration::from_millis(300),
                warmup: 1,
                max_iters: 5,
                min_iters: 1,
            }
        } else {
            Self::default()
        }
    }
}

/// `GVT_BENCH_SMOKE=1` — benches run 1 warmup + 1 iteration on minimal
/// problem sizes so scripts/verify.sh can *execute* (not just build) every
/// `harness = false` bench binary without burning CI minutes.
pub fn smoke() -> bool {
    std::env::var_os("GVT_BENCH_SMOKE").is_some()
}

/// Are we in any reduced-size mode (smoke or quick)? Benches use this to
/// pick their problem dimensions.
pub fn reduced_size() -> bool {
    smoke() || std::env::var_os("GVT_RLS_BENCH_QUICK").is_some()
}

/// Run one benchmark: call `f` repeatedly under the budget. `f` should
/// perform the full operation under test (use `std::hint::black_box` on
/// inputs/outputs inside).
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while samples.len() < cfg.min_iters
        || (start.elapsed() < cfg.budget && samples.len() < cfg.max_iters)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    summarize(name, &samples)
}

fn summarize(name: &str, samples: &[Duration]) -> BenchResult {
    let mut sorted = samples.to_vec();
    sorted.sort();
    let n = sorted.len();
    let total: Duration = sorted.iter().sum();
    let mean = total / (n as u32);
    let median = sorted[n / 2];
    let mean_s = mean.as_secs_f64();
    let var = sorted
        .iter()
        .map(|d| {
            let x = d.as_secs_f64() - mean_s;
            x * x
        })
        .sum::<f64>()
        / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean,
        median,
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: sorted[0],
        max: sorted[n - 1],
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Pretty-print duration adaptively.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Collects results and prints a markdown table at the end.
#[derive(Default)]
pub struct BenchSuite {
    results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run and record one benchmark, echoing a progress line.
    pub fn run<F: FnMut()>(&mut self, name: &str, cfg: &BenchConfig, f: F) -> &BenchResult {
        let r = bench(name, cfg, f);
        println!(
            "  {:<52} {:>12} (median {:>12}, n={})",
            r.name,
            fmt_duration(r.mean),
            fmt_duration(r.median),
            r.iters
        );
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Serialize the suite to a JSON file (no serde offline — hand-rolled
    /// emitter). Shape:
    /// `{"meta": {...}, "results": [{"name", "iters", "mean_ms", ...}]}`.
    /// `meta` carries free-form context (problem sizes, git describe, the
    /// fused/unfused ablation tag) so perf trajectories stay
    /// self-describing.
    pub fn write_json(
        &self,
        path: impl AsRef<std::path::Path>,
        meta: &[(&str, String)],
    ) -> std::io::Result<()> {
        let mut out = String::from("{\n  \"meta\": {");
        for (i, (k, v)) in meta.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\n    \"{}\": \"{}\"", json_escape(k), json_escape(v)));
        }
        out.push_str("\n  },\n  \"results\": [");
        for (i, r) in self.results.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"iters\": {}, \"mean_ms\": {:.6}, \
                 \"median_ms\": {:.6}, \"stddev_ms\": {:.6}, \"min_ms\": {:.6}, \
                 \"max_ms\": {:.6}}}",
                json_escape(&r.name),
                r.iters,
                r.mean.as_secs_f64() * 1e3,
                r.median.as_secs_f64() * 1e3,
                r.stddev.as_secs_f64() * 1e3,
                r.min.as_secs_f64() * 1e3,
                r.max.as_secs_f64() * 1e3,
            ));
        }
        out.push_str("\n  ]\n}\n");
        std::fs::write(path, out)
    }

    /// Markdown summary table.
    pub fn table(&self) -> String {
        let mut out = String::from(
            "| benchmark                                            |        mean |      median |      stddev | iters |\n\
             |------------------------------------------------------|-------------|-------------|-------------|-------|\n",
        );
        for r in &self.results {
            out.push_str(&format!(
                "| {:<52} | {:>11} | {:>11} | {:>11} | {:>5} |\n",
                r.name,
                fmt_duration(r.mean),
                fmt_duration(r.median),
                fmt_duration(r.stddev),
                r.iters
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let cfg = BenchConfig {
            budget: Duration::from_millis(50),
            warmup: 1,
            max_iters: 10,
            min_iters: 2,
        };
        let r = bench("spin", &cfg, || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert!(r.iters >= 2);
        assert!(r.min <= r.median && r.median <= r.max);
    }

    #[test]
    fn suite_table_contains_rows() {
        let mut s = BenchSuite::new();
        let cfg = BenchConfig {
            budget: Duration::from_millis(10),
            warmup: 0,
            max_iters: 2,
            min_iters: 1,
        };
        s.run("noop", &cfg, || {});
        assert!(s.table().contains("noop"));
    }

    #[test]
    fn write_json_roundtrips_through_parser() {
        let mut s = BenchSuite::new();
        let cfg = BenchConfig {
            budget: Duration::from_millis(5),
            warmup: 0,
            max_iters: 2,
            min_iters: 1,
        };
        s.run("kernel \"x\"", &cfg, || {});
        let path = std::env::temp_dir().join("gvt_rls_bench_json_test.json");
        s.write_json(&path, &[("n", "16000".to_string())]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::runtime::json::Json::parse(&text).unwrap();
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().as_str().unwrap(), "kernel \"x\"");
        assert!(results[0].get("mean_ms").unwrap().as_f64().unwrap() >= 0.0);
        let meta = parsed.get("meta").unwrap();
        assert_eq!(meta.get("n").unwrap().as_str().unwrap(), "16000");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.0 µs");
    }
}
