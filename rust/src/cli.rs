//! Hand-rolled CLI argument parsing (no clap offline).
//!
//! Grammar: `gvt-rls <subcommand> [--flag value]... [--switch]... [key=value]...`
//! Positional `key=value` tokens become config overrides.

use crate::error::{bail, gvt_err, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    /// First positional token (the subcommand).
    pub command: String,
    /// `--name value` options.
    pub options: BTreeMap<String, String>,
    /// `--name` switches with no value.
    pub switches: Vec<String>,
    /// Positional `key=value` overrides.
    pub overrides: Vec<String>,
    /// Remaining bare positionals.
    pub positionals: Vec<String>,
}

impl Cli {
    /// Parse from an argument iterator (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Cli> {
        let mut cli = Cli::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    cli.options.insert(name.to_string(), v);
                } else {
                    cli.switches.push(name.to_string());
                }
            } else if cli.command.is_empty() {
                cli.command = arg;
            } else if arg.contains('=') {
                cli.overrides.push(arg);
            } else {
                cli.positionals.push(arg);
            }
        }
        Ok(cli)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| gvt_err!("--{name} {v}: not an integer")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| gvt_err!("--{name} {v}: not an integer")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| gvt_err!("--{name} {v}: not a number")),
        }
    }

    /// A mandatory option (`predict`/`serve` require `--model` etc.).
    pub fn require_opt(&self, name: &str) -> Result<&str> {
        self.opt(name).ok_or_else(|| gvt_err!("missing required option --{name}"))
    }

    /// An option constrained to a fixed vocabulary (`--solver`,
    /// `--schedule`): unknown values error with the accepted list
    /// instead of a bare parse failure downstream.
    pub fn opt_choice(&self, name: &str, default: &str, choices: &[&str]) -> Result<String> {
        let v = self.opt_or(name, default).to_ascii_lowercase();
        if choices.iter().any(|c| *c == v) {
            Ok(v)
        } else {
            bail!("--{name} {v}: expected one of {}", choices.join("|"))
        }
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_switches() {
        let c = parse("experiment fig4 --folds 9 --quick --seed=42 lambda=1e-5");
        assert_eq!(c.command, "experiment");
        assert_eq!(c.positionals, vec!["fig4"]);
        assert_eq!(c.opt("folds"), Some("9"));
        assert!(c.has_switch("quick"));
        assert_eq!(c.opt_u64("seed", 0).unwrap(), 42);
        assert_eq!(c.overrides, vec!["lambda=1e-5"]);
    }

    #[test]
    fn option_followed_by_option() {
        let c = parse("train --verbose --kernel kronecker");
        assert!(c.has_switch("verbose"));
        assert_eq!(c.opt("kernel"), Some("kronecker"));
    }

    #[test]
    fn numeric_errors() {
        let c = parse("x --n abc");
        assert!(c.opt_usize("n", 1).is_err());
    }

    #[test]
    fn opt_choice_validates_vocabulary() {
        let c = parse("train --solver SGD");
        assert_eq!(
            c.opt_choice("solver", "minres", &["minres", "cg", "sgd"]).unwrap(),
            "sgd"
        );
        let d = parse("train");
        assert_eq!(
            d.opt_choice("solver", "minres", &["minres", "cg", "sgd"]).unwrap(),
            "minres"
        );
        let e = parse("train --solver newton");
        let err = format!("{}", e.opt_choice("solver", "minres", &["minres", "cg"]).unwrap_err());
        assert!(err.contains("minres|cg"), "{err}");
    }

    #[test]
    fn require_opt_reports_the_flag() {
        let c = parse("serve --model m.txt");
        assert_eq!(c.require_opt("model").unwrap(), "m.txt");
        let err = format!("{}", c.require_opt("pairs").unwrap_err());
        assert!(err.contains("--pairs"), "{err}");
    }
}
