//! Minimal `key = value` config format for the CLI (no serde offline).
//!
//! ```text
//! # comment
//! dataset  = metz
//! kernel   = kronecker
//! setting  = 1
//! lambda   = 1e-5
//! folds    = 9
//! ```

use crate::error::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed config: ordered key → value map with typed accessors.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Parse from text. Later keys override earlier ones.
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("config line {}: expected 'key = value', got {raw:?}", lineno + 1);
            };
            values.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Config { values })
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::parse(&text)
    }

    /// Build from `key=value` CLI overrides.
    pub fn from_overrides(args: &[String]) -> Result<Config> {
        Self::parse(&args.join("\n"))
    }

    /// Merge `other` over `self`.
    pub fn merged(mut self, other: &Config) -> Config {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}: not a number")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}: not an integer")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}: not an integer")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("config {key}={v}: expected true/false"),
        }
    }

    /// Keys present (for validation / help output).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_comments_and_types() {
        let c = Config::parse(
            "# experiment\nkernel = kronecker\nlambda = 1e-5 # small\nfolds=9\nverbose = true\n",
        )
        .unwrap();
        assert_eq!(c.get_str("kernel", "x"), "kronecker");
        assert_eq!(c.get_f64("lambda", 0.0).unwrap(), 1e-5);
        assert_eq!(c.get_usize("folds", 0).unwrap(), 9);
        assert!(c.get_bool("verbose", false).unwrap());
        assert_eq!(c.get_usize("missing", 3).unwrap(), 3);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::parse("just words").is_err());
    }

    #[test]
    fn merge_overrides() {
        let a = Config::parse("x = 1\ny = 2").unwrap();
        let b = Config::parse("y = 3").unwrap();
        let m = a.merged(&b);
        assert_eq!(m.get_usize("x", 0).unwrap(), 1);
        assert_eq!(m.get_usize("y", 0).unwrap(), 3);
    }

    #[test]
    fn bad_type_errors() {
        let c = Config::parse("lambda = abc").unwrap();
        assert!(c.get_f64("lambda", 0.0).is_err());
    }
}
