//! One experiment cell: cross-validated, early-stopped pairwise ridge
//! regression on one (dataset, kernel, setting) combination — the unit of
//! work behind every bar in Figures 4, 5 and 6.

use crate::data::{splits, PairDataset};
use crate::error::{Context, Result};
use crate::eval::{auc, FoldStats};
use crate::gvt::pairwise::PairwiseKernel;
use crate::solvers::ridge::{PairwiseRidge, RidgeConfig};
use crate::solvers::sgd::{fit_sgd, SgdConfig};
use crate::solvers::Solver;
use std::time::Instant;

/// Specification of one experiment cell.
#[derive(Clone)]
pub struct ExperimentSpec {
    /// Display name, e.g. `"heterodimer-domain"`.
    pub name: String,
    /// The dataset (kernels + labeled pairs).
    pub data: PairDataset,
    /// Pairwise kernel under test.
    pub kernel: PairwiseKernel,
    /// Prediction setting 1–4.
    pub setting: u8,
    /// Number of CV folds (paper: 9).
    pub folds: usize,
    /// Trainer hyperparameters.
    pub ridge: RidgeConfig,
    /// Training algorithm: MINRES runs the paper's full early-stopping
    /// protocol, CG fits to tolerance (`K + λI` is SPD for λ > 0), and
    /// SGD runs the stochastic vec trick trainer with a configuration
    /// derived from `ridge` ([`sgd_config_for`]) — so CG-vs-SGD columns
    /// land in the figure reports next to the exact-solver rows.
    pub solver: Solver,
    /// Master seed for folds and inner splits.
    pub seed: u64,
}

/// Derive the stochastic trainer's configuration from a cell's exact
/// solver settings, keeping `--solver sgd` grids comparable to the exact
/// rows: the epoch budget mirrors `max_iters`, patience and the GVT
/// policy carry over, and batching uses the serving-style default. The
/// tolerance is the stochastic trainer's practical floor (the exact
/// `rel_tol` of 1e-10 is unreachable for mini-batched steps).
pub fn sgd_config_for(ridge: &RidgeConfig) -> SgdConfig {
    SgdConfig {
        batch_size: 256,
        epochs: ridge.max_iters,
        policy: ridge.policy,
        tol: 1e-4,
        check_every: 5,
        patience: ridge.patience.max(1),
        ..Default::default()
    }
}

/// Aggregated result of one experiment cell.
#[derive(Clone, Debug)]
pub struct ExperimentResult {
    pub name: String,
    pub kernel: PairwiseKernel,
    pub setting: u8,
    /// Test AUC across folds.
    pub auc: FoldStats,
    /// Optimal iteration counts chosen by early stopping.
    pub iterations: FoldStats,
    /// Wall-clock training seconds per fold.
    pub train_secs: FoldStats,
    /// Folds that failed (e.g. single-class test sets) — reported, not
    /// silently dropped.
    pub failed_folds: usize,
}

/// Run one cell: `folds`-fold CV per the setting's Table 1 semantics,
/// paper training protocol per fold (inner split → early stop → refit),
/// AUC on the fold's test set.
pub fn run_cv_experiment(spec: &ExperimentSpec) -> Result<ExperimentResult> {
    let mut auc_stats = FoldStats::new();
    let mut iter_stats = FoldStats::new();
    let mut time_stats = FoldStats::new();
    let mut failed = 0usize;

    let folds = splits::cv_splits(&spec.data, spec.setting, spec.folds, spec.seed);
    for (f, split) in folds.iter().enumerate() {
        if split.train.is_empty() || split.test.is_empty() {
            failed += 1;
            continue;
        }
        let t0 = Instant::now();
        let fold_seed = spec.seed ^ (f as u64).wrapping_mul(0x9E37_79B9);
        let model = match spec.solver {
            Solver::Minres => PairwiseRidge::fit_early_stopping(
                &split.train,
                spec.setting,
                spec.kernel,
                &spec.ridge,
                fold_seed,
            ),
            Solver::Cg => PairwiseRidge::fit_exact(
                &split.train,
                spec.kernel,
                &spec.ridge,
                spec.ridge.max_iters,
                Solver::Cg,
            ),
            Solver::Sgd => fit_sgd(
                &split.train,
                spec.kernel,
                spec.ridge.lambda,
                &sgd_config_for(&spec.ridge),
                fold_seed,
            ),
            // Direct complete-grid lane: errors in-band when a CV fold is
            // not a complete grid (every Table-1 split drops cells, so
            // this arm only succeeds on purpose-built complete folds).
            Solver::Eigen => crate::solvers::complete::EigenRidge::new(
                &split.train,
                spec.kernel,
            )
            .and_then(|er| er.fit_model(spec.ridge.lambda)),
        }
        .with_context(|| format!("fold {f} of {}", spec.name))?;
        let secs = t0.elapsed().as_secs_f64();
        let preds = model.predict(&split.test.pairs)?;
        match auc(&preds, &split.test.binary_labels()) {
            Some(a) => {
                auc_stats.push(a);
                iter_stats.push(model.iterations as f64);
                time_stats.push(secs);
            }
            None => failed += 1,
        }
    }

    Ok(ExperimentResult {
        name: spec.name.clone(),
        kernel: spec.kernel,
        setting: spec.setting,
        auc: auc_stats,
        iterations: iter_stats,
        train_secs: time_stats,
        failed_folds: failed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::metz::MetzConfig;

    #[test]
    fn metz_cell_runs_and_beats_chance() {
        let data = MetzConfig::small().generate(42);
        let spec = ExperimentSpec {
            name: "metz-small".into(),
            data,
            kernel: PairwiseKernel::Kronecker,
            setting: 1,
            folds: 3,
            ridge: RidgeConfig { max_iters: 60, patience: 5, ..Default::default() },
            solver: Solver::Minres,
            seed: 7,
        };
        let res = run_cv_experiment(&spec).unwrap();
        assert_eq!(res.auc.count() + res.failed_folds, 3);
        assert!(res.auc.mean() > 0.6, "AUC {}", res.auc.mean());
        assert!(res.iterations.mean() >= 1.0);
    }

    #[test]
    fn sgd_and_cg_cells_run() {
        let data = MetzConfig::small().generate(44);
        for solver in [Solver::Sgd, Solver::Cg] {
            let spec = ExperimentSpec {
                name: format!("metz-{}", solver.name()),
                data: data.clone(),
                kernel: PairwiseKernel::Kronecker,
                setting: 1,
                folds: 2,
                ridge: RidgeConfig {
                    lambda: 1e-2,
                    max_iters: 40,
                    patience: 4,
                    ..Default::default()
                },
                solver,
                seed: 9,
            };
            let res = run_cv_experiment(&spec).unwrap();
            assert!(
                res.auc.count() >= 1,
                "{}: no fold completed",
                solver.name()
            );
            assert!(res.auc.mean() > 0.55, "{}: AUC {}", solver.name(), res.auc.mean());
        }
    }

    #[test]
    fn setting4_cell_runs() {
        let data = MetzConfig::small().generate(43);
        let spec = ExperimentSpec {
            name: "metz-s4".into(),
            data,
            kernel: PairwiseKernel::Linear,
            setting: 4,
            folds: 3,
            ridge: RidgeConfig { max_iters: 40, patience: 4, ..Default::default() },
            solver: Solver::Minres,
            seed: 11,
        };
        let res = run_cv_experiment(&spec).unwrap();
        assert!(res.auc.count() >= 1);
    }
}
