//! Regeneration of the paper's figures from the CLI
//! (`gvt-rls experiment <figN>`).
//!
//! Sizes: default is a medium scale that finishes in minutes; `--quick`
//! shrinks to smoke-test size; `--full` uses the paper's dimensions.
//! Benches (`cargo bench`) cover Figures 7 and 9, which are
//! time/memory-scaling figures.
//!
//! The grid figures (4–6) also accept `--solver minres|cg|sgd|all`:
//! `all` duplicates every cell across the training algorithms, so
//! CG-vs-SGD AUC/time columns land in the same report as the paper's
//! MINRES rows (rows tagged `·cg` / `·sgd`).

use crate::cli::Cli;
use crate::coordinator::report::{auc_table, results_csv, Series};
use crate::error::{bail, Result};
use crate::coordinator::runner::run_grid_with_progress;
use crate::coordinator::ExperimentSpec;
use crate::data::heterodimer::{HeterodimerConfig, ProteinFeature};
use crate::data::kernel_filling::KernelFillingConfig;
use crate::data::merget::MergetConfig;
use crate::data::metz::MetzConfig;
use crate::data::PairDataset;
use crate::gvt::pairwise::PairwiseKernel;
use crate::kernels::BaseKernel;
use crate::solvers::nystrom::{NystromConfig, NystromModel};
use crate::solvers::ridge::{PairwiseRidge, RidgeConfig};
use crate::solvers::Solver;

/// Scale selector shared by all figures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Medium,
    Full,
}

impl Scale {
    pub fn from_cli(cli: &Cli) -> Scale {
        if cli.has_switch("quick") {
            Scale::Quick
        } else if cli.has_switch("full") {
            Scale::Full
        } else {
            Scale::Medium
        }
    }
}

/// Entry point for `gvt-rls experiment <name>`.
pub fn run(which: &str, cli: &Cli) -> Result<()> {
    match which {
        "fig3" => fig3(cli),
        "fig4" => fig4(cli),
        "fig5" => fig5(cli),
        "fig6" => fig6(cli),
        "fig8" => fig8(cli),
        other => bail!("unknown experiment '{other}' (fig3|fig4|fig5|fig6|fig8)"),
    }
}

fn common_ridge(cli: &Cli, scale: Scale) -> Result<RidgeConfig> {
    Ok(RidgeConfig {
        lambda: cli.opt_f64("lambda", 1e-5)?,
        max_iters: match scale {
            Scale::Quick => 40,
            Scale::Medium => 150,
            Scale::Full => 400,
        },
        patience: cli.opt_usize("patience", 10)?,
        ..Default::default()
    })
}

fn folds(cli: &Cli, scale: Scale) -> Result<usize> {
    cli.opt_usize("folds", if scale == Scale::Quick { 3 } else { 9 })
}

/// Parse `--solver` for a figure grid: one training algorithm, or `all`
/// to run every cell once per solver so CG-vs-SGD columns land in the
/// report next to the exact-MINRES rows (`gvt-rls experiment fig5
/// --solver all`). Non-MINRES rows are tagged `·<solver>` in the dataset
/// name, keeping the report emitters unchanged.
fn grid_solvers(cli: &Cli) -> Result<Vec<Solver>> {
    let tok =
        cli.opt_choice("solver", "minres", &["minres", "cg", "sgd", "eigen", "all"])?;
    Ok(if tok == "all" {
        // Iterative solvers only: the eigen shortcut needs every CV fold
        // to be a complete grid, which Table-1 splits never produce —
        // requesting it explicitly still works and errors in-band.
        vec![Solver::Minres, Solver::Cg, Solver::Sgd]
    } else {
        vec![Solver::parse(&tok).expect("opt_choice validated the solver token")]
    })
}

/// Dataset-name tag for a grid row's solver (MINRES is the untagged
/// baseline, matching the paper's tables).
fn tag_name(name: &str, solver: Solver) -> String {
    match solver {
        Solver::Minres => name.to_string(),
        s => format!("{name}·{}", s.name()),
    }
}

fn grid(specs: Vec<ExperimentSpec>, cli: &Cli) -> Result<Vec<crate::coordinator::ExperimentResult>> {
    let workers = cli.opt_usize("workers", 2)?;
    // Progress goes through the leveled obs log: quiet by default,
    // GVT_RLS_LOG=info restores the per-cell lines, failures always
    // surface at warn.
    let results = run_grid_with_progress(specs, workers, |done, total, r| {
        match r {
            Ok(res) => crate::obs::log::info(format_args!(
                "[{done}/{total}] {} {} setting {}: AUC {}",
                res.name,
                res.kernel.name(),
                res.setting,
                res.auc.format()
            )),
            Err(e) => crate::obs::log::warn(format_args!("[{done}/{total}] FAILED: {e:#}")),
        }
    });
    results.into_iter().collect()
}

fn emit(results: &[crate::coordinator::ExperimentResult], cli: &Cli, label: &str) -> Result<()> {
    let refs: Vec<&crate::coordinator::ExperimentResult> = results.iter().collect();
    println!("\n## {label}\n");
    println!("{}", auc_table(&refs));
    if let Some(path) = cli.opt("csv") {
        std::fs::write(path, results_csv(&refs))?;
        println!("(csv written to {path})");
    }
    Ok(())
}

/// Figure 3: validation AUC per MINRES iteration under (a) small λ with
/// early stopping and (b) a λ sweep run to convergence.
fn fig3(cli: &Cli) -> Result<()> {
    let scale = Scale::from_cli(cli);
    let seed = cli.opt_u64("seed", 42)?;
    let data = match scale {
        Scale::Quick => MetzConfig::small(),
        Scale::Medium => MetzConfig { drugs: 80, targets: 200, ..MetzConfig::small() },
        Scale::Full => MetzConfig::paper(),
    }
    .generate(seed);
    let split = data.split_setting(1, 0.25, seed);
    let inner = split.train.split_setting(1, 0.25, seed ^ 1);

    println!("## Figure 3 — AUC per iteration and the effect of early stopping\n");
    let mut series = Vec::new();
    for lambda in [1e-5, 1e-2, 1.0, 100.0] {
        let cfg = RidgeConfig {
            lambda,
            max_iters: if scale == Scale::Quick { 40 } else { 200 },
            patience: usize::MAX, // run the full curve for the figure
            ..Default::default()
        };
        let (best_iter, history) = PairwiseRidge::find_optimal_iters(
            &inner.train,
            &inner.test,
            PairwiseKernel::Kronecker,
            &cfg,
        )?;
        println!(
            "λ = {lambda:>8.0e}: best validation AUC {:.4} at iteration {best_iter}",
            history
                .iter()
                .map(|p| p.validation_auc)
                .fold(f64::NEG_INFINITY, f64::max)
        );
        series.push(Series {
            label: format!("λ={lambda:.0e}"),
            points: history
                .iter()
                .map(|p| (p.iteration as f64, p.validation_auc))
                .collect(),
        });
    }
    println!("\n{}", crate::coordinator::report::series_table("iteration", &series));
    println!(
        "Interpretation: with small λ the AUC peaks early then declines \
         (early stopping regularizes); with a well-chosen λ the curve \
         converges to the same optimum — the paper's Figure 3 observation."
    );
    Ok(())
}

/// Figure 4: heterodimer — 3 feature families × 6 kernels × 4 settings.
fn fig4(cli: &Cli) -> Result<()> {
    let scale = Scale::from_cli(cli);
    let seed = cli.opt_u64("seed", 42)?;
    let ridge = common_ridge(cli, scale)?;
    let folds = folds(cli, scale)?;
    let cfg = match scale {
        Scale::Quick => HeterodimerConfig::small(),
        Scale::Medium => HeterodimerConfig {
            proteins: 300,
            pairs: 1200,
            positive_rate: 0.06,
            clusters: 40,
            feature_scale: 0.25,
        },
        Scale::Full => HeterodimerConfig::paper(),
    };
    let kernels = [
        PairwiseKernel::Linear,
        PairwiseKernel::Poly2D,
        PairwiseKernel::Kronecker,
        PairwiseKernel::Cartesian,
        PairwiseKernel::Symmetric,
        PairwiseKernel::Mlpk,
    ];
    let solvers = grid_solvers(cli)?;
    let mut specs = Vec::new();
    for feature in ProteinFeature::ALL {
        let data = cfg.generate(feature, seed);
        for kernel in kernels {
            for setting in 1..=4u8 {
                for &solver in &solvers {
                    specs.push(ExperimentSpec {
                        name: tag_name(&data.name, solver),
                        data: data.clone(),
                        kernel,
                        setting,
                        folds,
                        ridge: ridge.clone(),
                        solver,
                        seed,
                    });
                }
            }
        }
    }
    let results = grid(specs, cli)?;
    emit(&results, cli, "Figure 4 — Heterodimers: AUC by feature, kernel, setting")
}

/// Figure 5: Metz — 2 base kernels × 4 pairwise kernels × 4 settings.
fn fig5(cli: &Cli) -> Result<()> {
    let scale = Scale::from_cli(cli);
    let seed = cli.opt_u64("seed", 42)?;
    let ridge = common_ridge(cli, scale)?;
    let folds = folds(cli, scale)?;
    let base_cfg = match scale {
        Scale::Quick => MetzConfig::small(),
        Scale::Medium => MetzConfig {
            drugs: 80,
            targets: 250,
            density: 0.42,
            ..MetzConfig::small()
        },
        Scale::Full => MetzConfig::paper(),
    };
    let solvers = grid_solvers(cli)?;
    let mut specs = Vec::new();
    for base in [BaseKernel::Linear, BaseKernel::Gaussian] {
        let mut data = base_cfg.clone().with_kernel(base).generate(seed);
        data.name = format!("metz[{}]", base.name());
        for kernel in [
            PairwiseKernel::Linear,
            PairwiseKernel::Poly2D,
            PairwiseKernel::Kronecker,
            PairwiseKernel::Cartesian,
        ] {
            for setting in 1..=4u8 {
                for &solver in &solvers {
                    specs.push(ExperimentSpec {
                        name: tag_name(&data.name, solver),
                        data: data.clone(),
                        kernel,
                        setting,
                        folds,
                        ridge: ridge.clone(),
                        solver,
                        seed,
                    });
                }
            }
        }
    }
    let results = grid(specs, cli)?;
    emit(&results, cli, "Figure 5 — Metz: AUC by base kernel, pairwise kernel, setting")
}

/// Figure 6: Merget — (drug, target) kernel pairs × 4 pairwise × settings.
fn fig6(cli: &Cli) -> Result<()> {
    let scale = Scale::from_cli(cli);
    let seed = cli.opt_u64("seed", 42)?;
    let ridge = common_ridge(cli, scale)?;
    let folds = folds(cli, scale)?;
    let base_cfg = match scale {
        Scale::Quick => MergetConfig::small(),
        Scale::Medium => MergetConfig {
            drugs: 250,
            targets: 60,
            ..MergetConfig::small()
        },
        Scale::Full => MergetConfig::paper(),
    };
    // The paper reports the first two (drug, target) kernel pairs.
    let pairs = [(0usize, 0usize), (1, 0)];
    let solvers = grid_solvers(cli)?;
    let mut specs = Vec::new();
    for (dk, tk) in pairs {
        let data: PairDataset = base_cfg.generate(dk, tk, seed);
        for kernel in [
            PairwiseKernel::Linear,
            PairwiseKernel::Poly2D,
            PairwiseKernel::Kronecker,
            PairwiseKernel::Cartesian,
        ] {
            for setting in 1..=4u8 {
                for &solver in &solvers {
                    specs.push(ExperimentSpec {
                        name: tag_name(&data.name, solver),
                        data: data.clone(),
                        kernel,
                        setting,
                        folds,
                        ridge: ridge.clone(),
                        solver,
                        seed,
                    });
                }
            }
        }
    }
    let results = grid(specs, cli)?;
    emit(&results, cli, "Figure 6 — Merget: AUC by kernel pair, pairwise kernel, setting")
}

/// Figure 8: Falkon/Nyström hyperparameter tuning — iterations to optimal
/// validation AUC, #basis vectors, regularization.
fn fig8(cli: &Cli) -> Result<()> {
    let scale = Scale::from_cli(cli);
    let seed = cli.opt_u64("seed", 42)?;
    let (k, n, centers): (usize, usize, Vec<usize>) = match scale {
        Scale::Quick => (48, 1500, vec![16, 32, 64]),
        Scale::Medium => (128, 10_000, vec![32, 128, 512]),
        Scale::Full => (360, 64_000, vec![32, 128, 512, 2048]),
    };
    let data = KernelFillingConfig::small().generate(k, n, seed);
    let split = data.split_setting(1, 0.25, seed);
    let inner = split.train.split_setting(1, 0.25, seed ^ 1);
    println!("## Figure 8 — Nyström (Falkon-style) tuning on kernel filling ({n} pairs)\n");

    println!("### AUC vs number of basis vectors (λ = 1e-5)\n");
    for &nc in &centers {
        let cfg = NystromConfig { num_centers: nc, seed, ..Default::default() };
        let model =
            NystromModel::fit_with_validation(&inner.train, &inner.test, PairwiseKernel::Kronecker, &cfg)?;
        let preds = model.predict(&split.test.pairs);
        let a = crate::eval::auc(&preds, &split.test.binary_labels()).unwrap_or(f64::NAN);
        println!(
            "N = {nc:>5}: test AUC {a:.4} | CG iterations {:>3} | K_nm memory {}",
            model.iterations,
            crate::coordinator::memory::format_bytes(model.knm_bytes)
        );
    }

    println!("\n### AUC vs regularization (N = {})\n", centers[centers.len() / 2]);
    for lambda in [1e-7, 1e-5, 1e-3, 1e-1] {
        let cfg = NystromConfig {
            num_centers: centers[centers.len() / 2],
            lambda,
            seed,
            ..Default::default()
        };
        let model = NystromModel::fit(&inner.train, PairwiseKernel::Kronecker, &cfg)?;
        let preds = model.predict(&split.test.pairs);
        let a = crate::eval::auc(&preds, &split.test.binary_labels()).unwrap_or(f64::NAN);
        println!("λ = {lambda:>8.0e}: test AUC {a:.4} ({} iterations)", model.iterations);
    }
    Ok(())
}
