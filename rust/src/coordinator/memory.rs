//! Memory accounting for the Figure 7/9 memory-usage series.
//!
//! Two complementary sources:
//!
//! * [`TrackingAlloc`] — a global-allocator wrapper counting live bytes
//!   and the high-water mark. Installed by the binaries/benches with
//!   `#[global_allocator]`; the library only defines it, so `cargo test`
//!   keeps the system allocator.
//! * [`vm_hwm_bytes`] — the kernel's own peak-RSS reading
//!   (`/proc/self/status: VmHWM`), the number the paper's 16 GiB cutoff
//!   refers to.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

/// Counting wrapper around the system allocator.
pub struct TrackingAlloc;

// SAFETY: every operation defers to `System` with the caller's
// pointer/layout unchanged, so `GlobalAlloc`'s contract is inherited
// verbatim; the bookkeeping is plain atomics and cannot itself allocate
// (which would recurse into this allocator).
unsafe impl GlobalAlloc for TrackingAlloc {
    // SAFETY: forwards to `System.alloc` with the caller's layout; the
    // counter update only runs on a non-null result.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    // SAFETY: forwards to `System.dealloc` with the caller's pointer and
    // layout untouched.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    // SAFETY: forwards to `System.realloc`; pointer, layout, and
    // new_size pass through untouched.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                let live =
                    LIVE.fetch_add(new_size - layout.size(), Ordering::Relaxed)
                        + (new_size - layout.size());
                PEAK.fetch_max(live, Ordering::Relaxed);
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

/// Currently live heap bytes (0 if the tracking allocator isn't installed).
pub fn live_bytes() -> usize {
    LIVE.load(Ordering::Relaxed)
}

/// Peak live heap bytes since start or last [`reset_peak`].
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// Reset the high-water mark to the current live count (per-experiment
/// accounting).
pub fn reset_peak() {
    PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// Kernel-reported peak resident set size in bytes (`VmHWM`), if readable.
pub fn vm_hwm_bytes() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: usize = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Human-readable byte count (`"1.50 GiB"`).
pub fn format_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_bytes_units() {
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(2048), "2.00 KiB");
        assert_eq!(format_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(format_bytes(16 * 1024 * 1024 * 1024), "16.00 GiB");
    }

    #[test]
    fn vm_hwm_readable_on_linux() {
        // Should parse on any Linux; tolerate absence elsewhere.
        // Some sandboxes restrict /proc; only assert when readable.
        if let Some(h) = vm_hwm_bytes() {
            assert!(h > 0);
        }
    }
}
