//! L3 experiment coordination.
//!
//! The paper's evaluation is a grid of (dataset, feature view, pairwise
//! kernel, setting) cells, each trained with 9-fold CV + inner early
//! stopping. This module is the leader/worker machinery that runs that
//! grid:
//!
//! * [`experiment`] — one cell: CV folds, the paper's training protocol,
//!   AUC/iterations/time/memory accounting.
//! * [`runner`] — a leader thread + worker pool draining a job queue
//!   (no rayon offline; this is a from-scratch work-stealing-free pool).
//! * [`memory`] — tracking allocator + VmHWM reader for the Figure 7
//!   memory series.
//! * [`report`] — markdown/CSV emitters shaped like the paper's figures.
//! * [`config`] — a small `key = value` config format for the CLI.

pub mod config;
pub mod experiment;
pub mod figures;
pub mod memory;
pub mod report;
pub mod runner;
pub mod tuning;

pub use experiment::{run_cv_experiment, ExperimentResult, ExperimentSpec};
pub use runner::run_grid;
