//! Report emitters: markdown tables shaped like the paper's figures and a
//! CSV sink for downstream plotting.

use crate::coordinator::experiment::ExperimentResult;
use crate::gvt::pairwise::PairwiseKernel;

/// Render a grid of results as the paper's figure layout: one row per
/// (dataset/feature, kernel), one column per setting, cells `AUC ± std`.
pub fn auc_table(results: &[&ExperimentResult]) -> String {
    // Collect distinct (name, kernel) rows and settings columns, in order.
    let mut rows: Vec<(String, PairwiseKernel)> = Vec::new();
    let mut settings: Vec<u8> = Vec::new();
    for r in results {
        let key = (r.name.clone(), r.kernel);
        if !rows.contains(&key) {
            rows.push(key);
        }
        if !settings.contains(&r.setting) {
            settings.push(r.setting);
        }
    }
    settings.sort_unstable();

    let mut out = String::new();
    out.push_str(&format!("| {:<28} | {:<13} |", "dataset", "kernel"));
    for s in &settings {
        out.push_str(&format!(" Setting {s}      |"));
    }
    out.push('\n');
    out.push_str(&format!("|{}|{}|", "-".repeat(30), "-".repeat(15)));
    for _ in &settings {
        out.push_str(&format!("{}|", "-".repeat(16)));
    }
    out.push('\n');
    for (name, kernel) in &rows {
        out.push_str(&format!("| {:<28} | {:<13} |", name, kernel.name()));
        for s in &settings {
            let cell = results
                .iter()
                .find(|r| &r.name == name && r.kernel == *kernel && r.setting == *s)
                .map(|r| r.auc.format())
                .unwrap_or_else(|| "—".into());
            out.push_str(&format!(" {cell:<14} |"));
        }
        out.push('\n');
    }
    out
}

/// CSV with one row per (cell, metric) for plotting.
pub fn results_csv(results: &[&ExperimentResult]) -> String {
    let mut out = String::from(
        "dataset,kernel,setting,auc_mean,auc_std,iters_mean,train_secs_mean,folds,failed\n",
    );
    for r in results {
        out.push_str(&format!(
            "{},{},{},{:.4},{:.4},{:.1},{:.4},{},{}\n",
            r.name,
            r.kernel.name(),
            r.setting,
            r.auc.mean(),
            r.auc.std(),
            r.iterations.mean(),
            r.train_secs.mean(),
            r.auc.count(),
            r.failed_folds
        ));
    }
    out
}

/// A labeled numeric series (the scalability figures print these).
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

/// Render aligned series as a markdown table: first column x, one column
/// per series (the Figure 7/9 panels: CPU time / memory / AUC vs N).
pub fn series_table(x_label: &str, series: &[Series]) -> String {
    let mut xs: Vec<f64> = Vec::new();
    for s in series {
        for &(x, _) in &s.points {
            if !xs.iter().any(|&v| (v - x).abs() < 1e-9) {
                xs.push(x);
            }
        }
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let mut out = format!("| {x_label:>12} |");
    for s in series {
        out.push_str(&format!(" {:>14} |", s.label));
    }
    out.push('\n');
    out.push_str(&format!("|{}|", "-".repeat(14)));
    for _ in series {
        out.push_str(&format!("{}|", "-".repeat(16)));
    }
    out.push('\n');
    for &x in &xs {
        out.push_str(&format!("| {x:>12.0} |"));
        for s in series {
            let v = s.points.iter().find(|(px, _)| (px - x).abs() < 1e-9);
            match v {
                Some((_, y)) => out.push_str(&format!(" {y:>14.4} |")),
                None => out.push_str(&format!(" {:>14} |", "—")),
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::FoldStats;

    fn fake(name: &str, kernel: PairwiseKernel, setting: u8, auc: f64) -> ExperimentResult {
        let mut s = FoldStats::new();
        s.push(auc);
        s.push(auc + 0.01);
        ExperimentResult {
            name: name.into(),
            kernel,
            setting,
            auc: s,
            iterations: FoldStats::new(),
            train_secs: FoldStats::new(),
            failed_folds: 0,
        }
    }

    #[test]
    fn auc_table_has_row_per_kernel_and_col_per_setting() {
        let r1 = fake("d", PairwiseKernel::Linear, 1, 0.8);
        let r2 = fake("d", PairwiseKernel::Linear, 2, 0.7);
        let r3 = fake("d", PairwiseKernel::Kronecker, 1, 0.9);
        let t = auc_table(&[&r1, &r2, &r3]);
        assert!(t.contains("Setting 1"));
        assert!(t.contains("Setting 2"));
        assert!(t.contains("linear"));
        assert!(t.contains("kronecker"));
        // Kronecker has no setting-2 cell -> em dash.
        assert!(t.contains("—"));
    }

    #[test]
    fn csv_emits_one_line_per_result() {
        let r1 = fake("d", PairwiseKernel::Linear, 1, 0.8);
        let csv = results_csv(&[&r1]);
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.lines().nth(1).unwrap().starts_with("d,linear,1,"));
    }

    #[test]
    fn series_table_aligns_on_x() {
        let s1 = Series { label: "gvt".into(), points: vec![(1000.0, 0.5), (2000.0, 1.0)] };
        let s2 = Series { label: "naive".into(), points: vec![(1000.0, 5.0)] };
        let t = series_table("N", &[s1, s2]);
        assert!(t.contains("1000"));
        assert!(t.contains("2000"));
        assert!(t.contains("—"));
    }
}
