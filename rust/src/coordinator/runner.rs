//! Leader/worker job runner.
//!
//! The experiment grids (Figures 4–6 sweep dozens of cells) parallelize at
//! the cell level: a leader thread owns the job queue, workers pull cells
//! and run the fold loop. Inside a cell, the GVT mat-vecs run on the
//! **shared** runtime pool (see [`crate::linalg::par`] /
//! [`crate::runtime::pool`]) — concurrent cells submit jobs to one
//! worker set instead of each spawning scoped threads, so the runner
//! caps cell-level workers only to bound memory, not to avoid
//! oversubscription.

use crate::coordinator::experiment::{run_cv_experiment, ExperimentResult, ExperimentSpec};
use crate::error::Result;
use std::collections::VecDeque;
use std::sync::Mutex;

/// Run a grid of experiment cells across `workers` threads, preserving
/// input order in the output. Failures are returned in-place (a failed
/// cell doesn't abort the grid — the paper's harness runs overnight; ours
/// should be as robust).
pub fn run_grid(
    specs: Vec<ExperimentSpec>,
    workers: usize,
) -> Vec<Result<ExperimentResult>> {
    let n = specs.len();
    let queue: Mutex<VecDeque<(usize, ExperimentSpec)>> =
        Mutex::new(specs.into_iter().enumerate().collect());
    let results: Mutex<Vec<Option<Result<ExperimentResult>>>> =
        Mutex::new((0..n).map(|_| None).collect());
    let workers = workers.max(1).min(n.max(1));

    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let job = queue.lock().unwrap().pop_front();
                let Some((idx, spec)) = job else { break };
                let res = run_cv_experiment(&spec);
                results.lock().unwrap()[idx] = Some(res);
            });
        }
    });

    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("runner: job not completed"))
        .collect()
}

/// Progress-reporting variant: calls `on_done(completed, total, &result)`
/// from worker threads as cells finish (the CLI prints a live grid).
pub fn run_grid_with_progress<F>(
    specs: Vec<ExperimentSpec>,
    workers: usize,
    on_done: F,
) -> Vec<Result<ExperimentResult>>
where
    F: Fn(usize, usize, &Result<ExperimentResult>) + Sync,
{
    let n = specs.len();
    let queue: Mutex<VecDeque<(usize, ExperimentSpec)>> =
        Mutex::new(specs.into_iter().enumerate().collect());
    let results: Mutex<Vec<Option<Result<ExperimentResult>>>> =
        Mutex::new((0..n).map(|_| None).collect());
    let done = std::sync::atomic::AtomicUsize::new(0);
    let workers = workers.max(1).min(n.max(1));

    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let job = queue.lock().unwrap().pop_front();
                let Some((idx, spec)) = job else { break };
                let res = run_cv_experiment(&spec);
                let c = done.fetch_add(1, std::sync::atomic::Ordering::SeqCst) + 1;
                on_done(c, n, &res);
                results.lock().unwrap()[idx] = Some(res);
            });
        }
    });

    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("runner: job not completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::metz::MetzConfig;
    use crate::gvt::pairwise::PairwiseKernel;
    use crate::solvers::ridge::RidgeConfig;

    fn spec(kernel: PairwiseKernel, setting: u8, seed: u64) -> ExperimentSpec {
        ExperimentSpec {
            name: format!("{}-s{setting}", kernel.name()),
            data: MetzConfig::small().generate(seed),
            kernel,
            setting,
            folds: 2,
            ridge: RidgeConfig { max_iters: 20, patience: 3, ..Default::default() },
            solver: crate::solvers::Solver::Minres,
            seed,
        }
    }

    #[test]
    fn grid_preserves_order_and_completes() {
        let specs = vec![
            spec(PairwiseKernel::Linear, 1, 1),
            spec(PairwiseKernel::Kronecker, 1, 2),
            spec(PairwiseKernel::Poly2D, 2, 3),
        ];
        let names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
        let results = run_grid(specs, 2);
        assert_eq!(results.len(), 3);
        for (r, n) in results.iter().zip(&names) {
            assert_eq!(&r.as_ref().unwrap().name, n);
        }
    }

    #[test]
    fn progress_callback_fires_for_each_cell() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        let specs = vec![spec(PairwiseKernel::Linear, 1, 4), spec(PairwiseKernel::Linear, 2, 5)];
        let _ = run_grid_with_progress(specs, 2, |_, total, _| {
            assert_eq!(total, 2);
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 2);
    }
}
