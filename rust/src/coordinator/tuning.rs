//! Hyperparameter selection — the λ / kernel searches behind the paper's
//! protocol ("finding an optimal λ and stopping iterations when the model
//! has converged", and Figure 3's comparison of the two regularization
//! modes).

use crate::data::{splits, PairDataset};
use crate::error::Result;
use crate::eval::auc;
use crate::gvt::pairwise::PairwiseKernel;
use crate::solvers::complete::EigenRidge;
use crate::solvers::ridge::{PairwiseRidge, RidgeConfig, RidgeModel};
use crate::solvers::sgd::{SgdConfig, SgdTrainer};
use crate::solvers::Solver;

/// One evaluated candidate.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub lambda: f64,
    pub kernel: PairwiseKernel,
    pub validation_auc: f64,
    pub iterations: usize,
    /// Exact leave-one-out MSE — only the eigen sweep
    /// ([`select_lambda_eigen`]) computes it; the split-based sweeps
    /// leave it `None`.
    pub loo_mse: Option<f64>,
}

/// Select λ on an inner validation split (setting-aware), training each
/// candidate to convergence (the Figure 3 "tuned λ" mode). Returns the
/// best candidate and the full sweep for reporting.
///
/// The whole sweep shares one training operator
/// ([`PairwiseRidge::fit_lambda_grid`]: the fused GVT plan and workspace
/// are built once) and the validation predictions for **all** λ come from
/// a single multi-RHS block product ([`RidgeModel::predict_batch`])
/// instead of one operator build + mat-vec per candidate.
pub fn select_lambda(
    train: &PairDataset,
    setting: u8,
    kernel: PairwiseKernel,
    lambdas: &[f64],
    cfg: &RidgeConfig,
    seed: u64,
) -> Result<(Candidate, Vec<Candidate>)> {
    let inner_split = splits::split_setting(train, setting, cfg.validation_fraction, seed);
    let (inner, validation) = (&inner_split.train, &inner_split.test);
    let models = PairwiseRidge::fit_lambda_grid(inner, kernel, cfg, lambdas)?;
    sweep_lambda_grid(&models, lambdas, kernel, validation)
}

/// Shared back half of the λ searches: score a fitted grid on the
/// validation split with **one** multi-RHS block product
/// ([`RidgeModel::predict_batch`]) and pick the best candidate.
fn sweep_lambda_grid(
    models: &[RidgeModel],
    lambdas: &[f64],
    kernel: PairwiseKernel,
    validation: &PairDataset,
) -> Result<(Candidate, Vec<Candidate>)> {
    let val_labels = validation.binary_labels();
    let mut sweep = Vec::new();
    if !models.is_empty() {
        let preds = RidgeModel::predict_batch(models, &validation.pairs)?;
        for (li, (model, &lambda)) in models.iter().zip(lambdas).enumerate() {
            let col = preds.column(li);
            sweep.push(Candidate {
                lambda,
                kernel,
                validation_auc: auc(&col, &val_labels).unwrap_or(0.5),
                iterations: model.iterations,
                loo_mse: None,
            });
        }
    }
    let best = sweep
        .iter()
        .cloned()
        .max_by(|a, b| a.validation_auc.partial_cmp(&b.validation_auc).unwrap())
        .expect("empty lambda grid");
    Ok((best, sweep))
}

/// λ selection under the stochastic solver: like [`select_lambda`] but
/// each candidate is trained with mini-batched SGD. The whole sweep
/// shares **one** [`SgdTrainer`] — the compiled training operator, its
/// pinned factorization, the warm workspace, and the power-iteration
/// step-size bound are built once (λ only shifts the diagonal, which the
/// trainer applies per fit) — and, as in the exact path, validation
/// predictions for all λ come from a single multi-RHS block product.
/// Every candidate fit shares `seed`, so the sweep isolates λ (identical
/// epoch shuffles across the grid).
pub fn select_lambda_sgd(
    train: &PairDataset,
    setting: u8,
    kernel: PairwiseKernel,
    lambdas: &[f64],
    cfg: &SgdConfig,
    validation_fraction: f64,
    seed: u64,
) -> Result<(Candidate, Vec<Candidate>)> {
    let inner_split = splits::split_setting(train, setting, validation_fraction, seed);
    let (inner, validation) = (&inner_split.train, &inner_split.test);
    let trainer = SgdTrainer::new(inner, kernel, cfg.clone())?;
    let models = lambdas
        .iter()
        .map(|&lambda| trainer.fit_model(lambda, seed))
        .collect::<Result<Vec<_>>>()?;
    sweep_lambda_grid(&models, lambdas, kernel, validation)
}

/// λ selection on a **complete grid** via the eigen shortcut: one
/// `O(m³ + q³)` eigendecomposition, then **exact** leave-one-out CV for
/// every λ in closed form ([`crate::solvers::complete::EigenRidge`]) —
/// no inner validation split, no solver iterations, no retrains. The
/// best candidate minimizes LOO MSE (the exact criterion the leverages
/// formula computes); each candidate also reports the AUC of its LOO
/// predictions against the binarized labels so eigen sweeps remain
/// comparable with the split-based sweeps, and `iterations` is 0 — the
/// direct lane has no Krylov loop. Errors in-band when the dataset is
/// not a complete grid or the kernel is not Kronecker.
pub fn select_lambda_eigen(
    train: &PairDataset,
    kernel: PairwiseKernel,
    lambdas: &[f64],
) -> Result<(Candidate, Vec<Candidate>)> {
    let er = EigenRidge::new(train, kernel)?;
    let cells = er.loocv(lambdas)?;
    let labels = train.binary_labels();
    let sweep: Vec<Candidate> = cells
        .iter()
        .map(|cell| Candidate {
            lambda: cell.lambda,
            kernel,
            validation_auc: auc(&cell.loo, &labels).unwrap_or(0.5),
            iterations: 0,
            loo_mse: Some(cell.mse),
        })
        .collect();
    let best = sweep
        .iter()
        .cloned()
        .min_by(|a, b| {
            a.loo_mse
                .expect("eigen candidates carry LOO MSE")
                .partial_cmp(&b.loo_mse.expect("eigen candidates carry LOO MSE"))
                .expect("LOO MSE is finite")
        })
        .expect("empty lambda grid");
    Ok((best, sweep))
}

/// Solver-dispatching λ selection for `--solver`-style callers: routes
/// the stochastic solver to [`select_lambda_sgd`] (one shared
/// [`SgdTrainer`] for the grid), both exact Krylov solvers to
/// [`select_lambda`] (one shared operator; the converged MINRES sweep
/// solutions are the same Tikhonov optima CG reaches, so the exact path
/// serves both), and the eigen solver to [`select_lambda_eigen`]
/// (complete grids: exact LOOCV, λ selection effectively free). The figure grids train at fixed λ and dispatch solvers
/// in [`crate::coordinator::experiment::run_cv_experiment`]; this is
/// the matching entry point for λ *searches* (a future `tune`
/// subcommand) so the two sweeps cannot drift.
#[allow(clippy::too_many_arguments)]
pub fn select_lambda_for(
    solver: Solver,
    train: &PairDataset,
    setting: u8,
    kernel: PairwiseKernel,
    lambdas: &[f64],
    cfg: &RidgeConfig,
    sgd: &SgdConfig,
    seed: u64,
) -> Result<(Candidate, Vec<Candidate>)> {
    match solver {
        Solver::Sgd => select_lambda_sgd(
            train,
            setting,
            kernel,
            lambdas,
            sgd,
            cfg.validation_fraction,
            seed,
        ),
        Solver::Minres | Solver::Cg => {
            select_lambda(train, setting, kernel, lambdas, cfg, seed)
        }
        Solver::Eigen => select_lambda_eigen(train, kernel, lambdas),
    }
}

/// Select the pairwise kernel on an inner validation split using the
/// early-stopping protocol per candidate. Skips kernels incompatible with
/// the dataset's domain structure.
pub fn select_kernel(
    train: &PairDataset,
    setting: u8,
    kernels: &[PairwiseKernel],
    cfg: &RidgeConfig,
    seed: u64,
) -> Result<(Candidate, Vec<Candidate>)> {
    let inner_split = splits::split_setting(train, setting, cfg.validation_fraction, seed);
    let (inner, validation) = (&inner_split.train, &inner_split.test);
    let val_labels = validation.binary_labels();
    let mut sweep = Vec::new();
    for &kernel in kernels {
        if !kernel.supports_heterogeneous() && !train.homogeneous {
            continue;
        }
        let model = PairwiseRidge::fit_early_stopping(inner, setting, kernel, cfg, seed)?;
        let preds = model.predict(&validation.pairs)?;
        sweep.push(Candidate {
            lambda: cfg.lambda,
            kernel,
            validation_auc: auc(&preds, &val_labels).unwrap_or(0.5),
            iterations: model.iterations,
            loo_mse: None,
        });
    }
    let best = sweep
        .iter()
        .cloned()
        .max_by(|a, b| a.validation_auc.partial_cmp(&b.validation_auc).unwrap())
        .expect("no applicable kernels");
    Ok((best, sweep))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::chessboard::{ChessboardConfig, Pattern};
    use crate::data::kernel_filling::KernelFillingConfig;
    use crate::data::metz::MetzConfig;

    #[test]
    fn lambda_sweep_reports_all_candidates() {
        let data = MetzConfig::small().generate(80);
        let cfg = RidgeConfig { max_iters: 30, ..Default::default() };
        let (best, sweep) = select_lambda(
            &data,
            1,
            PairwiseKernel::Kronecker,
            &[1e-4, 1e-1, 1e2],
            &cfg,
            3,
        )
        .unwrap();
        assert_eq!(sweep.len(), 3);
        assert!(sweep.iter().all(|c| c.validation_auc <= best.validation_auc + 1e-12));
    }

    #[test]
    fn sgd_lambda_sweep_reports_all_candidates() {
        let data = MetzConfig::small().generate(83);
        let cfg = SgdConfig {
            batch_size: 64,
            epochs: 40,
            tol: 1e-3,
            check_every: 5,
            ..Default::default()
        };
        let lambdas = [1e-3, 1e-1, 1e1];
        let (best, sweep) =
            select_lambda_sgd(&data, 1, PairwiseKernel::Kronecker, &lambdas, &cfg, 0.25, 9)
                .unwrap();
        assert_eq!(sweep.len(), 3);
        assert!(lambdas.contains(&best.lambda));
        for c in &sweep {
            assert!((0.0..=1.0).contains(&c.validation_auc));
            assert!(c.iterations > 0, "sgd candidates record their step count");
        }
        assert!(sweep.iter().all(|c| c.validation_auc <= best.validation_auc + 1e-12));
    }

    /// The solver dispatcher must route to the matching sweep: the SGD
    /// arm reproduces `select_lambda_sgd` and the exact arm reproduces
    /// `select_lambda` (identical candidates — same seeds, same paths).
    #[test]
    fn select_lambda_for_matches_direct_paths() {
        let data = MetzConfig::small().generate(85);
        let cfg = RidgeConfig { max_iters: 25, ..Default::default() };
        let scfg = SgdConfig {
            batch_size: 64,
            epochs: 30,
            tol: 1e-3,
            check_every: 5,
            ..Default::default()
        };
        let lambdas = [1e-3, 1e-1];
        let (_, via_exact) = select_lambda_for(
            Solver::Minres,
            &data,
            1,
            PairwiseKernel::Kronecker,
            &lambdas,
            &cfg,
            &scfg,
            4,
        )
        .unwrap();
        let (_, direct_exact) =
            select_lambda(&data, 1, PairwiseKernel::Kronecker, &lambdas, &cfg, 4).unwrap();
        assert_eq!(via_exact.len(), direct_exact.len());
        for (a, b) in via_exact.iter().zip(&direct_exact) {
            assert_eq!(a.validation_auc, b.validation_auc);
            assert_eq!(a.iterations, b.iterations);
        }
        let (_, via_sgd) = select_lambda_for(
            Solver::Sgd,
            &data,
            1,
            PairwiseKernel::Kronecker,
            &lambdas,
            &cfg,
            &scfg,
            4,
        )
        .unwrap();
        let (_, direct_sgd) = select_lambda_sgd(
            &data,
            1,
            PairwiseKernel::Kronecker,
            &lambdas,
            &scfg,
            cfg.validation_fraction,
            4,
        )
        .unwrap();
        for (a, b) in via_sgd.iter().zip(&direct_sgd) {
            assert_eq!(a.validation_auc, b.validation_auc);
            assert_eq!(a.iterations, b.iterations);
        }
    }

    #[test]
    fn eigen_lambda_selection_uses_exact_loocv() {
        // Complete 10×10 grid: the eigen sweep reports exact LOO MSE per
        // λ, zero iterations, and picks the LOO-MSE minimizer.
        let k = 10;
        let data = KernelFillingConfig::small().generate(k, k * k, 907);
        let lambdas = [1e-2, 1e-1, 1.0, 10.0];
        let (best, sweep) =
            select_lambda_eigen(&data, PairwiseKernel::Kronecker, &lambdas).unwrap();
        assert_eq!(sweep.len(), lambdas.len());
        assert!(sweep.iter().all(|c| c.iterations == 0));
        assert!(sweep.iter().all(|c| c.loo_mse.is_some()));
        let best_mse = best.loo_mse.unwrap();
        assert!(sweep.iter().all(|c| best_mse <= c.loo_mse.unwrap() + 1e-15));

        // The dispatcher routes Solver::Eigen to the same sweep.
        let cfg = RidgeConfig::default();
        let scfg = SgdConfig::default();
        let (b2, s2) = select_lambda_for(
            Solver::Eigen,
            &data,
            1,
            PairwiseKernel::Kronecker,
            &lambdas,
            &cfg,
            &scfg,
            4,
        )
        .unwrap();
        assert_eq!(b2.lambda, best.lambda);
        assert_eq!(s2.len(), sweep.len());

        // Preconditions fail in-band: non-Kronecker kernel, incomplete grid.
        assert!(select_lambda_eigen(&data, PairwiseKernel::Linear, &lambdas).is_err());
        let incomplete = KernelFillingConfig::small().generate(10, 50, 907);
        assert!(
            select_lambda_eigen(&incomplete, PairwiseKernel::Kronecker, &lambdas).is_err()
        );
    }

    #[test]
    fn kernel_selection_picks_interaction_kernel_on_xor() {
        // On the chessboard, kernel selection must reject Linear.
        let data = ChessboardConfig::new(Pattern::Chessboard).generate(81);
        let cfg = RidgeConfig { max_iters: 50, patience: 6, ..Default::default() };
        let (best, sweep) = select_kernel(
            &data,
            1,
            &[PairwiseKernel::Linear, PairwiseKernel::Kronecker],
            &cfg,
            5,
        )
        .unwrap();
        assert_eq!(sweep.len(), 2);
        assert_eq!(best.kernel, PairwiseKernel::Kronecker);
    }

    #[test]
    fn kernel_selection_skips_homogeneous_kernels_on_heterogeneous_data() {
        let data = MetzConfig::small().generate(82);
        let cfg = RidgeConfig { max_iters: 15, ..Default::default() };
        let (_, sweep) = select_kernel(
            &data,
            1,
            &[PairwiseKernel::Linear, PairwiseKernel::Mlpk],
            &cfg,
            5,
        )
        .unwrap();
        assert_eq!(sweep.len(), 1); // MLPK skipped
    }
}
