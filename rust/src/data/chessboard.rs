//! The Figure 1 toy problems: 'chessboard' (XOR of drug/target parities —
//! unlearnable by the linear pairwise kernel, the paper's motivating
//! example for the non-linearity assumption) and 'tablecloth' (SUM of
//! parities — perfectly linear).

use crate::data::PairDataset;
use crate::kernels::{kernel_matrix, BaseKernel, KernelParams};
use crate::linalg::Mat;
use crate::rng::{dist, Xoshiro256};
use crate::sparse::PairIndex;
use std::sync::Arc;

/// Which Figure 1 pattern to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    /// `y = parity(d) XOR parity(t)` — pure pairwise interaction.
    Chessboard,
    /// `y = 1 if parity(d) + parity(t) > 0` on interaction strengths of
    /// odd rows/columns — purely additive.
    Tablecloth,
}

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct ChessboardConfig {
    /// Number of drugs (rows of the board).
    pub drugs: usize,
    /// Number of targets (columns).
    pub targets: usize,
    /// Extra i.i.d. noise feature dimensions appended to the parity
    /// feature (makes the task realistic rather than trivially separable).
    pub noise_dims: usize,
    /// Which pattern.
    pub pattern: Pattern,
}

impl ChessboardConfig {
    pub fn new(pattern: Pattern) -> Self {
        Self { drugs: 24, targets: 24, noise_dims: 4, pattern }
    }
}

impl ChessboardConfig {
    /// Generate the complete labeled grid.
    ///
    /// Object features are `[1, s, ε…]` with `s = ±1` the parity and `ε`
    /// noise; kernels are linear on these features, so the pairwise linear
    /// kernel spans only `{1, s_d, s_t}` (no product term — it *cannot*
    /// represent XOR, Minsky & Papert 1969) while the Kronecker kernel's
    /// feature map contains `s_d·s_t`.
    pub fn generate(&self, seed: u64) -> PairDataset {
        let mut rng = Xoshiro256::seed_from(seed);
        let feats = |n: usize, rng: &mut Xoshiro256| {
            Mat::from_fn(n, 2 + self.noise_dims, |i, j| match j {
                0 => 1.0,
                1 => {
                    if i % 2 == 0 {
                        1.0
                    } else {
                        -1.0
                    }
                }
                _ => 0.3 * dist::standard_normal(rng),
            })
        };
        let xd = feats(self.drugs, &mut rng);
        let xt = feats(self.targets, &mut rng);
        let params = KernelParams::default();
        let d = Arc::new(kernel_matrix(BaseKernel::Linear, &params, &xd));
        let t = Arc::new(kernel_matrix(BaseKernel::Linear, &params, &xt));
        let pairs = PairIndex::complete(self.drugs, self.targets);
        let y: Vec<f64> = (0..pairs.len())
            .map(|i| {
                let pd = pairs.drug(i) % 2 == 0;
                let pt = pairs.target(i) % 2 == 0;
                let label = match self.pattern {
                    Pattern::Chessboard => pd ^ pt,
                    Pattern::Tablecloth => pd || pt,
                };
                if label {
                    1.0
                } else {
                    0.0
                }
            })
            .collect();
        PairDataset {
            name: format!("{:?}", self.pattern).to_lowercase(),
            d,
            t,
            pairs,
            y,
            homogeneous: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chessboard_is_balanced_xor() {
        let data = ChessboardConfig::new(Pattern::Chessboard).generate(1);
        assert_eq!(data.len(), 24 * 24);
        // XOR of two balanced parities is balanced.
        assert!((data.positive_rate() - 0.5).abs() < 1e-12);
        // Label at (0,0) (both even) is false; (0,1) is true.
        assert_eq!(data.y[0], 0.0);
        assert_eq!(data.y[1], 1.0);
    }

    #[test]
    fn tablecloth_is_monotone_in_parities() {
        let data = ChessboardConfig::new(Pattern::Tablecloth).generate(2);
        // OR of parities: 3/4 positive.
        assert!((data.positive_rate() - 0.75).abs() < 1e-12);
    }
}
