//! Heterodimer-like protein-complex dataset (§5.1).
//!
//! The real dataset: 1526 yeast proteins, 152 positive heterodimer pairs
//! vs 5345 negatives (2.8% positive), homogeneous domain, three binary
//! feature families (domains 2554 bits, phylogenetic profile 768 bits,
//! subcellular localization 83 bits) with Tanimoto kernels.
//!
//! The generator plants latent *complex clusters*: proteins in one cluster
//! share feature signatures, and heterodimer positives are pairs within a
//! cluster. Feature families carry the signal with different strengths —
//! reproducing the paper's headline Figure 4 observation that the best
//! pairwise kernel depends strongly on the feature family.

use crate::data::PairDataset;
use crate::kernels::{kernel_matrix, BaseKernel, KernelParams};
use crate::linalg::Mat;
use crate::rng::{dist, Rng, Xoshiro256};
use crate::sparse::PairIndex;
use std::sync::Arc;

/// The three feature families of §5.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProteinFeature {
    /// Protein-domain occurrences (strongest cluster signal).
    Domain,
    /// Phylogenetic profile (moderate signal).
    Genome,
    /// Subcellular localization (weak, low-dimensional signal).
    Location,
}

impl ProteinFeature {
    pub const ALL: [ProteinFeature; 3] =
        [ProteinFeature::Domain, ProteinFeature::Genome, ProteinFeature::Location];

    pub fn name(&self) -> &'static str {
        match self {
            ProteinFeature::Domain => "domain",
            ProteinFeature::Genome => "genome",
            ProteinFeature::Location => "location",
        }
    }

    /// (feature bits, signature bits per cluster, background density,
    /// signature density) — mirrors the real dimensionalities scaled down.
    fn spec(&self, scale: f64) -> (usize, usize, f64, f64) {
        match self {
            ProteinFeature::Domain => ((2554.0 * scale) as usize, 6, 0.004, 0.9),
            ProteinFeature::Genome => ((768.0 * scale) as usize, 12, 0.05, 0.65),
            ProteinFeature::Location => ((83.0 * scale).max(8.0) as usize, 2, 0.08, 0.5),
        }
    }
}

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct HeterodimerConfig {
    /// Number of proteins (paper: 1526).
    pub proteins: usize,
    /// Number of labeled pairs (paper: 5497).
    pub pairs: usize,
    /// Positive rate (paper: 152/5497 ≈ 0.028).
    pub positive_rate: f64,
    /// Latent complex clusters.
    pub clusters: usize,
    /// Feature-dimension scale vs the real dataset (1.0 = full size).
    pub feature_scale: f64,
}

impl HeterodimerConfig {
    /// Paper-scale dimensions.
    pub fn paper() -> Self {
        Self {
            proteins: 1526,
            pairs: 5497,
            positive_rate: 152.0 / 5497.0,
            clusters: 120,
            feature_scale: 1.0,
        }
    }

    /// Small variant for tests.
    pub fn small() -> Self {
        Self { proteins: 80, pairs: 300, positive_rate: 0.1, clusters: 12, feature_scale: 0.1 }
    }

    /// Generate the dataset with one feature family's Tanimoto kernel.
    pub fn generate(&self, feature: ProteinFeature, seed: u64) -> PairDataset {
        let mut rng = Xoshiro256::seed_from(seed);
        let n_prot = self.proteins;
        // Cluster assignment: most proteins belong to a latent complex.
        let cluster: Vec<usize> = (0..n_prot).map(|_| rng.index(self.clusters)).collect();

        // Binary features from the block model.
        let (bits, sig_bits, bg, sig) = feature.spec(self.feature_scale);
        let mut x = Mat::zeros(n_prot, bits);
        // Cluster signatures: disjoint-ish random bit sets.
        let signatures: Vec<Vec<usize>> = (0..self.clusters)
            .map(|_| dist::sample_without_replacement(&mut rng, bits, sig_bits.min(bits)))
            .collect();
        for p in 0..n_prot {
            for j in 0..bits {
                if dist::bernoulli(&mut rng, bg) {
                    x[(p, j)] = 1.0;
                }
            }
            for &j in &signatures[cluster[p]] {
                if dist::bernoulli(&mut rng, sig) {
                    x[(p, j)] = 1.0;
                }
            }
        }
        let d = kernel_matrix(BaseKernel::Tanimoto, &KernelParams::default(), &x);

        // Labeled pairs: positives within clusters, negatives across.
        let n_pos = ((self.pairs as f64) * self.positive_rate).round() as usize;
        let n_neg = self.pairs - n_pos;
        let mut pd = Vec::with_capacity(self.pairs);
        let mut pt = Vec::with_capacity(self.pairs);
        let mut y = Vec::with_capacity(self.pairs);
        let mut made = 0usize;
        let mut guard = 0usize;
        while made < n_pos && guard < 100 * n_pos {
            guard += 1;
            let a = rng.index(n_prot);
            let b = rng.index(n_prot);
            if a != b && cluster[a] == cluster[b] {
                pd.push(a as u32);
                pt.push(b as u32);
                y.push(1.0);
                made += 1;
            }
        }
        made = 0;
        while made < n_neg {
            let a = rng.index(n_prot);
            let b = rng.index(n_prot);
            if a != b && cluster[a] != cluster[b] {
                pd.push(a as u32);
                pt.push(b as u32);
                y.push(0.0);
                made += 1;
            }
        }
        let pairs = PairIndex::new(pd, pt, n_prot, n_prot);
        let d = Arc::new(d);
        PairDataset {
            name: format!("heterodimer-{}", feature.name()),
            d: d.clone(),
            t: d,
            pairs,
            y,
            homogeneous: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_with_shared_kernel() {
        let data = HeterodimerConfig::small().generate(ProteinFeature::Domain, 3);
        assert!(data.homogeneous);
        assert_eq!(data.pairs.m(), data.pairs.q());
        assert!(Arc::ptr_eq(&data.d, &data.t));
    }

    #[test]
    fn positive_rate_matches() {
        let data = HeterodimerConfig::small().generate(ProteinFeature::Genome, 4);
        assert!((data.positive_rate() - 0.1).abs() < 0.02);
        assert_eq!(data.len(), 300);
    }

    #[test]
    fn same_cluster_pairs_more_similar() {
        // The planted signal: positive pairs should have higher kernel
        // similarity than negative pairs on the Domain features.
        let data = HeterodimerConfig::small().generate(ProteinFeature::Domain, 5);
        let bins = data.binary_labels();
        let mut pos_sim = 0.0;
        let mut npos = 0.0;
        let mut neg_sim = 0.0;
        let mut nneg = 0.0;
        for i in 0..data.len() {
            let s = data.d[(data.pairs.drug(i), data.pairs.target(i))];
            if bins[i] {
                pos_sim += s;
                npos += 1.0;
            } else {
                neg_sim += s;
                nneg += 1.0;
            }
        }
        assert!(pos_sim / npos > neg_sim / nneg + 0.01);
    }

    #[test]
    fn all_feature_families_build() {
        for f in ProteinFeature::ALL {
            let data = HeterodimerConfig::small().generate(f, 6);
            assert!(data.d.is_symmetric(1e-12), "{f:?}");
        }
    }
}
