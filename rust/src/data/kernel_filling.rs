//! The kernel-filling scalability task (§5.4, Figures 7–9).
//!
//! The paper's largest experiment: given 10 drug kernels over the same
//! 2967 drugs, predict the entries of kernel `i` (labels `y = vec(Dⁱ)`)
//! using kernel `j` as features — 8 803 089 possible pairs, homogeneous,
//! 100% dense, real-valued. Because the task is *kernels about kernels*,
//! a synthetic fingerprint universe reproduces it exactly in structure:
//! we generate 10 Tanimoto kernels from correlated random fingerprints
//! (same construction as the paper's rcdk fingerprints).
//!
//! [`KernelFillingConfig::generate`] samples an `k × k` drug sub-universe
//! and `n` labeled (drug, drug) pairs from it, exactly the sub-sampling
//! protocol of §6.4.

use crate::data::metz::quantile;
use crate::data::PairDataset;
use crate::kernels::{kernel_matrix, BaseKernel, KernelParams};
use crate::linalg::Mat;
use crate::rng::{dist, Xoshiro256};
use crate::sparse::PairIndex;
use std::sync::Arc;

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct KernelFillingConfig {
    /// Size of the drug universe (paper: 2967).
    pub drugs: usize,
    /// Fingerprint bits per kernel view.
    pub fingerprint_bits: usize,
    /// Latent chemistry rank shared by all views.
    pub rank: usize,
    /// Which kernel provides labels (paper reports `circular`).
    pub label_kernel: usize,
    /// Which kernel provides features (paper reports `estate`).
    pub feature_kernel: usize,
    /// Positive rate for AUC binarization of the label-kernel entries.
    pub positive_rate: f64,
}

impl KernelFillingConfig {
    /// Paper-scale universe.
    pub fn paper() -> Self {
        Self {
            drugs: 2967,
            fingerprint_bits: 512,
            rank: 16,
            label_kernel: 1,   // "circular"
            feature_kernel: 4, // "estate"
            positive_rate: 0.1,
        }
    }

    /// Small universe for tests.
    pub fn small() -> Self {
        Self {
            drugs: 64,
            fingerprint_bits: 96,
            rank: 6,
            label_kernel: 1,
            feature_kernel: 4,
            positive_rate: 0.2,
        }
    }

    /// Build one fingerprint view and its Tanimoto kernel over the whole
    /// universe. Views share latent chemistry `u` but use independent
    /// projections + noise, like the paper's 10 rcdk fingerprints.
    fn view_kernel(&self, u: &Mat, view: usize, seed: u64) -> Mat {
        let m = u.rows();
        let r = u.cols();
        let mut vrng = Xoshiro256::seed_from(seed ^ (0xF1F0 + view as u64));
        let proj =
            Mat::from_vec(r, self.fingerprint_bits, dist::normal_vec(&mut vrng, r * self.fingerprint_bits));
        let scores = u.matmul(&proj);
        let fp = Mat::from_fn(m, self.fingerprint_bits, |i, j| {
            if scores[(i, j)] + 0.6 * dist::standard_normal(&mut vrng) > 0.5 {
                1.0
            } else {
                0.0
            }
        });
        kernel_matrix(BaseKernel::Tanimoto, &KernelParams::default(), &fp)
    }

    /// Generate the task restricted to a `k`-drug sub-universe with `n`
    /// labeled pairs sampled from the `k × k` grid (`n` is clamped to
    /// `k²`). `self.drugs` documents the full-universe size of the paper's
    /// task; `k` may be anything — the latent chemistry is generated at
    /// whatever sub-universe size the caller asks for.
    pub fn generate(&self, k: usize, n: usize, seed: u64) -> PairDataset {
        let n = n.min(k * k);
        let mut rng = Xoshiro256::seed_from(seed);

        // Latent chemistry for the sub-universe only (cheaper; the
        // sub-universe is the whole domain of this dataset instance).
        let u = Mat::from_vec(k, self.rank, dist::normal_vec(&mut rng, k * self.rank));
        let label_k = self.view_kernel(&u, self.label_kernel, seed);
        let feature_k = self.view_kernel(&u, self.feature_kernel, seed);

        // Sample n cells of the k×k grid.
        let chosen = dist::sample_without_replacement(&mut rng, k * k, n);
        let drugs: Vec<u32> = chosen.iter().map(|&p| (p / k) as u32).collect();
        let targets: Vec<u32> = chosen.iter().map(|&p| (p % k) as u32).collect();
        let pairs = PairIndex::new(drugs, targets, k, k);

        // Labels: entries of the label kernel, binarized at the quantile
        // for AUC evaluation (the paper evaluates AUC on these).
        let raw: Vec<f64> =
            (0..n).map(|i| label_k[(pairs.drug(i), pairs.target(i))]).collect();
        let thr = quantile(&raw, 1.0 - self.positive_rate);
        let y: Vec<f64> = raw.iter().map(|&v| if v >= thr { 1.0 } else { 0.0 }).collect();

        let d = Arc::new(feature_k);
        PairDataset {
            name: format!("kernel-filling[k={k},n={n}]"),
            d: d.clone(),
            t: d,
            pairs,
            y,
            homogeneous: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_size() {
        let data = KernelFillingConfig::small().generate(32, 400, 21);
        assert_eq!(data.len(), 400);
        assert_eq!(data.pairs.m(), 32);
        assert!(data.homogeneous);
    }

    #[test]
    fn dense_when_n_equals_grid() {
        let data = KernelFillingConfig::small().generate(16, 256, 22);
        assert!((data.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn label_and_feature_kernels_correlate() {
        // Shared latent chemistry ⇒ the feature kernel carries signal
        // about the label kernel (otherwise the task would be noise).
        let cfg = KernelFillingConfig::small();
        let data = cfg.generate(40, 800, 23);
        let bins = data.binary_labels();
        let mut pos = 0.0;
        let mut np = 0.0;
        let mut neg = 0.0;
        let mut nn = 0.0;
        for i in 0..data.len() {
            let f = data.d[(data.pairs.drug(i), data.pairs.target(i))];
            if bins[i] {
                pos += f;
                np += 1.0;
            } else {
                neg += f;
                nn += 1.0;
            }
        }
        assert!(pos / np > neg / nn, "feature kernel uninformative");
    }

    #[test]
    fn positive_rate_near_target() {
        let data = KernelFillingConfig::small().generate(32, 600, 24);
        assert!((data.positive_rate() - 0.2).abs() < 0.05);
    }
}
