//! Merget-like kinome profiling dataset (§5.3).
//!
//! The real dataset: 167 995 binding values over 2967 drugs × 226 kinases
//! (25% density), with **10 drug kernels** (Tanimoto on different molecular
//! fingerprints) and **9 target kernels** (Gaussian on GO profiles, SW and
//! GS sequence similarities). The paper's Figure 6 sweeps (drug kernel,
//! target kernel) pairs and finds the pairwise-kernel ranking essentially
//! invariant to the base kernels.
//!
//! The generator plants one latent bilinear + additive ground truth and
//! derives *families* of correlated base kernels from noisy views of the
//! latent factors — so different kernel pairs carry overlapping signal,
//! reproducing that invariance.

use crate::data::metz::quantile;
use crate::data::PairDataset;
use crate::kernels::{kernel_matrix, BaseKernel, KernelParams};
use crate::linalg::Mat;
use crate::rng::{dist, Xoshiro256};
use crate::sparse::PairIndex;
use std::sync::Arc;

/// Names of the synthetic drug fingerprint kernels (subset of the rcdk
/// fingerprints the paper lists).
pub const DRUG_KERNELS: [&str; 10] = [
    "sp", "circular", "kr", "maccs", "estate", "extended", "graph", "hybridization",
    "pubchem", "standard",
];

/// Names of the synthetic target kernels (GO profiles / sequence sims).
pub const TARGET_KERNELS: [&str; 9] = [
    "GS-atp-5.4.4", "GS-kindom-5.4.4", "GS-full-5.3", "GO-bp-71", "GO-cc-19",
    "GO-mf-31", "SW-kindom", "SW-full", "SW-atp",
];

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct MergetConfig {
    pub drugs: usize,
    pub targets: usize,
    pub density: f64,
    pub rank: usize,
    pub interaction_strength: f64,
    pub noise: f64,
    pub positive_rate: f64,
    /// Fingerprint bits per drug-kernel view.
    pub fingerprint_bits: usize,
}

impl MergetConfig {
    /// Paper-scale dimensions (2967 × 226, 25% density).
    pub fn paper() -> Self {
        Self {
            drugs: 2967,
            targets: 226,
            density: 0.25,
            rank: 10,
            interaction_strength: 1.0,
            noise: 0.3,
            positive_rate: 0.05,
            fingerprint_bits: 256,
        }
    }

    /// Small variant for tests and CI.
    pub fn small() -> Self {
        Self {
            drugs: 60,
            targets: 25,
            density: 0.4,
            rank: 5,
            interaction_strength: 1.0,
            noise: 0.25,
            positive_rate: 0.12,
            fingerprint_bits: 64,
        }
    }

    /// Generate with a chosen (drug kernel, target kernel) pair; indices
    /// select among the named views ([`DRUG_KERNELS`], [`TARGET_KERNELS`]).
    pub fn generate(&self, drug_kernel: usize, target_kernel: usize, seed: u64) -> PairDataset {
        assert!(drug_kernel < DRUG_KERNELS.len());
        assert!(target_kernel < TARGET_KERNELS.len());
        let mut rng = Xoshiro256::seed_from(seed);
        let (m, q, r) = (self.drugs, self.targets, self.rank);

        // Shared latent ground truth (independent of kernel view).
        let u = Mat::from_vec(m, r, dist::normal_vec(&mut rng, m * r));
        let v = Mat::from_vec(q, r, dist::normal_vec(&mut rng, q * r));
        let a: Vec<f64> = dist::normal_vec(&mut rng, m);
        let b: Vec<f64> = dist::normal_vec(&mut rng, q);

        // Drug kernel: Tanimoto on a fingerprint view derived from the
        // latent factors. Different views = different random projections +
        // noise, so each of the 10 kernels is a corrupted window on the
        // same chemistry.
        let d = {
            // Advance a view-specific RNG so views differ deterministically.
            let mut vrng = Xoshiro256::seed_from(seed ^ (0xD00D + drug_kernel as u64));
            let proj = Mat::from_vec(r, self.fingerprint_bits,
                dist::normal_vec(&mut vrng, r * self.fingerprint_bits));
            let scores = u.matmul(&proj);
            let fp = Mat::from_fn(m, self.fingerprint_bits, |i, j| {
                let noise = 0.5 * dist::standard_normal(&mut vrng);
                if scores[(i, j)] + noise > 0.6 {
                    1.0
                } else {
                    0.0
                }
            });
            kernel_matrix(BaseKernel::Tanimoto, &KernelParams::default(), &fp)
        };

        // Target kernel: Gaussian on a noisy profile view of V.
        let t = {
            let mut vrng = Xoshiro256::seed_from(seed ^ (0xBEEF + target_kernel as u64));
            let profile = Mat::from_fn(q, r + 4, |i, j| {
                if j < r {
                    v[(i, j)] + 0.4 * dist::standard_normal(&mut vrng)
                } else {
                    dist::standard_normal(&mut vrng)
                }
            });
            kernel_matrix(
                BaseKernel::Gaussian,
                &KernelParams { gamma: 0.1 / (r as f64), ..Default::default() },
                &profile,
            )
        };

        // Sample labeled pairs and binarize.
        let total = m * q;
        let n = ((total as f64) * self.density).round() as usize;
        let chosen = dist::sample_without_replacement(&mut rng, total, n);
        let drugs: Vec<u32> = chosen.iter().map(|&p| (p / q) as u32).collect();
        let targets: Vec<u32> = chosen.iter().map(|&p| (p % q) as u32).collect();
        let pairs = PairIndex::new(drugs, targets, m, q);
        let mut affinities: Vec<f64> = (0..n)
            .map(|i| {
                let di = pairs.drug(i);
                let ti = pairs.target(i);
                a[di] + b[ti]
                    + self.interaction_strength
                        * crate::linalg::vecops::dot(u.row(di), v.row(ti))
                    + self.noise * dist::standard_normal(&mut rng)
            })
            .collect();
        let thr = quantile(&affinities, 1.0 - self.positive_rate);
        for y in affinities.iter_mut() {
            *y = if *y >= thr { 1.0 } else { 0.0 };
        }

        PairDataset {
            name: format!(
                "merget[{}x{}]",
                DRUG_KERNELS[drug_kernel], TARGET_KERNELS[target_kernel]
            ),
            d: Arc::new(d),
            t: Arc::new(t),
            pairs,
            y: affinities,
            homogeneous: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_with_requested_shape() {
        let data = MergetConfig::small().generate(1, 0, 11);
        assert_eq!(data.pairs.m(), 60);
        assert_eq!(data.pairs.q(), 25);
        assert!((data.density() - 0.4).abs() < 0.02);
    }

    #[test]
    fn different_views_share_labels() {
        // Same seed, different kernels: identical labels & pairs (the
        // ground truth is view-independent, as in the real data).
        let a = MergetConfig::small().generate(0, 0, 12);
        let b = MergetConfig::small().generate(3, 5, 12);
        assert_eq!(a.y, b.y);
        assert_eq!(a.pairs.drugs(), b.pairs.drugs());
        // But the kernels differ.
        assert!(a.d.max_abs_diff(&b.d) > 1e-6);
    }

    #[test]
    fn kernels_are_valid() {
        let data = MergetConfig::small().generate(2, 3, 13);
        assert!(data.d.is_symmetric(1e-9));
        assert!(data.t.is_symmetric(1e-9));
        for i in 0..25 {
            assert!((data.t[(i, i)] - 1.0).abs() < 1e-9); // Gaussian diag
        }
    }
}
