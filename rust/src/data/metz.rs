//! Metz-like drug–kinase interaction dataset (§5.2).
//!
//! The real Metz et al. (2011) assay is 93 356 labeled pairs over 156
//! drugs × 1421 targets (42% density), with drug features = Tanimoto
//! similarity-matrix rows and target features = normalized Smith-Waterman
//! similarity rows, binarized at a stringent `K_i` threshold (~3%
//! positives). This generator reproduces that *structure*:
//!
//! * latent factor model: affinity = drug propensity + target propensity
//!   + β · ⟨u_d, v_t⟩ + noise — an explicit linear + pairwise-interaction
//!   signal mix (β tunes how much the non-linearity assumption holds,
//!   which drives the paper's "linear is surprisingly competitive"
//!   observation);
//! * observed features are *similarity-matrix rows* (as in the paper),
//!   from which linear or Gaussian kernels are built.

use crate::data::PairDataset;
use crate::kernels::{kernel_matrix, normalize_kernel, BaseKernel, KernelParams};
use crate::linalg::Mat;
use crate::rng::{dist, Xoshiro256};
use crate::sparse::PairIndex;
use std::sync::Arc;

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct MetzConfig {
    pub drugs: usize,
    pub targets: usize,
    /// Fraction of the complete grid that is labeled.
    pub density: f64,
    /// Latent factor dimension.
    pub rank: usize,
    /// Weight of the bilinear (pairwise-interaction) signal vs the
    /// additive one.
    pub interaction_strength: f64,
    /// Observation noise std.
    pub noise: f64,
    /// Positive rate after binarization (paper ≈ 0.03).
    pub positive_rate: f64,
    /// Base kernel applied to the similarity rows.
    pub base_kernel: BaseKernel,
    /// Gaussian bandwidth (paper uses 1e-5 on similarity rows).
    pub gamma: f64,
}

impl MetzConfig {
    /// Paper-scale dimensions (156 × 1421, 42% density).
    pub fn paper() -> Self {
        Self {
            drugs: 156,
            targets: 1421,
            density: 0.42,
            rank: 8,
            interaction_strength: 1.0,
            noise: 0.3,
            positive_rate: 0.03,
            base_kernel: BaseKernel::Linear,
            gamma: 1e-5,
        }
    }

    /// Small variant for tests and the quickstart example.
    pub fn small() -> Self {
        Self {
            drugs: 40,
            targets: 60,
            density: 0.5,
            rank: 4,
            interaction_strength: 1.0,
            noise: 0.2,
            positive_rate: 0.15,
            base_kernel: BaseKernel::Linear,
            gamma: 1e-3,
        }
    }

    pub fn with_kernel(mut self, k: BaseKernel) -> Self {
        self.base_kernel = k;
        self
    }

    /// Generate the dataset.
    pub fn generate(&self, seed: u64) -> PairDataset {
        let mut rng = Xoshiro256::seed_from(seed);
        let (m, q, r) = (self.drugs, self.targets, self.rank);

        // Latent structure.
        let u = Mat::from_vec(m, r, dist::normal_vec(&mut rng, m * r));
        let v = Mat::from_vec(q, r, dist::normal_vec(&mut rng, q * r));
        let a: Vec<f64> = dist::normal_vec(&mut rng, m); // drug propensity
        let b: Vec<f64> = dist::normal_vec(&mut rng, q); // target propensity

        // Observed features: noisy similarity-matrix rows (m×m and q×q).
        let sim_d = similarity_rows(&u, 0.1, &mut rng);
        let sim_t = similarity_rows(&v, 0.1, &mut rng);
        let params = KernelParams { gamma: self.gamma, ..Default::default() };
        let mut d = kernel_matrix(self.base_kernel, &params, &sim_d);
        let mut t = kernel_matrix(self.base_kernel, &params, &sim_t);
        if self.base_kernel == BaseKernel::Linear {
            normalize_kernel(&mut d);
            normalize_kernel(&mut t);
        }

        // Sample labeled pairs.
        let total = m * q;
        let n = ((total as f64) * self.density).round() as usize;
        let chosen = dist::sample_without_replacement(&mut rng, total, n);
        let drugs: Vec<u32> = chosen.iter().map(|&p| (p / q) as u32).collect();
        let targets: Vec<u32> = chosen.iter().map(|&p| (p % q) as u32).collect();
        let pairs = PairIndex::new(drugs, targets, m, q);

        // True affinities and binarization at the positive-rate quantile
        // (mirrors the paper's stringent K_i < 28.18 nM threshold).
        let mut affinities: Vec<f64> = (0..n)
            .map(|i| {
                let di = pairs.drug(i);
                let ti = pairs.target(i);
                let bilinear = crate::linalg::vecops::dot(u.row(di), v.row(ti));
                a[di] + b[ti]
                    + self.interaction_strength * bilinear
                    + self.noise * dist::standard_normal(&mut rng)
            })
            .collect();
        let threshold = quantile(&affinities, 1.0 - self.positive_rate);
        for v in affinities.iter_mut() {
            *v = if *v >= threshold { 1.0 } else { 0.0 };
        }

        PairDataset {
            name: "metz".into(),
            d: Arc::new(d),
            t: Arc::new(t),
            pairs,
            y: affinities,
            homogeneous: false,
        }
    }
}

/// Similarity-matrix rows `S = X Xᵀ / dim + noise`, the feature
/// representation the paper uses for both Metz drugs and targets.
fn similarity_rows(x: &Mat, noise: f64, rng: &mut Xoshiro256) -> Mat {
    let mut s = x.matmul_nt(x);
    s.scale(1.0 / x.cols() as f64);
    let n = s.rows();
    for i in 0..n {
        for j in 0..n {
            s[(i, j)] += noise * dist::standard_normal(rng);
        }
    }
    s
}

/// The `p`-quantile of a slice (nearest-rank).
pub(crate) fn quantile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((sorted.len() as f64 - 1.0) * p.clamp(0.0, 1.0)).round() as usize;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_and_density_match_config() {
        let cfg = MetzConfig::small();
        let data = cfg.generate(5);
        assert_eq!(data.pairs.m(), 40);
        assert_eq!(data.pairs.q(), 60);
        assert!((data.density() - 0.5).abs() < 0.01);
        assert!(!data.homogeneous);
    }

    #[test]
    fn positive_rate_near_target() {
        let data = MetzConfig::small().generate(6);
        assert!((data.positive_rate() - 0.15).abs() < 0.02, "{}", data.positive_rate());
    }

    #[test]
    fn kernels_are_symmetric_normalized() {
        let data = MetzConfig::small().generate(7);
        assert!(data.d.is_symmetric(1e-9));
        assert!(data.t.is_symmetric(1e-9));
        for i in 0..data.pairs.m() {
            assert!((data.d[(i, i)] - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = MetzConfig::small().generate(8);
        let b = MetzConfig::small().generate(8);
        assert_eq!(a.y, b.y);
        assert_eq!(a.pairs.drugs(), b.pairs.drugs());
    }

    #[test]
    fn gaussian_variant_builds() {
        let data = MetzConfig::small().with_kernel(BaseKernel::Gaussian).generate(9);
        // Gaussian kernel has unit diagonal by construction.
        for i in 0..data.pairs.m() {
            assert!((data.d[(i, i)] - 1.0).abs() < 1e-12);
        }
    }
}
