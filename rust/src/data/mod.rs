//! Datasets and splits.
//!
//! The paper evaluates on four biological datasets (Table 5). The raw data
//! is not redistributable / reachable from this environment, so each module
//! generates a synthetic dataset matching the published characteristics —
//! dimensions, density, homogeneity, feature structure, label imbalance,
//! and crucially the *linear + pairwise-interaction* signal mix that drives
//! the paper's kernel comparisons. See rust/DESIGN.md §Substitutions.
//!
//! * [`chessboard`] — the Figure 1 chessboard/tablecloth toy problems.
//! * [`heterodimer`] — homogeneous protein-complex classification.
//! * [`metz`] — drug–kinase affinity, 156 drugs × 1421 targets shape.
//! * [`merget`] — larger drug–kinase panel, multi-kernel.
//! * [`kernel_filling`] — the scalability task: predict one drug kernel's
//!   entries from another (structurally *identical* to the paper's, since
//!   that task is itself synthetic-on-kernels).
//! * [`splits`] — the Settings 1–4 train/test semantics of Table 1,
//!   single-split and k-fold cross-validation.

pub mod chessboard;
pub mod heterodimer;
pub mod kernel_filling;
pub mod merget;
pub mod metz;
pub mod splits;

use crate::linalg::Mat;
use crate::sparse::PairIndex;
use std::sync::Arc;

/// A labeled pairwise dataset: kernels over the full object domains plus a
/// sample of labeled (drug, target) pairs.
#[derive(Clone)]
pub struct PairDataset {
    /// Dataset name (report labels).
    pub name: String,
    /// Drug kernel over the full drug domain (`m × m`).
    pub d: Arc<Mat>,
    /// Target kernel over the full target domain (`q × q`); equals `d`
    /// for homogeneous datasets.
    pub t: Arc<Mat>,
    /// The labeled sample.
    pub pairs: PairIndex,
    /// Real-valued labels (binary datasets use {0, 1}).
    pub y: Vec<f64>,
    /// Whether both objects come from one domain (enables the symmetric /
    /// anti-symmetric / ranking / MLPK kernels).
    pub homogeneous: bool,
}

impl PairDataset {
    /// Number of labeled pairs.
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Restrict to a subset of pair rows (same kernels/domains).
    pub fn subset(&self, rows: &[usize]) -> PairDataset {
        PairDataset {
            name: self.name.clone(),
            d: self.d.clone(),
            t: self.t.clone(),
            pairs: self.pairs.subset(rows),
            y: rows.iter().map(|&i| self.y[i]).collect(),
            homogeneous: self.homogeneous,
        }
    }

    /// Binary labels for AUC (threshold at 0.5; generators emit {0,1} or
    /// already-binarized affinities).
    pub fn binary_labels(&self) -> Vec<bool> {
        self.y.iter().map(|&v| v >= 0.5).collect()
    }

    /// Fraction of positive labels.
    pub fn positive_rate(&self) -> f64 {
        if self.y.is_empty() {
            return 0.0;
        }
        self.binary_labels().iter().filter(|&&b| b).count() as f64 / self.y.len() as f64
    }

    /// Density: labeled pairs / all possible pairs (Table 5's "Dens.").
    pub fn density(&self) -> f64 {
        let total = self.pairs.m() as f64 * self.pairs.q() as f64;
        self.len() as f64 / total.max(1.0)
    }

    /// One row of Table 5.
    pub fn stats_row(&self) -> String {
        format!(
            "| {:<14} | {:>9} | {:>5} | {:>5} | {:^4} | {:>5.1}% |",
            self.name,
            self.len(),
            self.pairs.distinct_drugs(),
            self.pairs.distinct_targets(),
            if self.homogeneous { "X" } else { "" },
            100.0 * self.density()
        )
    }

    /// Convenience wrapper over [`splits::split_setting`].
    pub fn split_setting(&self, setting: u8, test_fraction: f64, seed: u64) -> splits::Split {
        splits::split_setting(self, setting, test_fraction, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::gen;
    use crate::rng::Xoshiro256;

    fn tiny() -> PairDataset {
        let mut rng = Xoshiro256::seed_from(80);
        let d = Arc::new(gen::psd_kernel(&mut rng, 4));
        let t = Arc::new(gen::psd_kernel(&mut rng, 5));
        let pairs = gen::pair_sample(&mut rng, 12, 4, 5);
        PairDataset {
            name: "tiny".into(),
            d,
            t,
            pairs,
            y: (0..12).map(|i| (i % 2) as f64).collect(),
            homogeneous: false,
        }
    }

    #[test]
    fn subset_keeps_alignment() {
        let data = tiny();
        let s = data.subset(&[0, 5, 7]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.y, vec![0.0, 1.0, 1.0]);
        assert_eq!(s.pairs.drug(1), data.pairs.drug(5));
    }

    #[test]
    fn density_and_positives() {
        let data = tiny();
        assert!((data.density() - 12.0 / 20.0).abs() < 1e-12);
        assert!((data.positive_rate() - 0.5).abs() < 1e-12);
    }
}
