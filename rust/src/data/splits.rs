//! Settings 1–4 train/test splits (Table 1) and k-fold cross-validation.
//!
//! * **Setting 1** — split *pairs*: test pairs share drugs and targets
//!   with training.
//! * **Setting 2** — split *targets*: test pairs have novel targets.
//! * **Setting 3** — split *drugs*: test pairs have novel drugs.
//! * **Setting 4** — split both: test pairs have novel drugs **and**
//!   targets; pairs mixing train/test objects are discarded ("ignored" in
//!   Table 1).
//!
//! For homogeneous datasets settings 2 and 3 are equivalent (the paper
//! notes this in §6.4); we still implement both literally — setting 2
//! splits on the second slot, setting 3 on the first.

use crate::data::PairDataset;
use crate::rng::{dist, Xoshiro256};

/// A train/test split of one dataset.
pub struct Split {
    pub train: PairDataset,
    pub test: PairDataset,
    /// The setting (1–4) that produced this split.
    pub setting: u8,
}

/// Split per Table 1. `test_fraction` is the held-out fraction of the
/// splitting unit (pairs for setting 1, objects for settings 2–4).
pub fn split_setting(
    data: &PairDataset,
    setting: u8,
    test_fraction: f64,
    seed: u64,
) -> Split {
    assert!((0.0..1.0).contains(&test_fraction), "test_fraction in (0,1)");
    let mut rng = Xoshiro256::seed_from(seed);
    let n = data.len();
    let (train_rows, test_rows): (Vec<usize>, Vec<usize>) = match setting {
        1 => {
            let k = ((n as f64) * test_fraction).round() as usize;
            let mut is_test = vec![false; n];
            for i in dist::sample_without_replacement(&mut rng, n, k) {
                is_test[i] = true;
            }
            partition(n, |i| !is_test[i])
        }
        2 => {
            let held = hold_out_objects(&mut rng, data.pairs.q(), test_fraction);
            partition(n, |i| !held[data.pairs.target(i)])
        }
        3 => {
            let held = hold_out_objects(&mut rng, data.pairs.m(), test_fraction);
            partition(n, |i| !held[data.pairs.drug(i)])
        }
        4 => {
            let held_d = hold_out_objects(&mut rng, data.pairs.m(), test_fraction);
            let held_t = hold_out_objects(&mut rng, data.pairs.q(), test_fraction);
            // Three-way: train (both in-train), test (both held), ignored.
            let mut train = Vec::new();
            let mut test = Vec::new();
            for i in 0..n {
                let hd = held_d[data.pairs.drug(i)];
                let ht = held_t[data.pairs.target(i)];
                match (hd, ht) {
                    (false, false) => train.push(i),
                    (true, true) => test.push(i),
                    _ => {} // ignored per Table 1
                }
            }
            (train, test)
        }
        s => panic!("unknown setting {s} (must be 1–4)"),
    };
    Split {
        train: data.subset(&train_rows),
        test: data.subset(&test_rows),
        setting,
    }
}

/// k-fold cross-validation respecting the setting semantics: fold the
/// splitting unit (pairs / targets / drugs / both), exactly as the paper's
/// 9-fold protocol.
pub fn cv_splits(data: &PairDataset, setting: u8, folds: usize, seed: u64) -> Vec<Split> {
    let mut rng = Xoshiro256::seed_from(seed);
    let n = data.len();
    match setting {
        1 => {
            let assign = dist::fold_assignment(&mut rng, n, folds);
            (0..folds)
                .map(|f| {
                    let (train, test) = partition(n, |i| assign[i] != f);
                    Split { train: data.subset(&train), test: data.subset(&test), setting }
                })
                .collect()
        }
        2 => {
            let assign = dist::fold_assignment(&mut rng, data.pairs.q(), folds);
            (0..folds)
                .map(|f| {
                    let (train, test) = partition(n, |i| assign[data.pairs.target(i)] != f);
                    Split { train: data.subset(&train), test: data.subset(&test), setting }
                })
                .collect()
        }
        3 => {
            let assign = dist::fold_assignment(&mut rng, data.pairs.m(), folds);
            (0..folds)
                .map(|f| {
                    let (train, test) = partition(n, |i| assign[data.pairs.drug(i)] != f);
                    Split { train: data.subset(&train), test: data.subset(&test), setting }
                })
                .collect()
        }
        4 => {
            let ad = dist::fold_assignment(&mut rng, data.pairs.m(), folds);
            let at = dist::fold_assignment(&mut rng, data.pairs.q(), folds);
            (0..folds)
                .map(|f| {
                    let mut train = Vec::new();
                    let mut test = Vec::new();
                    for i in 0..n {
                        let fd = ad[data.pairs.drug(i)] == f;
                        let ft = at[data.pairs.target(i)] == f;
                        match (fd, ft) {
                            (false, false) => train.push(i),
                            (true, true) => test.push(i),
                            _ => {}
                        }
                    }
                    Split { train: data.subset(&train), test: data.subset(&test), setting }
                })
                .collect()
        }
        s => panic!("unknown setting {s}"),
    }
}

fn hold_out_objects(rng: &mut Xoshiro256, domain: usize, fraction: f64) -> Vec<bool> {
    let k = ((domain as f64) * fraction).round().max(1.0) as usize;
    let k = k.min(domain.saturating_sub(1)).max(1);
    let mut held = vec![false; domain];
    for i in dist::sample_without_replacement(rng, domain, k) {
        held[i] = true;
    }
    held
}

fn partition(n: usize, in_train: impl Fn(usize) -> bool) -> (Vec<usize>, Vec<usize>) {
    let mut train = Vec::new();
    let mut test = Vec::new();
    for i in 0..n {
        if in_train(i) {
            train.push(i);
        } else {
            test.push(i);
        }
    }
    (train, test)
}

/// Check the defining invariant of each setting on a split (used by the
/// property tests): does the test set overlap training drugs/targets the
/// way Table 1 prescribes?
pub fn verify_split_invariant(split: &Split) -> Result<(), String> {
    let train = &split.train;
    let test = &split.test;
    let m = train.pairs.m();
    let q = train.pairs.q();
    let mut train_drugs = vec![false; m];
    let mut train_targets = vec![false; q];
    for i in 0..train.len() {
        train_drugs[train.pairs.drug(i)] = true;
        train_targets[train.pairs.target(i)] = true;
    }
    for i in 0..test.len() {
        let d_seen = train_drugs[test.pairs.drug(i)];
        let t_seen = train_targets[test.pairs.target(i)];
        let ok = match split.setting {
            1 => true, // pairs split; objects may overlap freely
            2 => !t_seen,
            3 => !d_seen,
            4 => !d_seen && !t_seen,
            _ => false,
        };
        if !ok {
            return Err(format!(
                "setting {} violated at test pair {i}: drug seen={d_seen}, target seen={t_seen}",
                split.setting
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::testing::gen;
    use std::sync::Arc;

    fn dataset(seed: u64, n: usize, m: usize, q: usize) -> PairDataset {
        let mut rng = Xoshiro256::seed_from(seed);
        PairDataset {
            name: "t".into(),
            d: Arc::new(gen::psd_kernel(&mut rng, m)),
            t: Arc::new(gen::psd_kernel(&mut rng, q)),
            pairs: gen::pair_sample(&mut rng, n, m, q),
            y: (0..n).map(|i| (i % 2) as f64).collect(),
            homogeneous: false,
        }
    }

    #[test]
    fn all_settings_satisfy_invariants() {
        let data = dataset(90, 400, 25, 30);
        for setting in 1..=4 {
            let split = split_setting(&data, setting, 0.25, 7);
            assert!(!split.train.is_empty(), "setting {setting} train empty");
            assert!(!split.test.is_empty(), "setting {setting} test empty");
            verify_split_invariant(&split).unwrap();
        }
    }

    #[test]
    fn setting1_partitions_pairs_exactly() {
        let data = dataset(91, 200, 10, 10);
        let split = split_setting(&data, 1, 0.3, 3);
        assert_eq!(split.train.len() + split.test.len(), 200);
        assert_eq!(split.test.len(), 60);
    }

    #[test]
    fn setting4_discards_mixed_pairs() {
        let data = dataset(92, 500, 20, 20);
        let split = split_setting(&data, 4, 0.3, 11);
        assert!(split.train.len() + split.test.len() < 500, "must ignore mixed pairs");
    }

    #[test]
    fn cv_folds_cover_each_pair_once_setting1() {
        let data = dataset(93, 123, 9, 11);
        let splits = cv_splits(&data, 1, 5, 17);
        let total_test: usize = splits.iter().map(|s| s.test.len()).sum();
        assert_eq!(total_test, 123);
        for s in &splits {
            verify_split_invariant(s).unwrap();
        }
    }

    #[test]
    fn cv_folds_settings_2_to_4_satisfy_invariants() {
        let data = dataset(94, 600, 18, 24);
        for setting in 2..=4 {
            for s in cv_splits(&data, setting, 4, 23) {
                verify_split_invariant(&s).unwrap();
            }
        }
    }
}
