//! Crate-local error subsystem (no external error crates offline).
//!
//! Mirrors the small slice of the usual context-chaining error API this
//! crate needs, with zero dependencies:
//!
//! * [`GvtError`] — the crate-wide error enum. Ad-hoc failures carry a
//!   message; foreign errors (I/O, number parsing, UTF-8) are wrapped so
//!   the `?` operator keeps working at every call site; layered context
//!   is a linked chain, printed innermost-last.
//! * [`Result`] — `Result<T, GvtError>` alias, the return type of every
//!   fallible API in the crate.
//! * [`bail!`](crate::bail) — early-return with a formatted message.
//! * [`gvt_err!`](crate::gvt_err) — build a [`GvtError`] from a format
//!   string (for `ok_or_else`/`map_err` sites).
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, attaching a caller-side description to a failure.
//!
//! Display formatting: `{}` prints the outermost description only, `{:#}`
//! prints the whole chain separated by `": "` (the `error: {e:#}`
//! reporting in the `gvt-rls` binary).

use std::fmt;

/// The crate-wide error type.
pub enum GvtError {
    /// Ad-hoc failure described by a message ([`bail!`](crate::bail) /
    /// [`gvt_err!`](crate::gvt_err)).
    Message(String),
    /// Filesystem / stream failure (model persistence, config loading,
    /// artifact discovery).
    Io(std::io::Error),
    /// Integer field that failed to parse (configs, CLI, model files).
    ParseInt(std::num::ParseIntError),
    /// Floating-point field that failed to parse (configs, CLI, JSON).
    ParseFloat(std::num::ParseFloatError),
    /// Invalid UTF-8 in a byte stream (JSON manifest parsing).
    Utf8(std::str::Utf8Error),
    /// A lower-level error wrapped with a caller-side description.
    Context {
        context: String,
        source: Box<GvtError>,
    },
}

impl GvtError {
    /// Build an ad-hoc error from anything displayable.
    pub fn msg(msg: impl fmt::Display) -> GvtError {
        GvtError::Message(msg.to_string())
    }

    /// Wrap `self` with an outer description (what the caller was doing).
    pub fn context(self, context: impl fmt::Display) -> GvtError {
        GvtError::Context { context: context.to_string(), source: Box::new(self) }
    }

    /// The outermost description (what `{}` prints).
    fn outermost(&self) -> String {
        match self {
            GvtError::Message(m) => m.clone(),
            GvtError::Io(e) => e.to_string(),
            GvtError::ParseInt(e) => e.to_string(),
            GvtError::ParseFloat(e) => e.to_string(),
            GvtError::Utf8(e) => e.to_string(),
            GvtError::Context { context, .. } => context.clone(),
        }
    }
}

impl fmt::Display for GvtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, outermost first — "reading config: No
            // such file or directory".
            write!(f, "{}", self.outermost())?;
            let mut cur = self;
            while let GvtError::Context { source, .. } = cur {
                cur = &**source;
                write!(f, ": {}", cur.outermost())?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.outermost())
        }
    }
}

impl fmt::Debug for GvtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `unwrap()` in tests prints Debug; show the full chain there too.
        write!(f, "{self:#}")
    }
}

impl std::error::Error for GvtError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            GvtError::Io(e) => Some(e),
            GvtError::ParseInt(e) => Some(e),
            GvtError::ParseFloat(e) => Some(e),
            GvtError::Utf8(e) => Some(e),
            GvtError::Context { source, .. } => Some(&**source),
            GvtError::Message(_) => None,
        }
    }
}

impl From<std::io::Error> for GvtError {
    fn from(e: std::io::Error) -> GvtError {
        GvtError::Io(e)
    }
}

impl From<std::num::ParseIntError> for GvtError {
    fn from(e: std::num::ParseIntError) -> GvtError {
        GvtError::ParseInt(e)
    }
}

impl From<std::num::ParseFloatError> for GvtError {
    fn from(e: std::num::ParseFloatError) -> GvtError {
        GvtError::ParseFloat(e)
    }
}

impl From<std::str::Utf8Error> for GvtError {
    fn from(e: std::str::Utf8Error) -> GvtError {
        GvtError::Utf8(e)
    }
}

/// Crate-wide result alias.
pub type Result<T, E = GvtError> = std::result::Result<T, E>;

/// Return early with a formatted [`GvtError`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::error::GvtError::msg(format!($($arg)*)))
    };
}

/// Build a [`GvtError`] from a format string.
#[macro_export]
macro_rules! gvt_err {
    ($($arg:tt)*) => {
        $crate::error::GvtError::msg(format!($($arg)*))
    };
}

// Make the macros importable alongside the rest of the subsystem:
// `use crate::error::{bail, Context, Result};`.
pub use crate::{bail, gvt_err};

/// Attach context to failures.
pub trait Context<T> {
    /// Wrap the error with a fixed description.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily-built description (use when the
    /// description allocates).
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<GvtError>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| GvtError::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| GvtError::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_port(s: &str) -> Result<u16> {
        let n: u16 = s.parse()?; // From<ParseIntError>
        if n == 0 {
            bail!("port must be nonzero, got {n}");
        }
        Ok(n)
    }

    #[test]
    fn question_mark_converts_foreign_errors() {
        assert_eq!(parse_port("8080").unwrap(), 8080);
        assert!(matches!(parse_port("x"), Err(GvtError::ParseInt(_))));
        assert!(matches!(parse_port("0"), Err(GvtError::Message(_))));
    }

    #[test]
    fn bail_formats_message() {
        let e = parse_port("0").unwrap_err();
        assert_eq!(e.to_string(), "port must be nonzero, got 0");
    }

    #[test]
    fn context_chain_prints_outermost_plain_and_full_alternate() {
        let e = parse_port("x").context("reading config").unwrap_err();
        let outer = format!("{e}");
        assert_eq!(outer, "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        assert!(full.len() > outer.len());
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u8> = Ok(1);
        let mut called = false;
        let v = ok
            .with_context(|| {
                called = true;
                "never"
            })
            .unwrap();
        assert_eq!(v, 1);
        assert!(!called, "context closure must not run on Ok");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(3u8).context("unused").unwrap(), 3);
    }

    #[test]
    fn gvt_err_macro_builds_error() {
        let e: GvtError = gvt_err!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
    }

    #[test]
    fn source_chain_is_walkable() {
        use std::error::Error;
        let e = parse_port("x").context("outer").unwrap_err();
        let src = e.source().expect("context has a source");
        assert!(src.source().is_some(), "ParseInt wraps the std error");
    }
}
