//! Area under the ROC curve — the paper's headline metric for every
//! figure. Exact rank-based computation with the midrank tie correction,
//! matching `sklearn.metrics.roc_auc_score` semantics.

/// AUC of `scores` against binary `labels` (`true` = positive).
///
/// Returns `None` when the labels are single-class (AUC undefined).
/// `O(n log n)` via sorting; ties among scores receive midranks so that
/// constant predictors score exactly 0.5.
pub fn auc(scores: &[f64], labels: &[bool]) -> Option<f64> {
    assert_eq!(scores.len(), labels.len(), "auc: length mismatch");
    let n = scores.len();
    let pos = labels.iter().filter(|&&l| l).count();
    let neg = n - pos;
    if pos == 0 || neg == 0 {
        return None;
    }
    // Sort indices by score ascending.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).expect("NaN score in AUC"));
    // Midranks: average rank within each tie group, 1-based.
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < n {
        let mut j = i + 1;
        while j < n && scores[idx[j]] == scores[idx[i]] {
            j += 1;
        }
        // Ranks i+1 ..= j (1-based); midrank is their mean.
        let midrank = ((i + 1 + j) as f64) / 2.0;
        for &k in &idx[i..j] {
            if labels[k] {
                rank_sum_pos += midrank;
            }
        }
        i = j;
    }
    // Mann–Whitney U statistic.
    let u = rank_sum_pos - (pos * (pos + 1)) as f64 / 2.0;
    Some(u / (pos as f64 * neg as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation() {
        let scores = vec![0.1, 0.2, 0.8, 0.9];
        let labels = vec![false, false, true, true];
        assert_eq!(auc(&scores, &labels), Some(1.0));
    }

    #[test]
    fn perfectly_wrong() {
        let scores = vec![0.9, 0.8, 0.2, 0.1];
        let labels = vec![false, false, true, true];
        assert_eq!(auc(&scores, &labels), Some(0.0));
    }

    #[test]
    fn constant_scores_give_half() {
        let scores = vec![0.5; 10];
        let labels: Vec<bool> = (0..10).map(|i| i % 2 == 0).collect();
        assert_eq!(auc(&scores, &labels), Some(0.5));
    }

    #[test]
    fn single_class_undefined() {
        assert_eq!(auc(&[0.1, 0.2], &[true, true]), None);
        assert_eq!(auc(&[0.1, 0.2], &[false, false]), None);
    }

    #[test]
    fn known_small_case() {
        // scores: pos {3, 1}, neg {2, 0}. Pairs: (3>2),(3>0),(1<2),(1>0)
        // => 3 wins of 4 => 0.75.
        let scores = vec![3.0, 2.0, 1.0, 0.0];
        let labels = vec![true, false, true, false];
        assert_eq!(auc(&scores, &labels), Some(0.75));
    }

    #[test]
    fn ties_get_half_credit() {
        // pos {1.0}, neg {1.0} tie => 0.5
        let scores = vec![1.0, 1.0];
        let labels = vec![true, false];
        assert_eq!(auc(&scores, &labels), Some(0.5));
    }

    #[test]
    fn matches_naive_pairwise_count() {
        use crate::rng::{dist, Rng, Xoshiro256};
        let mut rng = Xoshiro256::seed_from(12);
        for trial in 0..20 {
            let n = 30 + trial;
            // Quantize scores to force ties.
            let scores: Vec<f64> =
                (0..n).map(|_| (rng.next_f64() * 8.0).floor() / 8.0).collect();
            let labels: Vec<bool> = (0..n).map(|_| dist::bernoulli(&mut rng, 0.4)).collect();
            if labels.iter().all(|&l| l) || labels.iter().all(|&l| !l) {
                continue;
            }
            let fast = auc(&scores, &labels).unwrap();
            // Naive O(n²): wins + half-ties.
            let mut wins = 0.0;
            let mut total = 0.0;
            for i in 0..n {
                for j in 0..n {
                    if labels[i] && !labels[j] {
                        total += 1.0;
                        if scores[i] > scores[j] {
                            wins += 1.0;
                        } else if scores[i] == scores[j] {
                            wins += 0.5;
                        }
                    }
                }
            }
            let naive = wins / total;
            assert!((fast - naive).abs() < 1e-12, "trial {trial}: {fast} vs {naive}");
        }
    }
}
