//! Regression metrics (the kernel-filling task is real-valued before
//! AUC binarization; RMSE/correlations are reported alongside).

use crate::linalg::vecops::mean;

/// Root mean squared error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let mse = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / pred.len() as f64;
    mse.sqrt()
}

/// Pearson correlation coefficient. Returns 0 for degenerate inputs.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(x), mean(y));
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Midrank vector (1-based average ranks, ties averaged).
fn midranks(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| x[a].partial_cmp(&x[b]).expect("NaN in ranks"));
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i + 1;
        while j < n && x[idx[j]] == x[idx[i]] {
            j += 1;
        }
        let mid = ((i + 1 + j) as f64) / 2.0;
        for &k in &idx[i..j] {
            ranks[k] = mid;
        }
        i = j;
    }
    ranks
}

/// Spearman rank correlation (Pearson on midranks).
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&midranks(x), &midranks(y))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_zero_for_equal() {
        let x = vec![1.0, -2.0, 3.5];
        assert_eq!(rmse(&x, &x), 0.0);
    }

    #[test]
    fn rmse_known() {
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_linear() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v - 7.0).collect();
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yn: Vec<f64> = x.iter().map(|v| -2.0 * v).collect();
        assert!((pearson(&x, &yn) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let y: Vec<f64> = x.iter().map(|v| f64::exp(*v)).collect();
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }
}
