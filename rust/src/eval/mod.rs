//! Evaluation metrics and fold aggregation.

mod auc;
mod metrics;
mod stats;

pub use auc::auc;
pub use metrics::{pearson, rmse, spearman};
pub use stats::FoldStats;
