//! Aggregation of per-fold metrics into the mean ± std numbers every figure
//! in the paper reports.

use crate::linalg::vecops::{mean, std_dev};

/// Accumulates one metric across cross-validation folds.
#[derive(Clone, Debug, Default)]
pub struct FoldStats {
    values: Vec<f64>,
}

impl FoldStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one fold's metric value. `None` (e.g. undefined AUC on a
    /// single-class fold) is skipped but counted.
    pub fn push(&mut self, value: impl Into<Option<f64>>) {
        if let Some(v) = value.into() {
            self.values.push(v);
        }
    }

    /// Number of recorded (defined) folds.
    pub fn count(&self) -> usize {
        self.values.len()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.values)
    }

    pub fn std(&self) -> f64 {
        std_dev(&self.values)
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// `"0.873 ± 0.021"` formatting used by the report tables.
    pub fn format(&self) -> String {
        if self.values.is_empty() {
            "n/a".to_string()
        } else {
            format!("{:.3} ± {:.3}", self.mean(), self.std())
        }
    }

    /// Raw values (for CSV emission).
    pub fn values(&self) -> &[f64] {
        &self.values
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut s = FoldStats::new();
        for v in [0.8, 0.9, 1.0] {
            s.push(v);
        }
        s.push(None);
        assert_eq!(s.count(), 3);
        assert!((s.mean() - 0.9).abs() < 1e-12);
        assert_eq!(s.min(), 0.8);
        assert_eq!(s.max(), 1.0);
        assert!(s.format().starts_with("0.900"));
    }

    #[test]
    fn empty_formats_na() {
        assert_eq!(FoldStats::new().format(), "n/a");
    }
}
