//! The `O(n n̄)` explicit baseline: pairwise kernel matrices materialized
//! entry-by-entry from the Table 3 closed forms.
//!
//! This is deliberately an **independent implementation** of the kernel
//! semantics (no Kronecker-term machinery): it is the oracle that the GVT
//! path is validated against in `rust/tests/gvt_vs_explicit.rs`, and the
//! baseline method whose `O(n²)` time/memory blow-up Figure 7 documents.

use crate::gvt::pairwise::PairwiseKernel;
use crate::linalg::{par, Mat};
use crate::solvers::linear_op::LinOp;
use crate::sparse::PairIndex;

/// Evaluate one pairwise kernel entry from the Table 3 closed forms.
///
/// For heterogeneous kernels the pair is (drug, target); for homogeneous
/// kernels the pair is (d, d') and both index the drug kernel `d`.
pub fn kernel_entry(
    kernel: PairwiseKernel,
    d: &Mat,
    t: &Mat,
    row: (usize, usize),
    col: (usize, usize),
) -> f64 {
    let (rd, rt) = row;
    let (cd, ct) = col;
    match kernel {
        PairwiseKernel::Linear => d[(rd, cd)] + t[(rt, ct)],
        PairwiseKernel::Poly2D => {
            let s = d[(rd, cd)] + t[(rt, ct)];
            s * s
        }
        PairwiseKernel::Kronecker => d[(rd, cd)] * t[(rt, ct)],
        PairwiseKernel::Cartesian => {
            let mut v = 0.0;
            if rt == ct {
                v += d[(rd, cd)];
            }
            if rd == cd {
                v += t[(rt, ct)];
            }
            v
        }
        // Homogeneous kernels: slots (d, d') over the drug kernel.
        PairwiseKernel::Symmetric => d[(rd, cd)] * d[(rt, ct)] + d[(rd, ct)] * d[(rt, cd)],
        PairwiseKernel::AntiSymmetric => {
            d[(rd, cd)] * d[(rt, ct)] - d[(rd, ct)] * d[(rt, cd)]
        }
        PairwiseKernel::Ranking => {
            d[(rd, cd)] - d[(rd, ct)] - d[(rt, cd)] + d[(rt, ct)]
        }
        PairwiseKernel::Mlpk => {
            let r = d[(rd, cd)] - d[(rd, ct)] - d[(rt, cd)] + d[(rt, ct)];
            r * r
        }
    }
}

/// Materialize the full `n̄ × n` pairwise kernel matrix
/// `K[i,j] = k((d̄_i, t̄_i), (d_j, t_j))`. `O(n̄ n)` time and memory — this
/// is exactly the cost the GVT path avoids.
pub fn explicit_matrix(
    kernel: PairwiseKernel,
    d: &Mat,
    t: &Mat,
    rows: &PairIndex,
    cols: &PairIndex,
) -> Mat {
    let nbar = rows.len();
    let n = cols.len();
    let mut k = Mat::zeros(nbar, n);
    let kdata = k.as_mut_slice();
    par::parallel_fill_rows(kdata, n.max(1), 4 * n.max(1), |start_flat, _end, chunk| {
        let i0 = start_flat / n;
        let rows_here = chunk.len() / n;
        for r in 0..rows_here {
            let i = i0 + r;
            let row = (rows.drug(i), rows.target(i));
            let out = &mut chunk[r * n..(r + 1) * n];
            for (j, o) in out.iter_mut().enumerate() {
                *o = kernel_entry(kernel, d, t, row, (cols.drug(j), cols.target(j)));
            }
        }
    });
    k
}

/// The baseline operator: a materialized kernel matrix with dense mat-vec.
/// Implements [`LinOp`] so the same MINRES driver runs both methods —
/// mirroring the paper's setup where "these two methods are identical
/// except for the calculation of the matrix vector products".
pub struct ExplicitLinOp {
    k: Mat,
}

impl ExplicitLinOp {
    /// Materialize the kernel matrix for the given samples.
    pub fn new(
        kernel: PairwiseKernel,
        d: &Mat,
        t: &Mat,
        rows: &PairIndex,
        cols: &PairIndex,
    ) -> Self {
        Self { k: explicit_matrix(kernel, d, t, rows, cols) }
    }

    pub fn matrix(&self) -> &Mat {
        &self.k
    }

    /// Bytes held by the materialized matrix (Fig 7 memory series).
    pub fn memory_bytes(&self) -> usize {
        self.k.rows() * self.k.cols() * std::mem::size_of::<f64>()
    }
}

impl LinOp for ExplicitLinOp {
    fn dim_out(&self) -> usize {
        self.k.rows()
    }

    fn dim_in(&self) -> usize {
        self.k.cols()
    }

    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        let r = self.k.matvec(x);
        y.copy_from_slice(&r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{dist, Xoshiro256};
    use crate::testing::gen;

    #[test]
    fn explicit_training_matrix_symmetric_and_psd_diag() {
        let mut rng = Xoshiro256::seed_from(50);
        let m = 6;
        let d = gen::psd_kernel(&mut rng, m);
        let s = gen::homogeneous_sample(&mut rng, 20, m);
        for kernel in PairwiseKernel::ALL {
            let k = explicit_matrix(kernel, &d, &d, &s, &s);
            assert!(k.is_symmetric(1e-10), "{kernel:?} not symmetric");
            if !matches!(kernel, PairwiseKernel::AntiSymmetric | PairwiseKernel::Linear) {
                // PSD kernels (except linear, whose diagonal can still be
                // negative only if base kernels were; with PSD base kernels
                // diagonals are nonneg too — anti-symmetric diag is 0-ish).
                for i in 0..20 {
                    assert!(k[(i, i)] >= -1e-10, "{kernel:?} diag {}", k[(i, i)]);
                }
            }
        }
    }

    #[test]
    fn kronecker_entry_is_product() {
        let mut rng = Xoshiro256::seed_from(51);
        let d = gen::psd_kernel(&mut rng, 4);
        let t = gen::psd_kernel(&mut rng, 5);
        let v = kernel_entry(PairwiseKernel::Kronecker, &d, &t, (1, 2), (3, 4));
        assert_eq!(v, d[(1, 3)] * t[(2, 4)]);
    }

    #[test]
    fn linop_matches_matrix_product() {
        let mut rng = Xoshiro256::seed_from(52);
        let m = 5;
        let d = gen::psd_kernel(&mut rng, m);
        let s = gen::homogeneous_sample(&mut rng, 15, m);
        let op = ExplicitLinOp::new(PairwiseKernel::Symmetric, &d, &d, &s, &s);
        let a = dist::normal_vec(&mut rng, 15);
        let y = op.apply(&a);
        let y2 = op.matrix().matvec(&a);
        assert_eq!(y, y2);
        assert_eq!(op.memory_bytes(), 15 * 15 * 8);
    }

    #[test]
    fn mlpk_is_ranking_squared() {
        let mut rng = Xoshiro256::seed_from(53);
        let d = gen::psd_kernel(&mut rng, 6);
        for _ in 0..50 {
            use crate::rng::Rng;
            let row = (rng.index(6), rng.index(6));
            let col = (rng.index(6), rng.index(6));
            let r = kernel_entry(PairwiseKernel::Ranking, &d, &d, row, col);
            let m = kernel_entry(PairwiseKernel::Mlpk, &d, &d, row, col);
            assert!((m - r * r).abs() < 1e-12);
        }
    }
}
