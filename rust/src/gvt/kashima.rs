//! The Kashima et al. (2009b) Cartesian-kernel shortcut — the prior art
//! §4.8 improves on.
//!
//! Kashima showed the Cartesian kernel is a Kronecker *sum*
//! `D ⊗ I + I ⊗ T`, so on complete data the classic vec trick (Roth 1934)
//! gives the mat-vec as two dense products on the coefficient matrix
//! `W ∈ R^{q×m}` (`W[t_j, d_j] += a_j`):
//!
//! ```text
//! S = W Dᵀ + T W          — O(m²q + q²m)
//! p_i = S[t̄_i, d̄_i]
//! ```
//!
//! independent of `n`. The paper's GVT formulation instead exploits the
//! `I` factors sparsely at `O(n + n̄·(m + q))` — cheaper whenever the
//! sample is sparse (`n ≪ q·m`). `bench_perf_ablation` races the two;
//! the crossover is the paper's "In this work, we improve on this
//! result."

use crate::linalg::Mat;
use crate::sparse::PairIndex;

/// Cartesian-kernel mat-vec via the Kashima Kronecker-sum vec trick.
///
/// Requires both samples over the same domains as `d`/`t` (like the GVT
/// path). Cost `O(n + m²q + q²m + n̄)`.
pub fn cartesian_matvec_kashima(
    d: &Mat,
    t: &Mat,
    rows: &PairIndex,
    cols: &PairIndex,
    a: &[f64],
) -> Vec<f64> {
    assert_eq!(a.len(), cols.len());
    assert_eq!(d.rows(), rows.m());
    assert_eq!(d.cols(), cols.m());
    assert_eq!(t.rows(), rows.q());
    assert_eq!(t.cols(), cols.q());
    let (m_c, q_c) = (d.cols(), t.cols());

    // Scatter coefficients onto the complete grid.
    let mut w = Mat::zeros(q_c, m_c);
    for j in 0..a.len() {
        w[(cols.target(j), cols.drug(j))] += a[j];
    }

    // S = W Dᵀ + T W  (q_r × m_r with rectangular D/T handled by the
    // matmul shapes: W Dᵀ is q_c × m_r — for the training case all
    // domains coincide; for cross products the paper's Cartesian kernel
    // is Setting-1-only anyway).
    let mut s = w.matmul_nt(d); // (q_c × m_r) = W · Dᵀ
    let tw = t.matmul(&w); // (q_r × m_c)
    // Both terms are only conformable when domains match (square case).
    assert_eq!(s.shape(), tw.shape(), "Kashima shortcut needs matching domains");
    s.axpy(1.0, &tw);

    (0..rows.len()).map(|i| s[(rows.target(i), rows.drug(i))]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvt::explicit::explicit_matrix;
    use crate::gvt::pairwise::PairwiseKernel;
    use crate::rng::{dist, Xoshiro256};
    use crate::testing::gen;

    #[test]
    fn matches_explicit_cartesian_matrix() {
        let mut rng = Xoshiro256::seed_from(310);
        for (m, q, n, nbar) in [(5, 7, 40, 25), (8, 8, 64, 64)] {
            let d = gen::psd_kernel(&mut rng, m);
            let t = gen::psd_kernel(&mut rng, q);
            let cols = gen::pair_sample(&mut rng, n, m, q);
            let rows = gen::pair_sample(&mut rng, nbar, m, q);
            let a = dist::normal_vec(&mut rng, n);
            let fast = cartesian_matvec_kashima(&d, &t, &rows, &cols, &a);
            let k = explicit_matrix(PairwiseKernel::Cartesian, &d, &t, &rows, &cols);
            let slow = k.matvec(&a);
            let err = crate::linalg::vecops::max_abs_diff(&fast, &slow);
            assert!(err < 1e-9, "({m},{q},{n}): err {err}");
        }
    }

    #[test]
    fn matches_gvt_cartesian_path() {
        use crate::gvt::pairwise::PairwiseLinOp;
        use crate::gvt::vec_trick::GvtPolicy;
        use std::sync::Arc;
        let mut rng = Xoshiro256::seed_from(311);
        let (m, q, n) = (6, 9, 50);
        let d = Arc::new(gen::psd_kernel(&mut rng, m));
        let t = Arc::new(gen::psd_kernel(&mut rng, q));
        let s = gen::pair_sample(&mut rng, n, m, q);
        let a = dist::normal_vec(&mut rng, n);
        let op = PairwiseLinOp::new(
            PairwiseKernel::Cartesian,
            d.clone(),
            t.clone(),
            s.clone(),
            s.clone(),
            GvtPolicy::Auto,
        )
        .unwrap();
        let p1 = op.matvec(&a);
        let p2 = cartesian_matvec_kashima(&d, &t, &s, &s, &a);
        let err = crate::linalg::vecops::max_abs_diff(&p1, &p2);
        assert!(err < 1e-9, "err {err}");
    }
}
