//! The paper's contribution: the generalized vec trick and the operator
//! framework expressing pairwise kernels as sums of Kronecker products.
//!
//! * [`vec_trick`] — Theorem 1: `p = R(d̄,t̄)(A ⊗ B)R(d,t)ᵀ a` in
//!   `O(min(q̄n + mn̄, m̄n + qn̄))`, with a dense scatter-GEMM-gather variant
//!   (the formulation the JAX/Pallas artifact implements) and fast paths
//!   for `1` (all-ones) and `I` factors.
//! * [`terms`] — the operator algebra of Definition 1 / Theorem 2:
//!   commutation `P` and unification `Q` act on samples as index plumbing,
//!   so every pairwise kernel is a list of [`terms::KroneckerTerm`]s.
//! * [`plan`] — compiled multi-term execution plans: stage-1/stage-2 work
//!   shared across Kronecker terms, CSR-grouped stage 1, reusable
//!   workspaces (zero allocation per solver iteration), and the
//!   multi-RHS [`plan::gvt_matmat`] block product.
//! * [`pairwise`] — Corollary 1: the eight pairwise kernels as term sums,
//!   and [`pairwise::PairwiseLinOp`], the `K`-as-linear-operator used by
//!   the iterative solvers.
//! * [`explicit`] — the `O(n n̄)` explicit kernel matrices computed straight
//!   from the Table 3 closed forms: the baseline method of §6 and the
//!   oracle every GVT path is tested against.

pub mod explicit;
pub mod kashima;
pub mod pairwise;
pub mod plan;
pub mod tensor;
pub mod terms;
pub mod vec_trick;

pub use pairwise::{PairwiseKernel, PairwiseLinOp};
pub use plan::{gvt_matmat, GvtPlan, GvtWorkspace};
pub use terms::{Factor, IndexMap, KroneckerTerm};
pub use vec_trick::{gvt_matvec, GvtPolicy};
