//! Corollary 1 — the nine pairwise kernels as Kronecker-term sums, and the
//! linear-operator form consumed by the iterative solvers.
//!
//! Term derivations (`R(d,t)P = R(t,d)`, `R(d,t)Q = R(d,d)`,
//! `Q(D⊗D)Qᵀ = D^{⊙2} ⊗ 1`):
//!
//! * **Linear** `D⊗1 + 1⊗T` — 2 terms, both on the pooled fast path.
//! * **Poly2D** `Q(D⊗D)Qᵀ + 2·D⊗T + PQ(T⊗T)QᵀPᵀ
//!   = D^{⊙2}⊗1 + 2·D⊗T + 1⊗T^{⊙2}` — 3 terms.
//! * **Kronecker** `D⊗T` — 1 term.
//! * **Cartesian** `D⊗I + I⊗T` — 2 terms on the scatter fast path.
//! * **Symmetric** `(I + P)(D⊗D)` — 2 terms.
//! * **Anti-symmetric** `(I − P)(D⊗D)` — 2 terms. (The paper's Corollary 1
//!   table prints `(P − I)(D⊗D)`, which contradicts its own Table 3 /
//!   feature map by a global sign; we implement the Table 3 semantics —
//!   the PSD one — and pin it with the explicit-matrix oracle tests.)
//! * **Ranking** `(I − P)(D⊗1)(I − P)` — 4 terms, all pooled fast path.
//! * **MLPK** `(I+P)(I−Q)(D⊗D)(I−Q)ᵀ(I+P)` — expanding the square of the
//!   ranking kernel gives 16 products; the 4 squared terms collapse onto
//!   `D^{⊙2}⊗1` fast paths and the 12 cross terms merge pairwise by
//!   symmetry of the scalar product, leaving **10 summands** (matching the
//!   paper's count in §6.4).

use crate::error::{bail, Result};
use crate::gvt::terms::{Factor, IndexMap, KroneckerTerm, TermContext};
use crate::gvt::vec_trick::GvtPolicy;
use crate::linalg::Mat;
use crate::solvers::linear_op::LinOp;
use crate::sparse::PairIndex;
use std::sync::Arc;

use Factor::{DSq, Identity, Ones, TSq, D, T};
use IndexMap::{DupDrug, DupTarget, Id, Swap};

/// The pairwise kernels of Table 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PairwiseKernel {
    /// `k_D(d,d̄) + k_T(t,t̄)` — no drug–target interactions.
    Linear,
    /// `(k_D + k_T)²` — self + pairwise interactions.
    Poly2D,
    /// `k_D · k_T` — pure pairwise interactions (Ben-Hur & Noble 2005).
    Kronecker,
    /// `k_D·δ(t=t̄) + δ(d=d̄)·k_T` — Setting-1-only kernel (Kashima 2009).
    Cartesian,
    /// Symmetrized Kronecker over a homogeneous domain.
    Symmetric,
    /// Anti-symmetrized Kronecker over a homogeneous domain.
    AntiSymmetric,
    /// `k_D(d,d̄) − k_D(d,d̄') − k_D(d',d̄) + k_D(d',d̄')` (Herbrich 2000).
    Ranking,
    /// Metric-learning pairwise kernel: ranking kernel squared (Vert 2007).
    Mlpk,
}

impl PairwiseKernel {
    /// All kernels, in the paper's presentation order.
    pub const ALL: [PairwiseKernel; 8] = [
        PairwiseKernel::Linear,
        PairwiseKernel::Poly2D,
        PairwiseKernel::Kronecker,
        PairwiseKernel::Cartesian,
        PairwiseKernel::Symmetric,
        PairwiseKernel::AntiSymmetric,
        PairwiseKernel::Ranking,
        PairwiseKernel::Mlpk,
    ];

    /// Kernels applicable to heterogeneous (drug ≠ target) domains
    /// (Table 4's middle column).
    pub fn supports_heterogeneous(&self) -> bool {
        matches!(
            self,
            PairwiseKernel::Linear
                | PairwiseKernel::Poly2D
                | PairwiseKernel::Kronecker
                | PairwiseKernel::Cartesian
        )
    }

    /// Does the kernel need `D^{⊙2}` / `T^{⊙2}` precomputed?
    pub fn needs_squares(&self) -> bool {
        self.terms()
            .iter()
            .any(|t| matches!(t.left, DSq | TSq) || matches!(t.right, DSq | TSq))
    }

    pub fn name(&self) -> &'static str {
        match self {
            PairwiseKernel::Linear => "linear",
            PairwiseKernel::Poly2D => "poly2d",
            PairwiseKernel::Kronecker => "kronecker",
            PairwiseKernel::Cartesian => "cartesian",
            PairwiseKernel::Symmetric => "symmetric",
            PairwiseKernel::AntiSymmetric => "antisymmetric",
            PairwiseKernel::Ranking => "ranking",
            PairwiseKernel::Mlpk => "mlpk",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Some(Self::Linear),
            "poly2d" | "poly" | "polynomial" => Some(Self::Poly2D),
            "kronecker" | "kron" => Some(Self::Kronecker),
            "cartesian" => Some(Self::Cartesian),
            "symmetric" | "sym" => Some(Self::Symmetric),
            "antisymmetric" | "anti" | "anti-symmetric" => Some(Self::AntiSymmetric),
            "ranking" | "rank" => Some(Self::Ranking),
            "mlpk" => Some(Self::Mlpk),
            _ => None,
        }
    }

    /// The Corollary 1 decomposition into Kronecker terms.
    pub fn terms(&self) -> Vec<KroneckerTerm> {
        use KroneckerTerm as KT;
        match self {
            PairwiseKernel::Linear => vec![
                KT::new(1.0, D, Ones, Id, Id),
                KT::new(1.0, Ones, T, Id, Id),
            ],
            PairwiseKernel::Poly2D => vec![
                KT::new(1.0, DSq, Ones, Id, Id),
                KT::new(2.0, D, T, Id, Id),
                KT::new(1.0, Ones, TSq, Id, Id),
            ],
            PairwiseKernel::Kronecker => vec![KT::new(1.0, D, T, Id, Id)],
            PairwiseKernel::Cartesian => vec![
                KT::new(1.0, D, Identity, Id, Id),
                KT::new(1.0, Identity, T, Id, Id),
            ],
            PairwiseKernel::Symmetric => vec![
                KT::new(1.0, D, D, Id, Id),
                KT::new(1.0, D, D, Swap, Id),
            ],
            PairwiseKernel::AntiSymmetric => vec![
                KT::new(1.0, D, D, Id, Id),
                KT::new(-1.0, D, D, Swap, Id),
            ],
            PairwiseKernel::Ranking => vec![
                KT::new(1.0, D, Ones, Id, Id),
                KT::new(-1.0, D, Ones, Swap, Id),
                KT::new(-1.0, D, Ones, Id, Swap),
                KT::new(1.0, D, Ones, Swap, Swap),
            ],
            // MLPK: k = (r1 − r2 − r3 + r4)² with r1=D[d,d̄], r2=D[d,d̄'],
            // r3=D[d',d̄], r4=D[d',d̄']. Squares → D^{⊙2}⊗1 terms; cross
            // terms (u,v)+(v,u) merge with coefficient ±2.
            PairwiseKernel::Mlpk => vec![
                // Squared terms.
                KT::new(1.0, DSq, Ones, Id, Id),      // r1²
                KT::new(1.0, DSq, Ones, Id, Swap),    // r2²
                KT::new(1.0, DSq, Ones, Swap, Id),    // r3²
                KT::new(1.0, DSq, Ones, Swap, Swap),  // r4²
                // Cross terms (sign = s_u·s_v·2, s = (+,−,−,+)).
                KT::new(-2.0, D, D, DupDrug, Id),     // r1·r2
                KT::new(-2.0, D, D, Id, DupDrug),     // r1·r3
                KT::new(2.0, D, D, Id, Id),           // r1·r4
                KT::new(2.0, D, D, Id, Swap),         // r2·r3
                KT::new(-2.0, D, D, Id, DupTarget),   // r2·r4
                KT::new(-2.0, D, D, DupTarget, Id),   // r3·r4
            ],
        }
    }
}

/// A pairwise kernel as a linear operator `a ↦ R_rows K R_colsᵀ a`,
/// evaluated term-by-term with the generalized vec trick.
///
/// `d`/`t` are kernel matrices over the **full object domains** (all drugs
/// observed anywhere, all targets observed anywhere); `rows` and `cols`
/// index into those shared domains, so the same op covers the training
/// kernel matrix (`rows == cols == train`), validation predictions and
/// test predictions (rows = the prediction sample).
pub struct PairwiseLinOp {
    kernel: PairwiseKernel,
    d: Arc<Mat>,
    t: Arc<Mat>,
    dsq: Option<Mat>,
    tsq: Option<Mat>,
    rows: PairIndex,
    cols: PairIndex,
    policy: GvtPolicy,
    /// Terms with their index transforms pre-applied (§Perf: applying
    /// `P`/`Q` per mat-vec cloned full index vectors every iteration).
    terms: Vec<(KroneckerTerm, PairIndex, PairIndex)>,
}

impl PairwiseLinOp {
    /// Build the operator. For homogeneous kernels (Symmetric,
    /// AntiSymmetric, Ranking, MLPK) pass the same matrix as `d` and `t`
    /// and samples with `m == q`.
    pub fn new(
        kernel: PairwiseKernel,
        d: Arc<Mat>,
        t: Arc<Mat>,
        rows: PairIndex,
        cols: PairIndex,
        policy: GvtPolicy,
    ) -> Result<Self> {
        if d.rows() != rows.m() || d.cols() != cols.m() {
            bail!(
                "drug kernel is {}x{} but samples have drug domains {}/{}",
                d.rows(),
                d.cols(),
                rows.m(),
                cols.m()
            );
        }
        if t.rows() != rows.q() || t.cols() != cols.q() {
            bail!(
                "target kernel is {}x{} but samples have target domains {}/{}",
                t.rows(),
                t.cols(),
                rows.q(),
                cols.q()
            );
        }
        if !kernel.supports_heterogeneous() {
            // Homogeneous kernels: both slots must share one domain.
            if rows.m() != rows.q() || cols.m() != cols.q() {
                bail!(
                    "{} requires a homogeneous domain (m == q), got {}x{} / {}x{}",
                    kernel.name(),
                    rows.m(),
                    rows.q(),
                    cols.m(),
                    cols.q()
                );
            }
        }
        let needs_sq = kernel.needs_squares();
        let dsq = needs_sq.then(|| d.hadamard_square());
        let tsq = needs_sq.then(|| t.hadamard_square());
        // Pre-apply the P/Q index transforms once (identical transforms
        // share nothing here — at ≤10 terms the duplication is trivial,
        // and each term owning its samples keeps the hot loop branch-free).
        let terms = kernel
            .terms()
            .into_iter()
            .map(|term| {
                let r = term.row_map.apply(&rows);
                let c = term.col_map.apply(&cols);
                (term, r, c)
            })
            .collect();
        Ok(Self { kernel, d, t, dsq, tsq, rows, cols, policy, terms })
    }

    pub fn kernel(&self) -> PairwiseKernel {
        self.kernel
    }

    pub fn rows(&self) -> &PairIndex {
        &self.rows
    }

    pub fn cols(&self) -> &PairIndex {
        &self.cols
    }

    /// Number of Kronecker summands (the constant factor of Fig 7's
    /// per-kernel runtime differences).
    pub fn term_count(&self) -> usize {
        self.terms.len()
    }

    fn ctx(&self) -> TermContext<'_> {
        TermContext {
            d: &self.d,
            t: &self.t,
            dsq: self.dsq.as_ref(),
            tsq: self.tsq.as_ref(),
        }
    }

    /// `out = Σ_terms coeff · GVT(term)` — the `O(nm + nq)` product.
    pub fn matvec_into(&self, a: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.rows.len());
        out.fill(0.0);
        let ctx = self.ctx();
        for (term, rows_t, cols_t) in &self.terms {
            term.matvec_transformed(&ctx, rows_t, cols_t, a, self.policy, out);
        }
    }

    /// Allocating wrapper over [`Self::matvec_into`].
    pub fn matvec(&self, a: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows.len()];
        self.matvec_into(a, &mut out);
        out
    }

    /// Single kernel entry via the term decomposition (`O(terms)`), used
    /// by tests; the explicit oracle in [`crate::gvt::explicit`] computes
    /// the same value from the Table 3 closed forms independently.
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        let ctx = self.ctx();
        let row = (self.rows.drug(i), self.rows.target(i));
        let col = (self.cols.drug(j), self.cols.target(j));
        self.terms.iter().map(|(t, _, _)| t.entry(&ctx, row, col)).sum()
    }
}

impl LinOp for PairwiseLinOp {
    fn dim_out(&self) -> usize {
        self.rows.len()
    }

    fn dim_in(&self) -> usize {
        self.cols.len()
    }

    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_into(x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{dist, Xoshiro256};
    use crate::testing::gen;

    #[test]
    fn term_counts_match_paper() {
        assert_eq!(PairwiseKernel::Kronecker.terms().len(), 1);
        assert_eq!(PairwiseKernel::Linear.terms().len(), 2);
        assert_eq!(PairwiseKernel::Poly2D.terms().len(), 3);
        assert_eq!(PairwiseKernel::Cartesian.terms().len(), 2);
        assert_eq!(PairwiseKernel::Symmetric.terms().len(), 2);
        assert_eq!(PairwiseKernel::AntiSymmetric.terms().len(), 2);
        assert_eq!(PairwiseKernel::Ranking.terms().len(), 4);
        // "the MLPK slowest because it has 10 such terms" — §6.4.
        assert_eq!(PairwiseKernel::Mlpk.terms().len(), 10);
    }

    #[test]
    fn heterogeneous_support_matches_table4() {
        use PairwiseKernel::*;
        for k in [Linear, Poly2D, Kronecker, Cartesian] {
            assert!(k.supports_heterogeneous(), "{k:?}");
        }
        for k in [Symmetric, AntiSymmetric, Ranking, Mlpk] {
            assert!(!k.supports_heterogeneous(), "{k:?}");
        }
    }

    #[test]
    fn homogeneous_kernel_rejects_heterogeneous_sample() {
        let mut rng = Xoshiro256::seed_from(40);
        let d = Arc::new(gen::psd_kernel(&mut rng, 4));
        let t = Arc::new(gen::psd_kernel(&mut rng, 3));
        let s = gen::pair_sample(&mut rng, 10, 4, 3);
        let r = PairwiseLinOp::new(
            PairwiseKernel::Symmetric,
            d,
            t,
            s.clone(),
            s,
            GvtPolicy::Auto,
        );
        assert!(r.is_err());
    }

    #[test]
    fn training_matrix_is_symmetric_operator() {
        // <Ka, b> == <a, Kb> on the training sample for every kernel.
        let mut rng = Xoshiro256::seed_from(41);
        let m = 7;
        let d = Arc::new(gen::psd_kernel(&mut rng, m));
        let s = gen::homogeneous_sample(&mut rng, 30, m);
        for kernel in PairwiseKernel::ALL {
            let op = PairwiseLinOp::new(
                kernel,
                d.clone(),
                d.clone(),
                s.clone(),
                s.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let a = dist::normal_vec(&mut rng, 30);
            let b = dist::normal_vec(&mut rng, 30);
            let ka = op.matvec(&a);
            let kb = op.matvec(&b);
            let lhs: f64 = ka.iter().zip(&b).map(|(x, y)| x * y).sum();
            let rhs: f64 = a.iter().zip(&kb).map(|(x, y)| x * y).sum();
            assert!(
                (lhs - rhs).abs() < 1e-8 * lhs.abs().max(1.0),
                "{kernel:?}: {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn parse_roundtrip() {
        for k in PairwiseKernel::ALL {
            assert_eq!(PairwiseKernel::parse(k.name()), Some(k));
        }
    }
}
