//! Corollary 1 — the eight pairwise kernels as Kronecker-term sums, and the
//! linear-operator form consumed by the iterative solvers.
//!
//! Term derivations (`R(d,t)P = R(t,d)`, `R(d,t)Q = R(d,d)`,
//! `Q(D⊗D)Qᵀ = D^{⊙2} ⊗ 1`):
//!
//! * **Linear** `D⊗1 + 1⊗T` — 2 terms, both on the pooled fast path.
//! * **Poly2D** `Q(D⊗D)Qᵀ + 2·D⊗T + PQ(T⊗T)QᵀPᵀ
//!   = D^{⊙2}⊗1 + 2·D⊗T + 1⊗T^{⊙2}` — 3 terms.
//! * **Kronecker** `D⊗T` — 1 term.
//! * **Cartesian** `D⊗I + I⊗T` — 2 terms on the scatter fast path.
//! * **Symmetric** `(I + P)(D⊗D)` — 2 terms.
//! * **Anti-symmetric** `(I − P)(D⊗D)` — 2 terms. (The paper's Corollary 1
//!   table prints `(P − I)(D⊗D)`, which contradicts its own Table 3 /
//!   feature map by a global sign; we implement the Table 3 semantics —
//!   the PSD one — and pin it with the explicit-matrix oracle tests.)
//! * **Ranking** `(I − P)(D⊗1)(I − P)` — 4 terms, all pooled fast path.
//! * **MLPK** `(I+P)(I−Q)(D⊗D)(I−Q)ᵀ(I+P)` — expanding the square of the
//!   ranking kernel gives 16 products; the 4 squared terms collapse onto
//!   `D^{⊙2}⊗1` fast paths and the 12 cross terms merge pairwise by
//!   symmetry of the scalar product, leaving **10 summands** (matching the
//!   paper's count in §6.4).

use crate::error::{bail, Result};
use crate::gvt::plan::{fusion_disabled, GvtPlan, GvtWorkspace};
use crate::gvt::terms::{Factor, IndexMap, KroneckerTerm, TermContext};
use crate::gvt::vec_trick::GvtPolicy;
use crate::linalg::Mat;
use crate::solvers::linear_op::LinOp;
use crate::sparse::PairIndex;
use std::sync::{Arc, Mutex};

use Factor::{DSq, Identity, Ones, TSq, D, T};
use IndexMap::{DupDrug, DupTarget, Id, Swap};

/// The pairwise kernels of Table 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PairwiseKernel {
    /// `k_D(d,d̄) + k_T(t,t̄)` — no drug–target interactions.
    Linear,
    /// `(k_D + k_T)²` — self + pairwise interactions.
    Poly2D,
    /// `k_D · k_T` — pure pairwise interactions (Ben-Hur & Noble 2005).
    Kronecker,
    /// `k_D·δ(t=t̄) + δ(d=d̄)·k_T` — Setting-1-only kernel (Kashima 2009).
    Cartesian,
    /// Symmetrized Kronecker over a homogeneous domain.
    Symmetric,
    /// Anti-symmetrized Kronecker over a homogeneous domain.
    AntiSymmetric,
    /// `k_D(d,d̄) − k_D(d,d̄') − k_D(d',d̄) + k_D(d',d̄')` (Herbrich 2000).
    Ranking,
    /// Metric-learning pairwise kernel: ranking kernel squared (Vert 2007).
    Mlpk,
}

impl PairwiseKernel {
    /// All kernels, in the paper's presentation order.
    pub const ALL: [PairwiseKernel; 8] = [
        PairwiseKernel::Linear,
        PairwiseKernel::Poly2D,
        PairwiseKernel::Kronecker,
        PairwiseKernel::Cartesian,
        PairwiseKernel::Symmetric,
        PairwiseKernel::AntiSymmetric,
        PairwiseKernel::Ranking,
        PairwiseKernel::Mlpk,
    ];

    /// Kernels applicable to heterogeneous (drug ≠ target) domains
    /// (Table 4's middle column).
    pub fn supports_heterogeneous(&self) -> bool {
        matches!(
            self,
            PairwiseKernel::Linear
                | PairwiseKernel::Poly2D
                | PairwiseKernel::Kronecker
                | PairwiseKernel::Cartesian
        )
    }

    /// Does the kernel need `D^{⊙2}` / `T^{⊙2}` precomputed?
    pub fn needs_squares(&self) -> bool {
        self.terms()
            .iter()
            .any(|t| matches!(t.left, DSq | TSq) || matches!(t.right, DSq | TSq))
    }

    pub fn name(&self) -> &'static str {
        match self {
            PairwiseKernel::Linear => "linear",
            PairwiseKernel::Poly2D => "poly2d",
            PairwiseKernel::Kronecker => "kronecker",
            PairwiseKernel::Cartesian => "cartesian",
            PairwiseKernel::Symmetric => "symmetric",
            PairwiseKernel::AntiSymmetric => "antisymmetric",
            PairwiseKernel::Ranking => "ranking",
            PairwiseKernel::Mlpk => "mlpk",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Some(Self::Linear),
            "poly2d" | "poly" | "polynomial" => Some(Self::Poly2D),
            "kronecker" | "kron" => Some(Self::Kronecker),
            "cartesian" => Some(Self::Cartesian),
            "symmetric" | "sym" => Some(Self::Symmetric),
            "antisymmetric" | "anti" | "anti-symmetric" => Some(Self::AntiSymmetric),
            "ranking" | "rank" => Some(Self::Ranking),
            "mlpk" => Some(Self::Mlpk),
            _ => None,
        }
    }

    /// The Corollary 1 decomposition into Kronecker terms.
    pub fn terms(&self) -> Vec<KroneckerTerm> {
        use KroneckerTerm as KT;
        match self {
            PairwiseKernel::Linear => vec![
                KT::new(1.0, D, Ones, Id, Id),
                KT::new(1.0, Ones, T, Id, Id),
            ],
            PairwiseKernel::Poly2D => vec![
                KT::new(1.0, DSq, Ones, Id, Id),
                KT::new(2.0, D, T, Id, Id),
                KT::new(1.0, Ones, TSq, Id, Id),
            ],
            PairwiseKernel::Kronecker => vec![KT::new(1.0, D, T, Id, Id)],
            PairwiseKernel::Cartesian => vec![
                KT::new(1.0, D, Identity, Id, Id),
                KT::new(1.0, Identity, T, Id, Id),
            ],
            PairwiseKernel::Symmetric => vec![
                KT::new(1.0, D, D, Id, Id),
                KT::new(1.0, D, D, Swap, Id),
            ],
            PairwiseKernel::AntiSymmetric => vec![
                KT::new(1.0, D, D, Id, Id),
                KT::new(-1.0, D, D, Swap, Id),
            ],
            PairwiseKernel::Ranking => vec![
                KT::new(1.0, D, Ones, Id, Id),
                KT::new(-1.0, D, Ones, Swap, Id),
                KT::new(-1.0, D, Ones, Id, Swap),
                KT::new(1.0, D, Ones, Swap, Swap),
            ],
            // MLPK: k = (r1 − r2 − r3 + r4)² with r1=D[d,d̄], r2=D[d,d̄'],
            // r3=D[d',d̄], r4=D[d',d̄']. Squares → D^{⊙2}⊗1 terms; cross
            // terms (u,v)+(v,u) merge with coefficient ±2.
            PairwiseKernel::Mlpk => vec![
                // Squared terms.
                KT::new(1.0, DSq, Ones, Id, Id),      // r1²
                KT::new(1.0, DSq, Ones, Id, Swap),    // r2²
                KT::new(1.0, DSq, Ones, Swap, Id),    // r3²
                KT::new(1.0, DSq, Ones, Swap, Swap),  // r4²
                // Cross terms (sign = s_u·s_v·2, s = (+,−,−,+)).
                KT::new(-2.0, D, D, DupDrug, Id),     // r1·r2
                KT::new(-2.0, D, D, Id, DupDrug),     // r1·r3
                KT::new(2.0, D, D, Id, Id),           // r1·r4
                KT::new(2.0, D, D, Id, Swap),         // r2·r3
                KT::new(-2.0, D, D, Id, DupTarget),   // r2·r4
                KT::new(-2.0, D, D, DupTarget, Id),   // r3·r4
            ],
        }
    }
}

/// A pairwise kernel as a linear operator `a ↦ R_rows K R_colsᵀ a`,
/// evaluated term-by-term with the generalized vec trick.
///
/// `d`/`t` are kernel matrices over the **full object domains** (all drugs
/// observed anywhere, all targets observed anywhere); `rows` and `cols`
/// index into those shared domains, so the same op covers the training
/// kernel matrix (`rows == cols == train`), validation predictions and
/// test predictions (rows = the prediction sample).
pub struct PairwiseLinOp {
    kernel: PairwiseKernel,
    d: Arc<Mat>,
    t: Arc<Mat>,
    /// `D^{⊙2}` / `T^{⊙2}`, Arc-shared so [`Self::with_rows`] rebuilds
    /// (serving: a fresh row sample per request batch) skip recomputing
    /// the Hadamard squares of the full-domain matrices.
    dsq: Option<Arc<Mat>>,
    tsq: Option<Arc<Mat>>,
    rows: PairIndex,
    cols: PairIndex,
    policy: GvtPolicy,
    /// Terms with their index transforms pre-applied (§Perf: applying
    /// `P`/`Q` per mat-vec cloned full index vectors every iteration;
    /// with `Arc`-backed [`PairIndex`] buffers these are O(1) views).
    /// Kept alongside the plan for the unfused ablation path.
    terms: Vec<(KroneckerTerm, PairIndex, PairIndex)>,
    /// Compiled fused execution plan (see [`crate::gvt::plan`]): stage-1
    /// dedup across terms, accumulated stage-2 sweeps, grouped-CSR
    /// stage 1, and the multi-RHS path.
    plan: GvtPlan,
    /// Reusable workspace threaded through `apply_into` — after warmup,
    /// solver iterations perform zero heap allocations. Behind a `Mutex`
    /// so the operator stays `Sync`; solvers apply sequentially, so the
    /// lock is uncontended (~20 ns against a multi-ms mat-vec).
    ws: Mutex<GvtWorkspace>,
}

impl PairwiseLinOp {
    /// Build the operator. For homogeneous kernels (Symmetric,
    /// AntiSymmetric, Ranking, MLPK) pass the same matrix as `d` and `t`
    /// and samples with `m == q`.
    pub fn new(
        kernel: PairwiseKernel,
        d: Arc<Mat>,
        t: Arc<Mat>,
        rows: PairIndex,
        cols: PairIndex,
        policy: GvtPolicy,
    ) -> Result<Self> {
        let needs_sq = kernel.needs_squares();
        let dsq = if needs_sq { Some(Arc::new(d.hadamard_square())) } else { None };
        let tsq = if needs_sq { Some(Arc::new(t.hadamard_square())) } else { None };
        Self::assemble(kernel, d, t, dsq, tsq, rows, cols, policy)
    }

    /// Shared constructor body: validate shapes, pre-apply index
    /// transforms, compile the fused plan. The squared matrices are
    /// passed in (already wrapped) so the serving-path rebuilds can
    /// share them across operator instances.
    #[allow(clippy::too_many_arguments)]
    fn assemble(
        kernel: PairwiseKernel,
        d: Arc<Mat>,
        t: Arc<Mat>,
        dsq: Option<Arc<Mat>>,
        tsq: Option<Arc<Mat>>,
        rows: PairIndex,
        cols: PairIndex,
        policy: GvtPolicy,
    ) -> Result<Self> {
        if d.rows() != rows.m() || d.cols() != cols.m() {
            bail!(
                "drug kernel is {}x{} but samples have drug domains {}/{}",
                d.rows(),
                d.cols(),
                rows.m(),
                cols.m()
            );
        }
        if t.rows() != rows.q() || t.cols() != cols.q() {
            bail!(
                "target kernel is {}x{} but samples have target domains {}/{}",
                t.rows(),
                t.cols(),
                rows.q(),
                cols.q()
            );
        }
        if !kernel.supports_heterogeneous() {
            // Homogeneous kernels: both slots must share one domain.
            if rows.m() != rows.q() || cols.m() != cols.q() {
                bail!(
                    "{} requires a homogeneous domain (m == q), got {}x{} / {}x{}",
                    kernel.name(),
                    rows.m(),
                    rows.q(),
                    cols.m(),
                    cols.q()
                );
            }
        }
        // Pre-apply the P/Q index transforms once. With Arc-backed
        // PairIndex buffers each transform is an O(1) view, and identical
        // transforms share buffers — which is exactly what the plan
        // builder keys on to fuse stage-1/stage-2 work across terms.
        let terms: Vec<(KroneckerTerm, PairIndex, PairIndex)> = kernel
            .terms()
            .into_iter()
            .map(|term| {
                let r = term.row_map.apply(&rows);
                let c = term.col_map.apply(&cols);
                (term, r, c)
            })
            .collect();
        let ctx = TermContext {
            d: d.as_ref(),
            t: t.as_ref(),
            dsq: dsq.as_deref(),
            tsq: tsq.as_deref(),
        };
        let plan = GvtPlan::build(&terms, &ctx, policy, rows.len(), cols.len());
        Ok(Self {
            kernel,
            d,
            t,
            dsq,
            tsq,
            rows,
            cols,
            policy,
            terms,
            plan,
            ws: Mutex::new(GvtWorkspace::new()),
        })
    }

    /// Rebuild this operator for a **new row sample** over the same
    /// kernel matrices, column sample and policy — the serving hot path
    /// (each request batch is a fresh row sample against the fixed
    /// training sample). Reuses the `Arc`-shared kernel matrices and
    /// their Hadamard squares, and the column sample's buffers and
    /// grouping caches; only the (small) row-side transforms and the
    /// plan's unit tables are rebuilt.
    pub fn with_rows(&self, rows: PairIndex) -> Result<Self> {
        Self::assemble(
            self.kernel,
            self.d.clone(),
            self.t.clone(),
            self.dsq.clone(),
            self.tsq.clone(),
            rows,
            self.cols.clone(),
            self.policy,
        )
    }

    /// Rebuild for a new row sample **and** new row-side kernel
    /// matrices (serving queries that reference objects outside the
    /// training domains: `d`/`t` are batch-local cross-kernel matrices,
    /// `rows.m()/q()` index their rows, columns still index the training
    /// domains). The squares are recomputed — they are squares of the
    /// batch-local matrices, `O(batch × domain)`.
    pub fn reindexed(&self, d: Arc<Mat>, t: Arc<Mat>, rows: PairIndex) -> Result<Self> {
        let needs_sq = self.kernel.needs_squares();
        let dsq = if needs_sq { Some(Arc::new(d.hadamard_square())) } else { None };
        let tsq = if needs_sq { Some(Arc::new(t.hadamard_square())) } else { None };
        Self::assemble(
            self.kernel,
            d,
            t,
            dsq,
            tsq,
            rows,
            self.cols.clone(),
            self.policy,
        )
    }

    /// Rebuild with a different factorization policy over the same
    /// matrices and samples (serving pins `Auto` to a concrete mode at
    /// startup). Shares the kernel matrices and their Hadamard squares;
    /// only the plan is recompiled.
    pub fn with_policy(&self, policy: GvtPolicy) -> Result<Self> {
        Self::assemble(
            self.kernel,
            self.d.clone(),
            self.t.clone(),
            self.dsq.clone(),
            self.tsq.clone(),
            self.rows.clone(),
            self.cols.clone(),
            policy,
        )
    }

    /// Take this operator's workspace out, leaving a fresh one. Paired
    /// with [`Self::install_workspace`], this lets a long-lived owner (the
    /// serving [`crate::serve::Predictor`]) carry one warm workspace
    /// across many short-lived per-batch operators: buffers grow to the
    /// training-side shapes once and are reused by every later batch.
    pub fn take_workspace(&self) -> GvtWorkspace {
        std::mem::take(&mut *self.ws.lock().expect("GVT workspace poisoned"))
    }

    /// Replace this operator's workspace (see [`Self::take_workspace`]).
    pub fn install_workspace(&self, ws: GvtWorkspace) {
        *self.ws.lock().expect("GVT workspace poisoned") = ws;
    }

    /// The concrete factorization the compiled plan executes (`Auto`
    /// resolved; see [`GvtPlan::mode`]). Serving pins this so batched and
    /// one-shot prediction share one floating-point evaluation order.
    pub fn resolved_mode(&self) -> GvtPolicy {
        self.plan.mode()
    }

    /// The policy this operator was built with (possibly `Auto`).
    pub fn policy(&self) -> GvtPolicy {
        self.policy
    }

    pub fn kernel(&self) -> PairwiseKernel {
        self.kernel
    }

    pub fn rows(&self) -> &PairIndex {
        &self.rows
    }

    pub fn cols(&self) -> &PairIndex {
        &self.cols
    }

    /// Number of Kronecker summands (the constant factor of Fig 7's
    /// per-kernel runtime differences).
    pub fn term_count(&self) -> usize {
        self.terms.len()
    }

    fn ctx(&self) -> TermContext<'_> {
        TermContext {
            d: &self.d,
            t: &self.t,
            dsq: self.dsq.as_deref(),
            tsq: self.tsq.as_deref(),
        }
    }

    /// `out = Σ_terms coeff · GVT(term)` — the `O(nm + nq)` product,
    /// executed through the fused [`GvtPlan`] with the operator-owned
    /// workspace (zero heap allocations after the first call).
    /// `GVT_RLS_NO_FUSE=1` falls back to [`Self::matvec_into_unfused`].
    pub fn matvec_into(&self, a: &[f64], out: &mut [f64]) {
        if fusion_disabled() {
            self.matvec_into_unfused(a, out);
            return;
        }
        let ctx = self.ctx();
        let mut ws = self.ws.lock().expect("GVT workspace poisoned");
        self.plan.execute(&ctx, a, out, &mut ws);
    }

    /// The pre-plan path: every term evaluated in isolation (own stage-1
    /// pass, own stage-2 sweep, fresh intermediates). Kept for the §Perf
    /// fusion ablation (`bench_perf_ablation`, `GVT_RLS_NO_FUSE=1`) and
    /// as an independent implementation the fused path is tested against.
    pub fn matvec_into_unfused(&self, a: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.rows.len());
        out.fill(0.0);
        let ctx = self.ctx();
        for (term, rows_t, cols_t) in &self.terms {
            term.matvec_transformed(&ctx, rows_t, cols_t, a, self.policy, out);
        }
    }

    /// Allocating wrapper over [`Self::matvec_into`].
    pub fn matvec(&self, a: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows.len()];
        self.matvec_into(a, &mut out);
        out
    }

    /// Multi-RHS product `P = K · AB` for a block `AB` of `B` coefficient
    /// vectors (`n × B` row-major, see [`Mat::from_columns`]): the index
    /// arrays are streamed once per stage for the whole block instead of
    /// once per RHS. Used by ridge's multi-λ and k-fold CV prediction
    /// paths.
    pub fn matmat(&self, ab: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows.len(), ab.cols());
        self.matmat_into(ab, &mut out);
        out
    }

    /// [`Self::matmat`] into a caller-provided block. Under
    /// `GVT_RLS_NO_FUSE=1` this too avoids the plan (column loop over the
    /// per-term path), so the ablation hatch covers every product the
    /// operator performs, not just single-RHS mat-vecs.
    pub fn matmat_into(&self, ab: &Mat, out: &mut Mat) {
        if fusion_disabled() {
            assert_eq!(ab.rows(), self.cols.len());
            assert_eq!(out.shape(), (self.rows.len(), ab.cols()));
            let mut col_out = vec![0.0; self.rows.len()];
            for bb in 0..ab.cols() {
                let col = ab.column(bb);
                self.matvec_into_unfused(&col, &mut col_out);
                for i in 0..self.rows.len() {
                    out[(i, bb)] = col_out[i];
                }
            }
            return;
        }
        let ctx = self.ctx();
        let mut ws = self.ws.lock().expect("GVT workspace poisoned");
        self.plan.execute_multi(&ctx, ab, out, &mut ws);
    }

    /// One-line fused-plan structure summary (benches log this).
    pub fn plan_summary(&self) -> String {
        self.plan.summary()
    }

    /// The compiled plan (tests assert on its fusion structure).
    pub fn plan(&self) -> &GvtPlan {
        &self.plan
    }

    /// Single kernel entry via the term decomposition (`O(terms)`), used
    /// by tests; the explicit oracle in [`crate::gvt::explicit`] computes
    /// the same value from the Table 3 closed forms independently.
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        let ctx = self.ctx();
        let row = (self.rows.drug(i), self.rows.target(i));
        let col = (self.cols.drug(j), self.cols.target(j));
        self.terms.iter().map(|(t, _, _)| t.entry(&ctx, row, col)).sum()
    }
}

impl LinOp for PairwiseLinOp {
    fn dim_out(&self) -> usize {
        self.rows.len()
    }

    fn dim_in(&self) -> usize {
        self.cols.len()
    }

    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_into(x, y);
    }

    fn apply_block(&self, x: &Mat, y: &mut Mat) {
        self.matmat_into(x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{dist, Xoshiro256};
    use crate::testing::gen;

    #[test]
    fn term_counts_match_paper() {
        assert_eq!(PairwiseKernel::Kronecker.terms().len(), 1);
        assert_eq!(PairwiseKernel::Linear.terms().len(), 2);
        assert_eq!(PairwiseKernel::Poly2D.terms().len(), 3);
        assert_eq!(PairwiseKernel::Cartesian.terms().len(), 2);
        assert_eq!(PairwiseKernel::Symmetric.terms().len(), 2);
        assert_eq!(PairwiseKernel::AntiSymmetric.terms().len(), 2);
        assert_eq!(PairwiseKernel::Ranking.terms().len(), 4);
        // "the MLPK slowest because it has 10 such terms" — §6.4.
        assert_eq!(PairwiseKernel::Mlpk.terms().len(), 10);
    }

    #[test]
    fn heterogeneous_support_matches_table4() {
        use PairwiseKernel::*;
        for k in [Linear, Poly2D, Kronecker, Cartesian] {
            assert!(k.supports_heterogeneous(), "{k:?}");
        }
        for k in [Symmetric, AntiSymmetric, Ranking, Mlpk] {
            assert!(!k.supports_heterogeneous(), "{k:?}");
        }
    }

    #[test]
    fn homogeneous_kernel_rejects_heterogeneous_sample() {
        let mut rng = Xoshiro256::seed_from(40);
        let d = Arc::new(gen::psd_kernel(&mut rng, 4));
        let t = Arc::new(gen::psd_kernel(&mut rng, 3));
        let s = gen::pair_sample(&mut rng, 10, 4, 3);
        let r = PairwiseLinOp::new(
            PairwiseKernel::Symmetric,
            d,
            t,
            s.clone(),
            s,
            GvtPolicy::Auto,
        );
        assert!(r.is_err());
    }

    #[test]
    fn training_matrix_is_symmetric_operator() {
        // <Ka, b> == <a, Kb> on the training sample for every kernel.
        let mut rng = Xoshiro256::seed_from(41);
        let m = 7;
        let d = Arc::new(gen::psd_kernel(&mut rng, m));
        let s = gen::homogeneous_sample(&mut rng, 30, m);
        for kernel in PairwiseKernel::ALL {
            let op = PairwiseLinOp::new(
                kernel,
                d.clone(),
                d.clone(),
                s.clone(),
                s.clone(),
                GvtPolicy::Auto,
            )
            .unwrap();
            let a = dist::normal_vec(&mut rng, 30);
            let b = dist::normal_vec(&mut rng, 30);
            let ka = op.matvec(&a);
            let kb = op.matvec(&b);
            let lhs: f64 = ka.iter().zip(&b).map(|(x, y)| x * y).sum();
            let rhs: f64 = a.iter().zip(&kb).map(|(x, y)| x * y).sum();
            assert!(
                (lhs - rhs).abs() < 1e-8 * lhs.abs().max(1.0),
                "{kernel:?}: {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn parse_roundtrip() {
        for k in PairwiseKernel::ALL {
            assert_eq!(PairwiseKernel::parse(k.name()), Some(k));
        }
    }

    /// §Plan-Fusion: the compiled plan collapses the per-kernel term lists
    /// to the analyzed pass counts (see rust/DESIGN.md §Plan-Fusion).
    #[test]
    fn fused_plan_structure_matches_analysis() {
        let mut rng = Xoshiro256::seed_from(50);
        let m = 6;
        let d = Arc::new(gen::psd_kernel(&mut rng, m));
        let s = gen::homogeneous_sample(&mut rng, 20, m);
        let op = |k: PairwiseKernel| {
            PairwiseLinOp::new(
                k,
                d.clone(),
                d.clone(),
                s.clone(),
                s.clone(),
                GvtPolicy::SparseLeft,
            )
            .unwrap()
        };
        // Ranking: 4 pooled terms → 2 pool+GEMV passes, nothing else.
        let ranking = op(PairwiseKernel::Ranking);
        assert_eq!(ranking.plan().pooled_count(), 2);
        assert_eq!(ranking.plan().stage1_count(), 0);
        assert_eq!(ranking.plan().misc_count(), 0);
        // MLPK: 10 terms → 2 pooled + 4 stage-1 passes + 3 stage-2 sweeps.
        let mlpk = op(PairwiseKernel::Mlpk);
        assert_eq!(mlpk.plan().pooled_count(), 2);
        assert_eq!(mlpk.plan().stage1_count(), 4);
        assert_eq!(mlpk.plan().stage2_count(), 3);
        // Symmetric/AntiSymmetric: the two terms share one stage-1 pass.
        for k in [PairwiseKernel::Symmetric, PairwiseKernel::AntiSymmetric] {
            let o = op(k);
            assert_eq!(o.plan().stage1_count(), 1, "{k:?}");
            assert_eq!(o.plan().stage2_count(), 2, "{k:?}");
        }
        // Kronecker: single term, nothing to fuse.
        let kron = op(PairwiseKernel::Kronecker);
        assert_eq!(kron.plan().stage1_count(), 1);
        assert_eq!(kron.plan().stage2_count(), 1);
    }

    /// `with_rows` (the serving rebuild) must behave exactly like a
    /// freshly constructed operator over the new row sample — including
    /// for square-needing kernels, whose `D^{⊙2}`/`T^{⊙2}` it reuses.
    #[test]
    fn with_rows_matches_fresh_operator() {
        let mut rng = Xoshiro256::seed_from(60);
        let m = 6;
        let d = Arc::new(gen::psd_kernel(&mut rng, m));
        let train = gen::homogeneous_sample(&mut rng, 25, m);
        let batch = gen::homogeneous_sample(&mut rng, 7, m);
        let a = dist::normal_vec(&mut rng, 25);
        for kernel in PairwiseKernel::ALL {
            let template = PairwiseLinOp::new(
                kernel,
                d.clone(),
                d.clone(),
                train.clone(),
                train.clone(),
                GvtPolicy::SparseLeft,
            )
            .unwrap();
            let rebuilt = template.with_rows(batch.clone()).unwrap();
            // Warm-workspace carry-over: run the template once, then move
            // its workspace into the rebuilt operator.
            let _ = template.matvec(&a);
            rebuilt.install_workspace(template.take_workspace());
            let fresh = PairwiseLinOp::new(
                kernel,
                d.clone(),
                d.clone(),
                batch.clone(),
                train.clone(),
                GvtPolicy::SparseLeft,
            )
            .unwrap();
            let p1 = rebuilt.matvec(&a);
            let p2 = fresh.matvec(&a);
            assert_eq!(p1, p2, "{kernel:?}: with_rows vs fresh");
        }
    }

    /// `reindexed` swaps in batch-local (rectangular) cross matrices;
    /// rows copied out of the full matrices must reproduce the full
    /// operator's outputs bit-for-bit.
    #[test]
    fn reindexed_matches_submatrix_rows() {
        let mut rng = Xoshiro256::seed_from(61);
        let (m, q) = (5, 7);
        let d = Arc::new(gen::psd_kernel(&mut rng, m));
        let t = Arc::new(gen::psd_kernel(&mut rng, q));
        let train = gen::pair_sample(&mut rng, 30, m, q);
        let test = gen::pair_sample(&mut rng, 9, m, q);
        let a = dist::normal_vec(&mut rng, 30);
        let template = PairwiseLinOp::new(
            PairwiseKernel::Poly2D,
            d.clone(),
            t.clone(),
            train.clone(),
            train.clone(),
            GvtPolicy::SparseLeft,
        )
        .unwrap();
        // Batch-local domains: one row per test pair (duplicates allowed).
        let d_batch = Arc::new(d.gather_rows(&(0..test.len()).map(|i| test.drug(i)).collect::<Vec<_>>()));
        let t_batch = Arc::new(t.gather_rows(&(0..test.len()).map(|i| test.target(i)).collect::<Vec<_>>()));
        let rows = PairIndex::new(
            (0..test.len() as u32).collect(),
            (0..test.len() as u32).collect(),
            test.len(),
            test.len(),
        );
        let op = template.reindexed(d_batch, t_batch, rows).unwrap();
        let full = template.with_rows(test.clone()).unwrap();
        assert_eq!(op.matvec(&a), full.matvec(&a));
    }

    // Fused-vs-unfused equivalence (all kernels, homogeneous and
    // heterogeneous, plus the entry oracle and matmat-vs-column-loop) is
    // property-tested in tests/plan_fusion.rs.
}
