//! Compiled multi-term GVT execution plans.
//!
//! A pairwise kernel is a sum of Kronecker terms (Corollary 1), and §6.4
//! shows the per-kernel mat-vec cost is essentially proportional to the
//! term count — MLPK is slowest *only* because it has 10 summands. But the
//! terms of one kernel are far from independent: they are built from the
//! same two index buffers (`P`/`Q` only permute or duplicate streams), so
//! much of the per-term work is byte-identical across terms. This module
//! compiles the term list into a [`GvtPlan`] **once at operator
//! construction** and amortizes three things across the thousands of
//! CG/MINRES iterations of a solve:
//!
//! 1. **Stage-1 dedup.** Terms whose (gather matrix, column sample)
//!    coincide — witnessed by shared `Arc` index buffers, see
//!    [`PairIndex::same_view`] — share one stage-1 pass producing one `S`.
//!    Terms whose (stage-2 matrix, row sample) coincide accumulate their
//!    coefficient-weighted `S` matrices (`O(q·m)` each) and run **one**
//!    row-dot sweep (`O(n̄·m)`) instead of one per term. Pooled
//!    (`dense ⊗ 1`) terms fuse the same way: one pool + GEMV per distinct
//!    (matrix, pool stream). Ranking's 4 pooled terms collapse to 2
//!    pool+GEMV passes; MLPK's 10 terms to 4 stage-1 passes + 3 row-dot
//!    sweeps + 2 pooled GEMVs.
//! 2. **CSR-grouped stage 1.** The streamed stage-1 kernel performs 4
//!    random read-modify-writes per pair (`S[·, scatter[j]] +=`). The
//!    grouped kernel walks the cached [`crate::sparse::GroupBy`] of the
//!    scatter stream instead, accumulating each `S` column in registers
//!    and storing it once — the random RMWs become one random gather of
//!    `a[order[k]]`, and `S` needs no zeroing because every column is
//!    fully written. [`GvtPolicy::Auto`]'s cost model picks grouped vs
//!    streamed per stage-1 unit (grouped when the average column
//!    occupancy `n / s_cols ≥ 1`); `GVT_RLS_STAGE1_GROUPED=0|1` forces it.
//! 3. **Workspace reuse.** All intermediates (`S` matrices, accumulators,
//!    pool buffers, scratch) live in a [`GvtWorkspace`] that the owning
//!    operator threads through `LinOp::apply_into` — after the first
//!    (warmup) application, solver iterations perform zero heap
//!    allocations.
//! 4. **Pooled execution.** Every sweep runs on the persistent worker
//!    pool ([`crate::runtime::pool`]) through the
//!    [`crate::linalg::par`] façade — no thread is spawned per mat-vec —
//!    and the *independent* stage-1 passes of distinct fused units are
//!    submitted as one chunk-claim job (they write disjoint `S`
//!    buffers), so a multi-unit kernel pays one synchronization round
//!    per application instead of one per unit.
//!
//! The plan also executes **multi-RHS blocks** ([`GvtPlan::execute_multi`]
//! / [`gvt_matmat`]): the index arrays are streamed once for a block of
//! `B` coefficient vectors (the innermost dimension of `S` becomes `B`),
//! which is what ridge's multi-λ and k-fold CV prediction paths use.
//!
//! `GVT_RLS_NO_FUSE=1` disables plan execution in
//! [`crate::gvt::pairwise::PairwiseLinOp`] (falling back to the isolated
//! per-term path) — the §Perf ablation hatch, mirroring
//! `GVT_RLS_STAGE1_1ROW`.

use crate::gvt::terms::{
    accumulate_rowdot, Factor, IndexMap, KroneckerTerm, SlotMatrix, TermContext,
};
use crate::gvt::vec_trick::{
    choose_policy, scatter_w_grouped, stage1_scatter, stage1_single_row, GvtPolicy,
};
use crate::linalg::{microkernel, par, vecops, Mat};
use crate::sparse::{GroupBy, PairIndex};
use std::sync::{Arc, OnceLock};

/// `GVT_RLS_NO_FUSE=1` — run terms unfused (the pre-plan path); `0` or
/// unset keeps fusion on (same convention as `GVT_RLS_STAGE1_GROUPED`).
/// Read once and cached; the check sits on the per-mat-vec path.
pub(crate) fn fusion_disabled() -> bool {
    static CACHED: OnceLock<bool> = OnceLock::new();
    *CACHED.get_or_init(|| match std::env::var("GVT_RLS_NO_FUSE") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    })
}

/// `GVT_RLS_STAGE1_GROUPED=0|1` — force the stage-1 kernel choice for all
/// units, overriding the occupancy heuristic (A/B ablation hatch).
fn stage1_grouped_override() -> Option<bool> {
    static CACHED: OnceLock<Option<bool>> = OnceLock::new();
    *CACHED.get_or_init(|| match std::env::var("GVT_RLS_STAGE1_GROUPED") {
        Ok(v) if v == "0" => Some(false),
        Ok(v) if v == "1" => Some(true),
        _ => None,
    })
}

/// Resolve a factor that the plan classified as dense.
fn dense_mat<'a>(ctx: &TermContext<'a>, f: Factor) -> &'a Mat {
    match ctx.resolve(f) {
        SlotMatrix::Dense(m) => m,
        _ => unreachable!("plan unit references a non-dense factor"),
    }
}

fn is_dense(f: Factor) -> bool {
    matches!(f, Factor::D | Factor::T | Factor::DSq | Factor::TSq)
}

/// Shape-stable reuse for a matrix buffer: reallocates only when the
/// requested shape differs from the current one. Workspace buffers are
/// therefore kept **per plan unit** (each unit's shapes are fixed), so
/// after the first execution at a given shape no reallocation happens.
fn ensure_mat(m: &mut Mat, rows: usize, cols: usize) {
    if m.shape() != (rows, cols) {
        *m = Mat::zeros(rows, cols);
    }
}

/// Index into a `Vec<Mat>` of per-unit buffers, growing it on first use.
fn unit_mat(buf: &mut Vec<Mat>, idx: usize) -> &mut Mat {
    while buf.len() <= idx {
        buf.push(Mat::zeros(0, 0));
    }
    &mut buf[idx]
}

/// Zeroed scratch of `len` without shrinking capacity.
fn zeroed(buf: &mut Vec<f64>, len: usize) {
    buf.clear();
    buf.resize(len, 0.0);
}

/// Precomputed CSR grouping for one stage-1 unit: pair positions grouped
/// by the scatter stream, plus the gather stream permuted into group
/// order (so the inner loop reads two sequential arrays).
struct GroupedStage1 {
    grp: Arc<GroupBy>,
    gather_keys: Vec<u32>,
}

/// One stage-1 pass producing an `S` intermediate shared by every term
/// whose (gather matrix, column sample) coincide.
struct Stage1Unit {
    /// Matrix gathered from in stage 1 (the right factor under
    /// `SparseLeft`/`Dense`, the left factor under `SparseRight`).
    mat: Factor,
    /// Transformed column sample of the fused terms.
    cols: PairIndex,
    s_rows: usize,
    s_cols: usize,
    /// `Some` → CSR-grouped kernel; `None` → streamed scatter (or the
    /// GEMM formulation when the plan mode is `Dense`).
    grouped: Option<GroupedStage1>,
}

/// One stage-2 row-dot sweep consuming one or more coefficient-weighted
/// `S` intermediates that share (lhs matrix, row sample).
struct Stage2Unit {
    /// Row-dot matrix (left factor under `SparseLeft`/`Dense`, right
    /// factor under `SparseRight`).
    lhs: Factor,
    /// Transformed row sample of the fused terms.
    rows: PairIndex,
    s_rows: usize,
    s_cols: usize,
    /// `(coefficient, stage-1 unit index)` per fused term.
    contributions: Vec<(f64, usize)>,
}

/// One pool + GEMV pass shared by every `dense ⊗ 1` / `1 ⊗ dense` term
/// with the same (matrix, pool stream).
struct PooledUnit {
    mat: Factor,
    cols: PairIndex,
    /// Pool over the column sample's target stream (else drug stream).
    pool_targets: bool,
    /// `(coefficient, row sample, gather-the-target-stream)` per term.
    gathers: Vec<(f64, PairIndex, bool)>,
}

/// A term executed by the per-term fast path (`Identity` factors,
/// `1 ⊗ 1`) with plan-owned scratch; these are `O(n + n̄)`-ish and gain
/// nothing from cross-term fusion.
struct MiscTerm {
    term: KroneckerTerm,
    rows: PairIndex,
    cols: PairIndex,
}

/// Reusable scratch for plan execution. All buffers grow on first use
/// and are reused verbatim afterwards — repeated
/// [`GvtPlan::execute`] calls at fixed shapes perform no heap allocation.
pub struct GvtWorkspace {
    /// One `S` intermediate per stage-1 unit.
    s: Vec<Mat>,
    /// One accumulation buffer per multi-contribution stage-2 unit.
    s_acc: Vec<Mat>,
    /// Dense-mode scattered coefficient matrix `W`, per stage-1 unit
    /// (units can have different column domains, e.g. MLPK's transformed
    /// samples — one shared buffer would reallocate every call).
    w: Vec<Mat>,
    /// Pool + GEMV scratch (`w` then `v`, contiguous).
    pool: Vec<f64>,
    /// Scratch for misc terms (see `KroneckerTerm::matvec_transformed_with`).
    scratch: Vec<f64>,
    /// Multi-RHS `S` buffers, layout `[r][d][b]` (RHS innermost).
    sm: Vec<Vec<f64>>,
    /// Multi-RHS stage-2 accumulation buffers.
    sm_acc: Vec<Vec<f64>>,
    /// Multi-RHS pooled scratch (`W`, `V` blocks), per pooled unit.
    pw: Vec<Mat>,
    pv: Vec<Mat>,
    /// Per-column scratch for multi-RHS misc/fallback execution.
    col_in: Vec<f64>,
    col_out: Vec<f64>,
    /// Chunk table for the concurrent stage-1 sweep: `(unit, row0, row1)`
    /// per chunk, rebuilt (capacity reused — no allocation after warmup)
    /// every [`GvtPlan::execute`].
    s1_chunks: Vec<(u32, u32, u32)>,
    /// Per-unit `S` base pointers for the sweep, usize-erased so the
    /// chunk-claim closure can address all units' disjoint buffers.
    s1_bases: Vec<usize>,
}

impl GvtWorkspace {
    /// Empty workspace; buffers grow to the plan's shapes on first use.
    pub fn new() -> Self {
        Self {
            s: Vec::new(),
            s_acc: Vec::new(),
            w: Vec::new(),
            pool: Vec::new(),
            scratch: Vec::new(),
            sm: Vec::new(),
            sm_acc: Vec::new(),
            pw: Vec::new(),
            pv: Vec::new(),
            col_in: Vec::new(),
            col_out: Vec::new(),
            s1_chunks: Vec::new(),
            s1_bases: Vec::new(),
        }
    }
}

impl Default for GvtWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

/// The compiled execution plan for a list of Kronecker terms over fixed
/// row/column samples. Built once by
/// [`crate::gvt::pairwise::PairwiseLinOp::new`]; see the module docs for
/// what is fused.
pub struct GvtPlan {
    /// Concrete factorization for the dense×dense terms (never `Auto`).
    mode: GvtPolicy,
    pooled: Vec<PooledUnit>,
    stage1: Vec<Stage1Unit>,
    stage2: Vec<Stage2Unit>,
    misc: Vec<MiscTerm>,
    n_out: usize,
    n_in: usize,
}

impl GvtPlan {
    /// Analyze `terms` (each with its transformed row/column samples) and
    /// build the fused plan. `policy` selects the factorization for the
    /// dense×dense terms: `Auto` consults the shared cost model
    /// ([`choose_policy`]); forced policies are honored as-is.
    pub fn build(
        terms: &[(KroneckerTerm, PairIndex, PairIndex)],
        ctx: &TermContext<'_>,
        policy: GvtPolicy,
        n_out: usize,
        n_in: usize,
    ) -> GvtPlan {
        let mut pooled: Vec<PooledUnit> = Vec::new();
        let mut misc: Vec<MiscTerm> = Vec::new();
        let mut dense_terms: Vec<(KroneckerTerm, PairIndex, PairIndex)> = Vec::new();

        for (term, rows_t, cols_t) in terms {
            match (is_dense(term.left), is_dense(term.right)) {
                (true, true) => dense_terms.push((*term, rows_t.clone(), cols_t.clone())),
                (true, false) if term.right == Factor::Ones => {
                    // dense ⊗ 1: pool over the col drug stream, GEMV with
                    // the left matrix, gather by the row drug stream.
                    Self::add_pooled(
                        &mut pooled, term.left, cols_t, false, term.coeff, rows_t, false,
                    );
                }
                (false, true) if term.left == Factor::Ones => {
                    // 1 ⊗ dense: the mirror image on target streams.
                    Self::add_pooled(
                        &mut pooled, term.right, cols_t, true, term.coeff, rows_t, true,
                    );
                }
                _ => misc.push(MiscTerm {
                    term: *term,
                    rows: rows_t.clone(),
                    cols: cols_t.clone(),
                }),
            }
        }

        // Factorization for the dense×dense terms: one mode per plan (the
        // terms of a kernel share their shapes, so one cost evaluation is
        // representative).
        let mode = match (policy, dense_terms.first()) {
            (GvtPolicy::Auto, Some((term, rows_t, cols_t))) => choose_policy(
                cols_t.len(),
                rows_t.len(),
                dense_mat(ctx, term.left).shape(),
                dense_mat(ctx, term.right).shape(),
            ),
            (GvtPolicy::Auto, None) => GvtPolicy::SparseLeft,
            (forced, _) => forced,
        };

        let mut stage1: Vec<Stage1Unit> = Vec::new();
        let mut stage2: Vec<Stage2Unit> = Vec::new();
        for (term, rows_t, cols_t) in &dense_terms {
            // Under SparseRight the roles of the two factors swap: stage 1
            // gathers from the left matrix (scattering by target), stage 2
            // row-dots the right matrix (indexing rows by target stream).
            let (g_mat, l_mat) = match mode {
                GvtPolicy::SparseRight => (term.left, term.right),
                _ => (term.right, term.left),
            };
            let s_rows = dense_mat(ctx, g_mat).rows();
            let s_cols = dense_mat(ctx, l_mat).cols();

            // Stage 1: share units whose (matrix, column sample) coincide.
            let existing = stage1.iter().position(|u| {
                u.mat == g_mat
                    && u.s_rows == s_rows
                    && u.s_cols == s_cols
                    && u.cols.same_view(cols_t)
            });
            let s1 = match existing {
                Some(i) => i,
                None => {
                    let grouped = if mode == GvtPolicy::Dense {
                        None
                    } else {
                        let want = stage1_grouped_override()
                            .unwrap_or(cols_t.len() >= s_cols && s_cols > 0);
                        want.then(|| {
                            // Group by the scatter stream; permute the
                            // gather stream into group order.
                            let (grp, gather) = match mode {
                                GvtPolicy::SparseRight => {
                                    (cols_t.by_target_arc(), cols_t.drugs())
                                }
                                _ => (cols_t.by_drug_arc(), cols_t.targets()),
                            };
                            let gather_keys = grp
                                .positions()
                                .iter()
                                .map(|&p| gather[p as usize])
                                .collect();
                            GroupedStage1 { grp, gather_keys }
                        })
                    };
                    stage1.push(Stage1Unit {
                        mat: g_mat,
                        cols: cols_t.clone(),
                        s_rows,
                        s_cols,
                        grouped,
                    });
                    stage1.len() - 1
                }
            };

            // Stage 2: merge terms whose (matrix, row sample, S shape)
            // coincide — their weighted S's accumulate before one sweep.
            match stage2.iter_mut().find(|u| {
                u.lhs == l_mat
                    && u.s_rows == s_rows
                    && u.s_cols == s_cols
                    && u.rows.same_view(rows_t)
            }) {
                Some(u) => u.contributions.push((term.coeff, s1)),
                None => stage2.push(Stage2Unit {
                    lhs: l_mat,
                    rows: rows_t.clone(),
                    s_rows,
                    s_cols,
                    contributions: vec![(term.coeff, s1)],
                }),
            }
        }

        GvtPlan { mode, pooled, stage1, stage2, misc, n_out, n_in }
    }

    fn add_pooled(
        pooled: &mut Vec<PooledUnit>,
        mat: Factor,
        cols_t: &PairIndex,
        pool_targets: bool,
        coeff: f64,
        rows_t: &PairIndex,
        gather_targets: bool,
    ) {
        let key = if pool_targets { cols_t.targets_key() } else { cols_t.drugs_key() };
        let unit = pooled.iter_mut().find(|u| {
            u.mat == mat
                && u.pool_targets == pool_targets
                && (if u.pool_targets { u.cols.targets_key() } else { u.cols.drugs_key() })
                    == key
        });
        match unit {
            Some(u) => u.gathers.push((coeff, rows_t.clone(), gather_targets)),
            None => pooled.push(PooledUnit {
                mat,
                cols: cols_t.clone(),
                pool_targets,
                gathers: vec![(coeff, rows_t.clone(), gather_targets)],
            }),
        }
    }

    /// The concrete factorization the plan resolved for its dense×dense
    /// terms — never `Auto` (an `Auto` build consults the cost model,
    /// whose inputs include the *row sample size*). The serving layer
    /// ([`crate::serve`]) reads this to pin one factorization across all
    /// per-batch operator builds: with the mode fixed, every output entry
    /// is computed by the same sequence of floating-point operations
    /// regardless of how queries are batched, so micro-batched responses
    /// are bit-identical to one-shot prediction.
    pub fn mode(&self) -> GvtPolicy {
        self.mode
    }

    /// Number of stage-1 passes over the column sample (vs one per
    /// dense×dense term unfused).
    pub fn stage1_count(&self) -> usize {
        self.stage1.len()
    }

    /// Number of stage-2 row-dot sweeps (vs one per dense×dense term).
    pub fn stage2_count(&self) -> usize {
        self.stage2.len()
    }

    /// Number of pool + GEMV passes (vs one per `dense ⊗ 1` term).
    pub fn pooled_count(&self) -> usize {
        self.pooled.len()
    }

    /// Terms on the per-term fast path (not worth fusing).
    pub fn misc_count(&self) -> usize {
        self.misc.len()
    }

    /// One-line structure summary (benches and DESIGN.md record this).
    pub fn summary(&self) -> String {
        format!(
            "mode={:?} pooled={} stage1={} stage2={} misc={}",
            self.mode,
            self.pooled.len(),
            self.stage1.len(),
            self.stage2.len(),
            self.misc.len()
        )
    }

    /// `out = Σ_terms coeff · GVT(term) · a`, fused. `out` is fully
    /// overwritten; `ws` provides all intermediates (allocation-free
    /// after the first call at these shapes).
    // lint: alloc_free — the solver per-iteration path; every buffer
    // comes from `ws` (grow-once via ensure_mat/zeroed, not denied
    // idioms). tests/alloc_free.rs measures the guarantee dynamically.
    pub fn execute(
        &self,
        ctx: &TermContext<'_>,
        a: &[f64],
        out: &mut [f64],
        ws: &mut GvtWorkspace,
    ) {
        assert_eq!(a.len(), self.n_in, "plan: coefficient length mismatch");
        assert_eq!(out.len(), self.n_out, "plan: output length mismatch");
        out.fill(0.0);

        for unit in &self.pooled {
            self.exec_pooled(unit, ctx, a, out, ws);
        }

        while ws.s.len() < self.stage1.len() {
            ws.s.push(Mat::zeros(0, 0));
        }
        let span = crate::obs::trace::begin();
        if self.mode != GvtPolicy::Dense
            && self.stage1.len() > 1
            && par::num_threads() > 1
            && !par::in_parallel_region()
        {
            // Distinct stage-1 units write disjoint S buffers, so all
            // their row chunks go into ONE chunk-claim job: units run
            // concurrently and idle workers drain whichever unit still
            // has rows left instead of idling at per-unit barriers.
            self.exec_stage1_concurrent(ctx, a, ws);
        } else {
            for (k, unit) in self.stage1.iter().enumerate() {
                let w = unit_mat(&mut ws.w, k);
                self.exec_stage1(unit, ctx, a, &mut ws.s[k], w);
            }
        }
        crate::obs::trace::end("gvt.stage1", "gvt", span);

        while ws.s_acc.len() < self.stage2.len() {
            ws.s_acc.push(Mat::zeros(0, 0));
        }
        let span = crate::obs::trace::begin();
        for (idx, unit) in self.stage2.iter().enumerate() {
            let lhs = dense_mat(ctx, unit.lhs);
            let (li, ri) = match self.mode {
                GvtPolicy::SparseRight => (unit.rows.targets(), unit.rows.drugs()),
                _ => (unit.rows.drugs(), unit.rows.targets()),
            };
            if unit.contributions.len() == 1 {
                let (c, k) = unit.contributions[0];
                accumulate_rowdot(lhs, ws.s[k].as_slice(), unit.s_cols, li, ri, c, out);
            } else {
                let acc = &mut ws.s_acc[idx];
                ensure_mat(acc, unit.s_rows, unit.s_cols);
                let (c0, k0) = unit.contributions[0];
                vecops::scale_into(acc.as_mut_slice(), ws.s[k0].as_slice(), c0);
                for &(c, k) in &unit.contributions[1..] {
                    vecops::axpy(c, ws.s[k].as_slice(), acc.as_mut_slice());
                }
                accumulate_rowdot(lhs, acc.as_slice(), unit.s_cols, li, ri, 1.0, out);
            }
        }
        crate::obs::trace::end("gvt.stage2", "gvt", span);

        for mt in &self.misc {
            mt.term.matvec_transformed_with(
                ctx,
                &mt.rows,
                &mt.cols,
                a,
                self.mode,
                out,
                &mut ws.scratch,
            );
        }
    }

    // lint: alloc_free — scatter/gather over ws.pool only.
    fn exec_pooled(
        &self,
        unit: &PooledUnit,
        ctx: &TermContext<'_>,
        a: &[f64],
        out: &mut [f64],
        ws: &mut GvtWorkspace,
    ) {
        let mat = dense_mat(ctx, unit.mat);
        let (mr, mc) = mat.shape();
        zeroed(&mut ws.pool, mc + mr);
        let (w, v) = ws.pool.split_at_mut(mc);
        let stream =
            if unit.pool_targets { unit.cols.targets() } else { unit.cols.drugs() };
        for (j, &sj) in stream.iter().enumerate() {
            w[sj as usize] += a[j];
        }
        mat.matvec_into(w, v);
        for (c, rows, gather_targets) in &unit.gathers {
            let g = if *gather_targets { rows.targets() } else { rows.drugs() };
            for (i, o) in out.iter_mut().enumerate() {
                *o += c * v[g[i] as usize];
            }
        }
    }

    // lint: alloc_free — writes into the caller-owned S/W workspace
    // matrices through the row-aligned par wrappers.
    fn exec_stage1(
        &self,
        unit: &Stage1Unit,
        ctx: &TermContext<'_>,
        a: &[f64],
        s: &mut Mat,
        w: &mut Mat,
    ) {
        let mat = dense_mat(ctx, unit.mat);
        ensure_mat(s, unit.s_rows, unit.s_cols);
        if unit.s_rows == 0 || unit.s_cols == 0 {
            return;
        }
        let s_cols = unit.s_cols;
        match (&unit.grouped, self.mode) {
            (_, GvtPolicy::Dense) => {
                // Roth formulation: scatter W (threaded via the target
                // grouping), then one GEMM.
                ensure_mat(w, unit.cols.q(), s_cols);
                w.as_mut_slice().fill(0.0);
                scatter_w_grouped(w, &unit.cols, a);
                mat.matmul_into(w, s);
            }
            (Some(g), _) => {
                let offsets = g.grp.offsets();
                let order = g.grp.positions();
                let gather_keys = &g.gather_keys[..];
                let sdata = s.as_mut_slice();
                par::parallel_fill_rows(sdata, s_cols, 4 * s_cols, |start, _end, chunk| {
                    stage1_grouped(
                        mat,
                        start / s_cols,
                        chunk,
                        s_cols,
                        offsets,
                        order,
                        gather_keys,
                        a,
                    );
                });
            }
            (None, _) => {
                let (scatter, gather) = match self.mode {
                    GvtPolicy::SparseRight => (unit.cols.targets(), unit.cols.drugs()),
                    _ => (unit.cols.drugs(), unit.cols.targets()),
                };
                let sdata = s.as_mut_slice();
                sdata.fill(0.0);
                par::parallel_fill_rows(sdata, s_cols, 4 * s_cols, |start, _end, chunk| {
                    stage1_scatter(mat, start / s_cols, chunk, s_cols, scatter, gather, a);
                });
            }
        }
    }

    /// Execute every (sparse-mode) stage-1 unit as **one** chunk-claim
    /// job on the shared runtime pool: units write disjoint `S` buffers,
    /// so their row chunks are mutually independent and can interleave
    /// freely across workers. The serial per-unit loop runs one
    /// `parallel_fill_rows` barrier per unit — MLPK's 4 stage-1 passes
    /// paid 4 synchronization rounds per mat-vec; this path pays one.
    ///
    /// Determinism: the unit of work is whole `S` rows with per-row
    /// operation sequences identical to the per-unit path (the 4-row
    /// blocking in the kernels changes interleaving *across* rows, never
    /// the op order *within* a row), so the output is bit-identical to
    /// the serial loop for any worker count and claim order — pinned by
    /// `tests/pool_determinism.rs`.
    ///
    /// Chunk tables live in the workspace; after warmup this performs no
    /// heap allocation (pinned by `tests/alloc_free.rs`).
    // lint: alloc_free — chunk tables reuse ws.s1_chunks/s1_bases
    // capacity; S buffers grow once via ensure_mat.
    fn exec_stage1_concurrent(
        &self,
        ctx: &TermContext<'_>,
        a: &[f64],
        ws: &mut GvtWorkspace,
    ) {
        let threads = par::num_threads();
        ws.s1_chunks.clear();
        ws.s1_bases.clear();
        for (k, unit) in self.stage1.iter().enumerate() {
            let s = &mut ws.s[k];
            ensure_mat(s, unit.s_rows, unit.s_cols);
            ws.s1_bases.push(s.as_mut_slice().as_mut_ptr() as usize);
            if unit.s_rows == 0 || unit.s_cols == 0 {
                continue;
            }
            if unit.grouped.is_none() {
                // The streamed kernel accumulates into S; the grouped
                // kernel stores every cell (same contract as
                // `exec_stage1`).
                s.as_mut_slice().fill(0.0);
            }
            // Same granularity as the per-unit path (min_per_thread =
            // 4·s_cols there ⇒ ≥ 4 rows per chunk), up to 4 chunks per
            // worker so stragglers get stolen.
            let rows = unit.s_rows;
            let max_chunks = (rows / 4).max(1);
            let chunks = (threads * 4).min(max_chunks);
            let chunk_rows = rows.div_ceil(chunks);
            let mut r0 = 0usize;
            while r0 < rows {
                let r1 = (r0 + chunk_rows).min(rows);
                ws.s1_chunks.push((k as u32, r0 as u32, r1 as u32));
                r0 = r1;
            }
        }
        if ws.s1_chunks.is_empty() {
            return;
        }
        let table = &ws.s1_chunks;
        let bases = &ws.s1_bases;
        let units = &self.stage1;
        let mode = self.mode;
        // lint: allow(determinism, whole-S-rows chunks with per-row op
        // order identical to the serial path — bit-identical for any
        // worker count; pinned by tests/pool_determinism.rs)
        par::run_chunks(table.len(), |ci| {
            let (uk, r0, r1) = table[ci];
            let (uk, r0, r1) = (uk as usize, r0 as usize, r1 as usize);
            let unit = &units[uk];
            let mat = dense_mat(ctx, unit.mat);
            let s_cols = unit.s_cols;
            // SAFETY: chunk indices map to disjoint row ranges of
            // per-unit-distinct S buffers (sized by `ensure_mat` above,
            // untouched through references while `run_chunks` blocks);
            // each chunk is claimed by exactly one thread.
            let chunk = unsafe {
                std::slice::from_raw_parts_mut(
                    (bases[uk] as *mut f64).add(r0 * s_cols),
                    (r1 - r0) * s_cols,
                )
            };
            match &unit.grouped {
                Some(g) => stage1_grouped(
                    mat,
                    r0,
                    chunk,
                    s_cols,
                    g.grp.offsets(),
                    g.grp.positions(),
                    &g.gather_keys,
                    a,
                ),
                None => {
                    let (scatter, gather) = match mode {
                        GvtPolicy::SparseRight => (unit.cols.targets(), unit.cols.drugs()),
                        _ => (unit.cols.drugs(), unit.cols.targets()),
                    };
                    stage1_scatter(mat, r0, chunk, s_cols, scatter, gather, a);
                }
            }
        });
    }

    /// Multi-RHS execution: `out = Σ_terms coeff · GVT(term) · ab`, where
    /// `ab` is `n × B` row-major (row `j` holds pair `j`'s coefficient in
    /// every RHS) and `out` is `n̄ × B`. The index arrays are streamed once
    /// per stage for the whole block; `B` plays the register-reuse role
    /// the 4-row blocking plays in the single-RHS kernels.
    // lint: alloc_free — the multi-RHS hot path (stochastic trainer,
    // batched serve); block workspaces grow once, then are reused.
    pub fn execute_multi(
        &self,
        ctx: &TermContext<'_>,
        ab: &Mat,
        out: &mut Mat,
        ws: &mut GvtWorkspace,
    ) {
        assert_eq!(ab.rows(), self.n_in, "plan: coefficient block rows mismatch");
        assert_eq!(
            out.shape(),
            (self.n_out, ab.cols()),
            "plan: output block shape mismatch"
        );
        let b = ab.cols();
        out.as_mut_slice().fill(0.0);
        if b == 0 {
            return;
        }
        if self.mode == GvtPolicy::Dense && !self.stage1.is_empty() {
            // The GEMM formulation gains nothing from RHS blocking over a
            // column loop (W itself would need a third axis); fall back.
            self.execute_multi_by_columns(ctx, ab, out, ws);
            return;
        }

        for (pi, unit) in self.pooled.iter().enumerate() {
            self.exec_pooled_multi(pi, unit, ctx, ab, out, ws);
        }

        while ws.sm.len() < self.stage1.len() {
            // lint: allow(alloc, warmup-only: runs until the workspace
            // holds one S block per stage-1 unit, then never again)
            ws.sm.push(Vec::new());
        }
        for (k, unit) in self.stage1.iter().enumerate() {
            let mut sm = std::mem::take(&mut ws.sm[k]);
            self.exec_stage1_multi(unit, ctx, ab, &mut sm);
            ws.sm[k] = sm;
        }

        while ws.sm_acc.len() < self.stage2.len() {
            // lint: allow(alloc, warmup-only: one accumulator slot per
            // stage-2 unit, created on the first call at this shape)
            ws.sm_acc.push(Vec::new());
        }
        for (idx, unit) in self.stage2.iter().enumerate() {
            let lhs = dense_mat(ctx, unit.lhs);
            let (li, ri) = match self.mode {
                GvtPolicy::SparseRight => (unit.rows.targets(), unit.rows.drugs()),
                _ => (unit.rows.drugs(), unit.rows.targets()),
            };
            if unit.contributions.len() == 1 {
                let (c, k) = unit.contributions[0];
                stage2_rowdot_multi(lhs, &ws.sm[k], unit.s_cols, b, li, ri, c, out);
            } else {
                let len = unit.s_rows * unit.s_cols * b;
                let acc = &mut ws.sm_acc[idx];
                zeroed(acc, len);
                let (c0, k0) = unit.contributions[0];
                vecops::scale_into(acc, &ws.sm[k0][..len], c0);
                for &(c, k) in &unit.contributions[1..] {
                    vecops::axpy(c, &ws.sm[k][..len], acc);
                }
                stage2_rowdot_multi(lhs, acc, unit.s_cols, b, li, ri, 1.0, out);
            }
        }

        if !self.misc.is_empty() {
            self.exec_misc_multi_by_columns(ctx, ab, out, ws);
        }
    }

    /// Column-loop fallback over the whole plan (Dense-mode blocks).
    // lint: alloc_free — reuses ws.col_in/col_out across columns.
    fn execute_multi_by_columns(
        &self,
        ctx: &TermContext<'_>,
        ab: &Mat,
        out: &mut Mat,
        ws: &mut GvtWorkspace,
    ) {
        let b = ab.cols();
        let mut col_in = std::mem::take(&mut ws.col_in);
        let mut col_out = std::mem::take(&mut ws.col_out);
        zeroed(&mut col_in, self.n_in);
        zeroed(&mut col_out, self.n_out);
        for bb in 0..b {
            for j in 0..self.n_in {
                col_in[j] = ab[(j, bb)];
            }
            self.execute(ctx, &col_in, &mut col_out, ws);
            for i in 0..self.n_out {
                out[(i, bb)] += col_out[i];
            }
        }
        ws.col_in = col_in;
        ws.col_out = col_out;
    }

    /// Misc terms under multi-RHS: per-column with reused scratch (these
    /// paths are `O(n + n̄)`-ish; blocking would not pay for itself).
    // lint: alloc_free — reuses ws.col_in/col_out and ws.scratch.
    fn exec_misc_multi_by_columns(
        &self,
        ctx: &TermContext<'_>,
        ab: &Mat,
        out: &mut Mat,
        ws: &mut GvtWorkspace,
    ) {
        let b = ab.cols();
        let mut col_in = std::mem::take(&mut ws.col_in);
        let mut col_out = std::mem::take(&mut ws.col_out);
        zeroed(&mut col_in, self.n_in);
        for bb in 0..b {
            for j in 0..self.n_in {
                col_in[j] = ab[(j, bb)];
            }
            zeroed(&mut col_out, self.n_out);
            for mt in &self.misc {
                mt.term.matvec_transformed_with(
                    ctx,
                    &mt.rows,
                    &mt.cols,
                    &col_in,
                    self.mode,
                    &mut col_out,
                    &mut ws.scratch,
                );
            }
            for i in 0..self.n_out {
                out[(i, bb)] += col_out[i];
            }
        }
        ws.col_in = col_in;
        ws.col_out = col_out;
    }

    // lint: alloc_free — PW/PV blocks grow once per shape in ws.
    fn exec_pooled_multi(
        &self,
        pi: usize,
        unit: &PooledUnit,
        ctx: &TermContext<'_>,
        ab: &Mat,
        out: &mut Mat,
        ws: &mut GvtWorkspace,
    ) {
        let mat = dense_mat(ctx, unit.mat);
        let (mr, mc) = mat.shape();
        let b = ab.cols();
        let pw = unit_mat(&mut ws.pw, pi);
        ensure_mat(pw, mc, b);
        pw.as_mut_slice().fill(0.0);
        let stream =
            if unit.pool_targets { unit.cols.targets() } else { unit.cols.drugs() };
        for (j, &sj) in stream.iter().enumerate() {
            vecops::axpy(1.0, ab.row(j), pw.row_mut(sj as usize));
        }
        let pv = unit_mat(&mut ws.pv, pi);
        ensure_mat(pv, mr, b);
        mat.matmul_into(pw, pv);
        for (c, rows, gather_targets) in &unit.gathers {
            let g = if *gather_targets { rows.targets() } else { rows.drugs() };
            for i in 0..self.n_out {
                vecops::axpy(*c, pv.row(g[i] as usize), out.row_mut(i));
            }
        }
    }

    // lint: alloc_free — fills the caller's S block in place.
    fn exec_stage1_multi(
        &self,
        unit: &Stage1Unit,
        ctx: &TermContext<'_>,
        ab: &Mat,
        sm: &mut Vec<f64>,
    ) {
        let mat = dense_mat(ctx, unit.mat);
        let b = ab.cols();
        let s_cols = unit.s_cols;
        zeroed(sm, unit.s_rows * s_cols * b);
        if unit.s_rows == 0 || s_cols == 0 || b == 0 {
            return;
        }
        let abdata = ab.as_slice();
        let row_len = s_cols * b;
        match &unit.grouped {
            Some(g) => {
                let offsets = g.grp.offsets();
                let order = g.grp.positions();
                let gather_keys = &g.gather_keys[..];
                par::parallel_fill_rows(&mut sm[..], row_len, 2 * row_len, |start, _end, chunk| {
                    let r0 = start / row_len;
                    let rows_here = chunk.len() / row_len;
                    for r in 0..rows_here {
                        let mrow = mat.row(r0 + r);
                        let srow = &mut chunk[r * row_len..(r + 1) * row_len];
                        for d in 0..s_cols {
                            let cell = &mut srow[d * b..(d + 1) * b];
                            let lo = offsets[d] as usize;
                            let hi = offsets[d + 1] as usize;
                            for k in lo..hi {
                                let mv = mrow[gather_keys[k] as usize];
                                let j = order[k] as usize;
                                let arow = &abdata[j * b..(j + 1) * b];
                                for (cb, ab_j) in cell.iter_mut().zip(arow) {
                                    *cb += mv * ab_j;
                                }
                            }
                        }
                    }
                });
            }
            None => {
                let (scatter, gather) = match self.mode {
                    GvtPolicy::SparseRight => (unit.cols.targets(), unit.cols.drugs()),
                    _ => (unit.cols.drugs(), unit.cols.targets()),
                };
                par::parallel_fill_rows(&mut sm[..], row_len, 2 * row_len, |start, _end, chunk| {
                    let r0 = start / row_len;
                    let rows_here = chunk.len() / row_len;
                    for r in 0..rows_here {
                        let mrow = mat.row(r0 + r);
                        let srow = &mut chunk[r * row_len..(r + 1) * row_len];
                        for j in 0..scatter.len() {
                            let mv = mrow[gather[j] as usize];
                            let dst = scatter[j] as usize;
                            let cell = &mut srow[dst * b..(dst + 1) * b];
                            let arow = &abdata[j * b..(j + 1) * b];
                            for (cb, ab_j) in cell.iter_mut().zip(arow) {
                                *cb += mv * ab_j;
                            }
                        }
                    }
                });
            }
        }
    }
}

/// Grouped stage-1 kernel: for each `S` row `r` in this worker's band and
/// each column `d`, accumulate `Σ_{k ∈ group(d)} M[r, gather_keys[k]] ·
/// a[order[k]]` in registers and store once. Processes four rows per pass
/// over the index streams (same bandwidth argument as `stage1_scatter`'s
/// blocking; `GVT_RLS_STAGE1_1ROW=1` disables it for A/B runs).
// lint: alloc_free — register-blocked inner kernel; splits slices only.
#[allow(clippy::too_many_arguments)]
fn stage1_grouped(
    mat: &Mat,
    row0: usize,
    chunk: &mut [f64],
    row_len: usize,
    offsets: &[u32],
    order: &[u32],
    gather_keys: &[u32],
    a: &[f64],
) {
    debug_assert_eq!(offsets.len(), row_len + 1);
    let rows_here = chunk.len() / row_len;
    let mut r = 0;
    let block = !stage1_single_row();
    if block && microkernel::enabled() {
        // 8-row tiles first (GVT_RLS_MICROKERNEL=0 ablates back to the
        // 4-row/scalar passes below); each cell's group sum stays a
        // serial single accumulator, so the tile width cannot move a bit.
        r = microkernel::stage1_grouped8(mat, row0, chunk, row_len, offsets, order, gather_keys, a);
    }
    while block && r + 4 <= rows_here {
        let m0 = mat.row(row0 + r);
        let m1 = mat.row(row0 + r + 1);
        let m2 = mat.row(row0 + r + 2);
        let m3 = mat.row(row0 + r + 3);
        let (s0, rest) = chunk[r * row_len..].split_at_mut(row_len);
        let (s1, rest) = rest.split_at_mut(row_len);
        let (s2, s3full) = rest.split_at_mut(row_len);
        let s3 = &mut s3full[..row_len];
        for d in 0..row_len {
            let lo = offsets[d] as usize;
            let hi = offsets[d + 1] as usize;
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
            for k in lo..hi {
                let src = gather_keys[k] as usize;
                let aj = a[order[k] as usize];
                a0 += m0[src] * aj;
                a1 += m1[src] * aj;
                a2 += m2[src] * aj;
                a3 += m3[src] * aj;
            }
            s0[d] = a0;
            s1[d] = a1;
            s2[d] = a2;
            s3[d] = a3;
        }
        r += 4;
    }
    for rr in r..rows_here {
        let mrow = mat.row(row0 + rr);
        let srow = &mut chunk[rr * row_len..(rr + 1) * row_len];
        for d in 0..row_len {
            let lo = offsets[d] as usize;
            let hi = offsets[d + 1] as usize;
            let mut acc = 0.0;
            for k in lo..hi {
                acc += mrow[gather_keys[k] as usize] * a[order[k] as usize];
            }
            srow[d] = acc;
        }
    }
}

/// Multi-RHS stage-2 sweep: `out[i, b] += c · Σ_d lhs[li[i], d] ·
/// s[ri[i], d, b]` with `s` in `[r][d][b]` layout.
// lint: alloc_free — row-dot sweep over borrowed S/out blocks.
#[allow(clippy::too_many_arguments)]
fn stage2_rowdot_multi(
    lhs: &Mat,
    s: &[f64],
    s_cols: usize,
    b: usize,
    li: &[u32],
    ri: &[u32],
    c: f64,
    out: &mut Mat,
) {
    debug_assert_eq!(lhs.cols(), s_cols);
    let row_len = s_cols * b;
    let odata = out.as_mut_slice();
    let tiled = microkernel::enabled();
    par::parallel_fill_rows(odata, b.max(1), 2048, |start, _end, chunk| {
        let i0 = start / b.max(1);
        let rows_here = if b == 0 { 0 } else { chunk.len() / b };
        for t in 0..rows_here {
            let i = i0 + t;
            let lrow = lhs.row(li[i] as usize);
            let sbase = ri[i] as usize * row_len;
            let orow = &mut chunk[t * b..(t + 1) * b];
            if tiled {
                // 8-wide output blocks held in registers across the `d`
                // sweep; per-element order matches the scalar body below.
                microkernel::stage2_multi_row(lrow, s, sbase, b, c, orow);
            } else {
                // Scalar ablation body (GVT_RLS_MICROKERNEL=0).
                for d in 0..s_cols {
                    let l = c * lrow[d];
                    let cell = &s[sbase + d * b..sbase + (d + 1) * b];
                    for (ob, sb) in orow.iter_mut().zip(cell) {
                        *ob += l * sb;
                    }
                }
            }
        }
    });
}

/// Multi-RHS generalized vec trick for a single Kronecker term:
/// `P = R(rows) (A ⊗ B) R(cols)ᵀ AB` for a block `AB` of `B` coefficient
/// vectors (`n × B`, row-major), streaming the index arrays once for the
/// whole block. Returns the `n̄ × B` prediction block.
pub fn gvt_matmat(
    a_mat: &Mat,
    b_mat: &Mat,
    rows: &PairIndex,
    cols: &PairIndex,
    ab: &Mat,
    policy: GvtPolicy,
) -> Mat {
    assert_eq!(ab.rows(), cols.len(), "gvt_matmat: block rows != column sample size");
    assert_eq!(a_mat.rows(), rows.m(), "gvt_matmat: A rows != row-sample drug domain");
    assert_eq!(a_mat.cols(), cols.m(), "gvt_matmat: A cols != col-sample drug domain");
    assert_eq!(b_mat.rows(), rows.q(), "gvt_matmat: B rows != row-sample target domain");
    assert_eq!(b_mat.cols(), cols.q(), "gvt_matmat: B cols != col-sample target domain");
    let ctx = TermContext { d: a_mat, t: b_mat, dsq: None, tsq: None };
    let term = KroneckerTerm::new(1.0, Factor::D, Factor::T, IndexMap::Id, IndexMap::Id);
    let terms = [(term, rows.clone(), cols.clone())];
    let plan = GvtPlan::build(&terms, &ctx, policy, rows.len(), cols.len());
    let mut out = Mat::zeros(rows.len(), ab.cols());
    let mut ws = GvtWorkspace::new();
    plan.execute_multi(&ctx, ab, &mut out, &mut ws);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvt::vec_trick::{gvt_matvec, naive_matvec};
    use crate::rng::{dist, Xoshiro256};
    use crate::testing::gen;

    fn ctx_for<'a>(d: &'a Mat, t: &'a Mat) -> TermContext<'a> {
        TermContext { d, t, dsq: None, tsq: None }
    }

    /// Fused single-term plan == the unfused gvt_matvec == naive oracle,
    /// across sizes that exercise both the grouped (n ≥ s_cols) and
    /// streamed (n < s_cols) stage-1 kernels.
    #[test]
    fn single_term_plan_matches_naive_for_all_modes() {
        for (seed, n, nbar, m, q) in
            [(11u64, 60, 45, 7, 9), (12, 9, 30, 24, 21), (13, 120, 80, 6, 5)]
        {
            let mut rng = Xoshiro256::seed_from(seed);
            let am = Mat::from_vec(m, m, dist::normal_vec(&mut rng, m * m));
            let bm = Mat::from_vec(q, q, dist::normal_vec(&mut rng, q * q));
            let cols = gen::pair_sample(&mut rng, n, m, q);
            let rows = gen::pair_sample(&mut rng, nbar, m, q);
            let a = dist::normal_vec(&mut rng, n);
            let expect = naive_matvec(&am, &bm, &rows, &cols, &a);
            let ctx = ctx_for(&am, &bm);
            let term =
                KroneckerTerm::new(1.0, Factor::D, Factor::T, IndexMap::Id, IndexMap::Id);
            for policy in [
                GvtPolicy::Auto,
                GvtPolicy::SparseLeft,
                GvtPolicy::SparseRight,
                GvtPolicy::Dense,
            ] {
                let terms = [(term, rows.clone(), cols.clone())];
                let plan = GvtPlan::build(&terms, &ctx, policy, nbar, n);
                let mut ws = GvtWorkspace::new();
                let mut out = vec![0.0; nbar];
                plan.execute(&ctx, &a, &mut out, &mut ws);
                let err = crate::linalg::vecops::max_abs_diff(&out, &expect);
                assert!(err < 1e-9, "seed {seed} {policy:?}: err {err}");
                // And against the unfused path for good measure.
                let unfused = gvt_matvec(&am, &bm, &rows, &cols, &a, policy);
                let err2 = crate::linalg::vecops::max_abs_diff(&out, &unfused);
                assert!(err2 < 1e-9, "seed {seed} {policy:?} vs unfused: err {err2}");
            }
        }
    }

    /// Shared stage-1 with distinct stage-2 row samples (the
    /// Symmetric-kernel shape): one S, two sweeps, correct sum.
    #[test]
    fn shared_stage1_distinct_stage2() {
        let mut rng = Xoshiro256::seed_from(21);
        let m = 8;
        let d = gen::psd_kernel(&mut rng, m);
        let rows = gen::homogeneous_sample(&mut rng, 30, m);
        let cols = gen::homogeneous_sample(&mut rng, 40, m);
        let a = dist::normal_vec(&mut rng, 40);
        let ctx = ctx_for(&d, &d);
        let t1 = KroneckerTerm::new(1.0, Factor::D, Factor::D, IndexMap::Id, IndexMap::Id);
        let t2 =
            KroneckerTerm::new(-1.0, Factor::D, Factor::D, IndexMap::Swap, IndexMap::Id);
        let terms = [
            (t1, t1.row_map.apply(&rows), t1.col_map.apply(&cols)),
            (t2, t2.row_map.apply(&rows), t2.col_map.apply(&cols)),
        ];
        let plan = GvtPlan::build(&terms, &ctx, GvtPolicy::SparseLeft, 30, 40);
        assert_eq!(plan.stage1_count(), 1, "terms share one stage-1 pass");
        assert_eq!(plan.stage2_count(), 2);
        let mut ws = GvtWorkspace::new();
        let mut out = vec![0.0; 30];
        plan.execute(&ctx, &a, &mut out, &mut ws);
        let mut expect = vec![0.0; 30];
        for (term, r, c) in &terms {
            term.matvec_transformed(&ctx, r, c, &a, GvtPolicy::SparseLeft, &mut expect);
        }
        let err = crate::linalg::vecops::max_abs_diff(&out, &expect);
        assert!(err < 1e-9, "err {err}");
    }

    /// Stage-2 accumulation (shared rows, distinct cols — the MLPK cross
    /// term shape): weighted S's merge into one sweep, matching per-term.
    #[test]
    fn stage2_accumulation_matches_per_term() {
        let mut rng = Xoshiro256::seed_from(22);
        let m = 7;
        let d = gen::psd_kernel(&mut rng, m);
        let rows = gen::homogeneous_sample(&mut rng, 25, m);
        let cols = gen::homogeneous_sample(&mut rng, 35, m);
        let a = dist::normal_vec(&mut rng, 35);
        let ctx = ctx_for(&d, &d);
        let t1 = KroneckerTerm::new(2.0, Factor::D, Factor::D, IndexMap::Id, IndexMap::Id);
        let t2 =
            KroneckerTerm::new(-2.0, Factor::D, Factor::D, IndexMap::Id, IndexMap::Swap);
        let t3 = KroneckerTerm::new(
            -2.0,
            Factor::D,
            Factor::D,
            IndexMap::Id,
            IndexMap::DupDrug,
        );
        let terms: Vec<_> = [t1, t2, t3]
            .iter()
            .map(|t| (*t, t.row_map.apply(&rows), t.col_map.apply(&cols)))
            .collect();
        let plan = GvtPlan::build(&terms, &ctx, GvtPolicy::SparseLeft, 25, 35);
        assert_eq!(plan.stage1_count(), 3, "distinct col samples");
        assert_eq!(plan.stage2_count(), 1, "one accumulated sweep");
        let mut ws = GvtWorkspace::new();
        let mut out = vec![0.0; 25];
        plan.execute(&ctx, &a, &mut out, &mut ws);
        let mut expect = vec![0.0; 25];
        for (term, r, c) in &terms {
            term.matvec_transformed(&ctx, r, c, &a, GvtPolicy::SparseLeft, &mut expect);
        }
        let err = crate::linalg::vecops::max_abs_diff(&out, &expect);
        assert!(err < 1e-9, "err {err}");
    }

    /// gvt_matmat == per-column gvt_matvec.
    #[test]
    fn matmat_matches_column_loop() {
        let mut rng = Xoshiro256::seed_from(23);
        let (m, q, n, nbar, b) = (6, 8, 45, 30, 5);
        let am = Mat::from_vec(m, m, dist::normal_vec(&mut rng, m * m));
        let bm = Mat::from_vec(q, q, dist::normal_vec(&mut rng, q * q));
        let cols = gen::pair_sample(&mut rng, n, m, q);
        let rows = gen::pair_sample(&mut rng, nbar, m, q);
        let colvecs: Vec<Vec<f64>> =
            (0..b).map(|_| dist::normal_vec(&mut rng, n)).collect();
        let refs: Vec<&[f64]> = colvecs.iter().map(|v| v.as_slice()).collect();
        let ab = Mat::from_columns(&refs);
        for policy in [GvtPolicy::Auto, GvtPolicy::SparseLeft, GvtPolicy::SparseRight] {
            let got = gvt_matmat(&am, &bm, &rows, &cols, &ab, policy);
            for (bb, col) in colvecs.iter().enumerate() {
                let expect = gvt_matvec(&am, &bm, &rows, &cols, col, policy);
                for i in 0..nbar {
                    assert!(
                        (got[(i, bb)] - expect[i]).abs() < 1e-9,
                        "{policy:?} col {bb} row {i}"
                    );
                }
            }
        }
    }

    /// Workspace reuse: consecutive executions at the same shapes give
    /// identical results (buffers fully overwritten, not accumulated).
    #[test]
    fn workspace_reuse_is_idempotent() {
        let mut rng = Xoshiro256::seed_from(24);
        let m = 9;
        let d = gen::psd_kernel(&mut rng, m);
        let rows = gen::homogeneous_sample(&mut rng, 40, m);
        let cols = gen::homogeneous_sample(&mut rng, 40, m);
        let a = dist::normal_vec(&mut rng, 40);
        let ctx = ctx_for(&d, &d);
        let t1 = KroneckerTerm::new(1.0, Factor::D, Factor::D, IndexMap::Id, IndexMap::Id);
        let t2 =
            KroneckerTerm::new(0.5, Factor::D, Factor::D, IndexMap::Swap, IndexMap::Swap);
        let terms: Vec<_> = [t1, t2]
            .iter()
            .map(|t| (*t, t.row_map.apply(&rows), t.col_map.apply(&cols)))
            .collect();
        let plan = GvtPlan::build(&terms, &ctx, GvtPolicy::Auto, 40, 40);
        let mut ws = GvtWorkspace::new();
        let mut out1 = vec![0.0; 40];
        plan.execute(&ctx, &a, &mut out1, &mut ws);
        let mut out2 = vec![1e9; 40]; // dirty output buffer
        plan.execute(&ctx, &a, &mut out2, &mut ws);
        assert_eq!(out1, out2);
    }

    /// Empty samples flow through every unit kind without panicking.
    #[test]
    fn degenerate_samples_are_safe() {
        let d = Mat::full(3, 3, 1.5);
        let ctx = ctx_for(&d, &d);
        let empty = PairIndex::new(vec![], vec![], 3, 3);
        let some = PairIndex::new(vec![0, 2], vec![1, 1], 3, 3);
        let t = KroneckerTerm::new(1.0, Factor::D, Factor::D, IndexMap::Id, IndexMap::Id);
        // Empty column sample: output must be zeros.
        let terms = [(t, some.clone(), empty.clone())];
        let plan = GvtPlan::build(&terms, &ctx, GvtPolicy::Auto, 2, 0);
        let mut ws = GvtWorkspace::new();
        let mut out = vec![7.0; 2];
        plan.execute(&ctx, &[], &mut out, &mut ws);
        assert_eq!(out, vec![0.0, 0.0]);
        // Empty row sample: empty output.
        let terms = [(t, empty.clone(), some.clone())];
        let plan = GvtPlan::build(&terms, &ctx, GvtPolicy::Auto, 0, 2);
        let mut out: Vec<f64> = vec![];
        plan.execute(&ctx, &[0.5, -0.5], &mut out, &mut ws);
        assert!(out.is_empty());
    }
}
