//! Third-order generalized vec trick — the paper's stated open problem.
//!
//! §7: *"an open question remains under what conditions similar efficient
//! methods can be derived in general to nth order tensorial data, which
//! could be a Kronecker product of more than two kernel matrices. For
//! example, the data may consist of triplets (drug, target, cell line)."*
//!
//! This module answers the constructive half for order 3: the mat-vec
//!
//! ```text
//! p_i = Σ_j D[d̄_i, d_j] · T[t̄_i, t_j] · C[c̄_i, c_j] · a_j
//! ```
//!
//! over a sample of `n` (drug, target, cell-line) triplets factorizes by
//! peeling one mode at a time, exactly like Theorem 1:
//!
//! * stage 1 — for each cell-line row `c̄`:
//!   `S1[c̄, t, d] = Σ_j C[c̄, c_j] a_j [t_j = t][d_j = d]`  → `O(n·c̄dim)`
//! * stage 2 — for each `(c̄, t̄)`:
//!   `S2[c̄, t̄, d] = Σ_t T[t̄, t] S1[c̄, t, d]`               → dense GEMM
//! * stage 3 — gather-dot over drugs                          → `O(n̄·m)`
//!
//! Cost `O(n·c + c·q·(q + m) + n̄·m)` vs the naive `O(n·n̄)` — for the
//! triplet datasets the paper envisions (tens of drugs/targets/cell
//! lines, millions of triplets) this is the same orders-of-magnitude win
//! Theorem 1 gives for pairs. The memory price is the `c × q × m`
//! intermediate, the direct generalization of GVT's `q × m` matrix.
//! `bench_perf_ablation` exercises it; `examples/triplet.rs` trains a
//! (drug, target, cell-line) ridge model end-to-end with it.

use crate::linalg::{par, vecops, Mat};

/// A sample of `n` (drug, target, cell-line) index triplets.
#[derive(Clone, Debug)]
pub struct TripletIndex {
    drugs: Vec<u32>,
    targets: Vec<u32>,
    cells: Vec<u32>,
    m: usize,
    q: usize,
    c: usize,
}

impl TripletIndex {
    pub fn new(
        drugs: Vec<u32>,
        targets: Vec<u32>,
        cells: Vec<u32>,
        m: usize,
        q: usize,
        c: usize,
    ) -> Self {
        assert_eq!(drugs.len(), targets.len());
        assert_eq!(drugs.len(), cells.len());
        assert!(drugs.iter().all(|&d| (d as usize) < m), "drug index out of range");
        assert!(targets.iter().all(|&t| (t as usize) < q), "target index out of range");
        assert!(cells.iter().all(|&x| (x as usize) < c), "cell index out of range");
        Self { drugs, targets, cells, m, q, c }
    }

    pub fn len(&self) -> usize {
        self.drugs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.drugs.is_empty()
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn q(&self) -> usize {
        self.q
    }

    pub fn c(&self) -> usize {
        self.c
    }

    #[inline]
    pub fn drug(&self, i: usize) -> usize {
        self.drugs[i] as usize
    }

    #[inline]
    pub fn target(&self, i: usize) -> usize {
        self.targets[i] as usize
    }

    #[inline]
    pub fn cell(&self, i: usize) -> usize {
        self.cells[i] as usize
    }

    /// Sub-sample by row positions.
    pub fn subset(&self, rows: &[usize]) -> TripletIndex {
        TripletIndex::new(
            rows.iter().map(|&i| self.drugs[i]).collect(),
            rows.iter().map(|&i| self.targets[i]).collect(),
            rows.iter().map(|&i| self.cells[i]).collect(),
            self.m,
            self.q,
            self.c,
        )
    }
}

/// `p = R(rows) (D ⊗ T ⊗ C) R(cols)ᵀ a` for third-order samples.
///
/// `d: rows.m × cols.m`, `t: rows.q × cols.q`, `cmat: rows.c × cols.c`.
pub fn gvt3_matvec(
    d: &Mat,
    t: &Mat,
    cmat: &Mat,
    rows: &TripletIndex,
    cols: &TripletIndex,
    a: &[f64],
) -> Vec<f64> {
    assert_eq!(a.len(), cols.len());
    assert_eq!(d.rows(), rows.m());
    assert_eq!(d.cols(), cols.m());
    assert_eq!(t.rows(), rows.q());
    assert_eq!(t.cols(), cols.q());
    assert_eq!(cmat.rows(), rows.c());
    assert_eq!(cmat.cols(), cols.c());

    let (m_c, q_c) = (d.cols(), t.cols());
    let (q_r, c_r) = (t.rows(), cmat.rows());

    // Stage 1: peel the cell-line mode.
    // S1[c̄][t, d] = Σ_j C[c̄, c_j] · a_j at (t_j, d_j). One q_c × m_c
    // sheet per c̄ row; threaded over sheets.
    let sheet = q_c * m_c;
    let mut s1 = vec![0.0f64; c_r * sheet];
    par::parallel_fill_rows(&mut s1, sheet, sheet, |start_flat, _end, chunk| {
        let c0 = start_flat / sheet;
        for (k, sh) in chunk.chunks_mut(sheet).enumerate() {
            let crow = cmat.row(c0 + k);
            for j in 0..a.len() {
                sh[cols.target(j) * m_c + cols.drug(j)] += crow[cols.cell(j)] * a[j];
            }
        }
    });

    // Stage 2: peel the target mode with one GEMM per sheet:
    // S2[c̄] = T · S1[c̄]  (q_r × m_c).
    let mut s2 = vec![0.0f64; c_r * q_r * m_c];
    for cbar in 0..c_r {
        let sheet_in = Mat::from_vec(q_c, m_c, s1[cbar * sheet..(cbar + 1) * sheet].to_vec());
        let out = t.matmul(&sheet_in);
        s2[cbar * q_r * m_c..(cbar + 1) * q_r * m_c].copy_from_slice(out.as_slice());
    }
    drop(s1);

    // Stage 3: gather-dot over the drug mode.
    let mut p = vec![0.0; rows.len()];
    par::parallel_fill(&mut p, 2048, |start, _end, chunk| {
        for (k, pi) in chunk.iter_mut().enumerate() {
            let i = start + k;
            let drow = d.row(rows.drug(i));
            let srow =
                &s2[rows.cell(i) * q_r * m_c + rows.target(i) * m_c..][..m_c];
            *pi = vecops::dot(drow, srow);
        }
    });
    p
}

/// The third-order Kronecker kernel as a [`crate::solvers::linear_op::LinOp`],
/// so the same MINRES driver trains triplet models (see
/// `examples/triplet.rs`).
pub struct TensorKronOp {
    d: std::sync::Arc<Mat>,
    t: std::sync::Arc<Mat>,
    c: std::sync::Arc<Mat>,
    rows: TripletIndex,
    cols: TripletIndex,
}

impl TensorKronOp {
    pub fn new(
        d: std::sync::Arc<Mat>,
        t: std::sync::Arc<Mat>,
        c: std::sync::Arc<Mat>,
        rows: TripletIndex,
        cols: TripletIndex,
    ) -> Self {
        assert_eq!(d.rows(), rows.m());
        assert_eq!(d.cols(), cols.m());
        assert_eq!(t.rows(), rows.q());
        assert_eq!(t.cols(), cols.q());
        assert_eq!(c.rows(), rows.c());
        assert_eq!(c.cols(), cols.c());
        Self { d, t, c, rows, cols }
    }
}

impl crate::solvers::linear_op::LinOp for TensorKronOp {
    fn dim_out(&self) -> usize {
        self.rows.len()
    }

    fn dim_in(&self) -> usize {
        self.cols.len()
    }

    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        let p = gvt3_matvec(&self.d, &self.t, &self.c, &self.rows, &self.cols, x);
        y.copy_from_slice(&p);
    }
}

/// Naive `O(n̄ n)` third-order reference (test oracle).
pub fn naive3_matvec(
    d: &Mat,
    t: &Mat,
    cmat: &Mat,
    rows: &TripletIndex,
    cols: &TripletIndex,
    a: &[f64],
) -> Vec<f64> {
    let mut p = vec![0.0; rows.len()];
    for i in 0..rows.len() {
        let mut acc = 0.0;
        for j in 0..cols.len() {
            acc += d[(rows.drug(i), cols.drug(j))]
                * t[(rows.target(i), cols.target(j))]
                * cmat[(rows.cell(i), cols.cell(j))]
                * a[j];
        }
        p[i] = acc;
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{dist, Rng, Xoshiro256};
    use crate::testing::gen;

    fn triplet_sample(rng: &mut Xoshiro256, n: usize, m: usize, q: usize, c: usize) -> TripletIndex {
        TripletIndex::new(
            (0..n).map(|i| if i < m { i as u32 } else { rng.index(m) as u32 }).collect(),
            (0..n).map(|i| if i < q { i as u32 } else { rng.index(q) as u32 }).collect(),
            (0..n).map(|i| if i < c { i as u32 } else { rng.index(c) as u32 }).collect(),
            m,
            q,
            c,
        )
    }

    #[test]
    fn matches_naive_on_random_cases() {
        let mut rng = Xoshiro256::seed_from(300);
        for (n, nbar, m, q, c) in [(30, 20, 4, 5, 3), (80, 50, 7, 6, 5), (15, 40, 3, 3, 3)] {
            let d = gen::psd_kernel(&mut rng, m);
            let t = gen::psd_kernel(&mut rng, q);
            let cm = gen::psd_kernel(&mut rng, c);
            let cols = triplet_sample(&mut rng, n, m, q, c);
            let rows = triplet_sample(&mut rng, nbar, m, q, c);
            let a = dist::normal_vec(&mut rng, n);
            let fast = gvt3_matvec(&d, &t, &cm, &rows, &cols, &a);
            let slow = naive3_matvec(&d, &t, &cm, &rows, &cols, &a);
            let err = crate::linalg::vecops::max_abs_diff(&fast, &slow);
            assert!(err < 1e-9, "({n},{nbar},{m},{q},{c}): err {err}");
        }
    }

    #[test]
    fn reduces_to_pairwise_gvt_with_trivial_cell_mode() {
        // With a single cell line and C = [1], the third-order product is
        // exactly the pairwise GVT — the consistency anchor.
        let mut rng = Xoshiro256::seed_from(301);
        let (m, q, n) = (5, 6, 40);
        let d = gen::psd_kernel(&mut rng, m);
        let t = gen::psd_kernel(&mut rng, q);
        let ones = Mat::full(1, 1, 1.0);
        let pairs = gen::pair_sample(&mut rng, n, m, q);
        let trip = TripletIndex::new(
            pairs.drugs().to_vec(),
            pairs.targets().to_vec(),
            vec![0; n],
            m,
            q,
            1,
        );
        let a = dist::normal_vec(&mut rng, n);
        let p3 = gvt3_matvec(&d, &t, &ones, &trip, &trip, &a);
        let p2 = crate::gvt::vec_trick::gvt_matvec(
            &d,
            &t,
            &pairs,
            &pairs,
            &a,
            crate::gvt::vec_trick::GvtPolicy::Auto,
        );
        let err = crate::linalg::vecops::max_abs_diff(&p3, &p2);
        assert!(err < 1e-10, "err {err}");
    }

    #[test]
    fn operator_is_symmetric_on_training_sample() {
        let mut rng = Xoshiro256::seed_from(302);
        let (m, q, c, n) = (4, 4, 4, 30);
        let d = gen::psd_kernel(&mut rng, m);
        let t = gen::psd_kernel(&mut rng, q);
        let cm = gen::psd_kernel(&mut rng, c);
        let s = triplet_sample(&mut rng, n, m, q, c);
        let a = dist::normal_vec(&mut rng, n);
        let b = dist::normal_vec(&mut rng, n);
        let ka = gvt3_matvec(&d, &t, &cm, &s, &s, &a);
        let kb = gvt3_matvec(&d, &t, &cm, &s, &s, &b);
        let lhs: f64 = ka.iter().zip(&b).map(|(x, y)| x * y).sum();
        let rhs: f64 = a.iter().zip(&kb).map(|(x, y)| x * y).sum();
        assert!((lhs - rhs).abs() < 1e-8 * lhs.abs().max(1.0));
    }

    #[test]
    fn rejects_out_of_range_indices() {
        let r = std::panic::catch_unwind(|| {
            TripletIndex::new(vec![5], vec![0], vec![0], 5, 3, 3)
        });
        assert!(r.is_err());
    }
}
