//! The operator algebra of Definition 1 / Theorem 2.
//!
//! A pairwise kernel operator is a sum of terms `c · X(A ⊗ B) Y` where `X`,
//! `Y` are products of commutation (`P`) and unification (`Q`) operators.
//! Multiplied by sampling operators, `P`/`Q` reduce to **index plumbing**
//! (`R(d,t)P = R(t,d)`, `R(d,t)Q = R(d,d)` — proof of Corollary 1), so a
//! term is fully described by
//!
//! * a scalar coefficient,
//! * two factors (which matrix sits in each Kronecker slot, where the
//!   special factors `1` (all-ones) and `I` admit cheaper mat-vecs), and
//! * an [`IndexMap`] for the row and the column sample.
//!
//! [`KroneckerTerm::matvec`] dispatches to the generalized vec trick with
//! the fast paths:
//!
//! | factors        | algorithm                                   | cost          |
//! |----------------|---------------------------------------------|---------------|
//! | dense ⊗ dense  | GVT (Theorem 1)                             | O(nq̄ + n̄m)   |
//! | `1` in a slot  | pool-then-GEMV                              | O(n + mq + n̄) |
//! | `I` in a slot  | scatter + gather-dot                        | O(n + n̄m)     |
//! | `1 ⊗ 1`        | scalar sum                                  | O(n + n̄)      |

use crate::gvt::vec_trick::{gvt_matvec, GvtPolicy};
use crate::linalg::{par, vecops, Mat};
use crate::sparse::PairIndex;

/// Which matrix occupies a Kronecker slot.
///
/// `D`/`T` refer to the drug/target kernel matrices supplied to the op;
/// `DSq`/`TSq` to their elementwise squares (Theorem 2:
/// `Q(D⊗D)Qᵀ = D^{⊙2} ⊗ 1`); `Ones`/`Identity` to the `1` and `I`
/// operators over whichever domain the slot requires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Factor {
    D,
    T,
    DSq,
    TSq,
    Ones,
    Identity,
}

/// How a term derives its effective sample from the data sample — the
/// residue of the `P`/`Q` operators after absorption into `R`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexMap {
    /// `R(d, t)` unchanged.
    Id,
    /// `R(d,t)P = R(t,d)` — commutation.
    Swap,
    /// `R(d,t)Q = R(d,d)` — unification onto the drug slot.
    DupDrug,
    /// `R(d,t)PQ = R(t,t)` — unification onto the target slot.
    DupTarget,
}

impl IndexMap {
    /// Apply to a sample.
    pub fn apply(&self, s: &PairIndex) -> PairIndex {
        match self {
            IndexMap::Id => s.clone(),
            IndexMap::Swap => s.swapped(),
            IndexMap::DupDrug => s.dupe_drugs(),
            IndexMap::DupTarget => s.dupe_targets(),
        }
    }

    /// Does this map require a homogeneous domain (m == q)?
    pub fn needs_homogeneous(&self) -> bool {
        !matches!(self, IndexMap::Id)
    }
}

/// One summand `coeff · (left ⊗ right)` with row/column index maps.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KroneckerTerm {
    pub coeff: f64,
    pub left: Factor,
    pub right: Factor,
    pub row_map: IndexMap,
    pub col_map: IndexMap,
}

impl KroneckerTerm {
    pub const fn new(
        coeff: f64,
        left: Factor,
        right: Factor,
        row_map: IndexMap,
        col_map: IndexMap,
    ) -> Self {
        Self { coeff, left, right, row_map, col_map }
    }
}

/// Resolved matrices for a term's two slots.
pub(crate) enum SlotMatrix<'a> {
    Dense(&'a Mat),
    Ones,
    Identity,
}

/// Context holding the kernel matrices a term may reference.
///
/// `d` is the drug kernel (`m×m`), `t` the target kernel (`q×q`; for
/// homogeneous kernels pass the drug kernel in both). `dsq`/`tsq` are
/// computed lazily by [`crate::gvt::pairwise::PairwiseLinOp`].
pub struct TermContext<'a> {
    pub d: &'a Mat,
    pub t: &'a Mat,
    pub dsq: Option<&'a Mat>,
    pub tsq: Option<&'a Mat>,
}

impl<'a> TermContext<'a> {
    pub(crate) fn resolve(&self, f: Factor) -> SlotMatrix<'a> {
        match f {
            Factor::D => SlotMatrix::Dense(self.d),
            Factor::T => SlotMatrix::Dense(self.t),
            Factor::DSq => SlotMatrix::Dense(
                self.dsq.expect("DSq factor requested but not precomputed"),
            ),
            Factor::TSq => SlotMatrix::Dense(
                self.tsq.expect("TSq factor requested but not precomputed"),
            ),
            Factor::Ones => SlotMatrix::Ones,
            Factor::Identity => SlotMatrix::Identity,
        }
    }
}

impl KroneckerTerm {
    /// `out += coeff · R(row_map(rows)) (left ⊗ right) R(col_map(cols))ᵀ a`.
    ///
    /// Applies the index maps on the fly; the hot path
    /// ([`crate::gvt::pairwise::PairwiseLinOp`]) pre-applies them once at
    /// construction and calls [`Self::matvec_transformed`] instead.
    pub fn matvec_accumulate(
        &self,
        ctx: &TermContext<'_>,
        rows: &PairIndex,
        cols: &PairIndex,
        a: &[f64],
        policy: GvtPolicy,
        out: &mut [f64],
    ) {
        let rows_t = self.row_map.apply(rows);
        let cols_t = self.col_map.apply(cols);
        self.matvec_transformed(ctx, &rows_t, &cols_t, a, policy, out);
    }

    /// Like [`Self::matvec_accumulate`] but `rows_t`/`cols_t` are already
    /// the transformed samples (`row_map(rows)`, `col_map(cols)`).
    ///
    /// Fast paths for `Ones`/`Identity` factors; dense×dense falls through
    /// to [`gvt_matvec`]. Allocates internal scratch — the hot path
    /// ([`crate::gvt::plan::GvtPlan`]) uses
    /// [`Self::matvec_transformed_with`] with a reused buffer instead.
    pub fn matvec_transformed(
        &self,
        ctx: &TermContext<'_>,
        rows_t: &PairIndex,
        cols_t: &PairIndex,
        a: &[f64],
        policy: GvtPolicy,
        out: &mut [f64],
    ) {
        let mut scratch = Vec::new();
        self.matvec_transformed_with(ctx, rows_t, cols_t, a, policy, out, &mut scratch);
    }

    /// [`Self::matvec_transformed`] with caller-provided scratch: after the
    /// first call at a given size, no heap allocation happens on any
    /// `Ones`/`Identity` fast path (`scratch` is cleared and reused). The
    /// dense×dense arm still allocates its own `S` — the fused plan never
    /// routes dense×dense terms here.
    pub(crate) fn matvec_transformed_with(
        &self,
        ctx: &TermContext<'_>,
        rows_t: &PairIndex,
        cols_t: &PairIndex,
        a: &[f64],
        policy: GvtPolicy,
        out: &mut [f64],
        scratch: &mut Vec<f64>,
    ) {
        assert_eq!(out.len(), rows_t.len());
        assert_eq!(a.len(), cols_t.len());
        let left = ctx.resolve(self.left);
        let right = ctx.resolve(self.right);
        let c = self.coeff;
        // Zeroed scratch of `len` without shrinking capacity.
        let zeroed = |scratch: &mut Vec<f64>, len: usize| {
            scratch.clear();
            scratch.resize(len, 0.0);
        };
        match (left, right) {
            (SlotMatrix::Ones, SlotMatrix::Ones) => {
                // p_i = Σ_j a_j, constant.
                let s: f64 = a.iter().sum();
                for o in out.iter_mut() {
                    *o += c * s;
                }
            }
            (SlotMatrix::Dense(am), SlotMatrix::Ones) => {
                // Pool over drugs then one GEMV: p_i = (A w)[d̄_i],
                // w[d] = Σ_{j: d_j = d} a_j.
                zeroed(scratch, am.cols() + am.rows());
                let (w, v) = scratch.split_at_mut(am.cols());
                for j in 0..a.len() {
                    w[cols_t.drug(j)] += a[j];
                }
                am.matvec_into(w, v);
                for (i, o) in out.iter_mut().enumerate() {
                    *o += c * v[rows_t.drug(i)];
                }
            }
            (SlotMatrix::Ones, SlotMatrix::Dense(bm)) => {
                zeroed(scratch, bm.cols() + bm.rows());
                let (w, v) = scratch.split_at_mut(bm.cols());
                for j in 0..a.len() {
                    w[cols_t.target(j)] += a[j];
                }
                bm.matvec_into(w, v);
                for (i, o) in out.iter_mut().enumerate() {
                    *o += c * v[rows_t.target(i)];
                }
            }
            (SlotMatrix::Dense(am), SlotMatrix::Identity) => {
                // B = I over targets: p_i = Σ_{j: t_j = t̄_i} A[d̄_i, d_j]a_j.
                // Scatter W[t, d] then contiguous row dots.
                assert_eq!(
                    rows_t.q(),
                    cols_t.q(),
                    "Identity factor needs matching target domains"
                );
                let wc = am.cols();
                zeroed(scratch, cols_t.q() * wc);
                for j in 0..a.len() {
                    scratch[cols_t.target(j) * wc + cols_t.drug(j)] += a[j];
                }
                accumulate_rowdot(am, scratch, wc, rows_t.drugs(), rows_t.targets(), c, out);
            }
            (SlotMatrix::Identity, SlotMatrix::Dense(bm)) => {
                assert_eq!(
                    rows_t.m(),
                    cols_t.m(),
                    "Identity factor needs matching drug domains"
                );
                let wc = bm.cols();
                zeroed(scratch, cols_t.m() * wc);
                for j in 0..a.len() {
                    scratch[cols_t.drug(j) * wc + cols_t.target(j)] += a[j];
                }
                accumulate_rowdot(bm, scratch, wc, rows_t.targets(), rows_t.drugs(), c, out);
            }
            (SlotMatrix::Identity, SlotMatrix::Identity) => {
                // p_i = Σ_{j: d_j=d̄_i, t_j=t̄_i} a_j — sparse diagonal-ish.
                let wc = cols_t.q();
                zeroed(scratch, cols_t.m() * wc);
                for j in 0..a.len() {
                    scratch[cols_t.drug(j) * wc + cols_t.target(j)] += a[j];
                }
                for (i, o) in out.iter_mut().enumerate() {
                    *o += c * scratch[rows_t.drug(i) * wc + rows_t.target(i)];
                }
            }
            (SlotMatrix::Identity, SlotMatrix::Ones) => {
                zeroed(scratch, cols_t.m());
                for j in 0..a.len() {
                    scratch[cols_t.drug(j)] += a[j];
                }
                for (i, o) in out.iter_mut().enumerate() {
                    *o += c * scratch[rows_t.drug(i)];
                }
            }
            (SlotMatrix::Ones, SlotMatrix::Identity) => {
                zeroed(scratch, cols_t.q());
                for j in 0..a.len() {
                    scratch[cols_t.target(j)] += a[j];
                }
                for (i, o) in out.iter_mut().enumerate() {
                    *o += c * scratch[rows_t.target(i)];
                }
            }
            (SlotMatrix::Dense(am), SlotMatrix::Dense(bm)) => {
                let p = gvt_matvec(am, bm, rows_t, cols_t, a, policy);
                vecops::axpy(c, &p, out);
            }
        }
    }

    /// Evaluate this term's contribution to a single kernel entry — the
    /// `O(1)` scalar form used by the explicit-matrix oracle tests.
    pub fn entry(
        &self,
        ctx: &TermContext<'_>,
        row: (usize, usize),
        col: (usize, usize),
    ) -> f64 {
        let (rd, rt) = match self.row_map {
            IndexMap::Id => row,
            IndexMap::Swap => (row.1, row.0),
            IndexMap::DupDrug => (row.0, row.0),
            IndexMap::DupTarget => (row.1, row.1),
        };
        let (cd, ct) = match self.col_map {
            IndexMap::Id => col,
            IndexMap::Swap => (col.1, col.0),
            IndexMap::DupDrug => (col.0, col.0),
            IndexMap::DupTarget => (col.1, col.1),
        };
        let lv = match self.left {
            Factor::D => ctx.d[(rd, cd)],
            Factor::T => ctx.t[(rd, cd)],
            Factor::DSq => ctx.d[(rd, cd)] * ctx.d[(rd, cd)],
            Factor::TSq => ctx.t[(rd, cd)] * ctx.t[(rd, cd)],
            Factor::Ones => 1.0,
            Factor::Identity => {
                if rd == cd {
                    1.0
                } else {
                    0.0
                }
            }
        };
        let rv = match self.right {
            Factor::D => ctx.d[(rt, ct)],
            Factor::T => ctx.t[(rt, ct)],
            Factor::DSq => ctx.d[(rt, ct)] * ctx.d[(rt, ct)],
            Factor::TSq => ctx.t[(rt, ct)] * ctx.t[(rt, ct)],
            Factor::Ones => 1.0,
            Factor::Identity => {
                if rt == ct {
                    1.0
                } else {
                    0.0
                }
            }
        };
        self.coeff * lv * rv
    }
}

/// `out[i] += c · ⟨lhs[li[i], :], w[ri[i]·w_cols .. +w_cols]⟩`, threaded.
/// `w` is a row-major matrix given as a raw slice so callers can hand in
/// reused workspace buffers (the fused plan) as well as `Mat` data.
pub(crate) fn accumulate_rowdot(
    lhs: &Mat,
    w: &[f64],
    w_cols: usize,
    li: &[u32],
    ri: &[u32],
    c: f64,
    out: &mut [f64],
) {
    debug_assert_eq!(lhs.cols(), w_cols);
    debug_assert_eq!(w.len() % w_cols.max(1), 0);
    // 1024 rows/chunk (re-tuned from 2048 for the pooled runtime).
    par::parallel_fill(out, 1024, |start, _end, chunk| {
        for (k, o) in chunk.iter_mut().enumerate() {
            let i = start + k;
            let r = ri[i] as usize;
            *o += c * vecops::dot(lhs.row(li[i] as usize), &w[r * w_cols..(r + 1) * w_cols]);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{dist, Xoshiro256};
    use crate::testing::gen;

    /// Every fast path must equal the dense `entry()`-based naive matvec.
    #[test]
    fn fast_paths_match_entry_oracle() {
        let mut rng = Xoshiro256::seed_from(31);
        let m = 6;
        let q = 6; // homogeneous so all index maps are legal
        let d = gen::psd_kernel(&mut rng, m);
        let t = gen::psd_kernel(&mut rng, q);
        let dsq = d.hadamard_square();
        let tsq = t.hadamard_square();
        let ctx = TermContext { d: &d, t: &t, dsq: Some(&dsq), tsq: Some(&tsq) };
        let rows = gen::pair_sample(&mut rng, 25, m, q);
        let cols = gen::pair_sample(&mut rng, 40, m, q);
        let a = dist::normal_vec(&mut rng, 40);

        let factors = [
            Factor::D,
            Factor::T,
            Factor::DSq,
            Factor::TSq,
            Factor::Ones,
            Factor::Identity,
        ];
        let maps = [IndexMap::Id, IndexMap::Swap, IndexMap::DupDrug, IndexMap::DupTarget];
        for &left in &factors {
            for &right in &factors {
                for &rm in &maps {
                    for &cm in &maps {
                        let term = KroneckerTerm::new(1.25, left, right, rm, cm);
                        let mut fast = vec![0.0; rows.len()];
                        term.matvec_accumulate(
                            &ctx,
                            &rows,
                            &cols,
                            &a,
                            GvtPolicy::Auto,
                            &mut fast,
                        );
                        // Naive via entry().
                        let mut naive = vec![0.0; rows.len()];
                        for i in 0..rows.len() {
                            for j in 0..cols.len() {
                                naive[i] += term.entry(
                                    &ctx,
                                    (rows.drug(i), rows.target(i)),
                                    (cols.drug(j), cols.target(j)),
                                ) * a[j];
                            }
                        }
                        let err = vecops::max_abs_diff(&fast, &naive);
                        assert!(
                            err < 1e-9,
                            "term {left:?}⊗{right:?} maps ({rm:?},{cm:?}): err {err}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn accumulation_adds_terms() {
        let mut rng = Xoshiro256::seed_from(32);
        let d = gen::psd_kernel(&mut rng, 4);
        let t = gen::psd_kernel(&mut rng, 4);
        let ctx = TermContext { d: &d, t: &t, dsq: None, tsq: None };
        let rows = gen::pair_sample(&mut rng, 10, 4, 4);
        let cols = rows.clone();
        let a = dist::normal_vec(&mut rng, 10);
        let t1 = KroneckerTerm::new(1.0, Factor::D, Factor::T, IndexMap::Id, IndexMap::Id);
        let t2 = KroneckerTerm::new(2.0, Factor::D, Factor::T, IndexMap::Id, IndexMap::Id);
        let mut out1 = vec![0.0; 10];
        t1.matvec_accumulate(&ctx, &rows, &cols, &a, GvtPolicy::Auto, &mut out1);
        t1.matvec_accumulate(&ctx, &rows, &cols, &a, GvtPolicy::Auto, &mut out1);
        let mut out2 = vec![0.0; 10];
        t2.matvec_accumulate(&ctx, &rows, &cols, &a, GvtPolicy::Auto, &mut out2);
        assert!(vecops::max_abs_diff(&out1, &out2) < 1e-12);
    }

    #[test]
    fn index_maps_apply_correctly() {
        let s = PairIndex::new(vec![0, 2], vec![1, 1], 3, 3);
        let sw = IndexMap::Swap.apply(&s);
        assert_eq!(sw.drug(0), 1);
        assert_eq!(sw.target(1), 2);
        let dd = IndexMap::DupDrug.apply(&s);
        assert_eq!((dd.drug(1), dd.target(1)), (2, 2));
        let dt = IndexMap::DupTarget.apply(&s);
        assert_eq!((dt.drug(0), dt.target(0)), (1, 1));
    }
}
