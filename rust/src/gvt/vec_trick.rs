//! Theorem 1 — the generalized vec trick (GVT).
//!
//! Computes `p = R(d̄,t̄) (A ⊗ B) R(d,t)ᵀ a` without materializing the
//! `n̄ × n` kernel matrix, where
//!
//! * `A ∈ R^{m_r × m_c}` is the drug-side factor (rows indexed by the row
//!   sample's drug domain, columns by the column sample's),
//! * `B ∈ R^{q_r × q_c}` is the target-side factor,
//! * `rows` is the sample indexing output entries (`n̄` pairs),
//! * `cols` is the sample indexing input entries (`n` pairs).
//!
//! Entry-wise: `p_i = Σ_j A[d̄_i, d_j] · B[t̄_i, t_j] · a_j`.
//!
//! Two sparse factorizations exist, mirroring the `O(min(q̄n + mn̄,
//! m̄n + qn̄))` bound of the theorem (note the roles of row/col samples):
//!
//! * **left**: `S[t̄, d] = Σ_j B[t̄, t_j] a_j [d_j = d]`, then
//!   `p_i = ⟨A[d̄_i, :], S[t̄_i, :]⟩` — cost `O(n·q_r + n̄·m_c)`.
//! * **right**: `S[d̄, t] = Σ_j A[d̄, d_j] a_j [t_j = t]`, then
//!   `p_i = ⟨B[t̄_i, :], S[d̄_i, :]⟩` — cost `O(n·m_r + n̄·q_c)`.
//!
//! plus a **dense** formulation (scatter → GEMM → gather-dot) that trades
//! `O(n·q_r)` irregular scalar work for an `O(q_r·q_c·m_c)` vectorized
//! GEMM — the formulation the JAX/Pallas artifact implements, and faster
//! on dense samples (see bench_gvt_vs_explicit and rust/DESIGN.md
//! §Hardware-Adaptation).

use crate::linalg::{par, Mat};
use crate::sparse::PairIndex;
use std::sync::OnceLock;

/// Which GVT factorization to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GvtPolicy {
    /// Pick the cheaper factorization from the cost model, switching to
    /// the dense path when the sample is dense enough to favor GEMM.
    Auto,
    /// Force the `S ∈ R^{q_r × m_c}` sparse factorization.
    SparseLeft,
    /// Force the `S ∈ R^{m_r × q_c}` sparse factorization.
    SparseRight,
    /// Force scatter → GEMM → gather-dot.
    Dense,
}

impl GvtPolicy {
    /// Canonical name (model artifacts, CLI flags, bench labels).
    pub fn name(&self) -> &'static str {
        match self {
            GvtPolicy::Auto => "auto",
            GvtPolicy::SparseLeft => "sparse-left",
            GvtPolicy::SparseRight => "sparse-right",
            GvtPolicy::Dense => "dense",
        }
    }

    /// Parse a policy name (inverse of [`Self::name`], plus aliases).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(Self::Auto),
            "sparse-left" | "sparseleft" | "left" => Some(Self::SparseLeft),
            "sparse-right" | "sparseright" | "right" => Some(Self::SparseRight),
            "dense" => Some(Self::Dense),
            _ => None,
        }
    }
}

/// Density threshold above which `Auto` prefers the dense GEMM path.
/// Tuned in the §Perf pass (see rust/DESIGN.md §Cost-Model): the GEMM
/// runs ~8 f64 FMAs/cycle while the sparse path does ~1 gather-multiply
/// per cycle.
const DENSE_DENSITY_THRESHOLD: f64 = 0.10;

/// `p = R(rows) (A ⊗ B) R(cols)ᵀ a` — see module docs.
///
/// Shape requirements (checked):
/// `A: rows.m() × cols.m()`, `B: rows.q() × cols.q()`,
/// `a.len() == cols.len()`; returns `p` with `rows.len()` entries.
pub fn gvt_matvec(
    a_mat: &Mat,
    b_mat: &Mat,
    rows: &PairIndex,
    cols: &PairIndex,
    a: &[f64],
    policy: GvtPolicy,
) -> Vec<f64> {
    check_shapes(a_mat, b_mat, rows, cols, a);
    match policy {
        GvtPolicy::SparseLeft => sparse_left(a_mat, b_mat, rows, cols, a),
        GvtPolicy::SparseRight => sparse_right(a_mat, b_mat, rows, cols, a),
        GvtPolicy::Dense => dense(a_mat, b_mat, rows, cols, a),
        GvtPolicy::Auto => {
            match choose_policy(cols.len(), rows.len(), a_mat.shape(), b_mat.shape()) {
                GvtPolicy::Dense => dense(a_mat, b_mat, rows, cols, a),
                GvtPolicy::SparseRight => sparse_right(a_mat, b_mat, rows, cols, a),
                _ => sparse_left(a_mat, b_mat, rows, cols, a),
            }
        }
    }
}

/// The `Auto` cost model, shared with the fused-plan builder
/// ([`crate::gvt::plan::GvtPlan`]): returns the concrete factorization
/// (`SparseLeft`/`SparseRight`/`Dense`, never `Auto`) the cost
/// expressions favor for a term of the given shapes.
pub(crate) fn choose_policy(
    n: usize,
    nbar: usize,
    a_shape: (usize, usize),
    b_shape: (usize, usize),
) -> GvtPolicy {
    let n = n as f64;
    let nbar = nbar as f64;
    let (m_r, m_c) = a_shape;
    let (q_r, q_c) = b_shape;
    let cost_left = n * q_r as f64 + nbar * m_c as f64;
    let cost_right = n * m_r as f64 + nbar * q_c as f64;
    // Dense path: GEMM flops with a vectorization discount, only
    // competitive when the sample covers a decent fraction of the
    // complete q×m grid. §Perf: the discount was measured at ~2×
    // against the 4-row-blocked sparse stage 1 (an 8× guess made
    // Auto pick Dense where SparseLeft was 1.5× faster — see
    // rust/DESIGN.md §Perf).
    let density = n / (q_c as f64 * m_c as f64).max(1.0);
    let cost_dense = (q_r as f64 * q_c as f64 * m_c as f64) / 2.0 + n + nbar * m_c as f64;
    if density >= DENSE_DENSITY_THRESHOLD && cost_dense < cost_left.min(cost_right) {
        GvtPolicy::Dense
    } else if cost_left <= cost_right {
        GvtPolicy::SparseLeft
    } else {
        GvtPolicy::SparseRight
    }
}

fn check_shapes(a_mat: &Mat, b_mat: &Mat, rows: &PairIndex, cols: &PairIndex, a: &[f64]) {
    assert_eq!(a.len(), cols.len(), "gvt: coefficient length != column sample size");
    assert_eq!(a_mat.rows(), rows.m(), "gvt: A rows != row-sample drug domain");
    assert_eq!(a_mat.cols(), cols.m(), "gvt: A cols != col-sample drug domain");
    assert_eq!(b_mat.rows(), rows.q(), "gvt: B rows != row-sample target domain");
    assert_eq!(b_mat.cols(), cols.q(), "gvt: B cols != col-sample target domain");
}

/// Left factorization: `S ∈ R^{q_r × m_c}`, stage 1 `O(n·q_r)`, stage 2
/// `O(n̄·m_c)`. Both stages threaded.
fn sparse_left(
    a_mat: &Mat,
    b_mat: &Mat,
    rows: &PairIndex,
    cols: &PairIndex,
    a: &[f64],
) -> Vec<f64> {
    let q_r = b_mat.rows();
    let m_c = a_mat.cols();
    // Stage 1: each worker owns a band of S rows (t̄ values) and streams
    // the whole column sample once: S[t̄, d_j] += B[t̄, t_j] * a_j.
    let mut s = Mat::zeros(q_r, m_c);
    {
        let sdata = s.as_mut_slice();
        par::parallel_fill_rows(sdata, m_c.max(1), 4 * m_c.max(1), |start_flat, _end, chunk| {
            stage1_scatter(b_mat, start_flat / m_c, chunk, m_c, cols.drugs(), cols.targets(), a);
        });
    }
    // Stage 2: p_i = ⟨A[d̄_i, :], S[t̄_i, :]⟩ — contiguous row dots.
    stage2_rowdot(a_mat, &s, rows.drugs(), rows.targets())
}

/// Right factorization: mirror image of [`sparse_left`].
fn sparse_right(
    a_mat: &Mat,
    b_mat: &Mat,
    rows: &PairIndex,
    cols: &PairIndex,
    a: &[f64],
) -> Vec<f64> {
    let m_r = a_mat.rows();
    let q_c = b_mat.cols();
    let mut s = Mat::zeros(m_r, q_c);
    {
        let sdata = s.as_mut_slice();
        par::parallel_fill_rows(sdata, q_c.max(1), 4 * q_c.max(1), |start_flat, _end, chunk| {
            // Mirror image: S rows indexed by drugs, gathers by drug index,
            // scatters by target index.
            stage1_scatter(a_mat, start_flat / q_c, chunk, q_c, cols.targets(), cols.drugs(), a);
        });
    }
    // p_i = ⟨B[t̄_i, :], S[d̄_i, :]⟩.
    stage2_rowdot(b_mat, &s, rows.targets(), rows.drugs())
}

/// Dense complete-data formulation (the Roth vec trick on a scattered
/// coefficient matrix): `W[t_j, d_j] += a_j`; `S = B·W`; gather-dot.
fn dense(
    a_mat: &Mat,
    b_mat: &Mat,
    rows: &PairIndex,
    cols: &PairIndex,
    a: &[f64],
) -> Vec<f64> {
    let q_c = b_mat.cols();
    let m_c = a_mat.cols();
    let mut w = Mat::zeros(q_c, m_c);
    scatter_w_grouped(&mut w, cols, a);
    let s = b_mat.matmul(&w); // q_r × m_c
    stage2_rowdot(a_mat, &s, rows.drugs(), rows.targets())
}

/// `W[t_j, d_j] += a_j` over a zeroed `W` (`cols.q() × cols.m()`),
/// parallelized via the cached `by_target` CSR grouping: each worker owns
/// a band of W rows and walks only the pairs landing in it, so the
/// scatter is race-free without atomics. §Perf: the previous serial loop
/// was the only single-threaded stage of the dense path.
pub(crate) fn scatter_w_grouped(w: &mut Mat, cols: &PairIndex, a: &[f64]) {
    debug_assert_eq!(w.shape(), (cols.q(), cols.m()));
    debug_assert_eq!(a.len(), cols.len());
    let m_c = cols.m();
    let grp = cols.by_target();
    let drugs = cols.drugs();
    let wdata = w.as_mut_slice();
    par::parallel_fill_rows(wdata, m_c.max(1), 16 * m_c.max(1), |start_flat, _end, chunk| {
        let t0 = start_flat / m_c.max(1);
        let rows_here = if m_c == 0 { 0 } else { chunk.len() / m_c };
        for r in 0..rows_here {
            let t = t0 + r;
            let wrow = &mut chunk[r * m_c..(r + 1) * m_c];
            for &p in grp.group(t) {
                wrow[drugs[p as usize] as usize] += a[p as usize];
            }
        }
    });
}

/// Stage-1 kernel shared by both sparse factorizations: for each S row
/// `r` in this worker's band, `S[r, scatter[j]] += M[r0+r, gather[j]] · a[j]`.
///
/// §Perf: processes FOUR S rows per pass over the column sample so the
/// three index/coefficient streams (`scatter[j]`, `gather[j]`, `a[j]`,
/// 12 B/pair) are loaded once per 4 rows instead of once per row — stage 1
/// is index-bandwidth-bound, and this cut the n=16k Kronecker mat-vec by
/// ~35% (see rust/DESIGN.md §Perf).
pub(crate) fn stage1_scatter(
    mat: &Mat,
    row0: usize,
    chunk: &mut [f64],
    row_len: usize,
    scatter: &[u32],
    gather: &[u32],
    a: &[f64],
) {
    debug_assert_eq!(scatter.len(), a.len());
    debug_assert_eq!(gather.len(), a.len());
    let rows_here = chunk.len() / row_len;
    let mut r = 0;
    let block = !stage1_single_row();
    if block && crate::linalg::microkernel::enabled() {
        // 8-row tiles first (GVT_RLS_MICROKERNEL=0 ablates back to the
        // 4-row/scalar passes below); per-(row, j) update order is
        // unchanged, so the blocking width cannot move a bit.
        r = crate::linalg::microkernel::stage1_scatter8(
            mat, row0, chunk, row_len, scatter, gather, a,
        );
    }
    while block && r + 4 <= rows_here {
        let m0 = mat.row(row0 + r);
        let m1 = mat.row(row0 + r + 1);
        let m2 = mat.row(row0 + r + 2);
        let m3 = mat.row(row0 + r + 3);
        // Split the 4 destination rows out of the chunk.
        let (s0, rest) = chunk[r * row_len..].split_at_mut(row_len);
        let (s1, rest) = rest.split_at_mut(row_len);
        let (s2, s3full) = rest.split_at_mut(row_len);
        let s3 = &mut s3full[..row_len];
        for j in 0..a.len() {
            let dst = scatter[j] as usize;
            let src = gather[j] as usize;
            let aj = a[j];
            s0[dst] += m0[src] * aj;
            s1[dst] += m1[src] * aj;
            s2[dst] += m2[src] * aj;
            s3[dst] += m3[src] * aj;
        }
        r += 4;
    }
    for rr in r..rows_here {
        let mrow = mat.row(row0 + rr);
        let srow = &mut chunk[rr * row_len..(rr + 1) * row_len];
        for j in 0..a.len() {
            srow[scatter[j] as usize] += mrow[gather[j] as usize] * a[j];
        }
    }
}

/// A/B escape hatch used by the §Perf ablation (bench_perf_ablation):
/// `GVT_RLS_STAGE1_1ROW=1` disables [`stage1_scatter`]'s 4-row blocking
/// (and the grouped stage-1 kernel's, in `gvt/plan.rs`).
///
/// Read once and cached: stage 1 runs on every worker chunk of every GVT
/// mat-vec, and `env::var_os` takes a process-global lock on some
/// platforms — exactly the hot path the blocking exists to speed up. The
/// ablation sets the variable before the process starts, so a cached
/// read is equivalent.
pub(crate) fn stage1_single_row() -> bool {
    static CACHED: OnceLock<bool> = OnceLock::new();
    *CACHED.get_or_init(|| std::env::var_os("GVT_RLS_STAGE1_1ROW").is_some())
}

/// `p_i = ⟨lhs[li[i], :], s[ri[i], :]⟩`, threaded over output chunks.
fn stage2_rowdot(lhs: &Mat, s: &Mat, li: &[u32], ri: &[u32]) -> Vec<f64> {
    debug_assert_eq!(lhs.cols(), s.cols());
    let mut p = vec![0.0; li.len()];
    // 1024 rows/chunk (re-tuned from 2048 for the pooled runtime — the
    // cheaper dispatch pays off on smaller row samples).
    par::parallel_fill(&mut p, 1024, |start, _end, chunk| {
        for (k, pi) in chunk.iter_mut().enumerate() {
            let i = start + k;
            let lrow = lhs.row(li[i] as usize);
            let srow = s.row(ri[i] as usize);
            *pi = crate::linalg::vecops::dot(lrow, srow);
        }
    });
    p
}

/// Naive `O(n̄ · n)` reference: materializes nothing but loops all pairs.
/// Used by tests and the explicit-baseline benches.
pub fn naive_matvec(
    a_mat: &Mat,
    b_mat: &Mat,
    rows: &PairIndex,
    cols: &PairIndex,
    a: &[f64],
) -> Vec<f64> {
    check_shapes(a_mat, b_mat, rows, cols, a);
    let mut p = vec![0.0; rows.len()];
    for i in 0..rows.len() {
        let (di, ti) = (rows.drug(i), rows.target(i));
        let mut acc = 0.0;
        for j in 0..cols.len() {
            acc += a_mat[(di, cols.drug(j))] * b_mat[(ti, cols.target(j))] * a[j];
        }
        p[i] = acc;
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{dist, Xoshiro256};
    use crate::testing::gen;

    fn random_case(
        seed: u64,
        n: usize,
        nbar: usize,
        m: usize,
        q: usize,
    ) -> (Mat, Mat, PairIndex, PairIndex, Vec<f64>) {
        let mut rng = Xoshiro256::seed_from(seed);
        let a_mat = Mat::from_vec(m, m, dist::normal_vec(&mut rng, m * m));
        let b_mat = Mat::from_vec(q, q, dist::normal_vec(&mut rng, q * q));
        let cols = gen::pair_sample(&mut rng, n, m, q);
        let rows = gen::pair_sample(&mut rng, nbar, m, q);
        let a = dist::normal_vec(&mut rng, n);
        (a_mat, b_mat, rows, cols, a)
    }

    #[test]
    fn all_policies_match_naive() {
        for (seed, n, nbar, m, q) in
            [(1u64, 40, 25, 6, 9), (2, 100, 100, 13, 7), (3, 17, 60, 5, 5)]
        {
            let (am, bm, rows, cols, a) = random_case(seed, n, nbar, m, q);
            let expect = naive_matvec(&am, &bm, &rows, &cols, &a);
            for policy in [
                GvtPolicy::SparseLeft,
                GvtPolicy::SparseRight,
                GvtPolicy::Dense,
                GvtPolicy::Auto,
            ] {
                let got = gvt_matvec(&am, &bm, &rows, &cols, &a, policy);
                let err = crate::linalg::vecops::max_abs_diff(&got, &expect);
                assert!(err < 1e-9, "{policy:?} seed {seed}: err {err}");
            }
        }
    }

    #[test]
    fn rectangular_factors_supported() {
        // Distinct row/col domains: A is 4×6, B is 3×5.
        let mut rng = Xoshiro256::seed_from(9);
        let am = Mat::from_vec(4, 6, dist::normal_vec(&mut rng, 24));
        let bm = Mat::from_vec(3, 5, dist::normal_vec(&mut rng, 15));
        let rows = gen::pair_sample(&mut rng, 20, 4, 3);
        let cols = gen::pair_sample(&mut rng, 30, 6, 5);
        let a = dist::normal_vec(&mut rng, 30);
        let expect = naive_matvec(&am, &bm, &rows, &cols, &a);
        for policy in [GvtPolicy::SparseLeft, GvtPolicy::SparseRight, GvtPolicy::Dense] {
            let got = gvt_matvec(&am, &bm, &rows, &cols, &a, policy);
            assert!(crate::linalg::vecops::max_abs_diff(&got, &expect) < 1e-10);
        }
    }

    #[test]
    fn complete_sample_matches_kronecker_definition() {
        // On the complete sample with identity coefficients the op returns
        // vec of B·W·Aᵀ per Roth's lemma; spot-check one basis vector.
        let m = 3;
        let q = 2;
        let am = Mat::from_fn(m, m, |i, j| (i * m + j) as f64);
        let bm = Mat::from_fn(q, q, |i, j| (10 + i * q + j) as f64);
        let c = PairIndex::complete(m, q);
        // a = e_0 selects pair (d=0, t=0): p_i = A[d_i,0]·B[t_i,0].
        let mut a = vec![0.0; m * q];
        a[0] = 1.0;
        let p = gvt_matvec(&am, &bm, &c, &c, &a, GvtPolicy::Auto);
        for i in 0..m * q {
            let (di, ti) = (c.drug(i), c.target(i));
            assert_eq!(p[i], am[(di, 0)] * bm[(ti, 0)]);
        }
    }

    #[test]
    fn empty_column_sample_gives_zeros() {
        let mut rng = Xoshiro256::seed_from(10);
        let am = Mat::from_vec(3, 3, dist::normal_vec(&mut rng, 9));
        let bm = Mat::from_vec(3, 3, dist::normal_vec(&mut rng, 9));
        let rows = gen::pair_sample(&mut rng, 5, 3, 3);
        let cols = PairIndex::new(vec![], vec![], 3, 3);
        let p = gvt_matvec(&am, &bm, &rows, &cols, &[], GvtPolicy::Auto);
        assert_eq!(p, vec![0.0; 5]);
    }

    /// The `Auto` cost model on an empty *row* sample (`n̄ = 0`): every
    /// branch it can pick must agree with the forced policies and with
    /// the naive oracle, and the division-free guards (`max(1)` in
    /// `gvt_matvec` / `parallel_fill_rows`) must keep the cost
    /// comparisons finite.
    #[test]
    fn auto_matches_forced_policies_on_empty_row_sample() {
        let mut rng = Xoshiro256::seed_from(21);
        let (m, q, n) = (4, 5, 30);
        let am = Mat::from_vec(m, m, dist::normal_vec(&mut rng, m * m));
        let bm = Mat::from_vec(q, q, dist::normal_vec(&mut rng, q * q));
        let cols = gen::pair_sample(&mut rng, n, m, q);
        let rows = PairIndex::new(vec![], vec![], m, q);
        let a = dist::normal_vec(&mut rng, n);
        let expect = naive_matvec(&am, &bm, &rows, &cols, &a);
        assert_eq!(expect, Vec::<f64>::new());
        for policy in [
            GvtPolicy::Auto,
            GvtPolicy::SparseLeft,
            GvtPolicy::SparseRight,
            GvtPolicy::Dense,
        ] {
            let got = gvt_matvec(&am, &bm, &rows, &cols, &a, policy);
            assert_eq!(got, expect, "{policy:?} on empty row sample");
        }
    }

    /// The `Auto` cost model on a degenerate 1×1 domain: density is
    /// computed against a 1-cell grid (the `max(1)` guard), and all
    /// policies must agree with the naive oracle.
    #[test]
    fn auto_matches_forced_policies_on_1x1_domain() {
        let am = Mat::full(1, 1, 2.5);
        let bm = Mat::full(1, 1, -0.5);
        // Several repeated (0, 0) pairs: n > m·q exercises density > 1.
        let cols = PairIndex::new(vec![0; 6], vec![0; 6], 1, 1);
        let rows = PairIndex::new(vec![0; 3], vec![0; 3], 1, 1);
        let a = vec![1.0, 2.0, -1.0, 0.5, 0.25, -0.75];
        let expect = naive_matvec(&am, &bm, &rows, &cols, &a);
        for policy in [
            GvtPolicy::Auto,
            GvtPolicy::SparseLeft,
            GvtPolicy::SparseRight,
            GvtPolicy::Dense,
        ] {
            let got = gvt_matvec(&am, &bm, &rows, &cols, &a, policy);
            let err = crate::linalg::vecops::max_abs_diff(&got, &expect);
            assert!(err < 1e-12, "{policy:?} on 1x1 domain: err {err}");
        }
    }

    /// Both degeneracies at once: empty column sample *and* empty row
    /// sample over a 1×1 domain — the operator is the 0×0 matrix.
    #[test]
    fn auto_handles_fully_empty_problem() {
        let am = Mat::full(1, 1, 3.0);
        let bm = Mat::full(1, 1, 4.0);
        let empty = PairIndex::new(vec![], vec![], 1, 1);
        for policy in [
            GvtPolicy::Auto,
            GvtPolicy::SparseLeft,
            GvtPolicy::SparseRight,
            GvtPolicy::Dense,
        ] {
            let got = gvt_matvec(&am, &bm, &empty, &empty, &[], policy);
            assert_eq!(got, Vec::<f64>::new(), "{policy:?}");
        }
    }

    #[test]
    fn policy_name_parse_roundtrip() {
        for p in [
            GvtPolicy::Auto,
            GvtPolicy::SparseLeft,
            GvtPolicy::SparseRight,
            GvtPolicy::Dense,
        ] {
            assert_eq!(GvtPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(GvtPolicy::parse("nope"), None);
    }

    #[test]
    fn linearity_in_coefficients() {
        let (am, bm, rows, cols, a) = random_case(12, 50, 30, 7, 8);
        let b: Vec<f64> = a.iter().map(|x| 0.5 * x + 1.0).collect();
        let pa = gvt_matvec(&am, &bm, &rows, &cols, &a, GvtPolicy::Auto);
        let pb = gvt_matvec(&am, &bm, &rows, &cols, &b, GvtPolicy::Auto);
        let sum: Vec<f64> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        let psum = gvt_matvec(&am, &bm, &rows, &cols, &sum, GvtPolicy::Auto);
        for i in 0..pa.len() {
            assert!((pa[i] + pb[i] - psum[i]).abs() < 1e-9);
        }
    }
}
