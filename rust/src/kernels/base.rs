//! Base kernel functions on feature vectors.

/// Hyperparameters for the parametric kernels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KernelParams {
    /// Gaussian bandwidth γ in `exp(-γ ||x - y||²)`. Paper uses 1e-5 on
    /// similarity-row features.
    pub gamma: f64,
    /// Polynomial degree.
    pub degree: u32,
    /// Polynomial bias term `c` in `(⟨x,y⟩ + c)^degree`.
    pub coef0: f64,
}

impl Default for KernelParams {
    fn default() -> Self {
        Self { gamma: 1e-5, degree: 2, coef0: 0.0 }
    }
}

/// The base kernels used across the paper's datasets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BaseKernel {
    /// `⟨x, y⟩`
    Linear,
    /// `(⟨x, y⟩ + c)^degree`
    Polynomial,
    /// `exp(-γ ||x − y||²)`
    Gaussian,
    /// Tanimoto / MinMax on nonnegative vectors:
    /// `Σ min(x_i, y_i) / Σ max(x_i, y_i)` (1 when both are all-zero).
    Tanimoto,
    /// Min (histogram-intersection) kernel: `Σ min(x_i, y_i)` — the "Min"
    /// variant the paper compares on the heterodimer binary features.
    Min,
    /// Cosine-normalized linear kernel: `⟨x,y⟩ / (‖x‖·‖y‖)` — the "Norm"
    /// variant of §6.1 (0 for zero vectors).
    Cosine,
}

impl BaseKernel {
    /// Evaluate `k(x, y)`.
    pub fn eval(&self, params: &KernelParams, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len(), "kernel eval: feature dim mismatch");
        match self {
            BaseKernel::Linear => dot(x, y),
            BaseKernel::Polynomial => (dot(x, y) + params.coef0).powi(params.degree as i32),
            BaseKernel::Gaussian => {
                let mut d2 = 0.0;
                for (a, b) in x.iter().zip(y) {
                    let d = a - b;
                    d2 += d * d;
                }
                (-params.gamma * d2).exp()
            }
            BaseKernel::Tanimoto => {
                let mut num = 0.0;
                let mut den = 0.0;
                for (a, b) in x.iter().zip(y) {
                    num += a.min(*b);
                    den += a.max(*b);
                }
                if den == 0.0 {
                    1.0
                } else {
                    num / den
                }
            }
            BaseKernel::Min => x.iter().zip(y).map(|(a, b)| a.min(*b)).sum(),
            BaseKernel::Cosine => {
                let (mut xy, mut xx, mut yy) = (0.0, 0.0, 0.0);
                for (a, b) in x.iter().zip(y) {
                    xy += a * b;
                    xx += a * a;
                    yy += b * b;
                }
                if xx == 0.0 || yy == 0.0 {
                    0.0
                } else {
                    xy / (xx * yy).sqrt()
                }
            }
        }
    }

    /// Parse from a config string (the CLI/experiment configs use these).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Some(Self::Linear),
            "polynomial" | "poly" => Some(Self::Polynomial),
            "gaussian" | "rbf" => Some(Self::Gaussian),
            "tanimoto" | "minmax" => Some(Self::Tanimoto),
            "min" => Some(Self::Min),
            "cosine" | "norm" => Some(Self::Cosine),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Linear => "linear",
            Self::Polynomial => "polynomial",
            Self::Gaussian => "gaussian",
            Self::Tanimoto => "tanimoto",
            Self::Min => "min",
            Self::Cosine => "cosine",
        }
    }
}

#[inline]
fn dot(x: &[f64], y: &[f64]) -> f64 {
    crate::linalg::vecops::dot(x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: KernelParams = KernelParams { gamma: 0.5, degree: 2, coef0: 1.0 };

    #[test]
    fn linear_is_dot() {
        assert_eq!(BaseKernel::Linear.eval(&P, &[1.0, 2.0], &[3.0, -1.0]), 1.0);
    }

    #[test]
    fn polynomial_known_value() {
        // (<[1,1],[2,3]> + 1)^2 = 36
        assert_eq!(BaseKernel::Polynomial.eval(&P, &[1.0, 1.0], &[2.0, 3.0]), 36.0);
    }

    #[test]
    fn gaussian_unit_at_self_and_decays() {
        let x = [0.3, -0.7, 2.0];
        assert_eq!(BaseKernel::Gaussian.eval(&P, &x, &x), 1.0);
        let y = [0.3, -0.7, 3.0];
        assert!((BaseKernel::Gaussian.eval(&P, &x, &y) - (-0.5f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn tanimoto_binary_semantics() {
        // Bits shared: 1; bits in union: 3 => 1/3.
        let x = [1.0, 1.0, 0.0, 0.0];
        let y = [1.0, 0.0, 1.0, 0.0];
        assert!((BaseKernel::Tanimoto.eval(&P, &x, &y) - 1.0 / 3.0).abs() < 1e-12);
        // All-zero pair defined as 1 (identical).
        assert_eq!(BaseKernel::Tanimoto.eval(&P, &[0.0; 3], &[0.0; 3]), 1.0);
    }

    #[test]
    fn tanimoto_self_is_one() {
        let x = [1.0, 0.0, 1.0, 1.0];
        assert_eq!(BaseKernel::Tanimoto.eval(&P, &x, &x), 1.0);
    }

    #[test]
    fn parse_roundtrip() {
        for k in [
            BaseKernel::Linear,
            BaseKernel::Polynomial,
            BaseKernel::Gaussian,
            BaseKernel::Tanimoto,
            BaseKernel::Min,
            BaseKernel::Cosine,
        ] {
            assert_eq!(BaseKernel::parse(k.name()), Some(k));
        }
        assert_eq!(BaseKernel::parse("nope"), None);
    }

    #[test]
    fn min_kernel_counts_shared_bits() {
        // On binary vectors, Min = intersection size.
        let x = [1.0, 1.0, 0.0, 1.0];
        let y = [1.0, 0.0, 1.0, 1.0];
        assert_eq!(BaseKernel::Min.eval(&P, &x, &y), 2.0);
    }

    #[test]
    fn cosine_is_normalized_linear() {
        let x = [3.0, 4.0];
        let y = [4.0, 3.0];
        assert!((BaseKernel::Cosine.eval(&P, &x, &y) - 24.0 / 25.0).abs() < 1e-12);
        assert_eq!(BaseKernel::Cosine.eval(&P, &x, &x), 1.0);
        assert_eq!(BaseKernel::Cosine.eval(&P, &[0.0, 0.0], &y), 0.0);
    }

    #[test]
    fn min_minmax_norm_agree_on_self_similarity_ordering() {
        // §6.1: the binary-feature kernel variants rank similar pairs the
        // same way — check monotone agreement on nested bit sets.
        let a = [1.0, 1.0, 1.0, 0.0];
        let b = [1.0, 1.0, 0.0, 0.0]; // subset of a
        let c = [1.0, 0.0, 0.0, 0.0]; // subset of b
        for k in [BaseKernel::Tanimoto, BaseKernel::Min, BaseKernel::Cosine] {
            let ab = k.eval(&P, &a, &b);
            let ac = k.eval(&P, &a, &c);
            assert!(ab > ac, "{k:?}: {ab} vs {ac}");
        }
    }
}
