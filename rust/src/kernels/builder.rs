//! Kernel matrix construction from feature matrices.

use crate::kernels::{BaseKernel, KernelParams};
use crate::linalg::{par, Mat};

/// Symmetric kernel matrix `K[i,j] = k(X[i,:], X[j,:])` over the rows of a
/// feature matrix. Threaded over row panels; exploits symmetry.
pub fn kernel_matrix(kernel: BaseKernel, params: &KernelParams, x: &Mat) -> Mat {
    let n = x.rows();
    let mut k = Mat::zeros(n, n);
    // Fill the full square in parallel (each worker owns disjoint rows);
    // symmetry is exploited by computing j>=i then mirroring serially —
    // simpler: compute full rows; kernels are cheap relative to bookkeeping
    // and this keeps the parallel write pattern trivially disjoint.
    let cols = n;
    let kdata = k.as_mut_slice();
    par::parallel_fill_rows(kdata, cols.max(1), 4 * cols.max(1), |start_flat, _end, chunk| {
        let row0 = start_flat / cols;
        let rows_here = chunk.len() / cols;
        for r in 0..rows_here {
            let i = row0 + r;
            let xi = x.row(i);
            let out = &mut chunk[r * cols..(r + 1) * cols];
            for (j, o) in out.iter_mut().enumerate() {
                *o = kernel.eval(params, xi, x.row(j));
            }
        }
    });
    k
}

/// Cross kernel matrix `K[i,j] = k(A[i,:], B[j,:])`.
pub fn cross_kernel_matrix(
    kernel: BaseKernel,
    params: &KernelParams,
    a: &Mat,
    b: &Mat,
) -> Mat {
    assert_eq!(a.cols(), b.cols(), "cross kernel: feature dims differ");
    Mat::from_fn(a.rows(), b.rows(), |i, j| kernel.eval(params, a.row(i), b.row(j)))
}

/// Cosine-normalize a symmetric kernel matrix in place:
/// `K[i,j] ← K[i,j] / sqrt(K[i,i]·K[j,j])`. Entries with nonpositive
/// diagonal are zeroed (degenerate objects).
pub fn normalize_kernel(k: &mut Mat) {
    let n = k.rows();
    assert_eq!(n, k.cols(), "normalize_kernel: square matrix required");
    let diag: Vec<f64> = (0..n).map(|i| k[(i, i)]).collect();
    for i in 0..n {
        for j in 0..n {
            let d = diag[i] * diag[j];
            k[(i, j)] = if d > 0.0 { k[(i, j)] / d.sqrt() } else { 0.0 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{dist, Xoshiro256};

    #[test]
    fn kernel_matrix_is_symmetric_psd_linear() {
        let mut rng = Xoshiro256::seed_from(21);
        let x = Mat::from_vec(12, 5, dist::normal_vec(&mut rng, 60));
        let k = kernel_matrix(BaseKernel::Linear, &KernelParams::default(), &x);
        assert!(k.is_symmetric(1e-12));
        // PSD check via Cholesky with jitter.
        let mut kj = k.clone();
        for i in 0..12 {
            kj[(i, i)] += 1e-9;
        }
        assert!(crate::linalg::chol::Cholesky::factor(&kj).is_ok());
    }

    #[test]
    fn cross_matches_symmetric_block() {
        let mut rng = Xoshiro256::seed_from(22);
        let x = Mat::from_vec(8, 4, dist::normal_vec(&mut rng, 32));
        let k = kernel_matrix(BaseKernel::Gaussian, &KernelParams { gamma: 0.1, ..Default::default() }, &x);
        let c = cross_kernel_matrix(
            BaseKernel::Gaussian,
            &KernelParams { gamma: 0.1, ..Default::default() },
            &x,
            &x,
        );
        assert!(k.max_abs_diff(&c) < 1e-12);
    }

    #[test]
    fn normalization_puts_ones_on_diagonal() {
        let mut rng = Xoshiro256::seed_from(23);
        let x = Mat::from_vec(10, 6, dist::normal_vec(&mut rng, 60));
        let mut k = kernel_matrix(BaseKernel::Linear, &KernelParams::default(), &x);
        normalize_kernel(&mut k);
        for i in 0..10 {
            assert!((k[(i, i)] - 1.0).abs() < 1e-12);
        }
        assert!(k.is_symmetric(1e-12));
        // Off-diagonals in [-1, 1].
        for i in 0..10 {
            for j in 0..10 {
                assert!(k[(i, j)].abs() <= 1.0 + 1e-12);
            }
        }
    }
}
