//! Kernel matrix construction from feature matrices.
//!
//! The dot-product family (linear / polynomial / Gaussian) builds through
//! the [`microkernel`] row-dot tiles — the Gram entry is a feature dot
//! product (Gaussian via `‖x‖² + ‖y‖² − 2⟨x,y⟩`), so a `K` build is one
//! triangular `X·Xᵀ` sweep instead of `n²` independent `eval` calls. The
//! combinatorial kernels (Tanimoto / Min / Cosine) and the
//! `GVT_RLS_MICROKERNEL=0` ablation keep the per-entry `eval` path. All
//! paths compute the upper triangle through the pool and mirror it: every
//! `eval` is bitwise symmetric in its arguments (products and min/max
//! commute), so mirroring returns the same bits at half the work — and
//! makes `K` *exactly* symmetric by construction.
//!
//! The linear/polynomial tiled path is bit-identical to `eval` (both
//! reduce through `vecops::dot`); the Gaussian squared-norm expansion is
//! the one documented tolerance-level exception (rust/DESIGN.md
//! §Micro-Kernels) — it is algebraically, not bitwise, equal to the
//! per-entry `(x−y)²` sum, and `max(·, 0.0)` clamps the cancellation so
//! the diagonal is still exactly 1.

use crate::kernels::{BaseKernel, KernelParams};
use crate::linalg::{microkernel, par, vecops, Mat};

/// Can this kernel's Gram matrix be assembled from feature dot products?
fn gram_by_dot(kernel: BaseKernel) -> bool {
    matches!(
        kernel,
        BaseKernel::Linear | BaseKernel::Polynomial | BaseKernel::Gaussian
    )
}

/// Finish one Gram entry from the dot product `g = ⟨x_i, x_j⟩` and the
/// squared norms (only read for Gaussian; callers pass 0.0 otherwise).
#[inline]
fn gram_value(kernel: BaseKernel, params: &KernelParams, g: f64, sqi: f64, sqj: f64) -> f64 {
    match kernel {
        BaseKernel::Linear => g,
        BaseKernel::Polynomial => (g + params.coef0).powi(params.degree as i32),
        BaseKernel::Gaussian => (-params.gamma * (sqi + sqj - 2.0 * g).max(0.0)).exp(),
        // Gated by `gram_by_dot` at both call sites.
        _ => f64::NAN,
    }
}

/// Copy the strict upper triangle onto the lower one. Serial: the mirror
/// is a straight `n²/2` copy, cheap next to the dot products above it,
/// and the column-gather read pattern does not row-partition cleanly.
fn mirror_upper(k: &mut Mat) {
    let n = k.rows();
    for i in 1..n {
        for j in 0..i {
            k[(i, j)] = k[(j, i)];
        }
    }
}

/// Symmetric kernel matrix `K[i,j] = k(X[i,:], X[j,:])` over the rows of a
/// feature matrix. Upper triangle through the pool (each worker owns
/// disjoint row bands; the chunk-claim scheduler absorbs the triangular
/// imbalance), then mirrored.
pub fn kernel_matrix(kernel: BaseKernel, params: &KernelParams, x: &Mat) -> Mat {
    let n = x.rows();
    let mut k = Mat::zeros(n, n);
    if n == 0 {
        return k;
    }
    let tiled = microkernel::enabled() && gram_by_dot(kernel);
    let needs_sq = tiled && kernel == BaseKernel::Gaussian;
    let sq: Vec<f64> = if needs_sq {
        (0..n).map(|i| vecops::dot(x.row(i), x.row(i))).collect()
    } else {
        Vec::new()
    };
    let cols = n;
    let kdata = k.as_mut_slice();
    par::parallel_fill_rows(kdata, cols, 4 * cols, |start_flat, _end, chunk| {
        let row0 = start_flat / cols;
        let rows_here = chunk.len() / cols;
        for r in 0..rows_here {
            let i = row0 + r;
            let xi = x.row(i);
            let out = &mut chunk[r * cols..(r + 1) * cols];
            if tiled {
                let sqi = if needs_sq { sq[i] } else { 0.0 };
                let mut j = i;
                while j + 4 <= n {
                    let g = microkernel::dot4(xi, x.row(j), x.row(j + 1), x.row(j + 2), x.row(j + 3));
                    for (t, gt) in g.iter().enumerate() {
                        let sqj = if needs_sq { sq[j + t] } else { 0.0 };
                        out[j + t] = gram_value(kernel, params, *gt, sqi, sqj);
                    }
                    j += 4;
                }
                while j < n {
                    let g = vecops::dot(xi, x.row(j));
                    let sqj = if needs_sq { sq[j] } else { 0.0 };
                    out[j] = gram_value(kernel, params, g, sqi, sqj);
                    j += 1;
                }
            } else {
                // Per-entry path: combinatorial kernels and the
                // GVT_RLS_MICROKERNEL=0 ablation.
                for j in i..n {
                    out[j] = kernel.eval(params, xi, x.row(j));
                }
            }
        }
    });
    mirror_upper(&mut k);
    k
}

/// Cross kernel matrix `K[i,j] = k(A[i,:], B[j,:])`. Dot-product kernels
/// run pooled through the 1×4 row-dot tile (the serving predictor builds
/// cross rows on every cache miss); the rest — and the
/// `GVT_RLS_MICROKERNEL=0` ablation — keep the serial per-entry build.
pub fn cross_kernel_matrix(
    kernel: BaseKernel,
    params: &KernelParams,
    a: &Mat,
    b: &Mat,
) -> Mat {
    assert_eq!(a.cols(), b.cols(), "cross kernel: feature dims differ");
    let (na, nb) = (a.rows(), b.rows());
    if na == 0 || nb == 0 || !(microkernel::enabled() && gram_by_dot(kernel)) {
        return Mat::from_fn(na, nb, |i, j| kernel.eval(params, a.row(i), b.row(j)));
    }
    let needs_sq = kernel == BaseKernel::Gaussian;
    let (sqa, sqb): (Vec<f64>, Vec<f64>) = if needs_sq {
        (
            (0..na).map(|i| vecops::dot(a.row(i), a.row(i))).collect(),
            (0..nb).map(|j| vecops::dot(b.row(j), b.row(j))).collect(),
        )
    } else {
        (Vec::new(), Vec::new())
    };
    let mut k = Mat::zeros(na, nb);
    let kdata = k.as_mut_slice();
    par::parallel_fill_rows(kdata, nb, 4 * nb, |start_flat, _end, chunk| {
        let row0 = start_flat / nb;
        let rows_here = chunk.len() / nb;
        for r in 0..rows_here {
            let i = row0 + r;
            let ai = a.row(i);
            let sqi = if needs_sq { sqa[i] } else { 0.0 };
            let out = &mut chunk[r * nb..(r + 1) * nb];
            let mut j = 0;
            while j + 4 <= nb {
                let g = microkernel::dot4(ai, b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3));
                for (t, gt) in g.iter().enumerate() {
                    let sqj = if needs_sq { sqb[j + t] } else { 0.0 };
                    out[j + t] = gram_value(kernel, params, *gt, sqi, sqj);
                }
                j += 4;
            }
            while j < nb {
                let g = vecops::dot(ai, b.row(j));
                let sqj = if needs_sq { sqb[j] } else { 0.0 };
                out[j] = gram_value(kernel, params, g, sqi, sqj);
                j += 1;
            }
        }
    });
    k
}

/// Cosine-normalize a symmetric kernel matrix in place:
/// `K[i,j] ← K[i,j] / sqrt(K[i,i]·K[j,j])`. Entries with nonpositive
/// diagonal are zeroed (degenerate objects).
pub fn normalize_kernel(k: &mut Mat) {
    let n = k.rows();
    assert_eq!(n, k.cols(), "normalize_kernel: square matrix required");
    let diag: Vec<f64> = (0..n).map(|i| k[(i, i)]).collect();
    for i in 0..n {
        for j in 0..n {
            let d = diag[i] * diag[j];
            k[(i, j)] = if d > 0.0 { k[(i, j)] / d.sqrt() } else { 0.0 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{dist, Xoshiro256};

    #[test]
    fn kernel_matrix_is_symmetric_psd_linear() {
        let mut rng = Xoshiro256::seed_from(21);
        let x = Mat::from_vec(12, 5, dist::normal_vec(&mut rng, 60));
        let k = kernel_matrix(BaseKernel::Linear, &KernelParams::default(), &x);
        assert!(k.is_symmetric(1e-12));
        // PSD check via Cholesky with jitter.
        let mut kj = k.clone();
        for i in 0..12 {
            kj[(i, i)] += 1e-9;
        }
        assert!(crate::linalg::chol::Cholesky::factor(&kj).is_ok());
    }

    #[test]
    fn cross_matches_symmetric_block() {
        let mut rng = Xoshiro256::seed_from(22);
        let x = Mat::from_vec(8, 4, dist::normal_vec(&mut rng, 32));
        let k = kernel_matrix(BaseKernel::Gaussian, &KernelParams { gamma: 0.1, ..Default::default() }, &x);
        let c = cross_kernel_matrix(
            BaseKernel::Gaussian,
            &KernelParams { gamma: 0.1, ..Default::default() },
            &x,
            &x,
        );
        assert!(k.max_abs_diff(&c) < 1e-12);
    }

    #[test]
    fn normalization_puts_ones_on_diagonal() {
        let mut rng = Xoshiro256::seed_from(23);
        let x = Mat::from_vec(10, 6, dist::normal_vec(&mut rng, 60));
        let mut k = kernel_matrix(BaseKernel::Linear, &KernelParams::default(), &x);
        normalize_kernel(&mut k);
        for i in 0..10 {
            assert!((k[(i, i)] - 1.0).abs() < 1e-12);
        }
        assert!(k.is_symmetric(1e-12));
        // Off-diagonals in [-1, 1].
        for i in 0..10 {
            for j in 0..10 {
                assert!(k[(i, j)].abs() <= 1.0 + 1e-12);
            }
        }
    }
}
