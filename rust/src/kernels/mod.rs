//! Object-level (drug / target) kernels.
//!
//! These produce the `D ∈ R^{m×m}` and `T ∈ R^{q×q}` operator matrices that
//! the pairwise kernels of [`crate::gvt`] combine. The paper's datasets use
//! linear and Gaussian kernels on similarity-matrix rows (Metz/Merget) and
//! Tanimoto (MinMax) kernels on binary fingerprints (Heterodimer, drug
//! fingerprints).

mod base;
mod builder;

pub use base::{BaseKernel, KernelParams};
pub use builder::{cross_kernel_matrix, kernel_matrix, normalize_kernel};
