//! # gvt-rls — Generalized Vec Trick pairwise kernel learning
//!
//! A Rust + JAX/Pallas reproduction of *"Generalized vec trick for fast
//! learning of pairwise kernel models"* (Viljanen, Airola, Pahikkala;
//! Machine Learning 2022).
//!
//! Pairwise learning predicts labels for (drug, target) pairs. With `n`
//! training pairs over `m` unique drugs and `q` unique targets, explicit
//! pairwise kernel matrices cost `O(n²)` time and memory. This library
//! expresses all eight standard pairwise kernels — Linear, Poly2D, Kronecker,
//! Symmetric, Anti-Symmetric, Ranking, MLPK, Cartesian — as sums of permuted
//! Kronecker products (the paper's operator framework, Corollary 1) and
//! computes every kernel mat-vec in `O(nm + nq)` with the generalized vec
//! trick (Theorem 1), making iterative kernel ridge regression scale to
//! millions of pairs.
//!
//! ## Layout
//!
//! * [`gvt`] — the paper's contribution: sparse GVT mat-vec, the operator
//!   framework, and the eight pairwise kernels as Kronecker-term sums.
//! * [`solvers`] — MINRES / CG / early-stopping kernel ridge /
//!   Falkon-style Nyström baseline / the mini-batched stochastic vec
//!   trick trainer (`gvt-rls train --solver sgd`).
//! * [`kernels`] — object-level (drug/target) kernels: linear, polynomial,
//!   Gaussian, Tanimoto.
//! * [`data`] — synthetic dataset generators mirroring the paper's four
//!   evaluation datasets, plus Settings 1–4 splitters (Table 1).
//! * [`coordinator`] — experiment orchestration: leader/worker job queue,
//!   cross-validation, early stopping, memory accounting, reports.
//! * [`serve`] — online inference: a micro-batched prediction server
//!   over compiled GVT plans (`gvt-rls serve` / `gvt-rls predict`).
//! * [`runtime`] — execution runtime: the persistent worker pool
//!   ([`runtime::pool`]) every parallel loop in the crate runs on, plus
//!   the PJRT bridge loading AOT-compiled JAX/Pallas artifacts (HLO
//!   text) for the dense complete-data Kronecker mat-vec.
//! * [`lint`] — `gvt-lint`: the source-level static-analysis pass
//!   (`gvt-rls lint`) that turns the determinism / alloc-free /
//!   unsafe-audit / env-registry / panic-surface / clock-monopoly
//!   contracts into build failures (gates `scripts/verify.sh` and
//!   `tests/lint_clean.rs`).
//! * [`obs`] — unified telemetry: the metrics registry with log2
//!   latency histograms behind serve `stats`/`metrics`, the Chrome
//!   trace-event span recorder (`GVT_RLS_TRACE`), solver iteration
//!   sinks (`gvt-rls train --trace-solver`), leveled logging
//!   (`GVT_RLS_LOG`), and the process clock monopoly
//!   ([`obs::clock`]). Zero-cost when disarmed.
//! * [`linalg`], [`sparse`], [`rng`], [`eval`], [`bench`], [`testing`],
//!   [`error`] — from-scratch substrates (the sandbox has no rand/rayon/
//!   criterion/proptest or error-handling crates; the crate builds with
//!   zero dependencies, `cargo build --offline`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use gvt_rls::data::metz::MetzConfig;
//! use gvt_rls::gvt::pairwise::PairwiseKernel;
//! use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};
//!
//! let data = MetzConfig::small().generate(7);
//! let split = data.split_setting(1, 0.25, 42);
//! let model = PairwiseRidge::fit(
//!     &split.train,
//!     PairwiseKernel::Kronecker,
//!     &RidgeConfig::default(),
//! ).unwrap();
//! let p = model.predict(&split.test.pairs).unwrap();
//! ```

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod gvt;
pub mod kernels;
pub mod linalg;
pub mod lint;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod sparse;
pub mod testing;

/// Library version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
