//! Cholesky factorization and triangular solves.
//!
//! Used by (1) the closed-form ridge oracle that validates the iterative
//! GVT solver on small problems, and (2) the Falkon-style Nyström solver's
//! preconditioner (`K_mm + λI = LLᵀ`).

use crate::error::{bail, Result};
use crate::linalg::Mat;

/// Lower-triangular Cholesky factor of a symmetric positive-definite matrix.
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factor `a = L Lᵀ`. Fails if `a` is not (numerically) positive
    /// definite. `a` must be symmetric; only the lower triangle is read.
    pub fn factor(a: &Mat) -> Result<Self> {
        let n = a.rows();
        if a.cols() != n {
            bail!("cholesky: matrix must be square, got {}x{}", a.rows(), a.cols());
        }
        let mut l = Mat::zeros(n, n);
        for j in 0..n {
            // Diagonal element.
            let mut d = a[(j, j)];
            for k in 0..j {
                d -= l[(j, k)] * l[(j, k)];
            }
            if d <= 0.0 {
                bail!("cholesky: matrix not positive definite at pivot {j} (d={d:.3e})");
            }
            let djj = d.sqrt();
            l[(j, j)] = djj;
            // Column below the diagonal. Split borrows row-wise.
            for i in (j + 1)..n {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                l[(i, j)] = s / djj;
            }
        }
        Ok(Self { l })
    }

    /// Borrow the factor `L`.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Solve `L y = b` (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        for i in 0..n {
            let row = self.l.row(i);
            let mut s = y[i];
            for (k, &lik) in row[..i].iter().enumerate() {
                s -= lik * y[k];
            }
            y[i] = s / row[i];
        }
        y
    }

    /// Solve `Lᵀ x = b` (backward substitution).
    pub fn solve_upper(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        let mut x = b.to_vec();
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * x[k];
            }
            x[i] = s / self.l[(i, i)];
        }
        x
    }

    /// Solve `A x = b` where `A = L Lᵀ`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }
}

/// Solve the dense symmetric system `(A + λ I) x = b` by Cholesky. This is
/// the `O(n³)` closed-form ridge oracle used in tests and small baselines.
pub fn solve_regularized(a: &Mat, lambda: f64, b: &[f64]) -> Result<Vec<f64>> {
    let n = a.rows();
    let mut reg = a.clone();
    for i in 0..n {
        reg[(i, i)] += lambda;
    }
    Ok(Cholesky::factor(&reg)?.solve(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{dist, Xoshiro256};

    /// Random SPD matrix `XᵀX + εI`.
    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::seed_from(seed);
        let x = Mat::from_vec(n + 3, n, dist::normal_vec(&mut rng, (n + 3) * n));
        let mut a = x.transpose().matmul(&x);
        for i in 0..n {
            a[(i, i)] += 0.5;
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(12, 1);
        let ch = Cholesky::factor(&a).unwrap();
        let rec = ch.l().matmul(&ch.l().transpose());
        assert!(rec.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn solve_recovers_known_x() {
        let a = random_spd(20, 2);
        let mut rng = Xoshiro256::seed_from(3);
        let x_true = dist::normal_vec(&mut rng, 20);
        let b = a.matvec(&x_true);
        let x = Cholesky::factor(&a).unwrap().solve(&b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8, "{xi} vs {ti}");
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn regularized_solve_matches_unregularized_limit() {
        let a = random_spd(8, 4);
        let mut rng = Xoshiro256::seed_from(5);
        let b = dist::normal_vec(&mut rng, 8);
        let x0 = Cholesky::factor(&a).unwrap().solve(&b);
        let x1 = solve_regularized(&a, 1e-12, &b).unwrap();
        for (u, v) in x0.iter().zip(&x1) {
            assert!((u - v).abs() < 1e-6);
        }
    }
}
