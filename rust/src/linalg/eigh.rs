//! Symmetric eigendecomposition by the cyclic Jacobi method.
//!
//! Needed by the complete-data Kronecker ridge solver
//! ([`crate::solvers::complete`]): the closed form diagonalizes the drug
//! and target kernels once and solves every λ in `O(mq(m+q))`. Jacobi is
//! `O(n³)` per sweep with excellent accuracy on symmetric matrices and no
//! external LAPACK (none is available offline); fine for the `m, q ≤` a
//! few thousand this library targets.

use crate::error::{bail, Result};
use crate::linalg::Mat;

/// Eigendecomposition `A = V diag(λ) Vᵀ` of a symmetric matrix.
pub struct Eigh {
    /// Eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Orthonormal eigenvectors, one per **column**.
    pub vectors: Mat,
}

/// Decompose a symmetric matrix (symmetry is checked to `1e-8`).
pub fn eigh(a: &Mat) -> Result<Eigh> {
    let n = a.rows();
    if a.cols() != n {
        bail!("eigh: matrix must be square");
    }
    if !a.is_symmetric(1e-8) {
        bail!("eigh: matrix is not symmetric");
    }
    let mut m = a.clone();
    let mut v = Mat::eye(n);

    // Cyclic Jacobi sweeps until off-diagonal mass is negligible.
    let off = |m: &Mat| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                s += m[(i, j)] * m[(i, j)];
            }
        }
        s
    };
    let scale = a.fro_norm().max(1e-300);
    let tol = (1e-14 * scale) * (1e-14 * scale);
    for _sweep in 0..64 {
        if off(&m) <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                // Jacobi rotation annihilating (p, q).
                let theta = (m[(q, q)] - m[(p, p)]) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply rotation to rows/cols p, q of M.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Sort ascending by eigenvalue, permuting columns of V.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| m[(i, i)].partial_cmp(&m[(j, j)]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| m[(i, i)]).collect();
    let mut vectors = Mat::zeros(n, n);
    for (new_col, &old_col) in order.iter().enumerate() {
        for r in 0..n {
            vectors[(r, new_col)] = v[(r, old_col)];
        }
    }
    Ok(Eigh { values, vectors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::gen;
    use crate::rng::Xoshiro256;

    #[test]
    fn reconstructs_matrix() {
        let mut rng = Xoshiro256::seed_from(400);
        for n in [2, 5, 13, 24] {
            let a = gen::psd_kernel(&mut rng, n);
            let e = eigh(&a).unwrap();
            // A == V diag(λ) Vᵀ
            let mut lam = Mat::zeros(n, n);
            for i in 0..n {
                lam[(i, i)] = e.values[i];
            }
            let rec = e.vectors.matmul(&lam).matmul(&e.vectors.transpose());
            assert!(rec.max_abs_diff(&a) < 1e-8, "n={n}: {}", rec.max_abs_diff(&a));
        }
    }

    #[test]
    fn vectors_are_orthonormal() {
        let mut rng = Xoshiro256::seed_from(401);
        let a = gen::psd_kernel(&mut rng, 10);
        let e = eigh(&a).unwrap();
        let g = e.vectors.transpose().matmul(&e.vectors);
        assert!(g.max_abs_diff(&Mat::eye(10)) < 1e-10);
    }

    #[test]
    fn psd_matrix_has_nonnegative_spectrum_sorted() {
        let mut rng = Xoshiro256::seed_from(402);
        let a = gen::psd_kernel(&mut rng, 12);
        let e = eigh(&a).unwrap();
        for w in e.values.windows(2) {
            assert!(w[0] <= w[1] + 1e-12, "not sorted");
        }
        assert!(e.values[0] > -1e-9, "PSD matrix with negative eigenvalue");
    }

    #[test]
    fn diagonal_matrix_is_fixed_point() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 1.0;
        a[(2, 2)] = 2.0;
        let e = eigh(&a).unwrap();
        assert_eq!(e.values, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn rejects_asymmetric() {
        let mut a = Mat::eye(3);
        a[(0, 1)] = 1.0;
        assert!(eigh(&a).is_err());
    }

    #[test]
    fn agrees_with_cholesky_solve_on_spd() {
        // Solving (A + λI) x = b in the eigenbasis must match the
        // Cholesky oracle behind closed_form.rs.
        use crate::linalg::chol::solve_regularized;
        use crate::rng::dist;
        let mut rng = Xoshiro256::seed_from(403);
        for n in [4, 9, 16] {
            let a = gen::psd_kernel(&mut rng, n);
            let b = dist::normal_vec(&mut rng, n);
            let lambda = 0.5;
            let e = eigh(&a).unwrap();
            let mut coeff = e.vectors.transpose().matvec(&b);
            for (c, &v) in coeff.iter_mut().zip(&e.values) {
                *c /= v + lambda;
            }
            let x = e.vectors.matvec(&coeff);
            let oracle = solve_regularized(&a, lambda, &b).unwrap();
            for (xi, oi) in x.iter().zip(&oracle) {
                assert!((xi - oi).abs() < 1e-9, "n={n}: {xi} vs {oi}");
            }
        }
    }

    #[test]
    fn one_by_one_is_trivial() {
        let mut a = Mat::zeros(1, 1);
        a[(0, 0)] = 4.25;
        let e = eigh(&a).unwrap();
        assert_eq!(e.values, vec![4.25]);
        assert!((e.vectors[(0, 0)].abs() - 1.0).abs() < 1e-15);
    }

    #[test]
    fn repeated_eigenvalues_keep_invariants() {
        // A = 2I + 5 u uᵀ has spectrum {2, 2, 7}: the eigenvectors of the
        // repeated eigenvalue are not unique, so test only the invariants
        // (spectrum, orthonormality, reconstruction).
        let u = {
            let raw = [1.0, 2.0, -2.0]; // ‖raw‖ = 3
            raw.map(|x| x / 3.0)
        };
        let mut a = Mat::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                a[(i, j)] = 5.0 * u[i] * u[j] + if i == j { 2.0 } else { 0.0 };
            }
        }
        let e = eigh(&a).unwrap();
        for (got, want) in e.values.iter().zip(&[2.0, 2.0, 7.0]) {
            assert!((got - want).abs() < 1e-10, "spectrum: {:?}", e.values);
        }
        let g = e.vectors.transpose().matmul(&e.vectors);
        assert!(g.max_abs_diff(&Mat::eye(3)) < 1e-10);
        let mut lam = Mat::zeros(3, 3);
        for i in 0..3 {
            lam[(i, i)] = e.values[i];
        }
        let rec = e.vectors.matmul(&lam).matmul(&e.vectors.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn near_singular_rank_deficient_is_stable() {
        // K = X Xᵀ with X 8×2 has rank 2: six eigenvalues at (numerical)
        // zero must come out as ~0, not garbage, and the factorization
        // must still reconstruct and stay orthonormal.
        use crate::rng::dist;
        let mut rng = Xoshiro256::seed_from(404);
        let n = 8;
        let x = Mat::from_vec(n, 2, dist::normal_vec(&mut rng, n * 2));
        let k = x.matmul(&x.transpose());
        let e = eigh(&k).unwrap();
        for &v in &e.values[..n - 2] {
            assert!(v.abs() < 1e-8, "rank-deficient eigenvalue {v} not ~0");
        }
        assert!(e.values[n - 1] > 1e-2, "dominant eigenvalue collapsed");
        let g = e.vectors.transpose().matmul(&e.vectors);
        assert!(g.max_abs_diff(&Mat::eye(n)) < 1e-9);
        let mut lam = Mat::zeros(n, n);
        for i in 0..n {
            lam[(i, i)] = e.values[i];
        }
        let rec = e.vectors.matmul(&lam).matmul(&e.vectors.transpose());
        assert!(rec.max_abs_diff(&k) < 1e-8);
    }
}
