//! Dense row-major `f64` matrix.
//!
//! This is the storage type for object-level kernel matrices (`D ∈ R^{m×m}`,
//! `T ∈ R^{q×q}`), feature matrices, and the GVT intermediate `S`. GEMV,
//! GEMM, and `A·Bᵀ` run their per-chunk bodies through the register-blocked
//! tiles in [`crate::linalg::microkernel`] (packed panels, 4×8 / 4-row
//! tiles); `GVT_RLS_MICROKERNEL=0` falls back to the scalar cache-blocked
//! loops, bit-identically (tests/microkernel_equiv.rs).

use crate::linalg::{microkernel, par};
use std::fmt;

/// Row-major dense matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix of shape `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `value`.
    pub fn full(rows: usize, cols: usize, value: f64) -> Self {
        Self { rows, cols, data: vec![value; rows * cols] }
    }

    /// Identity matrix of order `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Borrow the backing row-major slice.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable backing slice.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the backing vector.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 64;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Stack equal-length column vectors into a matrix (row-major, so row
    /// `i` holds entry `i` of every column — the coefficient-block layout
    /// the multi-RHS GVT streams).
    pub fn from_columns(cols: &[&[f64]]) -> Mat {
        let b = cols.len();
        let n = cols.first().map_or(0, |c| c.len());
        assert!(cols.iter().all(|c| c.len() == n), "ragged columns");
        let mut m = Mat::zeros(n, b);
        for (j, col) in cols.iter().enumerate() {
            for i in 0..n {
                m.data[i * b + j] = col[i];
            }
        }
        m
    }

    /// Copy column `j` out as a vector.
    pub fn column(&self, j: usize) -> Vec<f64> {
        assert!(j < self.cols, "column index out of range");
        (0..self.rows).map(|i| self.data[i * self.cols + j]).collect()
    }

    /// Gather rows by index: result row `k` = `self` row `idx[k]`.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Symmetric submatrix `self[idx, idx]`.
    pub fn principal_submatrix(&self, idx: &[usize]) -> Mat {
        let k = idx.len();
        let mut out = Mat::zeros(k, k);
        for (a, &i) in idx.iter().enumerate() {
            let src = self.row(i);
            let dst = out.row_mut(a);
            for (b, &j) in idx.iter().enumerate() {
                dst[b] = src[j];
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max absolute entry difference vs `other` (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Is this matrix symmetric to tolerance `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Scale all entries in place.
    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// `self += s * other` (elementwise).
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Elementwise square, returned as a new matrix (the `D^{⊙2}` of
    /// Theorem 2's `Q(D⊗D)Qᵀ = D^{⊙2} ⊗ 1`).
    pub fn hadamard_square(&self) -> Mat {
        let data = self.data.iter().map(|x| x * x).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Dense matrix–vector product `y = self · x` (threaded over rows).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// `y = self · x` into a caller-provided buffer (hot path: the fused
    /// GVT plan's pooled terms run one of these per solver iteration).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "matvec dim mismatch");
        assert_eq!(y.len(), self.rows, "matvec output dim mismatch");
        let cols = self.cols;
        let data = &self.data;
        // Re-tuned for the pooled runtime (a condvar wake is ~1–2 µs vs
        // ~10 µs per scoped spawn): fan out once a chunk carries ≥ ~8k
        // MACs instead of the old fixed 256-row floor, so wide-but-short
        // GEMVs (the fused plan's pooled terms) parallelize too.
        let min_rows = (8192 / cols.max(1)).max(4);
        let tiled = microkernel::enabled();
        par::parallel_fill(y, min_rows, |start, _end, chunk| {
            if tiled {
                microkernel::gemv_chunk(data, cols, start, x, chunk);
            } else {
                // Scalar ablation body (GVT_RLS_MICROKERNEL=0).
                for (k, yi) in chunk.iter_mut().enumerate() {
                    let row = &data[(start + k) * cols..(start + k + 1) * cols];
                    *yi = crate::linalg::vecops::dot(row, x);
                }
            }
        });
    }

    /// Dense GEMM `self · other` (allocating wrapper over
    /// [`Self::matmul_into`]).
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut c);
        c
    }

    /// Dense GEMM `c = self · other` into a caller-provided matrix,
    /// cache-blocked and threaded over row panels. Each worker's chunk
    /// runs through [`microkernel::gemm_chunk`] (packed B panels, 4×8
    /// register tiles, occupancy-gated sparse-panel escape); the
    /// `GVT_RLS_MICROKERNEL=0` ablation keeps the scalar k-blocked
    /// `C[i,:] += A[i,k] * B[k,:]` triple loop. `c` is fully overwritten.
    pub fn matmul_into(&self, other: &Mat, c: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        assert_eq!(
            c.shape(),
            (self.rows, other.cols),
            "matmul output shape mismatch"
        );
        let (_m, k, n) = (self.rows, self.cols, other.cols);
        let a = &self.data;
        let b = &other.data;
        // Row-panel parallelism; each worker owns disjoint C rows.
        let cdata = c.as_mut_slice();
        cdata.fill(0.0);
        if n == 0 {
            return;
        }
        let tiled = microkernel::enabled();
        par::parallel_fill_rows(cdata, n, 8 * n, |row_start_flat, _end, chunk| {
            let row_start = row_start_flat / n;
            let rows_here = chunk.len() / n;
            if tiled {
                microkernel::gemm_chunk(a, b, k, n, row_start, chunk);
                return;
            }
            // Scalar ablation body (GVT_RLS_MICROKERNEL=0): branch-free
            // axpy inner loop (sparse A is the micro-kernel's concern —
            // its panel-occupancy escape keeps the historical skip-zero
            // route where measurement justifies it).
            const KB: usize = 256; // K-blocking: keep B panel in L2
            for kb in (0..k).step_by(KB) {
                let kend = (kb + KB).min(k);
                for i in 0..rows_here {
                    let ai = &a[(row_start + i) * k..(row_start + i) * k + k];
                    let ci = &mut chunk[i * n..(i + 1) * n];
                    for kk in kb..kend {
                        let aik = ai[kk];
                        let brow = &b[kk * n..(kk + 1) * n];
                        for (cij, bkj) in ci.iter_mut().zip(brow) {
                            *cij += aik * bkj;
                        }
                    }
                }
            }
        });
    }

    /// `self · otherᵀ` without materializing the transpose: row-dot-row,
    /// good when `other` is row-major and both row sets are gathered.
    /// Both paths reduce each element with `vecops::dot`'s fixed 8-wide
    /// tree (the tiled path via [`microkernel::rowdot_nt`]'s 1×4 tile),
    /// so `GVT_RLS_MICROKERNEL` on/off stays bit-identical.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt dim mismatch");
        let (m, n, k) = (self.rows, other.rows, self.cols);
        let mut c = Mat::zeros(m, n);
        if n == 0 {
            return c;
        }
        let a = &self.data;
        let b = &other.data;
        let cdata = c.as_mut_slice();
        let tiled = microkernel::enabled();
        par::parallel_fill_rows(cdata, n, 8 * n, |row_start_flat, _end, chunk| {
            let row_start = row_start_flat / n;
            let rows_here = chunk.len() / n;
            for i in 0..rows_here {
                let ai = &a[(row_start + i) * k..(row_start + i) * k + k];
                let ci = &mut chunk[i * n..(i + 1) * n];
                if tiled {
                    microkernel::rowdot_nt(ai, b, k, ci);
                } else {
                    // Scalar ablation body (GVT_RLS_MICROKERNEL=0).
                    for (j, cij) in ci.iter_mut().enumerate() {
                        *cij = crate::linalg::vecops::dot(ai, &b[j * k..(j + 1) * k]);
                    }
                }
            }
        });
        c
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            let cols = self.cols.min(8);
            let vals: Vec<String> =
                (0..cols).map(|j| format!("{:9.4}", self[(i, j)])).collect();
            writeln!(f, "  [{}{}]", vals.join(", "), if self.cols > 8 { ", …" } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        use crate::rng::{dist, Xoshiro256};
        let mut rng = Xoshiro256::seed_from(1);
        for &(m, k, n) in &[(3, 4, 5), (17, 31, 13), (64, 64, 64), (65, 127, 33)] {
            let a = Mat::from_vec(m, k, dist::normal_vec(&mut rng, m * k));
            let b = Mat::from_vec(k, n, dist::normal_vec(&mut rng, k * n));
            let c = a.matmul(&b);
            let c0 = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&c0) < 1e-10, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_nt_matches_transpose_path() {
        use crate::rng::{dist, Xoshiro256};
        let mut rng = Xoshiro256::seed_from(2);
        let a = Mat::from_vec(10, 7, dist::normal_vec(&mut rng, 70));
        let b = Mat::from_vec(12, 7, dist::normal_vec(&mut rng, 84));
        let c1 = a.matmul_nt(&b);
        let c2 = a.matmul(&b.transpose());
        assert!(c1.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn matvec_matches_matmul() {
        use crate::rng::{dist, Xoshiro256};
        let mut rng = Xoshiro256::seed_from(3);
        let a = Mat::from_vec(23, 17, dist::normal_vec(&mut rng, 23 * 17));
        let x = dist::normal_vec(&mut rng, 17);
        let y = a.matvec(&x);
        let xm = Mat::from_vec(17, 1, x);
        let ym = a.matmul(&xm);
        for i in 0..23 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_into_reuses_buffer() {
        use crate::rng::{dist, Xoshiro256};
        let mut rng = Xoshiro256::seed_from(8);
        let a = Mat::from_vec(6, 5, dist::normal_vec(&mut rng, 30));
        let b1 = Mat::from_vec(5, 4, dist::normal_vec(&mut rng, 20));
        let b2 = Mat::from_vec(5, 4, dist::normal_vec(&mut rng, 20));
        let mut c = Mat::zeros(6, 4);
        a.matmul_into(&b1, &mut c);
        // Second product into the same (dirty) buffer must fully overwrite.
        a.matmul_into(&b2, &mut c);
        assert!(c.max_abs_diff(&a.matmul(&b2)) < 1e-12);
    }

    #[test]
    fn from_columns_and_column_roundtrip() {
        let c0 = vec![1.0, 2.0, 3.0];
        let c1 = vec![-1.0, 0.5, 4.0];
        let m = Mat::from_columns(&[&c0, &c1]);
        assert_eq!(m.shape(), (3, 2));
        assert_eq!(m.column(0), c0);
        assert_eq!(m.column(1), c1);
        assert_eq!(m[(1, 1)], 0.5);
    }

    #[test]
    fn transpose_roundtrip() {
        use crate::rng::{dist, Xoshiro256};
        let mut rng = Xoshiro256::seed_from(4);
        let a = Mat::from_vec(37, 91, dist::normal_vec(&mut rng, 37 * 91));
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gather_and_principal_submatrix() {
        let a = Mat::from_fn(5, 5, |i, j| (10 * i + j) as f64);
        let g = a.gather_rows(&[3, 0, 3]);
        assert_eq!(g.row(0), a.row(3));
        assert_eq!(g.row(1), a.row(0));
        assert_eq!(g.row(2), a.row(3));
        let s = a.principal_submatrix(&[1, 4]);
        assert_eq!(s[(0, 0)], a[(1, 1)]);
        assert_eq!(s[(0, 1)], a[(1, 4)]);
        assert_eq!(s[(1, 0)], a[(4, 1)]);
        assert_eq!(s[(1, 1)], a[(4, 4)]);
    }

    #[test]
    fn hadamard_square_values() {
        let a = Mat::from_fn(2, 2, |i, j| (i as f64) - (j as f64));
        let h = a.hadamard_square();
        assert_eq!(h[(0, 1)], 1.0);
        assert_eq!(h[(1, 0)], 1.0);
        assert_eq!(h[(0, 0)], 0.0);
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        use crate::rng::{dist, Xoshiro256};
        let mut rng = Xoshiro256::seed_from(5);
        let a = Mat::from_vec(9, 9, dist::normal_vec(&mut rng, 81));
        assert!(a.matmul(&Mat::eye(9)).max_abs_diff(&a) < 1e-14);
        assert!(Mat::eye(9).matmul(&a).max_abs_diff(&a) < 1e-14);
    }
}
