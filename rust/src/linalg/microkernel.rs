//! Register-blocked dense micro-kernels for the pool's chunk bodies.
//!
//! The worker pool (PR 5) made dispatch cheap; these tiles make the work
//! inside each claimed chunk run at f64 throughput instead of one scalar
//! FMA per cycle. Every kernel here is a *pure* tile function invoked
//! from inside a `linalg::par` chunk body — no pool submission, no
//! threads, no allocation (the one scratch buffer, the GEMM B panel, is
//! a fixed-size stack array).
//!
//! ## Determinism contract (rust/DESIGN.md §Micro-Kernels)
//!
//! The PR 5 contract — bit-identical results for any worker count, chunk
//! claim order, and pool on/off — extends to tiling: a tile may only
//! block across *independent outputs* (multiple C rows/columns computed
//! simultaneously), never reorder the floating-point reduction chain of
//! any single output element. Concretely:
//!
//! * per-row reductions ([`dot4`]) reproduce `vecops::dot`'s exact
//!   sequence: eight accumulators over `chunks_exact(8)`, the fixed
//!   combine tree `((a0+a4)+(a1+a5)) + ((a2+a6)+(a3+a7))`, then a serial
//!   remainder;
//! * GEMM tiles ([`gemm_chunk`]) walk `k` in ascending order within each
//!   `KC` block and the blocks in ascending order, seeding the register
//!   tile from the current C values — the per-element chain is the same
//!   `c += a_ik · b_kj` sequence the scalar loop executes;
//! * stage-1 / stage-2 tiles keep each output cell's accumulation serial
//!   and in stream order; blocking only amortizes the index streams.
//!
//! `GVT_RLS_MICROKERNEL=0` disables every tile and falls back to the
//! scalar chunk bodies, so the equivalence is testable in-process
//! (tests/microkernel_equiv.rs); [`set_enabled`] is the in-process A/B
//! override the tests and benches use (same pattern as
//! `runtime::pool::set_pool_enabled`).
//!
//! The only caveat is ±0.0 / NaN pathology: the scalar GEMM historically
//! *skipped* zero `a_ik` entries while the packed tile multiplies through
//! them. For finite inputs the two are bit-identical — an accumulator
//! chain seeded at +0.0 can never produce -0.0 (exact cancellation of
//! finite nonzero values rounds to +0.0, and `+0.0 + (±0.0 · x)` stays
//! +0.0 in round-to-nearest) — so skipping a zero product is a no-op at
//! the bit level. NaN/Inf inputs would break that argument (0·Inf = NaN);
//! no solver path feeds them.
//!
//! This module is also the attach point for a dense accelerator backend:
//! the stubbed PJRT/XLA surface in `runtime/xla.rs` would replace these
//! CPU tiles per chunk, behind the same `enabled()`-style dispatch and
//! the same fixed-reduction-order contract.

use crate::linalg::vecops;
use crate::linalg::Mat;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// GEMM micro-tile rows (register-blocked C rows per pass).
pub const MR: usize = 4;
/// GEMM micro-tile columns = SIMD register width in f64 lanes (AVX-512
/// native, 2×AVX2). Also the stage-2 register block width.
pub const NR: usize = 8;
/// K-blocking depth: one packed B panel is `KC × NR` f64 = 16 KiB, small
/// enough to live on the worker's stack and stay L1-resident. Matches the
/// scalar fallback's historical `KB = 256` (the blocking does not affect
/// bits — `k` ascends globally either way — but keeping them equal makes
/// the A/B bench a pure tiling comparison).
pub const KC: usize = 256;
/// Minimum nonzero fraction of an A panel for the packed (branch-free)
/// GEMM path; sparser panels — the Dense-policy GVT scatter matrix `W` is
/// the motivating case — take the skip-zero scalar loop instead, which is
/// bit-identical on finite data (see module docs) and avoids multiplying
/// through a panel that is mostly structural zeros.
pub const SPARSE_PANEL_OCCUPANCY: f64 = 1.0 / 16.0;

// ---------------------------------------------------------------------
// Enable switch: env default + in-process override
// ---------------------------------------------------------------------

/// In-process override: 0 = unset (follow the env), 1 = forced off,
/// 2 = forced on. Same encoding as `runtime::pool::POOL_OVERRIDE`.
static MK_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// `GVT_RLS_MICROKERNEL` env gate, read once and cached: the dispatch
/// sits on every GEMV/GEMM/stage-1/stage-2 chunk, and `env::var_os`
/// takes a process-global lock on some platforms. Default on; `0`
/// disables (the scalar-ablation setting scripts/verify.sh sweeps).
fn env_enabled() -> bool {
    static CACHED: OnceLock<bool> = OnceLock::new();
    *CACHED.get_or_init(|| match std::env::var("GVT_RLS_MICROKERNEL") {
        Ok(v) => v != "0",
        Err(_) => true,
    })
}

/// Are the tiled kernels active? Checked once per chunk body (a relaxed
/// atomic load plus a cached env read — nanoseconds against chunk bodies
/// of ≥ thousands of MACs).
#[inline]
pub fn enabled() -> bool {
    match MK_OVERRIDE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => env_enabled(),
    }
}

/// In-process A/B override for tests and benches (process-global, like
/// the pool's thread/enable overrides): `Some(on)` forces the tiled or
/// scalar path, `None` restores the `GVT_RLS_MICROKERNEL` env default.
pub fn set_enabled(on: Option<bool>) {
    let v = match on {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    };
    MK_OVERRIDE.store(v, Ordering::Relaxed);
}

// ---------------------------------------------------------------------
// Multi-accumulator row-dot: the shared reduction primitive
// ---------------------------------------------------------------------

/// Four simultaneous dot products against one shared stream: returns
/// `[⟨x,y0⟩, ⟨x,y1⟩, ⟨x,y2⟩, ⟨x,y3⟩]`, each bit-identical to
/// `vecops::dot` on finite data (32 independent accumulators, the same
/// 8-wide combine tree and serial remainder per output; multiplication
/// order within a product commutes bitwise for non-NaN operands).
///
/// This is the GEMV tile (4 matrix rows × one `x`), the `A·Bᵀ` row-dot
/// tile (one A row × 4 B rows), and the Gram-builder tile (one `x_i` ×
/// 4 `x_j`) — the shared stream is loaded once per 4 outputs, which is
/// what makes the blocking pay: these kernels are stream-bandwidth-bound.
// lint: alloc_free — register tile over borrowed slices; no allocation.
#[inline]
pub fn dot4(x: &[f64], y0: &[f64], y1: &[f64], y2: &[f64], y3: &[f64]) -> [f64; 4] {
    let n = x.len();
    debug_assert!(y0.len() == n && y1.len() == n && y2.len() == n && y3.len() == n);
    let mut a0 = [0.0f64; 8];
    let mut a1 = [0.0f64; 8];
    let mut a2 = [0.0f64; 8];
    let mut a3 = [0.0f64; 8];
    let xc = x.chunks_exact(8);
    let tail = n - xc.remainder().len();
    for (c, xs) in xc.enumerate() {
        let base = c * 8;
        let (c0, c1) = (&y0[base..base + 8], &y1[base..base + 8]);
        let (c2, c3) = (&y2[base..base + 8], &y3[base..base + 8]);
        for k in 0..8 {
            a0[k] += xs[k] * c0[k];
            a1[k] += xs[k] * c1[k];
            a2[k] += xs[k] * c2[k];
            a3[k] += xs[k] * c3[k];
        }
    }
    let mut s0 = ((a0[0] + a0[4]) + (a0[1] + a0[5])) + ((a0[2] + a0[6]) + (a0[3] + a0[7]));
    let mut s1 = ((a1[0] + a1[4]) + (a1[1] + a1[5])) + ((a1[2] + a1[6]) + (a1[3] + a1[7]));
    let mut s2 = ((a2[0] + a2[4]) + (a2[1] + a2[5])) + ((a2[2] + a2[6]) + (a2[3] + a2[7]));
    let mut s3 = ((a3[0] + a3[4]) + (a3[1] + a3[5])) + ((a3[2] + a3[6]) + (a3[3] + a3[7]));
    for i in tail..n {
        let xi = x[i];
        s0 += xi * y0[i];
        s1 += xi * y1[i];
        s2 += xi * y2[i];
        s3 += xi * y3[i];
    }
    [s0, s1, s2, s3]
}

// ---------------------------------------------------------------------
// GEMV chunk: 4-row × 8-col register tile
// ---------------------------------------------------------------------

/// Tiled body for one `matvec_into` chunk: `out[r] = ⟨A[row0+r, :], x⟩`
/// for `r` in `0..out.len()`, four rows per pass over `x`.
// lint: alloc_free — slices only; per-row bits match vecops::dot.
pub fn gemv_chunk(data: &[f64], cols: usize, row0: usize, x: &[f64], out: &mut [f64]) {
    let rows_here = out.len();
    let mut r = 0;
    while r + 4 <= rows_here {
        let base = (row0 + r) * cols;
        let d = dot4(
            x,
            &data[base..base + cols],
            &data[base + cols..base + 2 * cols],
            &data[base + 2 * cols..base + 3 * cols],
            &data[base + 3 * cols..base + 4 * cols],
        );
        out[r] = d[0];
        out[r + 1] = d[1];
        out[r + 2] = d[2];
        out[r + 3] = d[3];
        r += 4;
    }
    for rr in r..rows_here {
        let base = (row0 + rr) * cols;
        out[rr] = vecops::dot(&data[base..base + cols], x);
    }
}

// ---------------------------------------------------------------------
// A·Bᵀ row-dot sweep: 1×4 tile over B rows
// ---------------------------------------------------------------------

/// Tiled body for one `matmul_nt` output row: `ci[j] = ⟨ai, B[j, :]⟩`,
/// four B rows per pass over `ai`.
// lint: alloc_free — slices only; per-element bits match vecops::dot.
pub fn rowdot_nt(ai: &[f64], b: &[f64], k: usize, ci: &mut [f64]) {
    let n = ci.len();
    let mut j = 0;
    while j + 4 <= n {
        let d = dot4(
            ai,
            &b[j * k..(j + 1) * k],
            &b[(j + 1) * k..(j + 2) * k],
            &b[(j + 2) * k..(j + 3) * k],
            &b[(j + 3) * k..(j + 4) * k],
        );
        ci[j..j + 4].copy_from_slice(&d);
        j += 4;
    }
    while j < n {
        ci[j] = vecops::dot(ai, &b[j * k..(j + 1) * k]);
        j += 1;
    }
}

// ---------------------------------------------------------------------
// GEMM chunk: 4×8 micro-tile over packed B panels
// ---------------------------------------------------------------------

/// Tiled body for one `matmul_into` row chunk: `chunk += A[row0.., :] · B`
/// where `chunk` holds `rows_here = chunk.len() / n` pre-zeroed C rows.
///
/// Per `KC` block of `k`, an occupancy scan over the chunk's A panel
/// routes mostly-zero panels (Dense-policy GVT `W`) to the skip-zero
/// scalar loop; dense panels pack B into a stack-resident `KC×NR` panel
/// and run 4×8 register tiles seeded from the current C values. Both
/// routes execute each C element's `k`-ascending chain identically
/// (finite data; see module docs for the ±0.0 argument).
// lint: alloc_free — B panel is a fixed stack array; borrows otherwise.
pub fn gemm_chunk(a: &[f64], b: &[f64], k: usize, n: usize, row0: usize, chunk: &mut [f64]) {
    if n == 0 {
        return;
    }
    let rows_here = chunk.len() / n;
    let mut panel = [0.0f64; KC * NR];
    let n_full = n - n % NR;
    let mut kb = 0;
    while kb < k {
        let kc = (k - kb).min(KC);
        // Occupancy scan: `rows_here × kc` loads, a ~1/n fraction of the
        // multiply work it sizes up.
        let mut nnz = 0usize;
        for i in 0..rows_here {
            let arow = &a[(row0 + i) * k + kb..(row0 + i) * k + kb + kc];
            for &v in arow {
                nnz += (v != 0.0) as usize;
            }
        }
        if (nnz as f64) < SPARSE_PANEL_OCCUPANCY * (rows_here * kc) as f64 {
            // Sparse-panel escape: the historical skip-zero axpy loop.
            for i in 0..rows_here {
                let arow = &a[(row0 + i) * k..(row0 + i) * k + k];
                let ci = &mut chunk[i * n..(i + 1) * n];
                for kk in kb..kb + kc {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (cij, bkj) in ci.iter_mut().zip(brow) {
                        *cij += aik * bkj;
                    }
                }
            }
            kb += kc;
            continue;
        }
        // Packed path over full NR-wide column bands.
        let mut jb = 0;
        while jb < n_full {
            for kk in 0..kc {
                let src = &b[(kb + kk) * n + jb..(kb + kk) * n + jb + NR];
                panel[kk * NR..kk * NR + NR].copy_from_slice(src);
            }
            let mut i = 0;
            while i + MR <= rows_here {
                gemm_tile_4x8(a, k, row0 + i, kb, kc, &panel, chunk, i, n, jb);
                i += MR;
            }
            while i < rows_here {
                gemm_tile_1x8(a, k, row0 + i, kb, kc, &panel, chunk, i, n, jb);
                i += 1;
            }
            jb += NR;
        }
        // Column remainder (n % NR): branch-free scalar sweep.
        if n_full < n {
            for i in 0..rows_here {
                let arow = &a[(row0 + i) * k..(row0 + i) * k + k];
                let ci = &mut chunk[i * n + n_full..(i + 1) * n];
                for kk in kb..kb + kc {
                    let aik = arow[kk];
                    let brow = &b[kk * n + n_full..kk * n + n];
                    for (cij, bkj) in ci.iter_mut().zip(brow) {
                        *cij += aik * bkj;
                    }
                }
            }
        }
        kb += kc;
    }
}

/// 4×8 register tile: `C[ci0..ci0+4, jb..jb+8] += A-block · panel`,
/// seeded from (and stored back to) the live C values so the per-element
/// chain continues across `KC` blocks unchanged.
// lint: alloc_free — fixed-size register tile.
#[allow(clippy::too_many_arguments)]
#[inline]
fn gemm_tile_4x8(
    a: &[f64],
    k: usize,
    arow0: usize,
    kb: usize,
    kc: usize,
    panel: &[f64],
    chunk: &mut [f64],
    ci0: usize,
    n: usize,
    jb: usize,
) {
    let mut acc = [[0.0f64; NR]; MR];
    for (r, accr) in acc.iter_mut().enumerate() {
        let base = (ci0 + r) * n + jb;
        accr.copy_from_slice(&chunk[base..base + NR]);
    }
    let a0 = &a[arow0 * k + kb..arow0 * k + kb + kc];
    let a1 = &a[(arow0 + 1) * k + kb..(arow0 + 1) * k + kb + kc];
    let a2 = &a[(arow0 + 2) * k + kb..(arow0 + 2) * k + kb + kc];
    let a3 = &a[(arow0 + 3) * k + kb..(arow0 + 3) * k + kb + kc];
    for kk in 0..kc {
        let bp = &panel[kk * NR..kk * NR + NR];
        let (v0, v1, v2, v3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
        for c in 0..NR {
            acc[0][c] += v0 * bp[c];
            acc[1][c] += v1 * bp[c];
            acc[2][c] += v2 * bp[c];
            acc[3][c] += v3 * bp[c];
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let base = (ci0 + r) * n + jb;
        chunk[base..base + NR].copy_from_slice(accr);
    }
}

/// 1×8 edge tile for chunks whose row count is not a multiple of `MR`.
// lint: alloc_free — fixed-size register tile.
#[allow(clippy::too_many_arguments)]
#[inline]
fn gemm_tile_1x8(
    a: &[f64],
    k: usize,
    arow: usize,
    kb: usize,
    kc: usize,
    panel: &[f64],
    chunk: &mut [f64],
    ci: usize,
    n: usize,
    jb: usize,
) {
    let base = ci * n + jb;
    let mut acc = [0.0f64; NR];
    acc.copy_from_slice(&chunk[base..base + NR]);
    let arow = &a[arow * k + kb..arow * k + kb + kc];
    for kk in 0..kc {
        let bp = &panel[kk * NR..kk * NR + NR];
        let v = arow[kk];
        for c in 0..NR {
            acc[c] += v * bp[c];
        }
    }
    chunk[base..base + NR].copy_from_slice(&acc);
}

// ---------------------------------------------------------------------
// Stage-1 tiles: 8-row scatter / grouped-gather
// ---------------------------------------------------------------------

/// 8-row head for `vec_trick::stage1_scatter`: processes
/// `floor(rows_here / 8) · 8` S rows and returns how many it consumed
/// (the caller finishes with the 4-row and single-row passes). The three
/// index/coefficient streams are loaded once per 8 rows; per-(row, j)
/// update order is exactly the scalar loop's.
// lint: alloc_free — splits the chunk into row slices only.
pub fn stage1_scatter8(
    mat: &Mat,
    row0: usize,
    chunk: &mut [f64],
    row_len: usize,
    scatter: &[u32],
    gather: &[u32],
    a: &[f64],
) -> usize {
    let rows_here = chunk.len() / row_len.max(1);
    let mut r = 0;
    while r + 8 <= rows_here {
        let m0 = mat.row(row0 + r);
        let m1 = mat.row(row0 + r + 1);
        let m2 = mat.row(row0 + r + 2);
        let m3 = mat.row(row0 + r + 3);
        let m4 = mat.row(row0 + r + 4);
        let m5 = mat.row(row0 + r + 5);
        let m6 = mat.row(row0 + r + 6);
        let m7 = mat.row(row0 + r + 7);
        let (s0, rest) = chunk[r * row_len..].split_at_mut(row_len);
        let (s1, rest) = rest.split_at_mut(row_len);
        let (s2, rest) = rest.split_at_mut(row_len);
        let (s3, rest) = rest.split_at_mut(row_len);
        let (s4, rest) = rest.split_at_mut(row_len);
        let (s5, rest) = rest.split_at_mut(row_len);
        let (s6, s7full) = rest.split_at_mut(row_len);
        let s7 = &mut s7full[..row_len];
        for j in 0..a.len() {
            let dst = scatter[j] as usize;
            let src = gather[j] as usize;
            let aj = a[j];
            s0[dst] += m0[src] * aj;
            s1[dst] += m1[src] * aj;
            s2[dst] += m2[src] * aj;
            s3[dst] += m3[src] * aj;
            s4[dst] += m4[src] * aj;
            s5[dst] += m5[src] * aj;
            s6[dst] += m6[src] * aj;
            s7[dst] += m7[src] * aj;
        }
        r += 8;
    }
    r
}

/// 8-row head for the fused plan's grouped stage-1 kernel (same contract
/// as [`stage1_scatter8`]: returns rows consumed). Each S cell keeps its
/// serial single-accumulator sum over the cell's group, matching the
/// scalar body bit-for-bit; only the index streams are amortized.
// lint: alloc_free — register accumulators + row splits only.
#[allow(clippy::too_many_arguments)]
pub fn stage1_grouped8(
    mat: &Mat,
    row0: usize,
    chunk: &mut [f64],
    row_len: usize,
    offsets: &[u32],
    order: &[u32],
    gather_keys: &[u32],
    a: &[f64],
) -> usize {
    let rows_here = chunk.len() / row_len.max(1);
    let mut r = 0;
    while r + 8 <= rows_here {
        let m0 = mat.row(row0 + r);
        let m1 = mat.row(row0 + r + 1);
        let m2 = mat.row(row0 + r + 2);
        let m3 = mat.row(row0 + r + 3);
        let m4 = mat.row(row0 + r + 4);
        let m5 = mat.row(row0 + r + 5);
        let m6 = mat.row(row0 + r + 6);
        let m7 = mat.row(row0 + r + 7);
        let (s0, rest) = chunk[r * row_len..].split_at_mut(row_len);
        let (s1, rest) = rest.split_at_mut(row_len);
        let (s2, rest) = rest.split_at_mut(row_len);
        let (s3, rest) = rest.split_at_mut(row_len);
        let (s4, rest) = rest.split_at_mut(row_len);
        let (s5, rest) = rest.split_at_mut(row_len);
        let (s6, s7full) = rest.split_at_mut(row_len);
        let s7 = &mut s7full[..row_len];
        for d in 0..row_len {
            let lo = offsets[d] as usize;
            let hi = offsets[d + 1] as usize;
            let mut acc = [0.0f64; 8];
            for k in lo..hi {
                let src = gather_keys[k] as usize;
                let aj = a[order[k] as usize];
                acc[0] += m0[src] * aj;
                acc[1] += m1[src] * aj;
                acc[2] += m2[src] * aj;
                acc[3] += m3[src] * aj;
                acc[4] += m4[src] * aj;
                acc[5] += m5[src] * aj;
                acc[6] += m6[src] * aj;
                acc[7] += m7[src] * aj;
            }
            s0[d] = acc[0];
            s1[d] = acc[1];
            s2[d] = acc[2];
            s3[d] = acc[3];
            s4[d] = acc[4];
            s5[d] = acc[5];
            s6[d] = acc[6];
            s7[d] = acc[7];
        }
        r += 8;
    }
    r
}

// ---------------------------------------------------------------------
// Stage-2 multi-RHS tile: 8-wide output blocks held in registers
// ---------------------------------------------------------------------

/// Register-blocked multi-RHS stage-2 row:
/// `orow[bb] += Σ_d (c · lrow[d]) · s[sbase + d·b + bb]`, `d` ascending
/// per element. The scalar body streams `orow` through memory once per
/// `d`; this tile keeps each `NR`-wide `orow` block in registers across
/// the whole `d` sweep, turning `s_cols` loads+stores per output into
/// one — same chain, same `(c · lrow[d]) · s` association.
// lint: alloc_free — register block over borrowed S/out slices.
pub fn stage2_multi_row(lrow: &[f64], s: &[f64], sbase: usize, b: usize, c: f64, orow: &mut [f64]) {
    let s_cols = lrow.len();
    let b_full = b - b % NR;
    let mut bc = 0;
    while bc < b_full {
        let mut acc = [0.0f64; NR];
        acc.copy_from_slice(&orow[bc..bc + NR]);
        for (d, ld) in lrow.iter().enumerate() {
            let l = c * ld;
            let cell = &s[sbase + d * b + bc..sbase + d * b + bc + NR];
            for t in 0..NR {
                acc[t] += l * cell[t];
            }
        }
        orow[bc..bc + NR].copy_from_slice(&acc);
        bc += NR;
    }
    for bb in b_full..b {
        let mut acc = orow[bb];
        for d in 0..s_cols {
            acc += (c * lrow[d]) * s[sbase + d * b + bb];
        }
        orow[bb] = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{dist, Xoshiro256};

    #[test]
    fn dot4_matches_vecops_dot_bitwise() {
        let mut rng = Xoshiro256::seed_from(91);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 100] {
            let x = dist::normal_vec(&mut rng, n);
            let ys: Vec<Vec<f64>> = (0..4).map(|_| dist::normal_vec(&mut rng, n)).collect();
            let d = dot4(&x, &ys[0], &ys[1], &ys[2], &ys[3]);
            for (t, y) in ys.iter().enumerate() {
                assert_eq!(
                    d[t].to_bits(),
                    vecops::dot(y, &x).to_bits(),
                    "n={n} lane {t}"
                );
            }
        }
    }

    #[test]
    fn override_wins_over_env_default() {
        set_enabled(Some(false));
        assert!(!enabled());
        set_enabled(Some(true));
        assert!(enabled());
        set_enabled(None);
    }

    #[test]
    fn stage2_tile_matches_scalar_sweep() {
        let mut rng = Xoshiro256::seed_from(92);
        for (s_cols, b) in [(5usize, 3usize), (8, 8), (13, 11), (4, 16), (6, 1)] {
            let lrow = dist::normal_vec(&mut rng, s_cols);
            let s = dist::normal_vec(&mut rng, 2 * s_cols * b);
            let sbase = s_cols * b / 2;
            let init = dist::normal_vec(&mut rng, b);
            let c = 1.25;
            let mut tiled = init.clone();
            stage2_multi_row(&lrow, &s, sbase, b, c, &mut tiled);
            let mut scalar = init;
            for d in 0..s_cols {
                let l = c * lrow[d];
                let cell = &s[sbase + d * b..sbase + (d + 1) * b];
                for (ob, sb) in scalar.iter_mut().zip(cell) {
                    *ob += l * sb;
                }
            }
            for (a, b2) in tiled.iter().zip(&scalar) {
                assert_eq!(a.to_bits(), b2.to_bits(), "s_cols={s_cols} b={b}");
            }
        }
    }
}
