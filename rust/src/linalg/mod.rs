//! Dense linear algebra substrate (no external BLAS available offline).
//!
//! * [`Mat`] — row-major dense `f64` matrix with the operations the GVT
//!   stack needs: blocked & threaded GEMM, GEMV, transpose, row gather.
//! * [`chol`] — Cholesky factorization + triangular solves (closed-form
//!   ridge oracle and the Nyström/Falkon preconditioner).
//! * [`vecops`] — dot/axpy/norm primitives used by the iterative solvers.
//! * [`microkernel`] — register-blocked GEMV/GEMM/stage-2 tile kernels
//!   behind the pool's chunk bodies (`GVT_RLS_MICROKERNEL=0` ablation).
//! * [`par`] — scoped-thread parallel-for helper (no rayon offline).

pub mod chol;
pub mod eigh;
pub mod mat;
pub mod microkernel;
pub mod par;
pub mod vecops;

pub use mat::Mat;
