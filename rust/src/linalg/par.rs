//! Parallel-for façade over the persistent worker pool
//! ([`crate::runtime::pool`]).
//!
//! `rayon` is not available offline, so the hot paths fan work out over
//! the in-tree runtime. Historically this module spawned a fresh
//! `std::thread::scope` per call (~10 µs each) — with every GVT stage,
//! every GEMM/GEMV, and every solver iteration calling in here, that
//! spawn/join cost dominated at the `O(nm + nq)` per-product sizes the
//! paper makes possible. The entry points below keep their original
//! signatures but now compile each call into a chunk-claim job on the
//! shared pool: parked workers (plus the calling thread) dynamically
//! claim chunks, so load imbalance self-corrects and nothing is spawned.
//!
//! Chunking is **row-aligned and output-disjoint**: the unit of work is
//! always a whole run of output rows, each computed from scratch by
//! whichever thread claims it. Results are therefore bit-identical for
//! any thread count, any chunk-claim order, and under the
//! `GVT_RLS_POOL=0` scoped-spawn ablation (pinned by
//! `tests/pool_determinism.rs`).
//!
//! Small inputs (`len / min_per_thread <= 1`) run inline — a condvar
//! wake is ~1–2 µs, still not worth it for trivial work. Calls from
//! inside a parallel chunk also run inline (the pool's
//! nested-parallelism guard), so helpers here can be used freely from
//! other parallel bodies.

pub use crate::runtime::pool::{in_parallel_region, num_threads, run_chunks};

/// Chunks offered per worker thread. More chunks than workers lets idle
/// workers steal the tail of a slow worker's share; 4 keeps the
/// per-chunk claim overhead (one `fetch_add`) negligible against chunk
/// bodies that are ≥ `min_per_thread` elements by construction.
const CHUNKS_PER_WORKER: usize = 4;

/// Run `f(chunk_index, start, end)` over `0..len` split into contiguous
/// chunks of at least `min_per_thread` elements, dynamically claimed by
/// the pool's workers. Falls back to one inline `f(0, 0, len)` call for
/// small `len`.
///
/// `f` must be `Sync` because it is shared across workers; interior
/// mutability (disjoint output slices via raw parts, atomics) is the
/// caller's responsibility — see [`split_mut_chunks`] and
/// [`parallel_fill_rows`] for the safe patterns.
pub fn parallel_ranges<F>(len: usize, min_per_thread: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let min = min_per_thread.max(1);
    let threads = num_threads();
    let max_chunks = len / min;
    if threads == 1 || max_chunks <= 1 || in_parallel_region() {
        f(0, 0, len);
        return;
    }
    let chunks = (threads * CHUNKS_PER_WORKER).min(max_chunks);
    let chunk = len.div_ceil(chunks);
    let chunks = len.div_ceil(chunk);
    run_chunks(chunks, |ci| {
        let start = ci * chunk;
        let end = ((ci + 1) * chunk).min(len);
        f(ci, start, end);
    });
}

/// Split a mutable slice into `k` near-equal contiguous chunks (the safe
/// counterpart for writing disjoint outputs from `parallel_ranges` workers).
pub fn split_mut_chunks<'a, T>(xs: &'a mut [T], k: usize) -> Vec<&'a mut [T]> {
    let len = xs.len();
    let chunk = len.div_ceil(k.max(1)).max(1);
    xs.chunks_mut(chunk).collect()
}

/// Parallel map over disjoint output chunks: `out` is split to match the
/// ranges handed to `f(start, end, out_chunk)`.
pub fn parallel_fill<T, F>(out: &mut [T], min_per_thread: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    parallel_fill_rows(out, 1, min_per_thread, f)
}

/// Row-aligned parallel fill: `out` is treated as rows of `row_len`
/// elements and chunk boundaries always fall on row boundaries, so workers
/// that index `chunk[i * row_len ..]` stay consistent. `f(start, end,
/// chunk)` receives flat element offsets.
pub fn parallel_fill_rows<T, F>(out: &mut [T], row_len: usize, min_per_thread: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let len = out.len();
    assert!(row_len >= 1 && len % row_len == 0, "parallel_fill_rows: ragged rows");
    let rows = len / row_len;
    let min_rows = min_per_thread.div_ceil(row_len).max(1);
    let threads = num_threads();
    let max_chunks = rows / min_rows;
    if threads == 1 || max_chunks <= 1 || in_parallel_region() {
        f(0, len, out);
        return;
    }
    let chunks = (threads * CHUNKS_PER_WORKER).min(max_chunks);
    let chunk_rows = rows.div_ceil(chunks);
    let chunks = rows.div_ceil(chunk_rows);
    let base = out.as_mut_ptr() as usize;
    run_chunks(chunks, |ci| {
        let r0 = ci * chunk_rows;
        let r1 = ((ci + 1) * chunk_rows).min(rows);
        let (start, end) = (r0 * row_len, r1 * row_len);
        // SAFETY: distinct chunk indices map to disjoint element ranges
        // of `out` (row-aligned, non-overlapping by construction), each
        // claimed by exactly one thread; `out` is exclusively borrowed
        // for the duration of the blocking `run_chunks` call; `T: Send`.
        let chunk = unsafe {
            std::slice::from_raw_parts_mut((base as *mut T).add(start), end - start)
        };
        f(start, end, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_fill_covers_everything() {
        let mut out = vec![0usize; 10_000];
        parallel_fill(&mut out, 1, |start, _end, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = start + i;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn parallel_ranges_partition() {
        use std::sync::Mutex;
        let seen = Mutex::new(vec![0u8; 1000]);
        parallel_ranges(1000, 1, |_, s, e| {
            let mut g = seen.lock().unwrap();
            for i in s..e {
                g[i] += 1;
            }
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn small_len_runs_inline() {
        let mut out = vec![0.0f64; 7];
        parallel_fill(&mut out, 1024, |_, _, chunk| {
            for v in chunk.iter_mut() {
                *v = 1.0;
            }
        });
        assert!(out.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn row_alignment_is_respected() {
        // 33 rows of 7: every chunk boundary must land on a multiple of 7.
        let mut out = vec![0u32; 33 * 7];
        parallel_fill_rows(&mut out, 7, 7, |start, end, chunk| {
            assert_eq!(start % 7, 0);
            assert_eq!(end % 7, 0);
            assert_eq!(chunk.len(), end - start);
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = (start + i) as u32;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn nested_fill_from_parallel_body_runs_inline() {
        // A parallel body may call back into the façade; the pool's
        // region guard must route the inner call inline.
        let mut out = vec![0.0f64; 4096];
        parallel_fill(&mut out, 1, |start, _end, chunk| {
            let mut inner = vec![0.0f64; 64];
            parallel_fill(&mut inner, 1, |s, _e, c| {
                for (i, v) in c.iter_mut().enumerate() {
                    *v = (s + i) as f64;
                }
            });
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = inner[(start + i) % 64];
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i % 64) as f64);
        }
    }
}
