//! Scoped-thread parallelism helper. `rayon` is not available offline, so
//! the hot paths fan work out over `std::thread::scope` with static
//! chunking — adequate because our parallel loops are regular (rows of a
//! matrix, chunks of an output vector).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: `GVT_RLS_THREADS` env override, else
/// available parallelism, clamped to at least 1.
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let cached = CACHED.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("GVT_RLS_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Run `f(chunk_index, start, end)` over `0..len` split into contiguous
/// chunks, one per worker. Falls back to inline execution for small `len`
/// (thread spawn ≈ 10 µs; not worth it under ~16k elements of trivial work).
///
/// `f` must be `Sync` because it is shared across workers; interior
/// mutability (disjoint output slices via raw parts, atomics) is the
/// caller's responsibility — see `split_mut_chunks` for the safe pattern.
pub fn parallel_ranges<F>(len: usize, min_per_thread: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let workers = num_threads().min(len / min_per_thread.max(1)).max(1);
    if workers == 1 {
        f(0, 0, len);
        return;
    }
    let chunk = len.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(len);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(w, start, end));
        }
    });
}

/// Split a mutable slice into `k` near-equal contiguous chunks (the safe
/// counterpart for writing disjoint outputs from `parallel_ranges` workers).
pub fn split_mut_chunks<'a, T>(xs: &'a mut [T], k: usize) -> Vec<&'a mut [T]> {
    let len = xs.len();
    let chunk = len.div_ceil(k.max(1)).max(1);
    xs.chunks_mut(chunk).collect()
}

/// Parallel map over disjoint output chunks: `out` is split to match the
/// ranges handed to `f(start, end, out_chunk)`.
pub fn parallel_fill<T, F>(out: &mut [T], min_per_thread: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    parallel_fill_rows(out, 1, min_per_thread, f)
}

/// Row-aligned parallel fill: `out` is treated as rows of `row_len`
/// elements and chunk boundaries always fall on row boundaries, so workers
/// that index `chunk[i * row_len ..]` stay consistent. `f(start, end,
/// chunk)` receives flat element offsets.
pub fn parallel_fill_rows<T, F>(out: &mut [T], row_len: usize, min_per_thread: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let len = out.len();
    assert!(row_len >= 1 && len % row_len == 0, "parallel_fill_rows: ragged rows");
    let rows = len / row_len;
    let min_rows = min_per_thread.div_ceil(row_len).max(1);
    let workers = num_threads().min(rows / min_rows).max(1);
    if workers == 1 {
        f(0, len, out);
        return;
    }
    let chunk_rows = rows.div_ceil(workers);
    let chunk = chunk_rows * row_len;
    std::thread::scope(|s| {
        let mut rest = out;
        let mut start = 0;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let f = &f;
            s.spawn(move || f(start, start + take, head));
            rest = tail;
            start += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_fill_covers_everything() {
        let mut out = vec![0usize; 10_000];
        parallel_fill(&mut out, 1, |start, _end, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = start + i;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn parallel_ranges_partition() {
        use std::sync::Mutex;
        let seen = Mutex::new(vec![0u8; 1000]);
        parallel_ranges(1000, 1, |_, s, e| {
            let mut g = seen.lock().unwrap();
            for i in s..e {
                g[i] += 1;
            }
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn small_len_runs_inline() {
        let mut out = vec![0.0f64; 7];
        parallel_fill(&mut out, 1024, |_, _, chunk| {
            for v in chunk.iter_mut() {
                *v = 1.0;
            }
        });
        assert!(out.iter().all(|&v| v == 1.0));
    }
}
