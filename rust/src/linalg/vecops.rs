//! Vector primitives for the iterative solvers (MINRES/CG run thousands of
//! these per training; kept allocation-free and auto-vectorizable).

/// Dot product.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    // Four-way unrolled accumulation: breaks the dependency chain so LLVM
    // emits vector FMAs; also slightly better numerics than strict serial.
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += x[i] * y[i];
        acc[1] += x[i + 1] * y[i + 1];
        acc[2] += x[i + 2] * y[i + 2];
        acc[3] += x[i + 3] * y[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// `y += a * x`.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// `y = a * x + b * y` (the MINRES update shape).
#[inline]
pub fn axpby(a: f64, x: &[f64], b: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = a * xi + b * *yi;
    }
}

/// Scale in place.
#[inline]
pub fn scale(x: &mut [f64], a: f64) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// Elementwise copy (explicit name for solver readability).
#[inline]
pub fn copy(from: &[f64], to: &mut [f64]) {
    to.copy_from_slice(from);
}

/// Max |x_i - y_i| (test helper).
pub fn max_abs_diff(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max)
}

/// Mean of a slice.
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().sum::<f64>() / x.len() as f64
}

/// Population standard deviation.
pub fn std_dev(x: &[f64]) -> f64 {
    if x.len() < 2 {
        return 0.0;
    }
    let m = mean(x);
    (x.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / x.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..1001).map(|i| (i as f64) * 0.25).collect();
        let y: Vec<f64> = (0..1001).map(|i| 1.0 - (i as f64) * 0.125).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-6 * naive.abs().max(1.0));
    }

    #[test]
    fn axpby_basic() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpby(2.0, &x, 0.5, &mut y);
        assert_eq!(y, vec![7.0, 14.0, 21.0]);
    }

    #[test]
    fn norm_of_unit_axis() {
        let mut x = vec![0.0; 9];
        x[4] = -3.0;
        assert_eq!(norm2(&x), 3.0);
    }

    #[test]
    fn mean_std() {
        let x = vec![2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&x) - 5.0).abs() < 1e-12);
        assert!((std_dev(&x) - 2.0).abs() < 1e-12);
    }
}
