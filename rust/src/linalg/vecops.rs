//! Vector primitives for the iterative solvers (MINRES/CG run thousands of
//! these per training; kept allocation-free and auto-vectorizable).
//!
//! §Perf: the hot primitives iterate via `chunks_exact` / 8-wide bodies.
//! The fixed-size chunk slices let LLVM drop every bounds check, and the
//! eight independent accumulators break the FP dependency chain so the
//! loop retires full-width FMAs instead of one serial add per element.

/// Dot product.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 8];
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let xr = xc.remainder();
    let yr = yc.remainder();
    for (xs, ys) in xc.zip(yc) {
        for k in 0..8 {
            acc[k] += xs[k] * ys[k];
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5]))
        + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for (xi, yi) in xr.iter().zip(yr) {
        s += xi * yi;
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// `y += a * x`.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let yc = y.chunks_exact_mut(8);
    let xc = x.chunks_exact(8);
    let xr = xc.remainder();
    for (ys, xs) in yc.zip(xc) {
        for k in 0..8 {
            ys[k] += a * xs[k];
        }
    }
    let tail = y.len() - xr.len();
    for (yi, xi) in y[tail..].iter_mut().zip(xr) {
        *yi += a * xi;
    }
}

/// `y += a * x` and return `‖y‖₂` of the updated vector in the same pass
/// (the CG residual-update shape: one stream over memory instead of two).
#[inline]
pub fn axpy_norm2(a: f64, x: &[f64], y: &mut [f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 8];
    let yc = y.chunks_exact_mut(8);
    let xc = x.chunks_exact(8);
    let xr = xc.remainder();
    for (ys, xs) in yc.zip(xc) {
        for k in 0..8 {
            let v = ys[k] + a * xs[k];
            ys[k] = v;
            acc[k] += v * v;
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5]))
        + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    let tail = y.len() - xr.len();
    for (yi, xi) in y[tail..].iter_mut().zip(xr) {
        let v = *yi + a * xi;
        *yi = v;
        s += v * v;
    }
    s.sqrt()
}

/// `y = a * x + b * y` (the MINRES update shape).
#[inline]
pub fn axpby(a: f64, x: &[f64], b: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let yc = y.chunks_exact_mut(8);
    let xc = x.chunks_exact(8);
    let xr = xc.remainder();
    for (ys, xs) in yc.zip(xc) {
        for k in 0..8 {
            ys[k] = a * xs[k] + b * ys[k];
        }
    }
    let tail = y.len() - xr.len();
    for (yi, xi) in y[tail..].iter_mut().zip(xr) {
        *yi = a * xi + b * *yi;
    }
}

/// `z = (x - a*u - b*w) * s` — the fused MINRES direction update
/// (`w_new = (v − ρ3·w_oold − ρ2·w_old) / ρ1` with `s = 1/ρ1`), one pass
/// over four streams instead of three two-stream passes.
#[inline]
pub fn fused_direction(z: &mut [f64], x: &[f64], a: f64, u: &[f64], b: f64, w: &[f64], s: f64) {
    debug_assert_eq!(z.len(), x.len());
    debug_assert_eq!(z.len(), u.len());
    debug_assert_eq!(z.len(), w.len());
    let n8 = (z.len() / 8) * 8;
    let zc = z.chunks_exact_mut(8);
    let xc = x.chunks_exact(8);
    let uc = u.chunks_exact(8);
    let wc = w.chunks_exact(8);
    for (((zs, xs), us), ws) in zc.zip(xc).zip(uc).zip(wc) {
        for k in 0..8 {
            zs[k] = (xs[k] - a * us[k] - b * ws[k]) * s;
        }
    }
    for i in n8..z.len() {
        z[i] = (x[i] - a * u[i] - b * w[i]) * s;
    }
}

// ---------------------------------------------------------------------
// Pooled variants — solver hot loops at large n
// ---------------------------------------------------------------------
//
// The elementwise primitives below fan out over the persistent worker
// pool once vectors are long enough that memory bandwidth beats a single
// core (`PAR_MIN_LEN`); below the threshold they are exactly the serial
// kernels. Only *elementwise* ops get pooled variants: each output
// element is computed by the same expression wherever the chunk
// boundaries fall, so results are bit-identical to the serial kernels
// for any worker count. Reductions (`dot`, `norm2`, `axpy_norm2`) stay
// serial on purpose — a parallel reduction's combine order would depend
// on the chunking, breaking the crate's bit-determinism contract (see
// rust/DESIGN.md §Runtime).

/// Length at which the pooled elementwise kernels start fanning out:
/// below this, a condvar wake (~1–2 µs) costs more than the loop.
const PAR_MIN_LEN: usize = 1 << 16;

/// Per-chunk floor for the pooled kernels (¼ of the threshold keeps at
/// least 4 chunks at the cutover length).
const PAR_MIN_CHUNK: usize = PAR_MIN_LEN / 4;

/// [`axpy`], fanned out over the worker pool for large `y`.
pub fn axpy_par(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if y.len() < PAR_MIN_LEN {
        return axpy(a, x, y);
    }
    crate::linalg::par::parallel_fill(y, PAR_MIN_CHUNK, |start, end, chunk| {
        axpy(a, &x[start..end], chunk);
    });
}

/// [`axpby`], fanned out over the worker pool for large `y`.
pub fn axpby_par(a: f64, x: &[f64], b: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if y.len() < PAR_MIN_LEN {
        return axpby(a, x, b, y);
    }
    crate::linalg::par::parallel_fill(y, PAR_MIN_CHUNK, |start, end, chunk| {
        axpby(a, &x[start..end], b, chunk);
    });
}

/// [`fused_direction`], fanned out over the worker pool for large `z`.
pub fn fused_direction_par(
    z: &mut [f64],
    x: &[f64],
    a: f64,
    u: &[f64],
    b: f64,
    w: &[f64],
    s: f64,
) {
    debug_assert_eq!(z.len(), x.len());
    if z.len() < PAR_MIN_LEN {
        return fused_direction(z, x, a, u, b, w, s);
    }
    crate::linalg::par::parallel_fill(z, PAR_MIN_CHUNK, |start, end, chunk| {
        fused_direction(chunk, &x[start..end], a, &u[start..end], b, &w[start..end], s);
    });
}

/// [`scale_into`], fanned out over the worker pool for large `dst`.
pub fn scale_into_par(dst: &mut [f64], src: &[f64], a: f64) {
    debug_assert_eq!(dst.len(), src.len());
    if dst.len() < PAR_MIN_LEN {
        return scale_into(dst, src, a);
    }
    crate::linalg::par::parallel_fill(dst, PAR_MIN_CHUNK, |start, end, chunk| {
        scale_into(chunk, &src[start..end], a);
    });
}

/// [`scale`], fanned out over the worker pool for large `x`.
pub fn scale_par(x: &mut [f64], a: f64) {
    if x.len() < PAR_MIN_LEN {
        return scale(x, a);
    }
    crate::linalg::par::parallel_fill(x, PAR_MIN_CHUNK, |_start, _end, chunk| {
        scale(chunk, a);
    });
}

/// `dst = src * a` (scaled copy; the MINRES Lanczos-normalization shape).
#[inline]
pub fn scale_into(dst: &mut [f64], src: &[f64], a: f64) {
    debug_assert_eq!(dst.len(), src.len());
    for (di, si) in dst.iter_mut().zip(src) {
        *di = si * a;
    }
}

/// Scale in place.
#[inline]
pub fn scale(x: &mut [f64], a: f64) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// Elementwise copy (explicit name for solver readability).
#[inline]
pub fn copy(from: &[f64], to: &mut [f64]) {
    to.copy_from_slice(from);
}

/// Max |x_i - y_i| (test helper).
pub fn max_abs_diff(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max)
}

/// Mean of a slice.
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().sum::<f64>() / x.len() as f64
}

/// Population standard deviation.
pub fn std_dev(x: &[f64]) -> f64 {
    if x.len() < 2 {
        return 0.0;
    }
    let m = mean(x);
    (x.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / x.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..1001).map(|i| (i as f64) * 0.25).collect();
        let y: Vec<f64> = (0..1001).map(|i| 1.0 - (i as f64) * 0.125).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-6 * naive.abs().max(1.0));
    }

    #[test]
    fn axpby_basic() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpby(2.0, &x, 0.5, &mut y);
        assert_eq!(y, vec![7.0, 14.0, 21.0]);
    }

    /// The 8-wide kernels must agree with their scalar definitions on
    /// lengths around the chunk boundary (0..=17 covers empty, tail-only,
    /// one chunk + tail, two chunks + tail).
    #[test]
    fn wide_kernels_match_scalar_on_ragged_lengths() {
        for n in 0..=17usize {
            let x: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5 - 1.0).collect();
            let y0: Vec<f64> = (0..n).map(|i| 2.0 - (i as f64) * 0.25).collect();
            // axpy
            let mut y = y0.clone();
            axpy(1.5, &x, &mut y);
            for i in 0..n {
                assert!((y[i] - (y0[i] + 1.5 * x[i])).abs() < 1e-12, "axpy n={n} i={i}");
            }
            // axpby
            let mut y = y0.clone();
            axpby(-0.5, &x, 2.0, &mut y);
            for i in 0..n {
                assert!((y[i] - (-0.5 * x[i] + 2.0 * y0[i])).abs() < 1e-12, "axpby n={n}");
            }
            // axpy_norm2
            let mut y = y0.clone();
            let nrm = axpy_norm2(0.75, &x, &mut y);
            let expect: f64 =
                y0.iter().zip(&x).map(|(a, b)| (a + 0.75 * b) * (a + 0.75 * b)).sum();
            assert!((nrm - expect.sqrt()).abs() < 1e-12, "axpy_norm2 n={n}");
            // fused_direction
            let u: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
            let w: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
            let mut z = vec![0.0; n];
            fused_direction(&mut z, &x, 0.3, &u, -0.7, &w, 2.0);
            for i in 0..n {
                let e = (x[i] - 0.3 * u[i] + 0.7 * w[i]) * 2.0;
                assert!((z[i] - e).abs() < 1e-12, "fused_direction n={n} i={i}");
            }
            // scale_into
            let mut z = vec![0.0; n];
            scale_into(&mut z, &x, -3.0);
            for i in 0..n {
                assert_eq!(z[i], x[i] * -3.0);
            }
        }
    }

    /// The pooled elementwise kernels must be BIT-identical to the
    /// serial ones above and below the fan-out threshold (elementwise ⇒
    /// chunking cannot change any output bit).
    #[test]
    fn pooled_kernels_bit_match_serial() {
        for n in [100usize, PAR_MIN_LEN + 123] {
            let x: Vec<f64> = (0..n).map(|i| ((i * 37 % 101) as f64) * 0.31 - 7.0).collect();
            let u: Vec<f64> = (0..n).map(|i| ((i * 53 % 97) as f64) * 0.11).collect();
            let w: Vec<f64> = (0..n).map(|i| ((i * 29 % 89) as f64) * -0.21).collect();
            let y0: Vec<f64> = (0..n).map(|i| ((i * 41 % 103) as f64) * 0.17 - 3.0).collect();
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

            let (mut a1, mut a2) = (y0.clone(), y0.clone());
            axpy(0.75, &x, &mut a1);
            axpy_par(0.75, &x, &mut a2);
            assert_eq!(bits(&a1), bits(&a2), "axpy n={n}");

            let (mut b1, mut b2) = (y0.clone(), y0.clone());
            axpby(-0.5, &x, 1.25, &mut b1);
            axpby_par(-0.5, &x, 1.25, &mut b2);
            assert_eq!(bits(&b1), bits(&b2), "axpby n={n}");

            let (mut z1, mut z2) = (vec![0.0; n], vec![0.0; n]);
            fused_direction(&mut z1, &x, 0.3, &u, -0.7, &w, 2.0);
            fused_direction_par(&mut z2, &x, 0.3, &u, -0.7, &w, 2.0);
            assert_eq!(bits(&z1), bits(&z2), "fused_direction n={n}");

            let (mut s1, mut s2) = (vec![0.0; n], vec![0.0; n]);
            scale_into(&mut s1, &x, -3.0);
            scale_into_par(&mut s2, &x, -3.0);
            assert_eq!(bits(&s1), bits(&s2), "scale_into n={n}");

            let (mut c1, mut c2) = (y0.clone(), y0);
            scale(&mut c1, 1.1);
            scale_par(&mut c2, 1.1);
            assert_eq!(bits(&c1), bits(&c2), "scale n={n}");
        }
    }

    #[test]
    fn norm_of_unit_axis() {
        let mut x = vec![0.0; 9];
        x[4] = -3.0;
        assert_eq!(norm2(&x), 3.0);
    }

    #[test]
    fn mean_std() {
        let x = vec![2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&x) - 5.0).abs() < 1e-12);
        assert!((std_dev(&x) - 2.0).abs() < 1e-12);
    }
}
