//! `gvt-lint` — a source-level static-analysis pass enforcing the repo's
//! runtime contracts (`gvt-rls lint [--json] [paths…]`).
//!
//! The crate's correctness story rests on three invariants that plain
//! `cargo test` samples but cannot exhaustively check: results are
//! bit-identical for any worker count (tests/pool_determinism.rs),
//! solver iterations never allocate (tests/alloc_free.rs), and the serve
//! loop survives arbitrary malformed input (tests/serve_concurrency.rs).
//! This pass makes the *source patterns* that break those invariants
//! build failures, so a regression in an untested configuration cannot
//! compile clean and ship. Six rules:
//!
//! * `determinism` — hash-map iteration, ad-hoc threads, wall-clock
//!   reads, and raw pool submission in result-affecting modules
//!   (`gvt/`, `linalg/`, `solvers/`, `serve/predictor.rs`);
//!   `runtime/pool.rs` and `linalg/par.rs` are the only sanctioned
//!   concurrency sites.
//! * `hot_alloc` — heap-allocating calls inside blocks annotated with
//!   the alloc-free marker comment (solver iteration bodies, the plan
//!   executors, the pool submission path).
//! * `unsafe_audit` — every `unsafe` site needs an immediately-preceding
//!   `SAFETY:` comment stating the invariant that makes it sound.
//! * `env_registry` — every `GVT_RLS`-prefixed knob read in source must
//!   appear in the README env-var table, and vice versa.
//! * `panic_surface` — unwrap/expect/panic/indexing in the serve request
//!   path must carry a justification.
//! * `clock_monopoly` — `Instant::now` / `SystemTime::now` anywhere
//!   outside `obs/clock.rs` and the measurement layers (`bench/`,
//!   `benches/`, `coordinator/`) must go through `crate::obs::clock`,
//!   so every latency number shares one shim and one anchor.
//!
//! Escapes are per-line comments — `lint: allow(<rule-key>, reason)` —
//! so every suppression is visible in review. The pass gates
//! `scripts/verify.sh` and `tests/lint_clean.rs`, and is zero-dependency
//! like the rest of the crate (see [`scan`] for the line scanner).

pub mod scan;

mod rules;

pub use rules::{check_all, Finding};

use crate::error::{Context, Result};
use std::path::{Path, PathBuf};

/// Directories walked when no explicit paths are given (repo-relative).
pub const DEFAULT_ROOTS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// A finished lint pass.
pub struct LintReport {
    /// All findings, sorted by file, line, rule.
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl LintReport {
    /// `file:line: rule: message` lines, one per finding.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: {}: {}\n", f.file, f.line, f.rule, f.message));
        }
        out
    }

    /// Machine-readable dump for the verify artifacts.
    pub fn render_json(&self) -> String {
        let mut out = String::from("{\n  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
                esc(&f.file),
                f.line,
                f.rule,
                esc(&f.message)
            ));
        }
        if !self.findings.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("],\n");
        out.push_str(&format!("  \"files_scanned\": {}\n}}", self.files_scanned));
        out
    }
}

/// Locate the repo root (the directory holding `rust/src` and
/// `README.md`) by walking up from the current directory.
pub fn find_repo_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust").join("src").is_dir() && dir.join("README.md").is_file() {
            return Some(dir);
        }
        dir = dir.parent()?.to_path_buf();
    }
}

/// Lint `paths` (files or directories; the [`DEFAULT_ROOTS`] under
/// `root` when empty) against the README at `root`.
pub fn lint_repo(root: &Path, paths: &[PathBuf]) -> Result<LintReport> {
    let mut on_disk: Vec<PathBuf> = Vec::new();
    if paths.is_empty() {
        for rel in DEFAULT_ROOTS {
            let dir = root.join(rel);
            if dir.is_dir() {
                collect_rs(&dir, &mut on_disk)?;
            }
        }
    } else {
        for p in paths {
            if p.is_dir() {
                collect_rs(p, &mut on_disk)?;
            } else {
                on_disk.push(p.clone());
            }
        }
    }
    on_disk.sort();
    on_disk.dedup();

    let canon_root = root.canonicalize().unwrap_or_else(|_| root.to_path_buf());
    let mut sources = Vec::with_capacity(on_disk.len());
    for path in &on_disk {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("lint: reading {}", path.display()))?;
        sources.push(scan::SourceFile::scan(&rel_label(&canon_root, path), &text));
    }

    let readme = std::fs::read_to_string(root.join("README.md")).ok();
    let findings = check_all(&sources, readme.as_deref());
    Ok(LintReport { findings, files_scanned: sources.len() })
}

/// Repo-relative, forward-slash label for rule scoping and reports.
fn rel_label(canon_root: &Path, path: &Path) -> String {
    let canon = path.canonicalize().unwrap_or_else(|_| path.to_path_buf());
    let rel = canon.strip_prefix(canon_root).unwrap_or(&canon);
    rel.to_string_lossy().replace('\\', "/")
}

/// Recursively collect `.rs` files, sorted so reports are deterministic.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("lint: reading directory {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().map_or(false, |x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_rendering_escapes_and_shapes() {
        let report = LintReport {
            findings: vec![Finding {
                file: "a\\b.rs".to_string(),
                line: 3,
                rule: "unsafe_audit",
                message: "needs \"SAFETY\"".to_string(),
            }],
            files_scanned: 7,
        };
        let j = report.render_json();
        let parsed = crate::runtime::json::Json::parse(&j).expect("render_json emits valid JSON");
        assert_eq!(parsed.get("files_scanned").and_then(|v| v.as_usize()), Some(7));
        let arr = parsed.get("findings").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("line").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(arr[0].get("file").and_then(|v| v.as_str()), Some("a\\b.rs"));
    }

    #[test]
    fn empty_report_renders_cleanly() {
        let report = LintReport { findings: Vec::new(), files_scanned: 0 };
        assert_eq!(report.render_text(), "");
        assert!(crate::runtime::json::Json::parse(&report.render_json()).is_ok());
    }
}
