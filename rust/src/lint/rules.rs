//! The six repo-contract rules, evaluated over scanned sources.
//!
//! Every rule reports `Finding`s; escapes are per-line justification
//! comments (see [`justified`]) so each suppression is visible in review.
//! Rule keys used in justifications: `determinism`, `alloc`, `panic`,
//! `clock`. The unsafe-audit rule's escape is the `SAFETY:` comment
//! itself, and the env-registry rule's is the README table — neither
//! needs `allow`.

use crate::lint::scan::{Line, SourceFile};
use std::collections::{BTreeMap, BTreeSet};

/// One rule violation, printed as `file:line: rule: message`.
pub struct Finding {
    /// Repo-relative path.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule identifier.
    pub rule: &'static str,
    /// What is wrong and what to do instead.
    pub message: String,
}

/// Run every rule over `files`. `readme` is the README text for the
/// env-registry rule; with `None` every env var read counts as
/// undocumented (used by fixtures; the driver always passes the file).
pub fn check_all(files: &[SourceFile], readme: Option<&str>) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        rule_determinism(f, &mut out);
        rule_alloc(f, &mut out);
        rule_unsafe(f, &mut out);
        rule_panic(f, &mut out);
        rule_clock(f, &mut out);
    }
    rule_env(files, readme, &mut out);
    out.sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));
    out
}

// ---------------------------------------------------------------------
// Scopes
// ---------------------------------------------------------------------

/// The only modules allowed to touch threads / raw pool submission: the
/// pool itself and the row-aligned wrappers that preserve determinism.
fn sanctioned_concurrency(rel: &str) -> bool {
    rel == "rust/src/runtime/pool.rs" || rel == "rust/src/linalg/par.rs"
}

/// Result-affecting modules: anything that can change a score by a bit.
fn deterministic_scope(rel: &str) -> bool {
    if sanctioned_concurrency(rel) {
        return false;
    }
    rel.starts_with("rust/src/gvt/")
        || rel.starts_with("rust/src/linalg/")
        || rel.starts_with("rust/src/solvers/")
        || rel == "rust/src/serve/predictor.rs"
}

/// The serve request path: a panic here kills a connection or the
/// dispatcher instead of producing an in-band JSON error. The fault
/// registry and the hot-reload slot are on that path too — an injected
/// fault or a failed reload must surface in-band, never abort.
fn panic_scope(rel: &str) -> bool {
    matches!(
        rel,
        "rust/src/serve/protocol.rs"
            | "rust/src/serve/server.rs"
            | "rust/src/serve/batcher.rs"
            | "rust/src/serve/reload.rs"
            | "rust/src/runtime/fault.rs"
    )
}

// ---------------------------------------------------------------------
// Escape hatches
// ---------------------------------------------------------------------

/// A finding on line `idx` is suppressed by a justification comment
/// `lint: allow(<key>, reason)` on the same line or on the contiguous
/// run of comment-only lines directly above it.
fn justified(lines: &[Line], idx: usize, key: &str) -> bool {
    let marker = format!("lint: allow({key}");
    if lines[idx].comment.contains(&marker) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if l.code.trim().is_empty() && !l.comment.is_empty() {
            if l.comment.contains(&marker) {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

/// An `unsafe` site is documented if a `SAFETY:` comment sits on the
/// same line or on the contiguous run of comment-only / attribute lines
/// immediately above it.
fn has_safety_comment(lines: &[Line], idx: usize) -> bool {
    if lines[idx].comment.contains("SAFETY:") {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        let code = l.code.trim();
        let comment_only = code.is_empty() && !l.comment.is_empty();
        let attribute = code.starts_with("#[");
        if comment_only || attribute {
            if l.comment.contains("SAFETY:") {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

// ---------------------------------------------------------------------
// Token matching
// ---------------------------------------------------------------------

/// Substring match with identifier-boundary checks on whichever ends of
/// the token are identifier characters (so `HashMap` does not match
/// `HashMapExt`, while `.unwrap()` matches regardless of what follows).
fn contains_token(code: &str, token: &str) -> bool {
    let first_ident = token
        .chars()
        .next()
        .map_or(false, |c| c.is_ascii_alphanumeric() || c == '_');
    let last_ident = token
        .chars()
        .last()
        .map_or(false, |c| c.is_ascii_alphanumeric() || c == '_');
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(token) {
        let abs = start + pos;
        let end = abs + token.len();
        let before_ok = !first_ident || abs == 0 || {
            let b = bytes[abs - 1];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        let after_ok = !last_ident || end >= code.len() || {
            let a = bytes[end];
            !(a.is_ascii_alphanumeric() || a == b'_')
        };
        if before_ok && after_ok {
            return true;
        }
        start = abs + token.len();
    }
    false
}

// ---------------------------------------------------------------------
// Rule 1: determinism
// ---------------------------------------------------------------------

const DETERMINISM_TOKENS: &[(&str, &str)] = &[
    (
        "HashMap",
        "iteration order is nondeterministic; use BTreeMap / an index-keyed Vec, or justify a lookup-only map",
    ),
    (
        "HashSet",
        "iteration order is nondeterministic; use BTreeSet or a sorted Vec",
    ),
    (
        "thread::spawn",
        "ad-hoc threads bypass the deterministic runtime pool; use linalg::par / runtime::pool",
    ),
    (
        "thread::scope",
        "ad-hoc scoped threads bypass the deterministic runtime pool; use linalg::par / runtime::pool",
    ),
    (
        "Instant::now",
        "wall-clock reads in a result-affecting module; route timing through obs::clock in a caller layer",
    ),
    (
        "SystemTime::now",
        "wall-clock reads in a result-affecting module; route timing through obs::clock in a caller layer",
    ),
    (
        "run_chunks",
        "raw pool submission in a result-affecting module; use the row-aligned linalg::par wrappers, or justify the chunk-to-output mapping",
    ),
];

fn rule_determinism(file: &SourceFile, out: &mut Vec<Finding>) {
    if !deterministic_scope(&file.rel_path) {
        return;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let head = line.code.trim_start();
        if head.starts_with("use ") || head.starts_with("pub use ") {
            continue;
        }
        for (token, why) in DETERMINISM_TOKENS {
            if contains_token(&line.code, token) && !justified(&file.lines, idx, "determinism") {
                out.push(Finding {
                    file: file.rel_path.clone(),
                    line: idx + 1,
                    rule: "determinism",
                    message: format!("`{token}`: {why}"),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------
// Rule 2: hot-path allocation
// ---------------------------------------------------------------------

const ALLOC_TOKENS: &[&str] = &[
    "Vec::new",
    "vec!",
    ".to_vec(",
    ".collect(",
    "collect::<",
    "Box::new",
    "format!",
    ".clone(",
    "String::new",
    ".to_string(",
    ".to_owned(",
    "with_capacity(",
];

fn rule_alloc(file: &SourceFile, out: &mut Vec<Finding>) {
    for (idx, line) in file.lines.iter().enumerate() {
        if !line.in_alloc_free || line.in_test {
            continue;
        }
        for token in ALLOC_TOKENS {
            if contains_token(&line.code, token) && !justified(&file.lines, idx, "alloc") {
                out.push(Finding {
                    file: file.rel_path.clone(),
                    line: idx + 1,
                    rule: "hot_alloc",
                    message: format!(
                        "`{token}` allocates inside an alloc-free region (tests/alloc_free.rs pins this dynamically)"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------
// Rule 3: unsafe audit
// ---------------------------------------------------------------------

fn rule_unsafe(file: &SourceFile, out: &mut Vec<Finding>) {
    for (idx, line) in file.lines.iter().enumerate() {
        // Applies everywhere, tests and benches included: an unsound
        // test helper is still unsound.
        if contains_token(&line.code, "unsafe") && !has_safety_comment(&file.lines, idx) {
            out.push(Finding {
                file: file.rel_path.clone(),
                line: idx + 1,
                rule: "unsafe_audit",
                message: "`unsafe` without an immediately-preceding `SAFETY:` comment stating the invariant that makes it sound".to_string(),
            });
        }
    }
}

// ---------------------------------------------------------------------
// Rule 4: env-var registry
// ---------------------------------------------------------------------

/// Assembled with `'_'` at match time so this file's own string
/// literals never register as knob reads.
const ENV_PREFIX: &str = "GVT_RLS";

fn extract_env_vars(text: &str, out: &mut BTreeSet<String>) {
    let pat = format!("{ENV_PREFIX}_");
    let bytes = text.as_bytes();
    let mut start = 0;
    while let Some(pos) = text[start..].find(&pat) {
        let abs = start + pos;
        if abs > 0 {
            let b = bytes[abs - 1];
            if b.is_ascii_alphanumeric() || b == b'_' {
                start = abs + pat.len();
                continue;
            }
        }
        let mut end = abs + pat.len();
        while end < bytes.len()
            && (bytes[end].is_ascii_uppercase() || bytes[end].is_ascii_digit() || bytes[end] == b'_')
        {
            end += 1;
        }
        if end > abs + pat.len() {
            out.insert(text[abs..end].to_string());
        }
        start = end;
    }
}

fn rule_env(files: &[SourceFile], readme: Option<&str>, out: &mut Vec<Finding>) {
    // Knob reads live inside string literals, so scan the strings channel.
    let mut used: BTreeMap<String, (String, usize)> = BTreeMap::new();
    for f in files {
        for (idx, line) in f.lines.iter().enumerate() {
            let mut vars = BTreeSet::new();
            extract_env_vars(&line.strings, &mut vars);
            for v in vars {
                used.entry(v).or_insert_with(|| (f.rel_path.clone(), idx + 1));
            }
        }
    }
    // Documented = rows of the README env-var table (`| `VAR` | effect |`);
    // prose mentions do not count as documentation.
    let mut documented: BTreeMap<String, usize> = BTreeMap::new();
    if let Some(text) = readme {
        for (idx, line) in text.lines().enumerate() {
            if !line.trim_start().starts_with('|') {
                continue;
            }
            let mut vars = BTreeSet::new();
            extract_env_vars(line, &mut vars);
            for v in vars {
                documented.entry(v).or_insert(idx + 1);
            }
        }
    }
    for (var, (file, line)) in &used {
        if !documented.contains_key(var) {
            out.push(Finding {
                file: file.clone(),
                line: *line,
                rule: "env_registry",
                message: format!("`{var}` is read in source but missing from the README env-var table"),
            });
        }
    }
    for (var, line) in &documented {
        if !used.contains_key(var) {
            out.push(Finding {
                file: "README.md".to_string(),
                line: *line,
                rule: "env_registry",
                message: format!("`{var}` is documented in the README env-var table but never read in source"),
            });
        }
    }
}

// ---------------------------------------------------------------------
// Rule 5: panic surface
// ---------------------------------------------------------------------

const PANIC_TOKENS: &[(&str, &str)] = &[
    (".unwrap()", "unwrap"),
    (".expect(", "expect"),
    ("panic!", "explicit panic"),
    ("unreachable!", "unreachable"),
    ("todo!", "todo"),
    ("unimplemented!", "unimplemented"),
];

/// `x[i]` / `x[a..b]` indexing: a `[` whose immediately-preceding byte
/// is an identifier character, `)`, or `]`. Attribute (`#[`), macro
/// (`vec![`), slice-type (`: [f64; 4]`), and slice-pattern (`let [a, b]`)
/// brackets all fail that test.
fn has_indexing(code: &str) -> bool {
    let b = code.as_bytes();
    for i in 1..b.len() {
        if b[i] == b'['
            && (b[i - 1].is_ascii_alphanumeric()
                || b[i - 1] == b'_'
                || b[i - 1] == b')'
                || b[i - 1] == b']')
        {
            return true;
        }
    }
    false
}

fn rule_panic(file: &SourceFile, out: &mut Vec<Finding>) {
    if !panic_scope(&file.rel_path) {
        return;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for (token, what) in PANIC_TOKENS {
            if contains_token(&line.code, token) && !justified(&file.lines, idx, "panic") {
                out.push(Finding {
                    file: file.rel_path.clone(),
                    line: idx + 1,
                    rule: "panic_surface",
                    message: format!(
                        "`{token}` ({what}) in the serve request path: malformed input must produce an in-band JSON error, not kill a worker"
                    ),
                });
            }
        }
        if has_indexing(&line.code) && !justified(&file.lines, idx, "panic") {
            out.push(Finding {
                file: file.rel_path.clone(),
                line: idx + 1,
                rule: "panic_surface",
                message: "indexing/slicing can panic in the serve request path: bounds-check and return a protocol error, or justify why it cannot overrun".to_string(),
            });
        }
    }
}

// ---------------------------------------------------------------------
// Rule 6: clock monopoly
// ---------------------------------------------------------------------

/// The layers allowed to read the wall clock directly: the obs clock
/// shim itself (everything else goes through it) and the offline
/// measurement layers, whose whole job is timing.
fn clock_sanctioned(rel: &str) -> bool {
    rel == "rust/src/obs/clock.rs"
        || rel.starts_with("rust/src/bench/")
        || rel.starts_with("rust/benches/")
        || rel.starts_with("rust/src/coordinator/")
}

const CLOCK_TOKENS: &[&str] = &["Instant::now", "SystemTime::now"];

/// Every wall-clock read outside the sanctioned timing layers must go
/// through `obs::clock` — one shim, one anchor, one place to audit when
/// a latency number looks wrong.
fn rule_clock(file: &SourceFile, out: &mut Vec<Finding>) {
    if clock_sanctioned(&file.rel_path) {
        return;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let head = line.code.trim_start();
        if head.starts_with("use ") || head.starts_with("pub use ") {
            continue;
        }
        for token in CLOCK_TOKENS {
            if contains_token(&line.code, token) && !justified(&file.lines, idx, "clock") {
                out.push(Finding {
                    file: file.rel_path.clone(),
                    line: idx + 1,
                    rule: "clock_monopoly",
                    message: format!(
                        "`{token}` outside the sanctioned timing layers; call crate::obs::clock::now / monotonic_us instead"
                    ),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_str(rel: &str, src: &str) -> Vec<Finding> {
        let f = SourceFile::scan(rel, src);
        check_all(&[f], None)
    }

    #[test]
    fn determinism_flags_hash_collections_in_scope() {
        let src = "fn f() {\n    let m = std::collections::HashMap::<u32, u32>::new();\n}\n";
        let f = lint_str("rust/src/gvt/fixture.rs", src);
        assert_eq!(f.len(), 1, "{:?}", f.iter().map(|x| &x.message).collect::<Vec<_>>());
        assert_eq!(f[0].rule, "determinism");
        assert_eq!(f[0].line, 2);
        // Sanctioned concurrency site: exempt.
        assert!(lint_str("rust/src/linalg/par.rs", src).is_empty());
        // Outside the result-affecting modules: exempt.
        assert!(lint_str("rust/src/bench/fixture.rs", src).is_empty());
    }

    #[test]
    fn determinism_skips_use_lines_and_accepts_justifications() {
        let src = "use std::collections::HashMap;\nfn f() {}\n";
        assert!(lint_str("rust/src/gvt/fixture.rs", src).is_empty());
        let justified = "fn f() {\n    // lint: allow(determinism, lookup-only map)\n    let m = std::collections::HashMap::<u32, u32>::new();\n}\n";
        assert!(lint_str("rust/src/gvt/fixture.rs", justified).is_empty());
    }

    #[test]
    fn determinism_flags_adhoc_threads_and_raw_submission() {
        let src = "fn f() {\n    std::thread::spawn(|| {});\n    crate::runtime::pool::run_chunks(4, |_| {});\n}\n";
        let f = lint_str("rust/src/solvers/fixture.rs", src);
        assert_eq!(f.len(), 2);
        assert!(f.iter().all(|x| x.rule == "determinism"));
    }

    #[test]
    fn alloc_rule_is_scoped_to_annotated_blocks() {
        let src = "\
fn solver() {
    let setup = vec![0.0; 4];
    // lint: alloc_free
    for _k in 0..3 {
        let hot = vec![0.0; 4];
    }
    let teardown = vec![0.0; 4];
}
";
        let f = lint_str("rust/src/anywhere.rs", src);
        assert_eq!(f.len(), 1, "{:?}", f.iter().map(|x| x.line).collect::<Vec<_>>());
        assert_eq!(f[0].rule, "hot_alloc");
        assert_eq!(f[0].line, 5);
    }

    #[test]
    fn alloc_rule_accepts_justifications_and_clean_bodies() {
        let justified = "\
// lint: alloc_free
fn hot(buf: &mut [f64]) {
    // lint: allow(alloc, one-time warmup growth)
    let w = vec![0.0; 4];
    buf[0] = w[0];
}
";
        assert!(lint_str("rust/src/anywhere.rs", justified).is_empty());
        let clean = "// lint: alloc_free\nfn hot(buf: &mut [f64]) {\n    buf[0] += 1.0;\n}\n";
        assert!(lint_str("rust/src/anywhere.rs", clean).is_empty());
    }

    #[test]
    fn unsafe_rule_requires_safety_comment() {
        let bad = "fn f(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n";
        let f = lint_str("rust/src/anywhere.rs", bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "unsafe_audit");
        assert_eq!(f[0].line, 2);
        let good = "fn f(p: *const u32) -> u32 {\n    // SAFETY: caller guarantees p is valid\n    unsafe { *p }\n}\n";
        assert!(lint_str("rust/src/anywhere.rs", good).is_empty());
        // Comment + attribute run above the site still counts.
        let attr = "// SAFETY: the pointee outlives the queue entry\n#[allow(dead_code)]\nunsafe impl Send for X {}\n";
        assert!(lint_str("rust/src/anywhere.rs", attr).is_empty());
    }

    #[test]
    fn unsafe_rule_applies_inside_test_modules() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(p: *const u32) -> u32 {\n        unsafe { *p }\n    }\n}\n";
        let f = lint_str("rust/src/anywhere.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn env_rule_reports_both_directions() {
        // Var names are assembled at runtime so this file's own literals
        // never register with the extractor.
        let used = format!("{}_{}", ENV_PREFIX, "FIXTURE_KNOB");
        let dead = format!("{}_{}", ENV_PREFIX, "GHOST_KNOB");
        let src = format!("fn f() {{\n    let _ = std::env::var(\"{used}\");\n}}\n");
        let readme = format!("| `{dead}` | does nothing |\n");
        let files = [SourceFile::scan("rust/src/anywhere.rs", &src)];
        let f = check_all(&files, Some(&readme));
        assert_eq!(f.len(), 2, "{:?}", f.iter().map(|x| &x.message).collect::<Vec<_>>());
        assert!(f.iter().any(|x| x.rule == "env_registry" && x.message.contains(&used)));
        assert!(f.iter().any(|x| x.file == "README.md" && x.message.contains(&dead)));
        // Documented + used: clean.
        let ok_readme = format!("| `{used}` | fixture knob |\n");
        assert!(check_all(&files, Some(&ok_readme)).is_empty());
    }

    #[test]
    fn panic_rule_flags_unwrap_and_indexing_in_serve_path() {
        let src = "fn f(v: &[f64], o: Option<f64>) -> f64 {\n    v[0] + o.unwrap()\n}\n";
        let f = lint_str("rust/src/serve/protocol.rs", src);
        assert_eq!(f.len(), 2);
        assert!(f.iter().all(|x| x.rule == "panic_surface"));
        // Same code outside the serve request path: not this rule's business.
        assert!(lint_str("rust/src/gvt/fixture.rs", src).is_empty());
    }

    #[test]
    fn panic_rule_accepts_justifications_and_safe_patterns() {
        let justified = "fn f(v: &[f64]) -> f64 {\n    // lint: allow(panic, length checked by caller)\n    v[0]\n}\n";
        assert!(lint_str("rust/src/serve/server.rs", justified).is_empty());
        // unwrap_or is not unwrap; slice patterns and attributes are not
        // indexing; vec! macro brackets are not indexing.
        let safe = "#[derive(Clone)]\nstruct S;\nfn f(v: &[f64], o: Option<f64>) -> f64 {\n    let [a, _b] = v else { return 0.0 };\n    let w = vec![1.0];\n    *a + o.unwrap_or(w[0] * 0.0)\n}\n";
        let f = lint_str("rust/src/serve/batcher.rs", safe);
        // Only w[0] is real indexing here.
        assert_eq!(f.len(), 1, "{:?}", f.iter().map(|x| (x.line, &x.message)).collect::<Vec<_>>());
        assert_eq!(f[0].line, 6);
    }

    #[test]
    fn clock_rule_enforces_the_obs_monopoly() {
        let src = "fn f() {\n    let _t = std::time::Instant::now();\n}\n";
        // Outside the sanctioned timing layers: flagged.
        let f = lint_str("rust/src/serve/batcher.rs", src);
        assert_eq!(f.len(), 1, "{:?}", f.iter().map(|x| &x.message).collect::<Vec<_>>());
        assert_eq!(f[0].rule, "clock_monopoly");
        assert_eq!(f[0].line, 2);
        // The shim itself and the measurement layers: exempt.
        assert!(lint_str("rust/src/obs/clock.rs", src).is_empty());
        assert!(lint_str("rust/src/bench/fixture.rs", src).is_empty());
        assert!(lint_str("rust/benches/bench_fixture.rs", src).is_empty());
        assert!(lint_str("rust/src/coordinator/fixture.rs", src).is_empty());
        // Importing the Instant *type* is fine; only `::now` reads are
        // the monopoly's business — and justifications still work.
        assert!(lint_str("rust/src/serve/batcher.rs", "use std::time::Instant;\n").is_empty());
        let justified = "fn f() {\n    // lint: allow(clock, timing a cold error path)\n    let _t = std::time::Instant::now();\n}\n";
        assert!(lint_str("rust/src/serve/batcher.rs", justified).is_empty());
        // SystemTime is covered too.
        let sys = "fn f() {\n    let _t = std::time::SystemTime::now();\n}\n";
        assert_eq!(lint_str("rust/src/runtime/pool.rs", sys).len(), 1);
    }

    #[test]
    fn seeded_violations_trip_all_six_rules() {
        let used = format!("{}_{}", ENV_PREFIX, "SEEDED_KNOB");
        let src = format!(
            "fn f(p: *const u32, v: &[f64]) {{\n    let m = std::collections::HashMap::<u32, u32>::new();\n    let _ = std::env::var(\"{used}\");\n    let _ = unsafe {{ *p }};\n    let _ = v[0];\n    // lint: alloc_free\n    {{\n        let hot = vec![0.0; 4];\n    }}\n}}\n"
        );
        let files = [SourceFile::scan("rust/src/serve/predictor.rs", &src)];
        // predictor.rs is in the determinism scope; route the panic-rule
        // and clock-rule tokens through a serve-path fixture as well.
        let serve = SourceFile::scan(
            "rust/src/serve/server.rs",
            "fn g(v: &[f64]) -> f64 {\n    let _t = std::time::Instant::now();\n    v[0]\n}\n",
        );
        let all = [files.into_iter().next().unwrap(), serve];
        let f = check_all(&all, Some(""));
        let rules: BTreeSet<&str> = f.iter().map(|x| x.rule).collect();
        for expected in [
            "determinism",
            "hot_alloc",
            "unsafe_audit",
            "env_registry",
            "panic_surface",
            "clock_monopoly",
        ] {
            assert!(rules.contains(expected), "missing {expected}: got {rules:?}");
        }
    }
}
