//! Line-level Rust source scanner for the lint pass.
//!
//! Deliberately *not* a full lexer: rules in this crate only need to know,
//! per line, (a) what is code vs. comment vs. string-literal content, and
//! (b) whether the line sits inside one of two brace-delimited regions —
//! a `#[cfg(test)]` item or a block annotated with the alloc-free marker
//! comment. The scanner therefore classifies each line into three
//! channels and tracks literal/comment state *across* lines, so token
//! matching on the `code` channel never fires on text inside a string,
//! a char literal, or a comment.
//!
//! Handled literal forms: `"…"` (including multi-line and `\`-escaped),
//! `r"…"` / `r#"…"#` raw strings, `b"…"` byte strings, `'x'` / `'\n'` /
//! `'\u{8}'` char literals (disambiguated from lifetimes and loop labels
//! without lookbehind), and nested `/* … */` block comments.

/// One scanned source line, split into channels.
pub struct Line {
    /// Source text with comments removed and string/char-literal contents
    /// blanked (the delimiting quotes remain, so shape is preserved).
    pub code: String,
    /// Comment text on this line: everything after `//`, and the contents
    /// of `/* … */` segments (including continuation lines).
    pub comment: String,
    /// Contents of string and char literals on this line, separated by
    /// `\n` so adjacent literals never concatenate into a false match.
    pub strings: String,
    /// Line is inside a `#[cfg(test)]` item (or a nested block of one).
    pub in_test: bool,
    /// Line is inside a block annotated with the alloc-free marker.
    pub in_alloc_free: bool,
}

/// A scanned file: repo-relative path (forward slashes) plus its lines.
pub struct SourceFile {
    pub rel_path: String,
    pub lines: Vec<Line>,
}

/// Scanner state that carries across lines.
enum Mode {
    Code,
    /// Inside `/* … */`; the payload is the nesting depth.
    BlockComment(u32),
    /// Inside a normal `"…"` string (they may span lines).
    Str,
    /// Inside a raw string; the payload is the `#` count of its opener.
    RawStr(u32),
}

impl SourceFile {
    /// Scan `text` into per-line channels and mark regions.
    pub fn scan(rel_path: &str, text: &str) -> SourceFile {
        let mut lines = Vec::new();
        let mut mode = Mode::Code;
        for raw in text.lines() {
            let chars: Vec<char> = raw.chars().collect();
            let mut code = String::new();
            let mut comment = String::new();
            let mut strings = String::new();
            let mut i = 0usize;
            while i < chars.len() {
                match mode {
                    Mode::BlockComment(depth) => {
                        if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                            i += 2;
                            mode = if depth == 1 {
                                Mode::Code
                            } else {
                                Mode::BlockComment(depth - 1)
                            };
                        } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                            mode = Mode::BlockComment(depth + 1);
                            i += 2;
                        } else {
                            comment.push(chars[i]);
                            i += 1;
                        }
                    }
                    Mode::Str => {
                        if chars[i] == '\\' {
                            if let Some(&c) = chars.get(i + 1) {
                                strings.push(c);
                            }
                            i += 2;
                        } else if chars[i] == '"' {
                            code.push('"');
                            strings.push('\n');
                            mode = Mode::Code;
                            i += 1;
                        } else {
                            strings.push(chars[i]);
                            i += 1;
                        }
                    }
                    Mode::RawStr(hashes) => {
                        if chars[i] == '"' && closes_raw(&chars, i, hashes) {
                            code.push('"');
                            strings.push('\n');
                            i += 1 + hashes as usize;
                            mode = Mode::Code;
                        } else {
                            strings.push(chars[i]);
                            i += 1;
                        }
                    }
                    Mode::Code => {
                        let c = chars[i];
                        let next = chars.get(i + 1).copied();
                        if c == '/' && next == Some('/') {
                            comment.extend(chars[i + 2..].iter());
                            i = chars.len();
                        } else if c == '/' && next == Some('*') {
                            mode = Mode::BlockComment(1);
                            i += 2;
                        } else if c == '"' {
                            code.push('"');
                            mode = Mode::Str;
                            i += 1;
                        } else if c == 'r' && !prev_is_ident(&chars, i) {
                            if let Some(h) = raw_string_hashes(&chars, i) {
                                code.push('r');
                                code.push('"');
                                i += 2 + h as usize;
                                mode = Mode::RawStr(h);
                            } else {
                                code.push(c);
                                i += 1;
                            }
                        } else if c == '\'' {
                            if let Some(end) = char_literal_end(&chars, i) {
                                code.push('\'');
                                strings.extend(chars[i + 1..end].iter());
                                strings.push('\n');
                                code.push('\'');
                                i = end + 1;
                            } else {
                                // Lifetime or loop label.
                                code.push('\'');
                                i += 1;
                            }
                        } else {
                            code.push(c);
                            i += 1;
                        }
                    }
                }
            }
            lines.push(Line {
                code,
                comment,
                strings,
                in_test: false,
                in_alloc_free: false,
            });
        }
        mark_regions(&mut lines);
        SourceFile { rel_path: rel_path.to_string(), lines }
    }
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// `chars[i] == 'r'`: if this opens a raw string, the `#` count of its
/// opener; `None` for a plain identifier starting with `r`.
fn raw_string_hashes(chars: &[char], i: usize) -> Option<u32> {
    let mut h = 0u32;
    let mut j = i + 1;
    while chars.get(j) == Some(&'#') {
        h += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some(h)
    } else {
        None
    }
}

/// `chars[i] == '"'` while inside a raw string: does this quote, followed
/// by the opener's `#` count, close it?
fn closes_raw(chars: &[char], i: usize, hashes: u32) -> bool {
    (0..hashes as usize).all(|k| chars.get(i + 1 + k) == Some(&'#'))
}

/// `chars[i] == '\''`: the index of the closing quote if this is a char
/// literal, `None` for lifetimes/labels. `'x'` closes two ahead; escaped
/// forms (`'\n'`, `'\''`, `'\u{8}'`) scan forward past the escape body.
fn char_literal_end(chars: &[char], i: usize) -> Option<usize> {
    match chars.get(i + 1)? {
        '\\' => {
            let mut j = i + 3;
            while j < chars.len() && j < i + 12 {
                if chars[j] == '\'' {
                    return Some(j);
                }
                j += 1;
            }
            None
        }
        _ => {
            if chars.get(i + 2) == Some(&'\'') {
                Some(i + 2)
            } else {
                None
            }
        }
    }
}

/// Second pass: mark `#[cfg(test)]` and alloc-free regions by brace
/// depth. An annotation binds to the **next** `{`-opened block (a fn
/// body, a loop, a bare block) and covers it until its matching `}`.
fn mark_regions(lines: &mut [Line]) {
    let mut depth: i64 = 0;
    let mut pending_test = false;
    let mut pending_alloc = false;
    let mut test_stack: Vec<i64> = Vec::new();
    let mut alloc_stack: Vec<i64> = Vec::new();
    for line in lines.iter_mut() {
        let mut in_test = !test_stack.is_empty();
        let mut in_alloc = !alloc_stack.is_empty();
        if line.code.contains("#[cfg(test)]") {
            pending_test = true;
        }
        if line.comment.trim_start().starts_with("lint: alloc_free") {
            pending_alloc = true;
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if pending_test {
                        test_stack.push(depth);
                        pending_test = false;
                        in_test = true;
                    }
                    if pending_alloc {
                        alloc_stack.push(depth);
                        pending_alloc = false;
                        in_alloc = true;
                    }
                }
                '}' => {
                    if test_stack.last() == Some(&depth) {
                        test_stack.pop();
                    }
                    if alloc_stack.last() == Some(&depth) {
                        alloc_stack.pop();
                    }
                    depth -= 1;
                }
                _ => {}
            }
        }
        line.in_test = in_test || !test_stack.is_empty();
        line.in_alloc_free = in_alloc || !alloc_stack.is_empty();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked_out_of_code() {
        let f = SourceFile::scan("x.rs", "let s = \"vec! here\"; // trailing vec!\n");
        assert!(!f.lines[0].code.contains("vec!"), "code: {}", f.lines[0].code);
        assert!(f.lines[0].strings.contains("vec!"));
        assert!(f.lines[0].comment.contains("vec!"));
    }

    #[test]
    fn char_literal_quote_does_not_open_a_string() {
        // A naive scanner would treat the '"' char literal as a string
        // opener and swallow the rest of the line.
        let f = SourceFile::scan("x.rs", "let c = '\"'; let v = vec![1];\n");
        assert!(f.lines[0].code.contains("vec!"), "code: {}", f.lines[0].code);
    }

    #[test]
    fn lifetimes_and_labels_are_not_char_literals() {
        let f = SourceFile::scan(
            "x.rs",
            "impl<'a> Foo<'a> { fn b(&'a self) { 'outer: loop { break 'outer; } } }\n",
        );
        assert!(f.lines[0].code.contains("'outer: loop"));
    }

    #[test]
    fn escaped_char_literals_close_correctly() {
        let f = SourceFile::scan("x.rs", "let a = '\\''; let b = '\\u{8}'; vec![a, b];\n");
        assert!(f.lines[0].code.contains("vec!"), "code: {}", f.lines[0].code);
        // The braces of '\u{8}' must not reach the region brace counter.
        assert!(!f.lines[0].code.contains('{'), "code: {}", f.lines[0].code);
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "let s = r#\"quote \" and vec! inside\"#; Box::new(1);\n";
        let f = SourceFile::scan("x.rs", src);
        assert!(!f.lines[0].code.contains("vec!"));
        assert!(f.lines[0].code.contains("Box::new"));
    }

    #[test]
    fn multi_line_strings_stay_blanked() {
        let src = "let s = \"first\nvec! still in string\nend\"; vec![2];\n";
        let f = SourceFile::scan("x.rs", src);
        assert!(!f.lines[1].code.contains("vec!"));
        assert!(f.lines[2].code.contains("vec!"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let src = "/* outer /* inner */ still comment */ let x = 1;\n/* open\nvec!\n*/ let y = 2;\n";
        let f = SourceFile::scan("x.rs", src);
        assert!(f.lines[0].code.contains("let x"));
        assert!(!f.lines[0].code.contains("inner"));
        assert!(!f.lines[2].code.contains("vec!"));
        assert!(f.lines[3].code.contains("let y"));
    }

    #[test]
    fn cfg_test_region_tracks_braces() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let f = SourceFile::scan("x.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[3].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn alloc_region_covers_the_next_block_only() {
        let src = "\
fn setup() {
    let a = 1;
    // lint: alloc_free
    for _k in 0..3 {
        if true {
            body();
        }
    }
    let after = 2;
}
";
        let f = SourceFile::scan("x.rs", src);
        assert!(!f.lines[1].in_alloc_free, "before the annotated loop");
        assert!(f.lines[5].in_alloc_free, "inside a nested block");
        assert!(!f.lines[8].in_alloc_free, "after the loop closes");
    }

    #[test]
    fn prose_mentioning_the_marker_does_not_open_a_region() {
        // Doc comments start with `/` or `!` after the `//`, so the
        // starts_with check must not bind them to the next block.
        let src = "/// annotated `// lint: alloc_free` bodies\nfn f() {\n    let v = vec![1];\n}\n";
        let f = SourceFile::scan("x.rs", src);
        assert!(!f.lines[2].in_alloc_free);
    }
}
