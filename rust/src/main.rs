//! `gvt-rls` — CLI for the pairwise-kernel learning framework.
//!
//! Subcommands:
//!
//! * `datasets` — print Table 5 (dataset statistics) for the generators.
//! * `train` — train one model and report test AUC across settings
//!   (`--solver minres|cg|sgd` picks the exact Krylov solvers or the
//!   mini-batched stochastic vec trick; `--save-model` writes a
//!   self-contained v2 artifact whichever solver produced α).
//! * `predict` — offline scoring: read `drug target` pairs from a file,
//!   score them with one block product against a saved model.
//! * `serve` — online scoring: micro-batched prediction server over
//!   line-delimited JSON (TCP or stdio). See `rust/src/serve/`.
//! * `experiment <fig3|fig4|fig5|fig6|fig8>` — regenerate a paper figure.
//! * `gvt-demo` — timing demo: GVT vs explicit mat-vec on one problem.
//! * `runtime-info` — list AOT artifacts and smoke-run one.
//! * `lint` — `gvt-lint`: static analysis enforcing the repo's
//!   determinism / alloc-free / unsafe-audit / env-registry /
//!   panic-surface contracts (see `rust/src/lint/`); exits non-zero on
//!   any finding.
//!
//! `--quick` shrinks every experiment to smoke-test size.

use gvt_rls::cli::Cli;
use gvt_rls::error::{gvt_err, Result};

// Install the tracking allocator so `--mem` reports are exact (Figure 7).
#[global_allocator]
static ALLOC: gvt_rls::coordinator::memory::TrackingAlloc =
    gvt_rls::coordinator::memory::TrackingAlloc;

fn main() {
    // Arm deterministic fault injection (GVT_RLS_FAULT) before any
    // command runs, so verify.sh can exercise serve/persist failure
    // paths; a malformed spec is a startup error, not an ignored knob.
    if let Err(e) = gvt_rls::runtime::fault::init_from_env() {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    }
    // Arm telemetry the same way: GVT_RLS_LOG sets stderr verbosity,
    // GVT_RLS_TRACE arms the Chrome-trace span recorder. Malformed
    // values are startup errors too.
    if let Err(e) = gvt_rls::obs::init_from_env() {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    }
    let cli = match Cli::parse(std::env::args().skip(1)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match cli.command.as_str() {
        "datasets" => cmd_datasets(&cli),
        "train" => cmd_train(&cli),
        "predict" => cmd_predict(&cli),
        "serve" => cmd_serve(&cli),
        "experiment" => cmd_experiment(&cli),
        "gvt-demo" => cmd_gvt_demo(&cli),
        "runtime-info" => cmd_runtime_info(&cli),
        "lint" => cmd_lint(&cli),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    // Drain the span ring to GVT_RLS_TRACE (if armed) whether the
    // command succeeded or not — a failed run's trace is the useful one.
    let flushed = gvt_rls::obs::flush();
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
    if let Err(e) = flushed {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "gvt-rls {} — generalized vec trick pairwise kernel learning\n\n\
         USAGE: gvt-rls <command> [options]\n\n\
         COMMANDS:\n\
         \x20 datasets                      print Table 5 dataset statistics\n\
         \x20 train                         train one model (--kernel --setting; --save-model FILE;\n\
         \x20                               --solver minres|cg|sgd|eigen;\n\
         \x20                               --dataset metz|kernel-filling [--grid K];\n\
         \x20                               sgd: --batch-size N --epochs N\n\
         \x20                               --lr X --schedule constant|invt|cosine --momentum X\n\
         \x20                               --tol X --check-every N --patience N --average;\n\
         \x20                               eigen: complete grids only — --lambdas \"1e-3,1e-2,…\"\n\
         \x20                               selects λ by exact LOOCV, zero solver iterations;\n\
         \x20                               cg: --precond eigen for the eigenbasis preconditioner;\n\
         \x20                               --trace-solver FILE writes per-iteration traces)\n\
         \x20 predict                       score a pair list offline (--model --pairs [--out])\n\
         \x20 serve                         prediction server (--model; --listen ADDR | --stdio;\n\
         \x20                               --max-batch N --max-wait-us U --cache N;\n\
         \x20                               robustness: --max-inflight N --deadline-us U\n\
         \x20                               --max-conns N --idle-timeout-ms MS --drain-ms MS\n\
         \x20                               --reload-stdin)\n\
         \x20 experiment <fig3|fig4|fig5|fig6|fig8>   regenerate a paper figure\n\
         \x20                               (fig4/5/6: --solver minres|cg|sgd|all puts\n\
         \x20                               CG/SGD rows next to the MINRES baseline)\n\
         \x20 gvt-demo                      GVT vs explicit mat-vec timing\n\
         \x20 runtime-info                  list + smoke-run AOT artifacts\n\
         \x20 lint [paths…]                 static analysis: determinism / alloc-free /\n\
         \x20                               unsafe-audit / env-registry / panic-surface /\n\
         \x20                               clock-monopoly contract rules (--json for tooling)\n\n\
         COMMON OPTIONS:\n\
         \x20 --seed <u64>      master seed (default 42)\n\
         \x20 --folds <n>       CV folds (default 9)\n\
         \x20 --workers <n>     experiment-grid worker threads (default 2)\n\
         \x20 --quick           shrink to smoke-test size\n\n\
         RUNTIME ENV: GVT_RLS_THREADS=<n> sizes the worker pool;\n\
         \x20 GVT_RLS_POOL=0 falls back to scoped spawning;\n\
         \x20 GVT_RLS_TRACE=<file> writes a Chrome trace; GVT_RLS_LOG=<level>\n\
         \x20 sets stderr verbosity (see README)\n",
        gvt_rls::VERSION
    );
}

fn cmd_datasets(cli: &Cli) -> Result<()> {
    use gvt_rls::data::heterodimer::{HeterodimerConfig, ProteinFeature};
    use gvt_rls::data::kernel_filling::KernelFillingConfig;
    use gvt_rls::data::merget::MergetConfig;
    use gvt_rls::data::metz::MetzConfig;

    let seed = cli.opt_u64("seed", 42)?;
    let quick = cli.has_switch("quick");
    println!("Generating datasets (quick={quick})…\n");
    println!(
        "| {:<14} | {:>9} | {:>5} | {:>5} | Hom. | Dens.  |",
        "Data set", "Pairs", "Drugs", "Targ."
    );
    println!("|{}|{}|{}|{}|------|--------|", "-".repeat(16), "-".repeat(11), "-".repeat(7), "-".repeat(7));
    let het = if quick { HeterodimerConfig::small() } else { HeterodimerConfig::paper() };
    println!("{}", het.generate(ProteinFeature::Domain, seed).stats_row());
    let metz = if quick { MetzConfig::small() } else { MetzConfig::paper() };
    println!("{}", metz.generate(seed).stats_row());
    let merget = if quick { MergetConfig::small() } else { MergetConfig::paper() };
    println!("{}", merget.generate(1, 0, seed).stats_row());
    let kf = KernelFillingConfig::small();
    let (k, n) = if quick { (48, 1500) } else { (256, 32_768) };
    println!("{}", kf.generate(k, n, seed).stats_row());
    Ok(())
}

fn cmd_train(cli: &Cli) -> Result<()> {
    use gvt_rls::data::metz::MetzConfig;
    use gvt_rls::eval::auc;
    use gvt_rls::gvt::pairwise::PairwiseKernel;
    use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};
    use gvt_rls::solvers::{SgdConfig, SgdTrainer, Solver, StepSchedule};

    let seed = cli.opt_u64("seed", 42)?;
    let kernel = PairwiseKernel::parse(&cli.opt_or("kernel", "kronecker"))
        .ok_or_else(|| gvt_err!("unknown --kernel"))?;
    let setting = cli.opt_usize("setting", 1)? as u8;
    let quick = cli.has_switch("quick");
    // Whitelist derived from the enum so the two vocabularies cannot
    // drift (a drifted whitelist would turn a bad flag into a panic).
    let solver_names = Solver::ALL.map(|s| s.name());
    let solver = Solver::parse(&cli.opt_choice("solver", "minres", &solver_names)?)
        .expect("opt_choice validated the solver token");
    let cfg = RidgeConfig {
        lambda: cli.opt_f64("lambda", if solver.is_stochastic() { 1e-2 } else { 1e-5 })?,
        max_iters: cli.opt_usize("max-iters", if quick { 50 } else { 400 })?,
        ..Default::default()
    };

    // --dataset: metz (the paper's incomplete-grid default) or
    // kernel-filling, whose n = k² sample covers the k×k grid — the
    // complete-data case the eigen solver needs.
    let dataset = cli.opt_choice("dataset", "metz", &["metz", "kernel-filling"])?;
    let data = match dataset.as_str() {
        "kernel-filling" => {
            use gvt_rls::data::kernel_filling::KernelFillingConfig;
            let k = cli.opt_usize("grid", if quick { 16 } else { 64 })?;
            KernelFillingConfig::small().generate(k, k * k, seed)
        }
        _ => if quick { MetzConfig::small() } else { MetzConfig::paper() }.generate(seed),
    };
    println!("dataset: {} ({} pairs)", data.name, data.len());

    // The eigen lane has no split, no iteration budget, and selects λ by
    // exact LOOCV over a grid — its own flow entirely.
    if solver == Solver::Eigen {
        return cmd_train_eigen(cli, &data, kernel);
    }

    let split = data.split_setting(setting, 0.25, seed);
    println!(
        "setting {}: train {} / test {}",
        setting,
        split.train.len(),
        split.test.len()
    );
    // --trace-solver: install a timestamping iteration sink for the
    // duration of the fit. The solvers report values only; the sink
    // stamps wall time up here (the determinism contract keeps clocks
    // out of solvers/).
    let trace_points = cli.opt("trace-solver").map(|_| {
        let points = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        gvt_rls::obs::iter::install(Box::new(gvt_rls::obs::iter::TimedTrace::new(
            points.clone(),
        )));
        points
    });
    let t0 = gvt_rls::obs::clock::now();
    let model = match solver {
        // MINRES keeps the paper's full early-stopping protocol.
        Solver::Minres => {
            PairwiseRidge::fit_early_stopping(&split.train, setting, kernel, &cfg, seed)?
        }
        // CG: plain Tikhonov fit to tolerance (SPD system for λ > 0).
        // --precond eigen swaps in the eigenbasis preconditioner
        // (two-step ridge; Kronecker kernel only, DESIGN §Eigen-Shortcut).
        Solver::Cg => {
            if cli.opt_choice("precond", "none", &["none", "eigen"])? == "eigen" {
                PairwiseRidge::fit_eigen_precond_cg(&split.train, kernel, &cfg, cfg.max_iters)?
            } else {
                PairwiseRidge::fit_exact(&split.train, kernel, &cfg, cfg.max_iters, Solver::Cg)?
            }
        }
        // Stochastic vec trick: mini-batched steps on batch-shaped
        // operators derived from one compiled template.
        Solver::Sgd => {
            let scfg = SgdConfig {
                batch_size: cli.opt_usize("batch-size", 512)?,
                epochs: cli.opt_usize("epochs", if quick { 60 } else { 200 })?,
                lr: cli.opt_f64("lr", 1.0)?,
                momentum: cli.opt_f64("momentum", 0.0)?,
                averaging: cli.has_switch("average"),
                schedule: StepSchedule::parse(&cli.opt_choice(
                    "schedule",
                    "constant",
                    &StepSchedule::NAMES,
                )?)
                .expect("opt_choice validated the schedule token"),
                tol: cli.opt_f64("tol", 1e-6)?,
                check_every: cli.opt_usize("check-every", 1)?,
                patience: cli.opt_usize("patience", 20)?,
                ..Default::default()
            };
            let trainer = SgdTrainer::new(&split.train, kernel, scfg)?;
            trainer.fit_model(cfg.lambda, seed)?
        }
        Solver::Eigen => unreachable!("dispatched to cmd_train_eigen above"),
    };
    let secs = t0.elapsed().as_secs_f64();
    if let Some(points) = trace_points {
        let path = cli.opt("trace-solver").expect("guarded by trace_points");
        gvt_rls::obs::iter::take();
        let points = points.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = format!("{{\"solver\": \"{}\", \"points\": [", solver.name());
        for (i, p) in points.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let value = if p.value.is_finite() {
                format!("{:e}", p.value)
            } else {
                "null".to_string()
            };
            out.push_str(&format!(
                "{{\"iter\": {}, \"value\": {value}, \"t_us\": {}}}",
                p.iter, p.t_us
            ));
        }
        out.push_str("]}\n");
        std::fs::write(path, out).map_err(|e| gvt_err!("writing {path}: {e}"))?;
        println!("wrote {} solver iteration points to {path}", points.len());
    }
    let preds = model.predict(&split.test.pairs)?;
    let a = auc(&preds, &split.test.binary_labels());
    println!(
        "kernel {} | solver {} | {} {} | train {:.2}s | test AUC {}",
        kernel.name(),
        solver.name(),
        if solver.is_stochastic() { "steps" } else { "iterations" },
        model.iterations,
        secs,
        a.map(|v| format!("{v:.4}")).unwrap_or_else(|| "n/a".into())
    );
    if let Some(path) = cli.opt("save-model") {
        use gvt_rls::solvers::persist::{save_model_v2, EmbedV2};
        let embed = EmbedV2 { matrices: true, ..Default::default() };
        save_model_v2(&model, std::path::Path::new(path), &embed)?;
        println!("saved v2 model artifact (kernel matrices embedded) to {path}");
    }
    Ok(())
}

/// The `--solver eigen` training flow: no train/test split and no
/// iteration budget. One eigendecomposition gives the ridge solution for
/// **every** λ in `--lambdas` plus exact leave-one-out CV per λ (the
/// leverages formula — rust/DESIGN.md §Eigen-Shortcut), so λ selection
/// is effectively free; the best-LOO model is refit in closed form and
/// saved as the same v2 artifact the iterative lane writes (`predict`
/// and `serve` are untouched).
fn cmd_train_eigen(
    cli: &Cli,
    data: &gvt_rls::data::PairDataset,
    kernel: gvt_rls::gvt::pairwise::PairwiseKernel,
) -> Result<()> {
    use gvt_rls::eval::auc;
    use gvt_rls::solvers::complete::EigenRidge;

    let lambdas = parse_lambda_list(&cli.opt_or(
        "lambdas",
        "1e-4,1e-3,1e-2,1e-1,1,10,100",
    ))?;
    let t0 = gvt_rls::obs::clock::now();
    let er = EigenRidge::new(data, kernel)?;
    let cells = er.loocv(&lambdas)?;
    let labels = data.binary_labels();
    println!(
        "λ grid ({} values) from one eigendecomposition — exact LOOCV, 0 iterations:",
        cells.len()
    );
    for c in &cells {
        let a = auc(&c.loo, &labels);
        println!(
            "  λ {:>10.3e} | LOO RMSE {:.6} | LOO AUC {}",
            c.lambda,
            c.mse.sqrt(),
            a.map(|v| format!("{v:.4}")).unwrap_or_else(|| "n/a".into())
        );
    }
    let best = cells
        .iter()
        .min_by(|a, b| a.mse.partial_cmp(&b.mse).expect("finite LOO MSE"))
        .ok_or_else(|| gvt_err!("--lambdas: empty λ grid"))?;
    let model = er.fit_model(best.lambda)?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "kernel {} | solver eigen | λ* {:.3e} | LOO RMSE {:.6} | iterations 0 | train {:.2}s",
        kernel.name(),
        best.lambda,
        best.mse.sqrt(),
        secs
    );
    if let Some(path) = cli.opt("save-model") {
        use gvt_rls::solvers::persist::{save_model_v2, EmbedV2};
        let embed = EmbedV2 { matrices: true, ..Default::default() };
        save_model_v2(&model, std::path::Path::new(path), &embed)?;
        println!("saved v2 model artifact (kernel matrices embedded) to {path}");
    }
    Ok(())
}

/// Parse a comma-separated λ grid (`--lambdas "1e-3,1e-2,0.1"`).
fn parse_lambda_list(s: &str) -> Result<Vec<f64>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        out.push(
            tok.parse::<f64>()
                .map_err(|_| gvt_err!("bad λ value {tok:?} in --lambdas"))?,
        );
    }
    if out.is_empty() {
        return Err(gvt_err!("--lambdas: no λ values given"));
    }
    Ok(out)
}

/// Read a `drug target` pair list (one pair per line, `#` comments and
/// blank lines skipped).
fn read_pair_list(path: &std::path::Path) -> Result<Vec<gvt_rls::serve::QueryPair>> {
    use gvt_rls::error::Context;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut pairs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (d, t) = line
            .split_once(char::is_whitespace)
            .ok_or_else(|| gvt_err!("line {}: expected 'drug target'", lineno + 1))?;
        let d: u32 = d
            .trim()
            .parse()
            .map_err(|_| gvt_err!("line {}: bad drug index {d:?}", lineno + 1))?;
        let t: u32 = t
            .trim()
            .parse()
            .map_err(|_| gvt_err!("line {}: bad target index {t:?}", lineno + 1))?;
        pairs.push(gvt_rls::serve::QueryPair::known(d, t));
    }
    Ok(pairs)
}

fn cmd_predict(cli: &Cli) -> Result<()> {
    use gvt_rls::serve::{Predictor, ServeOptions};
    use std::io::Write;

    let model_path = cli.require_opt("model")?;
    let pairs_path = cli.require_opt("pairs")?;
    let predictor = Predictor::from_file(
        std::path::Path::new(model_path),
        ServeOptions { cache_capacity: cli.opt_usize("cache", 1024)? },
    )?;
    let pairs = read_pair_list(std::path::Path::new(pairs_path))?;
    // One block product for the whole file — not one GVT pass per line.
    let scores = predictor.score(&pairs)?;
    let mut rendered = String::with_capacity(scores.len() * 26);
    for s in &scores {
        rendered.push_str(&gvt_rls::serve::protocol::fmt_score(*s));
        rendered.push('\n');
    }
    match cli.opt("out") {
        Some(path) => {
            std::fs::write(path, rendered)
                .map_err(|e| gvt_err!("writing {path}: {e}"))?;
            gvt_rls::obs::log::info(format_args!(
                "wrote {} scores to {path}",
                scores.len()
            ));
        }
        None => {
            print!("{rendered}");
            std::io::stdout().flush().ok();
        }
    }
    Ok(())
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    use gvt_rls::serve::{
        serve_stdio, serve_tcp, BatchConfig, Predictor, ServeConfig, ServeOptions,
    };
    use std::sync::Arc;
    use std::time::Duration;

    let model_path = cli.require_opt("model")?;
    let serve_opts = ServeOptions { cache_capacity: cli.opt_usize("cache", 1024)? };
    let predictor =
        Arc::new(Predictor::from_file(std::path::Path::new(model_path), serve_opts)?);
    // The admission budget falls back to GVT_RLS_MAX_INFLIGHT so
    // operators can bound a fleet without touching launch scripts.
    let max_inflight_default = std::env::var("GVT_RLS_MAX_INFLIGHT")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(0);
    let cfg = ServeConfig {
        batch: BatchConfig {
            max_batch: cli.opt_usize("max-batch", 256)?,
            max_wait: Duration::from_micros(cli.opt_u64("max-wait-us", 500)?),
            max_inflight: cli.opt_usize("max-inflight", max_inflight_default)?,
            deadline: Duration::from_micros(cli.opt_u64("deadline-us", 0)?),
        },
        max_connections: cli.opt_usize("max-conns", 0)?,
        idle_timeout: Duration::from_millis(cli.opt_u64("idle-timeout-ms", 0)?),
        drain_timeout: Duration::from_millis(cli.opt_u64("drain-ms", 2000)?),
        model_path: Some(std::path::PathBuf::from(model_path)),
        serve_opts,
        reload_stdin: cli.has_switch("reload-stdin"),
    };
    // Serving is the long-lived mode: arm the metrics registry so the
    // stats/metrics wire commands report real latency histograms.
    // Library embedders opt in themselves via obs::metrics::set_enabled.
    gvt_rls::obs::metrics::set_enabled(true);
    gvt_rls::obs::log::info(format_args!(
        "serving {} (policy {}, {} training pairs; plan: {})",
        model_path,
        predictor.policy().name(),
        predictor.model().train_size(),
        predictor.plan_summary()
    ));
    if cli.has_switch("stdio") {
        serve_stdio(predictor, cfg)
    } else {
        let listen = cli.opt_or("listen", "127.0.0.1:0");
        serve_tcp(predictor, &listen, cfg)
    }
}

fn cmd_experiment(cli: &Cli) -> Result<()> {
    let which = cli
        .positionals
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| gvt_err!("usage: gvt-rls experiment <fig3|fig4|fig5|fig6|fig8>"))?;
    gvt_rls::coordinator::figures::run(which, cli)
}

fn cmd_gvt_demo(cli: &Cli) -> Result<()> {
    use gvt_rls::data::kernel_filling::KernelFillingConfig;
    use gvt_rls::gvt::explicit::ExplicitLinOp;
    use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
    use gvt_rls::gvt::vec_trick::GvtPolicy;
    use gvt_rls::solvers::linear_op::LinOp;

    let quick = cli.has_switch("quick");
    let (k, n) = if quick { (48, 1200) } else { (192, 18_000) };
    let data = KernelFillingConfig::small().generate(k, n, cli.opt_u64("seed", 42)?);
    println!("kernel-filling problem: {} pairs over {}x{} drugs\n", n, k, k);
    let a: Vec<f64> = (0..n).map(|i| ((i % 7) as f64) - 3.0).collect();

    for kernel in [PairwiseKernel::Kronecker, PairwiseKernel::Poly2D, PairwiseKernel::Mlpk] {
        let op = PairwiseLinOp::new(
            kernel,
            data.d.clone(),
            data.t.clone(),
            data.pairs.clone(),
            data.pairs.clone(),
            GvtPolicy::Auto,
        )?;
        let t0 = gvt_rls::obs::clock::now();
        let p_gvt = op.matvec(&a);
        let gvt_s = t0.elapsed().as_secs_f64();

        let t1 = gvt_rls::obs::clock::now();
        let exp = ExplicitLinOp::new(kernel, &data.d, &data.t, &data.pairs, &data.pairs);
        let build_s = t1.elapsed().as_secs_f64();
        let t2 = gvt_rls::obs::clock::now();
        let p_exp = exp.apply(&a);
        let mv_s = t2.elapsed().as_secs_f64();
        let err = gvt_rls::linalg::vecops::max_abs_diff(&p_gvt, &p_exp);
        println!(
            "{:<14} terms {:>2} | GVT {:>9.4}s | explicit build {:>8.3}s + matvec {:>8.4}s ({}) | max|Δ| {:.2e}",
            kernel.name(),
            op.term_count(),
            gvt_s,
            build_s,
            mv_s,
            gvt_rls::coordinator::memory::format_bytes(exp.memory_bytes()),
            err
        );
    }
    Ok(())
}

fn cmd_runtime_info(cli: &Cli) -> Result<()> {
    use gvt_rls::runtime::{KronExec, Registry};
    let Some(reg) = Registry::discover() else {
        println!("no artifacts found — run `make artifacts`");
        return Ok(());
    };
    println!("artifacts:");
    for a in reg.artifacts() {
        println!("  {:<32} m={:<5} q={:<5} n={:<7} {}", a.name, a.m, a.q, a.n, a.file.display());
    }
    if cli.has_switch("smoke") {
        use gvt_rls::gvt::vec_trick::{gvt_matvec, GvtPolicy};
        use gvt_rls::rng::{dist, Xoshiro256};
        use gvt_rls::testing::gen;
        let meta = reg.artifacts().first().unwrap().clone();
        println!("\nsmoke-running {} …", meta.name);
        let exec = KronExec::load(&reg, &meta)?;
        let mut rng = Xoshiro256::seed_from(1);
        let m = meta.m.min(16);
        let q = meta.q.min(16);
        let d = gen::psd_kernel(&mut rng, m);
        let t = gen::psd_kernel(&mut rng, q);
        let cols = gen::pair_sample(&mut rng, 40, m, q);
        let rows = gen::pair_sample(&mut rng, 30, m, q);
        let a = dist::normal_vec(&mut rng, 40);
        let p_xla = exec.matvec(&d, &t, &rows, &cols, &a)?;
        let p_rust = gvt_matvec(&d, &t, &rows, &cols, &a, GvtPolicy::Auto);
        let err = gvt_rls::linalg::vecops::max_abs_diff(&p_xla, &p_rust);
        println!("XLA vs rust-native GVT: max|Δ| = {err:.3e} (f32 artifact)");
    }
    Ok(())
}

fn cmd_lint(cli: &Cli) -> Result<()> {
    use gvt_rls::lint;
    let root = lint::find_repo_root().ok_or_else(|| {
        gvt_err!("lint: no repo root (a directory holding rust/src and README.md) above the current directory")
    })?;
    let paths: Vec<std::path::PathBuf> =
        cli.positionals.iter().map(std::path::PathBuf::from).collect();
    let report = lint::lint_repo(&root, &paths)?;
    if cli.has_switch("json") {
        println!("{}", report.render_json());
    } else {
        print!("{}", report.render_text());
    }
    if report.findings.is_empty() {
        if !cli.has_switch("json") {
            println!("gvt-lint: clean ({} files)", report.files_scanned);
        }
        Ok(())
    } else {
        // Non-zero exit through the standard error path; the findings
        // themselves went to stdout above.
        Err(gvt_err!("gvt-lint: {} finding(s)", report.findings.len()))
    }
}
