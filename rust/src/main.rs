//! `gvt-rls` — CLI for the pairwise-kernel learning framework.
//!
//! Subcommands:
//!
//! * `datasets` — print Table 5 (dataset statistics) for the generators.
//! * `train` — train one model and report test AUC across settings.
//! * `experiment <fig3|fig4|fig5|fig6|fig8>` — regenerate a paper figure.
//! * `gvt-demo` — timing demo: GVT vs explicit mat-vec on one problem.
//! * `runtime-info` — list AOT artifacts and smoke-run one.
//!
//! `--quick` shrinks every experiment to smoke-test size.

use gvt_rls::cli::Cli;
use gvt_rls::error::{gvt_err, Result};

// Install the tracking allocator so `--mem` reports are exact (Figure 7).
#[global_allocator]
static ALLOC: gvt_rls::coordinator::memory::TrackingAlloc =
    gvt_rls::coordinator::memory::TrackingAlloc;

fn main() {
    let cli = match Cli::parse(std::env::args().skip(1)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match cli.command.as_str() {
        "datasets" => cmd_datasets(&cli),
        "train" => cmd_train(&cli),
        "experiment" => cmd_experiment(&cli),
        "gvt-demo" => cmd_gvt_demo(&cli),
        "runtime-info" => cmd_runtime_info(&cli),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "gvt-rls {} — generalized vec trick pairwise kernel learning\n\n\
         USAGE: gvt-rls <command> [options]\n\n\
         COMMANDS:\n\
         \x20 datasets                      print Table 5 dataset statistics\n\
         \x20 train                         train one model (--dataset --kernel --setting)\n\
         \x20 experiment <fig3|fig4|fig5|fig6|fig8>   regenerate a paper figure\n\
         \x20 gvt-demo                      GVT vs explicit mat-vec timing\n\
         \x20 runtime-info                  list + smoke-run AOT artifacts\n\n\
         COMMON OPTIONS:\n\
         \x20 --seed <u64>      master seed (default 42)\n\
         \x20 --folds <n>       CV folds (default 9)\n\
         \x20 --workers <n>     experiment-grid worker threads (default 2)\n\
         \x20 --quick           shrink to smoke-test size\n",
        gvt_rls::VERSION
    );
}

fn cmd_datasets(cli: &Cli) -> Result<()> {
    use gvt_rls::data::heterodimer::{HeterodimerConfig, ProteinFeature};
    use gvt_rls::data::kernel_filling::KernelFillingConfig;
    use gvt_rls::data::merget::MergetConfig;
    use gvt_rls::data::metz::MetzConfig;

    let seed = cli.opt_u64("seed", 42)?;
    let quick = cli.has_switch("quick");
    println!("Generating datasets (quick={quick})…\n");
    println!(
        "| {:<14} | {:>9} | {:>5} | {:>5} | Hom. | Dens.  |",
        "Data set", "Pairs", "Drugs", "Targ."
    );
    println!("|{}|{}|{}|{}|------|--------|", "-".repeat(16), "-".repeat(11), "-".repeat(7), "-".repeat(7));
    let het = if quick { HeterodimerConfig::small() } else { HeterodimerConfig::paper() };
    println!("{}", het.generate(ProteinFeature::Domain, seed).stats_row());
    let metz = if quick { MetzConfig::small() } else { MetzConfig::paper() };
    println!("{}", metz.generate(seed).stats_row());
    let merget = if quick { MergetConfig::small() } else { MergetConfig::paper() };
    println!("{}", merget.generate(1, 0, seed).stats_row());
    let kf = KernelFillingConfig::small();
    let (k, n) = if quick { (48, 1500) } else { (256, 32_768) };
    println!("{}", kf.generate(k, n, seed).stats_row());
    Ok(())
}

fn cmd_train(cli: &Cli) -> Result<()> {
    use gvt_rls::data::metz::MetzConfig;
    use gvt_rls::eval::auc;
    use gvt_rls::gvt::pairwise::PairwiseKernel;
    use gvt_rls::solvers::ridge::{PairwiseRidge, RidgeConfig};

    let seed = cli.opt_u64("seed", 42)?;
    let kernel = PairwiseKernel::parse(&cli.opt_or("kernel", "kronecker"))
        .ok_or_else(|| gvt_err!("unknown --kernel"))?;
    let setting = cli.opt_usize("setting", 1)? as u8;
    let quick = cli.has_switch("quick");
    let cfg = RidgeConfig {
        lambda: cli.opt_f64("lambda", 1e-5)?,
        max_iters: cli.opt_usize("max-iters", if quick { 50 } else { 400 })?,
        ..Default::default()
    };

    let data = if quick { MetzConfig::small() } else { MetzConfig::paper() }.generate(seed);
    println!("dataset: {} ({} pairs)", data.name, data.len());
    let split = data.split_setting(setting, 0.25, seed);
    println!(
        "setting {}: train {} / test {}",
        setting,
        split.train.len(),
        split.test.len()
    );
    let t0 = std::time::Instant::now();
    let model = PairwiseRidge::fit_early_stopping(&split.train, setting, kernel, &cfg, seed)?;
    let secs = t0.elapsed().as_secs_f64();
    let preds = model.predict(&split.test.pairs)?;
    let a = auc(&preds, &split.test.binary_labels());
    println!(
        "kernel {} | iterations {} | train {:.2}s | test AUC {}",
        kernel.name(),
        model.iterations,
        secs,
        a.map(|v| format!("{v:.4}")).unwrap_or_else(|| "n/a".into())
    );
    Ok(())
}

fn cmd_experiment(cli: &Cli) -> Result<()> {
    let which = cli
        .positionals
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| gvt_err!("usage: gvt-rls experiment <fig3|fig4|fig5|fig6|fig8>"))?;
    gvt_rls::coordinator::figures::run(which, cli)
}

fn cmd_gvt_demo(cli: &Cli) -> Result<()> {
    use gvt_rls::data::kernel_filling::KernelFillingConfig;
    use gvt_rls::gvt::explicit::ExplicitLinOp;
    use gvt_rls::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
    use gvt_rls::gvt::vec_trick::GvtPolicy;
    use gvt_rls::solvers::linear_op::LinOp;

    let quick = cli.has_switch("quick");
    let (k, n) = if quick { (48, 1200) } else { (192, 18_000) };
    let data = KernelFillingConfig::small().generate(k, n, cli.opt_u64("seed", 42)?);
    println!("kernel-filling problem: {} pairs over {}x{} drugs\n", n, k, k);
    let a: Vec<f64> = (0..n).map(|i| ((i % 7) as f64) - 3.0).collect();

    for kernel in [PairwiseKernel::Kronecker, PairwiseKernel::Poly2D, PairwiseKernel::Mlpk] {
        let op = PairwiseLinOp::new(
            kernel,
            data.d.clone(),
            data.t.clone(),
            data.pairs.clone(),
            data.pairs.clone(),
            GvtPolicy::Auto,
        )?;
        let t0 = std::time::Instant::now();
        let p_gvt = op.matvec(&a);
        let gvt_s = t0.elapsed().as_secs_f64();

        let t1 = std::time::Instant::now();
        let exp = ExplicitLinOp::new(kernel, &data.d, &data.t, &data.pairs, &data.pairs);
        let build_s = t1.elapsed().as_secs_f64();
        let t2 = std::time::Instant::now();
        let p_exp = exp.apply(&a);
        let mv_s = t2.elapsed().as_secs_f64();
        let err = gvt_rls::linalg::vecops::max_abs_diff(&p_gvt, &p_exp);
        println!(
            "{:<14} terms {:>2} | GVT {:>9.4}s | explicit build {:>8.3}s + matvec {:>8.4}s ({}) | max|Δ| {:.2e}",
            kernel.name(),
            op.term_count(),
            gvt_s,
            build_s,
            mv_s,
            gvt_rls::coordinator::memory::format_bytes(exp.memory_bytes()),
            err
        );
    }
    Ok(())
}

fn cmd_runtime_info(cli: &Cli) -> Result<()> {
    use gvt_rls::runtime::{KronExec, Registry};
    let Some(reg) = Registry::discover() else {
        println!("no artifacts found — run `make artifacts`");
        return Ok(());
    };
    println!("artifacts:");
    for a in reg.artifacts() {
        println!("  {:<32} m={:<5} q={:<5} n={:<7} {}", a.name, a.m, a.q, a.n, a.file.display());
    }
    if cli.has_switch("smoke") {
        use gvt_rls::gvt::vec_trick::{gvt_matvec, GvtPolicy};
        use gvt_rls::rng::{dist, Xoshiro256};
        use gvt_rls::testing::gen;
        let meta = reg.artifacts().first().unwrap().clone();
        println!("\nsmoke-running {} …", meta.name);
        let exec = KronExec::load(&reg, &meta)?;
        let mut rng = Xoshiro256::seed_from(1);
        let m = meta.m.min(16);
        let q = meta.q.min(16);
        let d = gen::psd_kernel(&mut rng, m);
        let t = gen::psd_kernel(&mut rng, q);
        let cols = gen::pair_sample(&mut rng, 40, m, q);
        let rows = gen::pair_sample(&mut rng, 30, m, q);
        let a = dist::normal_vec(&mut rng, 40);
        let p_xla = exec.matvec(&d, &t, &rows, &cols, &a)?;
        let p_rust = gvt_matvec(&d, &t, &rows, &cols, &a, GvtPolicy::Auto);
        let err = gvt_rls::linalg::vecops::max_abs_diff(&p_xla, &p_rust);
        println!("XLA vs rust-native GVT: max|Δ| = {err:.3e} (f32 artifact)");
    }
    Ok(())
}
