//! The crate's clock monopoly: the **only** module outside the
//! sanctioned timing layers (`bench/`, `benches/`, `coordinator/`) that
//! may call `Instant::now` / `SystemTime::now`. Everything else — the
//! serve stack, `main.rs`, the telemetry recorders in this subsystem —
//! reads time through [`now`] or [`monotonic_us`], so every wall-clock
//! read in the production binary is auditable from one file. The
//! `clock_monopoly` rule of `gvt-rls lint` enforces this statically
//! (`lint/rules.rs`); the determinism rule independently keeps clocks
//! out of `gvt/`, `linalg/`, and `solvers/` entirely.

use std::sync::OnceLock;
use std::time::Instant;

/// Process-start anchor for [`monotonic_us`]. Initialized on first use;
/// all µs timestamps in one process share it, so span starts and ends
/// from different threads are directly comparable.
fn anchor() -> Instant {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    *ANCHOR.get_or_init(Instant::now)
}

/// A monotonic instant, for callers that need `Instant` arithmetic
/// (deadlines, drain budgets). Thin veneer over `Instant::now` — the
/// point is the import site, not the behavior.
#[inline]
pub fn now() -> Instant {
    Instant::now()
}

/// Microseconds since the process-wide anchor (first clock use).
/// Monotonic, thread-agnostic, and cheap enough for span timestamps;
/// wraps after ~584 000 years, which we accept.
#[inline]
pub fn monotonic_us() -> u64 {
    anchor().elapsed().as_micros() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_us_is_monotone() {
        let a = monotonic_us();
        let b = monotonic_us();
        assert!(b >= a, "monotonic_us went backwards: {a} -> {b}");
    }

    #[test]
    fn now_and_anchor_agree_on_direction() {
        let t = now();
        let a = monotonic_us();
        let b = monotonic_us();
        assert!(t.elapsed().as_micros() as u64 >= b.saturating_sub(a));
    }
}
