//! Solver iteration telemetry: CG, MINRES, and the SGD trainer feed
//! their per-iteration convergence scalar (relative residual or
//! relative gradient) through [`record`] into whatever [`IterSink`] the
//! caller layer installed.
//!
//! The solvers report **values only** — no clocks, preserving the
//! gvt-lint determinism contract for `solvers/`. Wall-time is stamped
//! by the sink, which lives up here in `obs` ([`TimedTrace`] stamps
//! `clock::monotonic_us` per point); `gvt-rls train --trace-solver`
//! installs one around a fit and writes the collected points as JSON.
//!
//! With no sink installed (the default, and the state during every
//! test that measures allocation or determinism) [`record`] is a
//! single relaxed atomic load — nothing is locked, nothing allocates.

use crate::obs::clock;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A consumer of solver iteration values. Implementations run inside
/// the solver's iteration loop (under the global sink lock), so they
/// should do bounded work per call.
pub trait IterSink: Send {
    fn record(&mut self, iter: usize, value: f64);
}

static ACTIVE: AtomicBool = AtomicBool::new(false);

fn slot() -> &'static Mutex<Option<Box<dyn IterSink>>> {
    static SLOT: OnceLock<Mutex<Option<Box<dyn IterSink>>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

/// Feed one iteration value to the installed sink, if any. The
/// no-sink fast path is one relaxed load.
#[inline]
pub fn record(iter: usize, value: f64) {
    if !ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    record_slow(iter, value);
}

#[cold]
fn record_slow(iter: usize, value: f64) {
    if let Some(sink) = slot().lock().unwrap_or_else(|e| e.into_inner()).as_mut() {
        sink.record(iter, value);
    }
}

/// Install `sink` as the process-global iteration consumer (replacing
/// any previous one). Callers pair this with [`take`] around one fit;
/// concurrent fits would interleave into the same sink, which is why
/// the train CLI — one fit per process — is the intended installer.
pub fn install(sink: Box<dyn IterSink>) {
    *slot().lock().unwrap_or_else(|e| e.into_inner()) = Some(sink);
    ACTIVE.store(true, Ordering::Relaxed);
}

/// Remove and return the installed sink, disarming [`record`].
pub fn take() -> Option<Box<dyn IterSink>> {
    ACTIVE.store(false, Ordering::Relaxed);
    slot().lock().unwrap_or_else(|e| e.into_inner()).take()
}

/// One collected iteration point: the solver's `(iter, value)` plus the
/// wall-clock stamp added by the sink.
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub iter: usize,
    pub value: f64,
    pub t_us: u64,
}

/// An [`IterSink`] that appends every point, stamped with
/// [`clock::monotonic_us`], into shared storage. The installer keeps a
/// clone of the `Arc` and reads the points back after [`take`] — no
/// downcasting through the trait object needed.
pub struct TimedTrace {
    points: Arc<Mutex<Vec<TracePoint>>>,
}

impl TimedTrace {
    pub fn new(points: Arc<Mutex<Vec<TracePoint>>>) -> TimedTrace {
        TimedTrace { points }
    }
}

impl IterSink for TimedTrace {
    fn record(&mut self, iter: usize, value: f64) {
        let t_us = clock::monotonic_us();
        self.points
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(TracePoint { iter, value, t_us });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_record_take_round_trip() {
        // The sink slot is process-global; serialize with other obs
        // tests (and leave it empty on exit for the solver suites).
        let _serial = crate::obs::test_serial();
        let points = Arc::new(Mutex::new(Vec::new()));
        install(Box::new(TimedTrace::new(points.clone())));
        record(7001, 0.5);
        record(7002, 0.25);
        assert!(take().is_some());
        record(7003, 0.125); // disarmed: must not land
        // Concurrent solver tests may have recorded into the installed
        // sink too, so assert on our marker points, not exact length.
        let got = points.lock().unwrap();
        let ours: Vec<_> = got.iter().filter(|p| p.iter >= 7000).collect();
        assert_eq!(ours.len(), 2, "got {ours:?}");
        assert_eq!(ours[0].iter, 7001);
        assert_eq!(ours[1].value, 0.25);
        assert!(ours[1].t_us >= ours[0].t_us, "stamps must be monotone");
    }
}
