//! Leveled stderr diagnostics, gated by `GVT_RLS_LOG`.
//!
//! The default level is [`Level::Warn`]: routine progress chatter
//! (coordinator grid progress, the serve startup banner, "wrote N
//! scores" notices) is **quiet by default**, so tests and `--json`
//! consumers get a clean stderr, while failures stay visible.
//! `GVT_RLS_LOG=info` (or `debug`) restores the narration;
//! `GVT_RLS_LOG=error` silences warnings too.
//!
//! Call sites pass `format_args!` so arguments are formatted only when
//! the level is enabled:
//!
//! ```ignore
//! obs::log::info(format_args!("[{done}/{total}] {name}: AUC {auc:.4}"));
//! ```

use crate::error::{bail, Result};
use std::sync::atomic::{AtomicU8, Ordering};

/// Severity, most severe first. The numeric ordering is the gate:
/// a message prints when `its level ≤ the configured level`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// The configured level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// In-process override (tests; production configures via the env).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Would a message at `l` print right now? One relaxed load.
#[inline]
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Configure the level from `GVT_RLS_LOG` (`error` | `warn` | `info` |
/// `debug`, case-insensitive). Unset keeps the quiet default; a value
/// outside the alphabet is a startup error, not a silent fallback.
pub fn init_from_env() -> Result<()> {
    let Ok(v) = std::env::var("GVT_RLS_LOG") else {
        return Ok(());
    };
    match v.to_ascii_lowercase().as_str() {
        "error" => set_level(Level::Error),
        "warn" => set_level(Level::Warn),
        "info" => set_level(Level::Info),
        "debug" => set_level(Level::Debug),
        other => bail!("GVT_RLS_LOG: unknown level {other:?} (expected error|warn|info|debug)"),
    }
    Ok(())
}

/// Print `args` to stderr if `l` is enabled. Lines print bare — the
/// existing diagnostics kept their exact shapes when they moved here,
/// only their default visibility changed.
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    eprintln!("{args}");
}

pub fn error(args: std::fmt::Arguments<'_>) {
    log(Level::Error, args);
}

pub fn warn(args: std::fmt::Arguments<'_>) {
    log(Level::Warn, args);
}

pub fn info(args: std::fmt::Arguments<'_>) {
    log(Level::Info, args);
}

pub fn debug(args: std::fmt::Arguments<'_>) {
    log(Level::Debug, args);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating_and_round_trip() {
        let _serial = crate::obs::test_serial();
        let before = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Info));
        assert_eq!(level(), Level::Debug);
        set_level(before);
    }
}
