//! Metrics core: named atomic counters and fixed-bucket log2 latency
//! histograms, in the style of `runtime/fault.rs` — process-global
//! statics, a relaxed-atomic disarmed fast path, and zero cost when off.
//!
//! ## Cost model
//!
//! The whole registry is `static`: recording allocates nothing, ever.
//! With telemetry **disabled** (the library default) every record path
//! is a single relaxed atomic load — [`begin_us`] reads the enable flag
//! once and hands back the [`OFF`] sentinel, and
//! [`Histogram::record_since`] / [`Counter::add`] early-return on it
//! without touching another cache line. `gvt-rls serve` flips the flag
//! on at startup ([`set_enabled`]); telemetry never touches request
//! data, so responses are bit-identical either way
//! (`serve/server.rs` tests pin this).
//!
//! ## Histogram semantics
//!
//! Buckets are powers of two over **microseconds**: bucket `0` holds
//! exactly `0 µs`, bucket `i` (for `1 ≤ i < 31`) holds durations in
//! `[2^(i-1), 2^i - 1] µs`, and the last bucket absorbs everything
//! from `2^30 µs` (~18 min) up — saturation, never overflow.
//! Percentiles are derived by rank-walking the bucket counts and
//! reporting the matched bucket's **upper bound**, clamped to the
//! exact observed maximum (tracked separately via `fetch_max`), so a
//! reported p99 is a true upper bound on the 99th-percentile sample
//! and never exceeds the worst sample seen.

use crate::obs::clock;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Number of log2 buckets. 32 covers 0 µs to ~18 minutes per span.
pub const BUCKETS: usize = 32;

/// Sentinel returned by [`begin_us`] when telemetry is off: the record
/// side early-returns on it without any atomic traffic.
pub const OFF: u64 = u64::MAX;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is metric recording armed? One relaxed load — this is the entire
/// disabled-path cost of every counter bump and span record.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Arm or disarm metric recording process-wide. The serve entry points
/// arm it at startup; tests toggle it in-process. Counters and
/// histograms keep whatever they have accumulated — disarming stops
/// recording, it does not reset.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Start a span measurement: the current monotonic µs timestamp, or
/// [`OFF`] when telemetry is disarmed.
#[inline]
pub fn begin_us() -> u64 {
    if !enabled() {
        return OFF;
    }
    clock::monotonic_us()
}

/// A named monotonic counter. `const`-constructible so the registry is
/// a set of statics with no init order to get wrong.
pub struct Counter {
    name: &'static str,
    value: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str) -> Counter {
        Counter { name, value: AtomicU64::new(0) }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Bump by `n` when telemetry is armed; one relaxed load otherwise.
    #[inline]
    pub fn add(&self, n: u64) {
        if !enabled() {
            return;
        }
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Map a µs duration to its log2 bucket (see module docs).
#[inline]
pub(crate) fn bucket_index(us: u64) -> usize {
    let bits = (64 - us.leading_zeros()) as usize;
    if bits >= BUCKETS {
        BUCKETS - 1
    } else {
        bits
    }
}

/// Upper bound (inclusive, µs) of bucket `i`; the last bucket is
/// unbounded and reports the observed maximum instead.
fn bucket_upper_us(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        (1u64 << i) - 1
    }
}

/// A fixed-bucket log2 latency histogram (µs). All fields are atomics:
/// recording from any thread is lock-free and allocation-free.
pub struct Histogram {
    name: &'static str,
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

/// Plain-number copy of a [`Histogram`] for rendering and assertions.
#[derive(Clone, Copy, Debug, Default)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
    pub p50_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
}

impl Histogram {
    pub const fn new(name: &'static str) -> Histogram {
        // An explicit `const` item makes the array-repeat legal for a
        // non-Copy element type.
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            name,
            buckets: [ZERO; BUCKETS],
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Record a duration measured from a [`begin_us`] timestamp. With
    /// telemetry disarmed `begin` is [`OFF`] and this is a branch on an
    /// already-loaded register — no atomic access at all.
    #[inline]
    pub fn record_since(&self, begin: u64) {
        if begin == OFF {
            return;
        }
        self.record_us(clock::monotonic_us().saturating_sub(begin));
    }

    /// Record an explicit µs duration (armed callers and tests).
    pub fn record_us(&self, us: u64) {
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// The `q`-quantile (0 < q ≤ 1) as a µs upper bound: rank-walk the
    /// buckets, report the matched bucket's upper bound clamped to the
    /// observed maximum. Returns 0 for an empty histogram.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let count = self.count.load(Ordering::Relaxed);
        if count == 0 {
            return 0;
        }
        let max = self.max_us.load(Ordering::Relaxed);
        let target = ((q * count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                if i == BUCKETS - 1 {
                    return max;
                }
                return bucket_upper_us(i).min(max);
            }
        }
        max
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            max_us: self.max_us.load(Ordering::Relaxed),
            p50_us: self.quantile_us(0.50),
            p90_us: self.quantile_us(0.90),
            p99_us: self.quantile_us(0.99),
        }
    }

    /// Summary JSON object: counts and derived percentiles only.
    fn summary_json(&self) -> String {
        let s = self.snapshot();
        format!(
            "{{\"count\": {}, \"sum_us\": {}, \"max_us\": {}, \
             \"p50_us\": {}, \"p90_us\": {}, \"p99_us\": {}}}",
            s.count, s.sum_us, s.max_us, s.p50_us, s.p90_us, s.p99_us
        )
    }

    /// Full JSON object: the summary plus the non-empty buckets as
    /// `[upper_bound_us, count]` pairs (the last, saturated bucket
    /// renders its upper bound as the observed maximum).
    fn full_json(&self) -> String {
        let mut out = self.summary_json();
        out.pop();
        out.push_str(", \"buckets\": [");
        let mut first = true;
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            if !first {
                out.push_str(", ");
            }
            first = false;
            let upper = if i == BUCKETS - 1 {
                self.max_us.load(Ordering::Relaxed)
            } else {
                bucket_upper_us(i)
            };
            out.push_str(&format!("[{upper}, {n}]"));
        }
        out.push_str("]}");
        out
    }
}

// ---------------------------------------------------------------------
// The registry: request-lifecycle stages of the serve path, plus the
// dispatcher tallies. All static — nothing to initialize or look up.
// ---------------------------------------------------------------------

/// Time spent in the admission-control check before a job is enqueued.
pub static ADMISSION_WAIT: Histogram = Histogram::new("admission_wait_us");
/// Enqueue-to-triage wait in the dispatcher queue, per job.
pub static QUEUE_WAIT: Histogram = Histogram::new("queue_wait_us");
/// First-job-arrival to dispatch, per batch (the coalescing window).
pub static BATCH_ASSEMBLY: Histogram = Histogram::new("batch_assembly_us");
/// The GVT scoring pass, per batch.
pub static GVT_PASS: Histogram = Histogram::new("gvt_pass_us");
/// Response rendering (score formatting), per batch.
pub static RENDER: Histogram = Histogram::new("render_us");
/// Socket/stdout write of one response line.
pub static WRITE: Histogram = Histogram::new("write_us");

/// Every per-stage histogram, in pipeline order.
pub static SERVE_STAGES: [&Histogram; 6] =
    [&ADMISSION_WAIT, &QUEUE_WAIT, &BATCH_ASSEMBLY, &GVT_PASS, &RENDER, &WRITE];

/// Batches handed to a GVT pass by the dispatcher.
pub static BATCHES_DISPATCHED: Counter = Counter::new("batches_dispatched");
/// Jobs answered with scores (deadline-expired and panicked jobs are
/// tallied by the slot's robust counters instead).
pub static JOBS_SCORED: Counter = Counter::new("jobs_scored");

/// Every registered counter.
pub static COUNTERS: [&Counter; 2] = [&BATCHES_DISPATCHED, &JOBS_SCORED];

/// The `"latency"` block spliced into serve `stats`: per-stage summary
/// histograms (no buckets — the `metrics` command carries those).
pub fn latency_json() -> String {
    let mut out = format!("{{\"enabled\": {}", enabled());
    for h in SERVE_STAGES {
        out.push_str(&format!(", \"{}\": {}", h.name(), h.summary_json()));
    }
    out.push('}');
    out
}

/// The `{"cmd": "metrics"}` payload: counters plus full per-stage
/// histograms including bucket contents.
pub fn metrics_json() -> String {
    let mut out = format!("{{\"enabled\": {}, \"counters\": {{", enabled());
    let mut first = true;
    for c in COUNTERS {
        if !first {
            out.push_str(", ");
        }
        first = false;
        out.push_str(&format!("\"{}\": {}", c.name(), c.get()));
    }
    out.push_str("}, \"latency\": {");
    let mut first = true;
    for h in SERVE_STAGES {
        if !first {
            out.push_str(", ");
        }
        first = false;
        out.push_str(&format!("\"{}\": {}", h.name(), h.full_json()));
    }
    out.push_str("}}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(7), 3);
        assert_eq!(bucket_index(8), 4);
        // Exact power-of-two edges: 2^k lands in bucket k+1 (its range
        // is [2^k, 2^(k+1) - 1]).
        for k in 1..30 {
            assert_eq!(bucket_index(1u64 << k), k + 1, "2^{k}");
            assert_eq!(bucket_index((1u64 << k) - 1), k, "2^{k} - 1");
        }
    }

    #[test]
    fn saturation_at_max_bucket() {
        assert_eq!(bucket_index(1u64 << 30), BUCKETS - 1);
        assert_eq!(bucket_index(u64::MAX / 2), BUCKETS - 1);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        let h = Histogram::new("sat");
        h.record_us(u64::MAX / 2);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        assert_eq!(s.max_us, u64::MAX / 2);
        // The saturated bucket reports the exact observed maximum, not
        // a fictitious 2^31 upper bound.
        assert_eq!(s.p50_us, u64::MAX / 2);
        assert!(h.full_json().contains(&format!("[{}, 1]", u64::MAX / 2)));
    }

    #[test]
    fn percentiles_derive_from_bucket_ranks() {
        let h = Histogram::new("pct");
        for us in 1..=8u64 {
            h.record_us(us);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 8);
        assert_eq!(s.sum_us, 36);
        assert_eq!(s.max_us, 8);
        // Ranks: bucket1{1}, bucket2{2,3}, bucket3{4..7}, bucket4{8}.
        // p50 -> rank 4 -> bucket 3, upper bound 7.
        assert_eq!(s.p50_us, 7);
        // p90 -> rank 8 -> bucket 4, upper bound 15 clamped to max 8.
        assert_eq!(s.p90_us, 8);
        assert_eq!(s.p99_us, 8);
        // Empty histogram reports zeros.
        let empty = Histogram::new("empty");
        assert_eq!(empty.snapshot().p50_us, 0);
    }

    #[test]
    fn renders_are_valid_shapes() {
        let h = Histogram::new("shape");
        h.record_us(0);
        h.record_us(5);
        let full = h.full_json();
        assert!(full.starts_with('{') && full.ends_with('}'), "{full}");
        assert!(full.contains("\"buckets\": [[0, 1], [7, 1]]"), "{full}");
        let lat = latency_json();
        assert!(lat.contains("\"queue_wait_us\""), "{lat}");
        let m = metrics_json();
        assert!(m.contains("\"counters\""), "{m}");
        assert!(m.contains("\"batches_dispatched\""), "{m}");
    }

    #[test]
    fn disarmed_begin_returns_off_sentinel() {
        // ENABLED is process-global: serialize with every other test
        // that toggles it (the serve telemetry test does too).
        let _serial = crate::obs::test_serial();
        set_enabled(false);
        assert_eq!(begin_us(), OFF);
        let h = Histogram::new("off");
        h.record_since(OFF);
        assert_eq!(h.snapshot().count, 0, "OFF sentinel must not record");
        set_enabled(true);
        let t = begin_us();
        assert_ne!(t, OFF);
        h.record_since(t);
        assert_eq!(h.snapshot().count, 1);
        set_enabled(false);
    }
}
