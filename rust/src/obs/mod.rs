//! Unified telemetry: metrics, spans, solver iteration traces, logging.
//!
//! A zero-dependency observability layer in the style of
//! [`crate::runtime::fault`] — process-global registries, a
//! relaxed-atomic disarmed fast path on every record site, and zero
//! cost when off. Four recorders, one shared clock:
//!
//! * [`clock`] — the crate's **clock monopoly**: the only module
//!   outside the sanctioned timing layers allowed to call
//!   `Instant::now` (the `clock_monopoly` lint rule enforces it).
//! * [`metrics`] — named atomic counters and log2 latency histograms
//!   for the serve request lifecycle (admission wait, queue wait,
//!   batch assembly, GVT pass, render, write); rendered into serve
//!   `stats` as a `"latency"` block and by the `{"cmd": "metrics"}`
//!   wire command. Armed by `gvt-rls serve` at startup.
//! * [`trace`] — a bounded ring-buffer span recorder drained to Chrome
//!   trace-event JSON; armed by `GVT_RLS_TRACE=path.json`, flushed at
//!   process exit. Covers pool jobs/chunk claims, GVT stage-1/stage-2
//!   passes, batch dispatches, and hot-reloads.
//! * [`iter`] — an [`iter::IterSink`] the solvers feed per-iteration
//!   convergence values into (values only; wall-time is stamped here,
//!   never inside `solvers/`); `gvt-rls train --trace-solver` writes
//!   the collected curve as JSON.
//! * [`log`] — leveled stderr diagnostics gated by `GVT_RLS_LOG`
//!   (quiet by default: warnings and errors only).
//!
//! See `docs/OBSERVABILITY.md` for metric names, histogram semantics,
//! and the trace-event schema.

pub mod clock;
pub mod iter;
pub mod log;
pub mod metrics;
pub mod trace;

use crate::error::Result;

/// Arm the recorders that take environment configuration
/// (`GVT_RLS_LOG`, `GVT_RLS_TRACE`). Called by `main` before command
/// dispatch, next to the fault-injection init; a malformed value is a
/// startup error.
pub fn init_from_env() -> Result<()> {
    log::init_from_env()?;
    trace::init_from_env()?;
    Ok(())
}

/// Flush exit-time artifacts (the Chrome trace, when armed). Called by
/// `main` after command dispatch returns — on success *and* failure —
/// so a serve shutdown or an aborted train still leaves a usable
/// trace file.
pub fn flush() -> Result<()> {
    trace::flush_if_armed()
}

/// Serializes every test that mutates the process-global obs state
/// (metric enable flag, trace arming, the iteration sink, log level) —
/// sibling tests run concurrently under libtest.
#[cfg(test)]
pub(crate) fn test_serial() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}
