//! Bounded ring-buffer span recorder, drained to Chrome trace-event
//! JSON (load the file at `chrome://tracing` or <https://ui.perfetto.dev>).
//!
//! Armed by `GVT_RLS_TRACE=path.json` ([`init_from_env`], called by
//! `main` before command dispatch) and flushed by `main` after dispatch
//! returns ([`flush_if_armed`]), so one trace file covers the whole
//! process: pool jobs and chunk claims, GVT stage-1/stage-2 passes,
//! batch dispatches, hot-reloads.
//!
//! ## Cost model
//!
//! Disarmed (the default), [`begin`] is a single relaxed atomic load
//! returning the [`OFF`] sentinel and [`end`] is a branch on it — the
//! instrumented hot paths (`runtime/pool.rs` chunk claims, `gvt/plan.rs`
//! stage passes) pay nothing else. Armed, [`end`] takes a mutex on a
//! **preallocated** fixed-capacity ring: when the ring wraps, the oldest
//! spans are overwritten and tallied in `dropped` (reported in the
//! drained JSON) — tracing is bounded-memory by construction and never
//! reallocates after arming.
//!
//! Span names and categories are `&'static str` chosen from this crate,
//! so events store two pointers and no event ever allocates.

use crate::error::{bail, Context, Result};
use crate::obs::clock;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Sentinel returned by [`begin`] when tracing is disarmed.
pub const OFF: u64 = u64::MAX;

/// Ring capacity in events (~3 MiB armed; nothing allocated disarmed).
const CAPACITY: usize = 65_536;

static ARMED: AtomicBool = AtomicBool::new(false);

#[derive(Clone, Copy)]
struct Event {
    name: &'static str,
    cat: &'static str,
    start_us: u64,
    dur_us: u64,
    tid: u64,
}

struct Ring {
    events: Vec<Event>,
    /// Overwrite cursor once `events` is full.
    next: usize,
    /// Events overwritten after the ring wrapped.
    dropped: u64,
}

fn ring() -> &'static Mutex<Ring> {
    static RING: OnceLock<Mutex<Ring>> = OnceLock::new();
    RING.get_or_init(|| {
        Mutex::new(Ring { events: Vec::with_capacity(CAPACITY), next: 0, dropped: 0 })
    })
}

fn path_slot() -> &'static Mutex<Option<PathBuf>> {
    static PATH: OnceLock<Mutex<Option<PathBuf>>> = OnceLock::new();
    PATH.get_or_init(|| Mutex::new(None))
}

/// Small dense thread ids for the `tid` field: `ThreadId` has no stable
/// numeric accessor, so each thread takes the next ticket on its first
/// recorded span.
fn tid() -> u64 {
    use std::cell::Cell;
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: Cell<u64> = const { Cell::new(0) };
    }
    TID.with(|c| {
        let v = c.get();
        if v != 0 {
            return v;
        }
        let v = NEXT.fetch_add(1, Ordering::Relaxed);
        c.set(v);
        v
    })
}

/// Is the recorder armed?
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// In-process arm/disarm (tests; production arms via [`init_from_env`]).
pub fn set_armed(on: bool) {
    ARMED.store(on, Ordering::Relaxed);
}

/// Open a span: the current µs timestamp, or [`OFF`] when disarmed.
#[inline]
pub fn begin() -> u64 {
    if !armed() {
        return OFF;
    }
    clock::monotonic_us()
}

/// Close a span opened by [`begin`]. A no-op branch on the [`OFF`]
/// sentinel; otherwise records one complete (`ph: "X"`) event.
#[inline]
pub fn end(name: &'static str, cat: &'static str, begin: u64) {
    if begin == OFF {
        return;
    }
    end_slow(name, cat, begin);
}

#[cold]
fn end_slow(name: &'static str, cat: &'static str, begin: u64) {
    let now = clock::monotonic_us();
    let ev = Event { name, cat, start_us: begin, dur_us: now.saturating_sub(begin), tid: tid() };
    let mut r = ring().lock().unwrap_or_else(|e| e.into_inner());
    if r.events.len() < CAPACITY {
        r.events.push(ev);
    } else {
        let i = r.next % CAPACITY;
        r.events[i] = ev;
        r.next = i + 1;
        r.dropped += 1;
    }
}

/// Events currently held (tests).
pub fn len() -> usize {
    ring().lock().unwrap_or_else(|e| e.into_inner()).events.len()
}

/// Arm the recorder from `GVT_RLS_TRACE` (a file path the trace is
/// written to at process exit). Unset: stays disarmed. Set but empty:
/// an error — a misconfigured operator should hear about it at startup,
/// not find a missing trace afterwards.
pub fn init_from_env() -> Result<()> {
    match std::env::var("GVT_RLS_TRACE") {
        Err(_) => Ok(()),
        Ok(p) if p.is_empty() => {
            bail!("GVT_RLS_TRACE is set but empty; expected a trace output path")
        }
        Ok(p) => {
            *path_slot().lock().unwrap_or_else(|e| e.into_inner()) = Some(PathBuf::from(p));
            set_armed(true);
            Ok(())
        }
    }
}

/// Render everything recorded so far as a Chrome trace-event JSON
/// document (`traceEvents` of complete `"X"` events; timestamps and
/// durations in µs; `otherData.dropped` counts ring overwrites).
pub fn render_json() -> String {
    let r = ring().lock().unwrap_or_else(|e| e.into_inner());
    let mut out = String::with_capacity(64 + r.events.len() * 96);
    out.push_str("{\"displayTimeUnit\": \"ms\", \"traceEvents\": [");
    for (i, ev) in r.events.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!(
            "{{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"X\", \
             \"ts\": {}, \"dur\": {}, \"pid\": 1, \"tid\": {}}}",
            ev.name, ev.cat, ev.start_us, ev.dur_us, ev.tid
        ));
    }
    out.push_str(&format!("], \"otherData\": {{\"dropped\": {}}}}}", r.dropped));
    out
}

/// Write the trace to the `GVT_RLS_TRACE` path if the recorder was
/// armed from the environment; a no-op otherwise. `main` calls this
/// once, after command dispatch returns (success or failure), so serve
/// shutdowns and solver runs alike leave a complete file.
pub fn flush_if_armed() -> Result<()> {
    let path = path_slot().lock().unwrap_or_else(|e| e.into_inner()).clone();
    let Some(path) = path else {
        return Ok(());
    };
    std::fs::write(&path, render_json())
        .with_context(|| format!("writing Chrome trace to {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    // The ring and ARMED flag are process-global; every test that arms
    // the recorder serializes on the obs test lock and disarms before
    // releasing it, so concurrent suites never observe it armed.

    #[test]
    fn disarmed_spans_record_nothing() {
        let _serial = crate::obs::test_serial();
        set_armed(false);
        let before = len();
        let t = begin();
        assert_eq!(t, OFF);
        end("noop", "test", t);
        assert_eq!(len(), before);
    }

    #[test]
    fn armed_spans_render_as_chrome_events() {
        let _serial = crate::obs::test_serial();
        set_armed(true);
        let t = begin();
        assert_ne!(t, OFF);
        end("unit.span", "test", t);
        set_armed(false);
        let json = render_json();
        assert!(json.contains("\"traceEvents\""), "{json}");
        assert!(json.contains("\"name\": \"unit.span\""), "{json}");
        assert!(json.contains("\"ph\": \"X\""), "{json}");
        assert!(json.ends_with('}'), "{json}");
    }
}
