//! Sampling distributions and combinatorial helpers on top of [`Rng`].

use super::Rng;

/// Standard normal sample via the Marsaglia polar method.
pub fn standard_normal<R: Rng>(rng: &mut R) -> f64 {
    loop {
        let u = 2.0 * rng.next_f64() - 1.0;
        let v = 2.0 * rng.next_f64() - 1.0;
        let s = u * u + v * v;
        if s > 0.0 && s < 1.0 {
            return u * (-2.0 * s.ln() / s).sqrt();
        }
    }
}

/// Normal sample with given mean and standard deviation.
#[inline]
pub fn normal<R: Rng>(rng: &mut R, mean: f64, std: f64) -> f64 {
    mean + std * standard_normal(rng)
}

/// Vector of i.i.d. standard normals.
pub fn normal_vec<R: Rng>(rng: &mut R, n: usize) -> Vec<f64> {
    (0..n).map(|_| standard_normal(rng)).collect()
}

/// Uniform sample in `[lo, hi)`.
#[inline]
pub fn uniform<R: Rng>(rng: &mut R, lo: f64, hi: f64) -> f64 {
    lo + (hi - lo) * rng.next_f64()
}

/// Bernoulli trial with success probability `p`.
#[inline]
pub fn bernoulli<R: Rng>(rng: &mut R, p: f64) -> bool {
    rng.next_f64() < p
}

/// Random binary vector with density `p` (fraction of ones).
pub fn binary_vec<R: Rng>(rng: &mut R, n: usize, p: f64) -> Vec<f64> {
    (0..n).map(|_| if bernoulli(rng, p) { 1.0 } else { 0.0 }).collect()
}

/// In-place Fisher–Yates shuffle.
pub fn shuffle<R: Rng, T>(rng: &mut R, xs: &mut [T]) {
    for i in (1..xs.len()).rev() {
        let j = rng.index(i + 1);
        xs.swap(i, j);
    }
}

/// Random permutation of `0..n`.
pub fn permutation<R: Rng>(rng: &mut R, n: usize) -> Vec<usize> {
    let mut p: Vec<usize> = (0..n).collect();
    shuffle(rng, &mut p);
    p
}

/// Sample `k` distinct indices from `0..n` (k ≤ n), in random order.
///
/// Uses a partial Fisher–Yates over an index vector: `O(n)` memory,
/// `O(n + k)` time — fine for the dataset sizes here.
pub fn sample_without_replacement<R: Rng>(rng: &mut R, n: usize, k: usize) -> Vec<usize> {
    assert!(k <= n, "cannot sample {k} from {n} without replacement");
    let mut pool: Vec<usize> = (0..n).collect();
    for i in 0..k {
        let j = i + rng.index(n - i);
        pool.swap(i, j);
    }
    pool.truncate(k);
    pool
}

/// Reusable shuffled-epoch index stream for mini-batch training: owns one
/// permutation buffer of `0..n` and re-shuffles it in place per epoch, so
/// the epoch *shuffle* is allocation-free after construction. (The
/// stochastic trainer's steps still allocate their batch sample and
/// operator; this only keeps the sampling side out of that budget.)
pub struct EpochShuffler {
    perm: Vec<usize>,
}

impl EpochShuffler {
    /// Identity permutation over `0..n` (first epoch must call
    /// [`Self::shuffle`] before consuming).
    pub fn new(n: usize) -> EpochShuffler {
        EpochShuffler { perm: (0..n).collect() }
    }

    /// Re-shuffle in place and return the epoch's visiting order. A
    /// Fisher–Yates pass over an existing permutation is again uniform,
    /// so no identity reset is needed between epochs.
    pub fn shuffle<R: Rng>(&mut self, rng: &mut R) -> &[usize] {
        shuffle(rng, &mut self.perm);
        &self.perm
    }

    /// The current epoch order without re-shuffling.
    pub fn current(&self) -> &[usize] {
        &self.perm
    }
}

/// Split `0..n` into `folds` contiguous-in-permutation folds of near-equal
/// size. Returns fold assignment per index.
pub fn fold_assignment<R: Rng>(rng: &mut R, n: usize, folds: usize) -> Vec<usize> {
    assert!(folds >= 2, "need at least 2 folds");
    let perm = permutation(rng, n);
    let mut assign = vec![0usize; n];
    for (rank, &idx) in perm.iter().enumerate() {
        assign[idx] = rank * folds / n.max(1);
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::seed_from(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| standard_normal(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut rng = Xoshiro256::seed_from(4);
        let p = permutation(&mut rng, 100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_without_replacement_distinct_and_in_range() {
        let mut rng = Xoshiro256::seed_from(5);
        let s = sample_without_replacement(&mut rng, 50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn folds_are_balanced() {
        let mut rng = Xoshiro256::seed_from(6);
        let assign = fold_assignment(&mut rng, 103, 9);
        let mut counts = vec![0usize; 9];
        for &f in &assign {
            counts[f] += 1;
        }
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(max - min <= 1, "unbalanced: {counts:?}");
    }

    #[test]
    fn epoch_shuffler_stays_a_permutation() {
        let mut rng = Xoshiro256::seed_from(8);
        let mut es = EpochShuffler::new(37);
        for _ in 0..5 {
            let order = es.shuffle(&mut rng).to_vec();
            let mut seen = vec![false; 37];
            for &i in &order {
                assert!(!seen[i]);
                seen[i] = true;
            }
            assert!(seen.iter().all(|&s| s));
            assert_eq!(es.current(), order.as_slice());
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Xoshiro256::seed_from(7);
        let hits = (0..100_000).filter(|_| bernoulli(&mut rng, 0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }
}
