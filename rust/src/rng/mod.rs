//! Pseudo-random number generation substrate.
//!
//! The sandbox registry has no `rand` crate, so this module implements the
//! generators the rest of the library needs: [`SplitMix64`] for seeding and
//! [`Xoshiro256`] (xoshiro256++) as the workhorse generator, plus the
//! distribution / shuffling helpers in [`dist`].
//!
//! All experiment code takes explicit `u64` seeds so every figure and table
//! the CLI and benches print is exactly reproducible from its seed (see
//! rust/DESIGN.md §Perf, RNG note).

mod splitmix;
mod xoshiro;

pub mod dist;

pub use splitmix::SplitMix64;
pub use xoshiro::Xoshiro256;

/// Minimal RNG interface implemented by both generators.
pub trait Rng {
    /// Next raw 64 uniformly random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits — the low bits of some generators are weaker.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased).
    #[inline]
    fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below: bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` index in `[0, len)`.
    #[inline]
    fn index(&mut self, len: usize) -> usize {
        self.next_below(len as u64) as usize
    }
}

/// Derive `k` statistically independent child seeds from one master seed.
///
/// Used by the coordinator to hand each (fold, worker) job its own stream.
pub fn child_seeds(master: u64, k: usize) -> Vec<u64> {
    let mut sm = SplitMix64::new(master);
    (0..k).map(|_| sm.next_u64()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Xoshiro256::seed_from(1);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x), "{x} out of [0,1)");
        }
    }

    #[test]
    fn next_below_unbiased_small_bound() {
        let mut rng = Xoshiro256::seed_from(2);
        let mut counts = [0usize; 3];
        let trials = 300_000;
        for _ in 0..trials {
            counts[rng.next_below(3) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / trials as f64;
            assert!((frac - 1.0 / 3.0).abs() < 0.01, "biased: {frac}");
        }
    }

    #[test]
    fn child_seeds_distinct() {
        let seeds = child_seeds(42, 64);
        for i in 0..seeds.len() {
            for j in (i + 1)..seeds.len() {
                assert_ne!(seeds[i], seeds[j]);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a: Vec<u64> = {
            let mut r = Xoshiro256::seed_from(9);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Xoshiro256::seed_from(9);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }
}
