//! SplitMix64 (Steele, Lea, Flood 2014): a tiny, fast, well-mixed generator
//! used here to expand a single user seed into generator state and child
//! streams. Not used for bulk sampling (see [`super::Xoshiro256`]).

use super::Rng;

/// SplitMix64 generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from an arbitrary seed (all seeds valid).
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference values from the published SplitMix64 C implementation
    /// with seed 1234567.
    #[test]
    fn matches_reference_vector() {
        let mut r = SplitMix64::new(1234567);
        let got: Vec<u64> = (0..3).map(|_| r.next_u64()).collect();
        assert_eq!(
            got,
            vec![
                6_457_827_717_110_365_317,
                3_203_168_211_198_807_973,
                9_817_491_932_198_370_423,
            ]
        );
    }
}
