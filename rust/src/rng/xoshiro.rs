//! xoshiro256++ (Blackman & Vigna 2019): the library's bulk generator.
//! 256-bit state, period 2^256 − 1, passes BigCrush; `++` output scrambler
//! avoids the weak low bits of the `**` variant's predecessor.

use super::{Rng, SplitMix64};

/// xoshiro256++ generator state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed the 256-bit state from a single `u64` via SplitMix64 (the
    /// seeding procedure recommended by the xoshiro authors).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        // All-zero state is the one invalid state; SplitMix64 cannot emit
        // four consecutive zeros, but keep the guard for clarity.
        debug_assert!(s.iter().any(|&x| x != 0));
        Self { s }
    }

    /// Jump ahead 2^128 steps: gives a disjoint stream for a worker thread.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180E_C6D3_3CFD_0ABA,
            0xD5A6_1266_F0C9_392C,
            0xA958_2618_E03F_C9AA,
            0x39AB_DC45_29B1_661C,
        ];
        let mut t = [0u64; 4];
        for &jump_word in &JUMP {
            for b in 0..64 {
                if (jump_word & (1u64 << b)) != 0 {
                    for (ti, si) in t.iter_mut().zip(self.s.iter()) {
                        *ti ^= si;
                    }
                }
                self.next_u64();
            }
        }
        self.s = t;
    }
}

impl Rng for Xoshiro256 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jump_gives_disjoint_streams() {
        let mut a = Xoshiro256::seed_from(7);
        let mut b = a.clone();
        b.jump();
        let xs: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert!(xs.iter().all(|x| !ys.contains(x)));
    }

    #[test]
    fn mean_of_uniform_is_half() {
        let mut r = Xoshiro256::seed_from(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }
}
