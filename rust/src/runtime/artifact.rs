//! Artifact manifest: discovery and metadata for the AOT-compiled HLO
//! programs produced by `python/compile/aot.py`.

use crate::error::{bail, Context, Result};
use crate::runtime::json::Json;
use std::path::{Path, PathBuf};

/// Metadata of one shape-specialized artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// Logical name, e.g. `kron_matvec_m64_q64_n4096`.
    pub name: String,
    /// Drug-domain size baked into the program.
    pub m: usize,
    /// Target-domain size.
    pub q: usize,
    /// Output-sample capacity (gather rows padded to this).
    pub n: usize,
    /// HLO text file, relative to the artifacts dir.
    pub file: PathBuf,
}

/// The set of available artifacts.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    dir: PathBuf,
    artifacts: Vec<ArtifactMeta>,
}

impl Registry {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Registry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let version = j.get("version").and_then(|v| v.as_usize()).unwrap_or(0);
        if version != 1 {
            bail!("manifest version {version} unsupported (expected 1)");
        }
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .context("manifest missing 'artifacts' array")?
        {
            let get_usize = |k: &str| -> Result<usize> {
                a.get(k)
                    .and_then(|v| v.as_usize())
                    .with_context(|| format!("artifact missing numeric field '{k}'"))
            };
            let meta = ArtifactMeta {
                name: a
                    .get("name")
                    .and_then(|v| v.as_str())
                    .context("artifact missing 'name'")?
                    .to_string(),
                m: get_usize("m")?,
                q: get_usize("q")?,
                n: get_usize("n")?,
                file: PathBuf::from(
                    a.get("file").and_then(|v| v.as_str()).context("artifact missing 'file'")?,
                ),
            };
            let full = dir.join(&meta.file);
            if !full.is_file() {
                bail!("artifact file missing: {}", full.display());
            }
            artifacts.push(meta);
        }
        Ok(Registry { dir: dir.to_path_buf(), artifacts })
    }

    /// Load from the default location, `None` when artifacts aren't built
    /// (callers treat the XLA path as unavailable and fall back to the
    /// rust-native GVT).
    pub fn discover() -> Option<Registry> {
        let dir = crate::runtime::artifacts_dir()?;
        Registry::load(&dir).ok()
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Smallest artifact whose baked shape covers `(m, q)` (the sample
    /// capacity `n` is handled by chunking, so it doesn't constrain
    /// selection).
    pub fn pick(&self, m: usize, q: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.m >= m && a.q >= q)
            .min_by_key(|a| a.m * a.q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn load_and_pick() {
        let dir = std::env::temp_dir().join(format!("gvt_rls_reg_{}", std::process::id()));
        write_manifest(
            &dir,
            r#"{"version": 1, "artifacts": [
                {"name": "a64", "m": 64, "q": 64, "n": 4096, "file": "a64.hlo.txt"},
                {"name": "a128", "m": 128, "q": 128, "n": 8192, "file": "a128.hlo.txt"}
            ]}"#,
        );
        std::fs::write(dir.join("a64.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("a128.hlo.txt"), "x").unwrap();
        let reg = Registry::load(&dir).unwrap();
        assert_eq!(reg.artifacts().len(), 2);
        assert_eq!(reg.pick(32, 50).unwrap().name, "a64");
        assert_eq!(reg.pick(100, 10).unwrap().name, "a128");
        assert!(reg.pick(300, 300).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_error() {
        let dir = std::env::temp_dir().join(format!("gvt_rls_reg2_{}", std::process::id()));
        write_manifest(
            &dir,
            r#"{"version": 1, "artifacts": [
                {"name": "a", "m": 8, "q": 8, "n": 64, "file": "missing.hlo.txt"}
            ]}"#,
        );
        assert!(Registry::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_version_rejected() {
        let dir = std::env::temp_dir().join(format!("gvt_rls_reg3_{}", std::process::id()));
        write_manifest(&dir, r#"{"version": 2, "artifacts": []}"#);
        assert!(Registry::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
