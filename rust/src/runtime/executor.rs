//! PJRT executor for the AOT-compiled dense Kronecker mat-vec.
//!
//! The artifact program (see `python/compile/model.py::kron_matvec`)
//! computes, entirely on-device,
//!
//! ```text
//! S = T_mat @ W            # the Pallas-tiled MXU matmul (L1)
//! p[i] = Σ_d D[row_d[i], d] · S[row_t[i], d]
//! ```
//!
//! with shapes baked at AOT time: `D: f32[M,M]`, `T: f32[Q,Q]`,
//! `W: f32[Q,M]`, `row_d/row_t: i32[N]` → `p: f32[N]`.
//!
//! The executor pads the runtime problem into the artifact's shape
//! envelope: kernels are zero-padded (zero rows/cols contribute nothing),
//! output rows are chunked into batches of `N` with padding rows pointed
//! at index 0 and discarded.

use crate::error::{bail, Context, Result};
use crate::linalg::Mat;
use crate::runtime::artifact::{ArtifactMeta, Registry};
// Offline stub with the same API surface as the real `xla` PJRT bindings
// (see its module docs for the swap-back procedure).
use crate::runtime::xla;
use crate::sparse::PairIndex;

/// A compiled, loaded artifact ready to execute.
pub struct KronExec {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

impl KronExec {
    /// Load + compile one artifact on the PJRT CPU client.
    pub fn load(registry: &Registry, meta: &ArtifactMeta) -> Result<KronExec> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let path = registry.path_of(meta);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;
        Ok(KronExec { exe, meta: meta.clone() })
    }

    /// Convenience: discover the registry and load the best artifact for
    /// domain sizes `(m, q)`.
    pub fn for_domains(m: usize, q: usize) -> Result<KronExec> {
        let reg = Registry::discover()
            .context("artifacts not built — run `make artifacts` first")?;
        let meta = reg
            .pick(m, q)
            .with_context(|| format!("no artifact bucket covers m={m}, q={q}"))?
            .clone();
        Self::load(&reg, &meta)
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Dense Kronecker mat-vec through the artifact:
    /// `p_i = Σ_j D[d̄_i, d_j] T[t̄_i, t_j] a_j` — numerically the same
    /// operation as [`crate::gvt::vec_trick::gvt_matvec`] (f32 vs f64).
    pub fn matvec(
        &self,
        d: &Mat,
        t: &Mat,
        rows: &PairIndex,
        cols: &PairIndex,
        a: &[f64],
    ) -> Result<Vec<f64>> {
        let (bm, bq, bn) = (self.meta.m, self.meta.q, self.meta.n);
        if d.rows() > bm || d.cols() > bm || t.rows() > bq || t.cols() > bq {
            bail!(
                "kernel matrices ({}x{}, {}x{}) exceed artifact bucket ({bm}, {bq})",
                d.rows(),
                d.cols(),
                t.rows(),
                t.cols()
            );
        }
        assert_eq!(a.len(), cols.len());

        // Pad kernels into the bucket (f32).
        let d_lit = pad_matrix_literal(d, bm, bm)?;
        let t_lit = pad_matrix_literal(t, bq, bq)?;

        // Scatter the coefficients: W[t_j, d_j] += a_j (f32, padded).
        let mut w = vec![0.0f32; bq * bm];
        for j in 0..cols.len() {
            w[cols.target(j) * bm + cols.drug(j)] += a[j] as f32;
        }
        let w_lit = OwnedLiteral { data: w, rows: bq, cols: bm };

        // Chunk output rows into batches of bn.
        let nbar = rows.len();
        let mut out = Vec::with_capacity(nbar);
        let mut start = 0;
        while start < nbar {
            let end = (start + bn).min(nbar);
            let mut rd = vec![0i32; bn];
            let mut rt = vec![0i32; bn];
            for (k, i) in (start..end).enumerate() {
                rd[k] = rows.drug(i) as i32;
                rt[k] = rows.target(i) as i32;
            }
            let rd_lit = xla::Literal::vec1(&rd);
            let rt_lit = xla::Literal::vec1(&rt);
            let result = self
                .exe
                .execute::<xla::Literal>(&[
                    d_lit.clone_literal()?,
                    t_lit.clone_literal()?,
                    w_lit.clone_literal()?,
                    rd_lit,
                    rt_lit,
                ])
                .context("PJRT execute")?;
            let lit = result[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
            let p: Vec<f32> = lit.to_tuple1()?.to_vec::<f32>()?;
            out.extend(p[..end - start].iter().map(|&v| v as f64));
            start = end;
        }
        Ok(out)
    }
}

/// Zero-pad an f64 matrix into an `rows_to × cols_to` f32 literal.
fn pad_matrix_literal(m: &Mat, rows_to: usize, cols_to: usize) -> Result<OwnedLiteral> {
    let mut buf = vec![0.0f32; rows_to * cols_to];
    for i in 0..m.rows() {
        let src = m.row(i);
        let dst = &mut buf[i * cols_to..i * cols_to + m.cols()];
        for (d, s) in dst.iter_mut().zip(src) {
            *d = *s as f32;
        }
    }
    Ok(OwnedLiteral { data: buf, rows: rows_to, cols: cols_to })
}

/// A host-side buffer we can mint fresh `xla::Literal`s from per call
/// (literals are consumed by `execute`).
struct OwnedLiteral {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl OwnedLiteral {
    fn clone_literal(&self) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(&self.data).reshape(&[self.rows as i64, self.cols as i64])?)
    }
}
