//! Deterministic fault injection around the serve and persist seams.
//!
//! Production hardening is only trustworthy if every failure path is
//! *executed*, not inspected: `tests/serve_faults.rs` drives the server
//! through dispatcher panics, truncated artifacts, stalled reads, and
//! overload bursts by arming this registry instead of hoping for real
//! faults. Design constraints:
//!
//! * **Zero cost when off.** The hot-path check ([`trip`]) is a single
//!   relaxed atomic load returning `None`; parsing, locking, and
//!   book-keeping live behind it in a `#[cold]` slow path.
//! * **Deterministic.** A fault is `point:kind[:nth]` — it fires on the
//!   `nth` hit (1-based, default 1) of that injection point and then
//!   disarms. No randomness, no seeds to replay: the same arming always
//!   fires at the same place.
//! * **Two arming channels.** The `GVT_RLS_FAULT` environment variable
//!   (read once by [`init_from_env`], which `main` calls before
//!   dispatch) arms faults for CLI runs — `scripts/verify.sh` uses this
//!   to exercise the serve binary under injected failure. In-process
//!   tests arm with [`set`] / [`clear`] instead, since the registry is
//!   process-global state.
//!
//! Injection points compiled into the tree (the `point` names [`trip`]
//! is called with):
//!
//! | point | seam |
//! |---|---|
//! | `batcher_dispatch` | the micro-batch dispatcher, just before scoring |
//! | `artifact_read` | `ModelFile::read`, just after the file is read |
//! | `conn_read` | the per-connection TCP read loop |
//!
//! Kinds: `panic` panics at the site (the dispatcher's `catch_unwind`
//! recovery is the thing under test), `error` asks the caller to fail
//! with an injected error, `stall` sleeps [`STALL`] then proceeds
//! normally (saturates queues / holds batches), and `truncate` asks the
//! caller to truncate the data it just read (artifact corruption).

use crate::error::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// What an armed fault does when its point trips.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FaultKind {
    /// Panic at the injection site.
    Panic,
    /// Tell the caller to surface an injected error in-band.
    Error,
    /// Hold the tripping thread for [`STALL`], then proceed normally.
    Stall,
    /// Tell the caller to truncate the data it just read.
    Truncate,
}

/// A fired fault the *caller* must act on. `panic` and `stall` kinds
/// are handled inside [`trip`] and never reach the caller.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Fired {
    /// Fail the current operation with an injected error.
    Error,
    /// Truncate the just-read data before parsing it.
    Truncate,
}

/// How long a `stall` fault holds its thread. Long enough that a test
/// can deterministically order events around it, short enough that the
/// fault suite stays fast.
pub const STALL: Duration = Duration::from_millis(400);

#[derive(Clone, Debug)]
struct Spec {
    point: String,
    kind: FaultKind,
    /// Fires on the `nth` hit of `point` (1-based), then disarms.
    nth: u32,
    hits: u32,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static ARMED: Mutex<Vec<Spec>> = Mutex::new(Vec::new());

fn armed() -> std::sync::MutexGuard<'static, Vec<Spec>> {
    // A poisoned registry only means another thread panicked while
    // holding it (e.g. an injected panic racing a re-arm); the spec
    // list itself is always structurally valid.
    ARMED.lock().unwrap_or_else(|e| e.into_inner())
}

/// Arm the registry from a spec string: comma-separated
/// `point:kind[:nth]` entries, e.g. `batcher_dispatch:panic` or
/// `artifact_read:truncate:1,conn_read:stall:2`. Replaces any previous
/// arming. An empty spec disarms everything (same as [`clear`]).
pub fn set(spec: &str) -> Result<()> {
    let specs = parse(spec)?;
    let mut guard = armed();
    ENABLED.store(!specs.is_empty(), Ordering::Release);
    *guard = specs;
    Ok(())
}

/// Disarm every fault and restore the zero-cost fast path.
pub fn clear() {
    let mut guard = armed();
    guard.clear();
    ENABLED.store(false, Ordering::Release);
}

/// Read `GVT_RLS_FAULT` once and arm the registry from it. Called by
/// `main` before command dispatch; a malformed spec is a startup error,
/// not a silently ignored knob.
pub fn init_from_env() -> Result<()> {
    match std::env::var("GVT_RLS_FAULT") {
        Ok(spec) if !spec.trim().is_empty() => {
            set(&spec).context("parsing GVT_RLS_FAULT")
        }
        _ => Ok(()),
    }
}

fn parse(spec: &str) -> Result<Vec<Spec>> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let mut fields = part.split(':');
        let point = fields.next().unwrap_or("");
        let kind = match fields.next() {
            Some("panic") => FaultKind::Panic,
            Some("error") => FaultKind::Error,
            Some("stall") => FaultKind::Stall,
            Some("truncate") => FaultKind::Truncate,
            other => bail!(
                "fault spec {part:?}: unknown kind {other:?} (expected panic|error|stall|truncate)"
            ),
        };
        if point.is_empty() {
            bail!("fault spec {part:?}: empty injection point");
        }
        let nth = match fields.next() {
            None => 1,
            Some(n) => n
                .parse::<u32>()
                .with_context(|| format!("fault spec {part:?}: nth must be a positive integer"))?,
        };
        if nth == 0 {
            bail!("fault spec {part:?}: nth is 1-based (first hit = 1)");
        }
        if fields.next().is_some() {
            bail!("fault spec {part:?}: too many fields (point:kind[:nth])");
        }
        out.push(Spec { point: point.to_string(), kind, nth, hits: 0 });
    }
    Ok(out)
}

/// Trip the named injection point. With nothing armed this is one
/// relaxed atomic load and `None` — safe to compile into hot seams.
/// When an armed fault fires here: `panic` panics, `stall` sleeps
/// [`STALL`] and returns `None`, `error`/`truncate` return [`Fired`]
/// for the caller to act on. Each armed fault fires exactly once.
#[inline]
pub fn trip(point: &str) -> Option<Fired> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    trip_slow(point)
}

#[cold]
fn trip_slow(point: &str) -> Option<Fired> {
    let fired = {
        let mut guard = armed();
        let mut fired = None;
        for spec in guard.iter_mut() {
            if spec.point == point && spec.hits < spec.nth {
                spec.hits += 1;
                if spec.hits == spec.nth {
                    fired = Some(spec.kind);
                    break;
                }
            }
        }
        fired
    };
    match fired? {
        FaultKind::Panic => {
            // lint: allow(panic, fault injection: this deliberate panic is the
            // payload of an armed `panic` fault; the seams that compile in a
            // trip point catch it and answer in-band)
            panic!("injected fault: panic at {point}")
        }
        FaultKind::Stall => {
            std::thread::sleep(STALL);
            None
        }
        FaultKind::Error => Some(Fired::Error),
        FaultKind::Truncate => Some(Fired::Truncate),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests share the process-global registry with every other
    // test in the lib binary, so they arm only fixture point names no
    // real seam ever trips, disarm before returning, and serialize
    // against each other ([`set`] replaces the whole registry).
    static SERIAL: Mutex<()> = Mutex::new(());

    fn serial() -> std::sync::MutexGuard<'static, ()> {
        SERIAL.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(parse("p:panic").is_ok());
        assert!(parse("p:panic:3, q:stall").is_ok());
        assert!(parse("").unwrap().is_empty());
        assert!(parse("p").is_err(), "missing kind");
        assert!(parse("p:reboot").is_err(), "unknown kind");
        assert!(parse(":panic").is_err(), "empty point");
        assert!(parse("p:panic:0").is_err(), "nth is 1-based");
        assert!(parse("p:panic:x").is_err(), "non-numeric nth");
        assert!(parse("p:panic:1:2").is_err(), "trailing fields");
    }

    #[test]
    fn disabled_registry_never_fires() {
        let _g = serial();
        clear();
        assert!(trip("fault_fixture_a").is_none());
    }

    #[test]
    fn error_fault_fires_on_nth_hit_then_disarms() {
        let _g = serial();
        set("fault_fixture_b:error:3").unwrap();
        assert!(trip("fault_fixture_b").is_none());
        assert!(trip("fault_fixture_other").is_none(), "different point never fires");
        assert!(trip("fault_fixture_b").is_none());
        assert_eq!(trip("fault_fixture_b"), Some(Fired::Error));
        assert!(trip("fault_fixture_b").is_none(), "one-shot: disarmed after firing");
        clear();
    }

    #[test]
    fn panic_fault_panics_at_the_site() {
        let _g = serial();
        set("fault_fixture_c:panic").unwrap();
        let caught = std::panic::catch_unwind(|| trip("fault_fixture_c"));
        clear();
        assert!(caught.is_err(), "panic kind must unwind from trip()");
        assert!(trip("fault_fixture_c").is_none());
    }

    #[test]
    fn truncate_fault_reaches_the_caller() {
        let _g = serial();
        set("fault_fixture_d:truncate").unwrap();
        assert_eq!(trip("fault_fixture_d"), Some(Fired::Truncate));
        clear();
    }
}
