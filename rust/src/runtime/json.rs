//! Minimal JSON parser for the artifact manifest (no serde offline).
//!
//! Supports the subset the manifest uses: objects, arrays, strings
//! (with `\"`/`\\`/`\n`/`\t`/`\u` escapes), numbers, booleans, null.

use crate::error::{bail, gvt_err, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Maximum container nesting. Recursive descent uses one stack frame per
/// level, so an attacker-supplied `[[[[…` would otherwise overflow the
/// thread stack (an abort, not a catchable error) — fatal for the serve
/// path, which parses untrusted request lines with this parser.
const MAX_DEPTH: usize = 128;

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting level (bounded by [`MAX_DEPTH`]).
    depth: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.nested(true),
            Some(b'[') => self.nested(false),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    /// Parse a container (object if `obj`, else array) one nesting level
    /// down, keeping recursion bounded (see [`MAX_DEPTH`]).
    fn nested(&mut self, obj: bool) -> Result<Json> {
        if self.depth >= MAX_DEPTH {
            bail!("JSON nesting deeper than {MAX_DEPTH} levels at byte {}", self.pos);
        }
        self.depth += 1;
        let v = if obj { self.object() } else { self.array() };
        self.depth -= 1;
        v
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E') | Some(b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| gvt_err!("bad \\u escape"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(c) => {
                    // Multi-byte UTF-8 passthrough.
                    let len = utf8_len(c);
                    let chunk = &self.bytes[self.pos..self.pos + len];
                    out.push_str(std::str::from_utf8(chunk)?);
                    self.pos += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']' (found {other:?})"),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}' (found {other:?})"),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
            "version": 1,
            "artifacts": [
                {"name": "kron_matvec_m64_q64_n4096", "m": 64, "q": 64, "n": 4096,
                 "file": "kron_matvec_m64_q64_n4096.hlo.txt", "dtype": "f32"}
            ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("m").unwrap().as_usize(), Some(64));
        assert_eq!(
            arts[0].get("file").unwrap().as_str(),
            Some("kron_matvec_m64_q64_n4096.hlo.txt")
        );
    }

    #[test]
    fn parses_escapes_and_nesting() {
        let j = Json::parse(r#"{"a": "x\n\"y\"", "b": [1, -2.5e3, true, null]}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_str(), Some("x\n\"y\""));
        let b = j.get("b").unwrap().as_arr().unwrap();
        assert_eq!(b[1].as_f64(), Some(-2500.0));
        assert_eq!(b[2], Json::Bool(true));
        assert_eq!(b[3], Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn nesting_depth_is_bounded() {
        // Within the limit: parses fine.
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
        // A hostile `[[[[…` bomb errors instead of overflowing the stack
        // (an overflow aborts the process — no test could observe it).
        let bomb = "[".repeat(200_000);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(format!("{err:#}").contains("nesting"), "{err:#}");
        // Mixed object/array nesting hits the same bound.
        let mixed = "{\"a\":".repeat(5_000);
        assert!(Json::parse(&mixed).is_err());
    }
}
