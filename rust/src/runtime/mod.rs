//! Execution runtime: the persistent worker pool and the PJRT bridge.
//!
//! * [`pool`] — the crate-wide parallel execution runtime: parked worker
//!   threads with atomic chunk-claim scheduling, behind the
//!   [`crate::linalg::par`] façade every hot path uses. See its module
//!   docs for the determinism contract and the `GVT_RLS_THREADS` /
//!   `GVT_RLS_POOL` knobs.
//! * [`fault`] — deterministic fault injection (`GVT_RLS_FAULT`):
//!   zero-cost-when-off trip points compiled around the serve and
//!   persist seams, so `tests/serve_faults.rs` can exercise panic /
//!   stall / truncation / overload failure paths on demand.
//! * [`artifact`] / [`executor`] / [`xla`] — the PJRT bridge (below).
//!
//! # PJRT bridge — L3 ↔ L2
//!
//! `make artifacts` lowers the JAX/Pallas dense Kronecker mat-vec (L2/L1)
//! to HLO **text** once at build time; this module loads those artifacts,
//! compiles them on the PJRT CPU client, and exposes them as [`KronExec`]
//! executors the coordinator can call on its request path. Python never
//! runs at serve/train time.
//!
//! Artifacts are shape-specialized (`m`, `q`, `n` baked in); the executor
//! pads/chunks samples to fit, and the registry picks the smallest
//! compatible bucket.
//!
//! Interchange is HLO text rather than serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! In offline builds the PJRT bindings are replaced by the API-compatible
//! stub in [`xla`]; loading an executor then fails gracefully and every
//! caller falls back to the rust-native GVT.

pub mod artifact;
pub mod executor;
pub mod fault;
pub mod json;
pub mod pool;
pub mod xla;

pub use artifact::{ArtifactMeta, Registry};
pub use executor::KronExec;

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory: `GVT_RLS_ARTIFACTS` env var, else
/// `artifacts/` relative to cwd, else relative to the crate root (so
/// `cargo test` finds it from any working directory).
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("GVT_RLS_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        return p.is_dir().then_some(p);
    }
    let cwd = std::path::PathBuf::from(DEFAULT_ARTIFACTS_DIR);
    if cwd.is_dir() {
        return Some(cwd);
    }
    let crate_rel =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACTS_DIR);
    crate_rel.is_dir().then_some(crate_rel)
}
