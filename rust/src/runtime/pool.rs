//! Persistent worker-pool execution runtime.
//!
//! Every hot path of this crate — the GVT stage-1/stage-2 sweeps of
//! [`crate::gvt::plan::GvtPlan`], the dense [`crate::linalg::Mat`]
//! GEMM/GEMV kernels, every CG/MINRES iteration, every SGD batch step,
//! and every micro-batch the serve dispatcher coalesces — executes its
//! parallel loops through this module. The paper's whole point is that a
//! pairwise-kernel product costs only `O(nm + nq)` (Theorem 1), which at
//! real problem sizes makes **per-call overhead**, not FLOPs, the
//! dominant term: spawning and joining a `std::thread::scope` costs on
//! the order of 10 µs, and a converging MINRES training run performs
//! thousands of parallel regions. The pool replaces spawn/join with
//! **parked** worker threads (condvar wake ≈ 1–2 µs) that live for the
//! process lifetime.
//!
//! ## Scheduling: atomic chunk claiming
//!
//! A parallel region is a *job*: `chunks` units of work executed by
//! calling `f(chunk_index)` once per index. Jobs sit in a small shared
//! queue; parked workers wake, pick the oldest job with unclaimed
//! chunks, and **claim chunks via an atomic counter** until the job is
//! drained — idle workers steal remaining chunks instead of being pinned
//! to a static range, so a worker delayed by the OS does not stall the
//! whole region. The submitting thread participates too (it claims
//! chunks like any worker), so a region completes even with zero pool
//! workers, and small regions finish without any cross-thread traffic.
//!
//! ## Determinism
//!
//! The unit of work handed to `f` is always a *whole output row range*
//! (see [`crate::linalg::par`]): each chunk fully computes its own
//! disjoint output rows and never reads another chunk's output. Results
//! are therefore **bit-identical for any worker count and any
//! chunk-claim order** — the scheduler decides *when* and *where* a row
//! is computed, never *what* is computed. This is the contract that lets
//! the serving layer run batch products on the shared pool without
//! breaking the bit-stability guarantee pinned by
//! `tests/serve_concurrency.rs`, and it is pinned directly by
//! `tests/pool_determinism.rs`.
//!
//! ## Nested parallelism
//!
//! A chunk body must never re-enter the pool: all workers could be busy
//! executing outer chunks, and a blocking nested submit could deadlock
//! (and would destroy locality anyway). A thread-local region flag
//! ([`in_parallel_region`]) makes any nested parallel call run inline on
//! the calling worker.
//!
//! ## Knobs
//!
//! * `GVT_RLS_THREADS` — worker-thread budget for every parallel region
//!   (default: available parallelism). Read once at startup;
//!   [`set_num_threads`] is the in-process (test/ablation) override —
//!   the historical one-shot `AtomicUsize` latch in `linalg::par` meant
//!   tests could not vary the thread count within a process.
//! * `GVT_RLS_POOL=0` — ablation hatch: fall back to the pre-pool
//!   scoped-spawn path (same chunking, same results, fresh threads per
//!   region). [`set_pool_enabled`] is the in-process override.
//!
//! Allocation behavior: submitting a job allocates nothing — the job
//! header lives on the submitter's stack and the queue reuses its
//! capacity — so solver iterations stay allocation-free after pool
//! warmup (pinned by `tests/alloc_free.rs`). Workers are started lazily
//! on first use; [`warm`] pre-spawns them so a serving process does not
//! pay thread creation on its first request.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

// ---------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------

/// Process-start knobs, parsed once. In-process variation goes through
/// the explicit overrides below, not the environment.
struct EnvConfig {
    threads: usize,
    pool: bool,
}

fn env_config() -> &'static EnvConfig {
    static CFG: OnceLock<EnvConfig> = OnceLock::new();
    CFG.get_or_init(|| {
        let threads = std::env::var("GVT_RLS_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        let pool = match std::env::var("GVT_RLS_POOL") {
            Ok(v) => v != "0",
            Err(_) => true,
        };
        EnvConfig { threads, pool }
    })
}

/// `0` = no override (use the environment).
static THREADS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);
/// `0` = no override, `1` = forced off, `2` = forced on.
static POOL_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Worker-thread budget for parallel regions: the [`set_num_threads`]
/// override if set, else `GVT_RLS_THREADS`, else available parallelism.
/// Always ≥ 1 (1 means: run everything inline on the caller).
pub fn num_threads() -> usize {
    match THREADS_OVERRIDE.load(Ordering::Relaxed) {
        0 => env_config().threads,
        n => n,
    }
}

/// In-process override of the thread budget (`None` reverts to the
/// environment). For tests and ablations — production configuration is
/// `GVT_RLS_THREADS`. Takes effect for *subsequent* parallel regions;
/// regions already running are unaffected. Raising the budget above the
/// number of started workers spawns the missing workers on the next
/// pooled region.
pub fn set_num_threads(n: Option<usize>) {
    THREADS_OVERRIDE.store(n.map_or(0, |v| v.max(1)), Ordering::Relaxed);
}

/// Is the persistent pool active (vs the scoped-spawn fallback)?
pub fn pool_enabled() -> bool {
    match POOL_OVERRIDE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => env_config().pool,
    }
}

/// In-process override of `GVT_RLS_POOL` (`None` reverts to the
/// environment). For tests and ablations (`tests/pool_determinism.rs`
/// cross-checks both execution paths in one process).
pub fn set_pool_enabled(on: Option<bool>) {
    let v = match on {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    };
    POOL_OVERRIDE.store(v, Ordering::Relaxed);
}

// ---------------------------------------------------------------------
// Nested-parallelism guard
// ---------------------------------------------------------------------

thread_local! {
    /// True while this thread is executing a chunk of some parallel
    /// region (as a pool worker, a scoped-fallback worker, or a helping
    /// submitter).
    static IN_PARALLEL: Cell<bool> = const { Cell::new(false) };
}

/// Is the current thread inside a parallel chunk? Parallel entry points
/// check this and run inline instead of re-entering the pool.
pub fn in_parallel_region() -> bool {
    IN_PARALLEL.with(|c| c.get())
}

/// RAII region marker (restores the previous state, so explicitly inline
/// helpers can nest).
struct RegionGuard {
    prev: bool,
}

impl RegionGuard {
    fn enter() -> RegionGuard {
        RegionGuard { prev: IN_PARALLEL.with(|c| c.replace(true)) }
    }
}

impl Drop for RegionGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_PARALLEL.with(|c| c.set(prev));
    }
}

// ---------------------------------------------------------------------
// The job header
// ---------------------------------------------------------------------

/// One parallel region. Lives on the **submitter's stack**: submission
/// allocates nothing, which is what keeps pooled solver iterations
/// allocation-free. Liveness protocol: the submitter keeps the header
/// alive until (a) the queue entry is retired, (b) `refs == 0` (no
/// worker is attached), and (c) `completed == chunks`.
struct JobCore {
    /// Type-erased `&F` of the submitting call.
    data: *const (),
    /// Monomorphized trampoline invoking `(*data)(chunk_index)`.
    /// SAFETY: may be invoked only while `data` still points to the live
    /// closure this header was built from (the liveness protocol above),
    /// and only with a chunk index below `chunks`.
    call: unsafe fn(*const (), usize),
    chunks: usize,
    /// Chunk-claim counter; `fetch_add` hands out indices. Values ≥
    /// `chunks` mean "drained" and must not invoke `call`.
    next: AtomicUsize,
    /// Chunks whose `call` has returned.
    completed: AtomicUsize,
    /// Workers currently attached to this job.
    refs: AtomicUsize,
    panicked: AtomicBool,
    /// First panic payload, re-thrown on the submitter.
    payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// Queue entry. SAFETY: the pointee outlives its presence in the queue
/// (see [`JobCore`] liveness protocol), and `JobCore`'s fields are all
/// thread-safe to access through a shared reference.
struct JobPtr(*const JobCore);
// SAFETY: sending the raw pointer across threads is sound under the
// liveness protocol above — the pointee outlives its queue entry — and
// every `JobCore` field is accessed through atomics or a Mutex, so
// shared access from any thread is safe.
unsafe impl Send for JobPtr {}

// ---------------------------------------------------------------------
// The shared pool
// ---------------------------------------------------------------------

struct PoolShared {
    /// Pending/running jobs, oldest first. Entries are retired by their
    /// submitter (always) and opportunistically by workers that find
    /// them drained.
    queue: Mutex<VecDeque<JobPtr>>,
    /// Wakes parked workers when work arrives.
    work_cv: Condvar,
    /// Submitter wait channel: workers take this lock (empty critical
    /// section) and notify after finishing chunks, so a submitter
    /// checking its job's counters under the lock cannot miss a wakeup.
    done_lock: Mutex<()>,
    done_cv: Condvar,
    /// Workers started so far.
    spawned: AtomicUsize,
    /// Serializes worker spawning.
    spawn_lock: Mutex<()>,
}

fn shared() -> &'static PoolShared {
    static SHARED: OnceLock<PoolShared> = OnceLock::new();
    SHARED.get_or_init(|| PoolShared {
        queue: Mutex::new(VecDeque::with_capacity(64)),
        work_cv: Condvar::new(),
        done_lock: Mutex::new(()),
        done_cv: Condvar::new(),
        spawned: AtomicUsize::new(0),
        spawn_lock: Mutex::new(()),
    })
}

impl PoolShared {
    /// Lazily start workers until `target` are running. The pool sizes
    /// itself to `num_threads() - 1` (the submitter is the missing
    /// thread). Workers park forever when idle; they are never joined —
    /// the process exits through them.
    fn ensure_workers(&'static self, target: usize) {
        if self.spawned.load(Ordering::Acquire) >= target {
            return;
        }
        let _g = self.spawn_lock.lock().unwrap();
        let mut cur = self.spawned.load(Ordering::Acquire);
        while cur < target {
            std::thread::Builder::new()
                .name(format!("gvt-pool-{cur}"))
                .spawn(move || worker_loop(self))
                .expect("runtime pool: spawning worker thread");
            cur += 1;
        }
        self.spawned.store(cur, Ordering::Release);
    }
}

/// Pre-spawn the configured workers. Long-lived processes with latency
/// targets (the serve path) call this at startup so the first request
/// does not pay thread creation; everywhere else the pool starts on
/// first use.
pub fn warm() {
    if pool_enabled() && !in_parallel_region() {
        shared().ensure_workers(num_threads().saturating_sub(1));
    }
}

fn worker_loop(shared: &'static PoolShared) {
    loop {
        // Find the oldest job with unclaimed chunks, attaching to it
        // under the queue lock (an entry in the queue guarantees the
        // header is alive; attaching pins it past retirement).
        let job: *const JobCore = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                // Opportunistically retire drained entries.
                q.retain(|p| {
                    // SAFETY: an entry in the queue guarantees its header
                    // is alive — submitters retire their entry (under
                    // this lock) before their stack frame can die.
                    let j = unsafe { &*p.0 };
                    j.next.load(Ordering::Relaxed) < j.chunks
                });
                if let Some(p) = q.front() {
                    // SAFETY: same liveness argument as the retain above;
                    // the fetch_add attaches us, which additionally pins
                    // the header past retirement until we detach below.
                    let j = unsafe { &*p.0 };
                    j.refs.fetch_add(1, Ordering::Acquire);
                    break p.0;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        // SAFETY: we are attached (refs > 0), so the submitter cannot
        // return and invalidate the header until we detach.
        run_job_chunks(unsafe { &*job });
        // SAFETY: still attached, so the header is alive for this final
        // access. Detach: after this store the submitter may observe
        // refs == 0 and free the header — `job` must not be touched
        // again.
        unsafe { &*job }.refs.fetch_sub(1, Ordering::Release);
        // Lock-then-notify handshake with waiting submitters.
        drop(shared.done_lock.lock().unwrap());
        shared.done_cv.notify_all();
    }
}

/// Claim and execute chunks of `job` until its counter is drained.
/// Shared by pool workers and helping submitters.
// lint: alloc_free — the chunk-claim/execute loop runs inside solver
// iterations on every worker (tests/alloc_free.rs counts all threads).
fn run_job_chunks(job: &JobCore) {
    loop {
        let ci = job.next.fetch_add(1, Ordering::Relaxed);
        if ci >= job.chunks {
            return;
        }
        let _region = RegionGuard::enter();
        let span = crate::obs::trace::begin();
        // Contain chunk panics: an unwinding pool worker would strand
        // the submitter. The first payload is re-thrown on the
        // submitter, so test assertions inside parallel closures keep
        // their messages. SAFETY: `ci < chunks` was checked above and
        // the claim counter hands each index out exactly once, and the
        // header (hence `data`) is alive for the duration of the call —
        // the trampoline's contract holds.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            (job.call)(job.data, ci)
        }));
        crate::obs::trace::end("pool.chunk", "pool", span);
        if let Err(payload) = result {
            let mut slot = job.payload.lock().unwrap_or_else(|e| e.into_inner());
            if slot.is_none() {
                *slot = Some(payload);
            }
            drop(slot);
            job.panicked.store(true, Ordering::Release);
        }
        job.completed.fetch_add(1, Ordering::Release);
    }
}

// ---------------------------------------------------------------------
// Entry point
// ---------------------------------------------------------------------

/// Execute `f(chunk_index)` for every index in `0..chunks` as one
/// parallel region on the shared runtime, blocking until all chunks have
/// completed. The calling thread participates. Chunk indices must map to
/// **disjoint** outputs (the caller's responsibility — see
/// [`crate::linalg::par`] for the safe row-aligned wrappers); claim
/// order is unspecified, so per-chunk work must not depend on other
/// chunks having run.
///
/// Runs inline (plain loop, no threads) when `chunks <= 1`, when the
/// thread budget is 1, or when called from inside another parallel
/// region (the nested-parallelism guard). Honors the `GVT_RLS_POOL=0` /
/// [`set_pool_enabled`] ablation by falling back to scoped spawning with
/// identical chunking.
// lint: alloc_free — submission runs inside solver iterations; the job
// header lives on this stack frame and the queue reuses its capacity.
pub fn run_chunks<F>(chunks: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if chunks == 0 {
        return;
    }
    if chunks == 1 || num_threads() == 1 || in_parallel_region() {
        for ci in 0..chunks {
            f(ci);
        }
        return;
    }
    if pool_enabled() {
        run_pooled(chunks, &f);
    } else {
        run_scoped(chunks, &f);
    }
}

// lint: alloc_free — the pooled submission path (verified dynamically by
// the pooled section of tests/alloc_free.rs).
fn run_pooled<F>(chunks: usize, f: &F)
where
    F: Fn(usize) + Sync,
{
    // SAFETY: callers must pass the `data` pointer of the `&F` this job
    // was built from, still alive; the cast reconstructs exactly that
    // `&F`, so the dereference is sound for the call's duration.
    unsafe fn call<F: Fn(usize) + Sync>(data: *const (), ci: usize) {
        (*(data as *const F))(ci)
    }
    let shared = shared();
    shared.ensure_workers(num_threads().saturating_sub(1));
    let span = crate::obs::trace::begin();

    let job = JobCore {
        data: f as *const F as *const (),
        call: call::<F>,
        chunks,
        next: AtomicUsize::new(0),
        completed: AtomicUsize::new(0),
        refs: AtomicUsize::new(0),
        panicked: AtomicBool::new(false),
        payload: Mutex::new(None),
    };
    {
        let mut q = shared.queue.lock().unwrap();
        q.push_back(JobPtr(&job as *const JobCore));
    }
    // Wake at most as many workers as there are chunks for others.
    if chunks >= num_threads() {
        shared.work_cv.notify_all();
    } else {
        for _ in 1..chunks {
            shared.work_cv.notify_one();
        }
    }

    // Help: the submitter claims chunks like any worker.
    run_job_chunks(&job);

    // Retire the queue entry so no *new* worker attaches...
    {
        let me = &job as *const JobCore;
        let mut q = shared.queue.lock().unwrap();
        q.retain(|p| p.0 != me);
    }
    // ...then wait for attached workers to drain and detach. Only after
    // this loop may `job` (on our stack) be dropped.
    {
        let mut g = shared.done_lock.lock().unwrap();
        while job.completed.load(Ordering::Acquire) < chunks
            || job.refs.load(Ordering::Acquire) != 0
        {
            g = shared.done_cv.wait(g).unwrap();
        }
    }
    crate::obs::trace::end("pool.job", "pool", span);

    if job.panicked.load(Ordering::Acquire) {
        let payload = job
            .payload
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
            // lint: allow(alloc, cold path — runs only after a chunk panicked)
            .unwrap_or_else(|| Box::new("runtime pool: a parallel chunk panicked"));
        std::panic::resume_unwind(payload);
    }
}

/// `GVT_RLS_POOL=0` fallback: the pre-pool scoped-spawn execution, kept
/// as the ablation baseline (`benches/bench_pool.rs` measures the
/// difference). Same chunk-claim scheduling over fresh scoped threads,
/// so outputs are bit-identical to the pooled path — and the same
/// panic-payload relay, so a chunk panic surfaces on the submitter with
/// its original payload instead of `thread::scope`'s generic one.
fn run_scoped<F>(chunks: usize, f: &F)
where
    F: Fn(usize) + Sync,
{
    let helpers = num_threads().min(chunks).saturating_sub(1);
    let next = AtomicUsize::new(0);
    let payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    let work = || {
        let _region = RegionGuard::enter();
        loop {
            let ci = next.fetch_add(1, Ordering::Relaxed);
            if ci >= chunks {
                break;
            }
            if let Err(p) =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(ci)))
            {
                let mut slot = payload.lock().unwrap_or_else(|e| e.into_inner());
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
        }
    };
    std::thread::scope(|s| {
        for _ in 0..helpers {
            s.spawn(&work);
        }
        work();
    });
    if let Some(p) = payload.lock().unwrap_or_else(|e| e.into_inner()).take() {
        std::panic::resume_unwind(p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn every_chunk_runs_exactly_once() {
        for &chunks in &[1usize, 2, 3, 7, 64, 257] {
            let counts: Vec<AtomicU64> = (0..chunks).map(|_| AtomicU64::new(0)).collect();
            run_chunks(chunks, |ci| {
                counts[ci].fetch_add(1, Ordering::Relaxed);
            });
            for (ci, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "chunk {ci} of {chunks}");
            }
        }
    }

    #[test]
    fn concurrent_submitters_complete() {
        let total = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        run_chunks(8, |_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 50 * 8);
    }

    /// One test for everything that mutates the process-global overrides
    /// (sibling tests run concurrently under libtest — the mutations
    /// must stay serialized in a single test body). Covers: the
    /// round-trip of both overrides, pooled-vs-scoped equivalence, and
    /// the nested-parallelism guard (which needs a guaranteed
    /// multi-thread budget to observe a non-inline region).
    #[test]
    fn overrides_modes_and_nesting() {
        // Override round trips.
        set_num_threads(Some(3));
        assert_eq!(num_threads(), 3);
        set_num_threads(Some(4));

        // Nested regions run inline on the claiming thread.
        let outer = AtomicU64::new(0);
        let inner = AtomicU64::new(0);
        run_chunks(4, |_| {
            assert!(in_parallel_region());
            run_chunks(4, |_| {
                inner.fetch_add(1, Ordering::Relaxed);
            });
            outer.fetch_add(1, Ordering::Relaxed);
        });
        assert!(!in_parallel_region());
        assert_eq!(outer.load(Ordering::Relaxed), 4);
        assert_eq!(inner.load(Ordering::Relaxed), 16);

        // Pooled and scoped execution fill identically.
        let fill = |out: &mut [u64]| {
            let base = out.as_mut_ptr() as usize;
            run_chunks(out.len(), move |ci| {
                // SAFETY: one disjoint element per chunk.
                unsafe { *(base as *mut u64).add(ci) = (ci * ci) as u64 };
            });
        };
        let mut a = vec![0u64; 100];
        let mut b = vec![0u64; 100];
        set_pool_enabled(Some(true));
        fill(&mut a);
        set_pool_enabled(Some(false));
        fill(&mut b);
        assert_eq!(a, b);

        // Revert to the environment configuration.
        set_pool_enabled(None);
        set_num_threads(None);
        assert_eq!(num_threads(), env_config().threads);
    }

    #[test]
    fn chunk_panic_propagates_to_submitter() {
        let caught = std::panic::catch_unwind(|| {
            run_chunks(8, |ci| {
                if ci == 5 {
                    panic!("chunk 5 says hello");
                }
            });
        });
        let payload = caught.expect_err("panic must propagate");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
            .unwrap_or("");
        assert!(msg.contains("chunk 5"), "payload: {msg}");
    }
}
