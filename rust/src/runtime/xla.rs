//! Offline stub of the `xla` crate (Rust bindings to XLA/PJRT).
//!
//! The real bindings link `libxla_extension` and cannot be fetched or
//! built in this offline environment, so this module mirrors exactly the
//! slice of their API that [`crate::runtime::executor`] uses. Every entry
//! point fails fast at [`PjRtClient::cpu`] with a descriptive error.
//!
//! How that error surfaces depends on whether AOT artifacts exist:
//!
//! * **No `artifacts/` dir** (every offline build — producing artifacts
//!   requires the Python JAX pipeline): `runtime-info`, the
//!   `runtime_artifacts` tests and `bench_runtime` gate on
//!   [`crate::runtime::Registry::discover`] returning `None` and skip the
//!   XLA path entirely; learning/serving always uses the rust-native GVT
//!   ([`crate::gvt::vec_trick`]).
//! * **Artifacts present but this stub compiled in**: `KronExec::load`
//!   returns the descriptive error — the CLI reports it and the
//!   artifact-gated tests/benches fail *loudly* rather than silently
//!   falling back. That mismatch means the build wiring is wrong (real
//!   artifacts deserve the real backend), so hiding it would be worse.
//!
//! Swapping the real backend back in is a two-line change: delete the
//! `pub mod xla;` declaration in [`crate::runtime`] plus the
//! `use crate::runtime::xla;` import in the executor, and add the `xla`
//! dependency to Cargo.toml. No executor code changes.
//!
//! Until then, the CPU-side analogue of what the MXU would run lives in
//! [`crate::linalg::microkernel`]: the packed-panel GEMM tile is
//! shape-compatible with the scatter → GEMM → gather-dot formulation the
//! AOT pipeline lowers (rust/DESIGN.md §Micro-Kernels,
//! §Hardware-Adaptation), so a future real backend replaces tile calls,
//! not loop structure.

use crate::error::{gvt_err, GvtError, Result};

fn unavailable(what: &str) -> GvtError {
    gvt_err!(
        "XLA/PJRT backend is not available in this offline build \
         ({what}); use the rust-native GVT path instead"
    )
}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails offline — the executor surfaces this as "creating
    /// PJRT CPU client".
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// The real API is generic over the input literal type.
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub of `xla::Literal` (host-side tensor value).
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a host slice (any element type).
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    /// Unwrap a 1-tuple literal (AOT programs lower with
    /// `return_tuple=True`).
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_with_descriptive_error() {
        let err = PjRtClient::cpu().map(|_| ()).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("offline"), "{msg}");
        assert!(msg.contains("PjRtClient::cpu"), "{msg}");
    }

    #[test]
    fn literal_construction_is_infallible_but_inert() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
