//! Micro-batching dispatcher: coalesce concurrent requests into one GVT
//! pass.
//!
//! Every scoring pass over a batch of query pairs pays a fixed cost that
//! is independent of the batch size — the stage-1 streaming of the
//! training sample's index arrays (`O(n·m + n·q)` for the paper's
//! kernels) plus the per-batch operator assembly. Micro-batching
//! amortizes that cost: concurrent requests land on an mpsc queue, and a
//! single dispatcher thread drains up to [`BatchConfig::max_batch`]
//! pairs (waiting at most [`BatchConfig::max_wait`] after the first
//! request) into **one** [`Predictor::score`] call, then splits the
//! result vector back across the callers.
//!
//! Correctness is unconditional, not statistical: the predictor pins one
//! GVT factorization and every output entry is computed by a
//! row-independent operation sequence, so a request's scores are
//! bit-identical whether it was scored alone or coalesced with others
//! (pinned by `tests/serve_concurrency.rs`).

use crate::error::{gvt_err, Result};
use crate::serve::predictor::{Predictor, QueryPair};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Dispatcher tuning.
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    /// Hard cap on the *pairs* coalesced into one pass: a job that would
    /// push the batch over this opens the next batch instead. A single
    /// over-sized request is never split — it runs as its own (large)
    /// batch.
    pub max_batch: usize,
    /// How long the dispatcher waits for more requests after the first
    /// one of a batch arrives.
    pub max_wait: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self { max_batch: 256, max_wait: Duration::from_micros(500) }
    }
}

/// One queued request: the query pairs plus the caller's reply channel.
struct Job {
    pairs: Vec<QueryPair>,
    reply: mpsc::Sender<std::result::Result<Vec<f64>, String>>,
}

/// Cloneable client handle onto the dispatcher queue.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: mpsc::Sender<Job>,
}

impl BatcherHandle {
    /// Score `pairs`, blocking until the dispatcher's batch containing
    /// them completes. Thread-safe; call from any number of client
    /// threads.
    pub fn score(&self, pairs: Vec<QueryPair>) -> Result<Vec<f64>> {
        if pairs.is_empty() {
            return Ok(Vec::new());
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Job { pairs, reply: reply_tx })
            .map_err(|_| gvt_err!("batcher is shut down"))?;
        match reply_rx.recv() {
            Ok(Ok(scores)) => Ok(scores),
            Ok(Err(msg)) => Err(gvt_err!("{msg}")),
            Err(_) => Err(gvt_err!("batcher dropped the request")),
        }
    }
}

/// The running dispatcher. Dropping (or [`Batcher::shutdown`]) closes
/// the queue and joins the worker.
pub struct Batcher {
    handle: BatcherHandle,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn the dispatcher thread over `predictor`. Also pre-spawns the
    /// shared runtime pool's workers ([`crate::runtime::pool::warm`]):
    /// the dispatcher executes every batch product on the pool, and a
    /// lazily-started pool would tax the first request with thread
    /// creation. (Bit-stability is unaffected — the pool's unit of work
    /// is whole output rows, so results do not depend on worker count or
    /// chunk-claim order.)
    pub fn start(predictor: Arc<Predictor>, cfg: BatchConfig) -> Batcher {
        crate::runtime::pool::warm();
        let (tx, rx) = mpsc::channel::<Job>();
        let worker = std::thread::Builder::new()
            .name("gvt-serve-batcher".into())
            .spawn(move || dispatch_loop(rx, predictor, cfg))
            // lint: allow(panic, startup-time OS spawn failure, before
            // any request is accepted — nothing in-band to answer yet)
            .expect("spawning batcher thread");
        Batcher { handle: BatcherHandle { tx }, worker: Some(worker) }
    }

    /// A new client handle.
    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone()
    }

    /// Close the queue and wait for the dispatcher to drain. **Blocks
    /// until every [`BatcherHandle`] clone has been dropped** — handles
    /// keep the queue open, so drop them (or join the threads owning
    /// them) first.
    pub fn shutdown(self) {
        // Drop does the work: replaces the live sender, joins the worker.
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Replace the live sender so the worker can observe disconnect.
        self.handle = BatcherHandle { tx: dead_sender() };
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// A sender whose receiver is already gone (used to drop the live one).
fn dead_sender() -> mpsc::Sender<Job> {
    let (tx, _rx) = mpsc::channel();
    tx
}

fn dispatch_loop(rx: mpsc::Receiver<Job>, predictor: Arc<Predictor>, cfg: BatchConfig) {
    // A job that would push the current batch past max_batch is not
    // merged; it opens the next batch instead.
    let mut carry: Option<Job> = None;
    loop {
        // Block for the first request of the next batch.
        let first = match carry.take() {
            Some(job) => job,
            None => match rx.recv() {
                Ok(job) => job,
                Err(_) => return, // all handles dropped
            },
        };
        // Pairs are MOVED into one contiguous batch as jobs arrive (no
        // per-request clones — featured queries carry feature vectors);
        // `replies` remembers each job's reply channel and pair count.
        let mut batch: Vec<QueryPair> = first.pairs;
        let mut replies: Vec<(mpsc::Sender<std::result::Result<Vec<f64>, String>>, usize)> =
            vec![(first.reply, batch.len())];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(mut job) => {
                    if batch.len() + job.pairs.len() > cfg.max_batch {
                        // Over the cap: this job starts the next batch
                        // (a single over-sized request still runs alone,
                        // as its own large batch).
                        carry = Some(job);
                        break;
                    }
                    let n = job.pairs.len();
                    batch.append(&mut job.pairs);
                    replies.push((job.reply, n));
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // One fused pass for the whole batch.
        predictor
            .serve_stats()
            .record_batch(replies.len() as u64, batch.len() as u64);
        match predictor.score(&batch) {
            Ok(scores) => {
                let mut offset = 0;
                for (reply, n) in &replies {
                    // lint: allow(panic, per-job counts sum to the batch
                    // length by construction, and score() returned one
                    // score per pair)
                    let slice = scores[offset..offset + n].to_vec();
                    offset += n;
                    let _ = reply.send(Ok(slice));
                }
            }
            Err(e) if replies.len() == 1 => {
                // lint: allow(panic, guarded by the match arm — exactly
                // one reply entry exists here)
                let _ = replies[0].0.send(Err(format!("{e:#}")));
            }
            Err(_) => {
                // One bad request (e.g. an out-of-domain index) must not
                // fail its riders: retry each job alone so only the
                // offender errors. Per-job scoring is bit-identical to
                // the batched pass, so honest jobs lose nothing. The
                // failed pass's counters are backed out first — each
                // retry re-counts its own pairs.
                predictor.serve_stats().unrecord_score(batch.len() as u64);
                let mut offset = 0;
                for (reply, n) in &replies {
                    // lint: allow(panic, per-job counts sum to the batch
                    // length by construction — same slicing as the Ok arm)
                    let res = match predictor.score(&batch[offset..offset + n]) {
                        Ok(scores) => Ok(scores),
                        Err(e) => Err(format!("{e:#}")),
                    };
                    offset += n;
                    let _ = reply.send(res);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::PairDataset;
    use crate::gvt::pairwise::PairwiseKernel;
    use crate::rng::{dist, Xoshiro256};
    use crate::solvers::ridge::{PairwiseRidge, RidgeConfig};
    use crate::serve::predictor::ServeOptions;
    use crate::testing::gen;
    use std::sync::Arc;

    fn toy_predictor(seed: u64) -> (Arc<Predictor>, PairDataset) {
        let mut rng = Xoshiro256::seed_from(seed);
        let d = Arc::new(gen::psd_kernel(&mut rng, 6));
        let t = Arc::new(gen::psd_kernel(&mut rng, 7));
        let pairs = gen::pair_sample(&mut rng, 35, 6, 7);
        let data = PairDataset {
            name: "batcher-toy".into(),
            d,
            t,
            pairs,
            y: dist::normal_vec(&mut rng, 35),
            homogeneous: false,
        };
        let cfg = RidgeConfig { max_iters: 20, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        (
            Arc::new(Predictor::new(model, None, None, ServeOptions::default()).unwrap()),
            data,
        )
    }

    #[test]
    fn batched_replies_match_direct_scoring() {
        let (pred, _) = toy_predictor(110);
        let expect = pred
            .score(&[QueryPair::known(1, 2), QueryPair::known(3, 4)])
            .unwrap();
        let batcher = Batcher::start(pred.clone(), BatchConfig::default());
        let handle = batcher.handle();
        let got = handle
            .score(vec![QueryPair::known(1, 2), QueryPair::known(3, 4)])
            .unwrap();
        assert_eq!(got, expect);
        drop(handle);
        batcher.shutdown();
    }

    #[test]
    fn errors_propagate_to_callers() {
        let (pred, _) = toy_predictor(111);
        let batcher = Batcher::start(pred, BatchConfig::default());
        let handle = batcher.handle();
        // Out-of-domain index: the request must fail, not panic the
        // dispatcher — and the dispatcher must survive for later calls.
        assert!(handle.score(vec![QueryPair::known(99, 0)]).is_err());
        assert!(handle.score(vec![QueryPair::known(0, 0)]).is_ok());
        drop(handle);
        batcher.shutdown();
    }

    #[test]
    fn max_batch_is_a_hard_cap() {
        let (pred, _) = toy_predictor(115);
        let cfg = BatchConfig { max_batch: 4, max_wait: Duration::from_millis(150) };
        let batcher = Batcher::start(pred.clone(), cfg);
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let small = {
            let h = batcher.handle();
            let b = barrier.clone();
            std::thread::spawn(move || {
                b.wait();
                h.score(vec![QueryPair::known(0, 0)]).unwrap()
            })
        };
        let big = {
            let h = batcher.handle();
            let b = barrier.clone();
            std::thread::spawn(move || {
                b.wait();
                // 10 pairs > max_batch: must run as its own batch, never
                // merged with the 1-pair request.
                let pairs: Vec<QueryPair> =
                    (0..10u32).map(|k| QueryPair::known(k % 6, k % 7)).collect();
                h.score(pairs).unwrap()
            })
        };
        assert_eq!(small.join().unwrap().len(), 1);
        assert_eq!(big.join().unwrap().len(), 10);
        let stats = pred.stats();
        assert_eq!(stats.batches, 2, "cap must split the passes: {stats:?}");
        assert_eq!(stats.batch_pairs_max, 10, "{stats:?}");
        batcher.shutdown();
    }

    #[test]
    fn bad_rider_does_not_poison_the_batch() {
        let (pred, _) = toy_predictor(114);
        let cfg = BatchConfig { max_batch: 64, max_wait: Duration::from_millis(150) };
        let batcher = Batcher::start(pred, cfg);
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let good = {
            let h = batcher.handle();
            let b = barrier.clone();
            std::thread::spawn(move || {
                b.wait();
                h.score(vec![QueryPair::known(2, 3)])
            })
        };
        let bad = {
            let h = batcher.handle();
            let b = barrier.clone();
            std::thread::spawn(move || {
                b.wait();
                h.score(vec![QueryPair::known(99, 0)])
            })
        };
        assert!(good.join().unwrap().is_ok());
        assert!(bad.join().unwrap().is_err());
        batcher.shutdown();
    }

    #[test]
    fn empty_request_short_circuits() {
        let (pred, _) = toy_predictor(112);
        let batcher = Batcher::start(pred, BatchConfig::default());
        assert_eq!(batcher.handle().score(Vec::new()).unwrap(), Vec::<f64>::new());
        batcher.shutdown();
    }

    #[test]
    fn concurrent_requests_coalesce() {
        let (pred, _) = toy_predictor(113);
        let cfg = BatchConfig { max_batch: 64, max_wait: Duration::from_millis(150) };
        let batcher = Batcher::start(pred.clone(), cfg);
        let barrier = Arc::new(std::sync::Barrier::new(8));
        let mut threads = Vec::new();
        for k in 0..8u32 {
            let h = batcher.handle();
            let b = barrier.clone();
            threads.push(std::thread::spawn(move || {
                b.wait();
                h.score(vec![QueryPair::known(k % 6, k % 7)]).unwrap()
            }));
        }
        for th in threads {
            let scores = th.join().unwrap();
            assert_eq!(scores.len(), 1);
        }
        let stats = pred.stats();
        assert_eq!(stats.requests, 8);
        // With a 150 ms window and simultaneous release, at least one
        // dispatcher pass must have carried more than one request.
        assert!(
            stats.batch_jobs_max >= 2,
            "no coalescing observed: {stats:?}"
        );
        assert!(stats.batches < 8, "every request ran alone: {stats:?}");
        batcher.shutdown();
    }
}
