//! Micro-batching dispatcher: coalesce concurrent requests into one GVT
//! pass.
//!
//! Every scoring pass over a batch of query pairs pays a fixed cost that
//! is independent of the batch size — the stage-1 streaming of the
//! training sample's index arrays (`O(n·m + n·q)` for the paper's
//! kernels) plus the per-batch operator assembly. Micro-batching
//! amortizes that cost: concurrent requests land on an mpsc queue, and a
//! single dispatcher thread drains up to [`BatchConfig::max_batch`]
//! pairs (waiting at most [`BatchConfig::max_wait`] after the first
//! request) into **one** [`Predictor::score`] call, then splits the
//! result vector back across the callers.
//!
//! Correctness is unconditional, not statistical: the predictor pins one
//! GVT factorization and every output entry is computed by a
//! row-independent operation sequence, so a request's scores are
//! bit-identical whether it was scored alone or coalesced with others
//! (pinned by `tests/serve_concurrency.rs`).
//!
//! # Production hardening
//!
//! The dispatcher is the server's single point of failure, so its
//! failure modes are bounded explicitly (`tests/serve_faults.rs` pins
//! each one by injecting the fault):
//!
//! * **Admission control** ([`BatchConfig::max_inflight`]): the queue
//!   holds at most that many *pairs* across unanswered requests. A
//!   request that would exceed the budget is rejected immediately with
//!   [`ScoreFailure::Overloaded`] and a `retry_after_us` hint — clients
//!   get in-band backpressure instead of unbounded queueing. A single
//!   request larger than the whole budget is admitted when the queue is
//!   empty (it could otherwise never run).
//! * **Deadlines** ([`BatchConfig::deadline`], or per-request via
//!   [`BatcherHandle::submit`]): each job carries its expiry; when the
//!   dispatcher assembles a batch it answers expired jobs with an error
//!   instead of scoring them, so a stalled queue fails fast in-band
//!   rather than holding every rider hostage.
//! * **Panic recovery**: the scoring pass runs under `catch_unwind`; a
//!   panic answers every job of that batch with an in-band internal
//!   error and the dispatcher keeps serving the next batch.
//! * **Model hot-swap**: the dispatcher resolves
//!   [`PredictorSlot::current`] once per batch, so an in-flight batch
//!   finishes on the model it started with and the next batch picks up
//!   a reload atomically.
//! * **Drain accounting**: after [`PredictorSlot::begin_drain`], every
//!   job the dispatcher still answers counts into
//!   `RobustStats::drained_jobs` — the graceful-shutdown ledger.

use crate::error::{gvt_err, Result};
use crate::obs::{clock, metrics, trace};
use crate::serve::predictor::{Predictor, QueryPair, ServeOptions};
use crate::serve::reload::{PredictorSlot, RobustStats};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Dispatcher tuning.
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    /// Hard cap on the *pairs* coalesced into one pass: a job that would
    /// push the batch over this opens the next batch instead. A single
    /// over-sized request is never split — it runs as its own (large)
    /// batch.
    pub max_batch: usize,
    /// How long the dispatcher waits for more requests after the first
    /// one of a batch arrives.
    pub max_wait: Duration,
    /// Admission budget: maximum pairs queued-or-scoring at once across
    /// all clients (`0` = unbounded). Requests beyond it are rejected
    /// with [`ScoreFailure::Overloaded`] instead of queued.
    pub max_inflight: usize,
    /// Default per-request deadline, measured from enqueue
    /// (`Duration::ZERO` = none). A request-supplied deadline tightens
    /// but never loosens this.
    pub deadline: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self {
            max_batch: 256,
            max_wait: Duration::from_micros(500),
            max_inflight: 0,
            deadline: Duration::ZERO,
        }
    }
}

/// Why a submitted request produced no scores.
#[derive(Debug)]
pub enum ScoreFailure {
    /// Turned away by admission control before queueing; retry after the
    /// hinted number of microseconds (the protocol layer renders this as
    /// the in-band `{"error": "overloaded", "retry_after_us": …}` reply).
    Overloaded {
        /// Backoff hint: roughly two batching windows.
        retry_after_us: u64,
    },
    /// The request failed after admission (scoring error, expired
    /// deadline, dispatcher panic, shutdown); the message is
    /// client-renderable.
    Failed(String),
}

impl ScoreFailure {
    /// The client-facing message for the error-reply path.
    pub fn message(&self) -> String {
        match self {
            ScoreFailure::Overloaded { retry_after_us } => {
                format!("overloaded; retry in {retry_after_us}us")
            }
            ScoreFailure::Failed(msg) => msg.clone(),
        }
    }
}

/// One queued request: the query pairs, the caller's reply channel, and
/// the instant after which it should be answered with a deadline error
/// instead of scored.
struct Job {
    pairs: Vec<QueryPair>,
    reply: ReplyTx,
    deadline: Option<Instant>,
    /// Enqueue stamp for the queue-wait histogram ([`metrics::OFF`]
    /// when telemetry is disarmed — recording it is then a no-op).
    enqueued_at_us: u64,
}

type ReplyTx = mpsc::Sender<std::result::Result<Vec<f64>, String>>;

/// Cloneable client handle onto the dispatcher queue.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: mpsc::Sender<Job>,
    slot: Arc<PredictorSlot>,
    inflight: Arc<AtomicUsize>,
    cfg: BatchConfig,
}

impl BatcherHandle {
    /// Score `pairs`, blocking until the dispatcher's batch containing
    /// them completes. Thread-safe; call from any number of client
    /// threads. Admission rejections and failures are flattened into
    /// [`enum@crate::error::GvtError`] — the serve path uses
    /// [`BatcherHandle::submit`] instead to render them distinctly.
    pub fn score(&self, pairs: Vec<QueryPair>) -> Result<Vec<f64>> {
        self.submit(pairs, None).map_err(|f| gvt_err!("{}", f.message()))
    }

    /// Score `pairs` with an optional request-supplied deadline (µs from
    /// now; the configured [`BatchConfig::deadline`] still applies as an
    /// upper bound). Distinguishes admission rejection from failure so
    /// the protocol layer can answer `overloaded` with a retry hint.
    pub fn submit(
        &self,
        pairs: Vec<QueryPair>,
        deadline_us: Option<u64>,
    ) -> std::result::Result<Vec<f64>, ScoreFailure> {
        if pairs.is_empty() {
            return Ok(Vec::new());
        }
        let n = pairs.len();
        let t_admission = metrics::begin_us();
        let admitted = self.admit(n);
        metrics::ADMISSION_WAIT.record_since(t_admission);
        if !admitted {
            RobustStats::bump(&self.slot.robust.overload_rejected);
            return Err(ScoreFailure::Overloaded { retry_after_us: self.retry_after_us() });
        }
        let deadline = self.effective_deadline(deadline_us);
        let (reply_tx, reply_rx) = mpsc::channel();
        let enqueued_at_us = metrics::begin_us();
        if self.tx.send(Job { pairs, reply: reply_tx, deadline, enqueued_at_us }).is_err() {
            // Never reached the queue: back the admission out ourselves.
            self.inflight.fetch_sub(n, Ordering::AcqRel);
            return Err(ScoreFailure::Failed("batcher is shut down".to_string()));
        }
        match reply_rx.recv() {
            Ok(Ok(scores)) => Ok(scores),
            Ok(Err(msg)) => Err(ScoreFailure::Failed(msg)),
            Err(_) => Err(ScoreFailure::Failed("batcher dropped the request".to_string())),
        }
    }

    /// Reserve `n` pairs of the in-flight budget. With the budget
    /// saturated this fails without queueing; an over-budget request is
    /// still admitted when nothing is in flight (it could never run
    /// otherwise).
    fn admit(&self, n: usize) -> bool {
        let cap = self.cfg.max_inflight;
        if cap == 0 {
            self.inflight.fetch_add(n, Ordering::AcqRel);
            return true;
        }
        self.inflight
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |cur| {
                if cur == 0 || cur.saturating_add(n) <= cap {
                    Some(cur + n)
                } else {
                    None
                }
            })
            .is_ok()
    }

    /// Backoff hint for rejected requests: two batching windows, at
    /// least 100 µs.
    fn retry_after_us(&self) -> u64 {
        (self.cfg.max_wait.as_micros() as u64).saturating_mul(2).max(100)
    }

    /// Combine the configured default deadline with a request-supplied
    /// one (the tighter wins; `None`/zero-config means unbounded).
    fn effective_deadline(&self, deadline_us: Option<u64>) -> Option<Instant> {
        let cfg_us = self.cfg.deadline.as_micros() as u64;
        let limit = match (cfg_us, deadline_us) {
            (0, None) => None,
            (0, Some(us)) => Some(us),
            (c, None) => Some(c),
            (c, Some(us)) => Some(us.min(c)),
        };
        limit.map(|us| clock::now() + Duration::from_micros(us))
    }
}

/// The running dispatcher. Dropping (or [`Batcher::shutdown`]) closes
/// the queue and joins the worker.
pub struct Batcher {
    handle: BatcherHandle,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn the dispatcher over a bare predictor (wraps it in a private
    /// [`PredictorSlot`] — tests, benches, and examples use this; the
    /// server passes its own slot via [`Batcher::start_with_slot`] so
    /// reloads and robustness counters are shared).
    pub fn start(predictor: Arc<Predictor>, cfg: BatchConfig) -> Batcher {
        Batcher::start_with_slot(PredictorSlot::new(predictor, ServeOptions::default()), cfg)
    }

    /// Spawn the dispatcher thread over `slot`. Also pre-spawns the
    /// shared runtime pool's workers ([`crate::runtime::pool::warm`]):
    /// the dispatcher executes every batch product on the pool, and a
    /// lazily-started pool would tax the first request with thread
    /// creation. (Bit-stability is unaffected — the pool's unit of work
    /// is whole output rows, so results do not depend on worker count or
    /// chunk-claim order.)
    pub fn start_with_slot(slot: Arc<PredictorSlot>, cfg: BatchConfig) -> Batcher {
        crate::runtime::pool::warm();
        let (tx, rx) = mpsc::channel::<Job>();
        let inflight = Arc::new(AtomicUsize::new(0));
        let worker = {
            let slot = slot.clone();
            let inflight = inflight.clone();
            std::thread::Builder::new()
                .name("gvt-serve-batcher".into())
                .spawn(move || dispatch_loop(rx, slot, inflight, cfg))
                // lint: allow(panic, startup-time OS spawn failure, before
                // any request is accepted — nothing in-band to answer yet)
                .expect("spawning batcher thread")
        };
        Batcher { handle: BatcherHandle { tx, slot, inflight, cfg }, worker: Some(worker) }
    }

    /// A new client handle.
    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone()
    }

    /// Close the queue and wait for the dispatcher to drain. **Blocks
    /// until every [`BatcherHandle`] clone has been dropped** — handles
    /// keep the queue open, so drop them (or join the threads owning
    /// them) first.
    pub fn shutdown(self) {
        // Drop does the work: replaces the live sender, joins the worker.
    }

    /// Close the queue, then wait up to `timeout` for the dispatcher to
    /// flush what is queued and exit. Returns `true` on a clean join;
    /// on `false` the worker is abandoned (detached) so shutdown cannot
    /// hang on a stuck batch — the hard-stop half of graceful drain.
    pub fn shutdown_within(mut self, timeout: Duration) -> bool {
        self.close_queue();
        let clean = match &self.worker {
            None => true,
            Some(w) => {
                let deadline = clock::now() + timeout;
                loop {
                    if w.is_finished() {
                        break true;
                    }
                    if clock::now() >= deadline {
                        break false;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        };
        if clean {
            if let Some(w) = self.worker.take() {
                let _ = w.join();
            }
        } else {
            // Hard stop: detach the worker instead of blocking forever.
            drop(self.worker.take());
        }
        clean
    }

    /// Swap this batcher's live sender for one whose receiver is gone,
    /// so the dispatcher can observe disconnect once queued jobs and
    /// client handles are done.
    fn close_queue(&mut self) {
        self.handle = BatcherHandle {
            tx: dead_sender(),
            slot: self.handle.slot.clone(),
            inflight: self.handle.inflight.clone(),
            cfg: self.handle.cfg,
        };
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.close_queue();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// A sender whose receiver is already gone (used to drop the live one).
fn dead_sender() -> mpsc::Sender<Job> {
    let (tx, _rx) = mpsc::channel();
    tx
}

fn dispatch_loop(
    rx: mpsc::Receiver<Job>,
    slot: Arc<PredictorSlot>,
    inflight: Arc<AtomicUsize>,
    cfg: BatchConfig,
) {
    // A job that would push the current batch past max_batch is not
    // merged; it opens the next batch instead.
    let mut carry: Option<Job> = None;
    loop {
        // Block for the first request of the next batch.
        let first = match carry.take() {
            Some(job) => job,
            None => match rx.recv() {
                Ok(job) => job,
                Err(_) => return, // all handles dropped, queue flushed
            },
        };
        let t_assembly = metrics::begin_us();
        let mut jobs = vec![first];
        let mut total: usize = jobs.iter().map(|j| j.pairs.len()).sum();
        let deadline = clock::now() + cfg.max_wait;
        while total < cfg.max_batch {
            let now = clock::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(job) => {
                    if total + job.pairs.len() > cfg.max_batch {
                        // Over the cap: this job starts the next batch
                        // (a single over-sized request still runs alone,
                        // as its own large batch).
                        carry = Some(job);
                        break;
                    }
                    total += job.pairs.len();
                    jobs.push(job);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        metrics::BATCH_ASSEMBLY.record_since(t_assembly);
        let span = trace::begin();
        run_batch(&slot, &inflight, jobs);
        trace::end("serve.batch", "serve", span);
    }
}

/// Answer one assembled batch: triage expired jobs, score the rest in a
/// single fused pass (panic-safe), split the results back, and release
/// each job's admission reservation as it is answered.
fn run_batch(slot: &PredictorSlot, inflight: &AtomicUsize, jobs: Vec<Job>) {
    let draining = slot.is_draining();
    let mut answered: u64 = 0;

    // Deadline triage happens at assembly time — after the queue wait,
    // before the expensive pass — so an expired job neither rides along
    // nor delays the batch further.
    let now = clock::now();
    let mut batch: Vec<QueryPair> = Vec::new();
    let mut replies: Vec<(ReplyTx, usize)> = Vec::new();
    for mut job in jobs {
        let n = job.pairs.len();
        metrics::QUEUE_WAIT.record_since(job.enqueued_at_us);
        if job.deadline.map_or(false, |d| now >= d) {
            RobustStats::bump(&slot.robust.deadline_expired);
            let _ = job.reply.send(Err(
                "deadline expired before scoring (queue wait exceeded the request deadline)"
                    .to_string(),
            ));
            inflight.fetch_sub(n, Ordering::AcqRel);
            answered += 1;
            continue;
        }
        // Pairs are MOVED into one contiguous batch (no per-request
        // clones — featured queries carry feature vectors); `replies`
        // remembers each job's reply channel and pair count.
        batch.append(&mut job.pairs);
        replies.push((job.reply, n));
    }

    if !replies.is_empty() {
        // Resolved once per batch: a hot-reload swapping the slot
        // mid-batch cannot mix models within one pass.
        let predictor = slot.current();
        predictor.serve_stats().record_batch(replies.len() as u64, batch.len() as u64);
        // One fused pass for the whole batch, panic-safe: an unwinding
        // scoring pass (or an injected `batcher_dispatch:panic` fault)
        // must kill the batch in-band, never the dispatcher.
        metrics::BATCHES_DISPATCHED.add(1);
        let t_gvt = metrics::begin_us();
        let span = trace::begin();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if crate::runtime::fault::trip("batcher_dispatch").is_some() {
                return Err(gvt_err!("injected fault: batcher_dispatch"));
            }
            predictor.score(&batch)
        }));
        trace::end("serve.gvt_pass", "serve", span);
        metrics::GVT_PASS.record_since(t_gvt);
        match outcome {
            Ok(Ok(scores)) => {
                metrics::JOBS_SCORED.add(replies.len() as u64);
                let mut offset = 0;
                for (reply, n) in &replies {
                    // lint: allow(panic, per-job counts sum to the batch
                    // length by construction, and score() returned one
                    // score per pair)
                    let slice = scores[offset..offset + n].to_vec();
                    offset += n;
                    let _ = reply.send(Ok(slice));
                    inflight.fetch_sub(*n, Ordering::AcqRel);
                }
            }
            Ok(Err(e)) if replies.len() == 1 => {
                for (reply, n) in &replies {
                    let _ = reply.send(Err(format!("{e:#}")));
                    inflight.fetch_sub(*n, Ordering::AcqRel);
                }
            }
            Ok(Err(_)) => {
                // One bad request (e.g. an out-of-domain index) must not
                // fail its riders: retry each job alone so only the
                // offender errors. Per-job scoring is bit-identical to
                // the batched pass, so honest jobs lose nothing. The
                // failed pass's counters are backed out first — each
                // retry re-counts its own pairs.
                predictor.serve_stats().unrecord_score(batch.len() as u64);
                let mut offset = 0;
                for (reply, n) in &replies {
                    // lint: allow(panic, per-job counts sum to the batch
                    // length by construction — same slicing as the Ok arm)
                    let sub = &batch[offset..offset + n];
                    let res = match std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| predictor.score(sub)),
                    ) {
                        Ok(Ok(scores)) => Ok(scores),
                        Ok(Err(e)) => Err(format!("{e:#}")),
                        Err(_) => {
                            RobustStats::bump(&slot.robust.dispatcher_panics);
                            Err("internal error: scoring panicked; request abandoned"
                                .to_string())
                        }
                    };
                    offset += n;
                    let _ = reply.send(res);
                    inflight.fetch_sub(*n, Ordering::AcqRel);
                }
            }
            Err(_panic) => {
                // The pass unwound: answer every rider in-band and keep
                // dispatching. (Counters are left as recorded — whether
                // the pass got far enough to count itself is unknowable
                // from here, and overcounting one pass beats underflow.)
                RobustStats::bump(&slot.robust.dispatcher_panics);
                for (reply, n) in &replies {
                    let _ = reply.send(Err(
                        "internal error: scoring panicked; batch abandoned (server still up)"
                            .to_string(),
                    ));
                    inflight.fetch_sub(*n, Ordering::AcqRel);
                }
            }
        }
        answered += replies.len() as u64;
    }

    if draining && answered > 0 {
        slot.robust.drained_jobs.fetch_add(answered, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::PairDataset;
    use crate::gvt::pairwise::PairwiseKernel;
    use crate::rng::{dist, Xoshiro256};
    use crate::solvers::ridge::{PairwiseRidge, RidgeConfig};
    use crate::testing::gen;
    use std::sync::Arc;

    fn toy_predictor(seed: u64) -> (Arc<Predictor>, PairDataset) {
        let mut rng = Xoshiro256::seed_from(seed);
        let d = Arc::new(gen::psd_kernel(&mut rng, 6));
        let t = Arc::new(gen::psd_kernel(&mut rng, 7));
        let pairs = gen::pair_sample(&mut rng, 35, 6, 7);
        let data = PairDataset {
            name: "batcher-toy".into(),
            d,
            t,
            pairs,
            y: dist::normal_vec(&mut rng, 35),
            homogeneous: false,
        };
        let cfg = RidgeConfig { max_iters: 20, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        (
            Arc::new(Predictor::new(model, None, None, ServeOptions::default()).unwrap()),
            data,
        )
    }

    #[test]
    fn batched_replies_match_direct_scoring() {
        let (pred, _) = toy_predictor(110);
        let expect = pred
            .score(&[QueryPair::known(1, 2), QueryPair::known(3, 4)])
            .unwrap();
        let batcher = Batcher::start(pred.clone(), BatchConfig::default());
        let handle = batcher.handle();
        let got = handle
            .score(vec![QueryPair::known(1, 2), QueryPair::known(3, 4)])
            .unwrap();
        assert_eq!(got, expect);
        drop(handle);
        batcher.shutdown();
    }

    #[test]
    fn errors_propagate_to_callers() {
        let (pred, _) = toy_predictor(111);
        let batcher = Batcher::start(pred, BatchConfig::default());
        let handle = batcher.handle();
        // Out-of-domain index: the request must fail, not panic the
        // dispatcher — and the dispatcher must survive for later calls.
        assert!(handle.score(vec![QueryPair::known(99, 0)]).is_err());
        assert!(handle.score(vec![QueryPair::known(0, 0)]).is_ok());
        drop(handle);
        batcher.shutdown();
    }

    #[test]
    fn max_batch_is_a_hard_cap() {
        let (pred, _) = toy_predictor(115);
        let cfg = BatchConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(150),
            ..BatchConfig::default()
        };
        let batcher = Batcher::start(pred.clone(), cfg);
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let small = {
            let h = batcher.handle();
            let b = barrier.clone();
            std::thread::spawn(move || {
                b.wait();
                h.score(vec![QueryPair::known(0, 0)]).unwrap()
            })
        };
        let big = {
            let h = batcher.handle();
            let b = barrier.clone();
            std::thread::spawn(move || {
                b.wait();
                // 10 pairs > max_batch: must run as its own batch, never
                // merged with the 1-pair request.
                let pairs: Vec<QueryPair> =
                    (0..10u32).map(|k| QueryPair::known(k % 6, k % 7)).collect();
                h.score(pairs).unwrap()
            })
        };
        assert_eq!(small.join().unwrap().len(), 1);
        assert_eq!(big.join().unwrap().len(), 10);
        let stats = pred.stats();
        assert_eq!(stats.batches, 2, "cap must split the passes: {stats:?}");
        assert_eq!(stats.batch_pairs_max, 10, "{stats:?}");
        batcher.shutdown();
    }

    #[test]
    fn bad_rider_does_not_poison_the_batch() {
        let (pred, _) = toy_predictor(114);
        let cfg = BatchConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(150),
            ..BatchConfig::default()
        };
        let batcher = Batcher::start(pred, cfg);
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let good = {
            let h = batcher.handle();
            let b = barrier.clone();
            std::thread::spawn(move || {
                b.wait();
                h.score(vec![QueryPair::known(2, 3)])
            })
        };
        let bad = {
            let h = batcher.handle();
            let b = barrier.clone();
            std::thread::spawn(move || {
                b.wait();
                h.score(vec![QueryPair::known(99, 0)])
            })
        };
        assert!(good.join().unwrap().is_ok());
        assert!(bad.join().unwrap().is_err());
        batcher.shutdown();
    }

    #[test]
    fn empty_request_short_circuits() {
        let (pred, _) = toy_predictor(112);
        let batcher = Batcher::start(pred, BatchConfig::default());
        assert_eq!(batcher.handle().score(Vec::new()).unwrap(), Vec::<f64>::new());
        batcher.shutdown();
    }

    #[test]
    fn concurrent_requests_coalesce() {
        let (pred, _) = toy_predictor(113);
        let cfg = BatchConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(150),
            ..BatchConfig::default()
        };
        let batcher = Batcher::start(pred.clone(), cfg);
        let barrier = Arc::new(std::sync::Barrier::new(8));
        let mut threads = Vec::new();
        for k in 0..8u32 {
            let h = batcher.handle();
            let b = barrier.clone();
            threads.push(std::thread::spawn(move || {
                b.wait();
                h.score(vec![QueryPair::known(k % 6, k % 7)]).unwrap()
            }));
        }
        for th in threads {
            let scores = th.join().unwrap();
            assert_eq!(scores.len(), 1);
        }
        let stats = pred.stats();
        assert_eq!(stats.requests, 8);
        // With a 150 ms window and simultaneous release, at least one
        // dispatcher pass must have carried more than one request.
        assert!(
            stats.batch_jobs_max >= 2,
            "no coalescing observed: {stats:?}"
        );
        assert!(stats.batches < 8, "every request ran alone: {stats:?}");
        batcher.shutdown();
    }

    #[test]
    fn zero_deadline_expires_in_band() {
        let (pred, _) = toy_predictor(116);
        let batcher = Batcher::start(pred, BatchConfig::default());
        let handle = batcher.handle();
        // A 0 µs request deadline is already expired when the dispatcher
        // assembles its batch: the reply must be the deadline error, and
        // the dispatcher must keep serving.
        let err = handle
            .submit(vec![QueryPair::known(0, 0)], Some(0))
            .unwrap_err();
        match err {
            ScoreFailure::Failed(msg) => assert!(msg.contains("deadline expired"), "{msg}"),
            other => panic!("expected Failed, got {other:?}"),
        }
        assert!(handle.submit(vec![QueryPair::known(0, 0)], None).is_ok());
        let slot_stats = batcher.handle().slot.robust.snapshot();
        assert_eq!(slot_stats.deadline_expired, 1);
        drop(handle);
        batcher.shutdown();
    }

    #[test]
    fn inflight_budget_admits_oversized_request_on_empty_queue() {
        let (pred, _) = toy_predictor(117);
        let cfg = BatchConfig { max_inflight: 2, ..BatchConfig::default() };
        let batcher = Batcher::start(pred, cfg);
        let handle = batcher.handle();
        // 5 pairs > budget 2, but the queue is empty: must be admitted
        // and scored (otherwise it could never run at all).
        let pairs: Vec<QueryPair> = (0..5u32).map(|k| QueryPair::known(k % 6, k % 7)).collect();
        assert_eq!(handle.submit(pairs, None).unwrap().len(), 5);
        // Budget fully released afterwards: a normal request passes.
        assert!(handle.submit(vec![QueryPair::known(1, 1)], None).is_ok());
        drop(handle);
        batcher.shutdown();
    }

    #[test]
    fn timed_shutdown_joins_cleanly_when_idle() {
        let (pred, _) = toy_predictor(118);
        let batcher = Batcher::start(pred, BatchConfig::default());
        let handle = batcher.handle();
        assert!(handle.score(vec![QueryPair::known(0, 0)]).is_ok());
        drop(handle);
        assert!(batcher.shutdown_within(Duration::from_secs(5)), "idle drain must join");
    }
}
