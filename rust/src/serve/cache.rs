//! Bounded LRU cache for per-object serving intermediates.
//!
//! The expensive per-object work in serving is assembling the
//! cross-kernel row `k(x, X_train)` of an unseen object against every
//! training object — `O(m · p)` kernel evaluations that feed stage 1 of
//! the GVT product. Hot drugs/targets recur across requests (a few
//! popular compounds dominate real traffic), so the [`Predictor`]
//! (`crate::serve::Predictor`) keeps one bounded LRU per side, keyed by
//! the client-supplied object id.
//!
//! Implementation: `HashMap` for storage plus a `BTreeMap` recency index
//! (monotonic tick → key). Both `get` and `insert` are `O(log n)`; no
//! unsafe, no external crates, no background threads.

use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;

/// A bounded least-recently-used cache.
pub struct LruCache<K: Eq + Hash + Clone, V> {
    cap: usize,
    map: HashMap<K, (V, u64)>,
    recency: BTreeMap<u64, K>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    /// A cache holding at most `cap` entries. `cap == 0` disables
    /// caching entirely (every `get` misses, `insert` is a no-op).
    pub fn new(cap: usize) -> Self {
        Self {
            cap,
            map: HashMap::new(),
            recency: BTreeMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Look up `key`, marking it most-recently-used on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        self.tick += 1;
        let tick = self.tick;
        let old_stamp = match self.map.get_mut(key) {
            Some((_, stamp)) => {
                let old = *stamp;
                *stamp = tick;
                old
            }
            None => {
                self.misses += 1;
                return None;
            }
        };
        self.recency.remove(&old_stamp);
        self.recency.insert(tick, key.clone());
        self.hits += 1;
        self.map.get(key).map(|(v, _)| v)
    }

    /// Insert (or refresh) `key`, evicting the least-recently-used entry
    /// if the cache is full.
    pub fn insert(&mut self, key: K, value: V) {
        if self.cap == 0 {
            return;
        }
        self.tick += 1;
        if let Some((_, old_stamp)) = self.map.remove(&key) {
            self.recency.remove(&old_stamp);
        }
        while self.map.len() >= self.cap {
            // Oldest tick = least recently used.
            let (&oldest, _) = self.recency.iter().next().expect("recency tracks map");
            let victim = self.recency.remove(&oldest).expect("just seen");
            self.map.remove(&victim);
            self.evictions += 1;
        }
        self.map.insert(key.clone(), (value, self.tick));
        self.recency.insert(self.tick, key);
    }

    /// Entries currently cached.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Is the cache empty?
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Maximum entries (0 = caching disabled).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Lifetime hit count ([`Self::get`] found the key).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lifetime miss count.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Lifetime evictions (inserts that displaced the LRU entry).
    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recently_used() {
        let mut c: LruCache<u32, &'static str> = LruCache::new(2);
        c.insert(1, "a");
        c.insert(2, "b");
        assert_eq!(c.get(&1), Some(&"a")); // 1 is now most recent
        c.insert(3, "c"); // evicts 2
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&2), None);
        assert_eq!(c.get(&1), Some(&"a"));
        assert_eq!(c.get(&3), Some(&"c"));
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn reinsert_refreshes_without_growth() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(1, 11); // refresh, not a third entry
        assert_eq!(c.len(), 2);
        c.insert(3, 30); // evicts 2 (1 was refreshed later)
        assert_eq!(c.get(&2), None);
        assert_eq!(c.get(&1), Some(&11));
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let mut c: LruCache<u32, u32> = LruCache::new(0);
        c.insert(1, 10);
        assert!(c.is_empty());
        assert_eq!(c.get(&1), None);
        assert_eq!(c.misses(), 1);
        assert_eq!(c.hits(), 0);
    }

    #[test]
    fn counters_track_hits_and_misses() {
        let mut c: LruCache<&'static str, u32> = LruCache::new(4);
        assert_eq!(c.get(&"x"), None);
        c.insert("x", 1);
        assert_eq!(c.get(&"x"), Some(&1));
        assert_eq!(c.get(&"x"), Some(&1));
        assert_eq!((c.hits(), c.misses()), (2, 1));
    }
}
