//! Online inference: serve predictions from a fitted model at request
//! time.
//!
//! The paper frames the `O(nm + nq)` generalized vec trick as a
//! *training* speedup, but prediction is the same machinery — a
//! cross-kernel GVT product with the training sample,
//! `p = R(query) K R(train)ᵀ α` — and it is what makes answering
//! millions of (drug, target) queries feasible. This module turns the
//! compiled-plan primitives of [`crate::gvt::plan`] into a
//! request-serving engine:
//!
//! * [`predictor`] — [`Predictor`]: loads a fitted [`RidgeModel`]
//!   (typically from a self-contained v2 artifact,
//!   [`crate::solvers::persist`]), compiles the prediction-side operator
//!   against the training sample **once**, keeps its GVT workspace warm,
//!   pins the factorization for bit-stable batching, and answers all
//!   four out-of-sample settings — in-domain queries by index, unseen
//!   objects by feature vector (cross-kernel rows assembled from the
//!   artifact's feature spaces).
//! * [`batcher`] — [`Batcher`]: an mpsc micro-batching dispatcher that
//!   coalesces concurrent requests into one GVT pass, amortizing the
//!   per-pass streaming of the training sample's index arrays.
//! * [`cache`] — [`cache::LruCache`]: bounded LRU over per-object
//!   cross-kernel rows, so hot drugs/targets pay feature-space row
//!   assembly once.
//! * [`reload`] — [`PredictorSlot`]: the hot-swappable `Arc<Predictor>`
//!   seam (model reload without dropping connections) plus the
//!   [`RobustStats`] overload/deadline/drain counters that survive a
//!   swap.
//! * [`protocol`] / [`server`] — line-delimited JSON over stdin/stdout
//!   or TCP, exposed as the `gvt-rls serve` and `gvt-rls predict` CLI
//!   subcommands.
//!
//! Serving guarantees (pinned by `tests/serve_concurrency.rs`): batched
//! responses are **bit-identical** to sequential
//! [`RidgeModel::predict`] with the predictor's pinned policy, for every
//! pairwise kernel, however requests are interleaved or coalesced.
//!
//! [`RidgeModel`]: crate::solvers::ridge::RidgeModel
//! [`RidgeModel::predict`]: crate::solvers::ridge::RidgeModel::predict

pub mod batcher;
pub mod cache;
pub mod predictor;
pub mod protocol;
pub mod reload;
pub mod server;

pub use batcher::{BatchConfig, Batcher, BatcherHandle, ScoreFailure};
pub use predictor::{ObjectRef, Predictor, QueryPair, ServeOptions, StatsSnapshot};
pub use reload::{PredictorSlot, RobustSnapshot, RobustStats};
pub use server::{serve_on, serve_stdio, serve_tcp, ServeConfig};
