//! The [`Predictor`]: a fitted ridge model compiled for online serving.
//!
//! Prediction is a cross-kernel GVT product with the training sample:
//! `p = R(query) K R(train)ᵀ α`. Everything on the training side of that
//! product is fixed at load time, so the predictor compiles it **once**:
//!
//! * the prediction-side [`PairwiseLinOp`] / `GvtPlan` is built against
//!   the training sample at construction (the *template*); per-batch
//!   operators are derived from it with
//!   [`PairwiseLinOp::with_rows`] / [`PairwiseLinOp::reindexed`], which
//!   reuse the kernel matrices, their Hadamard squares, and the training
//!   sample's buffers and CSR grouping caches;
//! * one [`GvtWorkspace`] is kept warm across batches
//!   ([`PairwiseLinOp::install_workspace`] /
//!   [`PairwiseLinOp::take_workspace`]) — after the first batch at the
//!   training shapes, stage buffers are reused verbatim;
//! * the GVT factorization is **pinned** to the concrete mode the
//!   training-shaped plan resolves ([`PairwiseLinOp::resolved_mode`]).
//!   `Auto`'s cost model consults the row-sample size, which varies per
//!   batch; with the mode pinned, every output entry is produced by the
//!   same floating-point operation sequence no matter how requests are
//!   micro-batched, so batched responses are **bit-identical** to
//!   sequential [`RidgeModel::predict`].
//!
//! A query references each object either by training-domain index
//! ([`ObjectRef::Known`] — covers all four out-of-sample settings of
//! Table 1, since the domain kernel matrices span objects absent from
//! the training *sample*) or by raw feature vector
//! ([`ObjectRef::Featured`] — objects outside the domain entirely). For
//! featured objects the predictor assembles the cross-kernel row
//! `k(x, X_train)` from the artifact's embedded [`FeatureSpace`], with a
//! bounded LRU over client-supplied object ids so hot drugs/targets pay
//! the `O(m·p)` row assembly once (see [`crate::serve::cache`]).

use crate::error::{bail, gvt_err, Context, Result};
use crate::gvt::pairwise::{PairwiseKernel, PairwiseLinOp};
use crate::gvt::plan::GvtWorkspace;
use crate::gvt::vec_trick::GvtPolicy;
use crate::linalg::Mat;
use crate::serve::cache::LruCache;
use crate::solvers::persist::{FeatureSpace, ModelFile};
use crate::solvers::ridge::RidgeModel;
use crate::sparse::PairIndex;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// How a query names one object.
#[derive(Clone, Debug)]
pub enum ObjectRef {
    /// Index into the training domain (the object's row in the
    /// full-domain kernel matrix).
    Known(u32),
    /// An object outside the training domain, described by its raw
    /// feature vector. `id` (if any) keys the cross-kernel row cache.
    Featured { id: Option<String>, x: Vec<f64> },
}

/// One (drug, target) query.
#[derive(Clone, Debug)]
pub struct QueryPair {
    pub drug: ObjectRef,
    pub target: ObjectRef,
}

impl QueryPair {
    /// In-domain pair by indices.
    pub fn known(drug: u32, target: u32) -> QueryPair {
        QueryPair { drug: ObjectRef::Known(drug), target: ObjectRef::Known(target) }
    }
}

/// Predictor construction options.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Per-side capacity of the featured-object cross-kernel row cache
    /// (0 disables caching).
    pub cache_capacity: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { cache_capacity: 1024 }
    }
}

/// Monotonic serving counters (lock-free; snapshot via
/// [`Predictor::stats`]).
#[derive(Default)]
pub struct ServeStats {
    /// `score` invocations (one per executed batch or direct call).
    score_calls: AtomicU64,
    /// Query pairs scored, total.
    pairs: AtomicU64,
    /// Dispatcher batches executed (see [`crate::serve::Batcher`]).
    batches: AtomicU64,
    /// Client requests that passed through the dispatcher.
    requests: AtomicU64,
    /// Most requests coalesced into one batch.
    batch_jobs_max: AtomicU64,
    /// Most pairs coalesced into one batch.
    batch_pairs_max: AtomicU64,
}

impl ServeStats {
    /// Record one dispatcher batch of `jobs` requests / `pairs` pairs.
    pub fn record_batch(&self, jobs: u64, pairs: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.requests.fetch_add(jobs, Ordering::Relaxed);
        self.batch_jobs_max.fetch_max(jobs, Ordering::Relaxed);
        self.batch_pairs_max.fetch_max(pairs, Ordering::Relaxed);
    }

    /// Back out one failed batched `score` call's counters before its
    /// jobs are retried individually (each retry re-counts its own
    /// pairs; without this the poisoned batch would be counted twice).
    pub fn unrecord_score(&self, pairs: u64) {
        self.score_calls.fetch_sub(1, Ordering::Relaxed);
        self.pairs.fetch_sub(pairs, Ordering::Relaxed);
    }
}

/// A point-in-time copy of every serving counter.
#[derive(Clone, Copy, Debug, Default)]
pub struct StatsSnapshot {
    pub score_calls: u64,
    pub pairs: u64,
    pub batches: u64,
    pub requests: u64,
    pub batch_jobs_max: u64,
    pub batch_pairs_max: u64,
    pub drug_cache_hits: u64,
    pub drug_cache_misses: u64,
    pub drug_cache_evictions: u64,
    pub drug_cache_len: usize,
    pub target_cache_hits: u64,
    pub target_cache_misses: u64,
    pub target_cache_evictions: u64,
    pub target_cache_len: usize,
}

/// Which side of the pair an object reference sits on. Kernels over a
/// homogeneous domain (`m == q`, Symmetric/AntiSymmetric/Ranking/MLPK)
/// unify both slots into one object domain.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Side {
    Drug,
    Target,
    Unified,
}

impl Side {
    fn name(self) -> &'static str {
        match self {
            Side::Drug => "drug",
            Side::Target => "target",
            Side::Unified => "object",
        }
    }
}

/// See module docs.
pub struct Predictor {
    model: RidgeModel,
    /// The compiled prediction-side operator against the training
    /// sample; per-batch operators derive from it.
    template: PairwiseLinOp,
    /// Concrete (never `Auto`) factorization every batch executes.
    policy: GvtPolicy,
    d_features: Option<FeatureSpace>,
    t_features: Option<FeatureSpace>,
    drug_cache: Mutex<LruCache<String, Arc<CachedRow>>>,
    target_cache: Mutex<LruCache<String, Arc<CachedRow>>>,
    /// Warm GVT workspace carried across per-batch operators.
    ws: Mutex<GvtWorkspace>,
    stats: ServeStats,
}

impl Predictor {
    /// Compile a fitted model for serving. Feature spaces (optional)
    /// enable [`ObjectRef::Featured`] queries on the respective side.
    pub fn new(
        model: RidgeModel,
        d_features: Option<FeatureSpace>,
        t_features: Option<FeatureSpace>,
        opts: ServeOptions,
    ) -> Result<Predictor> {
        let train = model.train_pairs().clone();
        // Build the grouping caches on the canonical training sample
        // *before* the first operator build: clones and P/Q transforms
        // inherit the built `Arc`s, so no per-batch operator ever
        // re-derives a CSR grouping of the training sample.
        train.by_drug();
        train.by_target();
        let template = PairwiseLinOp::new(
            model.kernel(),
            model.d(),
            model.t(),
            train.clone(),
            train.clone(),
            model.policy(),
        )
        .context("compiling the serving template operator")?;
        // Pin `Auto` to the concrete factorization the training-shaped
        // plan picked (see module docs: bit-identical micro-batching).
        // The re-pin shares the first build's matrices and Hadamard
        // squares — only the plan is recompiled.
        let policy = template.resolved_mode();
        let template = if policy == template.policy() {
            template
        } else {
            template
                .with_policy(policy)
                .context("re-pinning the serving template operator")?
        };
        // A feature space must reproduce the model's operator matrix:
        // serving mixes matrix rows (known objects) with feature-derived
        // cross rows (featured objects), so an inconsistent space — e.g.
        // a kernel that was normalized after `kernel_matrix` — would
        // silently serve wrong featured scores. One-time O(m²·p) check.
        if let Some(fs) = &d_features {
            if !fs.reproduces(&model.d()) {
                bail!(
                    "drug feature space does not reproduce the model's drug kernel \
                     matrix (rows {}, domain {})",
                    fs.x.rows(),
                    model.train_pairs().m()
                );
            }
        }
        if let Some(fs) = &t_features {
            if !fs.reproduces(&model.t()) {
                bail!(
                    "target feature space does not reproduce the model's target kernel \
                     matrix (rows {}, domain {})",
                    fs.x.rows(),
                    model.train_pairs().q()
                );
            }
        }
        Ok(Predictor {
            model,
            template,
            policy,
            d_features,
            t_features,
            drug_cache: Mutex::new(LruCache::new(opts.cache_capacity)),
            target_cache: Mutex::new(LruCache::new(opts.cache_capacity)),
            ws: Mutex::new(GvtWorkspace::new()),
            stats: ServeStats::default(),
        })
    }

    /// Load a self-contained v2 artifact and compile it for serving.
    pub fn from_file(path: &Path, opts: ServeOptions) -> Result<Predictor> {
        let mut file = ModelFile::read(path)?;
        // Take the feature spaces out (they live on in the predictor —
        // cloning them would double transient memory for large feature
        // matrices) and resolve the kernel matrices here, so feature-only
        // artifacts still work without them inside `into_model`.
        let d_features = file.d_features.take();
        let t_features = file.t_features.take();
        let d = match file.d.take() {
            Some(m) => Some(Arc::new(m)),
            None => d_features.as_ref().map(|fs| Arc::new(fs.kernel_matrix())),
        };
        let t = match file.t.take() {
            Some(m) => Some(Arc::new(m)),
            None => t_features.as_ref().map(|fs| Arc::new(fs.kernel_matrix())),
        };
        let model = file
            .into_model(d, t)
            .with_context(|| format!("loading {}", path.display()))?;
        Self::new(model, d_features, t_features, opts)
    }

    /// Score a batch of queries: one GVT product for the whole batch —
    /// the stage-1 pass over the training sample (`O(n·q + n·m)` index
    /// streaming) is paid once and amortized over every pair in the
    /// batch. Output order matches input order, and each entry is
    /// bit-identical to scoring that pair alone (see module docs).
    pub fn score(&self, pairs: &[QueryPair]) -> Result<Vec<f64>> {
        if pairs.is_empty() {
            return Ok(Vec::new());
        }
        let op = self.batch_op(pairs)?;
        Ok(self.with_warm_workspace(&op, |op| op.matvec(&self.model.alpha)))
    }

    /// Score a batch for **several** models sharing this predictor's
    /// kernel and training sample (a λ grid served side by side): one
    /// multi-RHS block product ([`PairwiseLinOp::matmat`] /
    /// `GvtPlan::execute_multi`) instead of one pass per model. Column
    /// `b` holds `models[b]`'s scores; this predictor's own model is
    /// always column 0.
    pub fn score_grid(&self, pairs: &[QueryPair], extra: &[RidgeModel]) -> Result<Mat> {
        // Same kernel matrices too, not just the same pair indices: an
        // extra model solved against different D/T would be scored with
        // *this* predictor's matrices — silently wrong. Arc identity
        // covers the common case (one λ grid); content equality covers
        // models reloaded from artifacts.
        let same_matrix = |a: &Arc<Mat>, b: &Arc<Mat>| {
            Arc::ptr_eq(a, b) || (a.shape() == b.shape() && a.max_abs_diff(b) == 0.0)
        };
        for m in extra {
            if m.kernel() != self.model.kernel()
                || !m.train_pairs().same_pairs(self.model.train_pairs())
                || !same_matrix(&m.d(), &self.model.d())
                || !same_matrix(&m.t(), &self.model.t())
            {
                bail!(
                    "score_grid: models must share one kernel, training sample, \
                     and kernel matrices"
                );
            }
        }
        let op = self.batch_op(pairs)?;
        let mut cols: Vec<&[f64]> = Vec::with_capacity(1 + extra.len());
        cols.push(&self.model.alpha);
        for m in extra {
            cols.push(&m.alpha);
        }
        let block = Mat::from_columns(&cols);
        Ok(self.with_warm_workspace(&op, |op| op.matmat(&block)))
    }

    /// Shared per-batch front half of [`Self::score`] / [`Self::score_grid`]:
    /// bump the counters and build the batch operator (in-domain fast
    /// path when every reference is a `Known` index).
    fn batch_op(&self, pairs: &[QueryPair]) -> Result<PairwiseLinOp> {
        self.stats.score_calls.fetch_add(1, Ordering::Relaxed);
        self.stats.pairs.fetch_add(pairs.len() as u64, Ordering::Relaxed);
        let all_known = pairs.iter().all(|p| {
            matches!(p.drug, ObjectRef::Known(_)) && matches!(p.target, ObjectRef::Known(_))
        });
        if all_known {
            self.in_domain_op(pairs)
        } else {
            self.extended_op(pairs)
        }
    }

    /// Thread the predictor's long-lived warm workspace through one
    /// per-batch operator for the duration of `f`.
    fn with_warm_workspace<T>(
        &self,
        op: &PairwiseLinOp,
        f: impl FnOnce(&PairwiseLinOp) -> T,
    ) -> T {
        op.install_workspace(std::mem::take(
            &mut *self.ws.lock().expect("serve workspace poisoned"),
        ));
        let out = f(op);
        *self.ws.lock().expect("serve workspace poisoned") = op.take_workspace();
        out
    }

    /// Per-batch operator for all-in-domain queries: a fresh row sample
    /// over the training domains, everything else reused from the
    /// template.
    fn in_domain_op(&self, pairs: &[QueryPair]) -> Result<PairwiseLinOp> {
        let (m, q) = (self.model.train_pairs().m(), self.model.train_pairs().q());
        let mut drugs = Vec::with_capacity(pairs.len());
        let mut targets = Vec::with_capacity(pairs.len());
        for p in pairs {
            let (ObjectRef::Known(d), ObjectRef::Known(t)) = (&p.drug, &p.target) else {
                bail!("in_domain_op called with a featured object");
            };
            if *d as usize >= m {
                bail!("drug index {d} outside the domain 0..{m}");
            }
            if *t as usize >= q {
                bail!("target index {t} outside the domain 0..{q}");
            }
            drugs.push(*d);
            targets.push(*t);
        }
        self.template.with_rows(PairIndex::new(drugs, targets, m, q))
    }

    /// Per-batch operator when some queries carry feature vectors:
    /// batch-local domains, one cross-kernel matrix row per distinct
    /// object (known objects copy their full-domain row; featured
    /// objects assemble `k(x, X_train)`, cached by id).
    fn extended_op(&self, pairs: &[QueryPair]) -> Result<PairwiseLinOp> {
        if self.model.kernel() == PairwiseKernel::Cartesian {
            // Cartesian couples objects through identity factors
            // (`k_D·δ(t=t̄) + δ(d=d̄)·k_T`); a δ against an object outside
            // the domain is identically zero, so featured queries are
            // not defined for it.
            bail!("the cartesian kernel does not support featured (out-of-domain) objects");
        }
        if self.model.kernel().supports_heterogeneous() {
            let mut db = SideBuilder::new(self.model.train_pairs().m());
            let mut tb = SideBuilder::new(self.model.train_pairs().q());
            let mut drugs = Vec::with_capacity(pairs.len());
            let mut targets = Vec::with_capacity(pairs.len());
            for p in pairs {
                drugs.push(db.resolve(self, Side::Drug, &p.drug)?);
                targets.push(tb.resolve(self, Side::Target, &p.target)?);
            }
            let dm = Arc::new(db.into_mat());
            let tm = Arc::new(tb.into_mat());
            let rows = PairIndex::new(drugs, targets, dm.rows(), tm.rows());
            self.template.reindexed(dm, tm, rows)
        } else {
            // Homogeneous kernel: one shared object domain for both slots.
            let mut b = SideBuilder::new(self.model.train_pairs().m());
            let mut drugs = Vec::with_capacity(pairs.len());
            let mut targets = Vec::with_capacity(pairs.len());
            for p in pairs {
                drugs.push(b.resolve(self, Side::Unified, &p.drug)?);
                targets.push(b.resolve(self, Side::Unified, &p.target)?);
            }
            let mat = Arc::new(b.into_mat());
            let rows = PairIndex::new(drugs, targets, mat.rows(), mat.rows());
            self.template.reindexed(mat.clone(), mat, rows)
        }
    }

    /// Full-domain kernel matrix for one side.
    fn side_matrix(&self, side: Side) -> Arc<Mat> {
        match side {
            Side::Target => self.model.t(),
            Side::Drug | Side::Unified => self.model.d(),
        }
    }

    /// Cross-kernel row for a featured object (cache-aware; a cached id
    /// is only trusted when its stored features match the query's).
    fn featured_row(
        &self,
        side: Side,
        id: &Option<String>,
        x: &[f64],
    ) -> Result<Arc<CachedRow>> {
        let fs = match side {
            Side::Drug => self.d_features.as_ref(),
            Side::Target => self.t_features.as_ref(),
            Side::Unified => self.d_features.as_ref().or(self.t_features.as_ref()),
        };
        let fs = fs.ok_or_else(|| {
            gvt_err!(
                "model artifact bundles no {} feature space; cannot score unseen objects",
                side.name()
            )
        })?;
        let cache = match side {
            Side::Target => &self.target_cache,
            Side::Drug | Side::Unified => &self.drug_cache,
        };
        if let Some(id) = id {
            if let Some(hit) = cache.lock().expect("serve cache poisoned").get(id) {
                if hit.x == x {
                    return Ok(hit.clone());
                }
                // Same id, different features: fall through and replace.
            }
        }
        let row = fs.cross_row(x).with_context(|| {
            format!("assembling the cross-kernel row of {} {:?}", side.name(), id)
        })?;
        let entry = Arc::new(CachedRow { x: x.to_vec(), row });
        if let Some(id) = id {
            cache
                .lock()
                .expect("serve cache poisoned")
                .insert(id.clone(), entry.clone());
        }
        Ok(entry)
    }

    /// The pinned concrete GVT factorization (see module docs).
    pub fn policy(&self) -> GvtPolicy {
        self.policy
    }

    /// The served model.
    pub fn model(&self) -> &RidgeModel {
        &self.model
    }

    /// The compiled template plan's structure summary.
    pub fn plan_summary(&self) -> String {
        self.template.plan_summary()
    }

    /// Serving counters (shared with the batcher).
    pub fn serve_stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Snapshot every counter, including the per-side cache counters.
    pub fn stats(&self) -> StatsSnapshot {
        let dc = self.drug_cache.lock().expect("serve cache poisoned");
        let tc = self.target_cache.lock().expect("serve cache poisoned");
        StatsSnapshot {
            score_calls: self.stats.score_calls.load(Ordering::Relaxed),
            pairs: self.stats.pairs.load(Ordering::Relaxed),
            batches: self.stats.batches.load(Ordering::Relaxed),
            requests: self.stats.requests.load(Ordering::Relaxed),
            batch_jobs_max: self.stats.batch_jobs_max.load(Ordering::Relaxed),
            batch_pairs_max: self.stats.batch_pairs_max.load(Ordering::Relaxed),
            drug_cache_hits: dc.hits(),
            drug_cache_misses: dc.misses(),
            drug_cache_evictions: dc.evictions(),
            drug_cache_len: dc.len(),
            target_cache_hits: tc.hits(),
            target_cache_misses: tc.misses(),
            target_cache_evictions: tc.evictions(),
            target_cache_len: tc.len(),
        }
    }

    /// Counters + configuration as a JSON object (the `stats` wire
    /// command).
    pub fn stats_json(&self) -> String {
        let s = self.stats();
        format!(
            "{{\"kernel\": \"{}\", \"policy\": \"{}\", \"train_pairs\": {}, \
             \"plan\": \"{}\", \"score_calls\": {}, \"pairs\": {}, \
             \"batches\": {}, \"requests\": {}, \"batch_jobs_max\": {}, \
             \"batch_pairs_max\": {}, \"drug_cache\": {{\"hits\": {}, \
             \"misses\": {}, \"evictions\": {}, \"len\": {}}}, \
             \"target_cache\": {{\"hits\": {}, \"misses\": {}, \
             \"evictions\": {}, \"len\": {}}}}}",
            self.model.kernel().name(),
            self.policy.name(),
            self.model.train_size(),
            self.plan_summary(),
            s.score_calls,
            s.pairs,
            s.batches,
            s.requests,
            s.batch_jobs_max,
            s.batch_pairs_max,
            s.drug_cache_hits,
            s.drug_cache_misses,
            s.drug_cache_evictions,
            s.drug_cache_len,
            s.target_cache_hits,
            s.target_cache_misses,
            s.target_cache_evictions,
            s.target_cache_len,
        )
    }

    /// [`Predictor::stats_json`] extended with the server's robustness
    /// counters under a `"robust"` key. The counters live on the
    /// [`crate::serve::reload::PredictorSlot`], not the predictor — they
    /// must survive hot-reloads — so the server passes a snapshot in.
    pub fn stats_json_with(&self, robust: &crate::serve::reload::RobustSnapshot) -> String {
        let mut out = self.stats_json();
        // stats_json always renders one JSON object; splice the robust
        // block in before its closing brace.
        out.pop();
        out.push_str(&format!(
            ", \"robust\": {{\"overload_rejected\": {}, \"deadline_expired\": {}, \
             \"reloads_ok\": {}, \"reloads_failed\": {}, \"drained_jobs\": {}, \
             \"connections_rejected\": {}, \"idle_reaped\": {}, \
             \"dispatcher_panics\": {}, \"active_connections\": {}}}}}",
            robust.overload_rejected,
            robust.deadline_expired,
            robust.reloads_ok,
            robust.reloads_failed,
            robust.drained_jobs,
            robust.connections_rejected,
            robust.idle_reaped,
            robust.dispatcher_panics,
            robust.active_connections,
        ));
        out
    }
}

/// A cached cross-kernel row, stored with the features that produced it:
/// an id is client-supplied and may be reused with different features
/// (stale client, colliding namespaces) — a hit only counts if the
/// features match, otherwise the row is recomputed and replaced.
struct CachedRow {
    x: Vec<f64>,
    row: Vec<f64>,
}

/// Accumulates one batch-local cross-kernel matrix: one row per distinct
/// object referenced on this side, deduped by training index or
/// client-supplied id (featured objects without an id always get a fresh
/// row).
struct SideBuilder {
    width: usize,
    flat: Vec<f64>,
    count: u32,
    // lint: allow(determinism, lookup-only dedup map — row order is
    // fixed by request arrival, never by map iteration)
    known: HashMap<u32, u32>,
    /// id → (row index, features): a repeated id only dedups when its
    /// features match (ids are client-supplied and may collide).
    // lint: allow(determinism, lookup-only dedup map, never iterated)
    by_id: HashMap<String, (u32, Vec<f64>)>,
}

impl SideBuilder {
    fn new(width: usize) -> SideBuilder {
        SideBuilder {
            width,
            flat: Vec::new(),
            count: 0,
            // lint: allow(determinism, lookup-only dedup maps)
            known: HashMap::new(),
            // lint: allow(determinism, lookup-only dedup maps)
            by_id: HashMap::new(),
        }
    }

    fn push_row(&mut self, row: &[f64]) -> u32 {
        debug_assert_eq!(row.len(), self.width);
        self.flat.extend_from_slice(row);
        self.count += 1;
        self.count - 1
    }

    fn resolve(
        &mut self,
        pred: &Predictor,
        side: Side,
        obj: &ObjectRef,
    ) -> Result<u32> {
        match obj {
            ObjectRef::Known(g) => {
                if let Some(&i) = self.known.get(g) {
                    return Ok(i);
                }
                let mat = pred.side_matrix(side);
                if *g as usize >= mat.rows() {
                    bail!(
                        "{} index {g} outside the domain 0..{}",
                        side.name(),
                        mat.rows()
                    );
                }
                let i = self.push_row(mat.row(*g as usize));
                self.known.insert(*g, i);
                Ok(i)
            }
            ObjectRef::Featured { id, x } => {
                if let Some(id) = id {
                    if let Some((i, feats)) = self.by_id.get(id) {
                        if feats == x {
                            return Ok(*i);
                        }
                    }
                }
                let row = pred.featured_row(side, id, x)?;
                let i = self.push_row(&row.row);
                if let Some(id) = id {
                    self.by_id.insert(id.clone(), (i, x.clone()));
                }
                Ok(i)
            }
        }
    }

    fn into_mat(self) -> Mat {
        Mat::from_vec(self.count as usize, self.width, self.flat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::PairDataset;
    use crate::gvt::pairwise::PairwiseKernel;
    use crate::kernels::{kernel_matrix, BaseKernel, KernelParams};
    use crate::rng::{dist, Xoshiro256};
    use crate::solvers::ridge::{PairwiseRidge, RidgeConfig};
    use crate::testing::gen;

    fn feature_dataset(seed: u64, m: usize, q: usize, p: usize) -> (PairDataset, Mat, Mat) {
        let mut rng = Xoshiro256::seed_from(seed);
        let xd = Mat::from_vec(m, p, dist::normal_vec(&mut rng, m * p));
        let xt = Mat::from_vec(q, p, dist::normal_vec(&mut rng, q * p));
        let params = KernelParams::default();
        let d = Arc::new(kernel_matrix(BaseKernel::Linear, &params, &xd));
        let t = Arc::new(kernel_matrix(BaseKernel::Linear, &params, &xt));
        let pairs = gen::pair_sample(&mut rng, 6 * m, m, q);
        let y = dist::normal_vec(&mut rng, 6 * m);
        (
            PairDataset { name: "serve-toy".into(), d, t, pairs, y, homogeneous: m == q },
            xd,
            xt,
        )
    }

    #[test]
    fn score_matches_ridge_predict_bitwise() {
        let (data, _, _) = feature_dataset(90, 8, 9, 5);
        let cfg = RidgeConfig { max_iters: 30, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        let mut rng = Xoshiro256::seed_from(91);
        let test = gen::pair_sample(&mut rng, 17, 8, 9);
        // Oracle with the predictor's pinned policy.
        let alpha = model.alpha.clone();
        let lambda = model.lambda;
        let pred = Predictor::new(model, None, None, ServeOptions::default()).unwrap();
        let oracle = RidgeModel::from_parts(
            PairwiseKernel::Kronecker,
            data.d.clone(),
            data.t.clone(),
            data.pairs.clone(),
            pred.policy(),
            alpha,
            lambda,
        )
        .unwrap();
        let expect = oracle.predict(&test).unwrap();
        let queries: Vec<QueryPair> = (0..test.len())
            .map(|i| QueryPair::known(test.drug(i) as u32, test.target(i) as u32))
            .collect();
        // Whole batch, then assorted sub-batches: all bit-identical.
        assert_eq!(pred.score(&queries).unwrap(), expect);
        let mut got = Vec::new();
        for chunk in queries.chunks(3) {
            got.extend(pred.score(chunk).unwrap());
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn featured_refs_of_domain_objects_match_known_refs() {
        let (data, xd, xt) = feature_dataset(92, 7, 6, 4);
        let cfg = RidgeConfig { max_iters: 25, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Poly2D, &cfg).unwrap();
        let params = KernelParams::default();
        let dfs = FeatureSpace { x: xd.clone(), kernel: BaseKernel::Linear, params };
        let tfs = FeatureSpace { x: xt.clone(), kernel: BaseKernel::Linear, params };
        let pred =
            Predictor::new(model, Some(dfs), Some(tfs), ServeOptions::default()).unwrap();
        let known: Vec<QueryPair> =
            (0..6usize).map(|i| QueryPair::known(i as u32, (i % 6) as u32)).collect();
        let featured: Vec<QueryPair> = (0..6usize)
            .map(|i| QueryPair {
                drug: ObjectRef::Featured {
                    id: Some(format!("d{i}")),
                    x: xd.row(i).to_vec(),
                },
                target: ObjectRef::Featured {
                    id: Some(format!("t{}", i % 6)),
                    x: xt.row(i % 6).to_vec(),
                },
            })
            .collect();
        // A featured object whose features equal a domain object's row
        // reproduces that object's cross-kernel row exactly (same base
        // kernel, same evaluation order) — scores are bit-identical.
        assert_eq!(pred.score(&known).unwrap(), pred.score(&featured).unwrap());
        // Second pass hits the id-keyed cache.
        let before = pred.stats();
        let _ = pred.score(&featured).unwrap();
        let after = pred.stats();
        assert!(after.drug_cache_hits > before.drug_cache_hits);
        assert_eq!(after.drug_cache_misses, before.drug_cache_misses);
    }

    #[test]
    fn homogeneous_kernels_serve_featured_objects() {
        let mut rng = Xoshiro256::seed_from(93);
        let (m, p) = (8, 4);
        let x = Mat::from_vec(m, p, dist::normal_vec(&mut rng, m * p));
        let params = KernelParams::default();
        let d = Arc::new(kernel_matrix(BaseKernel::Linear, &params, &x));
        let pairs = gen::homogeneous_sample(&mut rng, 40, m);
        let data = PairDataset {
            name: "homo".into(),
            d: d.clone(),
            t: d.clone(),
            pairs,
            y: dist::normal_vec(&mut rng, 40),
            homogeneous: true,
        };
        let cfg = RidgeConfig { max_iters: 25, ..Default::default() };
        for kernel in [PairwiseKernel::Symmetric, PairwiseKernel::Mlpk] {
            let model = PairwiseRidge::fit(&data, kernel, &cfg).unwrap();
            let fs = FeatureSpace { x: x.clone(), kernel: BaseKernel::Linear, params };
            let pred =
                Predictor::new(model, Some(fs), None, ServeOptions::default()).unwrap();
            let known: Vec<QueryPair> =
                (0..m).map(|i| QueryPair::known(i as u32, ((i + 1) % m) as u32)).collect();
            let featured: Vec<QueryPair> = (0..m)
                .map(|i| QueryPair {
                    drug: ObjectRef::Featured { id: None, x: x.row(i).to_vec() },
                    target: ObjectRef::Known(((i + 1) % m) as u32),
                })
                .collect();
            assert_eq!(
                pred.score(&known).unwrap(),
                pred.score(&featured).unwrap(),
                "{kernel:?}"
            );
        }
    }

    #[test]
    fn rejects_out_of_domain_indices_cleanly() {
        let (data, _, _) = feature_dataset(94, 5, 5, 3);
        let cfg = RidgeConfig { max_iters: 10, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        let pred = Predictor::new(model, None, None, ServeOptions::default()).unwrap();
        assert!(pred.score(&[QueryPair::known(5, 0)]).is_err());
        assert!(pred.score(&[QueryPair::known(0, 99)]).is_err());
        // Featured query without a feature space: clean error, no panic.
        let q = QueryPair {
            drug: ObjectRef::Featured { id: None, x: vec![0.0; 3] },
            target: ObjectRef::Known(0),
        };
        assert!(pred.score(&[q]).is_err());
    }

    /// A reused object id with *different* features must not be served
    /// from the cache (or deduped within a batch): ids are
    /// client-supplied and may collide or go stale.
    #[test]
    fn reused_id_with_new_features_is_not_served_stale() {
        let (data, xd, xt) = feature_dataset(98, 6, 6, 4);
        let cfg = RidgeConfig { max_iters: 20, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        let params = KernelParams::default();
        let dfs = FeatureSpace { x: xd.clone(), kernel: BaseKernel::Linear, params };
        let tfs = FeatureSpace { x: xt.clone(), kernel: BaseKernel::Linear, params };
        let pred =
            Predictor::new(model, Some(dfs), Some(tfs), ServeOptions::default()).unwrap();
        let query = |drug_obj: usize| {
            vec![QueryPair {
                drug: ObjectRef::Featured {
                    id: Some("shared-id".into()),
                    x: xd.row(drug_obj).to_vec(),
                },
                target: ObjectRef::Known(2),
            }]
        };
        let s0 = pred.score(&query(0)).unwrap();
        // Same id, object 1's features: must match Known(1), not s0.
        let s1 = pred.score(&query(1)).unwrap();
        let known1 = pred.score(&[QueryPair::known(1, 2)]).unwrap();
        assert_eq!(s1, known1, "stale cache row served for a reused id");
        assert_ne!(s0, s1);
        // Within ONE batch too: same id, different features → two rows.
        let mixed = vec![query(0).remove(0), query(1).remove(0)];
        let both = pred.score(&mixed).unwrap();
        assert_eq!(both[0], s0[0]);
        assert_eq!(both[1], s1[0]);
    }

    #[test]
    fn cartesian_rejects_featured_objects() {
        let (data, xd, _) = feature_dataset(97, 5, 5, 3);
        let cfg = RidgeConfig { max_iters: 10, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Cartesian, &cfg).unwrap();
        let params = KernelParams::default();
        let dfs = FeatureSpace { x: xd.clone(), kernel: BaseKernel::Linear, params };
        let pred =
            Predictor::new(model, Some(dfs), None, ServeOptions::default()).unwrap();
        // In-domain works…
        assert!(pred.score(&[QueryPair::known(0, 1)]).is_ok());
        // …featured is a clean error, not an assertion failure.
        let q = QueryPair {
            drug: ObjectRef::Featured { id: None, x: xd.row(0).to_vec() },
            target: ObjectRef::Known(0),
        };
        assert!(pred.score(&[q]).is_err());
    }

    #[test]
    fn score_grid_matches_predict_batch() {
        let (data, _, _) = feature_dataset(95, 6, 7, 4);
        let cfg = RidgeConfig { max_iters: 40, rel_tol: 1e-12, ..Default::default() };
        let lambdas = [0.1, 1.0, 5.0];
        let grid =
            PairwiseRidge::fit_lambda_grid(&data, PairwiseKernel::Kronecker, &cfg, &lambdas)
                .unwrap();
        let mut rng = Xoshiro256::seed_from(96);
        let test = gen::pair_sample(&mut rng, 11, 6, 7);
        let queries: Vec<QueryPair> = (0..test.len())
            .map(|i| QueryPair::known(test.drug(i) as u32, test.target(i) as u32))
            .collect();
        let mut it = grid.into_iter();
        let primary = it.next().unwrap();
        let extra: Vec<RidgeModel> = it.collect();
        let pred = Predictor::new(primary, None, None, ServeOptions::default()).unwrap();
        let block = pred.score_grid(&queries, &extra).unwrap();
        assert_eq!(block.shape(), (11, 3));
        // Column 0 is the primary model; agreement with the single-RHS
        // path is within multi-RHS reassociation tolerance.
        let single = pred.score(&queries).unwrap();
        for (i, s) in single.iter().enumerate() {
            assert!((block[(i, 0)] - s).abs() < 1e-10);
        }
    }
}
