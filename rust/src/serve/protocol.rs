//! Line-delimited JSON wire protocol (stdin/stdout and TCP share it).
//!
//! One request per line, one response line per request:
//!
//! ```text
//! → {"id": 7, "pairs": [[0, 3], [5, 1]]}
//! ← {"id": 7, "scores": [1.25000000000000000e0, -7.50000000000000000e-1]}
//!
//! → {"id": 8, "pairs": [{"drug": {"id": "CHEMBL25", "features": [0.1, 0.7]},
//!                        "target": 4}]}
//! ← {"id": 8, "scores": [3.10000000000000000e0]}
//!
//! → {"cmd": "stats"}
//! ← {"stats": {...}}
//!
//! → {"cmd": "metrics"}
//! ← {"metrics": {...}}
//!
//! → {"cmd": "reload", "path": "model_v2.txt"}
//! ← {"ok": true}
//!
//! → {"cmd": "shutdown"}
//! ← {"ok": true}
//! ```
//!
//! A pair is either `[drug, target]` (both in-domain indices) or an
//! object `{"drug": <ref>, "target": <ref>}` where each `<ref>` is an
//! in-domain index or `{"features": [...], "id": "..."}` (`id` optional
//! — it keys the server-side cross-kernel row cache). Malformed requests
//! produce `{"id": ..., "error": "..."}` and leave the connection open.
//!
//! Robustness surface (docs/PROTOCOL.md): a score request may carry
//! `"deadline_us": N` — if the dispatcher cannot score it within N µs of
//! enqueue it answers a deadline error instead. A server over its
//! admission budget answers
//! `{"id": ..., "error": "overloaded", "retry_after_us": N}`
//! ([`overloaded_response`]) — same in-band shape, plus a backoff hint.
//! `reload` swaps in a fresh model from a v2 artifact (`path` optional
//! when the server was started from a file); on failure the old model
//! keeps serving and the response is an in-band error.
//!
//! Scores are rendered with 17 significant digits (`{:.17e}`), the exact
//! `f64` round-trip format the offline `gvt-rls predict` output uses —
//! `scripts/verify.sh` diffs the two textually.

use crate::error::{bail, gvt_err, Context, Result};
use crate::runtime::json::Json;
use crate::serve::predictor::{ObjectRef, QueryPair};

/// A parsed request line.
pub enum Request {
    Score { id: Option<f64>, pairs: Vec<QueryPair>, deadline_us: Option<u64> },
    Stats { id: Option<f64> },
    Metrics { id: Option<f64> },
    Reload { id: Option<f64>, path: Option<String> },
    Shutdown { id: Option<f64> },
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let json = Json::parse(line).context("malformed JSON")?;
    // Reject non-numeric ids up front: silently dropping the id would
    // leave pipelined clients unable to correlate responses.
    let id = match json.get("id") {
        None => None,
        Some(j) => {
            Some(j.as_f64().ok_or_else(|| gvt_err!("'id' must be a number"))?)
        }
    };
    if let Some(cmd) = json.get("cmd") {
        return match cmd.as_str() {
            Some("stats") => Ok(Request::Stats { id }),
            Some("metrics") => Ok(Request::Metrics { id }),
            Some("reload") => {
                let path = match json.get("path") {
                    None => None,
                    Some(p) => Some(
                        p.as_str()
                            .ok_or_else(|| gvt_err!("'path' must be a string"))?
                            .to_string(),
                    ),
                };
                Ok(Request::Reload { id, path })
            }
            Some("shutdown") => Ok(Request::Shutdown { id }),
            Some(other) => bail!("unknown cmd {other:?}"),
            None => bail!("cmd must be a string"),
        };
    }
    let pairs_json = json
        .get("pairs")
        .and_then(Json::as_arr)
        .ok_or_else(|| gvt_err!("request needs a 'pairs' array or a 'cmd'"))?;
    let mut pairs = Vec::with_capacity(pairs_json.len());
    for (i, p) in pairs_json.iter().enumerate() {
        pairs.push(parse_pair(p).with_context(|| format!("pair {i}"))?);
    }
    let deadline_us = match json.get("deadline_us") {
        None => None,
        Some(j) => {
            let v = j
                .as_f64()
                .ok_or_else(|| gvt_err!("'deadline_us' must be a number"))?;
            if !(v >= 0.0) || v.fract() != 0.0 || v > 9.0e15 {
                bail!("'deadline_us' must be a non-negative integer, got {v}");
            }
            Some(v as u64)
        }
    };
    Ok(Request::Score { id, pairs, deadline_us })
}

fn parse_pair(j: &Json) -> Result<QueryPair> {
    if let Some(arr) = j.as_arr() {
        // Slice pattern instead of arr[0]/arr[1]: length check and
        // element access in one panic-free step.
        let [d, t] = arr else {
            bail!("pair array must be [drug, target]");
        };
        return Ok(QueryPair {
            drug: parse_ref(d, "drug")?,
            target: parse_ref(t, "target")?,
        });
    }
    if j.as_obj().is_some() {
        let d = j.get("drug").ok_or_else(|| gvt_err!("pair object needs 'drug'"))?;
        let t = j.get("target").ok_or_else(|| gvt_err!("pair object needs 'target'"))?;
        return Ok(QueryPair {
            drug: parse_ref(d, "drug")?,
            target: parse_ref(t, "target")?,
        });
    }
    bail!("pair must be [drug, target] or {{\"drug\": ..., \"target\": ...}}")
}

fn parse_ref(j: &Json, side: &str) -> Result<ObjectRef> {
    if let Some(n) = j.as_f64() {
        if n < 0.0 || n.fract() != 0.0 || n > u32::MAX as f64 {
            bail!("{side} index {n} is not a valid object index");
        }
        return Ok(ObjectRef::Known(n as u32));
    }
    if j.as_obj().is_some() {
        let feats = j
            .get("features")
            .and_then(Json::as_arr)
            .ok_or_else(|| gvt_err!("{side} object needs a 'features' array"))?;
        let mut x = Vec::with_capacity(feats.len());
        for f in feats {
            x.push(
                f.as_f64()
                    .ok_or_else(|| gvt_err!("{side} features must be numbers"))?,
            );
        }
        let id = j.get("id").and_then(Json::as_str).map(str::to_string);
        return Ok(ObjectRef::Featured { id, x });
    }
    bail!("{side} must be an index or {{\"features\": [...]}}")
}

/// `f64` → JSON number with exact round-trip precision (17 significant
/// digits). Non-finite values render as `null` — JSON has no NaN/Inf.
pub fn fmt_score(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.17e}")
    } else {
        "null".to_string()
    }
}

fn fmt_id(id: &Option<f64>) -> String {
    match id {
        None => String::new(),
        Some(v) if v.fract() == 0.0 && v.abs() < 9.0e15 => {
            format!("\"id\": {}, ", *v as i64)
        }
        Some(v) => format!("\"id\": {v}, "),
    }
}

/// Success response for a score request.
pub fn scores_response(id: &Option<f64>, scores: &[f64]) -> String {
    let mut out = String::with_capacity(32 + scores.len() * 26);
    out.push('{');
    out.push_str(&fmt_id(id));
    out.push_str("\"scores\": [");
    for (i, s) in scores.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&fmt_score(*s));
    }
    out.push_str("]}");
    out
}

/// Error response (any request kind).
pub fn error_response(id: &Option<f64>, msg: &str) -> String {
    format!("{{{}\"error\": \"{}\"}}", fmt_id(id), json_escape(msg))
}

/// Admission-control rejection: the standard error shape (`"error"` is
/// the literal string `overloaded`, so clients can match on it) plus a
/// machine-readable backoff hint in microseconds.
pub fn overloaded_response(id: &Option<f64>, retry_after_us: u64) -> String {
    format!(
        "{{{}\"error\": \"overloaded\", \"retry_after_us\": {retry_after_us}}}",
        fmt_id(id)
    )
}

/// Stats response wrapping a pre-rendered JSON object.
pub fn stats_response(id: &Option<f64>, stats_obj: &str) -> String {
    format!("{{{}\"stats\": {stats_obj}}}", fmt_id(id))
}

/// Metrics response wrapping a pre-rendered JSON object (counters plus
/// per-stage latency histograms — see docs/OBSERVABILITY.md).
pub fn metrics_response(id: &Option<f64>, metrics_obj: &str) -> String {
    format!("{{{}\"metrics\": {metrics_obj}}}", fmt_id(id))
}

/// Acknowledgement (shutdown).
pub fn ok_response(id: &Option<f64>) -> String {
    format!("{{{}\"ok\": true}}", fmt_id(id))
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_index_pairs() {
        let r = parse_request(r#"{"id": 3, "pairs": [[0, 2], [5, 1]]}"#).unwrap();
        let Request::Score { id, pairs, deadline_us } = r else {
            panic!("not a score request")
        };
        assert_eq!(id, Some(3.0));
        assert!(deadline_us.is_none());
        assert_eq!(pairs.len(), 2);
        assert!(matches!(pairs[0].drug, ObjectRef::Known(0)));
        assert!(matches!(pairs[1].target, ObjectRef::Known(1)));
    }

    #[test]
    fn parses_featured_refs() {
        let r = parse_request(
            r#"{"pairs": [{"drug": {"id": "x", "features": [0.5, -1.0]}, "target": 7}]}"#,
        )
        .unwrap();
        let Request::Score { id, pairs, .. } = r else { panic!("not a score request") };
        assert!(id.is_none());
        match &pairs[0].drug {
            ObjectRef::Featured { id, x } => {
                assert_eq!(id.as_deref(), Some("x"));
                assert_eq!(x, &vec![0.5, -1.0]);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(pairs[0].target, ObjectRef::Known(7)));
    }

    #[test]
    fn parses_commands() {
        assert!(matches!(
            parse_request(r#"{"cmd": "stats"}"#).unwrap(),
            Request::Stats { .. }
        ));
        assert!(matches!(
            parse_request(r#"{"cmd": "metrics", "id": 4}"#).unwrap(),
            Request::Metrics { id: Some(_) }
        ));
        assert!(matches!(
            parse_request(r#"{"cmd": "shutdown", "id": 9}"#).unwrap(),
            Request::Shutdown { id: Some(_) }
        ));
        let r = parse_request(r#"{"cmd": "reload", "path": "m.txt"}"#).unwrap();
        let Request::Reload { path, .. } = r else { panic!("not a reload") };
        assert_eq!(path.as_deref(), Some("m.txt"));
        assert!(matches!(
            parse_request(r#"{"cmd": "reload"}"#).unwrap(),
            Request::Reload { path: None, .. }
        ));
    }

    #[test]
    fn parses_request_deadlines() {
        let r = parse_request(r#"{"id": 1, "pairs": [[0, 0]], "deadline_us": 2500}"#)
            .unwrap();
        let Request::Score { deadline_us, .. } = r else { panic!("not a score request") };
        assert_eq!(deadline_us, Some(2500));
        // Malformed deadlines are rejected, not silently dropped.
        assert!(parse_request(r#"{"pairs": [[0, 0]], "deadline_us": -5}"#).is_err());
        assert!(parse_request(r#"{"pairs": [[0, 0]], "deadline_us": 0.5}"#).is_err());
        assert!(parse_request(r#"{"pairs": [[0, 0]], "deadline_us": "soon"}"#).is_err());
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"pairs": [[1]]}"#).is_err());
        assert!(parse_request(r#"{"pairs": [[-1, 0]]}"#).is_err());
        assert!(parse_request(r#"{"pairs": [[0.5, 0]]}"#).is_err());
        assert!(parse_request(r#"{"cmd": "reboot"}"#).is_err());
        assert!(parse_request(r#"{"hello": 1}"#).is_err());
        assert!(parse_request(r#"{"cmd": "reload", "path": 7}"#).is_err());
        // String ids are rejected, not silently dropped.
        assert!(parse_request(r#"{"id": "req-7", "pairs": [[0, 1]]}"#).is_err());
    }

    #[test]
    fn score_rendering_roundtrips_exactly() {
        let values = [1.25, -0.1, 3.14159265358979312e-7, f64::MIN_POSITIVE, 0.0];
        for v in values {
            let line = scores_response(&Some(1.0), &[v]);
            let parsed = Json::parse(&line).unwrap();
            let back = parsed.get("scores").unwrap().as_arr().unwrap()[0]
                .as_f64()
                .unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{v}");
        }
    }

    #[test]
    fn responses_are_valid_json() {
        for line in [
            scores_response(&None, &[1.0, 2.0]),
            scores_response(&Some(42.0), &[]),
            error_response(&Some(1.0), "bad \"thing\"\n"),
            ok_response(&None),
            stats_response(&None, "{\"x\": 1}"),
            metrics_response(&Some(2.0), "{\"enabled\": true, \"counters\": {}}"),
            overloaded_response(&Some(4.0), 1000),
        ] {
            assert!(Json::parse(&line).is_ok(), "{line}");
        }
        let line = overloaded_response(&Some(4.0), 1000);
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("error").unwrap().as_str().unwrap(), "overloaded");
        assert_eq!(parsed.get("retry_after_us").unwrap().as_f64().unwrap(), 1000.0);
    }
}
