//! Hot-reload and robustness accounting for the serve subsystem.
//!
//! [`PredictorSlot`] is the single seam through which the server and the
//! micro-batch dispatcher reach the [`Predictor`]: an `Arc<Predictor>`
//! behind an `RwLock`. [`PredictorSlot::reload_from_path`] builds a
//! fresh predictor from a v2 artifact with the *same* serving options
//! the slot was created with, and atomically swaps the `Arc` on success
//! — batches already holding the old `Arc` finish on the old model, the
//! next batch picks up the new one, and no connection is dropped. A
//! failed load (missing file, truncated artifact, validation failure)
//! leaves the old predictor serving untouched and reports the error
//! in-band.
//!
//! Bit-identity across a reload of the *same* artifact is inherited, not
//! re-proven: the predictor pins its GVT factorization from the artifact
//! alone ([`Predictor::from_file`]), so two predictors built from one
//! file score identically — `tests/serve_faults.rs` pins this under
//! concurrent load.
//!
//! [`RobustStats`] lives on the slot rather than the predictor exactly
//! because reloads replace the predictor: overload/deadline/drain
//! counters must survive a swap to stay meaningful across the server's
//! lifetime.

use crate::error::{Context, Result};
use crate::serve::predictor::{Predictor, ServeOptions};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Robustness counters, shared by the accept loop, the connection
/// handlers, and the dispatcher. All relaxed: they are monotonic tallies
/// (plus one gauge), never synchronization.
#[derive(Debug, Default)]
pub struct RobustStats {
    /// Score requests turned away by the in-flight pair budget.
    pub overload_rejected: AtomicU64,
    /// Jobs answered with a deadline error instead of being scored.
    pub deadline_expired: AtomicU64,
    /// Successful hot-reloads (the swap happened).
    pub reloads_ok: AtomicU64,
    /// Rejected hot-reloads (old model kept serving).
    pub reloads_failed: AtomicU64,
    /// Jobs answered during the shutdown drain phase.
    pub drained_jobs: AtomicU64,
    /// Connections turned away by the connection cap.
    pub connections_rejected: AtomicU64,
    /// Connections closed by the idle timeout.
    pub idle_reaped: AtomicU64,
    /// Scoring panics caught and answered in-band by the dispatcher.
    pub dispatcher_panics: AtomicU64,
    /// Gauge: connection handlers currently running.
    pub active_connections: AtomicU64,
}

/// Plain-number copy of [`RobustStats`] for rendering.
#[derive(Clone, Copy, Debug, Default)]
pub struct RobustSnapshot {
    pub overload_rejected: u64,
    pub deadline_expired: u64,
    pub reloads_ok: u64,
    pub reloads_failed: u64,
    pub drained_jobs: u64,
    pub connections_rejected: u64,
    pub idle_reaped: u64,
    pub dispatcher_panics: u64,
    pub active_connections: u64,
}

impl RobustStats {
    /// Relaxed snapshot of every counter.
    pub fn snapshot(&self) -> RobustSnapshot {
        RobustSnapshot {
            overload_rejected: self.overload_rejected.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            reloads_ok: self.reloads_ok.load(Ordering::Relaxed),
            reloads_failed: self.reloads_failed.load(Ordering::Relaxed),
            drained_jobs: self.drained_jobs.load(Ordering::Relaxed),
            connections_rejected: self.connections_rejected.load(Ordering::Relaxed),
            idle_reaped: self.idle_reaped.load(Ordering::Relaxed),
            dispatcher_panics: self.dispatcher_panics.load(Ordering::Relaxed),
            active_connections: self.active_connections.load(Ordering::Relaxed),
        }
    }

    /// Bump a counter by one (all tallies are relaxed).
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }
}

/// The hot-swappable predictor seam (see module docs).
pub struct PredictorSlot {
    current: RwLock<Arc<Predictor>>,
    opts: ServeOptions,
    draining: AtomicBool,
    /// Robustness counters; survive reloads (see module docs).
    pub robust: RobustStats,
}

impl PredictorSlot {
    /// Wrap `predictor` in a slot. `opts` is the serving configuration
    /// every future reload is validated/built against.
    pub fn new(predictor: Arc<Predictor>, opts: ServeOptions) -> Arc<PredictorSlot> {
        Arc::new(PredictorSlot {
            current: RwLock::new(predictor),
            opts,
            draining: AtomicBool::new(false),
            robust: RobustStats::default(),
        })
    }

    /// The predictor new batches should score on, as of this call.
    /// Callers hold the returned `Arc` for the duration of one batch, so
    /// an in-flight batch finishes on the model it started with even if
    /// a reload swaps the slot mid-batch.
    pub fn current(&self) -> Arc<Predictor> {
        // A poisoned lock only means a thread panicked while holding it;
        // the Arc inside is always a fully-built predictor.
        self.current.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Build a fresh predictor from the v2 artifact at `path` (with this
    /// slot's serving options) and swap it in. On failure the previous
    /// predictor keeps serving and the error describes why the reload
    /// was rejected. Counted either way in [`RobustStats`].
    pub fn reload_from_path(&self, path: &Path) -> Result<()> {
        let span = crate::obs::trace::begin();
        let out = match Predictor::from_file(path, self.opts) {
            Ok(fresh) => {
                let fresh = Arc::new(fresh);
                *self.current.write().unwrap_or_else(|e| e.into_inner()) = fresh;
                RobustStats::bump(&self.robust.reloads_ok);
                Ok(())
            }
            Err(e) => {
                RobustStats::bump(&self.robust.reloads_failed);
                Err(e).with_context(|| {
                    format!("reload rejected ({}); previous model still serving", path.display())
                })
            }
        };
        crate::obs::trace::end("serve.reload", "serve", span);
        out
    }

    /// Enter the shutdown drain phase: jobs the dispatcher answers from
    /// here on count as drained stragglers.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::Release);
    }

    /// Whether the server is draining toward shutdown.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::PairDataset;
    use crate::gvt::pairwise::PairwiseKernel;
    use crate::rng::{dist, Xoshiro256};
    use crate::solvers::persist::{save_model_v2, EmbedV2};
    use crate::solvers::ridge::{PairwiseRidge, RidgeConfig};
    use crate::testing::gen;
    use std::sync::Arc;

    fn toy_slot(seed: u64, tag: &str) -> (Arc<PredictorSlot>, std::path::PathBuf) {
        let mut rng = Xoshiro256::seed_from(seed);
        let d = Arc::new(gen::psd_kernel(&mut rng, 6));
        let t = Arc::new(gen::psd_kernel(&mut rng, 7));
        let pairs = gen::pair_sample(&mut rng, 30, 6, 7);
        let y = dist::normal_vec(&mut rng, 30);
        let data = PairDataset { name: "reload".into(), d, t, pairs, y, homogeneous: false };
        let cfg = RidgeConfig { max_iters: 15, ..Default::default() };
        let model = PairwiseRidge::fit_fixed_iters(&data, PairwiseKernel::Kronecker, &cfg, 15)
            .unwrap();
        let path = std::env::temp_dir()
            .join(format!("gvt_reload_{tag}_{}.txt", std::process::id()));
        save_model_v2(&model, &path, &EmbedV2 { matrices: true, ..Default::default() }).unwrap();
        let pred =
            Arc::new(Predictor::from_file(&path, ServeOptions::default()).unwrap());
        (PredictorSlot::new(pred, ServeOptions::default()), path)
    }

    #[test]
    fn reload_same_artifact_swaps_and_scores_identically() {
        let (slot, path) = toy_slot(41, "swap");
        let q = [crate::serve::QueryPair::known(2, 3), crate::serve::QueryPair::known(5, 1)];
        let before_arc = slot.current();
        let before = before_arc.score(&q).unwrap();
        slot.reload_from_path(&path).unwrap();
        let after_arc = slot.current();
        assert!(!Arc::ptr_eq(&before_arc, &after_arc), "reload must swap the Arc");
        let after = after_arc.score(&q).unwrap();
        for (a, b) in before.iter().zip(&after) {
            assert_eq!(a.to_bits(), b.to_bits(), "same artifact must score bit-identically");
        }
        assert_eq!(slot.robust.snapshot().reloads_ok, 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn failed_reload_keeps_old_model_serving() {
        let (slot, path) = toy_slot(42, "fail");
        let q = [crate::serve::QueryPair::known(1, 1)];
        let before = slot.current().score(&q).unwrap();
        let missing = std::env::temp_dir().join("gvt_reload_no_such_artifact.txt");
        let err = slot.reload_from_path(&missing).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("reload rejected"), "{msg}");
        let after = slot.current().score(&q).unwrap();
        assert_eq!(before.first().map(|v| v.to_bits()), after.first().map(|v| v.to_bits()));
        let snap = slot.robust.snapshot();
        assert_eq!(snap.reloads_failed, 1);
        assert_eq!(snap.reloads_ok, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn drain_flag_is_sticky() {
        let (slot, path) = toy_slot(43, "drain");
        assert!(!slot.is_draining());
        slot.begin_drain();
        assert!(slot.is_draining());
        let _ = std::fs::remove_file(&path);
    }
}
