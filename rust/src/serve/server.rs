//! Transport layer: serve the wire protocol over stdin/stdout or TCP.
//!
//! Both transports share one request loop: read a line, parse it
//! ([`crate::serve::protocol`]), hand score requests to the
//! [`Batcher`] (blocking until the coalesced pass completes), write one
//! response line. Concurrency — and therefore micro-batching — comes
//! from multiple TCP connections: each connection gets its own handler
//! thread, so requests from different clients land on the dispatcher
//! queue together and ride one GVT pass.
//!
//! # Robustness contract
//!
//! Every failure the server can survive is answered **in-band** — one
//! JSON error line on the connection that caused it — and never takes
//! the process or a healthy connection down (`tests/serve_faults.rs`
//! exercises each path by injecting the fault):
//!
//! * **Connection cap** ([`ServeConfig::max_connections`]): excess
//!   connections get one `overloaded` error line and are closed; the
//!   accept loop keeps serving everyone else.
//! * **Idle reaping** ([`ServeConfig::idle_timeout`]): a connection that
//!   completes no request line within the window is answered and closed
//!   on a poll tick. Partial lines do *not* reset the clock, so a
//!   slow-loris drip of bytes cannot hold a handler forever; healthy
//!   connections completing requests are never touched.
//! * **Hot reload** (`{"cmd": "reload"}` or, with
//!   [`ServeConfig::reload_stdin`], a `reload [path]` line on the
//!   server's stdin): builds a fresh predictor from a v2 artifact and
//!   swaps it behind the [`PredictorSlot`] seam without dropping any
//!   connection — in-flight batches finish on the old model. A failed
//!   load answers an error and leaves the old model serving.
//! * **Graceful drain**: `{"cmd": "shutdown"}` stops admission, then the
//!   server answers stragglers, flushes the dispatcher queue, and joins
//!   — all bounded by [`ServeConfig::drain_timeout`], past which
//!   handlers and dispatcher are abandoned rather than hanging shutdown.

use crate::error::{gvt_err, Context, GvtError, Result};
use crate::obs::{clock, metrics};
use crate::runtime::fault;
use crate::serve::batcher::{Batcher, BatcherHandle, ScoreFailure};
use crate::serve::predictor::Predictor;
use crate::serve::protocol::{self, Request};
use crate::serve::reload::PredictorSlot;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Hard cap on one request line's byte length (features arrays are the
/// only large payload; 8 MiB ≈ 400k f64 literals, far beyond any real
/// feature dimension). Longer lines answer an in-band error and close.
const MAX_REQUEST_LINE: usize = 8 * 1024 * 1024;

/// Serving configuration: batching plus the robustness knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Dispatcher tuning (including the in-flight admission budget and
    /// the default request deadline).
    pub batch: crate::serve::batcher::BatchConfig,
    /// Maximum simultaneously-open connections (`0` = unbounded). Excess
    /// connections are answered with one in-band `overloaded` error line
    /// and closed.
    pub max_connections: usize,
    /// Close a connection that completes no request within this window
    /// (`Duration::ZERO` = never). Partial lines do not count as
    /// activity.
    pub idle_timeout: Duration,
    /// Hard stop for the shutdown drain phase: how long to wait for
    /// handlers to answer stragglers and the dispatcher to flush before
    /// abandoning them.
    pub drain_timeout: Duration,
    /// Default artifact for `{"cmd": "reload"}` requests that carry no
    /// `path` (the artifact the server was started from).
    pub model_path: Option<PathBuf>,
    /// Serving options reload builds fresh predictors with (match what
    /// the initial predictor was built with).
    pub serve_opts: crate::serve::predictor::ServeOptions,
    /// Also accept `reload [path]` lines on the server's *stdin* (the
    /// CLI-trigger channel for TCP serving, where stdin is otherwise
    /// unused). Off by default: a backgrounded process reading its
    /// terminal would be stopped by the shell.
    pub reload_stdin: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            batch: crate::serve::batcher::BatchConfig::default(),
            max_connections: 0,
            idle_timeout: Duration::ZERO,
            drain_timeout: Duration::from_millis(2000),
            model_path: None,
            serve_opts: crate::serve::predictor::ServeOptions::default(),
            reload_stdin: false,
        }
    }
}

/// What one bounded line read produced.
enum LineRead {
    /// A complete newline-terminated line is in the buffer.
    Line,
    /// The stream ended (a final unterminated line may be in the buffer).
    Eof,
    /// The cap was hit mid-line; the connection cannot resync.
    TooLong,
}

/// Append one line into `buf`, capped at [`MAX_REQUEST_LINE`] **inside**
/// the read (`read_until` alone would not return while a newline-less
/// stream keeps delivering bytes, so an after-the-fact length check
/// could never fire). Bytes are accumulated raw — a timeout error from
/// the underlying reader leaves any partial line (even one splitting a
/// multi-byte UTF-8 character) in `buf` for the next call; validation
/// to UTF-8 happens only once a full line has arrived.
fn read_bounded_line<R: BufRead>(
    reader: &mut R,
    buf: &mut Vec<u8>,
) -> std::io::Result<LineRead> {
    // One byte of headroom so a capped read is distinguishable from EOF.
    let limit = (MAX_REQUEST_LINE + 1 - buf.len()) as u64;
    match (&mut *reader).take(limit).read_until(b'\n', buf) {
        Ok(0) => Ok(LineRead::Eof),
        Ok(_) if buf.len() > MAX_REQUEST_LINE => Ok(LineRead::TooLong),
        Ok(_) if buf.last() != Some(&b'\n') => Ok(LineRead::Eof),
        Ok(_) => Ok(LineRead::Line),
        Err(e) => Err(e),
    }
}

/// Outcome of handling one request line.
enum LineOutcome {
    Respond(String),
    ShutdownAfter(String),
}

fn handle_line(
    line: &str,
    handle: &BatcherHandle,
    slot: &PredictorSlot,
    model_path: Option<&Path>,
) -> LineOutcome {
    match protocol::parse_request(line) {
        Ok(Request::Score { id, pairs, deadline_us }) => {
            match handle.submit(pairs, deadline_us) {
                Ok(scores) => {
                    let t_render = metrics::begin_us();
                    let resp = protocol::scores_response(&id, &scores);
                    metrics::RENDER.record_since(t_render);
                    LineOutcome::Respond(resp)
                }
                Err(ScoreFailure::Overloaded { retry_after_us }) => {
                    LineOutcome::Respond(protocol::overloaded_response(&id, retry_after_us))
                }
                Err(ScoreFailure::Failed(msg)) => {
                    LineOutcome::Respond(protocol::error_response(&id, &msg))
                }
            }
        }
        Ok(Request::Stats { id }) => {
            // The predictor renders its own counters (it is clock-free
            // by the determinism contract); the per-stage latency block
            // is spliced in here, at the transport layer that owns the
            // telemetry.
            let mut json = slot.current().stats_json_with(&slot.robust.snapshot());
            json.pop();
            json.push_str(", \"latency\": ");
            json.push_str(&metrics::latency_json());
            json.push('}');
            LineOutcome::Respond(protocol::stats_response(&id, &json))
        }
        Ok(Request::Metrics { id }) => {
            LineOutcome::Respond(protocol::metrics_response(&id, &metrics::metrics_json()))
        }
        Ok(Request::Reload { id, path }) => {
            let target = path.map(PathBuf::from).or_else(|| model_path.map(Path::to_path_buf));
            match target {
                None => LineOutcome::Respond(protocol::error_response(
                    &id,
                    "reload needs a 'path' (the server was not started from an artifact)",
                )),
                // The fresh predictor is built here, on this connection's
                // handler thread — the dispatcher and every other
                // connection keep serving the old model until the swap.
                Some(p) => match slot.reload_from_path(&p) {
                    Ok(()) => LineOutcome::Respond(protocol::ok_response(&id)),
                    Err(e) => {
                        LineOutcome::Respond(protocol::error_response(&id, &format!("{e:#}")))
                    }
                },
            }
        }
        Ok(Request::Shutdown { id }) => {
            LineOutcome::ShutdownAfter(protocol::ok_response(&id))
        }
        Err(e) => {
            LineOutcome::Respond(protocol::error_response(&None, &format!("{e:#}")))
        }
    }
}

/// Serve the protocol over stdin/stdout until EOF or `shutdown`.
/// Single-client by construction; the batcher still mediates so the
/// code path matches TCP serving exactly.
pub fn serve_stdio(predictor: Arc<Predictor>, cfg: ServeConfig) -> Result<()> {
    let slot = PredictorSlot::new(predictor, cfg.serve_opts);
    let batcher = Batcher::start_with_slot(slot.clone(), cfg.batch);
    let handle = batcher.handle();
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut input = stdin.lock();
    let mut out = stdout.lock();
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let status = read_bounded_line(&mut input, &mut buf).context("reading stdin")?;
        if matches!(status, LineRead::TooLong) {
            let resp = protocol::error_response(&None, "request line too long");
            writeln!(out, "{resp}")?;
            out.flush()?;
            break;
        }
        let mut done = matches!(status, LineRead::Eof);
        if !buf.is_empty() {
            let outcome = match std::str::from_utf8(&buf) {
                Ok(text) if text.trim().is_empty() => None,
                Ok(text) => {
                    Some(handle_line(text.trim(), &handle, &slot, cfg.model_path.as_deref()))
                }
                Err(_) => Some(LineOutcome::Respond(protocol::error_response(
                    &None,
                    "request line is not valid UTF-8",
                ))),
            };
            buf.clear();
            match outcome {
                None => {}
                Some(LineOutcome::Respond(resp)) => {
                    let t_write = metrics::begin_us();
                    writeln!(out, "{resp}")?;
                    out.flush()?;
                    metrics::WRITE.record_since(t_write);
                }
                Some(LineOutcome::ShutdownAfter(resp)) => {
                    writeln!(out, "{resp}")?;
                    out.flush()?;
                    done = true;
                }
            }
        }
        if done {
            break;
        }
    }
    slot.begin_drain();
    drop(handle);
    batcher.shutdown_within(cfg.drain_timeout);
    Ok(())
}

/// Bind `listen` (use port 0 for an ephemeral port), announce
/// `gvt-rls-serve listening on <addr>` on stdout (scripts parse this
/// line), and run the accept loop until a client sends `shutdown`.
pub fn serve_tcp(predictor: Arc<Predictor>, listen: &str, cfg: ServeConfig) -> Result<()> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
    let addr = listener.local_addr().context("reading bound address")?;
    println!("gvt-rls-serve listening on {addr}");
    std::io::stdout().flush().ok();
    serve_on(listener, predictor, cfg)
}

/// RAII increment of the active-connections gauge: constructed by the
/// accept loop (so the connection cap sees admitted-but-not-yet-running
/// handlers), decremented when the handler — or a failed spawn — drops
/// it.
struct ConnGauge(Arc<PredictorSlot>);

impl ConnGauge {
    fn new(slot: Arc<PredictorSlot>) -> ConnGauge {
        slot.robust.active_connections.fetch_add(1, Ordering::Relaxed);
        ConnGauge(slot)
    }
}

impl Drop for ConnGauge {
    fn drop(&mut self) {
        self.0.robust.active_connections.fetch_sub(1, Ordering::Relaxed);
    }
}

/// The accept loop over an already-bound listener (tests bind their own
/// so they know the port). Blocks until shutdown, then drains: stops
/// admitting, lets handlers answer stragglers, flushes the dispatcher
/// queue — all within [`ServeConfig::drain_timeout`], after which
/// whatever is still stuck is abandoned so shutdown cannot hang.
pub fn serve_on(
    listener: TcpListener,
    predictor: Arc<Predictor>,
    cfg: ServeConfig,
) -> Result<()> {
    let addr = listener.local_addr().context("reading bound address")?;
    // The shutdown self-poke must target a *connectable* address: for a
    // wildcard bind (0.0.0.0 / [::]) the local address is unspecified
    // and connecting to it is platform-dependent — use the loopback of
    // the same family instead.
    let poke_addr = {
        let mut a = addr;
        if a.ip().is_unspecified() {
            a.set_ip(match a.ip() {
                std::net::IpAddr::V4(_) => {
                    std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                }
                std::net::IpAddr::V6(_) => {
                    std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                }
            });
        }
        a
    };
    let slot = PredictorSlot::new(predictor, cfg.serve_opts);
    let batcher = Batcher::start_with_slot(slot.clone(), cfg.batch);
    if cfg.reload_stdin {
        spawn_stdin_reload_watcher(slot.clone(), cfg.model_path.clone());
    }
    let stop = Arc::new(AtomicBool::new(false));
    let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut spawn_err: Option<GvtError> = None;
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let mut stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        // Reap finished connection handlers so a long-lived server's
        // handle list doesn't grow with every connection ever accepted.
        handlers.retain(|h| !h.is_finished());
        // Connection cap: answer in-band and close instead of queueing
        // an unbounded number of handler threads.
        if cfg.max_connections > 0
            && slot.robust.active_connections.load(Ordering::Relaxed) as usize
                >= cfg.max_connections
        {
            crate::serve::reload::RobustStats::bump(&slot.robust.connections_rejected);
            let resp = protocol::error_response(
                &None,
                "overloaded: connection limit reached; retry later",
            );
            let _ = writeln!(stream, "{resp}").and_then(|_| stream.flush());
            continue;
        }
        let gauge = ConnGauge::new(slot.clone());
        let handle = batcher.handle();
        let conn_slot = slot.clone();
        let stop_flag = stop.clone();
        let conn_cfg = ConnConfig {
            idle_timeout: cfg.idle_timeout,
            model_path: cfg.model_path.clone(),
        };
        match std::thread::Builder::new().name("gvt-serve-conn".into()).spawn(move || {
            let _gauge = gauge;
            handle_connection(stream, handle, conn_slot, conn_cfg, stop_flag, poke_addr)
        }) {
            Ok(h) => handlers.push(h),
            Err(e) => {
                // Tear down in order: raise the stop flag FIRST so live
                // handlers exit on their next poll tick and release
                // their batcher handles — returning the error directly
                // would hang in Batcher::drop waiting on them.
                stop.store(true, Ordering::SeqCst);
                spawn_err = Some(gvt_err!("spawning connection handler: {e}"));
                break;
            }
        }
    }
    stop.store(true, Ordering::SeqCst);
    // Drain phase: no new admissions (the loop above has exited), jobs
    // answered from here on are counted as drained stragglers, and
    // everything is bounded by the drain timeout.
    slot.begin_drain();
    let drain_deadline = clock::now() + cfg.drain_timeout;
    for h in handlers {
        let joined = loop {
            if h.is_finished() {
                break true;
            }
            if clock::now() >= drain_deadline {
                break false;
            }
            std::thread::sleep(Duration::from_millis(5));
        };
        if joined {
            let _ = h.join();
        } else {
            // Past the hard stop: abandon the handler (its gauge entry
            // dies with the process) rather than hanging shutdown.
            drop(h);
        }
    }
    let left = drain_deadline
        .saturating_duration_since(clock::now())
        .max(Duration::from_millis(50));
    batcher.shutdown_within(left);
    match spawn_err {
        None => Ok(()),
        Some(e) => Err(e),
    }
}

/// The per-connection slice of [`ServeConfig`].
struct ConnConfig {
    idle_timeout: Duration,
    model_path: Option<PathBuf>,
}

/// Watch the server's own stdin for `reload [path]` lines — the CLI
/// trigger for operators driving a TCP server from a terminal or a
/// pipe (`--reload-stdin`). Acknowledgements go to stdout, matching the
/// `listening on` announcement scripts already parse. The thread is
/// detached: it parks on stdin for the process lifetime.
fn spawn_stdin_reload_watcher(slot: Arc<PredictorSlot>, default_path: Option<PathBuf>) {
    let _ = std::thread::Builder::new().name("gvt-serve-reload".into()).spawn(move || {
        let stdin = std::io::stdin();
        let mut line = String::new();
        loop {
            line.clear();
            match stdin.lock().read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            let cmd = line.trim();
            let Some(rest) = cmd.strip_prefix("reload") else {
                continue;
            };
            let arg = rest.trim();
            let target = if arg.is_empty() {
                default_path.clone()
            } else {
                Some(PathBuf::from(arg))
            };
            match target {
                None => println!("gvt-rls-serve reload error: no artifact path"),
                Some(p) => match slot.reload_from_path(&p) {
                    Ok(()) => println!("gvt-rls-serve reload ok: {}", p.display()),
                    Err(e) => println!("gvt-rls-serve reload error: {e:#}"),
                },
            }
            std::io::stdout().flush().ok();
        }
    });
}

fn handle_connection(
    stream: TcpStream,
    handle: BatcherHandle,
    slot: Arc<PredictorSlot>,
    cfg: ConnConfig,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
) {
    // Poll with a read timeout instead of blocking forever: serve_on
    // joins every handler at shutdown, and an idle connection parked in
    // a blocking read would hang the whole server. On each timeout tick
    // the handler re-checks the stop flag (and the idle clock) and exits
    // if another client shut the server down.
    //
    // Lines are accumulated as BYTES (`read_until`), not via
    // `read_line`: on an error `read_line` truncates any partial
    // not-yet-valid-UTF-8 tail off its buffer, so a timeout landing
    // inside a multi-byte character would silently drop the bytes read
    // so far. `read_until` keeps them; UTF-8 is validated only once a
    // full line has arrived.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    // The idle clock resets only when a request line COMPLETES — a
    // slow-loris connection dripping partial bytes still counts as idle
    // and is reaped.
    let mut last_done = clock::now();
    loop {
        // Injection point for connection-level faults: a `stall` holds
        // this read loop (exercising idle/health isolation between
        // connections); `error`/`truncate` force-close in-band.
        if fault::trip("conn_read").is_some() {
            let resp = protocol::error_response(&None, "injected fault: conn_read");
            let _ = writeln!(writer, "{resp}").and_then(|_| writer.flush());
            break;
        }
        let status = match read_bounded_line(&mut reader, &mut buf) {
            Ok(s) => s,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                // Timeout tick; partial bytes stay in `buf`.
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                if cfg.idle_timeout > Duration::ZERO
                    && last_done.elapsed() >= cfg.idle_timeout
                {
                    crate::serve::reload::RobustStats::bump(&slot.robust.idle_reaped);
                    let resp = protocol::error_response(
                        &None,
                        "idle timeout: no complete request within the window",
                    );
                    let _ = writeln!(writer, "{resp}").and_then(|_| writer.flush());
                    break;
                }
                continue;
            }
            Err(_) => break,
        };
        if matches!(status, LineRead::TooLong) {
            // Cap hit mid-line: no way to resync, answer in-band and
            // drop the connection.
            let resp = protocol::error_response(&None, "request line too long");
            let _ = writeln!(writer, "{resp}").and_then(|_| writer.flush());
            break;
        }
        let eof = matches!(status, LineRead::Eof);
        if !buf.is_empty() {
            let outcome = match std::str::from_utf8(&buf) {
                Ok(text) if text.trim().is_empty() => None,
                Ok(text) => Some(handle_line(
                    text.trim(),
                    &handle,
                    &slot,
                    cfg.model_path.as_deref(),
                )),
                Err(_) => Some(LineOutcome::Respond(protocol::error_response(
                    &None,
                    "request line is not valid UTF-8",
                ))),
            };
            buf.clear();
            last_done = clock::now();
            match outcome {
                None => {}
                Some(LineOutcome::Respond(resp)) => {
                    let t_write = metrics::begin_us();
                    let wrote = writeln!(writer, "{resp}").and_then(|_| writer.flush());
                    metrics::WRITE.record_since(t_write);
                    if wrote.is_err() {
                        break;
                    }
                }
                Some(LineOutcome::ShutdownAfter(resp)) => {
                    let _ = writeln!(writer, "{resp}").and_then(|_| writer.flush());
                    stop.store(true, Ordering::SeqCst);
                    // Poke the accept loop so it observes the flag.
                    let _ = TcpStream::connect(addr);
                    break;
                }
            }
        }
        if eof {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::PairDataset;
    use crate::gvt::pairwise::PairwiseKernel;
    use crate::rng::{dist, Xoshiro256};
    use crate::runtime::json::Json;
    use crate::serve::batcher::BatchConfig;
    use crate::serve::predictor::{QueryPair, ServeOptions};
    use crate::serve::protocol::fmt_score;
    use crate::solvers::ridge::{PairwiseRidge, RidgeConfig};
    use crate::testing::gen;
    use std::time::Duration;

    fn toy_predictor(seed: u64) -> Arc<Predictor> {
        let mut rng = Xoshiro256::seed_from(seed);
        let d = Arc::new(gen::psd_kernel(&mut rng, 5));
        let t = Arc::new(gen::psd_kernel(&mut rng, 6));
        let pairs = gen::pair_sample(&mut rng, 25, 5, 6);
        let data = PairDataset {
            name: "server-toy".into(),
            d,
            t,
            pairs,
            y: dist::normal_vec(&mut rng, 25),
            homogeneous: false,
        };
        let cfg = RidgeConfig { max_iters: 15, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        Arc::new(Predictor::new(model, None, None, ServeOptions::default()).unwrap())
    }

    fn quick_cfg() -> ServeConfig {
        ServeConfig {
            batch: BatchConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(200),
                ..BatchConfig::default()
            },
            ..ServeConfig::default()
        }
    }

    fn request_line(stream: &mut TcpStream, line: &str) -> String {
        writeln!(stream, "{line}").unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp.trim_end().to_string()
    }

    /// Full TCP round trip on an ephemeral port: responses textually
    /// match direct scoring, stats and malformed lines answer in-band,
    /// and `shutdown` terminates the accept loop cleanly.
    #[test]
    fn tcp_round_trip_and_shutdown() {
        let predictor = toy_predictor(120);
        let expect = predictor.score(&[QueryPair::known(1, 2)]).unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let pred = predictor.clone();
        let server = std::thread::spawn(move || {
            serve_on(listener, pred, quick_cfg()).unwrap();
        });

        let mut conn = TcpStream::connect(addr).unwrap();
        let resp = request_line(&mut conn, r#"{"id": 1, "pairs": [[1, 2]]}"#);
        assert_eq!(
            resp,
            format!("{{\"id\": 1, \"scores\": [{}]}}", fmt_score(expect[0]))
        );
        // Malformed request: in-band error, connection stays usable.
        let resp = request_line(&mut conn, "garbage");
        assert!(resp.contains("\"error\""), "{resp}");
        let resp = request_line(&mut conn, r#"{"id": 2, "pairs": [[1, 2]]}"#);
        assert!(resp.contains("\"scores\""), "{resp}");
        // Stats come back as JSON with our counters, including the
        // robustness block.
        let resp = request_line(&mut conn, r#"{"cmd": "stats"}"#);
        let parsed = Json::parse(&resp).unwrap();
        let stats = parsed.get("stats").unwrap();
        assert!(stats.get("pairs").unwrap().as_f64().unwrap() >= 2.0);
        assert_eq!(
            stats.get("policy").unwrap().as_str().unwrap(),
            predictor.policy().name()
        );
        let robust = stats.get("robust").unwrap();
        for key in [
            "overload_rejected",
            "deadline_expired",
            "reloads_ok",
            "reloads_failed",
            "drained_jobs",
            "connections_rejected",
            "idle_reaped",
            "dispatcher_panics",
        ] {
            assert_eq!(
                robust.get(key).unwrap().as_f64().unwrap(),
                0.0,
                "untripped counter {key} must render as 0"
            );
        }
        assert!(
            robust.get("active_connections").unwrap().as_f64().unwrap() >= 1.0,
            "this very connection must be on the gauge"
        );
        // A second concurrent connection works.
        let mut conn2 = TcpStream::connect(addr).unwrap();
        let resp = request_line(&mut conn2, r#"{"id": 7, "pairs": [[0, 0], [4, 5]]}"#);
        assert!(resp.starts_with("{\"id\": 7, \"scores\": ["), "{resp}");
        // Shutdown while conn2 is STILL OPEN and idle: its handler must
        // notice the stop flag on a poll tick, so the server exits
        // without waiting for every client to hang up.
        let resp = request_line(&mut conn, r#"{"cmd": "shutdown"}"#);
        assert_eq!(resp, "{\"ok\": true}");
        drop(conn);
        server.join().unwrap();
        drop(conn2);
    }

    /// Hostile input on ONE persistent connection: raw non-UTF-8 bytes,
    /// a deeply-nested JSON bomb (would overflow the handler stack
    /// without the parser's depth bound — an abort, not an error), and
    /// an unknown command each answer an in-band error; the same
    /// connection then scores a valid request, proving no handler
    /// thread died along the way, and shutdown still joins cleanly.
    #[test]
    fn hostile_lines_answer_in_band_and_server_survives() {
        let predictor = toy_predictor(121);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let pred = predictor.clone();
        let server = std::thread::spawn(move || {
            serve_on(listener, pred, quick_cfg()).unwrap();
        });

        fn next_line(reader: &mut BufReader<TcpStream>) -> String {
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            resp.trim_end().to_string()
        }

        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());

        // Bytes that are not valid UTF-8 in any decoding.
        conn.write_all(&[0xff, 0xfe, 0x80, b'\n']).unwrap();
        conn.flush().unwrap();
        let resp = next_line(&mut reader);
        assert!(resp.contains("\"error\""), "{resp}");
        assert!(resp.contains("UTF-8"), "{resp}");

        // A nesting bomb well under the 8 MiB line cap: recursive
        // descent must refuse it, not recurse 60k frames deep.
        let mut bomb = String::from("{\"pairs\": ");
        bomb.push_str(&"[".repeat(60_000));
        bomb.push('\n');
        conn.write_all(bomb.as_bytes()).unwrap();
        conn.flush().unwrap();
        let resp = next_line(&mut reader);
        assert!(resp.contains("\"error\""), "{resp}");
        assert!(resp.contains("nesting"), "{resp}");

        // Unknown command.
        conn.write_all(b"{\"cmd\": \"frobnicate\"}\n").unwrap();
        conn.flush().unwrap();
        let resp = next_line(&mut reader);
        assert!(resp.contains("\"error\""), "{resp}");

        // The same connection still scores.
        conn.write_all(b"{\"id\": 3, \"pairs\": [[1, 2]]}\n").unwrap();
        conn.flush().unwrap();
        let resp = next_line(&mut reader);
        assert!(resp.contains("\"scores\""), "{resp}");

        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        conn.flush().unwrap();
        let resp = next_line(&mut reader);
        assert_eq!(resp, "{\"ok\": true}");
        drop(conn);
        server.join().unwrap();
    }

    /// Telemetry pins: arming metrics mid-stream leaves score responses
    /// byte-identical (telemetry observes, never perturbs), the
    /// per-stage latency histograms grow monotonically across a burst,
    /// `stats` gains a `"latency"` block, and `{"cmd": "metrics"}`
    /// answers with counters plus full bucketed histograms.
    #[test]
    fn telemetry_is_invisible_to_scores_and_counts_stages() {
        use crate::obs::metrics;
        // ENABLED is process-global; serialize with the obs unit tests
        // and leave it disarmed on exit.
        let _serial = crate::obs::test_serial();
        metrics::set_enabled(false);

        let predictor = toy_predictor(122);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let pred = predictor.clone();
        let server = std::thread::spawn(move || {
            serve_on(listener, pred, quick_cfg()).unwrap();
        });

        let mut conn = TcpStream::connect(addr).unwrap();
        let burst: Vec<String> = (0..8)
            .map(|i| format!("{{\"id\": {i}, \"pairs\": [[1, 2], [3, 4]]}}"))
            .collect();

        // Disarmed burst: the baseline responses.
        let off: Vec<String> =
            burst.iter().map(|l| request_line(&mut conn, l)).collect();

        // Armed burst of the SAME requests: responses must match byte
        // for byte, and every serve stage must tally the traffic.
        metrics::set_enabled(true);
        let queue0 = metrics::QUEUE_WAIT.snapshot().count;
        let gvt0 = metrics::GVT_PASS.snapshot().count;
        let write0 = metrics::WRITE.snapshot().count;
        let scored0 = metrics::JOBS_SCORED.get();
        let on: Vec<String> =
            burst.iter().map(|l| request_line(&mut conn, l)).collect();
        assert_eq!(off, on, "telemetry must not change responses");

        // Monotone growth, `>=` because the registry is process-global
        // and other tests' serve traffic may land concurrently.
        assert!(metrics::QUEUE_WAIT.snapshot().count >= queue0 + 8);
        assert!(metrics::GVT_PASS.snapshot().count >= gvt0 + 1);
        assert!(metrics::WRITE.snapshot().count >= write0 + 8);
        assert!(metrics::JOBS_SCORED.get() >= scored0 + 8);

        // `stats` now carries the latency block with every stage.
        let resp = request_line(&mut conn, r#"{"cmd": "stats"}"#);
        let parsed = Json::parse(&resp).unwrap();
        let stats = parsed.get("stats").unwrap();
        let latency = stats.get("latency").unwrap();
        for h in metrics::SERVE_STAGES {
            assert!(latency.get(h.name()).is_some(), "missing stage {}", h.name());
        }
        assert!(
            latency.get("queue_wait_us").unwrap().get("count").unwrap().as_f64().unwrap()
                >= 8.0
        );
        // The evictions satellite: cache blocks render the counter.
        assert!(
            stats.get("drug_cache").unwrap().get("evictions").is_some(),
            "{resp}"
        );

        // The dedicated metrics command: counters + bucketed histograms.
        let resp = request_line(&mut conn, r#"{"cmd": "metrics", "id": 5}"#);
        let parsed = Json::parse(&resp).unwrap();
        assert_eq!(parsed.get("id").unwrap().as_f64().unwrap(), 5.0);
        let m = parsed.get("metrics").unwrap();
        assert!(matches!(m.get("enabled"), Some(Json::Bool(true))), "{resp}");
        assert!(m.get("counters").unwrap().get("jobs_scored").is_some());
        let gvt = m.get("latency").unwrap().get("gvt_pass_us").unwrap();
        assert!(gvt.get("buckets").unwrap().as_arr().unwrap().len() >= 1, "{resp}");

        let resp = request_line(&mut conn, r#"{"cmd": "shutdown"}"#);
        assert_eq!(resp, "{\"ok\": true}");
        drop(conn);
        server.join().unwrap();
        metrics::set_enabled(false);
    }
}
