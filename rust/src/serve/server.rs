//! Transport layer: serve the wire protocol over stdin/stdout or TCP.
//!
//! Both transports share one request loop: read a line, parse it
//! ([`crate::serve::protocol`]), hand score requests to the
//! [`Batcher`] (blocking until the coalesced pass completes), write one
//! response line. Concurrency — and therefore micro-batching — comes
//! from multiple TCP connections: each connection gets its own handler
//! thread, so requests from different clients land on the dispatcher
//! queue together and ride one GVT pass.
//!
//! Shutdown: any client may send `{"cmd": "shutdown"}`. The handler
//! acknowledges, raises the stop flag, and pokes the listener with a
//! throwaway connection so the accept loop observes the flag; the server
//! then joins its handler threads and drains the batcher.

use crate::error::{gvt_err, Context, GvtError, Result};
use crate::serve::batcher::{BatchConfig, Batcher, BatcherHandle};
use crate::serve::predictor::Predictor;
use crate::serve::protocol::{self, Request};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Hard cap on one request line's byte length (features arrays are the
/// only large payload; 8 MiB ≈ 400k f64 literals, far beyond any real
/// feature dimension). Longer lines answer an in-band error and close.
const MAX_REQUEST_LINE: usize = 8 * 1024 * 1024;

/// What one bounded line read produced.
enum LineRead {
    /// A complete newline-terminated line is in the buffer.
    Line,
    /// The stream ended (a final unterminated line may be in the buffer).
    Eof,
    /// The cap was hit mid-line; the connection cannot resync.
    TooLong,
}

/// Append one line into `buf`, capped at [`MAX_REQUEST_LINE`] **inside**
/// the read (`read_until` alone would not return while a newline-less
/// stream keeps delivering bytes, so an after-the-fact length check
/// could never fire). Bytes are accumulated raw — a timeout error from
/// the underlying reader leaves any partial line (even one splitting a
/// multi-byte UTF-8 character) in `buf` for the next call; validation
/// to UTF-8 happens only once a full line has arrived.
fn read_bounded_line<R: BufRead>(
    reader: &mut R,
    buf: &mut Vec<u8>,
) -> std::io::Result<LineRead> {
    // One byte of headroom so a capped read is distinguishable from EOF.
    let limit = (MAX_REQUEST_LINE + 1 - buf.len()) as u64;
    match (&mut *reader).take(limit).read_until(b'\n', buf) {
        Ok(0) => Ok(LineRead::Eof),
        Ok(_) if buf.len() > MAX_REQUEST_LINE => Ok(LineRead::TooLong),
        Ok(_) if buf.last() != Some(&b'\n') => Ok(LineRead::Eof),
        Ok(_) => Ok(LineRead::Line),
        Err(e) => Err(e),
    }
}

/// Outcome of handling one request line.
enum LineOutcome {
    Respond(String),
    ShutdownAfter(String),
}

fn handle_line(
    line: &str,
    handle: &BatcherHandle,
    predictor: &Predictor,
) -> LineOutcome {
    match protocol::parse_request(line) {
        Ok(Request::Score { id, pairs }) => match handle.score(pairs) {
            Ok(scores) => LineOutcome::Respond(protocol::scores_response(&id, &scores)),
            Err(e) => {
                LineOutcome::Respond(protocol::error_response(&id, &format!("{e:#}")))
            }
        },
        Ok(Request::Stats { id }) => {
            LineOutcome::Respond(protocol::stats_response(&id, &predictor.stats_json()))
        }
        Ok(Request::Shutdown { id }) => {
            LineOutcome::ShutdownAfter(protocol::ok_response(&id))
        }
        Err(e) => {
            LineOutcome::Respond(protocol::error_response(&None, &format!("{e:#}")))
        }
    }
}

/// Serve the protocol over stdin/stdout until EOF or `shutdown`.
/// Single-client by construction; the batcher still mediates so the
/// code path matches TCP serving exactly.
pub fn serve_stdio(predictor: Arc<Predictor>, cfg: BatchConfig) -> Result<()> {
    let batcher = Batcher::start(predictor.clone(), cfg);
    let handle = batcher.handle();
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut input = stdin.lock();
    let mut out = stdout.lock();
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let status = read_bounded_line(&mut input, &mut buf).context("reading stdin")?;
        if matches!(status, LineRead::TooLong) {
            let resp = protocol::error_response(&None, "request line too long");
            writeln!(out, "{resp}")?;
            out.flush()?;
            break;
        }
        let mut done = matches!(status, LineRead::Eof);
        if !buf.is_empty() {
            let outcome = match std::str::from_utf8(&buf) {
                Ok(text) if text.trim().is_empty() => None,
                Ok(text) => Some(handle_line(text.trim(), &handle, &predictor)),
                Err(_) => Some(LineOutcome::Respond(protocol::error_response(
                    &None,
                    "request line is not valid UTF-8",
                ))),
            };
            buf.clear();
            match outcome {
                None => {}
                Some(LineOutcome::Respond(resp)) => {
                    writeln!(out, "{resp}")?;
                    out.flush()?;
                }
                Some(LineOutcome::ShutdownAfter(resp)) => {
                    writeln!(out, "{resp}")?;
                    out.flush()?;
                    done = true;
                }
            }
        }
        if done {
            break;
        }
    }
    drop(handle);
    batcher.shutdown();
    Ok(())
}

/// Bind `listen` (use port 0 for an ephemeral port), announce
/// `gvt-rls-serve listening on <addr>` on stdout (scripts parse this
/// line), and run the accept loop until a client sends `shutdown`.
pub fn serve_tcp(predictor: Arc<Predictor>, listen: &str, cfg: BatchConfig) -> Result<()> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
    let addr = listener.local_addr().context("reading bound address")?;
    println!("gvt-rls-serve listening on {addr}");
    std::io::stdout().flush().ok();
    serve_on(listener, predictor, cfg)
}

/// The accept loop over an already-bound listener (tests bind their own
/// so they know the port). Blocks until shutdown; joins every
/// connection handler and drains the batcher before returning.
pub fn serve_on(
    listener: TcpListener,
    predictor: Arc<Predictor>,
    cfg: BatchConfig,
) -> Result<()> {
    let addr = listener.local_addr().context("reading bound address")?;
    // The shutdown self-poke must target a *connectable* address: for a
    // wildcard bind (0.0.0.0 / [::]) the local address is unspecified
    // and connecting to it is platform-dependent — use the loopback of
    // the same family instead.
    let poke_addr = {
        let mut a = addr;
        if a.ip().is_unspecified() {
            a.set_ip(match a.ip() {
                std::net::IpAddr::V4(_) => {
                    std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                }
                std::net::IpAddr::V6(_) => {
                    std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                }
            });
        }
        a
    };
    let batcher = Batcher::start(predictor.clone(), cfg);
    let stop = Arc::new(AtomicBool::new(false));
    let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut spawn_err: Option<GvtError> = None;
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        // Reap finished connection handlers so a long-lived server's
        // handle list doesn't grow with every connection ever accepted.
        handlers.retain(|h| !h.is_finished());
        let handle = batcher.handle();
        let pred = predictor.clone();
        let stop_flag = stop.clone();
        match std::thread::Builder::new().name("gvt-serve-conn".into()).spawn(move || {
            handle_connection(stream, handle, pred, stop_flag, poke_addr)
        }) {
            Ok(h) => handlers.push(h),
            Err(e) => {
                // Tear down in order: raise the stop flag FIRST so live
                // handlers exit on their next poll tick and release
                // their batcher handles — returning the error directly
                // would hang in Batcher::drop waiting on them.
                stop.store(true, Ordering::SeqCst);
                spawn_err = Some(gvt_err!("spawning connection handler: {e}"));
                break;
            }
        }
    }
    stop.store(true, Ordering::SeqCst);
    for h in handlers {
        let _ = h.join();
    }
    batcher.shutdown();
    match spawn_err {
        None => Ok(()),
        Some(e) => Err(e),
    }
}

fn handle_connection(
    stream: TcpStream,
    handle: BatcherHandle,
    predictor: Arc<Predictor>,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
) {
    // Poll with a read timeout instead of blocking forever: serve_on
    // joins every handler at shutdown, and an idle connection parked in
    // a blocking read would hang the whole server. On each timeout tick
    // the handler re-checks the stop flag and exits if another client
    // shut the server down.
    //
    // Lines are accumulated as BYTES (`read_until`), not via
    // `read_line`: on an error `read_line` truncates any partial
    // not-yet-valid-UTF-8 tail off its buffer, so a timeout landing
    // inside a multi-byte character would silently drop the bytes read
    // so far. `read_until` keeps them; UTF-8 is validated only once a
    // full line has arrived.
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(200)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let status = match read_bounded_line(&mut reader, &mut buf) {
            Ok(s) => s,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                // Timeout tick; partial bytes stay in `buf`.
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(_) => break,
        };
        if matches!(status, LineRead::TooLong) {
            // Cap hit mid-line: no way to resync, answer in-band and
            // drop the connection.
            let resp = protocol::error_response(&None, "request line too long");
            let _ = writeln!(writer, "{resp}").and_then(|_| writer.flush());
            break;
        }
        let eof = matches!(status, LineRead::Eof);
        if !buf.is_empty() {
            let outcome = match std::str::from_utf8(&buf) {
                Ok(text) if text.trim().is_empty() => None,
                Ok(text) => Some(handle_line(text.trim(), &handle, &predictor)),
                Err(_) => Some(LineOutcome::Respond(protocol::error_response(
                    &None,
                    "request line is not valid UTF-8",
                ))),
            };
            buf.clear();
            match outcome {
                None => {}
                Some(LineOutcome::Respond(resp)) => {
                    if writeln!(writer, "{resp}").and_then(|_| writer.flush()).is_err() {
                        break;
                    }
                }
                Some(LineOutcome::ShutdownAfter(resp)) => {
                    let _ = writeln!(writer, "{resp}").and_then(|_| writer.flush());
                    stop.store(true, Ordering::SeqCst);
                    // Poke the accept loop so it observes the flag.
                    let _ = TcpStream::connect(addr);
                    break;
                }
            }
        }
        if eof {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::PairDataset;
    use crate::gvt::pairwise::PairwiseKernel;
    use crate::rng::{dist, Xoshiro256};
    use crate::runtime::json::Json;
    use crate::serve::predictor::{QueryPair, ServeOptions};
    use crate::serve::protocol::fmt_score;
    use crate::solvers::ridge::{PairwiseRidge, RidgeConfig};
    use crate::testing::gen;
    use std::time::Duration;

    fn toy_predictor(seed: u64) -> Arc<Predictor> {
        let mut rng = Xoshiro256::seed_from(seed);
        let d = Arc::new(gen::psd_kernel(&mut rng, 5));
        let t = Arc::new(gen::psd_kernel(&mut rng, 6));
        let pairs = gen::pair_sample(&mut rng, 25, 5, 6);
        let data = PairDataset {
            name: "server-toy".into(),
            d,
            t,
            pairs,
            y: dist::normal_vec(&mut rng, 25),
            homogeneous: false,
        };
        let cfg = RidgeConfig { max_iters: 15, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        Arc::new(Predictor::new(model, None, None, ServeOptions::default()).unwrap())
    }

    fn request_line(stream: &mut TcpStream, line: &str) -> String {
        writeln!(stream, "{line}").unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp.trim_end().to_string()
    }

    /// Full TCP round trip on an ephemeral port: responses textually
    /// match direct scoring, stats and malformed lines answer in-band,
    /// and `shutdown` terminates the accept loop cleanly.
    #[test]
    fn tcp_round_trip_and_shutdown() {
        let predictor = toy_predictor(120);
        let expect = predictor.score(&[QueryPair::known(1, 2)]).unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let pred = predictor.clone();
        let server = std::thread::spawn(move || {
            serve_on(
                listener,
                pred,
                BatchConfig { max_batch: 16, max_wait: Duration::from_micros(200) },
            )
            .unwrap();
        });

        let mut conn = TcpStream::connect(addr).unwrap();
        let resp = request_line(&mut conn, r#"{"id": 1, "pairs": [[1, 2]]}"#);
        assert_eq!(
            resp,
            format!("{{\"id\": 1, \"scores\": [{}]}}", fmt_score(expect[0]))
        );
        // Malformed request: in-band error, connection stays usable.
        let resp = request_line(&mut conn, "garbage");
        assert!(resp.contains("\"error\""), "{resp}");
        let resp = request_line(&mut conn, r#"{"id": 2, "pairs": [[1, 2]]}"#);
        assert!(resp.contains("\"scores\""), "{resp}");
        // Stats come back as JSON with our counters.
        let resp = request_line(&mut conn, r#"{"cmd": "stats"}"#);
        let parsed = Json::parse(&resp).unwrap();
        let stats = parsed.get("stats").unwrap();
        assert!(stats.get("pairs").unwrap().as_f64().unwrap() >= 2.0);
        assert_eq!(
            stats.get("policy").unwrap().as_str().unwrap(),
            predictor.policy().name()
        );
        // A second concurrent connection works.
        let mut conn2 = TcpStream::connect(addr).unwrap();
        let resp = request_line(&mut conn2, r#"{"id": 7, "pairs": [[0, 0], [4, 5]]}"#);
        assert!(resp.starts_with("{\"id\": 7, \"scores\": ["), "{resp}");
        // Shutdown while conn2 is STILL OPEN and idle: its handler must
        // notice the stop flag on a poll tick, so the server exits
        // without waiting for every client to hang up.
        let resp = request_line(&mut conn, r#"{"cmd": "shutdown"}"#);
        assert_eq!(resp, "{\"ok\": true}");
        drop(conn);
        server.join().unwrap();
        drop(conn2);
    }

    /// Hostile input on ONE persistent connection: raw non-UTF-8 bytes,
    /// a deeply-nested JSON bomb (would overflow the handler stack
    /// without the parser's depth bound — an abort, not an error), and
    /// an unknown command each answer an in-band error; the same
    /// connection then scores a valid request, proving no handler
    /// thread died along the way, and shutdown still joins cleanly.
    #[test]
    fn hostile_lines_answer_in_band_and_server_survives() {
        let predictor = toy_predictor(121);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let pred = predictor.clone();
        let server = std::thread::spawn(move || {
            serve_on(
                listener,
                pred,
                BatchConfig { max_batch: 16, max_wait: Duration::from_micros(200) },
            )
            .unwrap();
        });

        fn next_line(reader: &mut BufReader<TcpStream>) -> String {
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            resp.trim_end().to_string()
        }

        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());

        // Bytes that are not valid UTF-8 in any decoding.
        conn.write_all(&[0xff, 0xfe, 0x80, b'\n']).unwrap();
        conn.flush().unwrap();
        let resp = next_line(&mut reader);
        assert!(resp.contains("\"error\""), "{resp}");
        assert!(resp.contains("UTF-8"), "{resp}");

        // A nesting bomb well under the 8 MiB line cap: recursive
        // descent must refuse it, not recurse 60k frames deep.
        let mut bomb = String::from("{\"pairs\": ");
        bomb.push_str(&"[".repeat(60_000));
        bomb.push('\n');
        conn.write_all(bomb.as_bytes()).unwrap();
        conn.flush().unwrap();
        let resp = next_line(&mut reader);
        assert!(resp.contains("\"error\""), "{resp}");
        assert!(resp.contains("nesting"), "{resp}");

        // Unknown command.
        conn.write_all(b"{\"cmd\": \"frobnicate\"}\n").unwrap();
        conn.flush().unwrap();
        let resp = next_line(&mut reader);
        assert!(resp.contains("\"error\""), "{resp}");

        // The same connection still scores.
        conn.write_all(b"{\"id\": 3, \"pairs\": [[1, 2]]}\n").unwrap();
        conn.flush().unwrap();
        let resp = next_line(&mut reader);
        assert!(resp.contains("\"scores\""), "{resp}");

        conn.write_all(b"{\"cmd\": \"shutdown\"}\n").unwrap();
        conn.flush().unwrap();
        let resp = next_line(&mut reader);
        assert_eq!(resp, "{\"ok\": true}");
        drop(conn);
        server.join().unwrap();
    }
}
