//! Conjugate gradient for symmetric positive-definite systems, with an
//! optional preconditioner. Used by the Nyström/Falkon baseline (§6.5,
//! "Falkon solves the resulting linear system using a preconditioned
//! conjugate gradient optimizer") and as a cross-check on MINRES.

use crate::error::{bail, Result};
use crate::linalg::vecops::{axpby_par, axpy_norm2, axpy_par, dot, norm2};
use crate::solvers::linear_op::LinOp;
use std::ops::ControlFlow;

/// Options for [`cg`].
#[derive(Clone, Debug)]
pub struct CgOptions {
    pub max_iters: usize,
    pub rel_tol: f64,
}

impl Default for CgOptions {
    fn default() -> Self {
        Self { max_iters: 1000, rel_tol: 1e-8 }
    }
}

/// Result of a CG run.
#[derive(Clone, Debug)]
pub struct CgOutcome {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub rel_residual: f64,
    pub converged: bool,
}

/// Solve `A x = b` (SPD `A`). `precond`, if given, applies `M⁻¹` (also
/// SPD). `callback(iter, x, relres)` can stop early.
///
/// Fails loudly — mirroring the SGD trainer's divergence contract — if
/// the recurrence produces a non-finite step or residual mid-iteration
/// (an operator or preconditioner emitting NaN/Inf): the error names the
/// iteration instead of letting garbage propagate into α.
pub fn cg<F>(
    a: &dyn LinOp,
    b: &[f64],
    precond: Option<&dyn LinOp>,
    opts: &CgOptions,
    mut callback: F,
) -> Result<CgOutcome>
where
    F: FnMut(usize, &[f64], f64) -> ControlFlow<()>,
{
    let n = b.len();
    assert_eq!(a.dim_in(), n);
    assert_eq!(a.dim_out(), n);
    let bnorm = norm2(b);
    if !bnorm.is_finite() {
        bail!("cg: right-hand side has non-finite entries (|b| = {bnorm:e})");
    }
    if bnorm == 0.0 {
        return Ok(CgOutcome {
            x: vec![0.0; n],
            iterations: 0,
            rel_residual: 0.0,
            converged: true,
        });
    }

    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut z = match precond {
        Some(m) => m.apply(&r),
        None => r.clone(),
    };
    let mut p = z.clone();
    let mut ap = vec![0.0; n];
    let mut rz = dot(&r, &z);

    let mut iterations = 0;
    let mut rel = 1.0;
    let mut converged = false;

    // lint: alloc_free — every per-iteration buffer is sized above; the
    // loop body must stay heap-silent (tests/alloc_free.rs measures it).
    for k in 1..=opts.max_iters {
        a.apply_into(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            // Not SPD (or numerically singular): stop with current iterate.
            break;
        }
        let alpha = rz / pap;
        if !alpha.is_finite() {
            bail!(
                "cg diverged: non-finite step α = {alpha:e} at iteration {k} \
                 (the operator or preconditioner produced non-finite values)"
            );
        }
        axpy_par(alpha, &p, &mut x);
        // Residual update and its norm in one pass over memory. Stays
        // serial: the fused norm is a reduction, and a parallel combine
        // order would break bit-determinism across worker counts.
        let rnorm = axpy_norm2(-alpha, &ap, &mut r);
        if !rnorm.is_finite() {
            bail!(
                "cg diverged: non-finite residual |r| = {rnorm:e} at iteration {k} \
                 (the operator or preconditioner produced non-finite values)"
            );
        }
        iterations = k;
        rel = rnorm / bnorm;
        // Values only — wall-time is stamped by the obs layer, never here.
        crate::obs::iter::record(k, rel);
        if let ControlFlow::Break(()) = callback(k, &x, rel) {
            break;
        }
        if rel <= opts.rel_tol {
            converged = true;
            break;
        }
        match precond {
            Some(m) => m.apply_into(&r, &mut z),
            None => z.copy_from_slice(&r),
        }
        let rz_next = dot(&r, &z);
        let beta = rz_next / rz;
        rz = rz_next;
        // p = z + beta p.
        axpby_par(1.0, &z, beta, &mut p);
    }

    Ok(CgOutcome { x, iterations, rel_residual: rel, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::Cholesky;
    use crate::rng::{dist, Xoshiro256};
    use crate::solvers::linear_op::DenseOp;
    use crate::testing::gen;

    fn no_cb(_: usize, _: &[f64], _: f64) -> ControlFlow<()> {
        ControlFlow::Continue(())
    }

    #[test]
    fn matches_cholesky() {
        let mut rng = Xoshiro256::seed_from(70);
        let mut a = gen::psd_kernel(&mut rng, 20);
        for i in 0..20 {
            a[(i, i)] += 0.5;
        }
        let b = dist::normal_vec(&mut rng, 20);
        let oracle = Cholesky::factor(&a).unwrap().solve(&b);
        let out = cg(
            &DenseOp::new(a),
            &b,
            None,
            &CgOptions { max_iters: 400, rel_tol: 1e-12 },
            no_cb,
        )
        .unwrap();
        assert!(out.converged);
        for (x, o) in out.x.iter().zip(&oracle) {
            assert!((x - o).abs() < 1e-6);
        }
    }

    #[test]
    fn preconditioner_reduces_iterations() {
        // Ill-conditioned diagonal system: Jacobi preconditioner should
        // solve it in O(1) iterations vs many for plain CG.
        let n = 50;
        let mut a = crate::linalg::Mat::zeros(n, n);
        for i in 0..n {
            a[(i, i)] = 1.0 + (i as f64) * 100.0;
        }
        let binv = {
            let mut m = crate::linalg::Mat::zeros(n, n);
            for i in 0..n {
                m[(i, i)] = 1.0 / a[(i, i)];
            }
            DenseOp::new(m)
        };
        let b = vec![1.0; n];
        let plain = cg(
            &DenseOp::new(a.clone()),
            &b,
            None,
            &CgOptions { max_iters: 1000, rel_tol: 1e-10 },
            no_cb,
        )
        .unwrap();
        let pre = cg(
            &DenseOp::new(a),
            &b,
            Some(&binv),
            &CgOptions { max_iters: 1000, rel_tol: 1e-10 },
            no_cb,
        )
        .unwrap();
        assert!(pre.converged);
        assert!(
            pre.iterations < plain.iterations,
            "precond {} vs plain {}",
            pre.iterations,
            plain.iterations
        );
    }

    #[test]
    fn non_finite_operator_fails_loudly() {
        // An operator emitting NaN must produce a structured error that
        // names the iteration — never a silent garbage solution
        // (mirrors the SGD trainer's divergent_lr_fails_loudly contract).
        let mut a = crate::linalg::Mat::eye(6);
        a[(2, 2)] = f64::NAN;
        let b = vec![1.0; 6];
        let err = cg(&DenseOp::new(a), &b, None, &CgOptions::default(), no_cb)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("diverged"), "{msg}");
        assert!(msg.contains("iteration 1"), "{msg}");
    }
}
