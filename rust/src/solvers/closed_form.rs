//! Closed-form `O(n³)` ridge solution via Cholesky on the explicit kernel
//! matrix. The small-problem oracle used by tests and by the standard
//! (non-GVT) baseline when users want exact solves.

use crate::data::PairDataset;
use crate::error::{Context, Result};
use crate::gvt::explicit::explicit_matrix;
use crate::gvt::pairwise::PairwiseKernel;
use crate::linalg::chol::solve_regularized;
use crate::sparse::PairIndex;

/// Exact ridge model: `a = (K + λI)⁻¹ y` with explicit `K`.
pub struct ClosedFormModel {
    kernel: PairwiseKernel,
    d: std::sync::Arc<crate::linalg::Mat>,
    t: std::sync::Arc<crate::linalg::Mat>,
    train_pairs: PairIndex,
    pub alpha: Vec<f64>,
}

impl ClosedFormModel {
    /// Fit by dense factorization. `O(n²)` memory, `O(n³)` time — use for
    /// n up to a few thousand only.
    pub fn fit(data: &PairDataset, kernel: PairwiseKernel, lambda: f64) -> Result<Self> {
        let k = explicit_matrix(kernel, &data.d, &data.t, &data.pairs, &data.pairs);
        let alpha = solve_regularized(&k, lambda, &data.y)
            .context("closed-form ridge: Cholesky failed (kernel not PD enough)")?;
        Ok(Self {
            kernel,
            d: data.d.clone(),
            t: data.t.clone(),
            train_pairs: data.pairs.clone(),
            alpha,
        })
    }

    /// Predict via the explicit cross kernel matrix.
    pub fn predict(&self, pairs: &PairIndex) -> Vec<f64> {
        let kx = explicit_matrix(self.kernel, &self.d, &self.t, pairs, &self.train_pairs);
        kx.matvec(&self.alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{dist, Xoshiro256};
    use crate::testing::gen;
    use std::sync::Arc;

    #[test]
    fn interpolates_training_data_with_tiny_lambda() {
        let mut rng = Xoshiro256::seed_from(110);
        let m = 6;
        let d = Arc::new(gen::psd_kernel(&mut rng, m));
        let t = Arc::new(gen::psd_kernel(&mut rng, m));
        // Distinct pairs so K is nonsingular.
        let pairs = PairIndex::complete(m, m).subset(&(0..20).collect::<Vec<_>>());
        let y = dist::normal_vec(&mut rng, 20);
        let data = PairDataset {
            name: "cf".into(),
            d,
            t,
            pairs: pairs.clone(),
            y: y.clone(),
            homogeneous: true,
        };
        let model = ClosedFormModel::fit(&data, PairwiseKernel::Kronecker, 1e-8).unwrap();
        let p = model.predict(&pairs);
        for (pi, yi) in p.iter().zip(&y) {
            assert!((pi - yi).abs() < 1e-3, "{pi} vs {yi}");
        }
    }
}
