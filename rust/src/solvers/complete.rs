//! Closed-form Kronecker ridge for **complete data** — the fast special
//! case the paper's introduction cites (Romera-Paredes & Torr 2015;
//! Pahikkala et al. 2013/2014; Stock et al. 2018/2020) and against which
//! GVT's contribution is defined: GVT removes the completeness
//! requirement.
//!
//! When every (drug, target) combination is labeled (`Y ∈ R^{m×q}`) and
//! the kernel is the Kronecker product, eigendecompose once —
//! `D = U Λ_d Uᵀ`, `T = V Λ_t Vᵀ` — and the dual solution of
//! `(D ⊗ T + λI) a = y` is
//!
//! ```text
//! A = U [ (Uᵀ Y V) ⊘ (λ_d λ_tᵀ + λ) ] Vᵀ        (a = vec(A))
//! ```
//!
//! `O(m³ + q³)` once, then `O(mq(m+q))` per λ — and re-solving for a new
//! λ is nearly free, which is why this is the method of choice on
//! complete data and why the paper's incomplete-data setting needed GVT.

use crate::data::PairDataset;
use crate::error::{bail, Context, Result};
use crate::linalg::eigh::{eigh, Eigh};
use crate::linalg::Mat;

/// Eigendecomposed complete-data Kronecker ridge solver.
pub struct CompleteKronRidge {
    ed: Eigh,
    et: Eigh,
}

impl CompleteKronRidge {
    /// Decompose the drug and target kernels (`O(m³ + q³)`, done once).
    pub fn new(d: &Mat, t: &Mat) -> Result<Self> {
        Ok(Self {
            ed: eigh(d).context("eigendecomposition of the drug kernel")?,
            et: eigh(t).context("eigendecomposition of the target kernel")?,
        })
    }

    /// Solve `(D ⊗ T + λI) vec(A) = vec(Y)` for a complete label matrix
    /// `Y ∈ R^{m×q}` (row-major: `Y[d, t]`). `O(mq(m+q))`.
    pub fn solve(&self, y: &Mat, lambda: f64) -> Result<Mat> {
        let m = self.ed.values.len();
        let q = self.et.values.len();
        if y.shape() != (m, q) {
            bail!("label matrix is {:?}, kernels give ({m}, {q})", y.shape());
        }
        if lambda <= 0.0 {
            bail!("lambda must be positive");
        }
        // Ỹ = Uᵀ Y V
        let u = &self.ed.vectors;
        let v = &self.et.vectors;
        let mut ytilde = u.transpose().matmul(y).matmul(v);
        // Elementwise shrink by the Kronecker spectrum.
        for i in 0..m {
            for j in 0..q {
                ytilde[(i, j)] /= self.ed.values[i] * self.et.values[j] + lambda;
            }
        }
        // A = U Ỹ Vᵀ
        Ok(u.matmul(&ytilde).matmul(&v.transpose()))
    }

    /// Convenience: fit on a complete [`PairDataset`] (must cover the full
    /// `m × q` grid exactly once) and return the dual vector aligned with
    /// `data.pairs`.
    pub fn fit_dataset(data: &PairDataset, lambda: f64) -> Result<Vec<f64>> {
        let m = data.pairs.m();
        let q = data.pairs.q();
        if data.len() != m * q {
            bail!(
                "complete-data solver needs all {} pairs, got {}",
                m * q,
                data.len()
            );
        }
        // Assemble Y from the (possibly shuffled) sample.
        let mut y = Mat::zeros(m, q);
        let mut seen = vec![false; m * q];
        for i in 0..data.len() {
            let (dd, tt) = (data.pairs.drug(i), data.pairs.target(i));
            if seen[dd * q + tt] {
                bail!("duplicate pair ({dd}, {tt}) in complete dataset");
            }
            seen[dd * q + tt] = true;
            y[(dd, tt)] = data.y[i];
        }
        let solver = Self::new(&data.d, &data.t)?;
        let a = solver.solve(&y, lambda)?;
        // Back to the sample's pair order.
        Ok((0..data.len())
            .map(|i| a[(data.pairs.drug(i), data.pairs.target(i))])
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::kernel_filling::KernelFillingConfig;
    use crate::gvt::pairwise::PairwiseKernel;
    use crate::solvers::ridge::{PairwiseRidge, RidgeConfig};

    #[test]
    fn matches_minres_gvt_on_complete_grid() {
        // Complete 20×20 kernel-filling grid: the closed form and the
        // iterative GVT solver must agree.
        let k = 20;
        let data = KernelFillingConfig::small().generate(k, k * k, 500);
        assert_eq!(data.len(), k * k);
        let lambda = 0.5;
        let closed = CompleteKronRidge::fit_dataset(&data, lambda).unwrap();
        let cfg = RidgeConfig {
            lambda,
            max_iters: 4000,
            rel_tol: 1e-13,
            ..Default::default()
        };
        let iterative = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        let err = crate::linalg::vecops::max_abs_diff(&closed, &iterative.alpha);
        assert!(err < 1e-5, "closed vs iterative: {err}");
    }

    #[test]
    fn relambda_is_consistent() {
        // Same decomposition reused across λ: each solve must match a
        // fresh Cholesky solve of the explicit system.
        use crate::gvt::explicit::explicit_matrix;
        use crate::linalg::chol::solve_regularized;
        let k = 8;
        let data = KernelFillingConfig::small().generate(k, k * k, 501);
        let solver = CompleteKronRidge::new(&data.d, &data.t).unwrap();
        let mut y = Mat::zeros(k, k);
        for i in 0..data.len() {
            y[(data.pairs.drug(i), data.pairs.target(i))] = data.y[i];
        }
        let kmat = explicit_matrix(
            PairwiseKernel::Kronecker,
            &data.d,
            &data.t,
            &data.pairs,
            &data.pairs,
        );
        for lambda in [1e-2, 1.0, 50.0] {
            let a = solver.solve(&y, lambda).unwrap();
            let oracle = solve_regularized(&kmat, lambda, &data.y).unwrap();
            for i in 0..data.len() {
                let ai = a[(data.pairs.drug(i), data.pairs.target(i))];
                assert!((ai - oracle[i]).abs() < 1e-7, "λ={lambda}: {ai} vs {}", oracle[i]);
            }
        }
    }

    #[test]
    fn rejects_incomplete_data() {
        let data = KernelFillingConfig::small().generate(10, 60, 502);
        assert!(CompleteKronRidge::fit_dataset(&data, 1.0).is_err());
    }
}
