//! Closed-form Kronecker ridge + exact LOOCV for **complete data** — the
//! fast special case the paper's introduction cites (Romera-Paredes &
//! Torr 2015; Pahikkala et al. 2013/2014; Stock et al. 2018/2020) and
//! against which GVT's contribution is defined: GVT removes the
//! completeness requirement.
//!
//! When every (drug, target) combination is labeled (`Y ∈ R^{m×q}`) and
//! the kernel is the Kronecker product, eigendecompose once —
//! `D = U Λ_d Uᵀ`, `T = V Λ_t Vᵀ` — and the dual solution of
//! `(D ⊗ T + λI) a = y` is
//!
//! ```text
//! A = U [ (Uᵀ Y V) ⊘ (λ_d λ_tᵀ + λ) ] Vᵀ        (a = vec(A))
//! ```
//!
//! `O(m³ + q³)` once, then `O(mq(m+q))` per λ — and re-solving for a new
//! λ is nearly free, which is why this is the method of choice on
//! complete data and why the paper's incomplete-data setting needed GVT.
//!
//! This module grows that observation into a full solver lane:
//!
//! * [`CompleteKronRidge::solve_grid`] — a whole λ grid from the one
//!   decomposition (filtered-eigenvalue update per λ, no
//!   re-factorization).
//! * [`CompleteKronRidge::loo_grid`] — **exact** leave-one-out CV per λ
//!   via the leverages matrix `L = (U∘U) E (V∘V)ᵀ` where
//!   `E[i,j] = σᵢ sⱼ / (σᵢ sⱼ + λ)` holds the filtered eigenvalues of
//!   the hat matrix `H = K (K + λI)⁻¹` (Stock et al., arXiv:1606.04275;
//!   derivation pointer in rust/DESIGN.md §Eigen-Shortcut). `L` is the
//!   diagonal of `H` reshaped to the grid, so the classic ridge LOO
//!   identity `ŷ₋ᵢ = (ŷᵢ − hᵢᵢ yᵢ) / (1 − hᵢᵢ)` applies cell-wise —
//!   n retrains collapse to three small GEMMs.
//! * [`EigenRidge`] — the dataset-level solver behind
//!   `gvt-rls train --solver eigen` and `tuning::select_lambda_for`,
//!   producing the same [`RidgeModel`] (and therefore the same v2
//!   artifact) as the iterative lane.
//! * [`EigenPrecond`] — the eigenbasis recycled as a CG preconditioner
//!   for **incomplete** grids (two-step-ridge style): applies
//!   `R (D ⊗ T + λI)⁻¹ Rᵀ` where `R` selects the observed cells.

use crate::data::PairDataset;
use crate::error::{bail, Context, Result};
use crate::gvt::pairwise::PairwiseKernel;
use crate::gvt::vec_trick::GvtPolicy;
use crate::linalg::eigh::{eigh, Eigh};
use crate::linalg::Mat;
use crate::solvers::linear_op::LinOp;
use crate::solvers::ridge::RidgeModel;
use crate::sparse::PairIndex;
use std::sync::{Arc, Mutex};

/// Check that a pair sample covers its `m × q` grid **exactly once**.
///
/// The structured error names the missing-cell and duplicate counts so
/// callers (CLI, tuning) can surface an actionable in-band message
/// instead of a silent wrong answer.
pub fn check_complete(pairs: &PairIndex) -> Result<()> {
    let (m, q) = (pairs.m(), pairs.q());
    let total = m * q;
    let mut seen = vec![false; total];
    let mut duplicates = 0usize;
    for i in 0..pairs.len() {
        let cell = pairs.drug(i) * q + pairs.target(i);
        if seen[cell] {
            duplicates += 1;
        } else {
            seen[cell] = true;
        }
    }
    let missing = total - (pairs.len() - duplicates);
    if missing == 0 && duplicates == 0 {
        return Ok(());
    }
    bail!(
        "incomplete grid: {missing} of {total} (drug, target) cells missing \
         and {duplicates} duplicated in a {m}×{q} sample of {} pairs — the \
         complete-data eigen solver needs every cell labeled exactly once \
         (use minres/cg/sgd on incomplete data)",
        pairs.len()
    )
}

/// Assemble the complete label matrix `Y[d, t]` from a (possibly
/// shuffled) sample, after [`check_complete`] passes.
fn assemble_y(data: &PairDataset) -> Result<Mat> {
    check_complete(&data.pairs)?;
    let (m, q) = (data.pairs.m(), data.pairs.q());
    let mut y = Mat::zeros(m, q);
    for i in 0..data.len() {
        y[(data.pairs.drug(i), data.pairs.target(i))] = data.y[i];
    }
    Ok(y)
}

/// Eigendecomposed complete-data Kronecker ridge solver.
///
/// Caches `Uᵀ`, `Vᵀ`, `U∘U`, and `(V∘V)ᵀ` at construction so the per-λ
/// solve and the LOOCV leverages are pure GEMM pipelines.
pub struct CompleteKronRidge {
    ed: Eigh,
    et: Eigh,
    /// `Uᵀ` (drug eigenvectors, transposed once).
    ut: Mat,
    /// `Vᵀ` (target eigenvectors, transposed once).
    vt: Mat,
    /// `U ∘ U` — the left factor of the leverages product.
    u2: Mat,
    /// `(V ∘ V)ᵀ` — the right factor of the leverages product.
    v2t: Mat,
}

impl CompleteKronRidge {
    /// Decompose the drug and target kernels (`O(m³ + q³)`, done once).
    pub fn new(d: &Mat, t: &Mat) -> Result<Self> {
        let ed = eigh(d).context("eigendecomposition of the drug kernel")?;
        let et = eigh(t).context("eigendecomposition of the target kernel")?;
        let ut = ed.vectors.transpose();
        let vt = et.vectors.transpose();
        let u2 = ed.vectors.hadamard_square();
        let v2t = vt.hadamard_square();
        Ok(Self { ed, et, ut, vt, u2, v2t })
    }

    fn dims(&self) -> (usize, usize) {
        (self.ed.values.len(), self.et.values.len())
    }

    fn check_inputs(&self, y: &Mat, lambdas: &[f64]) -> Result<()> {
        let (m, q) = self.dims();
        if y.shape() != (m, q) {
            bail!("label matrix is {:?}, kernels give ({m}, {q})", y.shape());
        }
        for &lambda in lambdas {
            if lambda <= 0.0 {
                bail!("lambda must be positive, got {lambda}");
            }
        }
        Ok(())
    }

    /// `Ỹ = Uᵀ Y V` — the one rotation shared by every λ.
    fn rotate(&self, y: &Mat) -> Mat {
        self.ut.matmul(y).matmul(&self.et.vectors)
    }

    /// Solve `(D ⊗ T + λI) vec(A) = vec(Y)` for a complete label matrix
    /// `Y ∈ R^{m×q}` (row-major: `Y[d, t]`). `O(mq(m+q))`.
    pub fn solve(&self, y: &Mat, lambda: f64) -> Result<Mat> {
        Ok(self.solve_grid(y, &[lambda])?.pop().expect("one λ in, one α out"))
    }

    /// Solve the same system for a **whole λ grid**, reusing the one
    /// eigendecomposition and the one rotation `Ỹ = Uᵀ Y V`: per λ only
    /// the elementwise spectral shrink and the back-rotation
    /// `A = U Ỹ_λ Vᵀ` run — `O(mq(m+q))` each, no re-factorization.
    pub fn solve_grid(&self, y: &Mat, lambdas: &[f64]) -> Result<Vec<Mat>> {
        self.check_inputs(y, lambdas)?;
        let (m, q) = self.dims();
        let ytilde = self.rotate(y);
        let mut out = Vec::with_capacity(lambdas.len());
        for &lambda in lambdas {
            let mut shrunk = Mat::zeros(m, q);
            for i in 0..m {
                for j in 0..q {
                    shrunk[(i, j)] =
                        ytilde[(i, j)] / (self.ed.values[i] * self.et.values[j] + lambda);
                }
            }
            out.push(self.ed.vectors.matmul(&shrunk).matmul(&self.vt));
        }
        Ok(out)
    }

    /// Exact leave-one-out predictions for every cell and every λ.
    ///
    /// Per λ (all three factors are cached, cost `O(mq(m+q))`):
    ///
    /// ```text
    /// E[i,j] = σᵢ sⱼ / (σᵢ sⱼ + λ)      filtered Kronecker spectrum
    /// Ŷ      = U (Ỹ ∘ E) Vᵀ             in-sample fit  H·vec(Y)
    /// L      = (U∘U) E (V∘V)ᵀ           leverages      diag(H) on the grid
    /// Ŷ₋     = (Ŷ − Y ∘ L) ⊘ (1 − L)    exact LOO predictions
    /// ```
    ///
    /// Returns one `m × q` LOO-prediction matrix per λ. Errors if a
    /// leverage reaches 1 (λ too small relative to the kernel spectrum:
    /// the model interpolates and leave-one-out is undefined).
    pub fn loo_grid(&self, y: &Mat, lambdas: &[f64]) -> Result<Vec<Mat>> {
        self.check_inputs(y, lambdas)?;
        let (m, q) = self.dims();
        let ytilde = self.rotate(y);
        let mut out = Vec::with_capacity(lambdas.len());
        for &lambda in lambdas {
            let mut e = Mat::zeros(m, q);
            let mut fit = Mat::zeros(m, q);
            for i in 0..m {
                for j in 0..q {
                    let sv = self.ed.values[i] * self.et.values[j];
                    let den = sv + lambda;
                    if den <= 0.0 {
                        bail!(
                            "non-positive shifted spectrum {den:e} at eigenpair \
                             ({i}, {j}) for λ = {lambda:e} — kernels are not PSD \
                             enough for this λ"
                        );
                    }
                    e[(i, j)] = sv / den;
                    fit[(i, j)] = ytilde[(i, j)] * e[(i, j)];
                }
            }
            let yhat = self.ed.vectors.matmul(&fit).matmul(&self.vt);
            let lev = self.u2.matmul(&e).matmul(&self.v2t);
            let mut loo = Mat::zeros(m, q);
            for d in 0..m {
                for t in 0..q {
                    let l = lev[(d, t)];
                    let den = 1.0 - l;
                    if den <= 1e-12 {
                        bail!(
                            "leverage {l} ≈ 1 at cell ({d}, {t}) for λ = {lambda:e} \
                             — exact LOOCV is undefined when the model interpolates; \
                             use a larger λ"
                        );
                    }
                    loo[(d, t)] = (yhat[(d, t)] - y[(d, t)] * l) / den;
                }
            }
            out.push(loo);
        }
        Ok(out)
    }

    /// Convenience: fit on a complete [`PairDataset`] (must cover the full
    /// `m × q` grid exactly once) and return the dual vector aligned with
    /// `data.pairs`.
    pub fn fit_dataset(data: &PairDataset, lambda: f64) -> Result<Vec<f64>> {
        let y = assemble_y(data)?;
        let solver = Self::new(&data.d, &data.t)?;
        let a = solver.solve(&y, lambda)?;
        // Back to the sample's pair order.
        Ok((0..data.len())
            .map(|i| a[(data.pairs.drug(i), data.pairs.target(i))])
            .collect())
    }
}

/// Per-λ exact LOOCV result from [`EigenRidge::loocv`].
#[derive(Clone, Debug)]
pub struct EigenLooCell {
    /// The regularizer this row was evaluated at.
    pub lambda: f64,
    /// Leave-one-out predictions, aligned with the dataset's pair order.
    pub loo: Vec<f64>,
    /// Mean squared leave-one-out error over all pairs.
    pub mse: f64,
}

/// Dataset-level eigen solver: the `--solver eigen` training lane.
///
/// Construction validates the two preconditions (Kronecker kernel,
/// complete grid) with in-band errors, assembles `Y`, and pays the one
/// `O(m³ + q³)` eigendecomposition. Every λ after that is closed-form:
/// [`Self::alpha_grid`] for duals, [`Self::loocv`] for exact model
/// selection, [`Self::fit_model`] for a [`RidgeModel`] indistinguishable
/// from the iterative solvers' output (same v2 artifact; `predict` and
/// `serve` are untouched).
pub struct EigenRidge {
    solver: CompleteKronRidge,
    kernel: PairwiseKernel,
    d: Arc<Mat>,
    t: Arc<Mat>,
    pairs: PairIndex,
    y: Mat,
}

impl EigenRidge {
    /// Validate and decompose. Errors (in-band, structured) when the
    /// kernel is not a single Kronecker product or the sample does not
    /// cover the grid exactly once.
    pub fn new(data: &PairDataset, kernel: PairwiseKernel) -> Result<Self> {
        if kernel != PairwiseKernel::Kronecker {
            bail!(
                "the eigen solver factorizes K = D ⊗ T; kernel '{}' is a sum \
                 of Kronecker products and is not simultaneously \
                 diagonalizable — use minres, cg, or sgd",
                kernel.name()
            );
        }
        let y = assemble_y(data)
            .with_context(|| format!("eigen solver on '{}'", data.name))?;
        let solver = CompleteKronRidge::new(&data.d, &data.t)?;
        Ok(Self {
            solver,
            kernel,
            d: data.d.clone(),
            t: data.t.clone(),
            pairs: data.pairs.clone(),
            y,
        })
    }

    /// Number of training pairs (`m · q`).
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    /// Gather a grid-shaped quantity back into the sample's pair order.
    fn gather(&self, grid: &Mat) -> Vec<f64> {
        (0..self.pairs.len())
            .map(|i| grid[(self.pairs.drug(i), self.pairs.target(i))])
            .collect()
    }

    /// Dual coefficient vectors for a whole λ grid (pair order), from
    /// the one decomposition.
    pub fn alpha_grid(&self, lambdas: &[f64]) -> Result<Vec<Vec<f64>>> {
        let grids = self.solver.solve_grid(&self.y, lambdas)?;
        Ok(grids.iter().map(|a| self.gather(a)).collect())
    }

    /// Exact leave-one-out CV for every λ — model selection without a
    /// single solver iteration or retrain.
    pub fn loocv(&self, lambdas: &[f64]) -> Result<Vec<EigenLooCell>> {
        let grids = self.solver.loo_grid(&self.y, lambdas)?;
        let mut out = Vec::with_capacity(lambdas.len());
        for (grid, &lambda) in grids.iter().zip(lambdas) {
            let loo = self.gather(grid);
            let n = loo.len() as f64;
            let mse = loo
                .iter()
                .zip(self.gather(&self.y))
                .map(|(p, y)| (p - y) * (p - y))
                .sum::<f64>()
                / n;
            out.push(EigenLooCell { lambda, loo, mse });
        }
        Ok(out)
    }

    /// Fit at one λ and package the result as a standard [`RidgeModel`]
    /// (`iterations = 0`: the direct lane has no Krylov loop).
    pub fn fit_model(&self, lambda: f64) -> Result<RidgeModel> {
        let a = self.solver.solve(&self.y, lambda)?;
        RidgeModel::from_parts(
            self.kernel,
            self.d.clone(),
            self.t.clone(),
            self.pairs.clone(),
            GvtPolicy::Auto,
            self.gather(&a),
            lambda,
        )
    }
}

/// Reusable workspace for [`EigenPrecond`] — three `m × q` scratch
/// matrices allocated once so each CG iteration's preconditioner apply
/// is allocation-free (the CG loop itself is under the alloc-free lint
/// contract).
struct PrecondWs {
    grid: Mat,
    a: Mat,
    b: Mat,
}

/// Eigenbasis preconditioner for CG on **incomplete** grids (two-step
/// ridge style — Stock et al., arXiv:1606.04275 / arXiv:1803.01575).
///
/// The system `(R (D ⊗ T) Rᵀ + λI) α = y` selects the `n` observed cells
/// with `R`. This preconditioner applies the inverse of the *complete*
/// operator restricted back to those cells:
///
/// ```text
/// M⁻¹ v = R (D ⊗ T + λI)⁻¹ Rᵀ v
///       = gather( U [ (Uᵀ scatter(v) V) ⊘ (λ_d λ_tᵀ + λ) ] Vᵀ )
/// ```
///
/// `Rᵀ` scatter-**adds** into the grid (the exact adjoint of the gather,
/// so `M⁻¹` stays symmetric positive definite even if the sample carries
/// duplicate pairs) and unobserved cells stay zero. The denser the
/// sample, the closer `M⁻¹ (K + λI)` is to the identity — on a complete
/// grid CG would converge in one iteration.
///
/// Determinism: the apply is four dense GEMMs (pooled, rows as the unit
/// of work — bit-identical for any thread count per DESIGN §Runtime)
/// plus serial scatter/gather loops in fixed pair order.
pub struct EigenPrecond {
    kr: CompleteKronRidge,
    /// `σᵢ sⱼ + λ`, precomputed.
    denom: Mat,
    rows: PairIndex,
    ws: Mutex<PrecondWs>,
}

impl EigenPrecond {
    /// Decompose the factor kernels and freeze the shifted spectrum.
    pub fn new(d: &Mat, t: &Mat, rows: PairIndex, lambda: f64) -> Result<Self> {
        if lambda <= 0.0 {
            bail!("eigen preconditioner needs λ > 0, got {lambda}");
        }
        if rows.m() != d.rows() || rows.q() != t.rows() {
            bail!(
                "pair sample is over a {}×{} grid but the kernels are {}×{}",
                rows.m(),
                rows.q(),
                d.rows(),
                t.rows()
            );
        }
        let kr = CompleteKronRidge::new(d, t)
            .context("eigen preconditioner factorization")?;
        let (m, q) = kr.dims();
        let mut denom = Mat::zeros(m, q);
        for i in 0..m {
            for j in 0..q {
                let den = kr.ed.values[i] * kr.et.values[j] + lambda;
                if den <= 0.0 {
                    bail!(
                        "eigen preconditioner: non-positive shifted spectrum \
                         {den:e} at eigenpair ({i}, {j}) — kernels are not PSD \
                         enough for λ = {lambda:e}"
                    );
                }
                denom[(i, j)] = den;
            }
        }
        Ok(Self {
            kr,
            denom,
            rows,
            ws: Mutex::new(PrecondWs {
                grid: Mat::zeros(m, q),
                a: Mat::zeros(m, q),
                b: Mat::zeros(m, q),
            }),
        })
    }
}

impl LinOp for EigenPrecond {
    fn dim_out(&self) -> usize {
        self.rows.len()
    }

    fn dim_in(&self) -> usize {
        self.rows.len()
    }

    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows.len(), "precond input dim mismatch");
        assert_eq!(y.len(), self.rows.len(), "precond output dim mismatch");
        let mut ws = self.ws.lock().unwrap_or_else(|e| e.into_inner());
        let PrecondWs { grid, a, b } = &mut *ws;
        // Rᵀ: scatter-add the residual into the grid (adjoint of gather).
        grid.as_mut_slice().fill(0.0);
        for i in 0..self.rows.len() {
            grid[(self.rows.drug(i), self.rows.target(i))] += x[i];
        }
        // (D ⊗ T + λI)⁻¹ in the eigenbasis: rotate, shrink, rotate back.
        self.kr.ut.matmul_into(grid, a);
        a.matmul_into(&self.kr.et.vectors, b);
        for (bv, den) in b.as_mut_slice().iter_mut().zip(self.denom.as_slice()) {
            *bv /= *den;
        }
        self.kr.ed.vectors.matmul_into(b, a);
        a.matmul_into(&self.kr.vt, grid);
        // R: gather the observed cells back out in pair order.
        for i in 0..self.rows.len() {
            y[i] = grid[(self.rows.drug(i), self.rows.target(i))];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::kernel_filling::KernelFillingConfig;
    use crate::gvt::pairwise::PairwiseKernel;
    use crate::solvers::ridge::{PairwiseRidge, RidgeConfig};

    #[test]
    fn matches_minres_gvt_on_complete_grid() {
        // Complete 20×20 kernel-filling grid: the closed form and the
        // iterative GVT solver must agree.
        let k = 20;
        let data = KernelFillingConfig::small().generate(k, k * k, 500);
        assert_eq!(data.len(), k * k);
        let lambda = 0.5;
        let closed = CompleteKronRidge::fit_dataset(&data, lambda).unwrap();
        let cfg = RidgeConfig {
            lambda,
            max_iters: 4000,
            rel_tol: 1e-13,
            ..Default::default()
        };
        let iterative = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        let err = crate::linalg::vecops::max_abs_diff(&closed, &iterative.alpha);
        assert!(err < 1e-5, "closed vs iterative: {err}");
    }

    #[test]
    fn relambda_is_consistent() {
        // Same decomposition reused across λ: each solve must match a
        // fresh Cholesky solve of the explicit system.
        use crate::gvt::explicit::explicit_matrix;
        use crate::linalg::chol::solve_regularized;
        let k = 8;
        let data = KernelFillingConfig::small().generate(k, k * k, 501);
        let solver = CompleteKronRidge::new(&data.d, &data.t).unwrap();
        let mut y = Mat::zeros(k, k);
        for i in 0..data.len() {
            y[(data.pairs.drug(i), data.pairs.target(i))] = data.y[i];
        }
        let kmat = explicit_matrix(
            PairwiseKernel::Kronecker,
            &data.d,
            &data.t,
            &data.pairs,
            &data.pairs,
        );
        for lambda in [1e-2, 1.0, 50.0] {
            let a = solver.solve(&y, lambda).unwrap();
            let oracle = solve_regularized(&kmat, lambda, &data.y).unwrap();
            for i in 0..data.len() {
                let ai = a[(data.pairs.drug(i), data.pairs.target(i))];
                assert!((ai - oracle[i]).abs() < 1e-7, "λ={lambda}: {ai} vs {}", oracle[i]);
            }
        }
    }

    #[test]
    fn solve_grid_matches_per_lambda_solve() {
        let k = 9;
        let data = KernelFillingConfig::small().generate(k, k * k, 503);
        let solver = CompleteKronRidge::new(&data.d, &data.t).unwrap();
        let mut y = Mat::zeros(k, k);
        for i in 0..data.len() {
            y[(data.pairs.drug(i), data.pairs.target(i))] = data.y[i];
        }
        let lambdas = [1e-3, 1e-1, 1.0, 25.0];
        let grid = solver.solve_grid(&y, &lambdas).unwrap();
        assert_eq!(grid.len(), lambdas.len());
        for (a, &lambda) in grid.iter().zip(&lambdas) {
            let single = solver.solve(&y, lambda).unwrap();
            assert!(a.max_abs_diff(&single) < 1e-12, "λ={lambda}");
        }
    }

    #[test]
    fn rejects_incomplete_data() {
        let data = KernelFillingConfig::small().generate(10, 60, 502);
        assert!(CompleteKronRidge::fit_dataset(&data, 1.0).is_err());
    }

    #[test]
    fn incomplete_rejection_names_missing_count() {
        // 60 of 100 cells labeled → the structured error must name the
        // 40 missing cells so the CLI surfaces an actionable message.
        let data = KernelFillingConfig::small().generate(10, 60, 502);
        let err = EigenRidge::new(&data, PairwiseKernel::Kronecker).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("incomplete grid"), "{msg}");
        assert!(msg.contains("40 of 100"), "{msg}");
    }

    #[test]
    fn rejects_duplicate_pairs() {
        use crate::sparse::PairIndex;
        // m·q entries but cell (0, 0) appears twice and (1, 1) never.
        let pairs = PairIndex::new(vec![0, 0, 1, 1], vec![0, 0, 0, 1], 2, 2);
        let err = check_complete(&pairs).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("1 duplicated") || msg.contains("duplicat"), "{msg}");
    }

    #[test]
    fn rejects_non_kronecker_kernels() {
        let k = 6;
        let data = KernelFillingConfig::small().generate(k, k * k, 504);
        for kernel in [PairwiseKernel::Linear, PairwiseKernel::Poly2D] {
            let err = EigenRidge::new(&data, kernel).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(kernel.name()), "{msg}");
        }
    }

    #[test]
    fn eigen_model_round_trips_through_predict() {
        // The eigen lane must produce a RidgeModel whose predictions on
        // the training grid match the closed-form in-sample fit.
        let k = 10;
        let data = KernelFillingConfig::small().generate(k, k * k, 505);
        let er = EigenRidge::new(&data, PairwiseKernel::Kronecker).unwrap();
        let model = er.fit_model(0.3).unwrap();
        assert_eq!(model.iterations, 0);
        let alpha_direct = CompleteKronRidge::fit_dataset(&data, 0.3).unwrap();
        let err = crate::linalg::vecops::max_abs_diff(&model.alpha, &alpha_direct);
        assert!(err < 1e-12, "eigen model vs direct fit: {err}");
        let preds = model.predict(&data.pairs).unwrap();
        assert_eq!(preds.len(), data.len());
    }
}
