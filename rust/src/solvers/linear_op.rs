//! Linear-operator abstraction: the solvers only ever see `x ↦ Ax`.

/// A (possibly rectangular) linear operator.
///
/// `Sync` so the coordinator can share ops across worker threads.
pub trait LinOp: Sync {
    /// Output dimension (rows).
    fn dim_out(&self) -> usize;

    /// Input dimension (columns).
    fn dim_in(&self) -> usize;

    /// `y = A x` into a caller-provided buffer (hot path: no allocation).
    fn apply_into(&self, x: &[f64], y: &mut [f64]);

    /// Allocating convenience wrapper.
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.dim_out()];
        self.apply_into(x, &mut y);
        y
    }
}

/// `(A + λI) x` — the regularized system operator of Equation 1.
pub struct ShiftedOp<'a> {
    op: &'a dyn LinOp,
    shift: f64,
}

impl<'a> ShiftedOp<'a> {
    /// Requires a square underlying operator.
    pub fn new(op: &'a dyn LinOp, shift: f64) -> Self {
        assert_eq!(op.dim_in(), op.dim_out(), "ShiftedOp needs a square operator");
        Self { op, shift }
    }
}

impl LinOp for ShiftedOp<'_> {
    fn dim_out(&self) -> usize {
        self.op.dim_out()
    }

    fn dim_in(&self) -> usize {
        self.op.dim_in()
    }

    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        self.op.apply_into(x, y);
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += self.shift * xi;
        }
    }
}

/// A dense matrix as a [`LinOp`] (test helper and small-problem baseline).
pub struct DenseOp {
    m: crate::linalg::Mat,
}

impl DenseOp {
    pub fn new(m: crate::linalg::Mat) -> Self {
        Self { m }
    }

    pub fn matrix(&self) -> &crate::linalg::Mat {
        &self.m
    }
}

impl LinOp for DenseOp {
    fn dim_out(&self) -> usize {
        self.m.rows()
    }

    fn dim_in(&self) -> usize {
        self.m.cols()
    }

    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        let r = self.m.matvec(x);
        y.copy_from_slice(&r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn shifted_op_adds_lambda_x() {
        let a = Mat::eye(3);
        let op = DenseOp::new(a);
        let sh = ShiftedOp::new(&op, 0.5);
        let y = sh.apply(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![1.5, 3.0, 4.5]);
    }

    #[test]
    #[should_panic(expected = "square")]
    fn shifted_op_rejects_rectangular() {
        let op = DenseOp::new(Mat::zeros(2, 3));
        let _ = ShiftedOp::new(&op, 1.0);
    }
}
