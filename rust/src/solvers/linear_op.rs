//! Linear-operator abstraction: the solvers only ever see `x ↦ Ax`.

/// A (possibly rectangular) linear operator.
///
/// `Sync` so the coordinator can share ops across worker threads.
pub trait LinOp: Sync {
    /// Output dimension (rows).
    fn dim_out(&self) -> usize;

    /// Input dimension (columns).
    fn dim_in(&self) -> usize;

    /// `y = A x` into a caller-provided buffer (hot path: no allocation).
    fn apply_into(&self, x: &[f64], y: &mut [f64]);

    /// Allocating convenience wrapper.
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.dim_out()];
        self.apply_into(x, &mut y);
        y
    }

    /// `Y = A X` for a block of `B` RHS vectors (`X: dim_in × B`,
    /// `Y: dim_out × B`, both row-major — see
    /// [`crate::linalg::Mat::from_columns`]). Default implementation is a
    /// column loop; operators with a native multi-RHS path (the GVT
    /// [`crate::gvt::PairwiseLinOp`], which streams its index arrays once
    /// for the whole block) override it.
    fn apply_block(&self, x: &crate::linalg::Mat, y: &mut crate::linalg::Mat) {
        assert_eq!(x.rows(), self.dim_in(), "apply_block: input rows mismatch");
        assert_eq!(
            y.shape(),
            (self.dim_out(), x.cols()),
            "apply_block: output shape mismatch"
        );
        let mut xin = vec![0.0; self.dim_in()];
        let mut yout = vec![0.0; self.dim_out()];
        for b in 0..x.cols() {
            for j in 0..x.rows() {
                xin[j] = x[(j, b)];
            }
            self.apply_into(&xin, &mut yout);
            for i in 0..self.dim_out() {
                y[(i, b)] = yout[i];
            }
        }
    }
}

/// `(A + λI) x` — the regularized system operator of Equation 1.
pub struct ShiftedOp<'a> {
    op: &'a dyn LinOp,
    shift: f64,
}

impl<'a> ShiftedOp<'a> {
    /// Requires a square underlying operator.
    pub fn new(op: &'a dyn LinOp, shift: f64) -> Self {
        assert_eq!(op.dim_in(), op.dim_out(), "ShiftedOp needs a square operator");
        Self { op, shift }
    }
}

impl LinOp for ShiftedOp<'_> {
    fn dim_out(&self) -> usize {
        self.op.dim_out()
    }

    fn dim_in(&self) -> usize {
        self.op.dim_in()
    }

    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        self.op.apply_into(x, y);
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += self.shift * xi;
        }
    }

    fn apply_block(&self, x: &crate::linalg::Mat, y: &mut crate::linalg::Mat) {
        self.op.apply_block(x, y);
        for (yi, xi) in y.as_mut_slice().iter_mut().zip(x.as_slice()) {
            *yi += self.shift * xi;
        }
    }
}

/// A dense matrix as a [`LinOp`] (test helper and small-problem baseline).
pub struct DenseOp {
    m: crate::linalg::Mat,
}

impl DenseOp {
    /// Wrap a dense matrix.
    pub fn new(m: crate::linalg::Mat) -> Self {
        Self { m }
    }

    /// The wrapped matrix.
    pub fn matrix(&self) -> &crate::linalg::Mat {
        &self.m
    }
}

impl LinOp for DenseOp {
    fn dim_out(&self) -> usize {
        self.m.rows()
    }

    fn dim_in(&self) -> usize {
        self.m.cols()
    }

    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        let r = self.m.matvec(x);
        y.copy_from_slice(&r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn shifted_op_adds_lambda_x() {
        let a = Mat::eye(3);
        let op = DenseOp::new(a);
        let sh = ShiftedOp::new(&op, 0.5);
        let y = sh.apply(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![1.5, 3.0, 4.5]);
    }

    #[test]
    #[should_panic(expected = "square")]
    fn shifted_op_rejects_rectangular() {
        let op = DenseOp::new(Mat::zeros(2, 3));
        let _ = ShiftedOp::new(&op, 1.0);
    }

    #[test]
    fn apply_block_matches_column_loop() {
        let a = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        let op = DenseOp::new(a);
        let sh = ShiftedOp::new(&op, 2.0);
        let c0 = vec![1.0, 0.0, -1.0];
        let c1 = vec![0.5, 2.0, 1.5];
        let x = Mat::from_columns(&[&c0, &c1]);
        let mut y = Mat::zeros(3, 2);
        sh.apply_block(&x, &mut y);
        assert_eq!(y.column(0), sh.apply(&c0));
        assert_eq!(y.column(1), sh.apply(&c1));
    }
}
