//! MINRES — minimum residual method for symmetric (possibly indefinite)
//! systems (Paige & Saunders 1975). This is the paper's training solver
//! ("we used the scipy.sparse.linalg.minres method"); the GVT and explicit
//! baselines differ only in the `LinOp` handed to it.
//!
//! The implementation follows the classic Lanczos + Givens-QR recurrence;
//! per iteration it performs exactly one operator application plus `O(n)`
//! vector work and zero allocations after setup.

use crate::error::{bail, Result};
use crate::linalg::vecops::{axpy_par, dot, fused_direction_par, norm2, scale_into_par};
use crate::solvers::linear_op::LinOp;
use std::ops::ControlFlow;

/// Options for [`minres`].
#[derive(Clone, Debug)]
pub struct MinresOptions {
    /// Maximum number of iterations (operator applications).
    pub max_iters: usize,
    /// Relative residual tolerance `‖r_k‖ / ‖b‖`.
    pub rel_tol: f64,
}

impl Default for MinresOptions {
    fn default() -> Self {
        Self { max_iters: 1000, rel_tol: 1e-8 }
    }
}

/// Why MINRES stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MinresStop {
    /// Residual tolerance reached.
    Converged,
    /// Iteration budget exhausted.
    MaxIters,
    /// Lanczos breakdown: exact solution found in the Krylov subspace.
    Breakdown,
    /// The per-iteration callback requested a stop (early stopping).
    Callback,
    /// Right-hand side was zero.
    ZeroRhs,
}

/// Result of a MINRES run.
#[derive(Clone, Debug)]
pub struct MinresOutcome {
    /// Approximate solution.
    pub x: Vec<f64>,
    /// Iterations performed.
    pub iterations: usize,
    /// Final relative residual estimate.
    pub rel_residual: f64,
    /// Stop reason.
    pub stop: MinresStop,
}

/// Solve `A x = b` for symmetric `A`, invoking `callback(iter, x, relres)`
/// after each iteration; the callback may stop the run early (the paper's
/// early-stopping regularizer). `x` passed to the callback is the current
/// iterate — cheap to use for validation predictions.
///
/// Fails loudly — mirroring the SGD trainer's divergence contract — if
/// the Lanczos recurrence produces non-finite coefficients mid-iteration
/// (an operator emitting NaN/Inf): the error names the iteration instead
/// of letting garbage propagate through the Givens rotations.
pub fn minres<F>(
    a: &dyn LinOp,
    b: &[f64],
    opts: &MinresOptions,
    mut callback: F,
) -> Result<MinresOutcome>
where
    F: FnMut(usize, &[f64], f64) -> ControlFlow<()>,
{
    let n = b.len();
    assert_eq!(a.dim_in(), n, "minres: rhs/operator size mismatch");
    assert_eq!(a.dim_out(), n, "minres: operator must be square");

    let beta1 = norm2(b);
    if !beta1.is_finite() {
        bail!("minres: right-hand side has non-finite entries (|b| = {beta1:e})");
    }
    if beta1 == 0.0 {
        return Ok(MinresOutcome {
            x: vec![0.0; n],
            iterations: 0,
            rel_residual: 0.0,
            stop: MinresStop::ZeroRhs,
        });
    }

    // Lanczos vectors.
    let mut v_prev = vec![0.0; n]; // v_{k-1}
    let mut v: Vec<f64> = b.iter().map(|bi| bi / beta1).collect(); // v_k
    let mut av = vec![0.0; n]; // workspace for A v

    // Direction vectors for the solution update.
    let mut w_oold = vec![0.0; n];
    let mut w_old = vec![0.0; n];
    let mut w_new = vec![0.0; n];

    let mut x = vec![0.0; n];

    // Givens rotation state.
    let (mut c_old, mut c) = (1.0f64, 1.0f64);
    let (mut s_old, mut s) = (0.0f64, 0.0f64);
    let mut beta = beta1; // β_k
    let mut eta = beta1; // residual carrier

    let mut stop = MinresStop::MaxIters;
    let mut iterations = 0;
    let mut rel_res = 1.0;

    // lint: alloc_free — the Lanczos/Givens state is fully allocated
    // above; the loop body must stay heap-silent (tests/alloc_free.rs
    // measures it).
    for k in 1..=opts.max_iters {
        // Lanczos step: α, β_{k+1}, next v.
        a.apply_into(&v, &mut av);
        let alpha = dot(&v, &av);
        // av ← av − α v − β v_prev (three-term recurrence). The axpys
        // fan out over the worker pool at large n; dot/norm2 stay serial
        // (reduction order is part of the bit-determinism contract).
        axpy_par(-alpha, &v, &mut av);
        axpy_par(-beta, &v_prev, &mut av);
        let beta_next = norm2(&av);
        if !alpha.is_finite() || !beta_next.is_finite() {
            bail!(
                "minres diverged: non-finite Lanczos coefficients \
                 (α = {alpha:e}, β = {beta_next:e}) at iteration {k} \
                 (the operator produced non-finite values)"
            );
        }

        // Apply previous rotations to the new tridiagonal column.
        let delta = c * alpha - c_old * s * beta;
        let rho1 = (delta * delta + beta_next * beta_next).sqrt();
        let rho2 = s * alpha + c_old * c * beta;
        let rho3 = s_old * beta;

        if rho1 == 0.0 {
            // Singular leading block: cannot advance.
            stop = MinresStop::Breakdown;
            iterations = k - 1;
            break;
        }

        // New rotation.
        c_old = c;
        s_old = s;
        c = delta / rho1;
        s = beta_next / rho1;

        // w_new = (v − ρ3 w_oold − ρ2 w_old) / ρ1, one fused pass.
        fused_direction_par(&mut w_new, &v, rho3, &w_oold, rho2, &w_old, 1.0 / rho1);
        // x += c · η · w_new.
        axpy_par(c * eta, &w_new, &mut x);
        eta = -s * eta;

        // Shift registers.
        std::mem::swap(&mut w_oold, &mut w_old);
        std::mem::swap(&mut w_old, &mut w_new);
        std::mem::swap(&mut v_prev, &mut v);
        if beta_next > 0.0 {
            scale_into_par(&mut v, &av, 1.0 / beta_next);
        }
        beta = beta_next;

        iterations = k;
        rel_res = eta.abs() / beta1;
        // Values only — wall-time is stamped by the obs layer, never here.
        crate::obs::iter::record(k, rel_res);

        if let ControlFlow::Break(()) = callback(k, &x, rel_res) {
            stop = MinresStop::Callback;
            break;
        }
        if rel_res <= opts.rel_tol {
            stop = MinresStop::Converged;
            break;
        }
        if beta_next == 0.0 {
            // Krylov space exhausted — x is exact (up to rounding).
            stop = MinresStop::Breakdown;
            break;
        }
    }

    Ok(MinresOutcome { x, iterations, rel_residual: rel_res, stop })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::Cholesky;
    use crate::linalg::Mat;
    use crate::rng::{dist, Xoshiro256};
    use crate::solvers::linear_op::DenseOp;
    use crate::testing::gen;

    fn no_cb(_: usize, _: &[f64], _: f64) -> ControlFlow<()> {
        ControlFlow::Continue(())
    }

    #[test]
    fn solves_spd_system_to_cholesky_answer() {
        let mut rng = Xoshiro256::seed_from(60);
        let k = gen::psd_kernel(&mut rng, 25);
        let mut a = k.clone();
        for i in 0..25 {
            a[(i, i)] += 0.1;
        }
        let b = dist::normal_vec(&mut rng, 25);
        let oracle = Cholesky::factor(&a).unwrap().solve(&b);
        let out = minres(
            &DenseOp::new(a),
            &b,
            &MinresOptions { max_iters: 500, rel_tol: 1e-12 },
            no_cb,
        )
        .unwrap();
        assert!(matches!(out.stop, MinresStop::Converged | MinresStop::Breakdown));
        for (x, o) in out.x.iter().zip(&oracle) {
            assert!((x - o).abs() < 1e-6, "{x} vs {o}");
        }
    }

    #[test]
    fn handles_indefinite_symmetric() {
        // MINRES (unlike CG) must handle indefinite matrices — this is why
        // the paper uses it: anti-symmetric/ranking kernels give PSD but
        // near-singular K, and K itself (without +λI) may be indefinite
        // after floating-point symmetrization.
        let mut a = Mat::eye(4);
        a[(2, 2)] = -2.0;
        a[(0, 1)] = 0.3;
        a[(1, 0)] = 0.3;
        let b = vec![1.0, -1.0, 2.0, 0.5];
        let out = minres(
            &DenseOp::new(a.clone()),
            &b,
            &MinresOptions { max_iters: 100, rel_tol: 1e-12 },
            no_cb,
        )
        .unwrap();
        let r = a.matvec(&out.x);
        for (ri, bi) in r.iter().zip(&b) {
            assert!((ri - bi).abs() < 1e-8);
        }
    }

    #[test]
    fn zero_rhs_short_circuits() {
        let out = minres(
            &DenseOp::new(Mat::eye(5)),
            &[0.0; 5],
            &MinresOptions::default(),
            no_cb,
        )
        .unwrap();
        assert_eq!(out.stop, MinresStop::ZeroRhs);
        assert_eq!(out.x, vec![0.0; 5]);
    }

    #[test]
    fn callback_can_stop_early() {
        let mut rng = Xoshiro256::seed_from(61);
        let a = gen::psd_kernel(&mut rng, 30);
        let b = dist::normal_vec(&mut rng, 30);
        let out = minres(
            &DenseOp::new(a),
            &b,
            &MinresOptions { max_iters: 1000, rel_tol: 1e-14 },
            |k, _, _| {
                if k >= 3 {
                    ControlFlow::Break(())
                } else {
                    ControlFlow::Continue(())
                }
            },
        )
        .unwrap();
        assert_eq!(out.iterations, 3);
        assert_eq!(out.stop, MinresStop::Callback);
    }

    #[test]
    fn residual_estimate_tracks_true_residual() {
        let mut rng = Xoshiro256::seed_from(62);
        let mut a = gen::psd_kernel(&mut rng, 15);
        for i in 0..15 {
            a[(i, i)] += 1.0;
        }
        let b = dist::normal_vec(&mut rng, 15);
        let amat = a.clone();
        let bnorm = norm2(&b);
        minres(
            &DenseOp::new(a),
            &b,
            &MinresOptions { max_iters: 60, rel_tol: 1e-12 },
            |_, x, est| {
                let mut r = amat.matvec(x);
                for (ri, bi) in r.iter_mut().zip(&b) {
                    *ri = bi - *ri;
                }
                let truth = norm2(&r) / bnorm;
                assert!(
                    (truth - est).abs() < 1e-6 + 0.1 * truth,
                    "estimate {est} vs true {truth}"
                );
                ControlFlow::Continue(())
            },
        )
        .unwrap();
    }

    #[test]
    fn non_finite_operator_fails_loudly() {
        // An operator emitting NaN must produce a structured error that
        // names the iteration — never a silent garbage solution
        // (mirrors the SGD trainer's divergent_lr_fails_loudly contract).
        let mut a = Mat::eye(6);
        a[(3, 3)] = f64::INFINITY;
        let b = vec![1.0; 6];
        let err =
            minres(&DenseOp::new(a), &b, &MinresOptions::default(), no_cb).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("diverged"), "{msg}");
        assert!(msg.contains("iteration 1"), "{msg}");
    }
}
