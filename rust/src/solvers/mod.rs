//! Iterative, direct, and stochastic solvers for the regularized
//! least-squares problem `(K + λI) a = y` (Equation 1 of the paper).
//!
//! * [`linear_op`] — the operator abstraction: anything that can multiply
//!   a vector (GVT ops, explicit matrices, shifted/scaled compositions).
//! * [`minres`] — the minimum residual method (Paige & Saunders 1975),
//!   the paper's training algorithm (`scipy.sparse.linalg.minres`
//!   equivalent) with per-iteration callbacks for early stopping.
//! * [`cg`] — conjugate gradient, used by the Nyström/Falkon baseline.
//! * [`sgd`] — mini-batched stochastic vec trick trainer: batch-shaped
//!   GVT products instead of full passes, for `n` beyond the exact
//!   solvers' reach (plus [`schedule`], its step-size schedules).
//! * [`ridge`] — kernel ridge regression over pairwise kernels with
//!   validation-based early stopping (the paper's training protocol).
//! * [`nystrom`] — Falkon-style Nyström approximation baseline (§6.5).
//! * [`complete`] — closed-form eigen solver + exact LOOCV for complete
//!   grids with the Kronecker kernel, and the eigenbasis CG
//!   preconditioner for incomplete grids.
//! * [`closed_form`] — `O(n³)` Cholesky oracle for tests/small problems.
//! * [`persist`] — model artifacts (v1/v2) shared with `gvt-rls
//!   predict`/`serve`.
//!
//! [`Solver`] names the training algorithms the CLI and coordinator
//! dispatch over.

pub mod cg;
pub mod closed_form;
pub mod complete;
pub mod linear_op;
pub mod minres;
pub mod nystrom;
pub mod persist;
pub mod ridge;
pub mod schedule;
pub mod sgd;

pub use complete::{check_complete, CompleteKronRidge, EigenLooCell, EigenPrecond, EigenRidge};
pub use linear_op::{LinOp, ShiftedOp};
pub use minres::{minres, MinresOptions, MinresOutcome};
pub use ridge::{PairwiseRidge, RidgeConfig, RidgeModel};
pub use schedule::StepSchedule;
pub use sgd::{fit_sgd, SgdConfig, SgdRun, SgdTrainer};

/// The training algorithms `gvt-rls train --solver` (and the
/// coordinator's tuning paths) select between. MINRES and CG are exact
/// Krylov solvers — one full GVT product per iteration; SGD is the
/// stochastic vec trick trainer — one batch-shaped product per step
/// (see [`sgd`] for the cost model); EIGEN is the direct complete-grid
/// lane — no iterations at all (see [`complete`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// MINRES (the paper's solver; handles symmetric indefinite shifts).
    Minres,
    /// Conjugate gradient (SPD systems; the Falkon baseline's solver).
    Cg,
    /// Mini-batched stochastic vec trick ([`SgdTrainer`]).
    Sgd,
    /// Closed-form Kronecker eigen shortcut ([`EigenRidge`]) — complete
    /// grids only, with exact LOOCV for free λ selection.
    Eigen,
}

impl Solver {
    /// All solvers, exact first.
    pub const ALL: [Solver; 4] =
        [Solver::Minres, Solver::Cg, Solver::Sgd, Solver::Eigen];

    /// Canonical name (CLI flags, bench labels, reports).
    pub fn name(&self) -> &'static str {
        match self {
            Solver::Minres => "minres",
            Solver::Cg => "cg",
            Solver::Sgd => "sgd",
            Solver::Eigen => "eigen",
        }
    }

    /// Parse a CLI token (exactly the [`Self::name`] vocabulary — the
    /// CLI's `opt_choice` whitelist and this parser must stay one
    /// vocabulary).
    pub fn parse(s: &str) -> Option<Solver> {
        match s.to_ascii_lowercase().as_str() {
            "minres" => Some(Solver::Minres),
            "cg" => Some(Solver::Cg),
            "sgd" => Some(Solver::Sgd),
            "eigen" => Some(Solver::Eigen),
            _ => None,
        }
    }

    /// Does this solver take stochastic (mini-batched) steps rather than
    /// exact Krylov iterations? Stochastic solvers need the pairwise
    /// training structure (batch row sampling), not just a [`LinOp`].
    pub fn is_stochastic(&self) -> bool {
        matches!(self, Solver::Sgd)
    }

    /// Is this a direct (non-iterative) solver? Direct solvers have no
    /// iteration budget or convergence tolerance — and stricter input
    /// requirements (complete grid, Kronecker kernel).
    pub fn is_direct(&self) -> bool {
        matches!(self, Solver::Eigen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solver_parse_roundtrip() {
        for s in Solver::ALL {
            assert_eq!(Solver::parse(s.name()), Some(s));
        }
        assert_eq!(Solver::parse("newton"), None);
        assert!(Solver::Sgd.is_stochastic());
        assert!(!Solver::Minres.is_stochastic());
        assert!(!Solver::Cg.is_stochastic());
        assert!(!Solver::Eigen.is_stochastic());
        assert!(Solver::Eigen.is_direct());
        assert!(!Solver::Minres.is_direct());
    }
}
