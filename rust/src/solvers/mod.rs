//! Iterative and direct solvers for the regularized least-squares problem
//! `(K + λI) a = y` (Equation 1 of the paper).
//!
//! * [`linear_op`] — the operator abstraction: anything that can multiply
//!   a vector (GVT ops, explicit matrices, shifted/scaled compositions).
//! * [`minres`] — the minimum residual method (Paige & Saunders 1975),
//!   the paper's training algorithm (`scipy.sparse.linalg.minres`
//!   equivalent) with per-iteration callbacks for early stopping.
//! * [`cg`] — conjugate gradient, used by the Nyström/Falkon baseline.
//! * [`ridge`] — kernel ridge regression over pairwise kernels with
//!   validation-based early stopping (the paper's training protocol).
//! * [`nystrom`] — Falkon-style Nyström approximation baseline (§6.5).
//! * [`closed_form`] — `O(n³)` Cholesky oracle for tests/small problems.

pub mod cg;
pub mod closed_form;
pub mod complete;
pub mod linear_op;
pub mod minres;
pub mod nystrom;
pub mod persist;
pub mod ridge;

pub use linear_op::{LinOp, ShiftedOp};
pub use minres::{minres, MinresOptions, MinresOutcome};
pub use ridge::{PairwiseRidge, RidgeConfig, RidgeModel};
