//! Falkon-style Nyström approximation (Rudi, Carratino, Rosasco 2017) —
//! the state-of-the-art large-scale kernel baseline the paper compares
//! against in §6.5.
//!
//! The learned function is restricted to `N` center pairs sampled
//! uniformly from the training set. With `K_nm ∈ R^{n×N}` (training ×
//! centers) and `K_mm ∈ R^{N×N}`, the estimator solves
//!
//! ```text
//! (K_nmᵀ K_nm + λ n K_mm) β = K_nmᵀ y
//! ```
//!
//! by preconditioned conjugate gradient with the Falkon preconditioner
//! `M = n (K_mm²/N + λ K_mm)` applied through two Cholesky factors —
//! `M⁻¹v = L⁻ᵀ A⁻¹ L⁻¹ v / n`, `K_mm = LLᵀ`, `A = LᵀL/N + λI`.
//!
//! Storage is dominated by `K_nm` — exactly the paper's observation that
//! "a kernel matrix with 1 024 000 samples and 2048 basis vectors already
//! consumes 16GiB". [`NystromModel::knm_bytes`] reports it for Figure 8/9.

use crate::data::PairDataset;
use crate::error::{Context, Result};
use crate::eval::auc;
use crate::gvt::explicit::explicit_matrix;
use crate::gvt::pairwise::PairwiseKernel;
use crate::linalg::chol::Cholesky;
use crate::linalg::{Mat, vecops};
use crate::solvers::cg::{cg, CgOptions};
use crate::solvers::linear_op::LinOp;
use crate::sparse::PairIndex;
use std::ops::ControlFlow;
use std::sync::Arc;

/// Nyström/Falkon hyperparameters.
#[derive(Clone, Debug)]
pub struct NystromConfig {
    /// Number of Nyström centers (basis vectors) `N`.
    pub num_centers: usize,
    /// Regularization λ (the paper aligns with RLScore at 1e-5).
    pub lambda: f64,
    /// CG iteration cap.
    pub max_iters: usize,
    /// CG relative tolerance.
    pub rel_tol: f64,
    /// Center-sampling seed.
    pub seed: u64,
    /// Early-stopping patience on validation AUC (when validation given).
    pub patience: usize,
}

impl Default for NystromConfig {
    fn default() -> Self {
        Self {
            num_centers: 512,
            lambda: 1e-5,
            max_iters: 200,
            rel_tol: 1e-9,
            seed: 0,
            patience: 10,
        }
    }
}

/// Fitted Nyström model.
pub struct NystromModel {
    kernel: PairwiseKernel,
    d: Arc<Mat>,
    t: Arc<Mat>,
    centers: PairIndex,
    /// Coefficients over centers.
    pub beta: Vec<f64>,
    /// CG iterations used.
    pub iterations: usize,
    /// Bytes held by the `K_nm` matrix during training.
    pub knm_bytes: usize,
    /// Validation AUC curve when fitted with validation data.
    pub history: Vec<(usize, f64)>,
}

/// Normal-equations operator `x ↦ K_nmᵀ(K_nm x) + λ n K_mm x` — never
/// forms the `N×N` Gram of the normal equations explicitly.
struct NormalEqOp<'a> {
    knm: &'a Mat,
    kmm: &'a Mat,
    lambda_n: f64,
}

impl LinOp for NormalEqOp<'_> {
    fn dim_out(&self) -> usize {
        self.knm.cols()
    }

    fn dim_in(&self) -> usize {
        self.knm.cols()
    }

    fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        let v = self.knm.matvec(x); // n
        // y = K_nmᵀ v  (gemv with the transpose: accumulate rows).
        y.fill(0.0);
        for i in 0..self.knm.rows() {
            let row = self.knm.row(i);
            let vi = v[i];
            if vi != 0.0 {
                for (yj, kij) in y.iter_mut().zip(row) {
                    *yj += vi * kij;
                }
            }
        }
        let reg = self.kmm.matvec(x);
        vecops::axpy(self.lambda_n, &reg, y);
    }
}

/// The Falkon preconditioner as a [`LinOp`].
struct FalkonPrecond {
    l: Cholesky,  // K_mm = L Lᵀ
    la: Cholesky, // A = LᵀL/N + λI = La Laᵀ
    inv_n: f64,
}

impl LinOp for FalkonPrecond {
    fn dim_out(&self) -> usize {
        self.l.l().rows()
    }

    fn dim_in(&self) -> usize {
        self.l.l().rows()
    }

    fn apply_into(&self, v: &[f64], y: &mut [f64]) {
        // y = L⁻ᵀ A⁻¹ L⁻¹ v / n.
        let u = self.l.solve_lower(v);
        let w = self.la.solve(&u);
        let z = self.l.solve_upper(&w);
        for (yi, zi) in y.iter_mut().zip(&z) {
            *yi = self.inv_n * zi;
        }
    }
}

impl NystromModel {
    /// Fit without validation (fixed λ, run to tolerance).
    pub fn fit(
        data: &PairDataset,
        kernel: PairwiseKernel,
        cfg: &NystromConfig,
    ) -> Result<NystromModel> {
        Self::fit_impl(data, None, kernel, cfg)
    }

    /// Fit with early stopping on a validation sample (Figure 8 protocol).
    pub fn fit_with_validation(
        data: &PairDataset,
        validation: &PairDataset,
        kernel: PairwiseKernel,
        cfg: &NystromConfig,
    ) -> Result<NystromModel> {
        Self::fit_impl(data, Some(validation), kernel, cfg)
    }

    fn fit_impl(
        data: &PairDataset,
        validation: Option<&PairDataset>,
        kernel: PairwiseKernel,
        cfg: &NystromConfig,
    ) -> Result<NystromModel> {
        let n = data.len();
        let nc = cfg.num_centers.min(n);
        // Uniform center sampling (Falkon's default).
        let mut rng = crate::rng::Xoshiro256::seed_from(cfg.seed);
        let center_rows = crate::rng::dist::sample_without_replacement(&mut rng, n, nc);
        let centers = data.pairs.subset(&center_rows);

        // Materialize K_nm and K_mm (the memory cost Falkon pays).
        let knm = explicit_matrix(kernel, &data.d, &data.t, &data.pairs, &centers);
        let kmm = explicit_matrix(kernel, &data.d, &data.t, &centers, &centers);
        let knm_bytes = knm.rows() * knm.cols() * 8;

        // Preconditioner factors (jitter for numerical PD).
        let mut kmm_j = kmm.clone();
        for i in 0..nc {
            kmm_j[(i, i)] += 1e-8 * (1.0 + kmm[(i, i)].abs());
        }
        let l = Cholesky::factor(&kmm_j).context("Falkon preconditioner: chol(K_mm)")?;
        // A = LᵀL/N + λI.
        let lt = l.l().transpose();
        let mut a = lt.matmul(l.l());
        a.scale(1.0 / nc as f64);
        for i in 0..nc {
            a[(i, i)] += cfg.lambda.max(1e-12);
        }
        let la = Cholesky::factor(&a).context("Falkon preconditioner: chol(A)")?;
        let precond = FalkonPrecond { l, la, inv_n: 1.0 / n as f64 };

        // RHS: K_nmᵀ y.
        let mut rhs = vec![0.0; nc];
        for i in 0..n {
            let row = knm.row(i);
            let yi = data.y[i];
            for (rj, kij) in rhs.iter_mut().zip(row) {
                *rj += yi * kij;
            }
        }

        let op = NormalEqOp { knm: &knm, kmm: &kmm, lambda_n: cfg.lambda * n as f64 };

        // Validation machinery.
        let val_data = validation.map(|v| {
            let kx = explicit_matrix(kernel, &data.d, &data.t, &v.pairs, &centers);
            (kx, v.binary_labels())
        });
        let mut history = Vec::new();
        let mut best_auc = f64::NEG_INFINITY;
        let mut since_best = 0usize;

        let out = cg(
            &op,
            &rhs,
            Some(&precond),
            &CgOptions { max_iters: cfg.max_iters, rel_tol: cfg.rel_tol },
            |k, x, _| {
                if let Some((kx, labels)) = &val_data {
                    let preds = kx.matvec(x);
                    let a = auc(&preds, labels).unwrap_or(0.5);
                    history.push((k, a));
                    if a > best_auc {
                        best_auc = a;
                        since_best = 0;
                    } else {
                        since_best += 1;
                        if since_best >= cfg.patience {
                            return ControlFlow::Break(());
                        }
                    }
                }
                ControlFlow::Continue(())
            },
        )?;

        Ok(NystromModel {
            kernel,
            d: data.d.clone(),
            t: data.t.clone(),
            centers,
            beta: out.x,
            iterations: out.iterations,
            knm_bytes,
            history,
        })
    }

    /// Predict: `p = K(test, centers) β`.
    pub fn predict(&self, pairs: &PairIndex) -> Vec<f64> {
        let kx = explicit_matrix(self.kernel, &self.d, &self.t, pairs, &self.centers);
        kx.matvec(&self.beta)
    }

    /// Number of Nyström centers the model was fit with.
    pub fn num_centers(&self) -> usize {
        self.centers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{dist, Xoshiro256};
    use crate::solvers::closed_form::ClosedFormModel;
    use crate::testing::gen;

    fn toy(seed: u64, n: usize, m: usize, q: usize) -> PairDataset {
        let mut rng = Xoshiro256::seed_from(seed);
        let d = Arc::new(gen::psd_kernel(&mut rng, m));
        let t = Arc::new(gen::psd_kernel(&mut rng, q));
        let pairs = gen::pair_sample(&mut rng, n, m, q);
        let y = dist::normal_vec(&mut rng, n);
        PairDataset { name: "ny".into(), d, t, pairs, y, homogeneous: m == q }
    }

    #[test]
    fn full_rank_nystrom_matches_closed_form() {
        // With N == n, Nyström is exact (same hypothesis space); predictions
        // must match the closed-form ridge solution.
        let data = toy(120, 60, 7, 8);
        let cfg = NystromConfig {
            num_centers: 60,
            lambda: 1e-3,
            max_iters: 4000,
            rel_tol: 1e-13,
            ..Default::default()
        };
        let ny = NystromModel::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        let cf = ClosedFormModel::fit(&data, PairwiseKernel::Kronecker, 60.0 * 1e-3).unwrap();
        // NOTE: Falkon's objective is ‖Kβ − y‖² + λn βᵀKβ ⇒ matches ridge
        // with λ_ridge = λ·n.
        let mut rng = Xoshiro256::seed_from(121);
        let test = gen::pair_sample(&mut rng, 25, 7, 8);
        let p1 = ny.predict(&test);
        let p2 = cf.predict(&test);
        for (a, b) in p1.iter().zip(&p2) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn training_error_decreases_monotonically_with_rank() {
        // Same seed ⇒ the sampled center sets are prefix-nested
        // (dist::sample_without_replacement is a partial Fisher–Yates),
        // so every rank step only enlarges the hypothesis space: with a
        // vanishing regularizer the training error must be monotone
        // non-increasing across ranks and strictly smaller at full rank.
        let data = toy(122, 200, 10, 10);
        let ranks = [10, 25, 50, 100, 200];
        let mut errs = Vec::new();
        for &nc in &ranks {
            let cfg = NystromConfig {
                num_centers: nc,
                lambda: 1e-6,
                max_iters: 3000,
                rel_tol: 1e-12,
                ..Default::default()
            };
            let ny = NystromModel::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
            let p = ny.predict(&data.pairs);
            errs.push(crate::eval::rmse(&p, &data.y));
        }
        for (w, (&r0, &r1)) in errs.windows(2).zip(ranks.iter().zip(&ranks[1..])) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-6) + 1e-9,
                "train error rose from rank {r0} ({}) to rank {r1} ({}): {errs:?}",
                w[0],
                w[1]
            );
        }
        assert!(
            errs[ranks.len() - 1] < 0.5 * errs[0],
            "full rank should fit far better than rank {}: {errs:?}",
            ranks[0]
        );
    }

    #[test]
    fn memory_accounting() {
        let data = toy(123, 100, 9, 9);
        let cfg = NystromConfig { num_centers: 32, ..Default::default() };
        let ny = NystromModel::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        assert_eq!(ny.knm_bytes, 100 * 32 * 8);
        assert_eq!(ny.num_centers(), 32);
    }
}
