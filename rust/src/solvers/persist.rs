//! Model persistence: save/load fitted ridge models so the coordinator
//! can train once and serve later (kernel matrices are reloaded from the
//! dataset side; the model file stores what the representer theorem needs
//! — the dual coefficients and the training sample).
//!
//! Format (versioned, line-oriented text — no serde offline):
//!
//! ```text
//! gvt-rls-model v1
//! kernel <name>
//! domains <m> <q>
//! pairs <n>
//! <d_0> <t_0>
//! …
//! alpha
//! <a_0>
//! …
//! ```

use crate::error::{bail, Context, Result};
use crate::gvt::pairwise::PairwiseKernel;
use crate::gvt::vec_trick::GvtPolicy;
use crate::linalg::Mat;
use crate::solvers::ridge::RidgeModel;
use crate::sparse::PairIndex;
use std::io::Write;
use std::path::Path;
use std::sync::Arc;

/// Serialize a fitted model to `path`.
pub fn save_model(model: &RidgeModel, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    let pairs = model.train_pairs();
    writeln!(f, "gvt-rls-model v1")?;
    writeln!(f, "kernel {}", model.kernel().name())?;
    writeln!(f, "domains {} {}", pairs.m(), pairs.q())?;
    writeln!(f, "pairs {}", pairs.len())?;
    for i in 0..pairs.len() {
        writeln!(f, "{} {}", pairs.drug(i), pairs.target(i))?;
    }
    writeln!(f, "alpha")?;
    for a in &model.alpha {
        // {:e} round-trips f64 exactly enough at 17 significant digits.
        writeln!(f, "{a:.17e}")?;
    }
    Ok(())
}

/// Load a model saved by [`save_model`]. The kernel matrices are supplied
/// by the caller (they belong to the dataset, not the model).
pub fn load_model(path: &Path, d: Arc<Mat>, t: Arc<Mat>) -> Result<RidgeModel> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut lines = text.lines();
    let header = lines.next().context("empty model file")?;
    if header != "gvt-rls-model v1" {
        bail!("unsupported model header {header:?}");
    }
    let kernel_line = lines.next().context("missing kernel line")?;
    let kernel_name =
        kernel_line.strip_prefix("kernel ").context("malformed kernel line")?;
    let kernel = PairwiseKernel::parse(kernel_name)
        .with_context(|| format!("unknown kernel {kernel_name:?}"))?;
    let domains = lines.next().context("missing domains line")?;
    let mut it = domains.strip_prefix("domains ").context("malformed domains")?.split(' ');
    let m: usize = it.next().context("missing m")?.parse()?;
    let q: usize = it.next().context("missing q")?.parse()?;
    let npairs_line = lines.next().context("missing pairs line")?;
    let n: usize =
        npairs_line.strip_prefix("pairs ").context("malformed pairs line")?.parse()?;
    let mut drugs = Vec::with_capacity(n);
    let mut targets = Vec::with_capacity(n);
    for _ in 0..n {
        let line = lines.next().context("truncated pair list")?;
        let (dstr, tstr) = line.split_once(' ').context("malformed pair")?;
        drugs.push(dstr.parse::<u32>()?);
        targets.push(tstr.parse::<u32>()?);
    }
    if lines.next() != Some("alpha") {
        bail!("missing alpha section");
    }
    let mut alpha = Vec::with_capacity(n);
    for _ in 0..n {
        alpha.push(lines.next().context("truncated alpha")?.parse::<f64>()?);
    }
    if d.rows() != m || t.rows() != q {
        bail!(
            "kernel matrices ({}, {}) do not match model domains ({m}, {q})",
            d.rows(),
            t.rows()
        );
    }
    RidgeModel::from_parts(
        kernel,
        d,
        t,
        PairIndex::new(drugs, targets, m, q),
        GvtPolicy::Auto,
        alpha,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::metz::MetzConfig;
    use crate::solvers::ridge::{PairwiseRidge, RidgeConfig};
    use crate::testing::gen;

    #[test]
    fn roundtrip_preserves_predictions() {
        let data = MetzConfig::small().generate(70);
        let cfg = RidgeConfig { max_iters: 40, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        let path = std::env::temp_dir().join(format!("gvt_model_{}.txt", std::process::id()));
        save_model(&model, &path).unwrap();
        let loaded = load_model(&path, data.d.clone(), data.t.clone()).unwrap();
        let mut rng = crate::rng::Xoshiro256::seed_from(71);
        let test = gen::pair_sample(&mut rng, 25, data.pairs.m(), data.pairs.q());
        let p1 = model.predict(&test).unwrap();
        let p2 = loaded.predict(&test).unwrap();
        assert!(crate::linalg::vecops::max_abs_diff(&p1, &p2) < 1e-12);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_mismatched_kernels() {
        let data = MetzConfig::small().generate(72);
        let cfg = RidgeConfig { max_iters: 10, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Linear, &cfg).unwrap();
        let path = std::env::temp_dir().join(format!("gvt_model2_{}.txt", std::process::id()));
        save_model(&model, &path).unwrap();
        // Wrong-domain kernel matrix must be rejected, not silently used.
        let mut rng = crate::rng::Xoshiro256::seed_from(73);
        let wrong = std::sync::Arc::new(gen::psd_kernel(&mut rng, 3));
        assert!(load_model(&path, wrong, data.t.clone()).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = std::env::temp_dir().join(format!("gvt_model3_{}.txt", std::process::id()));
        std::fs::write(&path, "not a model").unwrap();
        let data = MetzConfig::small().generate(74);
        assert!(load_model(&path, data.d.clone(), data.t.clone()).is_err());
        std::fs::remove_file(&path).ok();
    }
}
