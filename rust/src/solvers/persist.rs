//! Model persistence: save/load fitted ridge models so the coordinator
//! can train once and serve later. The model file stores what the
//! representer theorem needs — the dual coefficients and the training
//! sample — plus, in v2, everything a prediction server needs to start
//! from a single file.
//!
//! Two versioned, line-oriented text formats (no serde offline):
//!
//! **v1** (legacy, still loadable; kernel matrices supplied by the caller):
//!
//! ```text
//! gvt-rls-model v1
//! kernel <name>
//! domains <m> <q>
//! pairs <n>
//! <d_0> <t_0>
//! …
//! alpha
//! <a_0>
//! …
//! ```
//!
//! **v2** adds the GVT policy, the training λ, and optional embedded
//! payloads, terminated by an explicit `end`:
//!
//! ```text
//! gvt-rls-model v2
//! kernel <name>
//! policy <auto|sparse-left|sparse-right|dense>
//! lambda <float or 'unknown'>
//! domains <m> <q>
//! pairs <n>
//! <d_0> <t_0>
//! …
//! alpha
//! <a_0>
//! …
//! dmatrix <rows> <cols>          # optional: full-domain drug kernel
//! <row of floats>
//! …
//! tmatrix <rows> <cols>          # optional: full-domain target kernel
//! …
//! dfeatures <rows> <cols> <base-kernel> <gamma> <degree> <coef0>
//! <row of floats>                # optional: drug features + base kernel,
//! …                              # for cross-kernel rows of unseen drugs
//! tfeatures <rows> <cols> <base-kernel> <gamma> <degree> <coef0>
//! …
//! end
//! ```
//!
//! All floats are written with 17 significant decimal digits (`{:.17e}`),
//! which round-trips `f64` exactly — the round-trip property test below
//! pins bit-exact `alpha` reproduction.

use crate::error::{bail, Context, Result};
use crate::gvt::pairwise::PairwiseKernel;
use crate::gvt::vec_trick::GvtPolicy;
use crate::kernels::{cross_kernel_matrix, kernel_matrix, BaseKernel, KernelParams};
use crate::linalg::Mat;
use crate::solvers::ridge::RidgeModel;
use crate::sparse::PairIndex;
use std::io::Write;
use std::path::Path;
use std::sync::Arc;

/// A feature space bundled in a v2 artifact: the training objects' raw
/// feature matrix plus the base kernel that derived the operator matrix
/// from it. A server uses this to assemble cross-kernel rows
/// `k(x_new, X[j,:])` for objects it has never seen.
#[derive(Clone)]
pub struct FeatureSpace {
    /// One training object per row.
    pub x: Mat,
    pub kernel: BaseKernel,
    pub params: KernelParams,
}

impl FeatureSpace {
    /// Cross-kernel row of a query object against every training object
    /// (the 1-row case of [`cross_kernel_matrix`]).
    pub fn cross_row(&self, query: &[f64]) -> Result<Vec<f64>> {
        if query.len() != self.x.cols() {
            bail!(
                "feature dimension {} != training feature dimension {}",
                query.len(),
                self.x.cols()
            );
        }
        let q = Mat::from_vec(1, query.len(), query.to_vec());
        Ok(cross_kernel_matrix(self.kernel, &self.params, &q, &self.x).into_vec())
    }

    /// The full-domain operator matrix this space derives.
    pub fn kernel_matrix(&self) -> Mat {
        kernel_matrix(self.kernel, &self.params, &self.x)
    }

    /// Does this space reproduce `mat` (the model's operator matrix)?
    /// False for any post-hoc transform the `(features, base kernel)`
    /// pair cannot represent — e.g. `normalize_kernel` applied after
    /// `kernel_matrix`, as the Metz/Merget pipelines do. Serving mixes
    /// rows of `mat` (known objects) with `cross_row`s (featured
    /// objects), so an inconsistent space would silently scale featured
    /// scores wrong; callers reject it up front instead.
    pub fn reproduces(&self, mat: &Mat) -> bool {
        if mat.shape() != (self.x.rows(), self.x.rows()) {
            return false;
        }
        let derived = self.kernel_matrix();
        let scale = mat
            .as_slice()
            .iter()
            .fold(1.0_f64, |m, v| m.max(v.abs()));
        derived.max_abs_diff(mat) <= 1e-9 * scale
    }
}

/// Everything a model file contains, before kernel-matrix resolution.
pub struct ModelFile {
    pub version: u8,
    pub kernel: PairwiseKernel,
    /// `Auto` for v1 files (which predate the field).
    pub policy: GvtPolicy,
    /// `NaN` when the file does not record λ (v1, or `lambda unknown`).
    pub lambda: f64,
    pub m: usize,
    pub q: usize,
    pub drugs: Vec<u32>,
    pub targets: Vec<u32>,
    pub alpha: Vec<f64>,
    /// Embedded full-domain kernel matrices (v2, optional).
    pub d: Option<Mat>,
    pub t: Option<Mat>,
    /// Embedded feature spaces (v2, optional).
    pub d_features: Option<FeatureSpace>,
    pub t_features: Option<FeatureSpace>,
}

/// Line reader that tracks its position so every parse failure can name
/// the offending line and the section the parser expected there — a
/// truncated or half-written artifact produces "line 412: unexpected end
/// of file (expected a matrix row)" instead of a bare parse error, which
/// is what a failed hot reload surfaces to the operator.
struct Reader<'a> {
    lines: std::str::Lines<'a>,
    line_no: usize,
}

impl<'a> Reader<'a> {
    fn next(&mut self, expected: &str) -> Result<&'a str> {
        self.line_no += 1;
        self.lines.next().with_context(|| {
            format!("line {}: unexpected end of file (expected {expected})", self.line_no)
        })
    }
}

impl ModelFile {
    /// Parse a v1 or v2 model file. Every failure is a contextual error
    /// naming the line offset and the section being read.
    pub fn read(path: &Path) -> Result<ModelFile> {
        let mut text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        // Fault point for the serving robustness suite: corrupt or fail
        // the artifact *after* the filesystem read so reload error paths
        // are exercised deterministically (see [`crate::runtime::fault`]).
        match crate::runtime::fault::trip("artifact_read") {
            Some(crate::runtime::fault::Fired::Truncate) => {
                let mut keep = text.len() / 2;
                while keep > 0 && !text.is_char_boundary(keep) {
                    keep -= 1;
                }
                text.truncate(keep);
            }
            Some(crate::runtime::fault::Fired::Error) => {
                bail!("injected fault: artifact_read ({})", path.display());
            }
            None => {}
        }
        Self::parse(&text)
            .with_context(|| format!("parsing model file {}", path.display()))
    }

    fn parse(text: &str) -> Result<ModelFile> {
        let mut r = Reader { lines: text.lines(), line_no: 0 };
        let header = r.next("the 'gvt-rls-model' header")?;
        let version = match header {
            "gvt-rls-model v1" => 1u8,
            "gvt-rls-model v2" => 2u8,
            other => bail!("line 1: unsupported model header {other:?}"),
        };
        let kernel_line = r.next("the kernel line")?;
        let kernel_name = kernel_line.strip_prefix("kernel ").with_context(|| {
            format!("line {}: malformed kernel line {kernel_line:?}", r.line_no)
        })?;
        let kernel = PairwiseKernel::parse(kernel_name)
            .with_context(|| format!("line {}: unknown kernel {kernel_name:?}", r.line_no))?;
        let (policy, lambda) = if version >= 2 {
            let pl = r.next("the policy line")?;
            let pname = pl.strip_prefix("policy ").with_context(|| {
                format!("line {}: malformed policy line {pl:?}", r.line_no)
            })?;
            let policy = GvtPolicy::parse(pname).with_context(|| {
                format!("line {}: unknown policy {pname:?}", r.line_no)
            })?;
            let ll = r.next("the lambda line")?;
            let lstr = ll.strip_prefix("lambda ").with_context(|| {
                format!("line {}: malformed lambda line {ll:?}", r.line_no)
            })?;
            let lambda = if lstr == "unknown" {
                f64::NAN
            } else {
                lstr.parse::<f64>().with_context(|| {
                    format!("line {}: malformed lambda value {lstr:?}", r.line_no)
                })?
            };
            (policy, lambda)
        } else {
            (GvtPolicy::Auto, f64::NAN)
        };
        let domains = r.next("the domains line")?;
        let mut it = domains
            .strip_prefix("domains ")
            .with_context(|| format!("line {}: malformed domains line {domains:?}", r.line_no))?
            .split(' ');
        let m: usize = it
            .next()
            .with_context(|| format!("line {}: domains line missing m", r.line_no))?
            .parse()
            .with_context(|| format!("line {}: malformed domain size m", r.line_no))?;
        let q: usize = it
            .next()
            .with_context(|| format!("line {}: domains line missing q", r.line_no))?
            .parse()
            .with_context(|| format!("line {}: malformed domain size q", r.line_no))?;
        let npairs_line = r.next("the pairs count")?;
        let n: usize = npairs_line
            .strip_prefix("pairs ")
            .with_context(|| {
                format!("line {}: malformed pairs line {npairs_line:?}", r.line_no)
            })?
            .parse()
            .with_context(|| format!("line {}: malformed pair count", r.line_no))?;
        let mut drugs = Vec::with_capacity(n);
        let mut targets = Vec::with_capacity(n);
        for i in 0..n {
            let line = r
                .next("a pair row")
                .with_context(|| format!("pair list truncated at pair {i} of {n}"))?;
            let (dstr, tstr) = line
                .split_once(' ')
                .with_context(|| format!("line {}: malformed pair {line:?}", r.line_no))?;
            let d = dstr.parse::<u32>().with_context(|| {
                format!("line {}: malformed drug index {dstr:?}", r.line_no)
            })?;
            let t = tstr.parse::<u32>().with_context(|| {
                format!("line {}: malformed target index {tstr:?}", r.line_no)
            })?;
            if d as usize >= m || t as usize >= q {
                bail!("line {}: pair ({d}, {t}) outside domains ({m}, {q})", r.line_no);
            }
            drugs.push(d);
            targets.push(t);
        }
        let alpha_header = r.next("the 'alpha' section header")?;
        if alpha_header != "alpha" {
            bail!(
                "line {}: expected the 'alpha' section header, found {alpha_header:?}",
                r.line_no
            );
        }
        let mut alpha = Vec::with_capacity(n);
        for i in 0..n {
            let line = r
                .next("an alpha coefficient")
                .with_context(|| format!("alpha section truncated at entry {i} of {n}"))?;
            alpha.push(line.parse::<f64>().with_context(|| {
                format!("line {}: malformed alpha value {line:?}", r.line_no)
            })?);
        }

        let mut file = ModelFile {
            version,
            kernel,
            policy,
            lambda,
            m,
            q,
            drugs,
            targets,
            alpha,
            d: None,
            t: None,
            d_features: None,
            t_features: None,
        };
        if version >= 2 {
            loop {
                let line = r.next("a v2 section header or the 'end' terminator")?;
                if line == "end" {
                    break;
                }
                let mut fields = line.split(' ');
                let section = fields
                    .next()
                    .with_context(|| format!("line {}: empty section header", r.line_no))?;
                match section {
                    "dmatrix" | "tmatrix" => {
                        let header_line = r.line_no;
                        let rows: usize = fields
                            .next()
                            .with_context(|| {
                                format!("line {header_line}: {section} header missing rows")
                            })?
                            .parse()
                            .with_context(|| {
                                format!("line {header_line}: malformed {section} rows")
                            })?;
                        let cols: usize = fields
                            .next()
                            .with_context(|| {
                                format!("line {header_line}: {section} header missing cols")
                            })?
                            .parse()
                            .with_context(|| {
                                format!("line {header_line}: malformed {section} cols")
                            })?;
                        let mat = read_matrix(&mut r, rows, cols)
                            .with_context(|| format!("reading the {section} section"))?;
                        if section == "dmatrix" {
                            file.d = Some(mat);
                        } else {
                            file.t = Some(mat);
                        }
                    }
                    "dfeatures" | "tfeatures" => {
                        let header_line = r.line_no;
                        let mut field = |name: &str| {
                            fields.next().with_context(|| {
                                format!("line {header_line}: {section} header missing {name}")
                            })
                        };
                        let rows: usize = field("rows")?.parse().with_context(|| {
                            format!("line {header_line}: malformed {section} rows")
                        })?;
                        let cols: usize = field("cols")?.parse().with_context(|| {
                            format!("line {header_line}: malformed {section} cols")
                        })?;
                        let kname = field("the base kernel name")?;
                        let base = BaseKernel::parse(kname).with_context(|| {
                            format!("line {header_line}: unknown base kernel {kname:?}")
                        })?;
                        let gamma: f64 = field("gamma")?.parse().with_context(|| {
                            format!("line {header_line}: malformed {section} gamma")
                        })?;
                        let degree: u32 = field("degree")?.parse().with_context(|| {
                            format!("line {header_line}: malformed {section} degree")
                        })?;
                        let coef0: f64 = field("coef0")?.parse().with_context(|| {
                            format!("line {header_line}: malformed {section} coef0")
                        })?;
                        let x = read_matrix(&mut r, rows, cols)
                            .with_context(|| format!("reading the {section} section"))?;
                        let fs = FeatureSpace {
                            x,
                            kernel: base,
                            params: KernelParams { gamma, degree, coef0 },
                        };
                        if section == "dfeatures" {
                            file.d_features = Some(fs);
                        } else {
                            file.t_features = Some(fs);
                        }
                    }
                    other => bail!("line {}: unknown v2 section {other:?}", r.line_no),
                }
            }
        }
        Ok(file)
    }

    /// Build the fitted model, resolving each kernel matrix in priority
    /// order: caller-supplied > embedded matrix > recomputed from an
    /// embedded feature space.
    pub fn into_model(
        self,
        d: Option<Arc<Mat>>,
        t: Option<Arc<Mat>>,
    ) -> Result<RidgeModel> {
        let ModelFile {
            kernel,
            policy,
            lambda,
            m,
            q,
            drugs,
            targets,
            alpha,
            d: d_embedded,
            t: t_embedded,
            d_features,
            t_features,
            ..
        } = self;
        let d = resolve_matrix("drug", d, d_embedded, d_features.as_ref())?;
        let t = resolve_matrix("target", t, t_embedded, t_features.as_ref())?;
        if d.rows() != m || t.rows() != q {
            bail!(
                "kernel matrices ({}, {}) do not match model domains ({m}, {q})",
                d.rows(),
                t.rows()
            );
        }
        RidgeModel::from_parts(
            kernel,
            d,
            t,
            PairIndex::new(drugs, targets, m, q),
            policy,
            alpha,
            lambda,
        )
    }
}

fn resolve_matrix(
    side: &str,
    supplied: Option<Arc<Mat>>,
    embedded: Option<Mat>,
    features: Option<&FeatureSpace>,
) -> Result<Arc<Mat>> {
    if let Some(m) = supplied {
        return Ok(m);
    }
    if let Some(m) = embedded {
        return Ok(Arc::new(m));
    }
    if let Some(fs) = features {
        return Ok(Arc::new(fs.kernel_matrix()));
    }
    bail!(
        "cannot resolve the {side} kernel matrix: not supplied by the caller \
         and the artifact embeds neither a matrix nor a feature space"
    )
}

fn read_matrix(r: &mut Reader<'_>, rows: usize, cols: usize) -> Result<Mat> {
    let mut data = Vec::with_capacity(rows * cols);
    for row in 0..rows {
        let line = r
            .next("a matrix row")
            .with_context(|| format!("matrix truncated at row {row} of {rows}"))?;
        let before = data.len();
        for tok in line.split(' ') {
            data.push(tok.parse::<f64>().with_context(|| {
                format!("line {}: malformed matrix entry {tok:?}", r.line_no)
            })?);
        }
        if data.len() - before != cols {
            bail!(
                "line {}: matrix row {row} has {} entries, expected {cols}",
                r.line_no,
                data.len() - before
            );
        }
    }
    Ok(Mat::from_vec(rows, cols, data))
}

fn write_matrix(f: &mut impl Write, mat: &Mat) -> Result<()> {
    for r in 0..mat.rows() {
        let row = mat.row(r);
        let mut line = String::with_capacity(row.len() * 24);
        for (i, v) in row.iter().enumerate() {
            if i > 0 {
                line.push(' ');
            }
            line.push_str(&format!("{v:.17e}"));
        }
        writeln!(f, "{line}")?;
    }
    Ok(())
}

/// Optional embedded payloads for [`save_model_v2`].
#[derive(Default)]
pub struct EmbedV2<'a> {
    /// Embed the full-domain kernel matrices — the artifact alone can
    /// then serve every in-domain query (all four prediction settings).
    pub matrices: bool,
    /// Embed drug features + the base kernel deriving `D` — enables
    /// cross-kernel rows for drugs outside the training domain.
    pub d_features: Option<(&'a Mat, BaseKernel, KernelParams)>,
    /// Target-side counterpart of `d_features`.
    pub t_features: Option<(&'a Mat, BaseKernel, KernelParams)>,
}

/// Serialize a fitted model to `path` in the **v1** format (kernel
/// matrices reloaded from the dataset side at load time).
pub fn save_model(model: &RidgeModel, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    let pairs = model.train_pairs();
    writeln!(f, "gvt-rls-model v1")?;
    writeln!(f, "kernel {}", model.kernel().name())?;
    writeln!(f, "domains {} {}", pairs.m(), pairs.q())?;
    writeln!(f, "pairs {}", pairs.len())?;
    for i in 0..pairs.len() {
        writeln!(f, "{} {}", pairs.drug(i), pairs.target(i))?;
    }
    writeln!(f, "alpha")?;
    for a in &model.alpha {
        // {:e} round-trips f64 exactly enough at 17 significant digits.
        writeln!(f, "{a:.17e}")?;
    }
    Ok(())
}

/// Serialize a fitted model to `path` in the **v2** format, optionally
/// bundling kernel matrices and/or feature spaces so a prediction server
/// starts from this single file (see [`crate::serve`]).
pub fn save_model_v2(model: &RidgeModel, path: &Path, embed: &EmbedV2<'_>) -> Result<()> {
    // Refuse to bundle a feature space that cannot reproduce the model's
    // operator matrix (e.g. a post-hoc normalized kernel): a server
    // would mix matrix rows (known objects) with feature-derived rows
    // (featured objects) on different scales — silently wrong scores.
    for (side, spec, mat) in [
        ("drug", &embed.d_features, model.d()),
        ("target", &embed.t_features, model.t()),
    ] {
        if let Some((x, base, params)) = spec {
            let fs = FeatureSpace { x: (*x).clone(), kernel: *base, params: *params };
            if !fs.reproduces(&mat) {
                bail!(
                    "{side} feature space does not reproduce the model's {side} kernel \
                     matrix — (features, base kernel) cannot represent post-hoc \
                     transforms such as normalize_kernel; embed matrices only"
                );
            }
        }
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    let pairs = model.train_pairs();
    writeln!(f, "gvt-rls-model v2")?;
    writeln!(f, "kernel {}", model.kernel().name())?;
    writeln!(f, "policy {}", model.policy().name())?;
    if model.lambda.is_finite() {
        writeln!(f, "lambda {:.17e}", model.lambda)?;
    } else {
        writeln!(f, "lambda unknown")?;
    }
    writeln!(f, "domains {} {}", pairs.m(), pairs.q())?;
    writeln!(f, "pairs {}", pairs.len())?;
    for i in 0..pairs.len() {
        writeln!(f, "{} {}", pairs.drug(i), pairs.target(i))?;
    }
    writeln!(f, "alpha")?;
    for a in &model.alpha {
        writeln!(f, "{a:.17e}")?;
    }
    if embed.matrices {
        let d = model.d();
        writeln!(f, "dmatrix {} {}", d.rows(), d.cols())?;
        write_matrix(&mut f, &d)?;
        let t = model.t();
        writeln!(f, "tmatrix {} {}", t.rows(), t.cols())?;
        write_matrix(&mut f, &t)?;
    }
    for (section, spec) in
        [("dfeatures", &embed.d_features), ("tfeatures", &embed.t_features)]
    {
        if let Some((x, base, params)) = spec {
            writeln!(
                f,
                "{section} {} {} {} {:.17e} {} {:.17e}",
                x.rows(),
                x.cols(),
                base.name(),
                params.gamma,
                params.degree,
                params.coef0
            )?;
            write_matrix(&mut f, x)?;
        }
    }
    writeln!(f, "end")?;
    Ok(())
}

/// Load a model saved by [`save_model`] (v1) or [`save_model_v2`]. The
/// kernel matrices are supplied by the caller; for self-contained v2
/// artifacts use [`ModelFile::read`] + [`ModelFile::into_model`] with
/// `None` instead.
pub fn load_model(path: &Path, d: Arc<Mat>, t: Arc<Mat>) -> Result<RidgeModel> {
    ModelFile::read(path)?.into_model(Some(d), Some(t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::metz::MetzConfig;
    use crate::rng::{dist, Xoshiro256};
    use crate::solvers::ridge::{PairwiseRidge, RidgeConfig};
    use crate::testing::gen;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gvt_model_{tag}_{}.txt", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_predictions() {
        let data = MetzConfig::small().generate(70);
        let cfg = RidgeConfig { max_iters: 40, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        let path = tmp("v1rt");
        save_model(&model, &path).unwrap();
        let loaded = load_model(&path, data.d.clone(), data.t.clone()).unwrap();
        let mut rng = crate::rng::Xoshiro256::seed_from(71);
        let test = gen::pair_sample(&mut rng, 25, data.pairs.m(), data.pairs.q());
        let p1 = model.predict(&test).unwrap();
        let p2 = loaded.predict(&test).unwrap();
        assert!(crate::linalg::vecops::max_abs_diff(&p1, &p2) < 1e-12);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_mismatched_kernels() {
        let data = MetzConfig::small().generate(72);
        let cfg = RidgeConfig { max_iters: 10, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Linear, &cfg).unwrap();
        let path = tmp("v1mk");
        save_model(&model, &path).unwrap();
        // Wrong-domain kernel matrix must be rejected, not silently used.
        let mut rng = crate::rng::Xoshiro256::seed_from(73);
        let wrong = std::sync::Arc::new(gen::psd_kernel(&mut rng, 3));
        assert!(load_model(&path, wrong, data.t.clone()).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmp("garbage");
        std::fs::write(&path, "not a model").unwrap();
        let data = MetzConfig::small().generate(74);
        assert!(load_model(&path, data.d.clone(), data.t.clone()).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// The v2 round-trip property the serving stack depends on: a fully
    /// self-contained artifact (matrices + feature spaces) must reproduce
    /// `alpha` **bit-exactly**, carry kernel/policy/λ through, and the
    /// reloaded model must predict identically with no caller-side data.
    #[test]
    fn v2_roundtrip_is_exact_and_self_contained() {
        let mut rng = Xoshiro256::seed_from(75);
        let (m, q, p) = (9, 7, 4);
        let xd = Mat::from_vec(m, p, dist::normal_vec(&mut rng, m * p));
        let xt = Mat::from_vec(q, p, dist::normal_vec(&mut rng, q * p));
        let params = KernelParams { gamma: 0.3, degree: 2, coef0: 1.0 };
        let d = Arc::new(kernel_matrix(BaseKernel::Gaussian, &params, &xd));
        let t = Arc::new(kernel_matrix(BaseKernel::Gaussian, &params, &xt));
        let pairs = gen::pair_sample(&mut rng, 40, m, q);
        let data = crate::data::PairDataset {
            name: "v2rt".into(),
            d: d.clone(),
            t: t.clone(),
            pairs,
            y: dist::normal_vec(&mut rng, 40),
            homogeneous: false,
        };
        let cfg = RidgeConfig { lambda: 0.25, max_iters: 60, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Poly2D, &cfg).unwrap();

        let path = tmp("v2rt");
        let embed = EmbedV2 {
            matrices: true,
            d_features: Some((&xd, BaseKernel::Gaussian, params)),
            t_features: Some((&xt, BaseKernel::Gaussian, params)),
        };
        save_model_v2(&model, &path, &embed).unwrap();

        let file = ModelFile::read(&path).unwrap();
        assert_eq!(file.version, 2);
        assert_eq!(file.kernel, PairwiseKernel::Poly2D);
        assert_eq!(file.policy, model.policy());
        assert_eq!(file.lambda, 0.25);
        // Bit-exact alpha (17-significant-digit round-trip).
        assert_eq!(file.alpha, model.alpha);
        // Embedded matrices and features survive exactly too.
        assert_eq!(file.d.as_ref().unwrap().as_slice(), d.as_slice());
        assert_eq!(file.t.as_ref().unwrap().as_slice(), t.as_slice());
        let dfs = file.d_features.as_ref().unwrap();
        assert_eq!(dfs.x.as_slice(), xd.as_slice());
        assert_eq!(dfs.kernel, BaseKernel::Gaussian);
        assert_eq!(dfs.params, params);

        // Self-contained load: no caller-side matrices at all.
        let loaded = file.into_model(None, None).unwrap();
        let test = gen::pair_sample(&mut rng, 20, m, q);
        assert_eq!(model.predict(&test).unwrap(), loaded.predict(&test).unwrap());
        std::fs::remove_file(&path).ok();
    }

    /// Feature-space-only artifact: the kernel matrix is recomputed from
    /// the embedded features at load and must match the training-time
    /// matrix exactly (same `kernel_matrix` code path).
    #[test]
    fn v2_feature_only_artifact_recomputes_matrices() {
        let mut rng = Xoshiro256::seed_from(76);
        let (m, p) = (8, 5);
        let x = Mat::from_vec(m, p, dist::normal_vec(&mut rng, m * p));
        let params = KernelParams::default();
        let d = Arc::new(kernel_matrix(BaseKernel::Linear, &params, &x));
        let pairs = gen::homogeneous_sample(&mut rng, 30, m);
        let data = crate::data::PairDataset {
            name: "v2feat".into(),
            d: d.clone(),
            t: d.clone(),
            pairs,
            y: dist::normal_vec(&mut rng, 30),
            homogeneous: true,
        };
        let cfg = RidgeConfig { max_iters: 30, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Symmetric, &cfg).unwrap();
        let path = tmp("v2feat");
        let embed = EmbedV2 {
            matrices: false,
            d_features: Some((&x, BaseKernel::Linear, params)),
            t_features: Some((&x, BaseKernel::Linear, params)),
        };
        save_model_v2(&model, &path, &embed).unwrap();
        let loaded = ModelFile::read(&path).unwrap().into_model(None, None).unwrap();
        let test = gen::homogeneous_sample(&mut rng, 12, m);
        assert_eq!(model.predict(&test).unwrap(), loaded.predict(&test).unwrap());
        std::fs::remove_file(&path).ok();
    }

    /// A feature space that cannot reproduce the model's operator matrix
    /// (here: the kernel was cosine-normalized after `kernel_matrix`, as
    /// the Metz/Merget pipelines do) must be rejected at save — bundling
    /// it would silently serve featured objects on the wrong scale.
    #[test]
    fn v2_rejects_inconsistent_feature_space() {
        let mut rng = Xoshiro256::seed_from(78);
        let (m, p) = (7, 4);
        let x = Mat::from_vec(m, p, dist::normal_vec(&mut rng, m * p));
        let params = KernelParams::default();
        let mut dmat = kernel_matrix(BaseKernel::Linear, &params, &x);
        crate::kernels::normalize_kernel(&mut dmat);
        let d = Arc::new(dmat);
        let pairs = gen::homogeneous_sample(&mut rng, 25, m);
        let data = crate::data::PairDataset {
            name: "v2norm".into(),
            d: d.clone(),
            t: d.clone(),
            pairs,
            y: dist::normal_vec(&mut rng, 25),
            homogeneous: true,
        };
        let cfg = RidgeConfig { max_iters: 10, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        let path = tmp("v2norm");
        let embed = EmbedV2 {
            matrices: true,
            d_features: Some((&x, BaseKernel::Linear, params)),
            t_features: None,
        };
        let err = save_model_v2(&model, &path, &embed);
        assert!(err.is_err(), "normalized kernel must not pass the consistency check");
        std::fs::remove_file(&path).ok();
    }

    /// Corruption robustness: truncating a v2 artifact at any interior
    /// byte offset must yield a structured error that names the line it
    /// failed on — never a panic, never a silently short model. This is
    /// the contract the hot-reload path leans on when it rejects a
    /// half-written artifact and keeps the old model serving.
    #[test]
    fn truncated_artifacts_fail_with_located_errors() {
        let data = MetzConfig::small().generate(79);
        let cfg = RidgeConfig { max_iters: 10, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        let path = tmp("v2corrupt");
        save_model_v2(&model, &path, &EmbedV2 { matrices: true, ..Default::default() })
            .unwrap();
        let full = std::fs::read_to_string(&path).unwrap();
        let len = full.len();
        // len-4 cuts exactly the trailing "end\n"; the rest land inside
        // the header, the pair list, alpha, and the embedded matrices.
        for cut in [10, len / 4, len / 2, 3 * len / 4, len - 4] {
            let bad = tmp(&format!("v2cut{cut}"));
            std::fs::write(&bad, &full[..cut]).unwrap();
            let err = ModelFile::read(&bad).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("line "),
                "cut at {cut}/{len}: error must name a line offset: {msg}"
            );
            std::fs::remove_file(&bad).ok();
        }
        // A corrupted section header is named too, not just truncation.
        let swapped = full.replace("\nalpha\n", "\nalhpa\n");
        assert_ne!(swapped, full, "fixture must contain the alpha header");
        let bad = tmp("v2swap");
        std::fs::write(&bad, &swapped).unwrap();
        let msg = format!("{:#}", ModelFile::read(&bad).unwrap_err());
        assert!(msg.contains("'alpha' section header"), "{msg}");
        std::fs::remove_file(&bad).ok();
        std::fs::remove_file(&path).ok();
    }

    /// A v2 file with no embedded payloads still loads the v1 way —
    /// caller supplies matrices — and errors clearly when it can't.
    #[test]
    fn v2_bare_artifact_needs_caller_matrices() {
        let data = MetzConfig::small().generate(77);
        let cfg = RidgeConfig { max_iters: 10, ..Default::default() };
        let model = PairwiseRidge::fit(&data, PairwiseKernel::Kronecker, &cfg).unwrap();
        let path = tmp("v2bare");
        save_model_v2(&model, &path, &EmbedV2::default()).unwrap();
        assert!(ModelFile::read(&path).unwrap().into_model(None, None).is_err());
        let loaded = load_model(&path, data.d.clone(), data.t.clone()).unwrap();
        assert_eq!(loaded.alpha, model.alpha);
        std::fs::remove_file(&path).ok();
    }
}
